#!/usr/bin/env python3
"""Perf regression gate: compare a fresh bench trajectory point against the
committed baseline and fail on regression.

Usage (CI runs this from rust/ right after the train-bench smoke step):

    python3 ../scripts/bench_gate.py \
        --baseline ../BENCH_train.json --fresh BENCH_train.json

Gated keys are the speedup ratios (`train_speedup`, `kernel_speedup_*`):
ratios of two timings taken on the same machine in the same run, so they
are comparable across hosts in a way raw milliseconds are not.

Two kinds of checks:

* **Absolute floors** — always enforced.  The sparse engine must beat the
  dense baseline by `--train-floor` (default 5x; the full-length
  acceptance target is 10x, but CI smoke runs measure with
  FEDS_BENCH_FAST's short sampling windows, so the floor leaves noise
  margin), and every dispatched kernel must at least match the scalar
  oracle (`--kernel-floor`, default 1.0).
* **Relative band vs the baseline** — each fresh speedup must be at least
  `--band` (default 0.5) times the committed value.  Skipped for any key
  the baseline lacks, and skipped entirely when the baseline is marked
  `"bootstrap": true` (a placeholder committed before the first measured
  snapshot — floors still apply).

Exit code 0 = pass, 1 = regression, 2 = usage/IO error.
"""

import argparse
import json
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def speedup_keys(point):
    keys = [k for k in point if k == "train_speedup" or k.startswith("kernel_speedup_")]
    return sorted(keys)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed trajectory point")
    ap.add_argument("--fresh", required=True, help="just-measured trajectory point")
    ap.add_argument("--band", type=float, default=0.5,
                    help="fresh speedup must be >= band * baseline (default 0.5)")
    ap.add_argument("--train-floor", type=float, default=5.0,
                    help="absolute floor for train_speedup (default 5.0)")
    ap.add_argument("--kernel-floor", type=float, default=1.0,
                    help="absolute floor for each kernel_speedup_* (default 1.0)")
    args = ap.parse_args()

    baseline = load(args.baseline)
    fresh = load(args.fresh)
    bootstrap = bool(baseline.get("bootstrap"))

    keys = speedup_keys(fresh)
    if "train_speedup" not in keys:
        print("bench_gate: fresh point has no train_speedup — wrong file?", file=sys.stderr)
        sys.exit(2)

    failures = []
    for key in keys:
        val = float(fresh[key])
        floor = args.train_floor if key == "train_speedup" else args.kernel_floor
        verdicts = []
        if val < floor:
            failures.append(f"{key} = {val:.2f}x is below the absolute floor {floor:.2f}x")
            verdicts.append("FLOOR FAIL")
        else:
            verdicts.append("floor ok")
        if not bootstrap and key in baseline:
            want = args.band * float(baseline[key])
            if val < want:
                failures.append(
                    f"{key} = {val:.2f}x regressed below {args.band:.2f} x "
                    f"baseline {float(baseline[key]):.2f}x (= {want:.2f}x)")
                verdicts.append("BAND FAIL")
            else:
                verdicts.append(f"band ok vs {float(baseline[key]):.2f}x")
        elif bootstrap:
            verdicts.append("band skipped (bootstrap baseline)")
        else:
            verdicts.append("band skipped (key not in baseline)")
        print(f"bench_gate: {key:28s} {val:8.2f}x  [{'; '.join(verdicts)}]")

    if failures:
        print("bench_gate: FAIL", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print(f"bench_gate: PASS ({len(keys)} speedup keys checked)")


if __name__ == "__main__":
    main()
