#!/usr/bin/env python3
"""Perf regression gate: compare a fresh bench trajectory point against the
committed baseline and fail on regression.

Usage (CI runs this from rust/ right after each bench smoke step):

    python3 ../scripts/bench_gate.py \
        --baseline ../BENCH_train.json --fresh BENCH_train.json

Three point shapes are understood, detected from the fresh file:

* **Speedup points** (BENCH_train.json) gate the speedup ratios
  (`train_speedup`, `kernel_speedup_*`): ratios of two timings taken on
  the same machine in the same run, so they are comparable across hosts
  in a way raw milliseconds are not.  Bigger is better; checks are
  **floors**.
* **Scale points** (BENCH_scale.json, recognized by `scale_round_ratio`)
  gate cost ratios where *smaller* is better; checks are **ceilings**:
  `scale_round_ratio` (server round time at E=1M over E=100k at fixed
  touched-K — near 1 when per-round cost is O(touched), not O(E)) and
  `rss_fraction` (peak RSS of an E=1M mmap run over its dense table
  bytes — well below 1 when only touched pages go resident; skipped
  when the fresh point lacks it, e.g. off-Linux).
* **Bytes points** (BENCH_bytes.json, recognized by
  `bytes_reduction_topk_int8`) gate the compression frontier:
  `bytes_reduction_topk_int8` (bytes-per-round of the topk stack over
  topk,int8) must reach `--bytes-floor` (default 3.0 — int8 rows carry
  a quarter of the payload plus a per-row scale), while
  `mrr_degradation_topk_int8` (relative converged-MRR loss of topk,int8
  vs topk) must stay under `--mrr-degradation-max` (default 0.01).  The
  reduction also honors the relative band vs the committed baseline.

Two kinds of checks in either mode:

* **Absolute floors/ceilings** — always enforced.  The sparse engine
  must beat the dense baseline by `--train-floor` (default 5x; the
  full-length acceptance target is 10x, but CI smoke runs measure with
  FEDS_BENCH_FAST's short sampling windows, so the bound leaves noise
  margin), every dispatched kernel must at least match the scalar
  oracle (`--kernel-floor`, default 1.0), the scale round ratio must
  stay under `--scale-ratio-max` (default 3.0) and the RSS fraction
  under `--rss-frac-max` (default 0.75).
* **Relative band vs the baseline** — each fresh speedup must be at
  least `--band` (default 0.5) times the committed value; each fresh
  cost ratio must be at most the committed value divided by `--band`.
  Skipped for any key the baseline lacks, and skipped entirely when the
  baseline is marked `"bootstrap": true` (a placeholder committed
  before the first measured snapshot — absolute bounds still apply).

Exit code 0 = pass, 1 = regression, 2 = usage/IO error.
"""

import argparse
import json
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def speedup_keys(point):
    keys = [k for k in point if k == "train_speedup" or k.startswith("kernel_speedup_")]
    return sorted(keys)


def gate_speedups(args, baseline, fresh, bootstrap):
    """Floor checks: bigger is better. Returns (failures, checked)."""
    keys = speedup_keys(fresh)
    if "train_speedup" not in keys:
        print("bench_gate: fresh point has no train_speedup — wrong file?", file=sys.stderr)
        sys.exit(2)

    failures = []
    for key in keys:
        val = float(fresh[key])
        floor = args.train_floor if key == "train_speedup" else args.kernel_floor
        verdicts = []
        if val < floor:
            failures.append(f"{key} = {val:.2f}x is below the absolute floor {floor:.2f}x")
            verdicts.append("FLOOR FAIL")
        else:
            verdicts.append("floor ok")
        if not bootstrap and key in baseline:
            want = args.band * float(baseline[key])
            if val < want:
                failures.append(
                    f"{key} = {val:.2f}x regressed below {args.band:.2f} x "
                    f"baseline {float(baseline[key]):.2f}x (= {want:.2f}x)")
                verdicts.append("BAND FAIL")
            else:
                verdicts.append(f"band ok vs {float(baseline[key]):.2f}x")
        elif bootstrap:
            verdicts.append("band skipped (bootstrap baseline)")
        else:
            verdicts.append("band skipped (key not in baseline)")
        print(f"bench_gate: {key:28s} {val:8.2f}x  [{'; '.join(verdicts)}]")
    return failures, len(keys)


def gate_scale(args, baseline, fresh, bootstrap):
    """Ceiling checks: smaller is better. Returns (failures, checked)."""
    ceilings = [("scale_round_ratio", args.scale_ratio_max),
                ("rss_fraction", args.rss_frac_max)]
    failures = []
    checked = 0
    for key, ceiling in ceilings:
        if key not in fresh:
            # rss_fraction is absent when the bench ran without procfs
            print(f"bench_gate: {key:28s} {'—':>8}   [skipped (not in fresh point)]")
            continue
        checked += 1
        val = float(fresh[key])
        verdicts = []
        if val > ceiling:
            failures.append(f"{key} = {val:.3f} is above the absolute ceiling {ceiling:.3f}")
            verdicts.append("CEILING FAIL")
        else:
            verdicts.append("ceiling ok")
        if not bootstrap and key in baseline:
            allow = float(baseline[key]) / args.band
            if val > allow:
                failures.append(
                    f"{key} = {val:.3f} regressed above baseline "
                    f"{float(baseline[key]):.3f} / {args.band:.2f} (= {allow:.3f})")
                verdicts.append("BAND FAIL")
            else:
                verdicts.append(f"band ok vs {float(baseline[key]):.3f}")
        elif bootstrap:
            verdicts.append("band skipped (bootstrap baseline)")
        else:
            verdicts.append("band skipped (key not in baseline)")
        print(f"bench_gate: {key:28s} {val:8.3f}   [{'; '.join(verdicts)}]")
    if checked == 0:
        print("bench_gate: fresh scale point has no gateable keys", file=sys.stderr)
        sys.exit(2)
    return failures, checked


def gate_bytes(args, baseline, fresh, bootstrap):
    """Frontier checks: the reduction is a floor, the degradation a
    ceiling. Returns (failures, checked)."""
    failures = []
    checked = 0

    key = "bytes_reduction_topk_int8"
    val = float(fresh[key])
    checked += 1
    verdicts = []
    if val < args.bytes_floor:
        failures.append(f"{key} = {val:.2f}x is below the absolute floor "
                        f"{args.bytes_floor:.2f}x")
        verdicts.append("FLOOR FAIL")
    else:
        verdicts.append("floor ok")
    if not bootstrap and key in baseline:
        want = args.band * float(baseline[key])
        if val < want:
            failures.append(
                f"{key} = {val:.2f}x regressed below {args.band:.2f} x "
                f"baseline {float(baseline[key]):.2f}x (= {want:.2f}x)")
            verdicts.append("BAND FAIL")
        else:
            verdicts.append(f"band ok vs {float(baseline[key]):.2f}x")
    elif bootstrap:
        verdicts.append("band skipped (bootstrap baseline)")
    else:
        verdicts.append("band skipped (key not in baseline)")
    print(f"bench_gate: {key:28s} {val:8.2f}x  [{'; '.join(verdicts)}]")

    key = "mrr_degradation_topk_int8"
    if key in fresh:
        checked += 1
        val = float(fresh[key])
        if val > args.mrr_degradation_max:
            failures.append(
                f"{key} = {val:.4f} is above the absolute ceiling "
                f"{args.mrr_degradation_max:.4f}")
            verdict = "CEILING FAIL"
        else:
            verdict = "ceiling ok"
        print(f"bench_gate: {key:28s} {val:8.4f}   [{verdict}]")
    else:
        print(f"bench_gate: {key:28s} {'—':>8}   [skipped (not in fresh point)]")

    return failures, checked


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed trajectory point")
    ap.add_argument("--fresh", required=True, help="just-measured trajectory point")
    ap.add_argument("--band", type=float, default=0.5,
                    help="fresh speedup must be >= band * baseline; "
                         "fresh cost ratio must be <= baseline / band (default 0.5)")
    ap.add_argument("--train-floor", type=float, default=5.0,
                    help="absolute floor for train_speedup (default 5.0)")
    ap.add_argument("--kernel-floor", type=float, default=1.0,
                    help="absolute floor for each kernel_speedup_* (default 1.0)")
    ap.add_argument("--scale-ratio-max", type=float, default=3.0,
                    help="absolute ceiling for scale_round_ratio (default 3.0)")
    ap.add_argument("--rss-frac-max", type=float, default=0.75,
                    help="absolute ceiling for rss_fraction (default 0.75)")
    ap.add_argument("--bytes-floor", type=float, default=3.0,
                    help="absolute floor for bytes_reduction_topk_int8 (default 3.0)")
    ap.add_argument("--mrr-degradation-max", type=float, default=0.01,
                    help="absolute ceiling for mrr_degradation_topk_int8 (default 0.01)")
    args = ap.parse_args()

    baseline = load(args.baseline)
    fresh = load(args.fresh)
    bootstrap = bool(baseline.get("bootstrap"))

    if "bytes_reduction_topk_int8" in fresh:
        failures, checked = gate_bytes(args, baseline, fresh, bootstrap)
        what = "frontier keys"
    elif "scale_round_ratio" in fresh:
        failures, checked = gate_scale(args, baseline, fresh, bootstrap)
        what = "scale keys"
    else:
        failures, checked = gate_speedups(args, baseline, fresh, bootstrap)
        what = "speedup keys"

    if failures:
        print("bench_gate: FAIL", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print(f"bench_gate: PASS ({checked} {what} checked)")


if __name__ == "__main__":
    main()
