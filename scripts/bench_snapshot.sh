#!/usr/bin/env bash
# Refresh the committed bench trajectory points at the repo root.
#
# Runs the bench suites full-length (no FEDS_BENCH_FAST) with
# FEDS_BENCH_SNAPSHOT=1, which makes `util::bench::write_trajectory`
# mirror each rust/BENCH_*.json into the repo root — the copies
# scripts/bench_gate.py treats as the baseline.  Commit the updated root
# files together with the change that moved the numbers.
set -euo pipefail

cd "$(dirname "$0")/../rust"

export FEDS_BENCH_SNAPSHOT=1
unset FEDS_BENCH_FAST || true

cargo bench --bench train_hot_path
cargo bench --bench server_shards
cargo bench --bench cluster_wallclock
cargo bench --bench scale
cargo bench --bench compression_frontier

echo "bench_snapshot: refreshed $(ls ../BENCH_*.json | tr '\n' ' ')"
