"""L2: the KGE compute graph (forward/backward/Adam + evaluation), in JAX.

Everything here is lowered ONCE by ``aot.py`` to HLO text and executed by the
Rust runtime — Python is never on the request path.  The scoring hot-spots
call the L1 Pallas kernels in ``kernels/score.py`` / ``kernels/change.py``.

Three KGE methods (paper §IV-B):

* TransE   — score  γ − ‖h + r − t‖₁            (entity width D,  relation D)
* RotatE   — score  γ − Σ|h ∘ e^{iθ_r} − t|      (entity width 2D, relation D)
* ComplEx  — score  Re⟨h, r, t̄⟩                  (entity width 2D, relation 2D)

Complex-valued tables are stored re‖im concatenated along the row.

The training step implements FedE's local objective: self-adversarial
negative sampling (Sun et al., RotatE) + dense Adam over the full embedding
tables (identical semantics to ``torch.optim.Adam`` on a dense
``nn.Embedding``, which is what FedE uses).
"""

import jax
import jax.numpy as jnp

from .config import Config
from .kernels import change as change_kernels
from .kernels import score as score_kernels

BIG = 1e9


def score_kind(method: str) -> str:
    """Which kernel family scores this method ('distance' kinds rank lower-
    is-better and are negated into goodness at the call sites)."""
    return {"transe": "l1", "rotate": "cmod", "complex": "dot"}[method]


def is_distance(method: str) -> bool:
    return method in ("transe", "rotate")


# ---------------------------------------------------------------------------
# query composition: fold (known entity, relation) into a single query row so
# every score is a kernel primitive (L1 / complex-modulus / dot) between the
# query and candidate entity rows.
# ---------------------------------------------------------------------------

def _split(x):
    dh = x.shape[-1] // 2
    return x[..., :dh], x[..., dh:]


def _rotate_phase(r, cfg: Config):
    # raw relation row → rotation phase, as in the reference RotatE impl.
    return r * (jnp.pi / cfg.embedding_range)


def compose(method: str, src, rel, predict_head, cfg: Config):
    """Build the query rows for scoring candidates.

    src:  (B, We) embedding of the *known* entity (head if predicting tail,
          tail if predicting head).
    rel:  (B, Wr)
    predict_head: (B,) float 0/1 — which side the candidates replace.
    Returns (B, We) query rows to feed the kernel with candidate rows.
    """
    flag = predict_head[:, None]
    if method == "transe":
        # tail: |h + r - t| ; head: |t - r - h|  → query = src ± r
        return src + rel * (1.0 - 2.0 * flag)
    if method == "rotate":
        theta = _rotate_phase(rel, cfg)
        cos, sin = jnp.cos(theta), jnp.sin(theta)
        sre, sim = _split(src)
        # tail: src ∘ e^{iθ} ; head: src ∘ e^{-iθ}  (|r| = 1 so the distance
        # |h∘r − t| equals |h − t∘r̄|).
        sin = sin * (1.0 - 2.0 * flag)
        qre = sre * cos - sim * sin
        qim = sre * sin + sim * cos
        return jnp.concatenate([qre, qim], axis=-1)
    if method == "complex":
        sre, sim = _split(src)
        rre, rim = _split(rel)
        # tail: Re⟨h∘r, t̄⟩ = dot(q, t) with q = (hr_re ‖ hr_im)
        t_qre = sre * rre - sim * rim
        t_qim = sre * rim + sim * rre
        # head: Re⟨h, r∘t̄ ⟩ = dot(q, h) with q = (w_re ‖ -w_im), w = r∘t̄
        #   (here src is the known tail)
        h_qre = rre * sre + rim * sim
        h_qim = -(rim * sre - rre * sim)
        qre = jnp.where(flag > 0.5, h_qre, t_qre)
        qim = jnp.where(flag > 0.5, h_qim, t_qim)
        return jnp.concatenate([qre, qim], axis=-1)
    raise ValueError(method)


def goodness_pairwise(method: str, q, cand, cfg: Config):
    """Kernel-scored logits, higher-is-better: γ − dist or raw dot."""
    raw = score_kernels.PAIRWISE[score_kind(method)](q, cand)
    return cfg.gamma - raw if is_distance(method) else raw


def goodness_all(method: str, q, table, cfg: Config):
    raw = score_kernels.ALL[score_kind(method)](q, table)
    return cfg.gamma - raw if is_distance(method) else raw


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def _logsigmoid(x):
    return -jax.nn.softplus(-x)


def nss_logits(method: str, ent, rel, pos, neg, neg_is_head, cfg: Config):
    """Positive and negative logits for a batch.

    pos: (B, 3) i32 [h, r, t];  neg: (B, NEG) i32 entity ids;
    neg_is_head: (B,) f32 — negatives corrupt the head (else the tail).
    """
    h = jnp.take(ent, pos[:, 0], axis=0)
    r = jnp.take(rel, pos[:, 1], axis=0)
    t = jnp.take(ent, pos[:, 2], axis=0)
    cand = jnp.take(ent, neg.reshape(-1), axis=0).reshape(
        neg.shape[0], neg.shape[1], ent.shape[1])

    # known side is the one NOT corrupted
    src = jnp.where(neg_is_head[:, None] > 0.5, t, h)
    q = compose(method, src, r, neg_is_head, cfg)
    neg_logit = goodness_pairwise(method, q, cand, cfg)

    true_cand = jnp.where(neg_is_head[:, None] > 0.5, h, t)[:, None, :]
    pos_logit = goodness_pairwise(method, q, true_cand, cfg)[:, 0]
    return pos_logit, neg_logit, (h, r, t, cand)


def nss_loss(method: str, ent, rel, pos, neg, neg_is_head, mask, cfg: Config):
    """Self-adversarial negative-sampling loss, masked mean over the batch."""
    pos_logit, neg_logit, gathered = nss_logits(
        method, ent, rel, pos, neg, neg_is_head, cfg)
    p = jax.nn.softmax(cfg.adv_temperature * neg_logit, axis=-1)
    p = jax.lax.stop_gradient(p)
    l_pos = -_logsigmoid(pos_logit)
    l_neg = -jnp.sum(p * _logsigmoid(-neg_logit), axis=-1)
    per = 0.5 * (l_pos + l_neg)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(per * mask) / denom
    if method == "complex":
        h, r, t, cand = gathered
        reg = (jnp.mean(h * h) + jnp.mean(r * r) + jnp.mean(t * t)
               + jnp.mean(cand * cand))
        loss = loss + cfg.complex_reg * reg
    return loss


# ---------------------------------------------------------------------------
# Adam (dense, torch semantics) over (ent, rel)
# ---------------------------------------------------------------------------

def adam_update(p, g, m, v, step, cfg: Config):
    b1, b2, eps, lr = (cfg.adam_beta1, cfg.adam_beta2,
                       cfg.adam_eps, cfg.learning_rate)
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    mh = m2 / (1.0 - jnp.power(b1, step))
    vh = v2 / (1.0 - jnp.power(b2, step))
    return p - lr * mh / (jnp.sqrt(vh) + eps), m2, v2


def make_train_step(method: str, cfg: Config):
    """One local SGD step: grads through the Pallas-scored loss + dense Adam.

    Signature (all f32 unless noted):
      ent (E,We), rel (R,Wr), ent_m, ent_v, rel_m, rel_v,
      step (scalar, 1-based Adam step),
      pos (B,3) i32, neg (B,NEG) i32, neg_is_head (B,), mask (B,)
    → (ent', rel', ent_m', ent_v', rel_m', rel_v', loss)
    """

    def train_step(ent, rel, ent_m, ent_v, rel_m, rel_v, step,
                   pos, neg, neg_is_head, mask):
        def loss_fn(params):
            e, r = params
            return nss_loss(method, e, r, pos, neg, neg_is_head, mask, cfg)

        loss, (g_ent, g_rel) = jax.value_and_grad(loss_fn)((ent, rel))
        ent2, ent_m2, ent_v2 = adam_update(ent, g_ent, ent_m, ent_v, step, cfg)
        rel2, rel_m2, rel_v2 = adam_update(rel, g_rel, rel_m, rel_v, step, cfg)
        return ent2, rel2, ent_m2, ent_v2, rel_m2, rel_v2, loss

    return train_step


def make_train_epoch(method: str, cfg: Config, steps: int):
    """`steps` training steps fused into ONE executable via `lax.scan`.

    The L3 hot-path optimization (EXPERIMENTS.md §Perf): the single-step
    artifact round-trips all six state tables host↔device per batch; this
    variant streams a whole local-training phase (padded to `steps` scan
    iterations) per PJRT call, so the tables cross the boundary once per
    communication round instead of once per batch.

    Fully-padded iterations (mask all-zero) are skipped EXACTLY: tables and
    the Adam step counter pass through unchanged, so a padded call is
    bit-identical to fewer single-step calls.

    Signature:
      ent, rel, ent_m, ent_v, rel_m, rel_v,
      step0 (scalar f32 — Adam steps completed before this call),
      pos (S,B,3) i32, neg (S,B,NEG) i32, nih (S,B) f32, mask (S,B) f32
    → (ent', rel', ent_m', ent_v', rel_m', rel_v', mean_loss, steps_done)
    """

    def train_epoch(ent, rel, ent_m, ent_v, rel_m, rel_v, step0,
                    pos, neg, neg_is_head, mask):
        def body(carry, xs):
            ent, rel, ent_m, ent_v, rel_m, rel_v, step = carry
            pos_b, neg_b, nih_b, mask_b = xs
            valid = jnp.sum(mask_b) > 0.0

            def loss_fn(params):
                e, r = params
                return nss_loss(method, e, r, pos_b, neg_b, nih_b, mask_b, cfg)

            loss, (g_ent, g_rel) = jax.value_and_grad(loss_fn)((ent, rel))
            step2 = step + 1.0
            ent2, ent_m2, ent_v2 = adam_update(ent, g_ent, ent_m, ent_v, step2, cfg)
            rel2, rel_m2, rel_v2 = adam_update(rel, g_rel, rel_m, rel_v, step2, cfg)

            sel = lambda a, b: jnp.where(valid, a, b)
            carry2 = (
                sel(ent2, ent), sel(rel2, rel),
                sel(ent_m2, ent_m), sel(ent_v2, ent_v),
                sel(rel_m2, rel_m), sel(rel_v2, rel_v),
                jnp.where(valid, step2, step),
            )
            return carry2, (jnp.where(valid, loss, 0.0),
                            jnp.where(valid, 1.0, 0.0))

        carry0 = (ent, rel, ent_m, ent_v, rel_m, rel_v, step0)
        carry, (losses, valids) = jax.lax.scan(
            body, carry0, (pos, neg, neg_is_head, mask), length=steps)
        ent, rel, ent_m, ent_v, rel_m, rel_v, _ = carry
        n = jnp.maximum(jnp.sum(valids), 1.0)
        return (ent, rel, ent_m, ent_v, rel_m, rel_v,
                jnp.sum(losses) / n, jnp.sum(valids))

    return train_epoch


# ---------------------------------------------------------------------------
# evaluation: filtered link-prediction ranks
# ---------------------------------------------------------------------------

def make_eval_step(method: str, cfg: Config):
    """Filtered ranks for a batch of queries.

    Signature:
      ent (E,We), rel (R,Wr),
      src (EB,) i32   — the known entity,
      r   (EB,) i32   — the relation,
      true (EB,) i32  — the answer entity,
      predict_head (EB,) f32,
      filter (EB,E) f32 — 1 marks known positives to exclude (never the true
                          answer itself; the Rust side guarantees that),
    → ranks (EB,) f32 with average tie-breaking.
    """

    def eval_step(ent, rel, src, r, true, predict_head, filt):
        src_e = jnp.take(ent, src, axis=0)
        rel_e = jnp.take(rel, r, axis=0)
        q = compose(method, src_e, rel_e, predict_head, cfg)
        good = goodness_all(method, q, ent, cfg)          # (EB, E)
        eb = src.shape[0]
        true_good = good[jnp.arange(eb), true]
        good = good - BIG * filt
        # exclude the true answer from both counts
        is_true = jax.nn.one_hot(true, good.shape[1], dtype=good.dtype)
        greater = jnp.sum((good > true_good[:, None]) * (1.0 - is_true), axis=1)
        equal = jnp.sum((good == true_good[:, None]) * (1.0 - is_true), axis=1)
        return 1.0 + greater + 0.5 * equal

    return eval_step


# ---------------------------------------------------------------------------
# upstream change scores (Eq. 1) — used by the FedS client before Top-K
# ---------------------------------------------------------------------------

def make_change_fn(cfg: Config):
    def change_fn(cur, hist):
        return change_kernels.change_scores(cur, hist)

    return change_fn


# ---------------------------------------------------------------------------
# FedE-KD baseline (paper Appendix VI-A): dual-dimension co-distillation.
# The *low*-dim table is what gets transmitted; both are trained jointly with
# mutual KL on softmaxed score vectors, with the adaptive 1/(L_L+L_H) weight.
# ---------------------------------------------------------------------------

def kd_loss(method: str, cfg: Config, cfg_lo: Config, params,
            pos, neg, neg_is_head, mask):
    """Eq. 6: supervised losses of both models + adaptive co-distillation."""
    eh, rh, el, rl = params
    ph, nh, _ = nss_logits(method, eh, rh, pos, neg, neg_is_head, cfg)
    pl_, nl, _ = nss_logits(method, el, rl, pos, neg, neg_is_head, cfg_lo)

    def sup(p_log, n_log):
        adv = jax.lax.stop_gradient(
            jax.nn.softmax(cfg.adv_temperature * n_log, axis=-1))
        per = 0.5 * (-_logsigmoid(p_log)
                     - jnp.sum(adv * _logsigmoid(-n_log), axis=-1))
        return jnp.sum(per * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    l_h = sup(ph, nh)
    l_l = sup(pl_, nl)

    # mutual distillation on softmaxed [pos ‖ neg] score vectors
    sh = jax.nn.log_softmax(jnp.concatenate([ph[:, None], nh], axis=-1), axis=-1)
    sl = jax.nn.log_softmax(jnp.concatenate([pl_[:, None], nl], axis=-1), axis=-1)

    def kl(lp, lq):
        per = jnp.sum(jnp.exp(lp) * (lp - lq), axis=-1)
        return jnp.sum(per * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    w = jax.lax.stop_gradient(jnp.maximum(l_h + l_l, 1e-3))
    return l_h + l_l + (kl(sl, sh) + kl(sh, sl)) / w


def make_kd_train_step(method: str, cfg: Config, cfg_lo: Config):
    def kd_train_step(ent_h, rel_h, ent_h_m, ent_h_v, rel_h_m, rel_h_v,
                      ent_l, rel_l, ent_l_m, ent_l_v, rel_l_m, rel_l_v,
                      step, pos, neg, neg_is_head, mask):
        def loss_fn(params):
            return kd_loss(method, cfg, cfg_lo, params, pos, neg,
                           neg_is_head, mask)

        loss, grads = jax.value_and_grad(loss_fn)((ent_h, rel_h, ent_l, rel_l))
        g_eh, g_rh, g_el, g_rl = grads
        ent_h2, ent_h_m2, ent_h_v2 = adam_update(ent_h, g_eh, ent_h_m,
                                                 ent_h_v, step, cfg)
        rel_h2, rel_h_m2, rel_h_v2 = adam_update(rel_h, g_rh, rel_h_m,
                                                 rel_h_v, step, cfg)
        ent_l2, ent_l_m2, ent_l_v2 = adam_update(ent_l, g_el, ent_l_m,
                                                 ent_l_v, step, cfg)
        rel_l2, rel_l_m2, rel_l_v2 = adam_update(rel_l, g_rl, rel_l_m,
                                                 rel_l_v, step, cfg)
        return (ent_h2, rel_h2, ent_h_m2, ent_h_v2, rel_h_m2, rel_h_v2,
                ent_l2, rel_l2, ent_l_m2, ent_l_v2, rel_l_m2, rel_l_v2, loss)

    return kd_train_step


def make_kd_train_epoch(method: str, cfg: Config, cfg_lo: Config, steps: int):
    """KD multi-step variant (scan), same padding semantics as
    `make_train_epoch`.  13 carried tables: hi model (6), lo model (6), step.

    → (12 tables, mean_loss, steps_done)
    """

    def kd_train_epoch(ent_h, rel_h, ent_h_m, ent_h_v, rel_h_m, rel_h_v,
                       ent_l, rel_l, ent_l_m, ent_l_v, rel_l_m, rel_l_v,
                       step0, pos, neg, neg_is_head, mask):
        def body(carry, xs):
            (eh, rh, ehm, ehv, rhm, rhv, el, rl, elm, elv, rlm, rlv,
             step) = carry
            pos_b, neg_b, nih_b, mask_b = xs
            valid = jnp.sum(mask_b) > 0.0

            def loss_fn(params):
                return kd_loss(method, cfg, cfg_lo, params, pos_b, neg_b,
                               nih_b, mask_b)

            loss, grads = jax.value_and_grad(loss_fn)((eh, rh, el, rl))
            g_eh, g_rh, g_el, g_rl = grads
            step2 = step + 1.0
            eh2, ehm2, ehv2 = adam_update(eh, g_eh, ehm, ehv, step2, cfg)
            rh2, rhm2, rhv2 = adam_update(rh, g_rh, rhm, rhv, step2, cfg)
            el2, elm2, elv2 = adam_update(el, g_el, elm, elv, step2, cfg_lo)
            rl2, rlm2, rlv2 = adam_update(rl, g_rl, rlm, rlv, step2, cfg_lo)

            sel = lambda a, b: jnp.where(valid, a, b)
            carry2 = (
                sel(eh2, eh), sel(rh2, rh), sel(ehm2, ehm), sel(ehv2, ehv),
                sel(rhm2, rhm), sel(rhv2, rhv),
                sel(el2, el), sel(rl2, rl), sel(elm2, elm), sel(elv2, elv),
                sel(rlm2, rlm), sel(rlv2, rlv),
                jnp.where(valid, step2, step),
            )
            return carry2, (jnp.where(valid, loss, 0.0),
                            jnp.where(valid, 1.0, 0.0))

        carry0 = (ent_h, rel_h, ent_h_m, ent_h_v, rel_h_m, rel_h_v,
                  ent_l, rel_l, ent_l_m, ent_l_v, rel_l_m, rel_l_v, step0)
        carry, (losses, valids) = jax.lax.scan(
            body, carry0, (pos, neg, neg_is_head, mask), length=steps)
        n = jnp.maximum(jnp.sum(valids), 1.0)
        return (*carry[:12], jnp.sum(losses) / n, jnp.sum(valids))

    return kd_train_epoch
