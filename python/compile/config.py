"""Single source of truth for artifact shapes and hyper-parameters.

The Rust runtime never recomputes any of this: everything lands in
``artifacts/manifest.json`` and is validated against the dataset config at
load time.  The defaults are the *scaled* reproduction setup described in
DESIGN.md §4 (the paper runs FB15k-237 / dim 256 on GPUs; we run a synthetic
FB15k-237-like KG / dim 64 on CPU-PJRT).
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class Config:
    # --- shapes -----------------------------------------------------------
    num_entities: int = 2048      # E  (power of two so eval tiles divide)
    num_relations: int = 24       # R
    dim: int = 64                 # D, the "base" dimension (paper: 256)
    batch: int = 256              # B, training batch (paper: 512)
    negatives: int = 64           # NEG, negative samples per positive
    eval_batch: int = 128         # EB, queries per eval step
    scan_steps: int = 32          # S, steps fused per train_epoch artifact

    # --- hyper-parameters (paper §IV-B) ------------------------------------
    gamma: float = 8.0            # margin γ
    epsilon: float = 2.0          # ε for the init range (γ+ε)/D
    adv_temperature: float = 1.0  # self-adversarial sampling temperature
    learning_rate: float = 1e-3   # paper: 1e-4 at dim 256; scaled up for dim 64
    adam_beta1: float = 0.9
    adam_beta2: float = 0.999
    adam_eps: float = 1e-8
    complex_reg: float = 1e-5     # L2 regularisation used for ComplEx (FedE)

    # --- FedS / FedEPL derived dims ----------------------------------------
    sparsity: float = 0.4         # p
    sync_interval: int = 4        # s

    # KD baseline: low-dim transport embeddings at 0.75·D (paper: 192/256)
    kd_ratio: float = 0.75

    def entity_width(self, method: str) -> int:
        """Row width of the entity table (complex methods store re‖im)."""
        return self.dim if method == "transe" else 2 * self.dim

    def relation_width(self, method: str) -> int:
        if method == "transe":
            return self.dim
        if method == "rotate":
            return self.dim          # phases
        if method == "complex":
            return 2 * self.dim
        raise ValueError(method)

    @property
    def embedding_range(self) -> float:
        return (self.gamma + self.epsilon) / self.dim

    def fedepl_dim(self) -> int:
        """Embedding dimension of the FedEPL baseline (paper Appendix VI-C).

        FedEPL lowers the dense baseline's dimension so that its per-cycle
        transmitted volume matches FedS's ratio R_c^p (Eq. 5).  Rounded up,
        as in the paper ("for benefiting FedEPL").
        """
        r = self.comm_ratio()
        d = int(self.dim * r)
        if self.dim * r > d:
            d += 1
        return d

    def comm_ratio(self) -> float:
        """Eq. 5: worst-case transmitted-parameter ratio of FedS vs dense."""
        p, s, d = self.sparsity, self.sync_interval, float(self.dim)
        return (p * s + 1.0 + (2.0 + p) * s / (2.0 * d)) / (s + 1.0)

    def kd_dim(self) -> int:
        return int(self.dim * self.kd_ratio)

    def to_dict(self) -> dict:
        return asdict(self)


DEFAULT = Config()

METHODS = ("transe", "rotate", "complex")
