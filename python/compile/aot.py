"""AOT pipeline: lower every L2 function to HLO *text* + write the manifest.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Run once via ``make artifacts``:
    cd python && python -m compile.aot --out-dir ../artifacts

Artifacts produced (per method m ∈ {transe, rotate, complex}):
  train_{m}_d{D}.hlo.txt     — one local training step (loss + dense Adam)
  eval_{m}_d{D}.hlo.txt      — filtered link-prediction ranks
  change_{m}_d{D}.hlo.txt    — Eq.1 cosine change scores (FedS upstream)
  train/eval at the FedEPL dimension (Appendix VI-C)
  train_kd_{m}_d{D}.hlo.txt  — FedE-KD dual-dim co-distillation (Table I),
                               transe & rotate only, as in the paper

plus ``manifest.json`` describing every artifact's I/O signature so the Rust
runtime can validate shapes before compiling.
"""

import argparse
import json
import os
from dataclasses import replace

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import DEFAULT, METHODS, Config
from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _sig(specs):
    return [[list(s.shape), str(s.dtype)] for s in specs]


def train_specs(cfg: Config, method: str):
    e, r = cfg.num_entities, cfg.num_relations
    we, wr = cfg.entity_width(method), cfg.relation_width(method)
    b, n = cfg.batch, cfg.negatives
    return [
        f32(e, we), f32(r, wr),               # ent, rel
        f32(e, we), f32(e, we),               # ent_m, ent_v
        f32(r, wr), f32(r, wr),               # rel_m, rel_v
        f32(),                                # adam step (1-based)
        i32(b, 3), i32(b, n), f32(b), f32(b)  # pos, neg, neg_is_head, mask
    ]


def train_epoch_specs(cfg: Config, method: str):
    e, r = cfg.num_entities, cfg.num_relations
    we, wr = cfg.entity_width(method), cfg.relation_width(method)
    b, n, s = cfg.batch, cfg.negatives, cfg.scan_steps
    return [
        f32(e, we), f32(r, wr),
        f32(e, we), f32(e, we),
        f32(r, wr), f32(r, wr),
        f32(),                                   # step0
        i32(s, b, 3), i32(s, b, n), f32(s, b), f32(s, b),
    ]


def kd_epoch_specs(cfg: Config, cfg_lo: Config, method: str):
    e, r = cfg.num_entities, cfg.num_relations
    we, wr = cfg.entity_width(method), cfg.relation_width(method)
    wel, wrl = cfg_lo.entity_width(method), cfg_lo.relation_width(method)
    b, n, s = cfg.batch, cfg.negatives, cfg.scan_steps
    return [
        f32(e, we), f32(r, wr), f32(e, we), f32(e, we), f32(r, wr), f32(r, wr),
        f32(e, wel), f32(r, wrl), f32(e, wel), f32(e, wel), f32(r, wrl),
        f32(r, wrl),
        f32(), i32(s, b, 3), i32(s, b, n), f32(s, b), f32(s, b),
    ]


def eval_specs(cfg: Config, method: str):
    e, r = cfg.num_entities, cfg.num_relations
    we, wr = cfg.entity_width(method), cfg.relation_width(method)
    eb = cfg.eval_batch
    return [
        f32(e, we), f32(r, wr),
        i32(eb), i32(eb), i32(eb), f32(eb), f32(eb, e),
    ]


def change_specs(cfg: Config, method: str):
    e, we = cfg.num_entities, cfg.entity_width(method)
    return [f32(e, we), f32(e, we)]


def kd_specs(cfg: Config, cfg_lo: Config, method: str):
    e, r = cfg.num_entities, cfg.num_relations
    we, wr = cfg.entity_width(method), cfg.relation_width(method)
    wel, wrl = cfg_lo.entity_width(method), cfg_lo.relation_width(method)
    b, n = cfg.batch, cfg.negatives
    return [
        f32(e, we), f32(r, wr), f32(e, we), f32(e, we), f32(r, wr), f32(r, wr),
        f32(e, wel), f32(r, wrl), f32(e, wel), f32(e, wel), f32(r, wrl),
        f32(r, wrl),
        f32(), i32(b, 3), i32(b, n), f32(b), f32(b),
    ]


def lower_one(fn, specs, path: str) -> int:
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def build_all(out_dir: str, cfg: Config, quick: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []

    def entry(name, role, method, c: Config, specs, n_outputs, extra=None):
        rec = {
            "name": name,
            "file": f"{name}.hlo.txt",
            "role": role,
            "method": method,
            "dim": c.dim,
            "entity_width": c.entity_width(method),
            "relation_width": c.relation_width(method),
            "num_entities": c.num_entities,
            "num_relations": c.num_relations,
            "batch": c.batch,
            "negatives": c.negatives,
            "eval_batch": c.eval_batch,
            "inputs": _sig(specs),
            "n_outputs": n_outputs,
        }
        if extra:
            rec.update(extra)
        return rec

    dims = {"base": cfg}
    if not quick:
        dims["fedepl"] = replace(cfg, dim=cfg.fedepl_dim())

    methods = METHODS if not quick else ("transe",)
    for method in methods:
        for variant, c in dims.items():
            name = f"train_{method}_d{c.dim}"
            specs = train_specs(c, method)
            n = lower_one(model.make_train_step(method, c), specs,
                          os.path.join(out_dir, f"{name}.hlo.txt"))
            artifacts.append(entry(name, "train", method, c, specs, 7))
            print(f"  {name}: {n} chars")

            name = f"train_epoch_{method}_d{c.dim}"
            specs = train_epoch_specs(c, method)
            n = lower_one(model.make_train_epoch(method, c, c.scan_steps),
                          specs, os.path.join(out_dir, f"{name}.hlo.txt"))
            artifacts.append(entry(name, "train_epoch", method, c, specs, 8,
                                   extra={"scan_steps": c.scan_steps}))
            print(f"  {name}: {n} chars")

            name = f"eval_{method}_d{c.dim}"
            specs = eval_specs(c, method)
            n = lower_one(model.make_eval_step(method, c), specs,
                          os.path.join(out_dir, f"{name}.hlo.txt"))
            artifacts.append(entry(name, "eval", method, c, specs, 1))
            print(f"  {name}: {n} chars")

            if variant == "base":
                name = f"change_{method}_d{c.dim}"
                specs = change_specs(c, method)
                n = lower_one(model.make_change_fn(c), specs,
                              os.path.join(out_dir, f"{name}.hlo.txt"))
                artifacts.append(entry(name, "change", method, c, specs, 1))
                print(f"  {name}: {n} chars")

        if method in ("transe", "rotate") and not quick:
            cfg_lo = replace(cfg, dim=cfg.kd_dim())
            name = f"train_kd_{method}_d{cfg.dim}"
            specs = kd_specs(cfg, cfg_lo, method)
            n = lower_one(model.make_kd_train_step(method, cfg, cfg_lo),
                          specs, os.path.join(out_dir, f"{name}.hlo.txt"))
            artifacts.append(entry(
                name, "train_kd", method, cfg, specs, 13,
                extra={"kd_dim": cfg_lo.dim,
                       "kd_entity_width": cfg_lo.entity_width(method),
                       "kd_relation_width": cfg_lo.relation_width(method)}))
            print(f"  {name}: {n} chars")

            name = f"train_kd_epoch_{method}_d{cfg.dim}"
            specs = kd_epoch_specs(cfg, cfg_lo, method)
            n = lower_one(
                model.make_kd_train_epoch(method, cfg, cfg_lo,
                                          cfg.scan_steps),
                specs, os.path.join(out_dir, f"{name}.hlo.txt"))
            artifacts.append(entry(
                name, "train_kd_epoch", method, cfg, specs, 14,
                extra={"kd_dim": cfg_lo.dim,
                       "kd_entity_width": cfg_lo.entity_width(method),
                       "kd_relation_width": cfg_lo.relation_width(method),
                       "scan_steps": cfg.scan_steps}))
            print(f"  {name}: {n} chars")

    manifest = {
        "version": 1,
        "config": cfg.to_dict(),
        "fedepl_dim": cfg.fedepl_dim(),
        "kd_dim": cfg.kd_dim(),
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--entities", type=int, default=DEFAULT.num_entities)
    ap.add_argument("--relations", type=int, default=DEFAULT.num_relations)
    ap.add_argument("--dim", type=int, default=DEFAULT.dim)
    ap.add_argument("--batch", type=int, default=DEFAULT.batch)
    ap.add_argument("--negatives", type=int, default=DEFAULT.negatives)
    ap.add_argument("--quick", action="store_true",
                    help="transe/base-dim only (CI smoke)")
    args = ap.parse_args()

    cfg = replace(
        DEFAULT,
        num_entities=args.entities,
        num_relations=args.relations,
        dim=args.dim,
        batch=args.batch,
        negatives=args.negatives,
    )
    m = build_all(args.out_dir, cfg, quick=args.quick)
    print(f"wrote {len(m['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
