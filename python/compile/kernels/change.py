"""L1 Pallas kernel: rowwise cosine change scores (Eq. 1, upstream Top-K).

``change_scores(cur, hist) = 1 - cos(cur[i], hist[i])`` over the full entity
table.  Bandwidth-bound: 2·E·W reads per E outputs, so the TPU schedule is a
single-axis grid over row blocks with both operand tiles streamed through
VMEM (BlockSpec handles the HBM→VMEM double buffering).  VMEM per tile at
TN=256, W=128: 2·256·128·4 B = 256 KiB.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .score import _tile

_INTERPRET = True


def _row_cosine_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]                        # (TN, W)
    b = b_ref[...]
    num = jnp.sum(a * b, axis=-1)
    den = jnp.sqrt(jnp.sum(a * a, axis=-1) * jnp.sum(b * b, axis=-1))
    o_ref[...] = num / jnp.maximum(den, ref.EPS)


def row_cosine(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    n, w = a.shape
    tn = _tile(n, 256)
    return pl.pallas_call(
        _row_cosine_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((tn, w), lambda i: (i, 0)),
            pl.BlockSpec((tn, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=_INTERPRET,
    )(a, b)


def change_scores(cur: jnp.ndarray, hist: jnp.ndarray) -> jnp.ndarray:
    """Eq. 1: M_c^t = 1 - cos(E_c^t, E_c^h) per entity row."""
    return 1.0 - row_cosine(cur, hist)
