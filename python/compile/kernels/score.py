"""L1 Pallas kernels: the KGE scoring hot-spots.

Two families:

* ``pairwise_*`` — each query row scored against its own NEG candidate rows
  (training-time negative sampling):  (B, W) × (B, N, W) → (B, N).
* ``all_*`` — each query row scored against the *full* entity table
  (link-prediction evaluation):       (EB, W) × (E, W) → (EB, E).

TPU mapping (DESIGN.md §6 Hardware-Adaptation): the original FKGE systems
run these as CUDA batched ops.  On TPU we tile for VMEM instead of shared
memory — the grid walks (query-tile, entity-tile) blocks, each block's
operands are staged HBM→VMEM by BlockSpec, and the reduction over W is fused
inside the tile so the (EB, E) score matrix is written exactly once.  The
MXU path is ``all_dot`` (a (TQ,W)×(W,TE) matmul per tile); the distance
kernels are VPU-bound element-wise reductions.

VMEM budget at the default tile sizes (f32):
  pairwise: TB=64, N=64, W≤128  →  64·128 + 64·64·128 + 64·64   ≈ 2.2 MiB
  all_*:    TQ=32, TE=256, W≤128 → 32·128 + 256·128 + 32·256    ≈ 0.2 MiB
both well under the ~16 MiB/core VMEM of a TPUv4.

Pallas runs with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); gradients flow through ``jax.custom_vjp`` with closed-form
jnp backward passes, so the lowered HLO contains the kernel forward and a
fused dense backward.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_INTERPRET = True
EPS = ref.EPS


def _tile(n: int, pref: int) -> int:
    """Largest tile ≤ pref that divides n (falls back to n itself)."""
    t = min(pref, n)
    while n % t != 0:
        t -= 1
    return t


# ---------------------------------------------------------------------------
# pairwise kernels: (B, W) × (B, N, W) → (B, N)
# ---------------------------------------------------------------------------

def _pairwise_l1_kernel(q_ref, c_ref, o_ref):
    q = q_ref[...]                       # (TB, W)
    c = c_ref[...]                       # (TB, N, W)
    o_ref[...] = jnp.sum(jnp.abs(q[:, None, :] - c), axis=-1)


def _pairwise_cmod_kernel(q_ref, c_ref, o_ref):
    q = q_ref[...]
    c = c_ref[...]
    dh = q.shape[-1] // 2
    dre = q[:, None, :dh] - c[..., :dh]
    dim = q[:, None, dh:] - c[..., dh:]
    o_ref[...] = jnp.sum(jnp.sqrt(dre * dre + dim * dim + EPS), axis=-1)


def _pairwise_dot_kernel(q_ref, c_ref, o_ref):
    q = q_ref[...]
    c = c_ref[...]
    o_ref[...] = jnp.einsum("bw,bnw->bn", q, c,
                            preferred_element_type=jnp.float32)


def _pairwise_call(kernel, q, c):
    b, w = q.shape
    _, n, _ = c.shape
    tb = _tile(b, 64)
    return pl.pallas_call(
        kernel,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, w), lambda i: (i, 0)),
            pl.BlockSpec((tb, n, w), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=_INTERPRET,
    )(q, c)


# custom_vjp wrappers — backward in closed form (jnp), so autodiff through
# the train-step loss works regardless of Pallas' own transpose support.

@jax.custom_vjp
def pairwise_l1(q, c):
    return _pairwise_call(_pairwise_l1_kernel, q, c)


def _pairwise_l1_fwd(q, c):
    return pairwise_l1(q, c), (q, c)


def _pairwise_l1_bwd(res, g):
    q, c = res
    sgn = jnp.sign(q[:, None, :] - c)            # (B, N, W)
    dq = jnp.einsum("bn,bnw->bw", g, sgn)
    dc = -g[..., None] * sgn
    return dq, dc


pairwise_l1.defvjp(_pairwise_l1_fwd, _pairwise_l1_bwd)


@jax.custom_vjp
def pairwise_cmod(q, c):
    return _pairwise_call(_pairwise_cmod_kernel, q, c)


def _pairwise_cmod_fwd(q, c):
    return pairwise_cmod(q, c), (q, c)


def _pairwise_cmod_bwd(res, g):
    q, c = res
    dh = q.shape[-1] // 2
    dre = q[:, None, :dh] - c[..., :dh]
    dim = q[:, None, dh:] - c[..., dh:]
    mod = jnp.sqrt(dre * dre + dim * dim + EPS)
    gre = g[..., None] * dre / mod               # (B, N, Dh)
    gim = g[..., None] * dim / mod
    dq = jnp.concatenate([gre.sum(axis=1), gim.sum(axis=1)], axis=-1)
    dc = jnp.concatenate([-gre, -gim], axis=-1)
    return dq, dc


pairwise_cmod.defvjp(_pairwise_cmod_fwd, _pairwise_cmod_bwd)


@jax.custom_vjp
def pairwise_dot(q, c):
    return _pairwise_call(_pairwise_dot_kernel, q, c)


def _pairwise_dot_fwd(q, c):
    return pairwise_dot(q, c), (q, c)


def _pairwise_dot_bwd(res, g):
    q, c = res
    dq = jnp.einsum("bn,bnw->bw", g, c)
    dc = g[..., None] * q[:, None, :]
    return dq, dc


pairwise_dot.defvjp(_pairwise_dot_fwd, _pairwise_dot_bwd)


# ---------------------------------------------------------------------------
# all-entity kernels: (EB, W) × (E, W) → (EB, E)   — eval path, no grads
# ---------------------------------------------------------------------------

def _all_l1_kernel(q_ref, t_ref, o_ref):
    q = q_ref[...]                       # (TQ, W)
    t = t_ref[...]                       # (TE, W)
    o_ref[...] = jnp.sum(jnp.abs(q[:, None, :] - t[None, :, :]), axis=-1)


def _all_cmod_kernel(q_ref, t_ref, o_ref):
    q = q_ref[...]
    t = t_ref[...]
    dh = q.shape[-1] // 2
    dre = q[:, None, :dh] - t[None, :, :dh]
    dim = q[:, None, dh:] - t[None, :, dh:]
    o_ref[...] = jnp.sum(jnp.sqrt(dre * dre + dim * dim + EPS), axis=-1)


def _all_dot_kernel(q_ref, t_ref, o_ref):
    # The MXU tile: (TQ, W) @ (W, TE)
    o_ref[...] = jnp.dot(q_ref[...], t_ref[...].T,
                         preferred_element_type=jnp.float32)


def _all_call(kernel, q, table):
    eb, w = q.shape
    e, _ = table.shape
    tq = _tile(eb, 32)
    te = _tile(e, 256)
    return pl.pallas_call(
        kernel,
        grid=(eb // tq, e // te),
        in_specs=[
            pl.BlockSpec((tq, w), lambda i, j: (i, 0)),
            pl.BlockSpec((te, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, te), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((eb, e), jnp.float32),
        interpret=_INTERPRET,
    )(q, table)


all_l1 = functools.partial(_all_call, _all_l1_kernel)
all_cmod = functools.partial(_all_call, _all_cmod_kernel)
all_dot = functools.partial(_all_call, _all_dot_kernel)


PAIRWISE = {"l1": pairwise_l1, "cmod": pairwise_cmod, "dot": pairwise_dot}
ALL = {"l1": all_l1, "cmod": all_cmod, "dot": all_dot}
