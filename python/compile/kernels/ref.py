"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(`score.py`, `change.py`) match these to float32 tolerance.  They are also
what the L2 model *would* use if Pallas were unavailable — the HLO the Rust
runtime loads is produced with the Pallas path.
"""

import jax.numpy as jnp

EPS = 1e-12


# --- pairwise scores: one query row vs its own NEG candidates ---------------

def pairwise_l1(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """sum_w |q[b,w] - c[b,n,w]|  →  (B, N).  TransE distance."""
    return jnp.sum(jnp.abs(q[:, None, :] - c), axis=-1)


def pairwise_cmod(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Complex-modulus distance, RotatE.  Width W = 2*Dh laid out re‖im.

    score[b,n] = sum_d sqrt((qre-cre)^2 + (qim-cim)^2)
    """
    w = q.shape[-1]
    dh = w // 2
    dre = q[:, None, :dh] - c[..., :dh]
    dim = q[:, None, dh:] - c[..., dh:]
    return jnp.sum(jnp.sqrt(dre * dre + dim * dim + EPS), axis=-1)


def pairwise_dot(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Plain dot product  →  (B, N).  ComplEx (re‖im layout folds the
    conjugation into the query construction, see model.py)."""
    return jnp.einsum("bw,bnw->bn", q, c)


# --- all-entity scores: query rows vs the full entity table ------------------

def all_l1(q: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """(EB, W) vs (E, W) → (EB, E) of sum_w |q - t|."""
    return jnp.sum(jnp.abs(q[:, None, :] - table[None, :, :]), axis=-1)


def all_cmod(q: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    w = q.shape[-1]
    dh = w // 2
    dre = q[:, None, :dh] - table[None, :, :dh]
    dim = q[:, None, dh:] - table[None, :, dh:]
    return jnp.sum(jnp.sqrt(dre * dre + dim * dim + EPS), axis=-1)


def all_dot(q: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return q @ table.T


# --- rowwise cosine change (upstream Top-K, Eq. 1) ---------------------------

def row_cosine(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """cos(a[i], b[i]) per row → (N,).  Zero rows cos to 0 (guarded)."""
    num = jnp.sum(a * b, axis=-1)
    den = jnp.sqrt(jnp.sum(a * a, axis=-1) * jnp.sum(b * b, axis=-1))
    return num / jnp.maximum(den, EPS)


def change_scores(cur: jnp.ndarray, hist: jnp.ndarray) -> jnp.ndarray:
    """Eq. 1: M = 1 - cos(E^t, E^h), per entity row."""
    return 1.0 - row_cosine(cur, hist)
