"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including non-power-of-two sizes that exercise the
tile-fallback path) and value distributions; every kernel must match the
oracle to float32 tolerance, and the custom_vjp backward passes must match
autodiff through the oracle.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import change, ref, score

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


PAIRWISE_CASES = [
    ("l1", ref.pairwise_l1),
    ("cmod", ref.pairwise_cmod),
    ("dot", ref.pairwise_dot),
]

ALL_CASES = [
    ("l1", ref.all_l1),
    ("cmod", ref.all_cmod),
    ("dot", ref.all_dot),
]


@pytest.mark.parametrize("kind,oracle", PAIRWISE_CASES)
@given(
    b=st.sampled_from([1, 3, 16, 64, 100]),
    n=st.sampled_from([1, 4, 7, 32]),
    dh=st.sampled_from([1, 3, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_matches_ref(kind, oracle, b, n, dh, seed):
    w = 2 * dh  # cmod needs an even width; use it everywhere for uniformity
    rng = np.random.default_rng(seed)
    q = _arr(rng, b, w)
    c = _arr(rng, b, n, w)
    got = score.PAIRWISE[kind](q, c)
    want = oracle(q, c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind,oracle", ALL_CASES)
@given(
    eb=st.sampled_from([1, 5, 32]),
    e=st.sampled_from([1, 13, 64, 300]),
    dh=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_all_matches_ref(kind, oracle, eb, e, dh, seed):
    w = 2 * dh
    rng = np.random.default_rng(seed)
    q = _arr(rng, eb, w)
    t = _arr(rng, e, w)
    got = score.ALL[kind](q, t)
    want = oracle(q, t)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind,oracle", PAIRWISE_CASES)
def test_pairwise_vjp_matches_ref_autodiff(kind, oracle):
    rng = np.random.default_rng(7)
    q = _arr(rng, 8, 10)
    c = _arr(rng, 8, 5, 10)
    g = _arr(rng, 8, 5)

    def via_kernel(q, c):
        return jnp.sum(score.PAIRWISE[kind](q, c) * g)

    def via_ref(q, c):
        return jnp.sum(oracle(q, c) * g)

    gq1, gc1 = jax.grad(via_kernel, argnums=(0, 1))(q, c)
    gq2, gc2 = jax.grad(via_ref, argnums=(0, 1))(q, c)
    np.testing.assert_allclose(gq1, gq2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gc1, gc2, rtol=1e-4, atol=1e-4)


def test_pairwise_l1_vjp_finite_difference():
    # independent of the oracle: check against numeric differentiation
    rng = np.random.default_rng(3)
    q = _arr(rng, 4, 6)
    c = _arr(rng, 4, 3, 6)

    def f(qv):
        return float(jnp.sum(score.pairwise_l1(qv, c)))

    g = jax.grad(lambda qv: jnp.sum(score.pairwise_l1(qv, c)))(q)
    eps = 1e-3
    for _ in range(5):
        i, j = rng.integers(0, 4), rng.integers(0, 6)
        dq = np.zeros_like(np.asarray(q))
        dq[i, j] = eps
        fd = (f(q + dq) - f(q - dq)) / (2 * eps)
        assert abs(fd - float(g[i, j])) < 1e-2


@given(
    n=st.sampled_from([1, 7, 64, 300]),
    w=st.sampled_from([2, 9, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_change_matches_ref(n, w, seed):
    rng = np.random.default_rng(seed)
    a = _arr(rng, n, w)
    b = _arr(rng, n, w)
    got = change.change_scores(a, b)
    want = ref.change_scores(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_change_identical_rows_is_zero():
    rng = np.random.default_rng(0)
    a = _arr(rng, 32, 16)
    got = np.asarray(change.change_scores(a, a))
    np.testing.assert_allclose(got, np.zeros(32), atol=1e-5)


def test_change_opposite_rows_is_two():
    rng = np.random.default_rng(0)
    a = _arr(rng, 16, 8)
    got = np.asarray(change.change_scores(a, -a))
    np.testing.assert_allclose(got, 2.0 * np.ones(16), atol=1e-4)


def test_change_zero_rows_guarded():
    a = jnp.zeros((4, 8), jnp.float32)
    got = np.asarray(change.change_scores(a, a))
    assert np.isfinite(got).all()


def test_all_dot_orthogonal_rows():
    q = jnp.eye(4, 8, dtype=jnp.float32)
    t = jnp.eye(6, 8, dtype=jnp.float32)
    got = np.asarray(score.all_dot(q, t))
    want = np.eye(4, 6, dtype=np.float32)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_pairwise_cmod_zero_distance():
    rng = np.random.default_rng(1)
    q = _arr(rng, 8, 10)
    c = jnp.broadcast_to(q[:, None, :], (8, 3, 10))
    got = np.asarray(score.pairwise_cmod(q, c))
    np.testing.assert_allclose(got, np.zeros((8, 3)), atol=1e-3)
