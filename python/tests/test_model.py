"""L2 correctness: train/eval/KD steps on a toy config.

These run the exact functions that get lowered to HLO, so any property that
holds here holds for the artifacts the Rust runtime executes.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from compile import model
from compile.config import Config, METHODS

CFG = replace(Config(), num_entities=64, num_relations=4, dim=8,
              batch=16, negatives=8, eval_batch=8)


def _init(cfg, method, seed=0):
    rng = np.random.default_rng(seed)
    we, wr = cfg.entity_width(method), cfg.relation_width(method)
    r = cfg.embedding_range
    ent = jnp.asarray(rng.uniform(-r, r, (cfg.num_entities, we)), jnp.float32)
    rel = jnp.asarray(rng.uniform(-r, r, (cfg.num_relations, wr)), jnp.float32)
    return ent, rel


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    pos = np.stack([
        rng.integers(0, cfg.num_entities, cfg.batch),
        rng.integers(0, cfg.num_relations, cfg.batch),
        rng.integers(0, cfg.num_entities, cfg.batch),
    ], axis=1).astype(np.int32)
    neg = rng.integers(0, cfg.num_entities,
                       (cfg.batch, cfg.negatives)).astype(np.int32)
    nih = rng.integers(0, 2, cfg.batch).astype(np.float32)
    mask = np.ones(cfg.batch, np.float32)
    return (jnp.asarray(pos), jnp.asarray(neg), jnp.asarray(nih),
            jnp.asarray(mask))


@pytest.mark.parametrize("method", METHODS)
def test_train_step_decreases_loss(method):
    ent, rel = _init(CFG, method)
    state = (ent, rel, jnp.zeros_like(ent), jnp.zeros_like(ent),
             jnp.zeros_like(rel), jnp.zeros_like(rel))
    pos, neg, nih, mask = _batch(CFG)
    ts = model.make_train_step(method, CFG)
    losses = []
    for step in range(1, 40):
        *state, loss = ts(*state, jnp.float32(step), pos, neg, nih, mask)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


@pytest.mark.parametrize("method", METHODS)
def test_train_step_respects_mask(method):
    """Fully-masked batch → zero grad → Adam with zero moments is a no-op."""
    ent, rel = _init(CFG, method)
    state = (ent, rel, jnp.zeros_like(ent), jnp.zeros_like(ent),
             jnp.zeros_like(rel), jnp.zeros_like(rel))
    pos, neg, nih, _ = _batch(CFG)
    mask = jnp.zeros(CFG.batch, jnp.float32)
    ts = model.make_train_step(method, CFG)
    out = ts(*state, jnp.float32(1.0), pos, neg, nih, mask)
    if method == "complex":
        # the L2 regulariser is not masked (matches FedE, which regularises
        # every gathered row) — only check finiteness there.
        assert np.isfinite(np.asarray(out[6]))
    else:
        np.testing.assert_allclose(out[0], ent, atol=1e-7)
        np.testing.assert_allclose(out[1], rel, atol=1e-7)


def test_adam_matches_manual():
    cfg = CFG
    p = jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)), jnp.float32)
    g = jnp.asarray(np.random.default_rng(1).normal(size=(4, 3)), jnp.float32)
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    p2, m2, v2 = model.adam_update(p, g, m, v, jnp.float32(1.0), cfg)
    # step 1 from zero moments: mhat = g, vhat = g², so Δ ≈ lr·sign(g)
    expect = p - cfg.learning_rate * g / (jnp.abs(g) + cfg.adam_eps)
    np.testing.assert_allclose(p2, expect, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("method", METHODS)
def test_eval_rank_of_planted_answer(method):
    """Plant a perfect answer: the true entity's embedding equals the query
    composition exactly (distance 0 / max dot) → rank must be 1."""
    cfg = CFG
    ent, rel = _init(cfg, method, seed=2)
    eb = cfg.eval_batch
    rng = np.random.default_rng(3)
    # src drawn outside the planted range: eval_step re-gathers src rows from
    # the table we are about to overwrite at rows [0, eb)
    src = jnp.asarray(rng.integers(eb, cfg.num_entities, eb), jnp.int32)
    r = jnp.asarray(rng.integers(0, cfg.num_relations, eb), jnp.int32)
    true = jnp.asarray(np.arange(eb), jnp.int32)  # plant into rows 0..eb-1
    ph = jnp.zeros(eb, jnp.float32)               # predict tail

    src_e = jnp.take(ent, src, axis=0)
    rel_e = jnp.take(rel, r, axis=0)
    q = model.compose(method, src_e, rel_e, ph, cfg)
    if method == "complex":
        # dot score: scale the planted row up so it dominates
        ent = ent.at[jnp.asarray(np.arange(eb))].set(q * 100.0)
    else:
        ent = ent.at[jnp.asarray(np.arange(eb))].set(q)

    es = model.make_eval_step(method, cfg)
    filt = jnp.zeros((eb, cfg.num_entities), jnp.float32)
    ranks = np.asarray(es(ent, rel, src, r, true, ph, filt))
    # allow ties at distance zero (duplicate rows are astronomically unlikely
    # but average-tie handling could give 1.5)
    assert (ranks <= 2.0).all(), ranks


@pytest.mark.parametrize("method", METHODS)
def test_eval_filter_excludes_entities(method):
    """Filtering every entity except the true answer forces rank 1."""
    cfg = CFG
    ent, rel = _init(cfg, method, seed=4)
    eb = cfg.eval_batch
    rng = np.random.default_rng(5)
    src = jnp.asarray(rng.integers(0, cfg.num_entities, eb), jnp.int32)
    r = jnp.asarray(rng.integers(0, cfg.num_relations, eb), jnp.int32)
    true = jnp.asarray(rng.integers(0, cfg.num_entities, eb), jnp.int32)
    ph = jnp.asarray(rng.integers(0, 2, eb), jnp.float32)
    filt = np.ones((eb, cfg.num_entities), np.float32)
    filt[np.arange(eb), np.asarray(true)] = 0.0
    es = model.make_eval_step(method, cfg)
    ranks = np.asarray(es(ent, rel, src, r, true, ph, jnp.asarray(filt)))
    np.testing.assert_allclose(ranks, np.ones(eb), atol=1e-6)


def test_eval_rank_consistency_with_numpy():
    """Cross-check ranks against a straightforward numpy ranking."""
    cfg = CFG
    method = "transe"
    ent, rel = _init(cfg, method, seed=6)
    eb = cfg.eval_batch
    rng = np.random.default_rng(7)
    src = rng.integers(0, cfg.num_entities, eb).astype(np.int32)
    r = rng.integers(0, cfg.num_relations, eb).astype(np.int32)
    true = rng.integers(0, cfg.num_entities, eb).astype(np.int32)
    ph = np.zeros(eb, np.float32)
    filt = np.zeros((eb, cfg.num_entities), np.float32)

    es = model.make_eval_step(method, cfg)
    got = np.asarray(es(ent, rel, jnp.asarray(src), jnp.asarray(r),
                        jnp.asarray(true), jnp.asarray(ph),
                        jnp.asarray(filt)))

    en, rl = np.asarray(ent), np.asarray(rel)
    for b in range(eb):
        q = en[src[b]] + rl[r[b]]
        dist = np.abs(q[None, :] - en).sum(axis=1)
        good = cfg.gamma - dist
        tg = good[true[b]]
        greater = np.sum((good > tg) & (np.arange(len(good)) != true[b]))
        equal = np.sum((good == tg) & (np.arange(len(good)) != true[b]))
        assert abs(got[b] - (1 + greater + 0.5 * equal)) < 1e-4


@pytest.mark.parametrize("method", ["transe", "rotate"])
def test_kd_train_step_runs_and_decreases(method):
    cfg = CFG
    cfg_lo = replace(cfg, dim=6)
    ent_h, rel_h = _init(cfg, method, seed=8)
    ent_l, rel_l = _init(cfg_lo, method, seed=9)
    state = [ent_h, rel_h, jnp.zeros_like(ent_h), jnp.zeros_like(ent_h),
             jnp.zeros_like(rel_h), jnp.zeros_like(rel_h),
             ent_l, rel_l, jnp.zeros_like(ent_l), jnp.zeros_like(ent_l),
             jnp.zeros_like(rel_l), jnp.zeros_like(rel_l)]
    pos, neg, nih, mask = _batch(cfg, seed=10)
    ts = model.make_kd_train_step(method, cfg, cfg_lo)
    losses = []
    for step in range(1, 25):
        *state, loss = ts(*state, jnp.float32(step), pos, neg, nih, mask)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_change_fn_matches_cosine():
    cfg = CFG
    fn = model.make_change_fn(cfg)
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    got = np.asarray(fn(a, b))
    an, bn = np.asarray(a), np.asarray(b)
    want = 1 - (an * bn).sum(1) / (np.linalg.norm(an, axis=1)
                                   * np.linalg.norm(bn, axis=1))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("method", METHODS)
def test_compose_head_tail_symmetry(method):
    """Scoring (h, r, t) as a tail query against t must equal scoring it as
    a head query against h — the same triple, seen from both sides."""
    cfg = CFG
    ent, rel = _init(cfg, method, seed=12)
    rng = np.random.default_rng(13)
    b = 16
    h = jnp.asarray(rng.integers(0, cfg.num_entities, b), jnp.int32)
    r = jnp.asarray(rng.integers(0, cfg.num_relations, b), jnp.int32)
    t = jnp.asarray(rng.integers(0, cfg.num_entities, b), jnp.int32)
    he, re_, te = (jnp.take(ent, h, axis=0), jnp.take(rel, r, axis=0),
                   jnp.take(ent, t, axis=0))
    zeros = jnp.zeros(b, jnp.float32)
    ones = jnp.ones(b, jnp.float32)
    q_tail = model.compose(method, he, re_, zeros, cfg)
    q_head = model.compose(method, te, re_, ones, cfg)
    s_tail = model.goodness_pairwise(method, q_tail, te[:, None, :], cfg)[:, 0]
    s_head = model.goodness_pairwise(method, q_head, he[:, None, :], cfg)[:, 0]
    np.testing.assert_allclose(s_tail, s_head, rtol=1e-3, atol=1e-3)
