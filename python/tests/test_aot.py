"""AOT pipeline: lowering produces parseable HLO text and a complete,
consistent manifest on a tiny config (fast, independent of `make artifacts`).
"""

import json
import os
from dataclasses import replace

import pytest

from compile import aot
from compile.config import DEFAULT, Config


TINY = replace(Config(), num_entities=128, num_relations=4, dim=8,
               batch=16, negatives=4, eval_batch=8)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build_all(out, TINY, quick=True)
    return out, manifest


def test_manifest_written(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["version"] == manifest["version"]
    assert len(on_disk["artifacts"]) == len(manifest["artifacts"])


def test_quick_build_has_train_eval_change(built):
    _, manifest = built
    roles = sorted(a["role"] for a in manifest["artifacts"])
    assert roles == ["change", "eval", "train", "train_epoch"]


def test_hlo_text_is_hlo(built):
    out, manifest = built
    for a in manifest["artifacts"]:
        with open(os.path.join(out, a["file"])) as f:
            text = f.read()
        assert text.startswith("HloModule"), a["name"]
        assert "ENTRY" in text, a["name"]


def test_input_signatures_match_config(built):
    _, manifest = built
    by_role = {a["role"]: a for a in manifest["artifacts"]}
    train = by_role["train"]
    e, we = TINY.num_entities, TINY.entity_width("transe")
    assert train["inputs"][0] == [[e, we], "float32"]
    assert train["inputs"][7] == [[TINY.batch, 3], "int32"]
    assert train["n_outputs"] == 7
    ev = by_role["eval"]
    assert ev["inputs"][6] == [[TINY.eval_batch, e], "float32"]
    assert ev["n_outputs"] == 1


def test_fedepl_dim_formula():
    # Appendix VI-C at paper scale: p=0.7, s=4, D=256 → R≈0.7642, dim 196
    c = replace(Config(), dim=256, sparsity=0.7, sync_interval=4)
    assert abs(c.comm_ratio() - 0.7642) < 1e-3
    assert c.fedepl_dim() == 196
    # and p=0.4 → 135
    c = replace(Config(), dim=256, sparsity=0.4, sync_interval=4)
    assert c.fedepl_dim() == 135


def test_default_config_tiles_divide():
    # power-of-two entity count so the eval kernel tiles divide exactly
    assert DEFAULT.num_entities % 256 == 0
    assert DEFAULT.batch % 64 == 0
    assert DEFAULT.eval_batch % 32 == 0
