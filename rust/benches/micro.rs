//! Micro-benchmarks of the L3 coordinator hot paths: Top-K selection,
//! personalized aggregation, wire codec, SVD codec, change scoring, and a
//! native train step.  `cargo bench --bench micro`.

use feds::comm::wire::{WireReader, WireWriter};
use feds::data::dataset::BatchIter;
use feds::data::Triple;
use feds::fed::compression::SvdCodec;
use feds::fed::protocol::{Download, Upload};
use feds::fed::topk::{select_by_change, select_by_priority};
use feds::fed::Server;
use feds::kge::native::NativeModel;
use feds::kge::{Hyper, Method};
use feds::util::bench::{bb, Bench};
use feds::util::rng::Rng;

fn main() {
    let mut b = Bench::from_env("micro");
    let mut rng = Rng::new(1);

    // --- Top-K selection ----------------------------------------------------
    for n in [2_048usize, 16_384] {
        let scores: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 2.0)).collect();
        let k = n * 4 / 10;
        b.bench(&format!("topk_change/{n}"), || bb(select_by_change(&scores, k)));
        let prios: Vec<u32> = (0..n).map(|_| rng.u32_below(10)).collect();
        let mut r2 = rng.fork(2);
        b.bench(&format!("topk_priority/{n}"), || {
            bb(select_by_priority(&prios, k, &mut r2))
        });
    }

    // --- server aggregation round --------------------------------------------
    {
        let e = 2_048;
        let w = 64;
        let n_clients = 10;
        let shared: Vec<Vec<u32>> = (0..n_clients)
            .map(|_| (0..e as u32).filter(|_| rng.bool(0.6)).collect())
            .collect();
        let uploads: Vec<(Vec<u32>, Vec<f32>)> = shared
            .iter()
            .map(|ids| {
                let sel: Vec<u32> = ids.iter().copied().filter(|_| rng.bool(0.4)).collect();
                let rows: Vec<f32> = (0..sel.len() * w).map(|_| rng.uniform(-1.0, 1.0)).collect();
                (sel, rows)
            })
            .collect();
        let mut server = Server::new(e, w, shared);
        let mut r3 = rng.fork(3);
        b.bench("server/feds_round_10c_2048e", || {
            server.begin_round();
            for (c, (ids, rows)) in uploads.iter().enumerate() {
                server.receive(c as u16, ids, rows);
            }
            for c in 0..n_clients {
                bb(server.feds_download(c as u16, 800, &mut r3));
            }
        });
    }

    // --- wire codec -----------------------------------------------------------
    {
        let emb: Vec<f32> = (0..800 * 64).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let sign: Vec<bool> = (0..2_048).map(|_| rng.bool(0.4)).collect();
        let mut w = WireWriter::new();
        w.f32s(&emb);
        let buf = w.finish();
        let up = Upload::Sparse { round: 9, client: 3, sign, emb };
        b.bench("wire/encode_sparse_upload_800x64", || bb(up.encode()));
        let frame = up.encode();
        b.bench("wire/decode_sparse_upload_800x64", || {
            bb(Upload::decode(&frame).unwrap())
        });
        let down = Download::Sparse {
            round: 9,
            sign: (0..2_048).map(|i| i % 3 == 0).collect(),
            emb: (0..700 * 64).map(|_| 0.5f32).collect(),
            prio: vec![2; 700],
        };
        b.bench("wire/roundtrip_sparse_download_700x64", || {
            bb(Download::decode(&down.encode()).unwrap())
        });
        b.bench("wire/read_f32s_51k", || {
            bb(WireReader::new(&buf).f32s().unwrap())
        });
    }

    // --- SVD codec -------------------------------------------------------------
    {
        let codec = SvdCodec::for_width(64, 8);
        let row: Vec<f32> = (0..64).map(|_| rng.uniform(-0.1, 0.1)).collect();
        b.bench("svd/encode_row_w64", || bb(codec.encode_row(&row)));
        let packed = codec.encode_row(&row);
        b.bench("svd/decode_row_w64", || bb(codec.decode_row(&packed, 64)));
    }

    // --- cosine change scoring ---------------------------------------------------
    {
        let w = 64;
        let n = 2_048;
        let a: Vec<f32> = (0..n * w).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let c: Vec<f32> = (0..n * w).map(|_| rng.uniform(-1.0, 1.0)).collect();
        b.bench("change/cosine_2048x64", || {
            let mut acc = 0.0f32;
            for i in 0..n {
                acc += feds::linalg::change_score(&a[i * w..(i + 1) * w], &c[i * w..(i + 1) * w]);
            }
            bb(acc)
        });
    }

    // --- native train step --------------------------------------------------------
    {
        let hyper = Hyper { dim: 32, ..Default::default() };
        let mut model = NativeModel::new(Method::TransE, hyper, 512, 8, &mut rng);
        let triples: Vec<Triple> = (0..128)
            .map(|_| Triple::new(rng.u32_below(512), rng.u32_below(8), rng.u32_below(512)))
            .collect();
        let ents: Vec<u32> = (0..512).collect();
        let mut r4 = rng.fork(4);
        let batch = BatchIter::new(&triples, &ents, 128, 32, &mut r4).next().unwrap();
        b.bench("native/train_step_b128_n32_d32", || bb(model.train_batch(&batch)));
    }

    b.finish();
}
