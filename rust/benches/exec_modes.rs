//! Sequential vs threaded federated execution on the native backend:
//! wall-clock per mode and the threaded speedup with an 8-client fleet,
//! plus a hard check that accounting is independent of the execution
//! mode.  `cargo bench --bench exec_modes`.

use feds::comm::transport::TransportSpec;
use feds::data::generator::{generate, GeneratorConfig};
use feds::data::partition::partition;
use feds::fed::orchestrator::params::auto_shards;
use feds::fed::{run_params, Algo, Backend, ExecMode, RoundParams};
use feds::kge::{Hyper, Method};
use feds::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("exec_modes");
    std::env::set_var("FEDS_LOG", "warn");

    let kg = generate(&GeneratorConfig {
        num_entities: 768,
        num_relations: 24,
        num_triples: 12_000,
        num_clusters: 8,
        seed: 11,
        ..Default::default()
    });
    let data = partition(&kg, 8, 11);
    let backend = Backend::Native {
        hyper: Hyper { dim: 32, learning_rate: 3e-3, ..Default::default() },
        batch: 128,
        negatives: 32,
        eval_batch: 64,
    };

    for algo in [Algo::FedEP, Algo::FedS { sync: true }] {
        let mut cfg = RoundParams {
            algo,
            method: Method::TransE,
            max_rounds: 6,
            local_epochs: 2,
            eval_every: 3,
            patience: 3,
            sparsity: 0.4,
            sync_interval: 4,
            eval_cap: 128,
            seed: 42,
            svd_cols: 8,
            exec: ExecMode::Sequential,
            transport: TransportSpec::Mpsc,
            shards: auto_shards(),
            participation: Default::default(),
            storage: Default::default(),
            compression: Default::default(),
        };
        let name = algo.label();

        let t0 = std::time::Instant::now();
        let seq = run_params(&data, &cfg, &backend, &mut []).expect("sequential run");
        let seq_s = t0.elapsed().as_secs_f64();

        cfg.exec = ExecMode::Threaded;
        let t0 = std::time::Instant::now();
        let thr = run_params(&data, &cfg, &backend, &mut []).expect("threaded run");
        let thr_s = t0.elapsed().as_secs_f64();

        assert_eq!(
            (seq.acct.params(), seq.acct.bytes()),
            (thr.acct.params(), thr.acct.bytes()),
            "accounting must not depend on the execution mode"
        );

        b.report_value(&format!("seq_8c/{name}/wall_s"), seq_s, "s");
        b.report_value(&format!("threaded_8c/{name}/wall_s"), thr_s, "s");
        b.report_value(&format!("threaded_8c/{name}/speedup"), seq_s / thr_s, "x");
    }
    b.finish();
}
