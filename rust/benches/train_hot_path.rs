//! Sparse training engine vs the dense oracle at realistic scale, plus the
//! chunked parallel eval scan — the acceptance benchmark for the sparse
//! hot-path rework.  `cargo bench --bench train_hot_path`
//! (`FEDS_BENCH_FAST=1` for the CI smoke run).
//!
//! Scenario: E = 50 000 global entities, dim 128, batch 512, 64 negatives,
//! with positives and negatives drawn from one client's local entity set
//! (the FedE convention — a client never samples entities it does not
//! own), so a step gathers a few thousand distinct rows out of 50 000.
//! The dense baseline still zeroes and Adam-updates all E×W parameters
//! every step; the sparse engine only visits the gathered rows.
//!
//! Besides the criterion-style report (`reports/bench/train_hot_path.json`),
//! this writes a single `BENCH_train.json` trajectory point with the
//! measured per-step times and speedups, which CI uploads as an artifact
//! and gates against the committed baseline (`scripts/bench_gate.py`).
//! The point includes per-method kernel timings
//! (`kernel_{scalar,simd}_ms_<method>` / `kernel_speedup_<method>`)
//! comparing the width-dispatched forward/backward kernels against the
//! retained scalar oracle on the same batch.  Run via
//! `scripts/bench_snapshot.sh` to also refresh the committed root copy.

use feds::data::dataset::{BatchIter, EvalBatch};
use feds::data::Triple;
use feds::kge::kernels::KernelSet;
use feds::kge::native::{DenseOracle, NativeModel};
use feds::kge::{Hyper, Method};
use feds::util::bench::{bb, write_trajectory, Bench};
use feds::util::json::Json;
use feds::util::rng::Rng;

const NUM_ENTITIES: usize = 50_000;
const DIM: usize = 128;
const BATCH: usize = 512;
const NEGATIVES: usize = 64;
const NUM_RELATIONS: usize = 64;
/// One client's local entity count (cross-silo partition of 50k entities).
const LOCAL_ENTITIES: usize = 2_048;

fn main() {
    let mut b = Bench::from_env("train_hot_path");

    // --- data: one padded batch with client-local sampling ----------------
    let mut rng = Rng::new(42);
    let pool: Vec<u32> = (0..LOCAL_ENTITIES as u32).collect();
    let triples: Vec<Triple> = (0..BATCH)
        .map(|_| {
            Triple::new(
                rng.u32_below(LOCAL_ENTITIES as u32),
                rng.u32_below(NUM_RELATIONS as u32),
                rng.u32_below(LOCAL_ENTITIES as u32),
            )
        })
        .collect();
    let mut brng = rng.fork(1);
    let batch = BatchIter::new(&triples, &pool, BATCH, NEGATIVES, &mut brng)
        .next()
        .expect("one full batch");

    // --- models: identical init, two engines ------------------------------
    let hyper = Hyper { dim: DIM, ..Default::default() };
    let mut sparse = NativeModel::new(
        Method::TransE,
        hyper.clone(),
        NUM_ENTITIES,
        NUM_RELATIONS,
        &mut rng,
    );
    let mut dense = DenseOracle::new(sparse.clone());

    // engines agree before any timing (gap-free first step is bit-exact)
    {
        let mut s = sparse.clone();
        let mut d = DenseOracle::new(s.clone());
        let (ls, ld) = (s.train_batch(&batch), d.train_batch(&batch));
        assert!(
            (ls - ld).abs() <= 1e-5 * (1.0 + ld.abs()),
            "engines disagree on step 1: sparse {ls} vs dense {ld}"
        );
    }

    let label = format!("E{}k_d{DIM}_b{BATCH}_n{NEGATIVES}", NUM_ENTITIES / 1000);
    let name_sparse = format!("train_step/sparse_{label}");
    let name_dense = format!("train_step/dense_{label}");
    let s_sparse = b.bench(&name_sparse, || bb(sparse.train_batch(&batch)));
    let s_dense = b.bench(&name_dense, || bb(dense.train_batch(&batch)));
    let train_speedup = s_dense.mean_ns / s_sparse.mean_ns;
    b.report_value("train_step/speedup", train_speedup, "x");

    // --- per-method kernels: dispatched vs the retained scalar oracle -----
    // times forward_backward (gather + score + gradient accumulation, no
    // optimizer step) so the comparison isolates exactly the kernel work
    let mut kernel_fields: Vec<(String, f64)> = Vec::new();
    for (mi, method) in Method::ALL.into_iter().enumerate() {
        let mut krng = rng.fork(100 + mi as u64);
        let mut fast =
            NativeModel::new(method, hyper.clone(), NUM_ENTITIES, NUM_RELATIONS, &mut krng);
        let mut scalar = fast.clone();
        scalar.kernels = KernelSet::scalar();
        assert!(!fast.kernels.is_scalar(), "d={DIM} must select fixed-width kernels");
        let (lf, ls) = (fast.forward_backward(&batch), scalar.forward_backward(&batch));
        assert!(
            (lf - ls).abs() <= 1e-5 * (1.0 + ls.abs()),
            "{} dispatched kernels disagree with the scalar oracle: {lf} vs {ls}",
            method.name()
        );
        let s_fast = b.bench(&format!("kernel_fwd_bwd/simd_{}_{label}", method.name()), || {
            bb(fast.forward_backward(&batch))
        });
        let s_scalar = b.bench(&format!("kernel_fwd_bwd/scalar_{}_{label}", method.name()), || {
            bb(scalar.forward_backward(&batch))
        });
        let speedup = s_scalar.mean_ns / s_fast.mean_ns;
        b.report_value(&format!("kernel_fwd_bwd/speedup_{}", method.name()), speedup, "x");
        let m = method.name();
        kernel_fields.push((format!("kernel_scalar_ms_{m}"), s_scalar.mean_ns / 1e6));
        kernel_fields.push((format!("kernel_simd_ms_{m}"), s_fast.mean_ns / 1e6));
        kernel_fields.push((format!("kernel_speedup_{m}"), speedup));
    }

    // --- eval: candidate scan, sequential vs chunked across threads -------
    // queries × candidates must clear PAR_EVAL_MIN_WORK (1 << 18) or the
    // auto budget stays sequential and the comparison measures nothing
    let eval_len = 8usize;
    assert!(eval_len * NUM_ENTITIES >= 1 << 18, "eval workload below the parallel threshold");
    let eb = EvalBatch {
        src: (0..eval_len as i32).collect(),
        rel: (0..eval_len as i32).map(|i| i % NUM_RELATIONS as i32).collect(),
        truth: (0..eval_len as i32).map(|i| i + 1000).collect(),
        pred_head: (0..eval_len).map(|i| (i % 2) as f32).collect(),
        filter: vec![0.0; eval_len * NUM_ENTITIES],
        len: eval_len,
        eval_batch: eval_len,
    };
    let name_eval_seq = format!("eval_ranks/seq_q{eval_len}_{label}");
    let name_eval_par = format!("eval_ranks/par_q{eval_len}_{label}");
    sparse.eval_threads = 1;
    let s_eval_seq = b.bench(&name_eval_seq, || bb(sparse.eval_ranks(&eb)));
    sparse.eval_threads = 0; // auto
    let s_eval_par = b.bench(&name_eval_par, || bb(sparse.eval_ranks(&eb)));
    let eval_speedup = s_eval_seq.mean_ns / s_eval_par.mean_ns;
    b.report_value("eval_ranks/speedup", eval_speedup, "x");

    // --- the BENCH_train.json trajectory point ----------------------------
    let hw_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut point = Json::obj()
        .set("suite", "train_hot_path")
        .set("entities", NUM_ENTITIES)
        .set("dim", DIM)
        .set("batch", BATCH)
        .set("negatives", NEGATIVES)
        .set("local_entities", LOCAL_ENTITIES)
        .set("dense_step_ms", s_dense.mean_ns / 1e6)
        .set("sparse_step_ms", s_sparse.mean_ns / 1e6)
        .set("train_speedup", train_speedup)
        .set("eval_seq_ms", s_eval_seq.mean_ns / 1e6)
        .set("eval_par_ms", s_eval_par.mean_ns / 1e6)
        .set("eval_speedup", eval_speedup)
        .set("threads", hw_threads);
    for (k, v) in &kernel_fields {
        point = point.set(k.as_str(), *v);
    }
    write_trajectory("BENCH_train", &point);
    println!(
        "train_hot_path: sparse {:.2} ms/step vs dense {:.2} ms/step → {:.1}x; \
         eval {:.2} ms → {:.2} ms → {:.1}x (BENCH_train.json written)",
        s_sparse.mean_ns / 1e6,
        s_dense.mean_ns / 1e6,
        train_speedup,
        s_eval_seq.mean_ns / 1e6,
        s_eval_par.mean_ns / 1e6,
        eval_speedup
    );
    b.finish();
}
