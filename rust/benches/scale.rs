//! Million-entity scale trajectory (the storage subsystem's cap):
//! `cargo bench --bench scale` (`FEDS_BENCH_FAST=1` for the CI smoke run).
//!
//! Two claims, one trajectory point (`BENCH_scale.json`):
//!
//! 1. **Per-round server cost is O(touched rows), not O(E).**  A full
//!    communication phase (`begin_round` + `receive` + `fede_download`)
//!    against an mmap-backed accumulator is timed at E = 100k and
//!    E = 1M with the *same* K touched rows; `scale_round_ratio` is the
//!    large/small time ratio, which stays near 1 when the round never
//!    walks the table (`scripts/bench_gate.py` caps it).
//!
//! 2. **A million-entity federated run fits in a fraction of its dense
//!    table footprint.**  An end-to-end FedS run at E = 1M on the mmap
//!    backend is driven through `spec::Session`; `rss_fraction` is the
//!    process peak RSS over the summed dense size of every
//!    O(entities × width) table the run owns (per-client model + Adam
//!    moments + history, plus the server accumulator).  Only touched
//!    pages of the mmap-backed tables ever become resident, so the
//!    fraction stays well below 1 (gated at 0.75).

use std::time::Instant;

use feds::fed::{ExecMode, Server};
use feds::kge::Method;
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, Session};
use feds::store::StorageSpec;
use feds::util::bench::{bb, peak_rss_bytes, write_trajectory, Bench};
use feds::util::json::Json;

/// Touched rows per round in the server sweep — fixed across E.
const TOUCHED_K: usize = 2048;
const SWEEP_WIDTH: usize = 64;
const SWEEP_CLIENTS: usize = 2;

const RUN_ENTITIES: usize = 1_000_000;
const RUN_DIM: usize = 32;
const RUN_CLIENTS: usize = 3;

/// One timed server round at `num_entities` with K touched rows: the
/// upload ids are spread evenly over the whole id space so every shard
/// participates, and both clients share the same list so aggregation
/// actually averages.
fn server_round_ms(b: &mut Bench, num_entities: usize, label: &str) -> f64 {
    let ids: Vec<u32> = (0..TOUCHED_K)
        .map(|i| (i as u64 * num_entities as u64 / TOUCHED_K as u64) as u32)
        .collect();
    let rows = vec![0.01f32; TOUCHED_K * SWEEP_WIDTH];
    let shared = vec![ids.clone(); SWEEP_CLIENTS];
    let mut server = Server::with_store(
        num_entities,
        SWEEP_WIDTH,
        shared,
        4,
        &StorageSpec::Mmap { dir: None },
    )
    .expect("mmap store");
    let stats = b.bench(&format!("round/mmap_{label}_k{TOUCHED_K}"), || {
        server.begin_round();
        for c in 0..SWEEP_CLIENTS as u16 {
            server.receive(c, &ids, &rows);
        }
        bb(server.fede_download(0).len())
    });
    stats.mean_ns / 1e6
}

fn main() {
    let fast = std::env::var("FEDS_BENCH_FAST").as_deref() == Ok("1");
    let mut b = Bench::from_env("scale");

    // -- claim 1: round time vs E at fixed K --------------------------------
    let round_small_ms = server_round_ms(&mut b, 100_000, "e100k");
    let round_large_ms = server_round_ms(&mut b, RUN_ENTITIES, "e1m");
    let scale_round_ratio = round_large_ms / round_small_ms.max(1e-9);
    b.report_value("scale_round_ratio", scale_round_ratio, "x (1M / 100k)");

    // -- claim 2: end-to-end E = 1M run on the mmap backend -----------------
    // Entity coverage in the generator emits one triple per otherwise-
    // unseen entity, so the KG carries ~E triples regardless of
    // `triples`; one local epoch then touches every local entity.  The
    // RSS saving is the non-local rows of each client's full-width
    // tables plus the never-touched rows of history and accumulator.
    let rounds = if fast { 1 } else { 2 };
    let spec = ExperimentSpec {
        name: "scale_e1m".to_string(),
        method: Method::TransE,
        algo: AlgoSpec::FedS { sparsity: 0.2, sync_interval: 2, sync: true },
        data: DataSpec {
            entities: RUN_ENTITIES,
            relations: 64,
            triples: 200_000,
            clusters: 16,
            clients: RUN_CLIENTS,
            seed: 11,
        },
        backend: BackendSpec::Native {
            dim: RUN_DIM,
            learning_rate: 3e-3,
            batch: 512,
            negatives: 16,
            // one query per eval batch keeps the O(eval_batch × E)
            // rank filter at 4 MB instead of swamping the RSS claim
            eval_batch: 1,
        },
        budget: BudgetSpec {
            max_rounds: rounds,
            local_epochs: 1,
            eval_every: rounds,
            patience: 3,
            eval_cap: 4,
        },
        seed: 7,
        exec: ExecMode::Sequential,
        transport: Default::default(),
        shards: 0,
        participation: Default::default(),
        storage: StorageSpec::Mmap { dir: None },
        compression: Default::default(),
    };

    let wall = Instant::now();
    let mut run = Session::new().build(&spec).expect("build E=1M run");
    run.quiet();
    let out = run.execute().expect("execute E=1M run");
    let run_wall_s = wall.elapsed().as_secs_f64();
    assert!(!out.history.records.is_empty(), "run produced no history");
    b.report_value("run_e1m_wall", run_wall_s, "s");

    // every full-size table the run owns, at dense (all-resident) size:
    // per client ent + Adam m + Adam v + FedS history, plus the server
    // accumulator — relation tables are O(R) and negligible.
    let width = Method::TransE.entity_width(RUN_DIM);
    let row_bytes = (RUN_ENTITIES * width * std::mem::size_of::<f32>()) as u64;
    let dense_table_bytes = (4 * RUN_CLIENTS as u64 + 1) * row_bytes;

    let mut point = Json::obj()
        .set("suite", "scale")
        .set("entities_small", 100_000u64)
        .set("entities_large", RUN_ENTITIES as u64)
        .set("width", SWEEP_WIDTH as u64)
        .set("touched_k", TOUCHED_K as u64)
        .set("round_small_ms", round_small_ms)
        .set("round_large_ms", round_large_ms)
        .set("scale_round_ratio", scale_round_ratio)
        .set("run_entities", RUN_ENTITIES as u64)
        .set("run_dim", RUN_DIM as u64)
        .set("run_clients", RUN_CLIENTS as u64)
        .set("run_rounds", rounds as u64)
        .set("run_wall_s", run_wall_s)
        .set("dense_table_bytes", dense_table_bytes);
    match peak_rss_bytes() {
        Some(peak) => {
            let rss_fraction = peak as f64 / dense_table_bytes as f64;
            assert!(
                rss_fraction < 1.0,
                "peak RSS {peak} reached dense table size {dense_table_bytes}: \
                 the mmap backend is no longer O(touched rows)"
            );
            b.report_value("peak_rss", peak as f64 / (1024.0 * 1024.0), "MiB");
            b.report_value("rss_fraction", rss_fraction, "of dense tables");
            point = point.set("peak_rss_bytes", peak).set("rss_fraction", rss_fraction);
        }
        // off-Linux: no procfs — the ratio claim still gates
        None => eprintln!("warning: peak RSS unavailable; rss_fraction omitted"),
    }
    write_trajectory("BENCH_scale", &point);
    println!(
        "scale: round {round_small_ms:.2} ms @100k vs {round_large_ms:.2} ms @1M \
         (ratio {scale_round_ratio:.2}), E=1M run {run_wall_s:.1} s"
    );
    b.finish();
}
