//! Bytes-vs-accuracy frontier of the `--compress` stage stacks:
//! `cargo bench --bench compression_frontier` (`FEDS_BENCH_FAST=1` for
//! the CI smoke run).
//!
//! One FedEP configuration is trained to the same round budget under a
//! sweep of compression stacks (none / topk / topk,int8 / topk,fp16 /
//! topk,svd / topk,int8:ef).  Every run meters its actual packed frame
//! bytes through the transport `Accounting`, so `bytes_per_round_<stack>`
//! is what really crossed the simulated wire, not an analytic estimate.
//! The trajectory point (`BENCH_bytes.json`) carries, per stack, bytes
//! per round and converged test MRR, plus the gated frontier claim:
//!
//! * `bytes_reduction_topk_int8` — bytes-per-round ratio of `topk` over
//!   `topk,int8`; quantizing the kept rows to int8 must cut at least 3×
//!   more bytes (`scripts/bench_gate.py` floors it).
//! * `mrr_degradation_topk_int8` — relative MRR loss of `topk,int8`
//!   against `topk` (clamped at 0), gated at ≤ 1%.

use feds::fed::compression::PipelineSpec;
use feds::fed::ExecMode;
use feds::kge::Method;
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, Session};
use feds::util::bench::{write_trajectory, Bench};
use feds::util::json::Json;

/// The sweep: `(json key suffix, stack label)`.
const STACKS: &[(&str, &str)] = &[
    ("none", ""),
    ("topk", "topk"),
    ("topk_int8", "topk,int8"),
    ("topk_fp16", "topk,fp16"),
    ("topk_svd", "topk,svd@8"),
    ("topk_int8_ef", "topk,int8:ef"),
];

fn spec_for(stack: &str, rounds: usize) -> ExperimentSpec {
    ExperimentSpec {
        name: format!("frontier_{}", if stack.is_empty() { "none" } else { stack }),
        method: Method::TransE,
        algo: AlgoSpec::FedEP,
        data: DataSpec {
            entities: 512,
            relations: 24,
            triples: 8_000,
            clusters: 8,
            clients: 3,
            seed: 64501,
        },
        backend: BackendSpec::Native {
            dim: 32,
            learning_rate: 5e-3,
            batch: 128,
            negatives: 16,
            eval_batch: 64,
        },
        budget: BudgetSpec {
            max_rounds: rounds,
            local_epochs: 1,
            // evaluate only at the end: every stack pays the same round
            // budget, so bytes-per-round comparisons are like-for-like
            eval_every: rounds,
            patience: rounds,
            eval_cap: 256,
        },
        seed: 64501,
        exec: ExecMode::Sequential,
        transport: Default::default(),
        shards: 0,
        participation: Default::default(),
        storage: Default::default(),
        compression: PipelineSpec::parse(stack).expect("frontier stacks parse"),
    }
}

fn main() {
    let fast = std::env::var("FEDS_BENCH_FAST").as_deref() == Ok("1");
    let rounds = if fast { 4 } else { 12 };
    let mut b = Bench::from_env("compression_frontier");

    let mut point = Json::obj()
        .set("suite", "compression_frontier")
        .set("rounds", rounds as u64)
        .set("entities", 512u64)
        .set("dim", 32u64)
        .set("clients", 3u64);

    let mut bytes_per_round = Vec::new();
    let mut mrrs = Vec::new();
    for (key, stack) in STACKS {
        let spec = spec_for(stack, rounds);
        let mut run = Session::new().build(&spec).expect("build frontier run");
        run.quiet();
        let out = run.execute().expect("execute frontier run");
        let executed = out.history.records.last().map(|r| r.round).unwrap_or(rounds);
        let bpr = out.acct.bytes() as f64 / executed.max(1) as f64;
        let mrr = out.history.mrr_cg();
        b.report_value(&format!("bytes_per_round_{key}"), bpr, "B/round");
        b.report_value(&format!("mrr_{key}"), mrr, "test MRR");
        point = point
            .set(format!("bytes_per_round_{key}").as_str(), bpr)
            .set(format!("mrr_{key}").as_str(), mrr);
        bytes_per_round.push((*key, bpr));
        mrrs.push((*key, mrr));
        println!("frontier: {:<14} {bpr:>12.0} B/round  MRR {mrr:.4}", format!("[{stack}]"));
    }

    let bpr_of = |k: &str| bytes_per_round.iter().find(|(key, _)| *key == k).unwrap().1;
    let mrr_of = |k: &str| mrrs.iter().find(|(key, _)| *key == k).unwrap().1;

    let reduction = bpr_of("topk") / bpr_of("topk_int8").max(1e-9);
    let degradation =
        ((mrr_of("topk") - mrr_of("topk_int8")) / mrr_of("topk").max(1e-9)).max(0.0);
    b.report_value("bytes_reduction_topk_int8", reduction, "x (topk / topk,int8)");
    b.report_value("mrr_degradation_topk_int8", degradation, "rel. MRR loss");
    point = point
        .set("bytes_reduction_topk_int8", reduction)
        .set("mrr_degradation_topk_int8", degradation);

    write_trajectory("BENCH_bytes", &point);
    println!(
        "frontier: topk,int8 transmits {reduction:.2}x fewer bytes than topk \
         at {:.2}% relative MRR loss",
        degradation * 100.0
    );
    b.finish();
}
