//! Sharded server aggregation throughput: one full communication phase
//! (`begin_round` + per-client `receive` + per-client `feds_download`)
//! at realistic scale, swept over the shard count.
//! `cargo bench --bench server_shards` (`FEDS_BENCH_FAST=1` for the CI
//! smoke run).
//!
//! Scenario: E = 50 000 entities, width 128, 8 clients each uploading a
//! 40% Top-K subset of their shared list — the FedS paper-default round
//! shape.  Every shard count produces bit-identical downloads (asserted
//! against the single-shard baseline before timing); only the
//! parallelism changes.  Besides the criterion-style report
//! (`reports/bench/server_shards.json`), this writes a single
//! `BENCH_server.json` trajectory point with per-shard-count round times
//! and speedups, which CI uploads as an artifact.

use feds::fed::Server;
use feds::util::bench::{bb, write_trajectory, Bench};
use feds::util::json::Json;
use feds::util::rng::Rng;

const NUM_ENTITIES: usize = 50_000;
const WIDTH: usize = 128;
const CLIENTS: usize = 8;
const SPARSITY: f64 = 0.4;

fn main() {
    let mut b = Bench::from_env("server_shards");
    let mut rng = Rng::new(42);

    // shared lists: each client shares ~60% of the entity space
    let shared: Vec<Vec<u32>> = (0..CLIENTS)
        .map(|_| (0..NUM_ENTITIES as u32).filter(|_| rng.bool(0.6)).collect())
        .collect();
    // uploads: an ascending ~40% subset of each client's shared list
    let uploads: Vec<(Vec<u32>, Vec<f32>)> = shared
        .iter()
        .map(|ids| {
            let up: Vec<u32> = ids.iter().copied().filter(|_| rng.bool(SPARSITY)).collect();
            let rows: Vec<f32> = (0..up.len() * WIDTH).map(|_| rng.uniform(-1.0, 1.0)).collect();
            (up, rows)
        })
        .collect();
    let k = (shared[0].len() as f64 * SPARSITY) as usize;

    let round = |server: &mut Server, seed: u64| {
        server.begin_round();
        for (c, (ids, rows)) in uploads.iter().enumerate() {
            server.receive(c as u16, ids, rows);
        }
        // deterministic download stream so every shard count sees the
        // same selection work
        let mut drng = Rng::new(seed);
        let mut checksum = 0u64;
        for c in 0..CLIENTS as u16 {
            let (_, rows, _) = server.feds_download(c, k, &mut drng);
            checksum ^= rows.len() as u64;
        }
        checksum
    };

    // correctness first: all shard counts agree with the 1-shard baseline
    let reference = {
        let mut server = Server::with_shards(NUM_ENTITIES, WIDTH, shared.clone(), 1);
        server.begin_round();
        for (c, (ids, rows)) in uploads.iter().enumerate() {
            server.receive(c as u16, ids, rows);
        }
        let mut drng = Rng::new(7);
        (0..CLIENTS as u16).map(|c| server.feds_download(c, k, &mut drng)).collect::<Vec<_>>()
    };

    let shard_counts = [1usize, 2, 4, 8];
    let mut round_ms = Vec::new();
    for &n_shards in &shard_counts {
        let mut server = Server::with_shards(NUM_ENTITIES, WIDTH, shared.clone(), n_shards);
        {
            server.begin_round();
            for (c, (ids, rows)) in uploads.iter().enumerate() {
                server.receive(c as u16, ids, rows);
            }
            let mut drng = Rng::new(7);
            for (c, want) in reference.iter().enumerate() {
                let got = server.feds_download(c as u16, k, &mut drng);
                assert_eq!(&got.0, &want.0, "sign diverged at {n_shards} shards");
                assert!(
                    got.1.iter().zip(&want.1).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "rows diverged at {n_shards} shards"
                );
                assert_eq!(&got.2, &want.2, "priorities diverged at {n_shards} shards");
            }
        }
        let stats = b.bench(&format!("round/shards{n_shards}"), || bb(round(&mut server, 11)));
        round_ms.push(stats.mean_ns / 1e6);
    }

    let speedups: Vec<f64> = round_ms.iter().map(|&ms| round_ms[0] / ms).collect();
    for (i, &n) in shard_counts.iter().enumerate() {
        b.report_value(&format!("round/shards{n}/speedup"), speedups[i], "x");
    }

    let hw_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let point = Json::obj()
        .set("suite", "server_shards")
        .set("entities", NUM_ENTITIES)
        .set("width", WIDTH)
        .set("clients", CLIENTS)
        .set("sparsity", SPARSITY)
        .set("shard_counts", Json::Arr(shard_counts.iter().map(|&n| Json::from(n)).collect()))
        .set("round_ms", Json::Arr(round_ms.iter().map(|&x| Json::from(x)).collect()))
        .set("speedup_vs_1", Json::Arr(speedups.iter().map(|&x| Json::from(x)).collect()))
        .set("threads", hw_threads);
    write_trajectory("BENCH_server", &point);
    println!(
        "server_shards: round {:.2} ms @ 1 shard → {:.2} ms @ {} shards → {:.2}x \
         (BENCH_server.json written)",
        round_ms[0],
        round_ms[round_ms.len() - 1],
        shard_counts[shard_counts.len() - 1],
        speedups[speedups.len() - 1]
    );
    b.finish();
}
