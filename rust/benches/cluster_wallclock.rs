//! Measured wall-clock per federated round on the cluster runtime:
//! a loopback `ClusterServer` plus one OS thread per client process,
//! run unthrottled and again on a rate-limited link ([`Throttle`]
//! enforcing a [`BandwidthModel`]), so the per-round seconds in
//! `ClusterOutcome::times` *measure* what `comm::bandwidth` predicts
//! statically from bytes.  `cargo bench --bench cluster_wallclock`
//! (`FEDS_BENCH_FAST=1` for the CI smoke run).
//!
//! The throttled run must stay bit-identical to the unthrottled one —
//! pacing delays frames, it never changes them — which the bench asserts
//! before reporting.  Besides the criterion-style report this writes one
//! `BENCH_cluster.json` trajectory point (measured round seconds, the
//! static model estimate, and the accounting totals), which CI uploads
//! as an artifact.
//!
//! [`Throttle`]: feds::comm::bandwidth::Throttle

use std::time::Duration;

use feds::comm::bandwidth::BandwidthModel;
use feds::fed::cluster::{run_client, ClientOpts, ClusterOutcome, ClusterServer, ServeOpts};
use feds::kge::Method;
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec};
use feds::util::bench::{write_trajectory, Bench};
use feds::util::json::Json;

fn bench_spec(rounds: usize) -> ExperimentSpec {
    ExperimentSpec {
        name: "cluster_wallclock".into(),
        method: Method::TransE,
        algo: AlgoSpec::feds(),
        data: DataSpec {
            entities: 256,
            relations: 12,
            triples: 4000,
            clusters: 4,
            clients: 3,
            seed: 11,
        },
        backend: BackendSpec::Native {
            dim: 16,
            learning_rate: 5e-3,
            batch: 64,
            negatives: 16,
            eval_batch: 32,
        },
        budget: BudgetSpec {
            max_rounds: rounds,
            local_epochs: 1,
            eval_every: 4,
            patience: 99,
            eval_cap: 64,
        },
        seed: 7,
        exec: Default::default(),
        transport: Default::default(),
        shards: 0,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    }
}

/// One full cluster run over loopback TCP: the server in this thread,
/// every client as its own thread speaking the cluster protocol.
fn cluster_run(spec: &ExperimentSpec, bandwidth: Option<BandwidthModel>) -> ClusterOutcome {
    let opts = ServeOpts { deadline: Duration::from_secs(60), bandwidth, ..ServeOpts::default() };
    let server = ClusterServer::bind("127.0.0.1:0", spec, opts).expect("bind loopback");
    let addr = server.addr().to_string();
    let handles: Vec<_> = (0..spec.data.clients)
        .map(|id| {
            let spec = spec.clone();
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut o = ClientOpts::new(addr, id as u16);
                o.bandwidth = bandwidth;
                run_client(&spec, &o).expect("client run");
            })
        })
        .collect();
    let out = server.run(&mut []).expect("server run");
    for h in handles {
        h.join().expect("client thread");
    }
    out
}

fn main() {
    let mut b = Bench::from_env("cluster_wallclock");
    let fast = std::env::var("FEDS_BENCH_FAST").as_deref() == Ok("1");
    let spec = bench_spec(if fast { 4 } else { 12 });

    // 200 Mbit/s + 2 ms per message: fast enough to keep the bench quick,
    // slow enough that the link (not the loopback stack) dominates
    let link = BandwidthModel { bytes_per_sec: 200e6 / 8.0, latency_s: 0.002 };
    let free = cluster_run(&spec, None);
    let throttled = cluster_run(&spec, Some(link));

    // pacing must not change what is computed, only when it arrives
    assert_eq!(free.run.acct.params(), throttled.run.acct.params(), "params diverged");
    assert_eq!(free.run.acct.bytes(), throttled.run.acct.bytes(), "bytes diverged");
    let (a, b_) = (&free.run.history.records, &throttled.run.history.records);
    assert_eq!(a.len(), b_.len(), "record count diverged");
    for (x, y) in a.iter().zip(b_.iter()) {
        assert_eq!(x.valid.mrr.to_bits(), y.valid.mrr.to_bits(), "MRR diverged at {}", x.round);
    }

    let rounds = throttled.times.secs.len() as u64;
    // static estimate: total metered bytes spread over the measured
    // rounds, two messages (upload + download) per client per comm round
    let per_round_bytes = throttled.run.acct.bytes() / rounds.max(1);
    let model_round_s = link.time_for(per_round_bytes / spec.data.clients as u64, 2);

    b.report_value("round/unthrottled/mean", free.times.mean(), "s");
    b.report_value("round/unthrottled/max", free.times.max(), "s");
    b.report_value("round/throttled/mean", throttled.times.mean(), "s");
    b.report_value("round/throttled/max", throttled.times.max(), "s");
    b.report_value("round/throttled/model", model_round_s, "s");

    let secs = |ts: &[f64]| Json::Arr(ts.iter().map(|&s| Json::from(s)).collect());
    let point = Json::obj()
        .set("suite", "cluster_wallclock")
        .set("clients", spec.data.clients)
        .set("rounds", rounds)
        .set("rate_mbps", link.bytes_per_sec * 8.0 / 1e6)
        .set("latency_ms", link.latency_s * 1e3)
        .set("unthrottled_secs", secs(&free.times.secs))
        .set("throttled_secs", secs(&throttled.times.secs))
        .set("unthrottled_mean_s", free.times.mean())
        .set("throttled_mean_s", throttled.times.mean())
        .set("model_round_s", model_round_s)
        .set("bytes", throttled.run.acct.bytes())
        .set("params", throttled.run.acct.params());
    write_trajectory("BENCH_cluster", &point);
    println!(
        "cluster_wallclock: {} rounds, mean {:.4}s free → {:.4}s throttled \
         (model {:.4}s; BENCH_cluster.json written)",
        rounds,
        free.times.mean(),
        throttled.times.mean(),
        model_round_s
    );
    b.finish();
}
