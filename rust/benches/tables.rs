//! End-to-end table benchmarks: one entry per paper table/figure, running
//! the corresponding experiment driver at CI scale (native backend, fast
//! mode) and reporting wall-clock + the key headline number of each.
//! `cargo bench --bench tables`.
//!
//! Full-scale regeneration (XLA backend) is `feds exp <table>`; see
//! EXPERIMENTS.md for recorded results.

use feds::exp::{self, Ctx};
use feds::util::bench::Bench;

fn main() {
    let mut b = Bench::from_env("tables");
    let ctx = Ctx::new(exp::native_backend(), true, 64501);
    std::env::set_var("FEDS_LOG", "warn");

    let t0 = std::time::Instant::now();
    let rep = exp::table23::run(&ctx).expect("table23");
    b.report_value("table23/wall_s", t0.elapsed().as_secs_f64(), "s");
    // headline: FedS P@CG ratio averaged over cells
    let _ = rep;

    let t0 = std::time::Instant::now();
    exp::table1::run(&ctx).expect("table1");
    b.report_value("table1/wall_s", t0.elapsed().as_secs_f64(), "s");

    let t0 = std::time::Instant::now();
    exp::table4::run(&ctx).expect("table4");
    b.report_value("table4/wall_s", t0.elapsed().as_secs_f64(), "s");

    let t0 = std::time::Instant::now();
    exp::fig2::run(&ctx).expect("fig2");
    b.report_value("fig2/wall_s", t0.elapsed().as_secs_f64(), "s");

    let t0 = std::time::Instant::now();
    exp::table5::run(&ctx).expect("table5");
    b.report_value("table5/wall_s", t0.elapsed().as_secs_f64(), "s");

    let t0 = std::time::Instant::now();
    exp::table6::run(&ctx).expect("table6");
    b.report_value("table6/wall_s", t0.elapsed().as_secs_f64(), "s");

    b.finish();
}
