//! PJRT runtime benchmarks: artifact compile time, single-step vs
//! scan-fused training latency, eval and change-score latency — the L2/L1
//! perf numbers in EXPERIMENTS.md §Perf.  Self-skips without artifacts.
//! `cargo bench --bench runtime_step`.

use std::path::Path;
use std::rc::Rc;

use feds::data::dataset::{BatchIter, EvalSet, FilterIndex};
use feds::data::generator::{generate, GeneratorConfig};
use feds::kge::Method;
use feds::runtime::Runtime;
use feds::store::StoreTable;
use feds::trainer::{LocalTrainer, XlaTrainer};
use feds::util::bench::{bb, Bench};
use feds::util::rng::Rng;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("runtime_step: artifacts not built, skipping (run `make artifacts`)");
        return;
    }
    let rt: Rc<Runtime> = Runtime::load(&dir).expect("runtime");
    let m = rt.manifest.clone();
    let mut b = Bench::from_env("runtime_step");

    // compile time (fresh runtime → cold cache)
    {
        let t0 = std::time::Instant::now();
        let rt2 = Runtime::load(&dir).unwrap();
        let meta = rt2.manifest.find(feds::runtime::Role::Train, Method::TransE, m.hyper.dim).unwrap();
        rt2.executable(meta).unwrap();
        b.report_value("compile/train_transe_cold_ms", t0.elapsed().as_secs_f64() * 1e3, "ms");
    }

    let kg = generate(&GeneratorConfig {
        num_entities: m.num_entities,
        num_relations: m.num_relations,
        num_triples: 6_000,
        seed: 3,
        ..Default::default()
    });
    let ents: Vec<u32> = (0..m.num_entities as u32).collect();

    for method in Method::ALL {
        let mut rng = Rng::new(5);
        let mut t = XlaTrainer::new(rt.clone(), method, m.hyper.dim, &mut rng).unwrap();
        let mut brng = Rng::new(7);
        let batches: Vec<_> =
            BatchIter::new(&kg.triples, &ents, m.batch, m.negatives, &mut brng)
                .take(8)
                .collect();

        b.bench(&format!("train_step/{}", method.name()), || {
            bb(t.train_batch(&batches[0]).unwrap())
        });
        let s = b.bench(&format!("train_epoch8/{}", method.name()), || {
            bb(t.train_batches(&batches).unwrap())
        });
        b.report_value(
            &format!("train_epoch8/{}/per_step_ms", method.name()),
            s.mean_ns / 8.0 / 1e6,
            "ms/step",
        );

        let filters = FilterIndex::build(kg.triples.iter());
        let es = EvalSet::new(&kg.triples[..m.eval_batch / 2], m.num_entities);
        let eb = es.batches(m.eval_batch, &filters).remove(0);
        b.bench(&format!("eval_step/{}", method.name()), || {
            bb(t.eval_ranks(&eb).unwrap())
        });

        let we = t.entity_width();
        let hist = StoreTable::zeros(m.num_entities, we);
        let ids: Vec<u32> = (0..m.num_entities as u32).collect();
        b.bench(&format!("change_scores/{}", method.name()), || {
            bb(t.change_scores(&ids, &hist).unwrap())
        });
    }

    b.finish();
}
