//! End-to-end bar for the `--compress` stage stacks: an **empty**
//! pipeline must be byte-identical (accounting) and bit-identical
//! (metrics) to a run that never mentions the knob, for both transports
//! and both exec modes; a **non-empty** stack must complete training,
//! transmit strictly fewer bytes than the dense baseline, and stay
//! transport- and exec-invariant itself.

use feds::comm::accounting::Direction;
use feds::fed::compression::PipelineSpec;
use feds::fed::{ExecMode, RunOutcome};
use feds::kge::Method;
use feds::spec::{
    AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, Session, TransportSpec,
};

fn tiny_spec(
    algo: AlgoSpec,
    exec: ExecMode,
    transport: TransportSpec,
    compress: &str,
) -> ExperimentSpec {
    ExperimentSpec {
        name: String::new(),
        method: Method::TransE,
        algo,
        data: DataSpec {
            entities: 192,
            relations: 12,
            triples: 2400,
            clusters: 4,
            clients: 3,
            seed: 11,
        },
        backend: BackendSpec::Native {
            dim: 16,
            learning_rate: 5e-3,
            batch: 64,
            negatives: 16,
            eval_batch: 32,
        },
        budget: BudgetSpec {
            max_rounds: 6,
            local_epochs: 1,
            eval_every: 2,
            patience: 3,
            eval_cap: 64,
        },
        seed: 7,
        exec,
        transport,
        shards: 2,
        participation: Default::default(),
        storage: Default::default(),
        compression: PipelineSpec::parse(compress).unwrap(),
    }
}

fn run(spec: &ExperimentSpec) -> RunOutcome {
    let mut session = Session::new();
    let mut run = session.build(spec).unwrap();
    run.quiet();
    run.execute().unwrap()
}

fn assert_identical(tag: &str, a: &RunOutcome, b: &RunOutcome) {
    for dir in [Direction::Upload, Direction::Download] {
        assert_eq!(a.acct.params_dir(dir), b.acct.params_dir(dir), "{tag}: params {dir:?}");
        assert_eq!(a.acct.bytes_dir(dir), b.acct.bytes_dir(dir), "{tag}: bytes {dir:?}");
    }
    assert_eq!(a.acct.messages(), b.acct.messages(), "{tag}: messages");
    let (x, y) = (&a.history.records, &b.history.records);
    assert_eq!(x.len(), y.len(), "{tag}: record count");
    assert_eq!(a.history.converged_idx, b.history.converged_idx, "{tag}: convergence");
    for (r, s) in x.iter().zip(y.iter()) {
        assert_eq!(r.round, s.round, "{tag}");
        assert_eq!(r.params_cum, s.params_cum, "{tag}: params@{}", r.round);
        assert_eq!(r.bytes_cum, s.bytes_cum, "{tag}: bytes@{}", r.round);
        assert_eq!(r.mean_loss.to_bits(), s.mean_loss.to_bits(), "{tag}: loss@{}", r.round);
        assert_eq!(r.test.mrr.to_bits(), s.test.mrr.to_bits(), "{tag}: test MRR@{}", r.round);
    }
}

/// `--compress ""` is the identity: for every dense algorithm, both
/// transports and both exec modes, a spec carrying the empty pipeline
/// runs byte- and bit-identically to one that never set the knob.
#[test]
fn empty_pipeline_is_identical_to_no_knob() {
    for algo in [AlgoSpec::FedEP, AlgoSpec::FedEPL] {
        for exec in [ExecMode::Sequential, ExecMode::Threaded] {
            for transport in [TransportSpec::Mpsc, TransportSpec::Tcp] {
                let bare = tiny_spec(algo.clone(), exec, transport, "");
                let mut knobbed = bare.clone();
                knobbed.apply_str("compression", "").unwrap();
                knobbed.validate().unwrap();
                assert_eq!(bare, knobbed, "empty pipeline must compare equal");
                let tag = format!("{algo:?}/{exec:?}/{transport:?}");
                assert_identical(&tag, &run(&bare), &run(&knobbed));
            }
        }
    }
}

/// A compressed FedEP run completes, learns (positive MRR), and puts
/// strictly fewer bytes on the wire than the dense baseline — for every
/// shipped stack shape.
#[test]
fn compressed_runs_transmit_fewer_bytes() {
    let dense = run(&tiny_spec(AlgoSpec::FedEP, ExecMode::Sequential, TransportSpec::Mpsc, ""));
    assert!(dense.acct.bytes() > 0);
    for stack in ["topk", "topk,int8", "topk,fp16", "topk,svd@4", "topk,int8:ef"] {
        let out =
            run(&tiny_spec(AlgoSpec::FedEP, ExecMode::Sequential, TransportSpec::Mpsc, stack));
        assert!(
            !out.history.records.is_empty(),
            "[{stack}] produced no evaluated rounds"
        );
        assert!(out.history.mrr_cg() > 0.0, "[{stack}] MRR collapsed");
        assert!(
            out.acct.bytes() < dense.acct.bytes(),
            "[{stack}] transmitted {} bytes, dense only {}",
            out.acct.bytes(),
            dense.acct.bytes()
        );
    }
}

/// A non-empty stack is itself transport- and exec-invariant: the packed
/// frames meter identically over mpsc and TCP, sequential and threaded.
#[test]
fn compressed_run_is_transport_and_exec_invariant() {
    let stack = "topk@0.5,int8:ef";
    let base = run(&tiny_spec(AlgoSpec::FedEP, ExecMode::Sequential, TransportSpec::Mpsc, stack));
    for (exec, transport) in [
        (ExecMode::Sequential, TransportSpec::Tcp),
        (ExecMode::Threaded, TransportSpec::Mpsc),
        (ExecMode::Threaded, TransportSpec::Tcp),
    ] {
        let other = run(&tiny_spec(AlgoSpec::FedEP, exec, transport, stack));
        assert_identical(&format!("{exec:?}/{transport:?}"), &base, &other);
    }
}
