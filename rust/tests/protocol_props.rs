//! Property tests of the FedS protocol pieces in combination: server
//! aggregation conservation, sign/row consistency, Eq. 4 merge algebra,
//! sync cycle structure, failure injection on the wire, and the packed
//! compression frames (stage-tagged `--compress` payloads).

use feds::comm::accounting::{Accounting, Direction};
use feds::comm::transport::{duplex, Endpoint, TcpTransport};
use feds::comm::wire::{read_frame, write_frame};
use feds::fed::compression::{int8_dequantize, int8_quantize, Pipeline, PipelineSpec};
use feds::fed::protocol::{Download, Upload};
use feds::store::StorageSpec;
use feds::fed::topk::{select_by_change, select_by_priority, top_k_count};
use feds::fed::{Server, SyncSchedule};
use feds::util::prop::check;
use feds::util::rng::Rng;

/// Random federation: n clients, e entities, random shared lists + uploads.
fn random_round(
    rng: &mut Rng,
) -> (Server, Vec<Vec<u32>>, Vec<Vec<(u32, Vec<f32>)>>, usize) {
    let e = 8 + rng.usize_below(40);
    let w = 1 + rng.usize_below(6);
    let n_clients = 2 + rng.usize_below(4);
    let shared: Vec<Vec<u32>> = (0..n_clients)
        .map(|_| (0..e as u32).filter(|_| rng.bool(0.7)).collect())
        .collect();
    let mut server = Server::new(e, w, shared.clone());
    server.begin_round();
    let mut uploads = Vec::new();
    for (c, ids) in shared.iter().enumerate() {
        let mut these = Vec::new();
        for &id in ids {
            if rng.bool(0.5) {
                let row: Vec<f32> = (0..w).map(|_| rng.uniform(-1.0, 1.0)).collect();
                these.push((id, row));
            }
        }
        let flat_ids: Vec<u32> = these.iter().map(|(i, _)| *i).collect();
        let flat_rows: Vec<f32> = these.iter().flat_map(|(_, r)| r.clone()).collect();
        server.receive(c as u16, &flat_ids, &flat_rows);
        uploads.push(these);
    }
    (server, shared, uploads, w)
}

#[test]
fn personalized_aggregation_is_sum_of_others() {
    check("agg_conservation", 40, |rng| {
        let (server, shared, uploads, w) = random_round(rng);
        let n_clients = shared.len();
        let c = rng.usize_below(n_clients);
        let (sign, rows, prio) = server.feds_download(c as u16, usize::MAX, rng);
        assert_eq!(sign.len(), shared[c].len());
        let mut row_idx = 0;
        for (i, &id) in shared[c].iter().enumerate() {
            if !sign[i] {
                continue;
            }
            // reference: sum over all *other* clients that uploaded id
            let mut want = vec![0.0f32; w];
            let mut count = 0u32;
            for (cc, these) in uploads.iter().enumerate() {
                if cc == c {
                    continue;
                }
                if let Some((_, r)) = these.iter().find(|(i2, _)| *i2 == id) {
                    for j in 0..w {
                        want[j] += r[j];
                    }
                    count += 1;
                }
            }
            assert!(count > 0, "selected entity must have a contributor");
            assert_eq!(prio[row_idx], count);
            for j in 0..w {
                let got = rows[row_idx * w + j];
                assert!(
                    (got - want[j]).abs() < 1e-5,
                    "agg mismatch at entity {id} dim {j}: {got} vs {}",
                    want[j]
                );
            }
            row_idx += 1;
        }
        assert_eq!(rows.len(), row_idx * w);
    });
}

#[test]
fn downstream_never_selects_uncontributed_entities() {
    check("no_phantom_entities", 40, |rng| {
        let (server, shared, uploads, _) = random_round(rng);
        let c = rng.usize_below(shared.len());
        let k = 1 + rng.usize_below(8);
        let (sign, _, _) = server.feds_download(c as u16, k, rng);
        for (i, &id) in shared[c].iter().enumerate() {
            if sign[i] {
                let others_uploaded = uploads
                    .iter()
                    .enumerate()
                    .any(|(cc, these)| cc != c && these.iter().any(|(i2, _)| *i2 == id));
                assert!(others_uploaded, "entity {id} selected without contributors");
            }
        }
        let n_sel = sign.iter().filter(|&&s| s).count();
        assert!(n_sel <= k);
    });
}

#[test]
fn eq4_merge_is_inclusive_average() {
    // (A + E)/(1 + P) where A sums P other clients == average over P+1 values
    check("eq4_average", 30, |rng| {
        let w = 1 + rng.usize_below(8);
        let p = 1 + rng.usize_below(5);
        let own: Vec<f32> = (0..w).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let others: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..w).map(|_| rng.uniform(-1.0, 1.0)).collect())
            .collect();
        let mut a = vec![0.0f32; w];
        for o in &others {
            for j in 0..w {
                a[j] += o[j];
            }
        }
        for j in 0..w {
            let merged = (a[j] + own[j]) / (1.0 + p as f32);
            let mut avg = own[j];
            for o in &others {
                avg += o[j];
            }
            avg /= (p + 1) as f32;
            assert!((merged - avg).abs() < 1e-5);
        }
    });
}

#[test]
fn upstream_selection_consistent_with_k_formula() {
    check("upstream_k", 40, |rng| {
        let n = 1 + rng.usize_below(300);
        let p = rng.f64();
        let k = top_k_count(n, p);
        let scores: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 2.0)).collect();
        let sel = select_by_change(&scores, k);
        assert_eq!(sel.len(), k);
        assert!(k <= n);
        if p > 0.0 {
            assert!(k >= 1);
        }
    });
}

#[test]
fn priority_selection_total_order_property() {
    check("priority_order", 40, |rng| {
        let n = 1 + rng.usize_below(100);
        let prios: Vec<u32> = (0..n).map(|_| rng.u32_below(5)).collect();
        let k = rng.usize_below(n + 1);
        let sel = select_by_priority(&prios, k, rng);
        // sorted by priority descending in the output order
        for w in sel.windows(2) {
            assert!(prios[w[0]] >= prios[w[1]]);
        }
    });
}

#[test]
fn sync_cycles_are_regular_for_any_interval() {
    check("sync_cycles", 20, |rng| {
        let s = 1 + rng.usize_below(10);
        let mut sched = SyncSchedule::new(Some(s));
        let mut last = 0usize;
        let mut gaps = Vec::new();
        for round in 1..=200 {
            if sched.step(round) {
                gaps.push(round - last);
                last = round;
            }
        }
        assert!(!gaps.is_empty());
        // every gap is exactly s+1 rounds (s sparse + 1 sync)
        assert!(gaps.iter().all(|&g| g == s + 1), "{gaps:?} for s={s}");
    });
}

#[test]
fn wire_corruption_fails_loudly_not_silently() {
    check("wire_corruption", 30, |rng| {
        let up = Upload::Sparse {
            round: rng.next_u64() as u32,
            client: 3,
            sign: (0..40).map(|_| rng.bool(0.5)).collect(),
            emb: (0..64).map(|_| rng.uniform(-1.0, 1.0)).collect(),
        };
        let mut frame = up.encode();
        // truncation must error
        let cut = rng.usize_below(frame.len().saturating_sub(1));
        assert!(Upload::decode(&frame[..cut]).is_err() || cut >= frame.len() - 5);
        // tag corruption must error
        frame[0] = 77;
        assert!(Upload::decode(&frame).is_err());
    });
}

#[test]
fn download_decode_rejects_truncation() {
    let d = Download::Sparse {
        round: 1,
        sign: vec![true; 16],
        emb: vec![1.0; 32],
        prio: vec![2; 8],
    };
    let frame = d.encode();
    for cut in [1usize, 5, frame.len() / 2] {
        assert!(Download::decode(&frame[..cut]).is_err());
    }
}

#[test]
fn transport_metering_matches_frames() {
    let acct = Accounting::new();
    let (client, server) = duplex(acct.clone());
    let mut total_bytes = 0u64;
    let mut rng = Rng::new(4);
    for round in 0..10u32 {
        let up = Upload::Full {
            round,
            client: 0,
            emb: (0..rng.usize_below(100)).map(|_| 1.0f32).collect(),
        };
        let frame = up.encode();
        total_bytes += frame.len() as u64;
        client.send(frame, up.params()).unwrap();
        let got = Upload::decode(&server.recv().unwrap()).unwrap();
        assert_eq!(got, up);
    }
    assert_eq!(acct.bytes(), total_bytes);
    assert_eq!(acct.messages(), 10);
}

/// Property: `Upload::Sparse`/`Download::Sparse` survive the wire for any
/// sign/emb/prio shape, the bit-packed `bits` codec included, and the
/// frame layout is exactly what the codec promises.
#[test]
fn sparse_messages_roundtrip_the_wire() {
    check("sparse_wire_roundtrip", 60, |rng| {
        let n = 1 + rng.usize_below(256);
        let w = 1 + rng.usize_below(12);
        let sign: Vec<bool> = (0..n).map(|_| rng.bool(0.4)).collect();
        let k = sign.iter().filter(|&&s| s).count();
        let emb: Vec<f32> = (0..k * w).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let round = rng.next_u64() as u32;
        let client = rng.u32_below(u16::MAX as u32 + 1) as u16;

        let up = Upload::Sparse { round, client, sign: sign.clone(), emb: emb.clone() };
        let frame = up.encode();
        assert_eq!(Upload::decode(&frame).unwrap(), up);
        // tag(1) + round(4) + client(2) + bits(4 + ⌈n/8⌉) + f32s(4 + 4kw)
        assert_eq!(frame.len(), 15 + n.div_ceil(8) + 4 * emb.len(), "n={n} k={k} w={w}");
        // paper-parameter count stays the dense-typed one (§III-F)
        assert_eq!(up.params(), (n + k * w) as u64);

        let prio: Vec<u32> = (0..k).map(|_| rng.u32_below(64)).collect();
        let down = Download::Sparse { round, sign, emb, prio: prio.clone() };
        let frame = down.encode();
        assert_eq!(Download::decode(&frame).unwrap(), down);
        // tag(1) + round(4) + bits(4 + ⌈n/8⌉) + f32s(4 + 4kw) + u32s(4 + 4k)
        assert_eq!(frame.len(), 17 + n.div_ceil(8) + 4 * (k * w) + 4 * k);
        assert_eq!(down.params(), (n + k * w + k) as u64);

        // truncation must error, never panic
        assert!(Download::decode(&frame[..frame.len() - 1]).is_err());
    });
}

/// Property: sparse frames over a metered duplex link record exactly the
/// paper-parameter count and the bit-packed byte size, in both directions.
#[test]
fn endpoint_meters_sparse_frames_exactly() {
    check("sparse_endpoint_metering", 30, |rng| {
        let n = 1 + rng.usize_below(128);
        let w = 1 + rng.usize_below(8);
        let sign: Vec<bool> = (0..n).map(|_| rng.bool(0.5)).collect();
        let k = sign.iter().filter(|&&s| s).count();
        let emb: Vec<f32> = (0..k * w).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let prio: Vec<u32> = (0..k).map(|_| rng.u32_below(8)).collect();

        let acct = Accounting::new();
        let (client, server) = duplex(acct.clone());
        let up = Upload::Sparse { round: 1, client: 0, sign: sign.clone(), emb: emb.clone() };
        client.send(up.encode(), up.params()).unwrap();
        assert_eq!(Upload::decode(&server.recv().unwrap()).unwrap(), up);
        let down = Download::Sparse { round: 1, sign, emb, prio };
        server.send(down.encode(), down.params()).unwrap();
        assert_eq!(Download::decode(&client.recv().unwrap()).unwrap(), down);

        assert_eq!(acct.params_dir(Direction::Upload), up.params());
        assert_eq!(acct.params_dir(Direction::Download), down.params());
        assert_eq!(acct.bytes_dir(Direction::Upload), up.encode().len() as u64);
        assert_eq!(acct.bytes_dir(Direction::Download), down.encode().len() as u64);
    });
}

/// Every stage-tag combination the pipeline grammar admits at depth ≤ 3
/// (Top-K first when present, no duplicate stage kinds).
const ALL_STACKS: &[&str] = &[
    "topk",
    "topk@0.25",
    "topk:ef",
    "int8",
    "int8:ef",
    "fp16",
    "fp16:ef",
    "svd@4",
    "svd@4:ef",
    "topk,int8",
    "topk,int8:ef",
    "topk,fp16",
    "topk,fp16:ef",
    "topk,svd@4",
    "topk@0.5:ef,svd@4:ef",
    "topk,int8:ef,svd@4",
    "topk:ef,fp16:ef,svd@4:ef",
    "int8,svd@4",
    "fp16,svd@4",
];

/// Encode a random block through `stack` at `width`, wrap it in
/// `Upload::Packed`, and hand it back with the frame.
fn random_packed(rng: &mut Rng, stack: &str) -> (Upload, Vec<u8>) {
    let width = 4 + 4 * rng.usize_below(4); // 4..=16, divisible for svd@4
    let n = 1 + rng.usize_below(24);
    let pipeline = Pipeline::new(&PipelineSpec::parse(stack).unwrap(), width).unwrap();
    let ids: Vec<u32> = (0..n as u32).collect();
    let deltas: Vec<f32> = (0..n * width).map(|_| rng.uniform(-2.0, 2.0)).collect();
    let mut res = pipeline.make_residuals(&StorageSpec::Ram, n).unwrap();
    let block = pipeline.encode(&ids, &deltas, None, &mut res);
    let up = Upload::Packed {
        round: rng.next_u64() as u32,
        client: rng.u32_below(64) as u16,
        block,
    };
    let frame = up.encode();
    (up, frame)
}

/// Property: packed frames of every stage-tag combination round-trip the
/// wire exactly, and the decoded block reconstructs through the pipeline.
#[test]
fn packed_frames_roundtrip_for_every_stack() {
    check("packed_wire_roundtrip", 2, |rng| {
        for stack in ALL_STACKS {
            let (up, frame) = random_packed(rng, stack);
            let got = Upload::decode(&frame).unwrap();
            assert_eq!(got, up, "stack {stack}");
            let Upload::Packed { round, block, .. } = got else { unreachable!() };
            let down = Download::Packed { round, block: block.clone() };
            let dframe = down.encode();
            assert_eq!(Download::decode(&dframe).unwrap(), down, "stack {stack}");
            // the decoded block is still decodable by the same pipeline
            let width = block.width as usize;
            let pipeline =
                Pipeline::new(&PipelineSpec::parse(stack).unwrap(), width).unwrap();
            let (idx, rows) = pipeline.decode(&block).unwrap();
            assert_eq!(rows.len(), idx.len() * width, "stack {stack}");
        }
    });
}

/// Property: truncating or corrupting a packed frame at any byte yields a
/// typed error, never a panic.
#[test]
fn malformed_packed_frames_error_not_panic() {
    check("packed_malformed", 2, |rng| {
        for stack in ["topk", "topk,int8:ef", "topk,fp16", "int8,svd@4"] {
            let (_, frame) = random_packed(rng, stack);
            for cut in 0..frame.len() {
                assert!(Upload::decode(&frame[..cut]).is_err(), "cut {cut} stack {stack}");
            }
            let mut bad = frame.clone();
            let at = rng.usize_below(bad.len());
            bad[at] ^= 0xA5;
            // any outcome but a panic is acceptable: most flips error,
            // some land in the float payload and still decode
            let _ = Upload::decode(&bad);
        }
    });
}

/// Property: the int8 row quantizer's reconstruction error is bounded by
/// half a quantization step (scale / 254) per component.
#[test]
fn int8_row_error_bounded() {
    check("int8_error_bound", 40, |rng| {
        let n = 1 + rng.usize_below(64);
        let vals: Vec<f32> = (0..n).map(|_| rng.uniform(-8.0, 8.0)).collect();
        let (scale, codes) = int8_quantize(&vals);
        let back = int8_dequantize(scale, &codes);
        let bound = (scale / 254.0) * (1.0 + 1e-5) + 1e-30;
        for (a, b) in vals.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "|{a} - {b}| > {bound} (scale {scale})");
        }
    });
}

/// A `Read` that returns at most `cap` bytes per call — the shortest
/// reads a stream socket could legally produce.
struct ChunkedReader<'a> {
    buf: &'a [u8],
    pos: usize,
    cap: usize,
}

impl std::io::Read for ChunkedReader<'_> {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        let n = out.len().min(self.cap).min(self.buf.len() - self.pos);
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

fn random_upload(rng: &mut Rng) -> Upload {
    let n = 1 + rng.usize_below(96);
    let w = 1 + rng.usize_below(8);
    if rng.bool(0.5) {
        Upload::Full {
            round: rng.next_u64() as u32,
            client: rng.u32_below(64) as u16,
            emb: (0..n * w).map(|_| rng.uniform(-2.0, 2.0)).collect(),
        }
    } else {
        let sign: Vec<bool> = (0..n).map(|_| rng.bool(0.4)).collect();
        let k = sign.iter().filter(|&&s| s).count();
        Upload::Sparse {
            round: rng.next_u64() as u32,
            client: rng.u32_below(64) as u16,
            sign,
            emb: (0..k * w).map(|_| rng.uniform(-2.0, 2.0)).collect(),
        }
    }
}

fn random_download(rng: &mut Rng) -> Download {
    let n = 1 + rng.usize_below(96);
    let w = 1 + rng.usize_below(8);
    if rng.bool(0.5) {
        Download::Full {
            round: rng.next_u64() as u32,
            emb: (0..n * w).map(|_| rng.uniform(-2.0, 2.0)).collect(),
        }
    } else {
        let sign: Vec<bool> = (0..n).map(|_| rng.bool(0.4)).collect();
        let k = sign.iter().filter(|&&s| s).count();
        Download::Sparse {
            round: rng.next_u64() as u32,
            sign,
            emb: (0..k * w).map(|_| rng.uniform(-2.0, 2.0)).collect(),
            prio: (0..k).map(|_| rng.u32_below(32)).collect(),
        }
    }
}

/// Property: arbitrary protocol frames survive the stream framing codec
/// under arbitrarily short reads — the TCP reader reassembles frame
/// boundaries no matter how the stream fragments.
#[test]
fn frames_roundtrip_the_stream_codec_under_partial_reads() {
    check("stream_codec_partial_reads", 40, |rng| {
        let ups: Vec<Upload> = (0..1 + rng.usize_below(6)).map(|_| random_upload(rng)).collect();
        let downs: Vec<Download> =
            (0..1 + rng.usize_below(6)).map(|_| random_download(rng)).collect();
        let mut stream = Vec::new();
        for u in &ups {
            write_frame(&mut stream, &u.encode()).unwrap();
        }
        for d in &downs {
            write_frame(&mut stream, &d.encode()).unwrap();
        }
        let cap = 1 + rng.usize_below(17);
        let mut r = ChunkedReader { buf: &stream, pos: 0, cap };
        for u in &ups {
            let frame = read_frame(&mut r).unwrap().expect("frame present");
            assert_eq!(&Upload::decode(&frame).unwrap(), u, "cap {cap}");
        }
        for d in &downs {
            let frame = read_frame(&mut r).unwrap().expect("frame present");
            assert_eq!(&Download::decode(&frame).unwrap(), d, "cap {cap}");
        }
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF at a frame boundary");
    });
}

/// Property: arbitrary `Upload`/`Download` frames round-trip the real TCP
/// loopback transport — boundaries, order and metering all intact, with
/// byte accounting identical to what the mpsc duplex records for the
/// same frames.
#[test]
fn frames_roundtrip_the_tcp_transport() {
    check("tcp_transport_roundtrip", 12, |rng| {
        let ups: Vec<Upload> = (0..1 + rng.usize_below(5)).map(|_| random_upload(rng)).collect();
        let downs: Vec<Download> =
            (0..1 + rng.usize_below(5)).map(|_| random_download(rng)).collect();

        let tcp_acct = Accounting::new();
        let transport = TcpTransport::bind_loopback().unwrap();
        let (tcp_client, tcp_server) = transport.connect_pair(tcp_acct.clone()).unwrap();
        let mpsc_acct = Accounting::new();
        let (mpsc_client, mpsc_server) = duplex(mpsc_acct.clone());

        for u in &ups {
            tcp_client.send(u.encode(), u.params()).unwrap();
            mpsc_client.send(u.encode(), u.params()).unwrap();
        }
        for u in &ups {
            assert_eq!(&Upload::decode(&tcp_server.recv().unwrap()).unwrap(), u);
            mpsc_server.recv().unwrap();
        }
        for d in &downs {
            tcp_server.send(d.encode(), d.params()).unwrap();
            mpsc_server.send(d.encode(), d.params()).unwrap();
        }
        for d in &downs {
            assert_eq!(&Download::decode(&tcp_client.recv().unwrap()).unwrap(), d);
            mpsc_client.recv().unwrap();
        }

        // the metering contract is transport-independent, bit for bit
        for dir in [Direction::Upload, Direction::Download] {
            assert_eq!(tcp_acct.params_dir(dir), mpsc_acct.params_dir(dir), "{dir:?} params");
            assert_eq!(tcp_acct.bytes_dir(dir), mpsc_acct.bytes_dir(dir), "{dir:?} bytes");
        }
        assert_eq!(tcp_acct.messages(), mpsc_acct.messages());
    });
}
