//! The storage subsystem's correctness bar: a run whose O(entities ×
//! width) tables live in mmap-backed files must be **bit-identical** —
//! same accounting, same losses, same ranks — to the same run on the
//! in-RAM backend, for every algorithm and both execution modes.  The
//! backend may only change *where* rows live, never a single bit of
//! what the protocol computes.

use feds::comm::accounting::Direction;
use feds::fed::{run_params, Backend, ExecMode, RoundParams, RunOutcome};
use feds::kge::{Hyper, Method};
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec};
use feds::store::StorageSpec;

fn tiny_spec(algo: AlgoSpec, exec: ExecMode) -> ExperimentSpec {
    ExperimentSpec {
        name: String::new(),
        method: Method::TransE,
        algo,
        data: DataSpec {
            entities: 192,
            relations: 12,
            triples: 2400,
            clusters: 4,
            clients: 3,
            seed: 11,
        },
        backend: BackendSpec::Native {
            dim: 16,
            learning_rate: 5e-3,
            batch: 64,
            negatives: 16,
            eval_batch: 32,
        },
        budget: BudgetSpec {
            max_rounds: 4,
            local_epochs: 1,
            eval_every: 2,
            patience: 3,
            eval_cap: 64,
        },
        seed: 7,
        exec,
        transport: Default::default(),
        shards: 0,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    }
}

fn run_with(algo: AlgoSpec, exec: ExecMode, storage: StorageSpec) -> RunOutcome {
    let spec = tiny_spec(algo, exec);
    let data = spec.data.build();
    let BackendSpec::Native { dim, learning_rate, batch, negatives, eval_batch } = &spec.backend
    else {
        unreachable!()
    };
    let backend = Backend::Native {
        hyper: Hyper { dim: *dim, learning_rate: *learning_rate, ..Default::default() },
        batch: *batch,
        negatives: *negatives,
        eval_batch: *eval_batch,
    };
    let mut params = RoundParams::from_spec(&spec, &backend);
    params.storage = storage;
    run_params(&data, &params, &backend, &mut []).unwrap()
}

fn assert_bit_identical(tag: &str, ram: &RunOutcome, mmap: &RunOutcome) {
    for dir in [Direction::Upload, Direction::Download] {
        assert_eq!(ram.acct.params_dir(dir), mmap.acct.params_dir(dir), "{tag}: params {dir:?}");
        assert_eq!(ram.acct.bytes_dir(dir), mmap.acct.bytes_dir(dir), "{tag}: bytes {dir:?}");
    }
    assert_eq!(ram.acct.messages(), mmap.acct.messages(), "{tag}: messages");
    assert_eq!(ram.eq5_ratio, mmap.eq5_ratio, "{tag}: eq5");
    let (a, b) = (&ram.history.records, &mmap.history.records);
    assert_eq!(a.len(), b.len(), "{tag}: record count");
    assert_eq!(ram.history.converged_idx, mmap.history.converged_idx, "{tag}: convergence");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round, "{tag}");
        assert_eq!(x.params_cum, y.params_cum, "{tag}: params@{}", x.round);
        assert_eq!(x.bytes_cum, y.bytes_cum, "{tag}: bytes@{}", x.round);
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{tag}: loss@{}", x.round);
        assert_eq!(x.valid.mrr.to_bits(), y.valid.mrr.to_bits(), "{tag}: valid MRR@{}", x.round);
        assert_eq!(x.test.mrr.to_bits(), y.test.mrr.to_bits(), "{tag}: test MRR@{}", x.round);
        assert_eq!(x.test.hits10.to_bits(), y.test.hits10.to_bits(), "{tag}: hits@{}", x.round);
    }
}

/// Every algorithm × both exec modes: the mmap backend reproduces the
/// in-RAM run bit for bit.
#[test]
fn mmap_backend_matches_ram_for_every_algo_and_exec_mode() {
    let algos = [
        AlgoSpec::Single,
        AlgoSpec::FedEP,
        AlgoSpec::FedEPL,
        AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: true },
        AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: false },
        AlgoSpec::Svd { cols: 8, plus: false },
        AlgoSpec::Svd { cols: 8, plus: true },
    ];
    for algo in algos {
        for exec in [ExecMode::Sequential, ExecMode::Threaded] {
            let ram = run_with(algo.clone(), exec, StorageSpec::Ram);
            let mmap = run_with(algo.clone(), exec, StorageSpec::Mmap { dir: None });
            assert_bit_identical(&format!("{algo:?}/{exec:?}"), &ram, &mmap);
        }
    }
}

/// An explicit scratch directory is honored and left usable: the run
/// completes against it and its files never outlive their stores on
/// platforms with unlink-after-map (elsewhere they are plain files in
/// the chosen directory, not strewn into the global temp dir).
#[test]
fn mmap_backend_honors_explicit_directory() {
    let dir = std::env::temp_dir().join("feds_storage_equiv_test");
    std::fs::create_dir_all(&dir).unwrap();
    let storage = StorageSpec::Mmap { dir: Some(dir.to_string_lossy().into_owned()) };
    let ram = run_with(AlgoSpec::feds(), ExecMode::Sequential, StorageSpec::Ram);
    let mmap = run_with(AlgoSpec::feds(), ExecMode::Sequential, storage);
    assert_bit_identical("feds/explicit-dir", &ram, &mmap);
    if cfg!(target_os = "linux") {
        let left: Vec<_> = std::fs::read_dir(&dir).unwrap().flatten().collect();
        assert!(left.is_empty(), "scratch files must not accumulate: {left:?}");
    }
}
