//! The API-redesign correctness bar: a run driven through the
//! `spec::Session` observer pipeline must yield **byte-identical**
//! communication accounting and **bit-identical** round history to the
//! same engine driven directly (`run_params` on hand-derived
//! `RoundParams`), for every algorithm and both execution modes, and a
//! sweep-grid cell must equal the same run driven directly.

use feds::comm::accounting::Direction;
use feds::exp::sweep::{run_sweep, SweepSpec};
use feds::fed::{run_params, Backend, ExecMode, RoundParams, RunOutcome};
use feds::kge::{Hyper, Method};
use feds::metrics::observe::JsonlSink;
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, Session};
use feds::util::json::Json;

fn tiny_spec(algo: AlgoSpec, exec: ExecMode) -> ExperimentSpec {
    ExperimentSpec {
        name: String::new(),
        method: Method::TransE,
        algo,
        data: DataSpec {
            entities: 192,
            relations: 12,
            triples: 2400,
            clusters: 4,
            clients: 3,
            seed: 11,
        },
        backend: BackendSpec::Native {
            dim: 16,
            learning_rate: 5e-3,
            batch: 64,
            negatives: 16,
            eval_batch: 32,
        },
        budget: BudgetSpec {
            max_rounds: 6,
            local_epochs: 1,
            eval_every: 2,
            patience: 3,
            eval_cap: 64,
        },
        seed: 7,
        exec,
        transport: Default::default(),
        shards: 0,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    }
}

/// The direct-path run for `spec`: same dataset, same resolved params,
/// same backend — through the bare `run_params` engine, no Session.
fn direct_run(spec: &ExperimentSpec) -> RunOutcome {
    let data = spec.data.build();
    let BackendSpec::Native { dim, learning_rate, batch, negatives, eval_batch } = &spec.backend
    else {
        panic!("equivalence tests run on the native backend");
    };
    let backend = Backend::Native {
        hyper: Hyper { dim: *dim, learning_rate: *learning_rate, ..Default::default() },
        batch: *batch,
        negatives: *negatives,
        eval_batch: *eval_batch,
    };
    let params = RoundParams::from_spec(spec, &backend);
    run_params(&data, &params, &backend, &mut []).unwrap()
}

fn assert_equivalent(tag: &str, direct: &RunOutcome, session: &RunOutcome) {
    for dir in [Direction::Upload, Direction::Download] {
        assert_eq!(
            direct.acct.params_dir(dir),
            session.acct.params_dir(dir),
            "{tag}: params {dir:?}"
        );
        assert_eq!(
            direct.acct.bytes_dir(dir),
            session.acct.bytes_dir(dir),
            "{tag}: bytes {dir:?}"
        );
    }
    assert_eq!(direct.acct.messages(), session.acct.messages(), "{tag}: messages");
    assert_eq!(direct.eq5_ratio, session.eq5_ratio, "{tag}: eq5");
    let (a, b) = (&direct.history.records, &session.history.records);
    assert_eq!(a.len(), b.len(), "{tag}: record count");
    assert_eq!(
        direct.history.converged_idx, session.history.converged_idx,
        "{tag}: convergence index"
    );
    assert_eq!(direct.history.label, session.history.label, "{tag}: label");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round, "{tag}");
        assert_eq!(x.params_cum, y.params_cum, "{tag}: params@{}", x.round);
        assert_eq!(x.bytes_cum, y.bytes_cum, "{tag}: bytes@{}", x.round);
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{tag}: loss@{}", x.round);
        assert_eq!(x.valid.mrr.to_bits(), y.valid.mrr.to_bits(), "{tag}: valid MRR@{}", x.round);
        assert_eq!(x.test.mrr.to_bits(), y.test.mrr.to_bits(), "{tag}: test MRR@{}", x.round);
        assert_eq!(
            x.test.hits10.to_bits(),
            y.test.hits10.to_bits(),
            "{tag}: hits@10 @{}",
            x.round
        );
    }
}

/// Every algorithm × both exec modes: Session == direct engine, byte for byte.
#[test]
fn session_matches_direct_engine_for_every_algo_and_exec_mode() {
    let algos = [
        AlgoSpec::Single,
        AlgoSpec::FedEP,
        AlgoSpec::FedEPL,
        AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: true },
        AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: false },
        AlgoSpec::Svd { cols: 8, plus: false },
        AlgoSpec::Svd { cols: 8, plus: true },
    ];
    let mut session = Session::new();
    for algo in algos {
        for exec in [ExecMode::Sequential, ExecMode::Threaded] {
            let spec = tiny_spec(algo.clone(), exec);
            let direct = direct_run(&spec);
            let mut run = session.build(&spec).unwrap();
            run.quiet();
            let out = run.execute().unwrap();
            assert_equivalent(&format!("{algo:?}/{exec:?}"), &direct, &out);
        }
    }
}

/// A table4-shaped sweep grid (FedEP / FedEPL / FedS over one dataset)
/// equals the same three runs driven directly through the bare engine.
#[test]
fn sweep_grid_matches_direct_runs() {
    let base = tiny_spec(AlgoSpec::FedEP, ExecMode::Sequential);
    let sweep = SweepSpec::new("mini_table4", base.clone()).axis(
        "algo",
        vec![Json::from("fedep"), Json::from("fedepl"), Json::from("feds")],
    );
    let mut session = Session::new();
    let grid = run_sweep(&mut session, &sweep, &mut []).unwrap();
    assert_eq!(grid.cells.len(), 3);

    for (i, label) in ["fedep", "fedepl", "feds"].iter().enumerate() {
        let mut spec = base.clone();
        spec.apply("algo", &Json::from(*label)).unwrap();
        let direct = direct_run(&spec);
        assert_equivalent(&format!("sweep cell {label}"), &direct, &grid.at(&[i]).outcome);
        assert_eq!(grid.at(&[i]).spec.algo, AlgoSpec::parse(label).unwrap());
    }
    // lookup by override value finds the same cell
    let found = grid.find(&[("algo", &Json::from("feds"))]).unwrap();
    assert_eq!(found.spec.algo, AlgoSpec::feds());
}

/// The sweep's JSONL stream is non-empty, line-parseable, and carries one
/// evaluated line per history record.
#[test]
fn sweep_jsonl_stream_matches_histories() {
    let base = tiny_spec(AlgoSpec::FedEP, ExecMode::Sequential);
    let sweep = SweepSpec::new("jsonl_smoke", base).axis(
        "algo",
        vec![Json::from("fedep"), Json::from("feds")],
    );
    let dir = std::env::temp_dir().join("feds_jsonl_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("stream.jsonl");
    let mut session = Session::new();
    let grid = {
        let mut sink = JsonlSink::create(&path).unwrap();
        run_sweep(&mut session, &sweep, &mut [&mut sink]).unwrap()
    };
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(!text.trim().is_empty(), "stream must be non-empty");
    let mut starts = 0usize;
    let mut evaluated = 0usize;
    let mut ends = 0usize;
    for line in text.lines() {
        let j = Json::parse(line).expect("every line is one JSON object");
        match j.get("event").and_then(Json::as_str) {
            Some("run_start") => starts += 1,
            Some("evaluated") => evaluated += 1,
            Some("run_end") => ends += 1,
            Some(_) => {}
            None => panic!("event tag missing: {line}"),
        }
    }
    assert_eq!(starts, 2, "one run_start per cell");
    assert_eq!(ends, 2, "one run_end per cell");
    let records: usize = grid.cells.iter().map(|c| c.outcome.history.records.len()).sum();
    assert_eq!(evaluated, records, "one evaluated event per history record");
}
