//! The cluster-runtime correctness bar.
//!
//! * Handshake: wrong protocol version, wrong spec digest, out-of-range
//!   id, and a non-hello first frame are all refused with a reasoned
//!   [`ClusterMsg::Reject`].
//! * No failures: a 3-client run over real loopback TCP processes¹ is
//!   **bit-identical** — accounting, round records, convergence — to the
//!   same spec driven in-process by the bare engine.
//! * Crash mid-run: the abrupt client is cut, the round aggregates
//!   partially (`PartialRound`), and the run still completes.
//! * Handover: a clean leave and a mid-frame crash at the same round,
//!   each followed by a rejoin with resync, yield bit-identical runs —
//!   failure *classification* differs, failure *semantics* don't.
//! * Crash recovery: a coordinator halted right after a checkpoint is
//!   restored on the same address; the clients ride through the outage
//!   on reconnect backoff and the stitched run is **bit-identical** to
//!   one that never stopped.  Tampered or mismatched snapshots are
//!   refused loudly at bind, never silently restarted.
//! * Sampled participation: a non-`Full` policy draws a seeded cohort
//!   every round (deterministically — two runs agree bit-for-bit), and a
//!   salvaged upload still folds exactly once even when its sender is
//!   never sampled again.
//!
//! ¹ client processes are OS threads here (same sockets, same protocol);
//!   `tests/cluster_process.rs` runs the real multi-process drill.

use std::fs;
use std::net::TcpStream;
use std::path::PathBuf;
use std::thread;

use feds::comm::accounting::Direction;
use feds::comm::wire::{read_frame, write_frame};
use feds::fed::cluster::{
    chaos, checkpoint, run_client, spec_digest, Checkpoint, ClientOpts, ClusterMsg, ClusterOutcome,
    ClusterServer, CoordinatorHalted, ServeOpts, PROTO_VERSION,
};
use feds::fed::protocol::Upload;
use feds::fed::{run_params, Backend, RoundParams, RunOutcome};
use feds::kge::{Hyper, Method};
use feds::metrics::observe::{RunEvent, RunObserver};
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, ParticipationSpec};

fn tiny_spec(algo: AlgoSpec, max_rounds: usize) -> ExperimentSpec {
    ExperimentSpec {
        name: String::new(),
        method: Method::TransE,
        algo,
        data: DataSpec {
            entities: 192,
            relations: 12,
            triples: 2400,
            clusters: 4,
            clients: 3,
            seed: 11,
        },
        backend: BackendSpec::Native {
            dim: 16,
            learning_rate: 5e-3,
            batch: 64,
            negatives: 16,
            eval_batch: 32,
        },
        budget: BudgetSpec {
            max_rounds,
            local_epochs: 1,
            eval_every: 2,
            patience: 3,
            eval_cap: 64,
        },
        seed: 7,
        exec: Default::default(),
        transport: Default::default(),
        shards: 0,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    }
}

/// The in-process reference run: same dataset, same resolved params,
/// through the bare `run_params` engine.
fn direct_run(spec: &ExperimentSpec) -> RunOutcome {
    let data = spec.data.build();
    let BackendSpec::Native { dim, learning_rate, batch, negatives, eval_batch } = &spec.backend
    else {
        panic!("cluster tests run on the native backend");
    };
    let backend = Backend::Native {
        hyper: Hyper { dim: *dim, learning_rate: *learning_rate, ..Default::default() },
        batch: *batch,
        negatives: *negatives,
        eval_batch: *eval_batch,
    };
    let params = RoundParams::from_spec(spec, &backend);
    run_params(&data, &params, &backend, &mut []).unwrap()
}

fn assert_equivalent(tag: &str, direct: &RunOutcome, cluster: &RunOutcome) {
    for dir in [Direction::Upload, Direction::Download] {
        assert_eq!(
            direct.acct.params_dir(dir),
            cluster.acct.params_dir(dir),
            "{tag}: params {dir:?}"
        );
        assert_eq!(
            direct.acct.bytes_dir(dir),
            cluster.acct.bytes_dir(dir),
            "{tag}: bytes {dir:?}"
        );
    }
    assert_eq!(direct.acct.messages(), cluster.acct.messages(), "{tag}: messages");
    assert_eq!(direct.eq5_ratio, cluster.eq5_ratio, "{tag}: eq5");
    let (a, b) = (&direct.history.records, &cluster.history.records);
    assert_eq!(a.len(), b.len(), "{tag}: record count");
    assert_eq!(
        direct.history.converged_idx, cluster.history.converged_idx,
        "{tag}: convergence index"
    );
    assert_eq!(direct.history.label, cluster.history.label, "{tag}: label");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round, "{tag}");
        assert_eq!(x.params_cum, y.params_cum, "{tag}: params@{}", x.round);
        assert_eq!(x.bytes_cum, y.bytes_cum, "{tag}: bytes@{}", x.round);
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{tag}: loss@{}", x.round);
        assert_eq!(x.valid.mrr.to_bits(), y.valid.mrr.to_bits(), "{tag}: valid MRR@{}", x.round);
        assert_eq!(x.test.mrr.to_bits(), y.test.mrr.to_bits(), "{tag}: test MRR@{}", x.round);
        assert_eq!(
            x.test.hits10.to_bits(),
            y.test.hits10.to_bits(),
            "{tag}: hits@10 @{}",
            x.round
        );
    }
}

#[derive(Default)]
struct EventLog(Vec<RunEvent>);

impl RunObserver for EventLog {
    fn on_event(&mut self, ev: &RunEvent) {
        self.0.push(ev.clone());
    }
}

/// One full cluster run over loopback: the coordinator on this thread,
/// every entry of `clients` as its own OS thread running the real
/// `run_client` protocol loop (`connect` is filled in from the bind).
fn cluster_run(spec: &ExperimentSpec, clients: Vec<ClientOpts>) -> (ClusterOutcome, Vec<RunEvent>) {
    let server = ClusterServer::bind("127.0.0.1:0", spec, ServeOpts::default()).expect("bind");
    let addr = server.addr().to_string();
    let handles: Vec<_> = clients
        .into_iter()
        .map(|mut o| {
            let spec = spec.clone();
            o.connect = addr.clone();
            thread::spawn(move || run_client(&spec, &o).expect("client run"))
        })
        .collect();
    let mut log = EventLog::default();
    let out = server.run(&mut [&mut log]).expect("server run");
    for h in handles {
        h.join().expect("client thread");
    }
    (out, log.0)
}

fn fleet(n: u16) -> Vec<ClientOpts> {
    (0..n).map(|id| ClientOpts::new("", id)).collect()
}

/// Every refusable handshake is refused with a reasoned reject frame.
#[test]
fn handshake_rejects_mismatched_registrations() {
    let spec = tiny_spec(AlgoSpec::FedEP, 6);
    let digest = spec_digest(&spec);
    let server = ClusterServer::bind("127.0.0.1:0", &spec, ServeOpts::default()).expect("bind");
    let addr = server.addr();

    let expect_reject = |first: ClusterMsg, needle: &str| {
        let sock = TcpStream::connect(addr).expect("connect");
        write_frame(&mut (&sock), &first.encode()).expect("send first frame");
        let frame = read_frame(&mut (&sock)).expect("read reply").expect("reply before close");
        match ClusterMsg::decode(&frame).expect("decode reply") {
            ClusterMsg::Reject { reason } => {
                assert!(reason.contains(needle), "reason {reason:?} lacks {needle:?}");
            }
            other => panic!("expected a reject, got {other:?}"),
        }
    };

    let hello = |version, client, spec_digest| ClusterMsg::Hello {
        version,
        client,
        spec_digest,
        join_round: 0,
    };
    expect_reject(hello(PROTO_VERSION + 1, 0, digest), "protocol version");
    expect_reject(hello(PROTO_VERSION, 0, digest ^ 1), "spec mismatch");
    expect_reject(hello(PROTO_VERSION, 9, digest), "out of range");
    let report = ClusterMsg::Report { round: 1, loss: 0.0, batches: 1, eval: None };
    expect_reject(report, "hello");
    // the acceptor stays up for real joins afterwards; the run is never
    // started here, so the server value just drops (acceptor detaches)
}

/// With no failures injected, a multi-process run is bit-identical to
/// the in-process engine — for a dense algorithm and for sparse FedS.
#[test]
fn cluster_run_matches_in_process_engine() {
    for algo in [AlgoSpec::FedEP, AlgoSpec::feds()] {
        let spec = tiny_spec(algo.clone(), 6);
        let direct = direct_run(&spec);
        let (out, events) = cluster_run(&spec, fleet(3));
        assert_equivalent(&format!("{algo:?}"), &direct, &out.run);
        assert_eq!(out.times.secs.len(), 6, "{algo:?}: one wall-clock sample per round");
        let joins = events
            .iter()
            .filter(|e| matches!(e, RunEvent::ClientJoined { rejoin: false, .. }))
            .count();
        assert_eq!(joins, 3, "{algo:?}: three fresh registrations");
        let failures = events.iter().any(|e| {
            matches!(e, RunEvent::ClientDropped { .. } | RunEvent::PartialRound { .. })
        });
        assert!(!failures, "{algo:?}: no failure events in a failure-free run");
    }
}

/// A client killed mid-frame is classified as an abrupt crash, cut from
/// the round, and the round aggregates whoever reported.
#[test]
fn crashed_client_is_cut_and_the_round_aggregates_partially() {
    let spec = tiny_spec(AlgoSpec::FedEP, 6);
    let mut clients = fleet(3);
    clients[1].fail_after = Some(2);
    let (out, events) = cluster_run(&spec, clients);

    let dropped = events.iter().any(|e| {
        matches!(e, RunEvent::ClientDropped { round: 3, client: 1, clean: false })
    });
    assert!(dropped, "client 1 must be cut abruptly at round 3: {events:?}");
    let partial = events.iter().any(|e| {
        matches!(e, RunEvent::PartialRound { round: 3, reported: 2, expected: 3 })
    });
    assert!(partial, "round 3 must aggregate partially: {events:?}");
    assert_eq!(out.run.history.records.len(), 3, "evaluations at rounds 2, 4, 6");
    assert_eq!(out.times.secs.len(), 6, "the run completes every round despite the crash");
}

/// The handover drill: client 2 leaves after round 3 — once cleanly,
/// once by dying mid-frame — and a replacement process for the same id
/// rejoins at round 6, resynced from the cached download.  The two
/// scenarios differ only in disconnect classification; every number in
/// the run is bit-identical.
#[test]
fn clean_leave_and_crash_handover_are_bit_identical_with_rejoin() {
    let spec = tiny_spec(AlgoSpec::feds(), 8);
    let scenario = |crash: bool| {
        let mut clients = fleet(3);
        if crash {
            clients[2].fail_after = Some(3);
        } else {
            clients[2].leave_after = Some(3);
        }
        let mut replacement = ClientOpts::new("", 2);
        replacement.join_round = 6;
        clients.push(replacement);
        cluster_run(&spec, clients)
    };
    let (clean, clean_ev) = scenario(false);
    let (crash, crash_ev) = scenario(true);

    assert_equivalent("clean vs crash handover", &clean.run, &crash.run);
    let clean_drop = clean_ev.iter().any(|e| {
        matches!(e, RunEvent::ClientDropped { client: 2, clean: true, .. })
    });
    assert!(clean_drop, "the leave must classify as clean: {clean_ev:?}");
    let crash_drop = crash_ev.iter().any(|e| {
        matches!(e, RunEvent::ClientDropped { client: 2, clean: false, .. })
    });
    assert!(crash_drop, "the crash must classify as abrupt: {crash_ev:?}");
    for events in [&clean_ev, &crash_ev] {
        let rejoined = events.iter().any(|e| {
            matches!(e, RunEvent::ClientJoined { round: 6, client: 2, rejoin: true })
        });
        assert!(rejoined, "the replacement must rejoin at round 6: {events:?}");
        let partial = events.iter().any(|e| {
            matches!(e, RunEvent::PartialRound { round: 4, reported: 2, expected: 3 })
        });
        assert!(partial, "round 4 must aggregate partially: {events:?}");
    }
    assert_eq!(clean.run.history.records.len(), 4, "evaluations at rounds 2, 4, 6, 8");
}

/// A fresh per-test scratch directory under the system temp dir.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("feds-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// A minimal hand-crafted snapshot: rounds 1..=2 "completed" with nothing
/// metered and nothing cached — just enough state for a coordinator to
/// restore at round 3 and welcome a fresh fleet.
fn crafted_checkpoint(spec: &ExperimentSpec, carried: Vec<(u16, Vec<u8>)>) -> Checkpoint {
    Checkpoint {
        spec_digest: spec_digest(spec),
        round: 2,
        early_stop: (f64::NEG_INFINITY, 0, 0, 0),
        up_params: 0,
        down_params: 0,
        up_bytes: 0,
        down_bytes: 0,
        messages: 0,
        secs: vec![0.0, 0.0],
        records: Vec::new(),
        last_download: vec![None; 3],
        carried,
        exchange: Some(Vec::new()),
    }
}

/// The crash-recovery drill: the coordinator checkpoints every round and
/// halts (typed fault injection) right after the round-3 snapshot; a
/// replacement coordinator restores the snapshot on the same address.
/// The clients ride through the outage on reconnect backoff alone, and
/// the stitched run is bit-identical to one that never stopped.
#[test]
fn halted_coordinator_restores_bit_identically_and_clients_reconnect() {
    let spec = tiny_spec(AlgoSpec::feds(), 8);
    let direct = direct_run(&spec);
    let dir = scratch("restore-drill");

    let mut opts = ServeOpts { checkpoint: Some(dir.clone()), ..ServeOpts::default() };
    chaos::halt_coordinator_at(&mut opts, 3);
    let server = ClusterServer::bind("127.0.0.1:0", &spec, opts).expect("bind");
    let addr = server.addr().to_string();
    let handles: Vec<_> = (0..3u16)
        .map(|id| {
            let spec = spec.clone();
            let opts = ClientOpts::new(addr.clone(), id);
            thread::spawn(move || {
                run_client(&spec, &opts).expect("client rides through the coordinator outage")
            })
        })
        .collect();
    let mut log = EventLog::default();
    let err = server.run(&mut [&mut log]).err().expect("the injected halt must surface");
    let halted = err.downcast_ref::<CoordinatorHalted>().expect("the halt error is typed");
    assert_eq!(halted.round, 3, "the halt lands right after the round-3 checkpoint");
    let snapshot = log.0.iter().any(|e| matches!(e, RunEvent::CheckpointWritten { round: 3, .. }));
    assert!(snapshot, "the round-3 snapshot must be announced: {:?}", log.0);

    // the replacement coordinator binds the same address the clients are
    // re-dialing with backoff right now
    let ropts = ServeOpts { restore: Some(dir.clone()), ..ServeOpts::default() };
    let server = ClusterServer::bind(&addr, &spec, ropts).expect("rebind with restore");
    let mut rlog = EventLog::default();
    let out = server.run(&mut [&mut rlog]).expect("restored run completes");
    for h in handles {
        h.join().expect("client thread");
    }

    assert_equivalent("restored vs never-stopped", &direct, &out.run);
    assert_eq!(out.times.secs.len(), 8, "3 checkpointed + 5 resumed wall-clock samples");
    let is_rejoin = |e: &&RunEvent| matches!(e, RunEvent::ClientReconnected { .. });
    let reconnects = rlog.0.iter().filter(is_rejoin).count();
    assert_eq!(reconnects, 3, "every client re-registers after the outage: {:?}", rlog.0);
    let _ = fs::remove_dir_all(&dir);
}

/// A restore refuses — loudly, at bind time — a checkpoint that belongs
/// to a different spec or that lost bytes to a torn write.  Neither case
/// may quietly start a fresh run.
#[test]
fn restore_refuses_mismatched_or_tampered_checkpoints() {
    let spec = tiny_spec(AlgoSpec::FedEP, 4);
    let dir = scratch("ckpt-refusal");
    checkpoint::save(&dir, &crafted_checkpoint(&spec, Vec::new())).expect("write the snapshot");
    let restore_opts = || ServeOpts { restore: Some(dir.clone()), ..ServeOpts::default() };

    // the matching spec loads (the round loop is never started here)
    ClusterServer::bind("127.0.0.1:0", &spec, restore_opts()).expect("valid restore binds");
    // another spec's server must not adopt this run
    let other = tiny_spec(AlgoSpec::FedEP, 5);
    let err = ClusterServer::bind("127.0.0.1:0", &other, restore_opts())
        .err()
        .expect("a mismatched snapshot must be refused");
    assert!(format!("{err}").contains("different spec"), "unexpected reason: {err}");
    // a torn write is corruption, not a quiet fresh start
    chaos::truncate_checkpoint(&dir, 9).expect("truncate the snapshot");
    let err = ClusterServer::bind("127.0.0.1:0", &spec, restore_opts())
        .err()
        .expect("a truncated snapshot must be refused");
    assert!(format!("{err}").contains("corrupt checkpoint"), "unexpected reason: {err}");
    let _ = fs::remove_dir_all(&dir);
}

/// Sampled participation: every round draws exactly k live clients from
/// a stream keyed only by (seed, round), so two runs of the same spec
/// are bit-identical, sitting a round out is not a dropout, and the run
/// still completes every round.
#[test]
fn sampled_participation_draws_k_per_round_and_is_deterministic() {
    let mut spec = tiny_spec(AlgoSpec::feds(), 6);
    spec.participation = ParticipationSpec::KofN(2);
    let (a, ev_a) = cluster_run(&spec, fleet(3));
    let (b, _ev_b) = cluster_run(&spec, fleet(3));

    assert_equivalent("two sampled runs", &a.run, &b.run);
    for round in 1..=6usize {
        let drawn: Vec<usize> = ev_a
            .iter()
            .filter_map(|e| match e {
                RunEvent::ClientSampled { round: r, client } if *r == round => Some(*client),
                _ => None,
            })
            .collect();
        assert_eq!(drawn.len(), 2, "round {round} samples exactly 2 of 3: {drawn:?}");
    }
    let failures = ev_a.iter().any(|e| {
        matches!(e, RunEvent::ClientDropped { .. } | RunEvent::PartialRound { .. })
    });
    assert!(!failures, "sitting a round out must not classify as a failure: {ev_a:?}");
    assert_eq!(a.times.secs.len(), 6, "the run completes every round");
    assert_eq!(a.run.history.records.len(), 3, "evaluations at rounds 2, 4, 6");
}

/// The carried-upload × participation regression: a snapshot carries an
/// upload salvaged from a client that never comes back — so it is in no
/// later round's cohort — and the restored coordinator must fold it
/// exactly once (deterministically, and observably: the aggregation it
/// folds into shifts relative to a restore that carried nothing).
#[test]
fn carried_upload_folds_exactly_once_even_when_its_sender_is_never_sampled() {
    let mut spec = tiny_spec(AlgoSpec::FedEP, 4);
    spec.participation = ParticipationSpec::KofN(2);
    let data = spec.data.build();
    let rows = data.shared_entities_of(2).len();
    let upload = Upload::Full { round: 2, client: 2, emb: vec![0.25; rows * 16] };

    let resume = |tag: &str, carried: Vec<(u16, Vec<u8>)>| {
        let dir = scratch(tag);
        checkpoint::save(&dir, &crafted_checkpoint(&spec, carried)).expect("write the snapshot");
        // client 2 is gone for good; only 0 and 1 greet the restored
        // coordinator
        let opts = ServeOpts { restore: Some(dir.clone()), expect: 2, ..ServeOpts::default() };
        let server = ClusterServer::bind("127.0.0.1:0", &spec, opts).expect("bind");
        let addr = server.addr().to_string();
        let handles: Vec<_> = (0..2u16)
            .map(|id| {
                let spec = spec.clone();
                let opts = ClientOpts::new(addr.clone(), id);
                thread::spawn(move || run_client(&spec, &opts).expect("client run"))
            })
            .collect();
        let mut log = EventLog::default();
        let out = server.run(&mut [&mut log]).expect("restored run completes");
        for h in handles {
            h.join().expect("client thread");
        }
        let _ = fs::remove_dir_all(&dir);
        (out, log.0)
    };

    let (with, ev) = resume("carried-a", vec![(2, upload.encode())]);
    let (again, _) = resume("carried-b", vec![(2, upload.encode())]);
    let (without, _) = resume("carried-none", Vec::new());

    // deterministic: the carried rows fold once, the same way, every time
    assert_equivalent("carried fold determinism", &with.run, &again.run);
    // the dead sender is in no cohort (sampling draws from live ids only)
    let ghost = ev.iter().any(|e| matches!(e, RunEvent::ClientSampled { client: 2, .. }));
    assert!(!ghost, "a gone client must never be sampled: {ev:?}");
    // and the fold really happened: the aggregation (and everything
    // downstream of it) shifts relative to a restore that carried nothing
    let (ra, rb) = (&with.run.history.records, &without.run.history.records);
    assert_eq!(ra.len(), rb.len(), "same evaluation schedule either way");
    let moved = ra.iter().zip(rb.iter()).any(|(x, y)| {
        x.mean_loss.to_bits() != y.mean_loss.to_bits()
            || x.valid.mrr.to_bits() != y.valid.mrr.to_bits()
    });
    assert!(moved, "the carried upload must fold into the round-3 aggregation");
    // folding is unmetered at restore time: the salvage was already
    // accounted when the client was cut, before the snapshot
    assert_eq!(with.run.acct.params(), without.run.acct.params(), "fold is not re-metered");
}

/// A restored coordinator knows it may be behind the fleet: an id that
/// already dropped claiming a join round ahead of the coordinator's
/// position is refused with the reason spelled out (satellite of the
/// restore work: never silently rewind a client).
#[test]
fn restored_coordinator_rejects_clients_from_its_future() {
    let spec = tiny_spec(AlgoSpec::FedEP, 4);
    let dir = scratch("reject-ahead");
    checkpoint::save(&dir, &crafted_checkpoint(&spec, Vec::new())).expect("write the snapshot");
    let opts = ServeOpts { restore: Some(dir.clone()), expect: 2, ..ServeOpts::default() };
    let server = ClusterServer::bind("127.0.0.1:0", &spec, opts).expect("bind");
    let addr = server.addr().to_string();

    // a peer from the coordinator's future registers first, while the
    // barrier is still waiting — it must be turned away, not held
    let sock = TcpStream::connect(&addr).expect("connect");
    let hello = ClusterMsg::Hello {
        version: PROTO_VERSION,
        client: 2,
        spec_digest: spec_digest(&spec),
        join_round: 40,
    };
    write_frame(&mut (&sock), &hello.encode()).expect("send hello");

    let handles: Vec<_> = (0..2u16)
        .map(|id| {
            let spec = spec.clone();
            let opts = ClientOpts::new(addr.clone(), id);
            thread::spawn(move || run_client(&spec, &opts).expect("client run"))
        })
        .collect();

    let frame = read_frame(&mut (&sock)).expect("read reply").expect("reply before close");
    match ClusterMsg::decode(&frame).expect("decode reply") {
        ClusterMsg::Reject { reason } => {
            assert!(
                reason.contains("ahead of the coordinator"),
                "reason {reason:?} must name the restore skew"
            );
        }
        other => panic!("expected a reject, got {other:?}"),
    }
    server.run(&mut []).expect("the run completes without the rejected peer");
    for h in handles {
        h.join().expect("client thread");
    }
    let _ = fs::remove_dir_all(&dir);
}
