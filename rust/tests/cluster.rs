//! The cluster-runtime correctness bar.
//!
//! * Handshake: wrong protocol version, wrong spec digest, out-of-range
//!   id, and a non-hello first frame are all refused with a reasoned
//!   [`ClusterMsg::Reject`].
//! * No failures: a 3-client run over real loopback TCP processes¹ is
//!   **bit-identical** — accounting, round records, convergence — to the
//!   same spec driven in-process by the bare engine.
//! * Crash mid-run: the abrupt client is cut, the round aggregates
//!   partially (`PartialRound`), and the run still completes.
//! * Handover: a clean leave and a mid-frame crash at the same round,
//!   each followed by a rejoin with resync, yield bit-identical runs —
//!   failure *classification* differs, failure *semantics* don't.
//!
//! ¹ client processes are OS threads here (same sockets, same protocol);
//!   `tests/cluster_process.rs` runs the real multi-process drill.

use std::net::TcpStream;
use std::thread;

use feds::comm::accounting::Direction;
use feds::comm::wire::{read_frame, write_frame};
use feds::fed::cluster::{
    run_client, spec_digest, ClientOpts, ClusterMsg, ClusterOutcome, ClusterServer, ServeOpts,
    PROTO_VERSION,
};
use feds::fed::{run_params, Backend, RoundParams, RunOutcome};
use feds::kge::{Hyper, Method};
use feds::metrics::observe::{RunEvent, RunObserver};
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec};

fn tiny_spec(algo: AlgoSpec, max_rounds: usize) -> ExperimentSpec {
    ExperimentSpec {
        name: String::new(),
        method: Method::TransE,
        algo,
        data: DataSpec {
            entities: 192,
            relations: 12,
            triples: 2400,
            clusters: 4,
            clients: 3,
            seed: 11,
        },
        backend: BackendSpec::Native {
            dim: 16,
            learning_rate: 5e-3,
            batch: 64,
            negatives: 16,
            eval_batch: 32,
        },
        budget: BudgetSpec {
            max_rounds,
            local_epochs: 1,
            eval_every: 2,
            patience: 3,
            eval_cap: 64,
        },
        seed: 7,
        exec: Default::default(),
        transport: Default::default(),
        shards: 0,
    }
}

/// The in-process reference run: same dataset, same resolved params,
/// through the bare `run_params` engine.
fn direct_run(spec: &ExperimentSpec) -> RunOutcome {
    let data = spec.data.build();
    let BackendSpec::Native { dim, learning_rate, batch, negatives, eval_batch } = &spec.backend
    else {
        panic!("cluster tests run on the native backend");
    };
    let backend = Backend::Native {
        hyper: Hyper { dim: *dim, learning_rate: *learning_rate, ..Default::default() },
        batch: *batch,
        negatives: *negatives,
        eval_batch: *eval_batch,
    };
    let params = RoundParams::from_spec(spec, &backend);
    run_params(&data, &params, &backend, &mut []).unwrap()
}

fn assert_equivalent(tag: &str, direct: &RunOutcome, cluster: &RunOutcome) {
    for dir in [Direction::Upload, Direction::Download] {
        assert_eq!(
            direct.acct.params_dir(dir),
            cluster.acct.params_dir(dir),
            "{tag}: params {dir:?}"
        );
        assert_eq!(
            direct.acct.bytes_dir(dir),
            cluster.acct.bytes_dir(dir),
            "{tag}: bytes {dir:?}"
        );
    }
    assert_eq!(direct.acct.messages(), cluster.acct.messages(), "{tag}: messages");
    assert_eq!(direct.eq5_ratio, cluster.eq5_ratio, "{tag}: eq5");
    let (a, b) = (&direct.history.records, &cluster.history.records);
    assert_eq!(a.len(), b.len(), "{tag}: record count");
    assert_eq!(
        direct.history.converged_idx, cluster.history.converged_idx,
        "{tag}: convergence index"
    );
    assert_eq!(direct.history.label, cluster.history.label, "{tag}: label");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round, "{tag}");
        assert_eq!(x.params_cum, y.params_cum, "{tag}: params@{}", x.round);
        assert_eq!(x.bytes_cum, y.bytes_cum, "{tag}: bytes@{}", x.round);
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{tag}: loss@{}", x.round);
        assert_eq!(x.valid.mrr.to_bits(), y.valid.mrr.to_bits(), "{tag}: valid MRR@{}", x.round);
        assert_eq!(x.test.mrr.to_bits(), y.test.mrr.to_bits(), "{tag}: test MRR@{}", x.round);
        assert_eq!(
            x.test.hits10.to_bits(),
            y.test.hits10.to_bits(),
            "{tag}: hits@10 @{}",
            x.round
        );
    }
}

#[derive(Default)]
struct EventLog(Vec<RunEvent>);

impl RunObserver for EventLog {
    fn on_event(&mut self, ev: &RunEvent) {
        self.0.push(ev.clone());
    }
}

/// One full cluster run over loopback: the coordinator on this thread,
/// every entry of `clients` as its own OS thread running the real
/// `run_client` protocol loop (`connect` is filled in from the bind).
fn cluster_run(spec: &ExperimentSpec, clients: Vec<ClientOpts>) -> (ClusterOutcome, Vec<RunEvent>) {
    let server = ClusterServer::bind("127.0.0.1:0", spec, ServeOpts::default()).expect("bind");
    let addr = server.addr().to_string();
    let handles: Vec<_> = clients
        .into_iter()
        .map(|mut o| {
            let spec = spec.clone();
            o.connect = addr.clone();
            thread::spawn(move || run_client(&spec, &o).expect("client run"))
        })
        .collect();
    let mut log = EventLog::default();
    let out = server.run(&mut [&mut log]).expect("server run");
    for h in handles {
        h.join().expect("client thread");
    }
    (out, log.0)
}

fn fleet(n: u16) -> Vec<ClientOpts> {
    (0..n).map(|id| ClientOpts::new("", id)).collect()
}

/// Every refusable handshake is refused with a reasoned reject frame.
#[test]
fn handshake_rejects_mismatched_registrations() {
    let spec = tiny_spec(AlgoSpec::FedEP, 6);
    let digest = spec_digest(&spec);
    let server = ClusterServer::bind("127.0.0.1:0", &spec, ServeOpts::default()).expect("bind");
    let addr = server.addr();

    let expect_reject = |first: ClusterMsg, needle: &str| {
        let sock = TcpStream::connect(addr).expect("connect");
        write_frame(&mut (&sock), &first.encode()).expect("send first frame");
        let frame = read_frame(&mut (&sock)).expect("read reply").expect("reply before close");
        match ClusterMsg::decode(&frame).expect("decode reply") {
            ClusterMsg::Reject { reason } => {
                assert!(reason.contains(needle), "reason {reason:?} lacks {needle:?}");
            }
            other => panic!("expected a reject, got {other:?}"),
        }
    };

    let hello = |version, client, spec_digest| ClusterMsg::Hello {
        version,
        client,
        spec_digest,
        join_round: 0,
    };
    expect_reject(hello(PROTO_VERSION + 1, 0, digest), "protocol version");
    expect_reject(hello(PROTO_VERSION, 0, digest ^ 1), "spec mismatch");
    expect_reject(hello(PROTO_VERSION, 9, digest), "out of range");
    let report = ClusterMsg::Report { round: 1, loss: 0.0, batches: 1, eval: None };
    expect_reject(report, "hello");
    // the acceptor stays up for real joins afterwards; the run is never
    // started here, so the server value just drops (acceptor detaches)
}

/// With no failures injected, a multi-process run is bit-identical to
/// the in-process engine — for a dense algorithm and for sparse FedS.
#[test]
fn cluster_run_matches_in_process_engine() {
    for algo in [AlgoSpec::FedEP, AlgoSpec::feds()] {
        let spec = tiny_spec(algo.clone(), 6);
        let direct = direct_run(&spec);
        let (out, events) = cluster_run(&spec, fleet(3));
        assert_equivalent(&format!("{algo:?}"), &direct, &out.run);
        assert_eq!(out.times.secs.len(), 6, "{algo:?}: one wall-clock sample per round");
        let joins = events
            .iter()
            .filter(|e| matches!(e, RunEvent::ClientJoined { rejoin: false, .. }))
            .count();
        assert_eq!(joins, 3, "{algo:?}: three fresh registrations");
        let failures = events.iter().any(|e| {
            matches!(e, RunEvent::ClientDropped { .. } | RunEvent::PartialRound { .. })
        });
        assert!(!failures, "{algo:?}: no failure events in a failure-free run");
    }
}

/// A client killed mid-frame is classified as an abrupt crash, cut from
/// the round, and the round aggregates whoever reported.
#[test]
fn crashed_client_is_cut_and_the_round_aggregates_partially() {
    let spec = tiny_spec(AlgoSpec::FedEP, 6);
    let mut clients = fleet(3);
    clients[1].fail_after = Some(2);
    let (out, events) = cluster_run(&spec, clients);

    let dropped = events.iter().any(|e| {
        matches!(e, RunEvent::ClientDropped { round: 3, client: 1, clean: false })
    });
    assert!(dropped, "client 1 must be cut abruptly at round 3: {events:?}");
    let partial = events.iter().any(|e| {
        matches!(e, RunEvent::PartialRound { round: 3, reported: 2, expected: 3 })
    });
    assert!(partial, "round 3 must aggregate partially: {events:?}");
    assert_eq!(out.run.history.records.len(), 3, "evaluations at rounds 2, 4, 6");
    assert_eq!(out.times.secs.len(), 6, "the run completes every round despite the crash");
}

/// The handover drill: client 2 leaves after round 3 — once cleanly,
/// once by dying mid-frame — and a replacement process for the same id
/// rejoins at round 6, resynced from the cached download.  The two
/// scenarios differ only in disconnect classification; every number in
/// the run is bit-identical.
#[test]
fn clean_leave_and_crash_handover_are_bit_identical_with_rejoin() {
    let spec = tiny_spec(AlgoSpec::feds(), 8);
    let scenario = |crash: bool| {
        let mut clients = fleet(3);
        if crash {
            clients[2].fail_after = Some(3);
        } else {
            clients[2].leave_after = Some(3);
        }
        let mut replacement = ClientOpts::new("", 2);
        replacement.join_round = 6;
        clients.push(replacement);
        cluster_run(&spec, clients)
    };
    let (clean, clean_ev) = scenario(false);
    let (crash, crash_ev) = scenario(true);

    assert_equivalent("clean vs crash handover", &clean.run, &crash.run);
    let clean_drop = clean_ev.iter().any(|e| {
        matches!(e, RunEvent::ClientDropped { client: 2, clean: true, .. })
    });
    assert!(clean_drop, "the leave must classify as clean: {clean_ev:?}");
    let crash_drop = crash_ev.iter().any(|e| {
        matches!(e, RunEvent::ClientDropped { client: 2, clean: false, .. })
    });
    assert!(crash_drop, "the crash must classify as abrupt: {crash_ev:?}");
    for events in [&clean_ev, &crash_ev] {
        let rejoined = events.iter().any(|e| {
            matches!(e, RunEvent::ClientJoined { round: 6, client: 2, rejoin: true })
        });
        assert!(rejoined, "the replacement must rejoin at round 6: {events:?}");
        let partial = events.iter().any(|e| {
            matches!(e, RunEvent::PartialRound { round: 4, reported: 2, expected: 3 })
        });
        assert!(partial, "round 4 must aggregate partially: {events:?}");
    }
    assert_eq!(clean.run.history.records.len(), 4, "evaluations at rounds 2, 4, 6, 8");
}
