//! Integration: the PJRT-executed artifacts must agree with the pure-Rust
//! oracle step-for-step.  This is the strongest end-to-end correctness
//! signal in the repo: it exercises the Pallas kernels (L1), the JAX graph
//! + AOT lowering (L2), the HLO-text interchange, the PJRT runtime, and the
//! native implementation, and requires them all to produce the same numbers.
//!
//! Requires `make artifacts`; tests self-skip when artifacts are missing.
//!
//! Optimizer-semantics caveat (PR 3): the native engine uses lazy row-wise
//! Adam, which matches the artifact's dense Adam exactly on rows a batch
//! gathers but skips the dense zero-grad drift on untouched rows.  Over
//! the short runs here, with batches sampling the full entity set, the
//! residual divergence stays well inside the tolerances; if a row goes
//! ungathered for several steps on a new artifact config, it drifts by
//! ~lr per skipped step on the XLA side only — revisit tolerances (or land
//! the ROADMAP sparse-aware XLA optimizer) before tightening this suite.

use std::path::Path;
use std::rc::Rc;

use feds::data::dataset::{BatchIter, EvalSet, FilterIndex};
use feds::data::generator::{generate, GeneratorConfig};
use feds::kge::Method;
use feds::runtime::Runtime;
use feds::trainer::{evaluate, LocalTrainer, NativeTrainer, XlaTrainer};
use feds::util::rng::Rng;

fn runtime() -> Option<Rc<Runtime>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime load"))
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn parity_for(method: Method) {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let kg = generate(&GeneratorConfig {
        num_entities: m.num_entities,
        num_relations: m.num_relations,
        num_triples: 4000,
        seed: 11,
        ..Default::default()
    });

    // identical init: both trainers consume the same rng stream
    let mut rng_x = Rng::new(1234);
    let mut rng_n = Rng::new(1234);
    let mut xla_t = XlaTrainer::new(rt.clone(), method, m.hyper.dim, &mut rng_x).unwrap();
    let mut nat_t = NativeTrainer::new(
        method,
        m.hyper.clone(),
        m.num_entities,
        m.num_relations,
        m.eval_batch,
        &mut rng_n,
    );

    // run 3 identical training steps
    let ents: Vec<u32> = (0..m.num_entities as u32).collect();
    let mut brng_x = Rng::new(777);
    let mut brng_n = Rng::new(777);
    let batches_x: Vec<_> = BatchIter::new(&kg.triples[..m.batch * 3], &ents, m.batch, m.negatives, &mut brng_x).collect();
    let batches_n: Vec<_> = BatchIter::new(&kg.triples[..m.batch * 3], &ents, m.batch, m.negatives, &mut brng_n).collect();

    for (bx, bn) in batches_x.iter().zip(&batches_n) {
        let lx = xla_t.train_batch(bx).unwrap();
        let ln = nat_t.train_batch(bn).unwrap();
        assert!(
            (lx - ln).abs() < 2e-3 * (1.0 + ln.abs()),
            "{method:?} loss diverged: xla {lx} vs native {ln}"
        );
    }

    // table parity after training
    let ids: Vec<u32> = (0..64).collect();
    let rx = xla_t.get_entity_rows(&ids).unwrap();
    let rn = nat_t.get_entity_rows(&ids).unwrap();
    let d = max_abs_diff(&rx, &rn);
    assert!(d < 5e-4, "{method:?} entity tables diverged: max abs diff {d}");

    // eval parity on a subset of test queries
    let filters = FilterIndex::build(kg.triples.iter());
    let es = EvalSet::new(&kg.triples[..m.eval_batch], m.num_entities);
    let mx = evaluate(&mut xla_t, &es, &filters).unwrap();
    let mn = evaluate(&mut nat_t, &es, &filters).unwrap();
    assert!(
        (mx.mrr - mn.mrr).abs() < 0.02 * (1.0 + mn.mrr),
        "{method:?} eval MRR diverged: xla {} vs native {}",
        mx.mrr,
        mn.mrr
    );
}

#[test]
fn parity_transe() {
    parity_for(Method::TransE);
}

#[test]
fn parity_rotate() {
    parity_for(Method::RotatE);
}

#[test]
fn parity_complex() {
    parity_for(Method::ComplEx);
}

#[test]
fn change_scores_parity() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let mut rng = Rng::new(5);
    let mut xla_t = XlaTrainer::new(rt.clone(), Method::TransE, m.hyper.dim, &mut rng).unwrap();

    // history = perturbed copy of the current table
    let ids: Vec<u32> = (0..m.num_entities as u32).collect();
    let cur = xla_t.get_entity_rows(&ids).unwrap();
    let we = xla_t.entity_width();
    let mut hist_data = cur.clone();
    let mut prng = Rng::new(6);
    for v in hist_data.iter_mut() {
        *v += prng.uniform(-0.01, 0.01);
    }
    let hist = feds::store::StoreTable::from_vec(m.num_entities, we, hist_data);

    let probe: Vec<u32> = (0..200).map(|i| i * 7 % m.num_entities as u32).collect();
    let got = xla_t.change_scores(&probe, &hist).unwrap();
    for (k, &id) in probe.iter().enumerate() {
        let want = feds::linalg::change_score(
            &cur[id as usize * we..(id as usize + 1) * we],
            hist.row(id as usize),
        );
        assert!(
            (got[k] - want).abs() < 1e-4,
            "change score mismatch at {id}: {} vs {want}",
            got[k]
        );
    }
}

#[test]
fn xla_set_rows_roundtrip_through_device() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let mut rng = Rng::new(9);
    let mut t = XlaTrainer::new(rt.clone(), Method::TransE, m.hyper.dim, &mut rng).unwrap();
    let we = t.entity_width();
    let ids = vec![10u32, 500, 2000];
    let rows: Vec<f32> = (0..ids.len() * we).map(|i| i as f32 * 0.01).collect();
    t.set_entity_rows(&ids, &rows).unwrap();

    // force a device round-trip via a training step, then read back: the
    // written rows must have gone through the artifact (values will have
    // moved by at most the Adam step size)
    let kg = generate(&GeneratorConfig {
        num_entities: m.num_entities,
        num_relations: m.num_relations,
        num_triples: 2000,
        seed: 2,
        ..Default::default()
    });
    let ents: Vec<u32> = (0..m.num_entities as u32).collect();
    let mut brng = Rng::new(1);
    let batch = BatchIter::new(&kg.triples, &ents, m.batch, m.negatives, &mut brng)
        .next()
        .unwrap();
    t.train_batch(&batch).unwrap();
    let back = t.get_entity_rows(&ids).unwrap();
    let lr = rt.manifest.hyper.learning_rate;
    for (a, b) in rows.iter().zip(&back) {
        assert!((a - b).abs() <= 2.0 * lr + 1e-6, "{a} vs {b}");
    }
}

#[test]
fn epoch_artifact_matches_single_steps() {
    // the scan-fused train_epoch artifact must be bit-compatible (to f32
    // tolerance) with the same batches through the single-step artifact,
    // including the padded-step passthrough.
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let kg = generate(&GeneratorConfig {
        num_entities: m.num_entities,
        num_relations: m.num_relations,
        num_triples: 3000,
        seed: 21,
        ..Default::default()
    });
    let ents: Vec<u32> = (0..m.num_entities as u32).collect();
    for method in [Method::TransE, Method::ComplEx] {
        let mut rng_a = Rng::new(99);
        let mut rng_b = Rng::new(99);
        let mut a = XlaTrainer::new(rt.clone(), method, m.hyper.dim, &mut rng_a).unwrap();
        let mut b = XlaTrainer::new(rt.clone(), method, m.hyper.dim, &mut rng_b).unwrap();

        let mut brng1 = Rng::new(5);
        let mut brng2 = Rng::new(5);
        // 5 batches: not a multiple of scan_steps → exercises padding
        let batches1: Vec<_> =
            BatchIter::new(&kg.triples[..m.batch * 5], &ents, m.batch, m.negatives, &mut brng1)
                .collect();
        let batches2: Vec<_> =
            BatchIter::new(&kg.triples[..m.batch * 5], &ents, m.batch, m.negatives, &mut brng2)
                .collect();

        let loss_fused = a.train_batches(&batches1).unwrap();
        let mut loss_single = 0.0;
        for batch in &batches2 {
            loss_single += b.train_batch(batch).unwrap();
        }
        loss_single /= batches2.len() as f32;
        assert!(
            (loss_fused - loss_single).abs() < 1e-4 * (1.0 + loss_single.abs()),
            "{method:?} loss: fused {loss_fused} vs single {loss_single}"
        );

        let ids: Vec<u32> = (0..256).collect();
        let ra = a.get_entity_rows(&ids).unwrap();
        let rb = b.get_entity_rows(&ids).unwrap();
        let d = max_abs_diff(&ra, &rb);
        assert!(d < 1e-5, "{method:?} tables diverged: {d}");
    }
}

#[test]
fn kd_trainer_trains_and_evaluates() {
    // FedE-KD path: dual-dimension co-distillation artifact — loss must be
    // finite and decreasing, transport rows live at the low width, and the
    // hi model answers eval queries.
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let mut rng = Rng::new(77);
    let mut t = feds::trainer::KdXlaTrainer::new(rt.clone(), Method::TransE, &mut rng).unwrap();
    assert_eq!(
        t.entity_width(),
        Method::TransE.entity_width(m.kd_dim),
        "transport width must be the KD low dim"
    );
    let kg = generate(&GeneratorConfig {
        num_entities: m.num_entities,
        num_relations: m.num_relations,
        num_triples: 4000,
        seed: 31,
        ..Default::default()
    });
    let ents: Vec<u32> = (0..m.num_entities as u32).collect();
    let mut brng = Rng::new(8);
    let batches: Vec<_> =
        BatchIter::new(&kg.triples, &ents, m.batch, m.negatives, &mut brng)
            .take(6)
            .collect();
    let l1 = t.train_batches(&batches[..3]).unwrap();
    let l2 = t.train_batches(&batches[3..]).unwrap();
    assert!(l1.is_finite() && l2.is_finite());

    // row roundtrip on the lo table
    let ids = vec![1u32, 99, 1500];
    let rows: Vec<f32> = (0..ids.len() * t.entity_width()).map(|i| i as f32 * 1e-3).collect();
    t.set_entity_rows(&ids, &rows).unwrap();
    assert_eq!(t.get_entity_rows(&ids).unwrap(), rows);

    // eval answers come from the hi model
    let filters = FilterIndex::build(kg.triples.iter());
    let es = EvalSet::new(&kg.triples[..32], m.num_entities);
    let metrics = evaluate(&mut t, &es, &filters).unwrap();
    assert!(metrics.mrr > 0.0 && metrics.mrr <= 1.0);
}

#[test]
fn fedepl_dim_artifacts_load() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let mut rng = Rng::new(3);
    for method in Method::ALL {
        let mut t = XlaTrainer::new(rt.clone(), method, m.fedepl_dim, &mut rng).unwrap();
        assert_eq!(t.entity_width(), method.entity_width(m.fedepl_dim));
        // one smoke step
        let ents: Vec<u32> = (0..m.num_entities as u32).collect();
        let kg = generate(&GeneratorConfig {
            num_entities: m.num_entities,
            num_relations: m.num_relations,
            num_triples: 1000,
            seed: 4,
            ..Default::default()
        });
        let mut brng = Rng::new(2);
        let batch = BatchIter::new(&kg.triples, &ents, m.batch, m.negatives, &mut brng)
            .next()
            .unwrap();
        let loss = t.train_batch(&batch).unwrap();
        assert!(loss.is_finite(), "{method:?}");
    }
}
