//! The pluggable-transport correctness bar: a run over real TCP loopback
//! sockets must yield **byte-identical** communication accounting and
//! **bit-identical** metric history to the same run over in-process mpsc
//! links — for every algorithm and both execution modes.  The transport
//! is infrastructure; nothing about the run may depend on it.

use feds::comm::accounting::Direction;
use feds::fed::{ExecMode, RunOutcome};
use feds::kge::Method;
use feds::spec::{
    AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, Session, TransportSpec,
};

fn tiny_spec(algo: AlgoSpec, exec: ExecMode, transport: TransportSpec) -> ExperimentSpec {
    ExperimentSpec {
        name: String::new(),
        method: Method::TransE,
        algo,
        data: DataSpec {
            entities: 192,
            relations: 12,
            triples: 2400,
            clusters: 4,
            clients: 3,
            seed: 11,
        },
        backend: BackendSpec::Native {
            dim: 16,
            learning_rate: 5e-3,
            batch: 64,
            negatives: 16,
            eval_batch: 32,
        },
        budget: BudgetSpec {
            max_rounds: 6,
            local_epochs: 1,
            eval_every: 2,
            patience: 3,
            eval_cap: 64,
        },
        seed: 7,
        exec,
        transport,
        // exercise sharded aggregation on both transports too
        shards: 4,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    }
}

fn assert_equivalent(tag: &str, mpsc: &RunOutcome, tcp: &RunOutcome) {
    for dir in [Direction::Upload, Direction::Download] {
        assert_eq!(mpsc.acct.params_dir(dir), tcp.acct.params_dir(dir), "{tag}: params {dir:?}");
        assert_eq!(mpsc.acct.bytes_dir(dir), tcp.acct.bytes_dir(dir), "{tag}: bytes {dir:?}");
    }
    assert_eq!(mpsc.acct.messages(), tcp.acct.messages(), "{tag}: messages");
    assert_eq!(mpsc.eq5_ratio, tcp.eq5_ratio, "{tag}: eq5");
    let (a, b) = (&mpsc.history.records, &tcp.history.records);
    assert_eq!(a.len(), b.len(), "{tag}: record count");
    assert_eq!(
        mpsc.history.converged_idx, tcp.history.converged_idx,
        "{tag}: convergence index"
    );
    assert_eq!(mpsc.history.label, tcp.history.label, "{tag}: label");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round, "{tag}");
        assert_eq!(x.params_cum, y.params_cum, "{tag}: params@{}", x.round);
        assert_eq!(x.bytes_cum, y.bytes_cum, "{tag}: bytes@{}", x.round);
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{tag}: loss@{}", x.round);
        assert_eq!(x.valid.mrr.to_bits(), y.valid.mrr.to_bits(), "{tag}: valid MRR@{}", x.round);
        assert_eq!(x.test.mrr.to_bits(), y.test.mrr.to_bits(), "{tag}: test MRR@{}", x.round);
        assert_eq!(
            x.test.hits10.to_bits(),
            y.test.hits10.to_bits(),
            "{tag}: hits@10 @{}",
            x.round
        );
    }
}

/// Every algorithm × both exec modes: TCP == mpsc, byte for byte.
#[test]
fn tcp_matches_mpsc_for_every_algo_and_exec_mode() {
    let algos = [
        AlgoSpec::Single,
        AlgoSpec::FedEP,
        AlgoSpec::FedEPL,
        AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: true },
        AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: false },
        AlgoSpec::Svd { cols: 8, plus: false },
        AlgoSpec::Svd { cols: 8, plus: true },
    ];
    let mut session = Session::new();
    for algo in algos {
        for exec in [ExecMode::Sequential, ExecMode::Threaded] {
            let run = |transport: TransportSpec| -> RunOutcome {
                let spec = tiny_spec(algo.clone(), exec, transport);
                let mut run = session.build(&spec).unwrap();
                run.quiet();
                run.execute().unwrap()
            };
            let mpsc = run(TransportSpec::Mpsc);
            let tcp = run(TransportSpec::Tcp);
            assert_equivalent(&format!("{algo:?}/{exec:?}"), &mpsc, &tcp);
        }
    }
}

/// The TCP path really is selected from the spec: a `"transport": "tcp"`
/// spec resolves to TCP run params, and a tcp run still produces a
/// non-trivial accounting stream (frames actually crossed sockets).
#[test]
fn transport_spec_field_reaches_the_engine() {
    let spec = tiny_spec(AlgoSpec::feds(), ExecMode::Sequential, TransportSpec::Tcp);
    let mut session = Session::new();
    let mut run = session.build(&spec).unwrap();
    assert_eq!(run.params().transport, TransportSpec::Tcp);
    assert_eq!(run.params().shards, 4);
    run.quiet();
    let out = run.execute().unwrap();
    assert!(out.acct.messages() > 0, "frames crossed the sockets");
    assert!(out.acct.bytes() > 0);
}
