//! The real multi-process drills: the `feds` binary serving client
//! *processes* over loopback.
//!
//! * One client dies mid-frame partway in: the server must cut the
//!   crashed process, finish the run on partial aggregation, and stream
//!   the membership history to the JSONL sink.
//! * The **coordinator** dies — a true SIGKILL, injected right after a
//!   round checkpoint — and a replacement process restores the snapshot
//!   on the same address.  The clients ride through the outage on
//!   reconnect backoff, the stitched event stream is contiguous, and the
//!   evaluated records and final accounting are bit-identical to an
//!   uninterrupted run.
//!
//! This is the process-isolation counterpart of `tests/cluster.rs`
//! (which runs the same protocol on threads); CI runs a chaos smoke of
//! the SIGKILL drill from the workflow as well.

use std::io::{BufRead, BufReader};
use std::process::{Command, Stdio};

use feds::kge::Method;
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec};

fn drill_spec() -> ExperimentSpec {
    ExperimentSpec {
        name: "cluster_process_drill".into(),
        method: Method::TransE,
        algo: AlgoSpec::FedEP,
        data: DataSpec {
            entities: 192,
            relations: 12,
            triples: 2400,
            clusters: 4,
            clients: 3,
            seed: 11,
        },
        backend: BackendSpec::Native {
            dim: 16,
            learning_rate: 5e-3,
            batch: 64,
            negatives: 16,
            eval_batch: 32,
        },
        budget: BudgetSpec {
            max_rounds: 6,
            local_epochs: 1,
            eval_every: 2,
            patience: 3,
            eval_cap: 64,
        },
        seed: 7,
        exec: Default::default(),
        transport: Default::default(),
        shards: 0,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    }
}

#[test]
fn three_processes_one_dying_mid_run_complete_via_partial_aggregation() {
    let dir = std::env::temp_dir().join("feds_cluster_process_test");
    std::fs::create_dir_all(&dir).unwrap();
    let spec_path = dir.join("spec.json");
    std::fs::write(&spec_path, drill_spec().to_json().to_string_pretty()).unwrap();
    let jsonl = dir.join("events.jsonl");
    let _ = std::fs::remove_file(&jsonl);

    let bin = env!("CARGO_BIN_EXE_feds");
    let mut server = Command::new(bin)
        .args(["serve", "--spec", spec_path.to_str().unwrap(), "--bind", "127.0.0.1:0"])
        .args(["--jsonl", jsonl.to_str().unwrap(), "--deadline-ms", "20000", "--quiet"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn server");
    let stdout = server.stdout.take().expect("server stdout piped");
    let mut lines = BufReader::new(stdout).lines();
    let first = lines.next().expect("server prints its address").expect("read listen line");
    let addr = first.strip_prefix("listening on ").expect("listen-line prefix").to_string();

    let client = |id: &str, extra: &[&str]| {
        let mut cmd = Command::new(bin);
        cmd.args(["client", "--spec", spec_path.to_str().unwrap()]);
        cmd.args(["--connect", &addr, "--id", id]);
        cmd.args(extra);
        cmd.stdout(Stdio::null()).spawn().expect("spawn client")
    };
    let mut c0 = client("0", &[]);
    let mut c1 = client("1", &[]);
    // dies mid-frame after completing round 2 — the server classifies an
    // abrupt crash and must finish the run without it
    let mut c2 = client("2", &["--fail-after", "2"]);

    assert!(c2.wait().expect("wait c2").success(), "the crashing client exits by design");
    assert!(c0.wait().expect("wait c0").success(), "client 0 runs to completion");
    assert!(c1.wait().expect("wait c1").success(), "client 1 runs to completion");
    // drain remaining output so the server never blocks on a full pipe
    for line in lines.by_ref() {
        let _ = line;
    }
    assert!(server.wait().expect("wait server").success(), "server completes the run");

    let text = std::fs::read_to_string(&jsonl).expect("events.jsonl written");
    let needles = [
        r#""event": "client_dropped""#,
        r#""event": "partial_round""#,
        r#""event": "run_end""#,
    ];
    for needle in needles {
        assert!(text.contains(needle), "{needle} missing from the event stream:\n{text}");
    }
}

/// The coordinator-crash drill: a true SIGKILL (fault-injected right
/// after the round-3 checkpoint lands), a replacement process restoring
/// the snapshot on the same address, and three client processes that
/// ride through the outage on reconnect backoff alone.
#[test]
fn sigkilled_coordinator_restores_on_the_same_address_and_completes() {
    let dir = std::env::temp_dir().join("feds_cluster_sigkill_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let spec_path = dir.join("spec.json");
    std::fs::write(&spec_path, drill_spec().to_json().to_string_pretty()).unwrap();
    let bin = env!("CARGO_BIN_EXE_feds");

    // spawn a coordinator and parse the address it announces
    let serve = |args: &[&str]| {
        let mut cmd = Command::new(bin);
        cmd.args(["serve", "--spec", spec_path.to_str().unwrap()]);
        cmd.args(args);
        cmd.args(["--deadline-ms", "20000", "--quiet"]);
        cmd.stdout(Stdio::piped());
        let mut child = cmd.spawn().expect("spawn server");
        let stdout = child.stdout.take().expect("server stdout piped");
        let mut lines = BufReader::new(stdout).lines();
        let first = lines.next().expect("server prints its address").expect("read listen line");
        let addr = first.strip_prefix("listening on ").expect("listen-line prefix").to_string();
        (child, lines, addr)
    };
    let client = |addr: &str, id: usize| {
        let mut cmd = Command::new(bin);
        cmd.args(["client", "--spec", spec_path.to_str().unwrap()]);
        cmd.args(["--connect", addr, "--id", &id.to_string()]);
        cmd.stdout(Stdio::null()).spawn().expect("spawn client")
    };

    // the reference: the same spec, never interrupted
    let ref_jsonl = dir.join("reference.jsonl");
    let (mut rserver, mut rlines, raddr) =
        serve(&["--bind", "127.0.0.1:0", "--jsonl", ref_jsonl.to_str().unwrap()]);
    let mut rclients: Vec<_> = (0..3).map(|id| client(&raddr, id)).collect();
    for c in &mut rclients {
        assert!(c.wait().expect("wait client").success(), "reference client completes");
    }
    for line in rlines.by_ref() {
        let _ = line;
    }
    assert!(rserver.wait().expect("wait server").success(), "reference run completes");

    // the crash run: checkpoint every round, SIGKILL right after round 3's
    let ckpt = dir.join("ckpt");
    let jsonl = dir.join("events.jsonl");
    let (mut server, mut lines, addr) = serve(&[
        "--bind",
        "127.0.0.1:0",
        "--jsonl",
        jsonl.to_str().unwrap(),
        "--checkpoint",
        ckpt.to_str().unwrap(),
        "--chaos-kill-at",
        "3",
    ]);
    let mut clients: Vec<_> = (0..3).map(|id| client(&addr, id)).collect();
    for line in lines.by_ref() {
        let _ = line; // drain until the SIGKILL severs the pipe
    }
    let status = server.wait().expect("wait killed server");
    assert!(!status.success(), "the coordinator must die by signal, not exit cleanly");

    // the replacement restores the snapshot on the address the clients
    // are re-dialing with backoff right now
    let (mut server2, mut lines2, addr2) = serve(&[
        "--bind",
        &addr,
        "--jsonl",
        jsonl.to_str().unwrap(),
        "--restore",
        ckpt.to_str().unwrap(),
    ]);
    assert_eq!(addr2, addr, "the replacement binds the clients' address");
    for c in &mut clients {
        assert!(c.wait().expect("wait client").success(), "clients ride through the outage");
    }
    for line in lines2.by_ref() {
        let _ = line;
    }
    assert!(server2.wait().expect("wait restored server").success(), "restored run completes");

    // contiguous stream: the first segment survives up to its checkpoint
    // (the sink flushes on checkpoint boundaries), the second finishes
    let text = std::fs::read_to_string(&jsonl).expect("events.jsonl written");
    let ckpt_line = text.lines().any(|l| {
        l.contains(r#""event": "checkpoint_written""#) && l.contains(r#""round": 3"#)
    });
    assert!(ckpt_line, "the round-3 checkpoint must be on record:\n{text}");
    let starts = text.matches(r#""event": "run_start""#).count();
    assert_eq!(starts, 2, "one run_start per coordinator process:\n{text}");
    let last = text.trim_end().lines().last().expect("stream is non-empty");
    assert!(last.contains(r#""event": "run_end""#), "the stream must end closed:\n{text}");

    // bit-identical where it counts: the restored run re-evaluates
    // nothing, and every evaluated record and the final accounting line
    // match the uninterrupted reference byte for byte
    let reference = std::fs::read_to_string(&ref_jsonl).expect("reference.jsonl written");
    let pick = |t: &str, needle: &str| -> Vec<String> {
        t.lines().filter(|l| l.contains(needle)).map(str::to_string).collect()
    };
    assert_eq!(
        pick(&text, r#""event": "evaluated""#),
        pick(&reference, r#""event": "evaluated""#),
        "evaluated records diverged across the crash/restore boundary"
    );
    assert_eq!(
        pick(&text, r#""event": "run_end""#),
        pick(&reference, r#""event": "run_end""#),
        "final params/bytes/messages accounting diverged"
    );
}
