//! The real multi-process drill: the `feds` binary serving three client
//! *processes* over loopback, one of which dies mid-frame partway in.
//! The server must cut the crashed process, finish the run on partial
//! aggregation, and stream the membership history to the JSONL sink.
//!
//! This is the process-isolation counterpart of `tests/cluster.rs`
//! (which runs the same protocol on threads); CI additionally runs a
//! SIGKILL variant of this drill from the workflow.

use std::io::{BufRead, BufReader};
use std::process::{Command, Stdio};

use feds::kge::Method;
use feds::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec};

fn drill_spec() -> ExperimentSpec {
    ExperimentSpec {
        name: "cluster_process_drill".into(),
        method: Method::TransE,
        algo: AlgoSpec::FedEP,
        data: DataSpec {
            entities: 192,
            relations: 12,
            triples: 2400,
            clusters: 4,
            clients: 3,
            seed: 11,
        },
        backend: BackendSpec::Native {
            dim: 16,
            learning_rate: 5e-3,
            batch: 64,
            negatives: 16,
            eval_batch: 32,
        },
        budget: BudgetSpec {
            max_rounds: 6,
            local_epochs: 1,
            eval_every: 2,
            patience: 3,
            eval_cap: 64,
        },
        seed: 7,
        exec: Default::default(),
        transport: Default::default(),
        shards: 0,
    }
}

#[test]
fn three_processes_one_dying_mid_run_complete_via_partial_aggregation() {
    let dir = std::env::temp_dir().join("feds_cluster_process_test");
    std::fs::create_dir_all(&dir).unwrap();
    let spec_path = dir.join("spec.json");
    std::fs::write(&spec_path, drill_spec().to_json().to_string_pretty()).unwrap();
    let jsonl = dir.join("events.jsonl");
    let _ = std::fs::remove_file(&jsonl);

    let bin = env!("CARGO_BIN_EXE_feds");
    let mut server = Command::new(bin)
        .args(["serve", "--spec", spec_path.to_str().unwrap(), "--bind", "127.0.0.1:0"])
        .args(["--jsonl", jsonl.to_str().unwrap(), "--deadline-ms", "20000", "--quiet"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn server");
    let stdout = server.stdout.take().expect("server stdout piped");
    let mut lines = BufReader::new(stdout).lines();
    let first = lines.next().expect("server prints its address").expect("read listen line");
    let addr = first.strip_prefix("listening on ").expect("listen-line prefix").to_string();

    let client = |id: &str, extra: &[&str]| {
        let mut cmd = Command::new(bin);
        cmd.args(["client", "--spec", spec_path.to_str().unwrap()]);
        cmd.args(["--connect", &addr, "--id", id]);
        cmd.args(extra);
        cmd.stdout(Stdio::null()).spawn().expect("spawn client")
    };
    let mut c0 = client("0", &[]);
    let mut c1 = client("1", &[]);
    // dies mid-frame after completing round 2 — the server classifies an
    // abrupt crash and must finish the run without it
    let mut c2 = client("2", &["--fail-after", "2"]);

    assert!(c2.wait().expect("wait c2").success(), "the crashing client exits by design");
    assert!(c0.wait().expect("wait c0").success(), "client 0 runs to completion");
    assert!(c1.wait().expect("wait c1").success(), "client 1 runs to completion");
    // drain remaining output so the server never blocks on a full pipe
    for line in lines.by_ref() {
        let _ = line;
    }
    assert!(server.wait().expect("wait server").success(), "server completes the run");

    let text = std::fs::read_to_string(&jsonl).expect("events.jsonl written");
    let needles = [
        r#""event": "client_dropped""#,
        r#""event": "partial_round""#,
        r#""event": "run_end""#,
    ];
    for needle in needles {
        assert!(text.contains(needle), "{needle} missing from the event stream:\n{text}");
    }
}
