//! End-to-end federated runs on the native backend (artifact-free):
//! protocol correctness, communication accounting, and the paper's headline
//! qualitative claims at miniature scale.

use feds::comm::accounting::Direction;
use feds::comm::transport::TransportSpec;
use feds::data::generator::{generate, GeneratorConfig};
use feds::data::partition::partition;
use feds::fed::protocol::{Download, Upload};
use feds::fed::{comm_ratio, run_params, Algo, Backend, ExecMode, RoundParams, RunOutcome};
use feds::kge::{Hyper, Method};

fn tiny_data(clients: usize, seed: u64) -> feds::data::partition::FedDataset {
    let kg = generate(&GeneratorConfig {
        num_entities: 192,
        num_relations: 12,
        num_triples: 2400,
        num_clusters: 4,
        seed,
        ..Default::default()
    });
    partition(&kg, clients, seed)
}

fn native_backend(dim: usize) -> Backend {
    Backend::Native {
        hyper: Hyper { dim, learning_rate: 5e-3, ..Default::default() },
        batch: 64,
        negatives: 16,
        eval_batch: 32,
    }
}

fn base_cfg(algo: Algo, rounds: usize) -> RoundParams {
    RoundParams {
        algo,
        method: Method::TransE,
        max_rounds: rounds,
        local_epochs: 1,
        eval_every: 2,
        patience: 3,
        sparsity: 0.4,
        sync_interval: 4,
        eval_cap: 64,
        seed: 7,
        svd_cols: 8,
        exec: ExecMode::Sequential,
        transport: TransportSpec::Mpsc,
        shards: 1,
        participation: Default::default(),
        storage: Default::default(),
        compression: Default::default(),
    }
}

fn run(
    data: &feds::data::partition::FedDataset,
    cfg: &RoundParams,
    backend: &Backend,
) -> anyhow::Result<RunOutcome> {
    run_params(data, cfg, backend, &mut [])
}

#[test]
fn fedep_learns_and_meters() {
    let data = tiny_data(3, 1);
    let mut cfg = base_cfg(Algo::FedEP, 24);
    cfg.eval_every = 4;
    let out = run(&data, &cfg, &native_backend(16)).unwrap();
    let h = &out.history;
    assert!(!h.records.is_empty());
    // learning happened: clearly above the ~0.028 chance MRR of 192 entities
    assert!(h.mrr_cg() > 0.05, "MRR {}", h.mrr_cg());
    // dense accounting: every comm round moves 2 × Σ_c N_c × W params
    let total_shared: usize = (0..3)
        .map(|c| data.shared_entities_of(c as u16).len())
        .sum();
    let per_round = 2 * total_shared * 16;
    let comm_rounds = h.records.last().unwrap().round - 1; // comm happens after eval
    let expect_lo = (comm_rounds.saturating_sub(1)) as u64 * per_round as u64;
    let got = h.records.last().unwrap().params_cum;
    assert!(
        got >= expect_lo && got <= (comm_rounds as u64 + 1) * per_round as u64,
        "params {got}, per round {per_round}, rounds {comm_rounds}"
    );
}

#[test]
fn feds_transmits_fewer_params_than_fedep() {
    let data = tiny_data(4, 2);
    let fedep = run(&data, &base_cfg(Algo::FedEP, 6), &native_backend(16)).unwrap();
    let feds = run(
        &data,
        &base_cfg(Algo::FedS { sync: true }, 6),
        &native_backend(16),
    )
    .unwrap();
    let p_ep = fedep.history.records.last().unwrap().params_cum;
    let p_s = feds.history.records.last().unwrap().params_cum;
    assert!(p_s < p_ep, "FedS {p_s} vs FedEP {p_ep}");
    // and the measured ratio must not exceed the analytic worst case (Eq. 5)
    // by more than sign-vector rounding slack
    let ratio = p_s as f64 / p_ep as f64;
    let eq5 = feds.eq5_ratio.unwrap();
    assert!(
        ratio <= eq5 * 1.10 + 0.02,
        "measured {ratio:.4} vs Eq.5 worst case {eq5:.4}"
    );
}

#[test]
fn feds_nosync_transmits_even_fewer() {
    let data = tiny_data(3, 3);
    let with = run(
        &data,
        &base_cfg(Algo::FedS { sync: true }, 6),
        &native_backend(16),
    )
    .unwrap();
    let without = run(
        &data,
        &base_cfg(Algo::FedS { sync: false }, 6),
        &native_backend(16),
    )
    .unwrap();
    assert!(
        without.history.records.last().unwrap().params_cum
            < with.history.records.last().unwrap().params_cum
    );
}

#[test]
fn single_never_communicates() {
    let data = tiny_data(3, 4);
    let out = run(&data, &base_cfg(Algo::Single, 4), &native_backend(16)).unwrap();
    assert_eq!(out.acct.params(), 0);
    assert_eq!(out.acct.bytes(), 0);
}

#[test]
fn fedepl_runs_at_reduced_dim() {
    let data = tiny_data(3, 5);
    let out = run(&data, &base_cfg(Algo::FedEPL, 4), &native_backend(16)).unwrap();
    assert!(out.history.mrr_cg() > 0.0);
    // reduced dim → dense rounds cheaper than FedEP's
    let fedep = run(&data, &base_cfg(Algo::FedEP, 4), &native_backend(16)).unwrap();
    assert!(
        out.acct.params() < fedep.acct.params(),
        "FedEPL {} vs FedEP {}",
        out.acct.params(),
        fedep.acct.params()
    );
}

#[test]
fn svd_baselines_compress_per_round_but_run() {
    let data = tiny_data(3, 6);
    for constrained in [false, true] {
        let out = run(
            &data,
            &base_cfg(Algo::FedSvd { constrained }, 4),
            &native_backend(16),
        )
        .unwrap();
        let fedep =
            run(&data, &base_cfg(Algo::FedEP, 4), &native_backend(16)).unwrap();
        assert!(out.history.mrr_cg().is_finite());
        assert!(
            out.acct.params() < fedep.acct.params(),
            "constrained={constrained}: svd {} vs dense {}",
            out.acct.params(),
            fedep.acct.params()
        );
    }
}

#[test]
fn deterministic_given_seed() {
    let data = tiny_data(3, 7);
    let cfg = base_cfg(Algo::FedS { sync: true }, 4);
    let a = run(&data, &cfg, &native_backend(16)).unwrap();
    let b = run(&data, &cfg, &native_backend(16)).unwrap();
    assert_eq!(a.acct.params(), b.acct.params());
    let (ra, rb) = (&a.history.records, &b.history.records);
    assert_eq!(ra.len(), rb.len());
    for (x, y) in ra.iter().zip(rb.iter()) {
        assert_eq!(x.test.mrr, y.test.mrr);
    }
}

#[test]
fn federation_beats_single_on_shared_structure() {
    // the reason FKGE exists: shared entities benefit from other clients'
    // training signal. At miniature scale we only require a consistent win.
    let data = tiny_data(3, 8);
    let mut cfg = base_cfg(Algo::FedEP, 60);
    cfg.eval_every = 5;
    cfg.patience = 5;
    let fed = run(&data, &cfg, &native_backend(16)).unwrap();
    cfg.algo = Algo::Single;
    let single = run(&data, &cfg, &native_backend(16)).unwrap();
    assert!(
        fed.history.mrr_cg() > 0.9 * single.history.mrr_cg(),
        "FedEP {:.4} vs Single {:.4}",
        fed.history.mrr_cg(),
        single.history.mrr_cg()
    );
}

#[test]
fn eq5_ratio_reported_for_feds_only() {
    let data = tiny_data(3, 9);
    let feds = run(
        &data,
        &base_cfg(Algo::FedS { sync: true }, 2),
        &native_backend(16),
    )
    .unwrap();
    assert!(feds.eq5_ratio.is_some());
    assert!((feds.eq5_ratio.unwrap() - comm_ratio(0.4, 4, 16)).abs() < 1e-9);
    let fedep = run(&data, &base_cfg(Algo::FedEP, 2), &native_backend(16)).unwrap();
    assert!(fedep.eq5_ratio.is_none());
}

// --- refactor seams: exchange strategies over real transport -------------

/// Every algorithm must produce byte-identical accounting and bit-identical
/// metrics whether clients run inline or on their own OS threads.
#[test]
fn threaded_matches_sequential_bitwise() {
    let data = tiny_data(4, 11);
    for algo in [
        Algo::FedEP,
        Algo::FedEPL,
        Algo::FedS { sync: true },
        Algo::FedS { sync: false },
        Algo::FedSvd { constrained: false },
        Algo::FedSvd { constrained: true },
    ] {
        let mut cfg = base_cfg(algo, 8);
        let seq = run(&data, &cfg, &native_backend(16)).unwrap();
        cfg.exec = ExecMode::Threaded;
        let thr = run(&data, &cfg, &native_backend(16)).unwrap();
        for dir in [Direction::Upload, Direction::Download] {
            assert_eq!(
                seq.acct.params_dir(dir),
                thr.acct.params_dir(dir),
                "{algo:?} params {dir:?}"
            );
            assert_eq!(
                seq.acct.bytes_dir(dir),
                thr.acct.bytes_dir(dir),
                "{algo:?} bytes {dir:?}"
            );
        }
        let (a, b) = (&seq.history.records, &thr.history.records);
        assert_eq!(a.len(), b.len(), "{algo:?} record count");
        assert_eq!(seq.history.converged_idx, thr.history.converged_idx, "{algo:?}");
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.round, y.round, "{algo:?}");
            assert_eq!(x.params_cum, y.params_cum, "{algo:?}");
            assert_eq!(x.bytes_cum, y.bytes_cum, "{algo:?}");
            assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{algo:?} loss");
            assert_eq!(x.valid.mrr.to_bits(), y.valid.mrr.to_bits(), "{algo:?} valid MRR");
            assert_eq!(x.test.mrr.to_bits(), y.test.mrr.to_bits(), "{algo:?} test MRR");
            assert_eq!(x.test.hits10.to_bits(), y.test.hits10.to_bits(), "{algo:?} hits@10");
        }
    }
}

/// The dense exchange's accounting must equal a message-level replay: the
/// strategies meter exactly what the protocol frames encode, nothing more.
#[test]
fn dense_accounting_matches_message_frames_exactly() {
    let data = tiny_data(3, 12);
    let mut cfg = base_cfg(Algo::FedEP, 3);
    cfg.eval_every = 100; // no evals → no early stop → exactly 3 comm rounds
    let width = 16usize;
    let out = run(&data, &cfg, &native_backend(width)).unwrap();
    let mut params = 0u64;
    let mut bytes = 0u64;
    for round in 1..=3u32 {
        for c in 0..3u16 {
            let n = data.shared_entities_of(c).len();
            if n == 0 {
                continue;
            }
            let up = Upload::Full { round, client: c, emb: vec![0.0; n * width] };
            params += up.params();
            bytes += up.encode().len() as u64;
            let down = Download::Full { round, emb: vec![0.0; n * width] };
            params += down.params();
            bytes += down.encode().len() as u64;
        }
    }
    assert_eq!(out.acct.params(), params);
    assert_eq!(out.acct.bytes(), bytes);
    assert_eq!(out.acct.params_dir(Direction::Upload), params / 2);
}

#[test]
fn single_threaded_mode_never_communicates() {
    let data = tiny_data(3, 13);
    let mut cfg = base_cfg(Algo::Single, 4);
    cfg.exec = ExecMode::Threaded;
    let out = run(&data, &cfg, &native_backend(16)).unwrap();
    assert_eq!(out.acct.params(), 0);
    assert_eq!(out.acct.bytes(), 0);
    assert!(out.history.mrr_cg() > 0.0);
}
