//! Property tests for the declarative experiment API: `ExperimentSpec` →
//! JSON → `ExperimentSpec` round-trips exactly for every `AlgoSpec`
//! variant, and out-of-range knobs are rejected at validation.

use feds::fed::compression::PipelineSpec;
use feds::fed::ExecMode;
use feds::kge::Method;
use feds::spec::{
    AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, ParticipationSpec, TransportSpec,
};
use feds::store::StorageSpec;
use feds::util::json::Json;
use feds::util::prop;
use feds::util::rng::Rng;

fn random_algo(rng: &mut Rng) -> AlgoSpec {
    match rng.usize_below(8) {
        0 => AlgoSpec::Single,
        1 => AlgoSpec::FedEP,
        2 => AlgoSpec::FedEPL,
        3 => AlgoSpec::Kd,
        4 => AlgoSpec::Svd { cols: 1 + rng.usize_below(16), plus: false },
        5 => AlgoSpec::Svd { cols: 1 + rng.usize_below(16), plus: true },
        6 => AlgoSpec::FedS {
            // (0, 1]: from 0.001 up to exactly 1.0
            sparsity: (1 + rng.usize_below(1000)) as f64 / 1000.0,
            sync_interval: 1 + rng.usize_below(12),
            sync: true,
        },
        _ => AlgoSpec::FedS {
            sparsity: rng.f64().max(1e-6),
            sync_interval: 1 + rng.usize_below(12),
            sync: false,
        },
    }
}

fn random_spec(rng: &mut Rng) -> ExperimentSpec {
    let clusters = 2 + rng.usize_below(6);
    let clients = 2 + rng.usize_below(8);
    let algo = random_algo(rng);
    let backend = if algo == AlgoSpec::Kd || rng.bool(0.3) {
        BackendSpec::Xla
    } else {
        BackendSpec::Native {
            dim: 1 + rng.usize_below(64),
            learning_rate: rng.uniform(1e-4, 1e-1),
            batch: 1 + rng.usize_below(256),
            negatives: 1 + rng.usize_below(64),
            eval_batch: 1 + rng.usize_below(128),
        }
    };
    // a compression stack is only legal on the dense family
    let compression = match &algo {
        AlgoSpec::FedEP | AlgoSpec::FedEPL | AlgoSpec::Kd => {
            let stacks = [
                "",
                "topk",
                "topk@0.25",
                "topk:ef",
                "int8",
                "fp16:ef",
                "svd@4",
                "topk,int8:ef",
                "topk@0.5,fp16",
                "topk,svd@8:ef",
            ];
            PipelineSpec::parse(stacks[rng.usize_below(stacks.len())]).unwrap()
        }
        _ => PipelineSpec::default(),
    };
    ExperimentSpec {
        name: if rng.bool(0.5) { format!("spec-{}", rng.below(1000)) } else { String::new() },
        method: *rng.choose(&Method::ALL),
        algo,
        data: DataSpec {
            entities: clusters * 4 + rng.usize_below(2048),
            relations: clients + rng.usize_below(32),
            triples: 1 + rng.usize_below(50_000),
            clusters,
            clients,
            seed: rng.next_u64() >> 12,
        },
        backend,
        budget: {
            let max_rounds = 1 + rng.usize_below(300);
            BudgetSpec {
                max_rounds,
                local_epochs: 1 + rng.usize_below(5),
                // at least one evaluation must fit the budget
                eval_every: 1 + rng.usize_below(max_rounds.min(10)),
                patience: 1 + rng.usize_below(5),
                eval_cap: rng.usize_below(1000),
            }
        },
        seed: rng.next_u64() >> 12,
        exec: if rng.bool(0.5) { ExecMode::Sequential } else { ExecMode::Threaded },
        transport: if rng.bool(0.5) { TransportSpec::Mpsc } else { TransportSpec::Tcp },
        shards: rng.usize_below(17),
        participation: match rng.usize_below(3) {
            0 => ParticipationSpec::Full,
            1 => ParticipationSpec::Fraction(rng.uniform(1e-3, 1.0) as f64),
            _ => ParticipationSpec::KofN(1 + rng.usize_below(clients)),
        },
        storage: match rng.usize_below(3) {
            0 => StorageSpec::Ram,
            1 => StorageSpec::Mmap { dir: None },
            _ => StorageSpec::Mmap { dir: Some(format!("/tmp/feds-{}", rng.below(100))) },
        },
        compression,
    }
}

#[test]
fn spec_round_trips_exactly_for_all_variants() {
    prop::check("spec_json_round_trip", 200, |rng| {
        let spec = random_spec(rng);
        spec.validate().expect("random specs are in-range by construction");
        let pretty = spec.to_json().to_string_pretty();
        let rt = ExperimentSpec::parse(&pretty).expect("round-trip parse");
        assert_eq!(spec, rt, "pretty round-trip changed the spec:\n{pretty}");
        let compact = spec.to_json().to_string();
        let rt2 = ExperimentSpec::parse(&compact).expect("compact parse");
        assert_eq!(spec, rt2, "compact round-trip changed the spec:\n{compact}");
    });
}

#[test]
fn every_algo_variant_round_trips() {
    let variants = [
        AlgoSpec::Single,
        AlgoSpec::FedEP,
        AlgoSpec::FedEPL,
        AlgoSpec::Kd,
        AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: true },
        AlgoSpec::FedS { sparsity: 1.0, sync_interval: 1, sync: false },
        AlgoSpec::Svd { cols: 8, plus: false },
        AlgoSpec::Svd { cols: 3, plus: true },
    ];
    for v in variants {
        let j = v.to_json();
        let rt = AlgoSpec::from_json(&j).unwrap();
        assert_eq!(v, rt, "{j}");
    }
}

#[test]
fn out_of_range_sparsity_rejected() {
    for bad in ["0", "0.0", "-0.4", "1.5", "2"] {
        let text = format!(r#"{{"kind": "feds", "sparsity": {bad}}}"#);
        let j = Json::parse(&text).unwrap();
        assert!(
            AlgoSpec::from_json(&j).is_err(),
            "sparsity {bad} must be rejected (sparsity ∉ (0,1])"
        );
    }
    // the boundary p = 1.0 is legal (dense selection)
    let j = Json::parse(r#"{"kind": "feds", "sparsity": 1.0}"#).unwrap();
    assert!(AlgoSpec::from_json(&j).is_ok());
}

#[test]
fn zero_sync_interval_rejected() {
    let j = Json::parse(r#"{"kind": "feds", "sync_interval": 0}"#).unwrap();
    assert!(AlgoSpec::from_json(&j).is_err());
}

#[test]
fn zero_svd_cols_rejected() {
    let j = Json::parse(r#"{"kind": "svd", "cols": 0}"#).unwrap();
    assert!(AlgoSpec::from_json(&j).is_err());
}

#[test]
fn misplaced_knobs_rejected() {
    // a FedS knob on a dense baseline is a hard error, not ignored
    let j = Json::parse(r#"{"kind": "fedepl", "sparsity": 0.4}"#).unwrap();
    assert!(AlgoSpec::from_json(&j).is_err());
    let j = Json::parse(r#"{"kind": "feds", "cols": 8}"#).unwrap();
    assert!(AlgoSpec::from_json(&j).is_err());
}

#[test]
fn invalid_budget_and_data_rejected() {
    let base = Json::parse(
        r#"{
          "method": "transe",
          "algo": "feds",
          "data": {"entities": 192, "relations": 12, "triples": 2400,
                   "clusters": 4, "clients": 3, "seed": 7},
          "backend": "native",
          "budget": {"max_rounds": 10},
          "seed": 7
        }"#,
    )
    .unwrap();
    // the base parses fine
    let spec = ExperimentSpec::from_json(&base).unwrap();
    assert_eq!(spec.budget.max_rounds, 10);
    assert_eq!(spec.budget.local_epochs, 3, "budget defaults fill in");

    let mut bad = spec.clone();
    bad.budget.max_rounds = 0;
    assert!(bad.validate().is_err());
    let mut bad = spec.clone();
    bad.budget.eval_every = 0;
    assert!(bad.validate().is_err());
    let mut bad = spec.clone();
    bad.budget.eval_every = bad.budget.max_rounds + 1;
    assert!(
        bad.validate().is_err(),
        "a budget that never evaluates must be rejected, not panic downstream"
    );
    let mut bad = spec.clone();
    bad.data.clients = 1;
    assert!(bad.validate().is_err());
    let mut bad = spec;
    bad.data.relations = 2;
    assert!(bad.validate().is_err(), "fewer relations than clients must be rejected");
}
