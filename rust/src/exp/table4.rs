//! Table IV — FedS vs FedEPL (the "just lower the dimension" strawman).
//!
//! FedEPL reduces the base model's embedding dimension so a dense exchange
//! costs the same per cycle as FedS (Appendix VI-C).  The paper's shape:
//! FedS reaches higher MRR in fewer rounds; FedEPL often cannot reach
//! 98%/99% of FedEP's converged accuracy at all.

use anyhow::Result;

use crate::fed::Algo;
use crate::kge::Method;
use crate::util::json::Json;

use super::report::{fmt4, MdTable, Report};
use super::Ctx;

pub fn run(ctx: &Ctx) -> Result<Report> {
    let datasets = ctx.datasets(&[10, 5, 3]);
    let mut t = MdTable::new(&[
        "KGE", "Dataset", "Setting", "MRR", "R@CG", "params@CG", "reaches 98% of FedEP?",
    ]);
    let mut raw = Vec::new();

    for method in Method::ALL {
        for (dname, data) in &datasets {
            let fedep = ctx.run(data, &ctx.run_cfg(Algo::FedEP, method))?;
            let target98 = 0.98 * fedep.history.mrr_cg();
            for (label, algo) in [
                ("FedEPL", Algo::FedEPL),
                ("FedS", Algo::FedS { sync: true }),
            ] {
                let out = ctx.run(data, &ctx.run_cfg(algo, method))?;
                let reaches = out.history.params_at_mrr(target98).is_some();
                t.row(vec![
                    method.name().into(),
                    dname.clone(),
                    label.into(),
                    fmt4(out.history.mrr_cg()),
                    out.history.rounds_cg().to_string(),
                    out.history.params_cg().to_string(),
                    if reaches { "yes".into() } else { "NO".into() },
                ]);
                raw.push(
                    Json::obj()
                        .set("method", method.name())
                        .set("dataset", dname.as_str())
                        .set("setting", label)
                        .set("mrr", out.history.mrr_cg())
                        .set("rounds_cg", out.history.rounds_cg())
                        .set("params_cg", out.history.params_cg())
                        .set("reaches_98", reaches),
                );
            }
        }
    }

    let mut rep = Report::new("table4", "Table IV — FedS vs FedEPL at equal per-cycle budget");
    rep.note("Paper shape to verify: FedS beats FedEPL on MRR (FedEPL frequently never reaches 98% of FedEP's MRR@CG).");
    rep.table("Table IV", t);
    rep.raw = Json::obj().set("rows", Json::Arr(raw));
    Ok(rep)
}
