//! Table IV — FedS vs FedEPL (the "just lower the dimension" strawman).
//!
//! FedEPL reduces the base model's embedding dimension so a dense exchange
//! costs the same per cycle as FedS (Appendix VI-C).  The paper's shape:
//! FedS reaches higher MRR in fewer rounds; FedEPL often cannot reach
//! 98%/99% of FedEP's converged accuracy at all.
//!
//! Declared as a sweep grid (method × clients × algorithm) and executed by
//! the generic runner; this function only shapes the report.

use anyhow::Result;

use crate::kge::Method;
use crate::util::json::Json;

use super::report::{fmt4, MdTable, Report};
use super::Ctx;

const CLIENTS: [usize; 3] = [10, 5, 3];

pub fn run(ctx: &Ctx) -> Result<Report> {
    let sweep = ctx
        .sweep("table4")
        .axis(
            "method",
            Method::ALL.iter().map(|m| Json::from(m.name())).collect(),
        )
        .axis("data.clients", CLIENTS.iter().map(|&n| Json::from(n)).collect())
        .axis(
            "algo",
            vec![Json::from("fedep"), Json::from("fedepl"), Json::from("feds")],
        );
    let grid = ctx.run_sweep(&sweep)?;

    let mut t = MdTable::new(&[
        "KGE", "Dataset", "Setting", "MRR", "R@CG", "params@CG", "reaches 98% of FedEP?",
    ]);
    let mut raw = Vec::new();

    for (im, method) in Method::ALL.iter().enumerate() {
        for (id, &n) in CLIENTS.iter().enumerate() {
            let dname = format!("R{n}");
            let fedep = &grid.at(&[im, id, 0]).outcome;
            let target98 = 0.98 * fedep.history.mrr_cg();
            for (ia, label) in [(1usize, "FedEPL"), (2, "FedS")] {
                let out = &grid.at(&[im, id, ia]).outcome;
                let reaches = out.history.params_at_mrr(target98).is_some();
                t.row(vec![
                    method.name().into(),
                    dname.clone(),
                    label.into(),
                    fmt4(out.history.mrr_cg()),
                    out.history.rounds_cg().to_string(),
                    out.history.params_cg().to_string(),
                    if reaches { "yes".into() } else { "NO".into() },
                ]);
                raw.push(
                    Json::obj()
                        .set("method", method.name())
                        .set("dataset", dname.as_str())
                        .set("setting", label)
                        .set("mrr", out.history.mrr_cg())
                        .set("rounds_cg", out.history.rounds_cg())
                        .set("params_cg", out.history.params_cg())
                        .set("reaches_98", reaches),
                );
            }
        }
    }

    let mut rep = Report::new("table4", "Table IV — FedS vs FedEPL at equal per-cycle budget");
    rep.note("Paper shape to verify: FedS beats FedEPL on MRR (FedEPL frequently never reaches 98% of FedEP's MRR@CG).");
    rep.table("Table IV", t);
    rep.raw = Json::obj().set("rows", Json::Arr(raw));
    Ok(rep)
}
