//! Experiment harness: one driver per table/figure in the paper's
//! evaluation (§IV + Appendix), regenerating the same rows/series on the
//! scaled testbed (DESIGN.md §4 maps each to modules and CLI commands).
//!
//! All drivers share a single synthetic FB15k-237-like KG (seeded) split
//! into R10/R5/R3 analogues, and print + save their report under
//! `reports/`.

pub mod fig2;
pub mod report;
pub mod sweep;
pub mod table1;
pub mod table23;
pub mod table4;
pub mod table5;
pub mod table6;

use std::rc::Rc;

use anyhow::Result;

use crate::data::generator::{generate, GeneratorConfig};
use crate::data::partition::{partition, FedDataset};
use crate::fed::{Backend, ExecMode};
use crate::kge::{Hyper, Method};
use crate::runtime::Runtime;
use crate::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec, ExperimentSpec, Session};

use self::sweep::{SweepGrid, SweepSpec};

/// Shared experiment context.
pub struct Ctx {
    pub backend: Backend,
    /// fast mode: fewer rounds / smaller eval cap (CI smoke)
    pub fast: bool,
    pub seed: u64,
    pub max_rounds: usize,
    pub eval_cap: usize,
    /// client execution mode (threaded applies to native-backend runs)
    pub exec: ExecMode,
}

impl Ctx {
    pub fn new(backend: Backend, fast: bool, seed: u64) -> Self {
        // budgets sized for the single-core CPU testbed; see EXPERIMENTS.md
        let (max_rounds, eval_cap) = if fast { (24, 128) } else { (50, 256) };
        Self { backend, fast, seed, max_rounds, eval_cap, exec: ExecMode::Sequential }
    }

    pub fn with_exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    /// Build from CLI-ish options: `backend` ∈ {"xla", "native"}.
    pub fn from_options(backend: &str, fast: bool, seed: u64) -> Result<Self> {
        let backend = match backend {
            "xla" => Backend::Xla(xla_runtime()?),
            "native" => native_backend(),
            other => anyhow::bail!("unknown backend '{other}' (xla|native)"),
        };
        Ok(Self::new(backend, fast, seed))
    }

    /// The generator config matching the backend's artifact shapes.
    pub fn gen_config(&self) -> GeneratorConfig {
        match &self.backend {
            Backend::Xla(rt) => GeneratorConfig {
                num_entities: rt.manifest.num_entities,
                num_relations: rt.manifest.num_relations,
                num_triples: rt.manifest.num_entities * 15,
                num_clusters: 8,
                seed: self.seed,
                ..Default::default()
            },
            Backend::Native { .. } => GeneratorConfig {
                num_entities: 512,
                num_relations: 24,
                num_triples: 8_000,
                num_clusters: 8,
                seed: self.seed,
                ..Default::default()
            },
        }
    }

    /// The paper's three datasets: relation-partitioned into 10/5/3 clients.
    pub fn datasets(&self, client_counts: &[usize]) -> Vec<(String, FedDataset)> {
        let kg = generate(&self.gen_config());
        client_counts
            .iter()
            .map(|&n| (format!("R{n}"), partition(&kg, n, self.seed)))
            .collect()
    }

    /// The serializable description of this context's backend.
    pub fn backend_spec(&self) -> BackendSpec {
        BackendSpec::of(&self.backend)
    }

    /// The base [`ExperimentSpec`] every table sweep derives from: this
    /// context's data shape, backend and budget with the paper-default
    /// algorithm knobs (§IV-B, scaled).
    pub fn base_spec(&self) -> ExperimentSpec {
        let gen = self.gen_config();
        ExperimentSpec {
            name: String::new(),
            method: Method::TransE,
            algo: AlgoSpec::FedEP,
            data: DataSpec {
                entities: gen.num_entities,
                relations: gen.num_relations,
                triples: gen.num_triples,
                clusters: gen.num_clusters,
                clients: 3,
                seed: self.seed,
            },
            backend: self.backend_spec(),
            budget: BudgetSpec {
                max_rounds: self.max_rounds,
                local_epochs: 3,
                eval_every: if self.fast { 3 } else { 5 },
                patience: 3,
                eval_cap: self.eval_cap,
            },
            seed: self.seed ^ 0xA11CE,
            exec: self.exec,
            transport: crate::comm::transport::TransportSpec::Mpsc,
            shards: 0,
            participation: Default::default(),
            storage: Default::default(),
            compression: Default::default(),
        }
    }

    /// Start a sweep declaration off this context's base spec.
    pub fn sweep(&self, name: &str) -> SweepSpec {
        SweepSpec::new(name, self.base_spec())
    }

    /// Execute a sweep grid, reusing this context's runtime when XLA.
    pub fn run_sweep(&self, sweep: &SweepSpec) -> Result<SweepGrid> {
        let mut session = match &self.backend {
            Backend::Xla(rt) => Session::with_runtime(rt.clone()),
            _ => Session::new(),
        };
        crate::exp::sweep::run_sweep(&mut session, sweep, &mut [])
    }
}

/// The default XLA runtime (artifacts dir from $FEDS_ARTIFACTS or ./artifacts).
pub fn xla_runtime() -> Result<Rc<Runtime>> {
    Runtime::load_default()
}

/// The default native backend used by fast sweeps and artifact-free tests.
pub fn native_backend() -> Backend {
    Backend::Native {
        hyper: Hyper { dim: 32, learning_rate: 3e-3, ..Default::default() },
        batch: 128,
        negatives: 32,
        eval_batch: 64,
    }
}

pub fn reports_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("FEDS_REPORTS").unwrap_or_else(|_| "reports".to_string()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datasets_share_one_kg() {
        let ctx = Ctx::new(native_backend(), true, 3);
        let ds = ctx.datasets(&[3, 5]);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0].0, "R3");
        assert_eq!(
            ds[0].1.total_triples(),
            ds[1].1.total_triples(),
            "same KG, different partitioning"
        );
    }

    #[test]
    fn fast_mode_shrinks_budget() {
        let fast = Ctx::new(native_backend(), true, 1);
        let full = Ctx::new(native_backend(), false, 1);
        assert!(fast.max_rounds < full.max_rounds);
    }
}
