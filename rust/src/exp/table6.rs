//! Table VI — sensitivity to batch size (128/256/512), FedEP vs FedS,
//! TransE on the R10 analogue.
//!
//! Batch size is baked into the AOT artifact shapes, so this sweep always
//! runs on the native backend (identical math; DESIGN.md §5) — the knob
//! under study is a training hyper-parameter, not a runtime property.
//!
//! Declared as a sweep grid (backend.batch × setting) over a native-backend
//! base and executed by the generic runner.

use anyhow::Result;

use crate::metrics::tracker::efficiency;
use crate::spec::BackendSpec;
use crate::util::json::Json;

use super::report::{fmt4, fmt_ratio, MdTable, Report};
use super::Ctx;

pub fn run(ctx: &Ctx) -> Result<Report> {
    let batches: &[usize] = if ctx.fast { &[128, 256] } else { &[128, 256, 512] };
    let mut base = ctx.base_spec();
    base.data.clients = 10;
    // the batch-size knob lives on the native backend regardless of the
    // context's backend (legacy behaviour: ctx data shape, native training)
    base.backend = BackendSpec::native_default();
    let sweep = crate::exp::sweep::SweepSpec::new("table6", base)
        .axis(
            "backend.batch",
            batches.iter().map(|&b| Json::from(b)).collect(),
        )
        .axis("algo", vec![Json::from("fedep"), Json::from("feds")]);
    let grid = ctx.run_sweep(&sweep)?;

    let mut t = MdTable::new(&[
        "Batch size", "Setting", "MRR", "Hits@10", "P@CG", "P@99", "P@98",
    ]);
    let mut raw = Vec::new();

    for (ib, &bs) in batches.iter().enumerate() {
        let fedep = &grid.at(&[ib, 0]).outcome;
        let feds = &grid.at(&[ib, 1]).outcome;
        let eff = efficiency(&feds.history, &fedep.history);
        t.row(vec![
            bs.to_string(),
            "FedEP".into(),
            fmt4(fedep.history.mrr_cg()),
            fmt4(fedep.history.hits10_cg()),
            "1.00x".into(),
            "1.00x".into(),
            "1.00x".into(),
        ]);
        t.row(vec![
            bs.to_string(),
            "FedS".into(),
            fmt4(feds.history.mrr_cg()),
            fmt4(feds.history.hits10_cg()),
            format!("{:.4}x", eff.p_cg),
            fmt_ratio(eff.p99),
            fmt_ratio(eff.p98),
        ]);
        raw.push(
            Json::obj()
                .set("batch", bs)
                .set("fedep_mrr", fedep.history.mrr_cg())
                .set("feds_mrr", feds.history.mrr_cg())
                .set("p_cg", eff.p_cg),
        );
    }

    let mut rep = Report::new(
        "table6",
        "Table VI — batch-size sensitivity (TransE, R10 analogue, native backend)",
    );
    rep.note("Paper shape to verify: FedS ≈ FedEP accuracy at every batch size with P@* below 1.0x.");
    rep.note("Runs on the native backend: batch size is an artifact-shape constant on the XLA path.");
    rep.table("Table VI", t);
    rep.raw = Json::obj().set("rows", Json::Arr(raw));
    Ok(rep)
}
