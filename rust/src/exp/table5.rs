//! Table V — sensitivity to the number of local epochs (2/3/4/5), FedEP vs
//! FedS, TransE on the R10 analogue.  Paper shape: FedS maintains FedEP-level
//! accuracy with markedly lower P@CG/P@99/P@98 at every local-epoch setting.
//!
//! Declared as a sweep grid (local-epochs × setting) on the R10 base and
//! executed by the generic runner.

use anyhow::Result;

use crate::metrics::tracker::efficiency;
use crate::util::json::Json;

use super::report::{fmt4, fmt_ratio, MdTable, Report};
use super::Ctx;

pub fn run(ctx: &Ctx) -> Result<Report> {
    let epochs: &[usize] = if ctx.fast { &[2, 3] } else { &[2, 3, 4, 5] };
    let mut base = ctx.base_spec();
    base.data.clients = 10;
    let sweep = crate::exp::sweep::SweepSpec::new("table5", base)
        .axis(
            "budget.local_epochs",
            epochs.iter().map(|&e| Json::from(e)).collect(),
        )
        .axis("algo", vec![Json::from("fedep"), Json::from("feds")]);
    let grid = ctx.run_sweep(&sweep)?;

    let mut t = MdTable::new(&[
        "Local epochs", "Setting", "MRR", "Hits@10", "P@CG", "P@99", "P@98",
    ]);
    let mut raw = Vec::new();

    for (ie, &le) in epochs.iter().enumerate() {
        let fedep = &grid.at(&[ie, 0]).outcome;
        let feds = &grid.at(&[ie, 1]).outcome;
        let eff = efficiency(&feds.history, &fedep.history);
        t.row(vec![
            le.to_string(),
            "FedEP".into(),
            fmt4(fedep.history.mrr_cg()),
            fmt4(fedep.history.hits10_cg()),
            "1.00x".into(),
            "1.00x".into(),
            "1.00x".into(),
        ]);
        t.row(vec![
            le.to_string(),
            "FedS".into(),
            fmt4(feds.history.mrr_cg()),
            fmt4(feds.history.hits10_cg()),
            format!("{:.4}x", eff.p_cg),
            fmt_ratio(eff.p99),
            fmt_ratio(eff.p98),
        ]);
        raw.push(
            Json::obj()
                .set("local_epochs", le)
                .set("fedep_mrr", fedep.history.mrr_cg())
                .set("feds_mrr", feds.history.mrr_cg())
                .set("p_cg", eff.p_cg)
                .set("p99", eff.p99.map(Json::from).unwrap_or(Json::Null))
                .set("p98", eff.p98.map(Json::from).unwrap_or(Json::Null)),
        );
    }

    let mut rep = Report::new(
        "table5",
        "Table V — local-epoch sensitivity (TransE, R10 analogue)",
    );
    rep.note("Paper shape to verify: FedS ≈ FedEP accuracy at every local-epoch count, with P@* well below 1.0x throughout.");
    rep.table("Table V", t);
    rep.raw = Json::obj().set("rows", Json::Arr(raw));
    Ok(rep)
}
