//! Table I — why naive compression fails (§III-A).
//!
//! Compares FedE(P) against FedE-KD / FedE-SVD / FedE-SVD+ on the total
//! transmitted parameter size when first reaching 98% of FedE(P)'s
//! converged MRR.  The paper's finding: all three *inflate* total traffic
//! (1.3×–2.5×) despite compressing every round, because they reduce
//! embedding precision for all entities and slow convergence.
//!
//! Declared as a sweep grid (method × clients × model) and executed by the
//! generic runner; KD joins the model axis only on the XLA backend.

use anyhow::Result;

use crate::fed::Backend;
use crate::kge::Method;
use crate::util::json::Json;

use super::report::{MdTable, Report};
use super::Ctx;

const CLIENTS: [usize; 3] = [10, 5, 3];

pub fn run(ctx: &Ctx) -> Result<Report> {
    let methods = [Method::TransE, Method::RotatE];
    let kd_available = matches!(ctx.backend, Backend::Xla(_));

    let mut models: Vec<(&str, &str)> = vec![
        ("fedsvd", "FedE-SVD"),
        ("fedsvd+", "FedE-SVD+"),
    ];
    if kd_available {
        models.insert(0, ("fedkd", "FedE-KD"));
    }
    let mut algo_values = vec![Json::from("fedep")];
    algo_values.extend(models.iter().map(|(a, _)| Json::from(*a)));

    let sweep = ctx
        .sweep("table1")
        .axis(
            "method",
            methods.iter().map(|m| Json::from(m.name())).collect(),
        )
        .axis("data.clients", CLIENTS.iter().map(|&n| Json::from(n)).collect())
        .axis("algo", algo_values);
    let grid = ctx.run_sweep(&sweep)?;

    let mut t = MdTable::new(&["KGE", "Model", "Dataset", "P@98 (scaled by FedE)"]);
    let mut raw = Vec::new();

    for (im, method) in methods.iter().enumerate() {
        for (id, &n) in CLIENTS.iter().enumerate() {
            let dname = format!("R{n}");
            let fede = &grid.at(&[im, id, 0]).outcome;
            let target = 0.98 * fede.history.mrr_cg();
            let base_params = fede.history.params_at_mrr(target);

            t.row(vec![
                method.name().into(),
                "FedE".into(),
                dname.clone(),
                "1.00x".into(),
            ]);
            for (iv, (_, label)) in models.iter().enumerate() {
                let out = &grid.at(&[im, id, iv + 1]).outcome;
                let reached = out.history.params_at_mrr(target);
                let cell = match (reached, base_params) {
                    (Some(m), Some(b)) => format!("{:.2}x", m as f64 / b.max(1) as f64),
                    // never reached 98% within budget: report the lower
                    // bound from total traffic (the paper's point, amplified)
                    (None, Some(b)) => format!(
                        ">{:.2}x (never reached)",
                        out.acct.params() as f64 / b.max(1) as f64
                    ),
                    _ => "-".into(),
                };
                t.row(vec![method.name().into(), (*label).into(), dname.clone(), cell.clone()]);
                raw.push(
                    Json::obj()
                        .set("method", method.name())
                        .set("model", *label)
                        .set("dataset", dname.as_str())
                        .set("ratio", cell)
                        .set("model_mrr", out.history.mrr_cg())
                        .set("fede_mrr", fede.history.mrr_cg()),
                );
            }
        }
    }

    let mut rep = Report::new(
        "table1",
        "Table I — total transmitted parameters to reach 98% of FedE's converged MRR",
    );
    rep.note("Paper shape to verify: every compression baseline lands ABOVE 1.0x (naive per-round compression increases total traffic).");
    if !kd_available {
        rep.note("FedE-KD skipped: requires the XLA backend (co-distillation artifact).");
    }
    rep.note("SVD rank auto-chosen per width (DESIGN.md §5); paper used rank 5 of 8 at D=256.");
    rep.table("Table I", t);
    rep.raw = Json::obj().set("rows", Json::Arr(raw));
    Ok(rep)
}
