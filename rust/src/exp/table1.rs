//! Table I — why naive compression fails (§III-A).
//!
//! Compares FedE(P) against FedE-KD / FedE-SVD / FedE-SVD+ on the total
//! transmitted parameter size when first reaching 98% of FedE(P)'s
//! converged MRR.  The paper's finding: all three *inflate* total traffic
//! (1.3×–2.5×) despite compressing every round, because they reduce
//! embedding precision for all entities and slow convergence.

use anyhow::Result;

use crate::fed::{Algo, Backend};
use crate::kge::Method;
use crate::util::json::Json;

use super::report::{MdTable, Report};
use super::Ctx;

pub fn run(ctx: &Ctx) -> Result<Report> {
    let datasets = ctx.datasets(&[10, 5, 3]);
    let methods = [Method::TransE, Method::RotatE];
    let kd_available = matches!(ctx.backend, Backend::Xla(_));

    let mut t = MdTable::new(&["KGE", "Model", "Dataset", "P@98 (scaled by FedE)"]);
    let mut raw = Vec::new();

    for method in methods {
        for (dname, data) in &datasets {
            let fede = ctx.run(data, &ctx.run_cfg(Algo::FedEP, method))?;
            let target = 0.98 * fede.history.mrr_cg();
            let base_params = fede.history.params_at_mrr(target);

            let mut variants: Vec<(&str, Algo)> = vec![
                ("FedE-SVD", Algo::FedSvd { constrained: false }),
                ("FedE-SVD+", Algo::FedSvd { constrained: true }),
            ];
            if kd_available {
                variants.insert(0, ("FedE-KD", Algo::FedKd));
            }

            t.row(vec![
                method.name().into(),
                "FedE".into(),
                dname.clone(),
                "1.00x".into(),
            ]);
            for (label, algo) in variants {
                let out = ctx.run(data, &ctx.run_cfg(algo, method))?;
                let reached = out.history.params_at_mrr(target);
                let cell = match (reached, base_params) {
                    (Some(m), Some(b)) => format!("{:.2}x", m as f64 / b.max(1) as f64),
                    // never reached 98% within budget: report the lower
                    // bound from total traffic (the paper's point, amplified)
                    (None, Some(b)) => format!(
                        ">{:.2}x (never reached)",
                        out.acct.params() as f64 / b.max(1) as f64
                    ),
                    _ => "-".into(),
                };
                t.row(vec![method.name().into(), label.into(), dname.clone(), cell.clone()]);
                raw.push(
                    Json::obj()
                        .set("method", method.name())
                        .set("model", label)
                        .set("dataset", dname.as_str())
                        .set("ratio", cell)
                        .set("model_mrr", out.history.mrr_cg())
                        .set("fede_mrr", fede.history.mrr_cg()),
                );
            }
        }
    }

    let mut rep = Report::new(
        "table1",
        "Table I — total transmitted parameters to reach 98% of FedE's converged MRR",
    );
    rep.note("Paper shape to verify: every compression baseline lands ABOVE 1.0x (naive per-round compression increases total traffic).");
    if !kd_available {
        rep.note("FedE-KD skipped: requires the XLA backend (co-distillation artifact).");
    }
    rep.note("SVD rank auto-chosen per width (DESIGN.md §5); paper used rank 5 of 8 at D=256.");
    rep.table("Table I", t);
    rep.raw = Json::obj().set("rows", Json::Arr(raw));
    Ok(rep)
}
