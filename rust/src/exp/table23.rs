//! Tables II + III — the headline result.
//!
//! Table II: prediction accuracy (MRR, Hits@10) of Single / FedEP / FedS on
//! R10/R5/R3 × {TransE, RotatE, ComplEx}.
//! Table III: communication overhead of FedS scaled by FedEP — P@CG, P@99,
//! P@98 (§IV-B metric definitions).
//!
//! Declared as a sweep grid (method × clients × setting) and executed by
//! the generic runner; this function only shapes the two tables.

use anyhow::Result;

use crate::kge::Method;
use crate::metrics::tracker::efficiency;
use crate::util::json::Json;

use super::report::{fmt4, fmt_ratio, MdTable, Report};
use super::Ctx;

/// Optional env filters for budgeted runs:
/// `FEDS_EXP_METHODS=transe,rotate` / `FEDS_EXP_DATASETS=R10,R3`.
fn env_filter<T: Clone>(var: &str, all: Vec<(String, T)>) -> Vec<(String, T)> {
    match std::env::var(var) {
        Err(_) => all,
        Ok(list) => {
            let keep: Vec<String> = list.split(',').map(|s| s.trim().to_string()).collect();
            all.into_iter()
                .filter(|(name, _)| keep.iter().any(|k| name.eq_ignore_ascii_case(k)))
                .collect()
        }
    }
}

pub fn run(ctx: &Ctx) -> Result<Report> {
    let datasets = env_filter(
        "FEDS_EXP_DATASETS",
        [10usize, 5, 3].iter().map(|&n| (format!("R{n}"), n)).collect(),
    );
    let methods = env_filter(
        "FEDS_EXP_METHODS",
        Method::ALL.iter().map(|m| (m.name().to_string(), *m)).collect(),
    );

    let sweep = ctx
        .sweep("table23")
        .axis(
            "method",
            methods.iter().map(|(_, m)| Json::from(m.name())).collect(),
        )
        .axis(
            "data.clients",
            datasets.iter().map(|(_, n)| Json::from(*n)).collect(),
        )
        .axis(
            "algo",
            vec![Json::from("single"), Json::from("fedep"), Json::from("feds")],
        );
    let grid = ctx.run_sweep(&sweep)?;

    let mut t2 = MdTable::new(&["KGE", "Setting", "Dataset", "MRR", "Hits@10"]);
    let mut t3 = MdTable::new(&["KGE", "Dataset", "P@CG", "P@99", "P@98", "Eq.5 bound"]);
    let mut raw = Vec::new();

    for (im, (_, method)) in methods.iter().enumerate() {
        for (id, (dname, _)) in datasets.iter().enumerate() {
            let single = &grid.at(&[im, id, 0]).outcome;
            let fedep = &grid.at(&[im, id, 1]).outcome;
            let feds = &grid.at(&[im, id, 2]).outcome;

            for (label, out) in [("Single", single), ("FedEP", fedep), ("FedS", feds)] {
                t2.row(vec![
                    method.name().into(),
                    label.into(),
                    dname.clone(),
                    fmt4(out.history.mrr_cg()),
                    fmt4(out.history.hits10_cg()),
                ]);
            }

            let eff = efficiency(&feds.history, &fedep.history);
            t3.row(vec![
                method.name().into(),
                dname.clone(),
                format!("{:.4}x", eff.p_cg),
                fmt_ratio(eff.p99),
                fmt_ratio(eff.p98),
                fmt_ratio(feds.eq5_ratio),
            ]);

            raw.push(
                Json::obj()
                    .set("method", method.name())
                    .set("dataset", dname.as_str())
                    .set("single_mrr", single.history.mrr_cg())
                    .set("fedep_mrr", fedep.history.mrr_cg())
                    .set("feds_mrr", feds.history.mrr_cg())
                    .set("fedep_hits10", fedep.history.hits10_cg())
                    .set("feds_hits10", feds.history.hits10_cg())
                    .set("p_cg", eff.p_cg)
                    .set("p99", eff.p99.map(Json::from).unwrap_or(Json::Null))
                    .set("p98", eff.p98.map(Json::from).unwrap_or(Json::Null))
                    .set("fedep_rounds", fedep.history.rounds_cg())
                    .set("feds_rounds", feds.history.rounds_cg())
                    .set("fedep_params", fedep.history.params_cg())
                    .set("feds_params", feds.history.params_cg()),
            );
        }
    }

    let mut rep = Report::new(
        "table23",
        "Tables II & III — accuracy and communication overhead: Single / FedEP / FedS",
    );
    rep.note("Paper shape to verify: FedS MRR within ~1% of FedEP; P@CG/P@99/P@98 well below 1.0x; savings larger with more clients.");
    rep.table("Table II — prediction accuracy", t2);
    rep.table("Table III — communication overhead (scaled by FedEP)", t3);
    rep.raw = Json::obj().set("rows", Json::Arr(raw));
    Ok(rep)
}
