//! Report writers: markdown tables (mirroring the paper's layout) plus raw
//! JSON, written under `reports/`.

use std::path::Path;

use crate::util::json::Json;

/// A simple markdown table builder.
pub struct MdTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl MdTable {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("| {} |\n", self.header.join(" | ")));
        s.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            s.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        s
    }
}

/// A report: title, commentary, tables, raw data.
pub struct Report {
    pub name: String,
    pub title: String,
    pub notes: Vec<String>,
    pub tables: Vec<(String, MdTable)>,
    pub raw: Json,
}

impl Report {
    pub fn new(name: &str, title: &str) -> Self {
        Self {
            name: name.to_string(),
            title: title.to_string(),
            notes: Vec::new(),
            tables: Vec::new(),
            raw: Json::obj(),
        }
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn table(&mut self, caption: &str, t: MdTable) {
        self.tables.push((caption.to_string(), t));
    }

    pub fn to_markdown(&self) -> String {
        let mut s = format!("# {}\n\n", self.title);
        for n in &self.notes {
            s.push_str(&format!("- {n}\n"));
        }
        s.push('\n');
        for (cap, t) in &self.tables {
            s.push_str(&format!("## {cap}\n\n{}\n", t.to_markdown()));
        }
        s
    }

    /// Write `reports/<name>.md` (+ `.json` when raw data was attached)
    /// and echo the markdown to stdout.
    pub fn save(&self, dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let md = self.to_markdown();
        std::fs::write(dir.join(format!("{}.md", self.name)), &md)?;
        if self.raw != Json::obj() {
            std::fs::write(
                dir.join(format!("{}.json", self.name)),
                self.raw.to_string_pretty(),
            )?;
        }
        println!("{md}");
        println!("(saved to {}/{}.md)", dir.display(), self.name);
        Ok(())
    }
}

pub fn fmt4(x: f64) -> String {
    format!("{x:.4}")
}

pub fn fmt_ratio(x: Option<f64>) -> String {
    match x {
        Some(v) => format!("{v:.4}x"),
        None => "-".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert_eq!(md.lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn report_saves() {
        let mut r = Report::new("test_report", "Test");
        r.note("a note");
        let mut t = MdTable::new(&["x"]);
        t.row(vec!["y".into()]);
        r.table("cap", t);
        let dir = std::env::temp_dir().join("feds_test_reports");
        r.save(&dir).unwrap();
        let md = std::fs::read_to_string(dir.join("test_report.md")).unwrap();
        assert!(md.contains("# Test"));
        assert!(md.contains("a note"));
    }
}
