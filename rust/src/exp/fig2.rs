//! Figure 2 — ablation of the Intermittent Synchronization Mechanism.
//!
//! FedS vs FedS/syn (no synchronization) on R5/R3 × {TransE, RotatE}:
//! accuracy-vs-round curves.  Paper shape: FedS/syn may converge in fewer
//! rounds but FedS consistently reaches higher accuracy, and its curve
//! dominates as rounds grow.
//!
//! Declared as a sweep grid (method × clients × sync-ablation) and executed
//! by the generic runner; the full per-round curves come from each cell's
//! observer-assembled history.

use anyhow::Result;

use crate::kge::Method;
use crate::util::json::Json;

use super::report::{fmt4, MdTable, Report};
use super::Ctx;

const CLIENTS: [usize; 2] = [5, 3];

pub fn run(ctx: &Ctx) -> Result<Report> {
    let methods = [Method::TransE, Method::RotatE];
    let sweep = ctx
        .sweep("fig2")
        .axis(
            "method",
            methods.iter().map(|m| Json::from(m.name())).collect(),
        )
        .axis("data.clients", CLIENTS.iter().map(|&n| Json::from(n)).collect())
        .axis("algo", vec![Json::from("feds"), Json::from("feds-nosync")]);
    let grid = ctx.run_sweep(&sweep)?;

    let mut summary = MdTable::new(&[
        "KGE", "Dataset", "Setting", "MRR@CG", "R@CG",
    ]);
    let mut curves_md = MdTable::new(&["KGE", "Dataset", "round", "FedS MRR", "FedS/syn MRR"]);
    let mut raw = Vec::new();

    for (im, method) in methods.iter().enumerate() {
        for (id, &n) in CLIENTS.iter().enumerate() {
            let dname = format!("R{n}");
            let with = &grid.at(&[im, id, 0]).outcome;
            let without = &grid.at(&[im, id, 1]).outcome;

            for (label, out) in [("FedS", with), ("FedS/syn", without)] {
                summary.row(vec![
                    method.name().into(),
                    dname.clone(),
                    label.into(),
                    fmt4(out.history.mrr_cg()),
                    out.history.rounds_cg().to_string(),
                ]);
            }

            // aligned curve rows (the "figure" as a series)
            let n_rows = with.history.records.len().max(without.history.records.len());
            for i in 0..n_rows {
                let r_with = with.history.records.get(i);
                let r_without = without.history.records.get(i);
                let round = r_with
                    .map(|r| r.round)
                    .or(r_without.map(|r| r.round))
                    .unwrap_or(0);
                curves_md.row(vec![
                    method.name().into(),
                    dname.clone(),
                    round.to_string(),
                    r_with.map(|r| fmt4(r.test.mrr)).unwrap_or_else(|| "-".into()),
                    r_without.map(|r| fmt4(r.test.mrr)).unwrap_or_else(|| "-".into()),
                ]);
            }

            raw.push(
                Json::obj()
                    .set("method", method.name())
                    .set("dataset", dname.as_str())
                    .set(
                        "feds_curve",
                        Json::Arr(
                            with.history
                                .records
                                .iter()
                                .map(|r| {
                                    Json::obj().set("round", r.round).set("mrr", r.test.mrr)
                                })
                                .collect(),
                        ),
                    )
                    .set(
                        "feds_nosync_curve",
                        Json::Arr(
                            without
                                .history
                                .records
                                .iter()
                                .map(|r| {
                                    Json::obj().set("round", r.round).set("mrr", r.test.mrr)
                                })
                                .collect(),
                        ),
                    ),
            );
        }
    }

    let mut rep = Report::new(
        "fig2",
        "Figure 2 — FedS vs FedS/syn (Intermittent Synchronization ablation)",
    );
    rep.note("Paper shape to verify: FedS reaches higher converged accuracy than FedS/syn in every cell.");
    rep.table("Converged accuracy and rounds", summary);
    rep.table("Accuracy-vs-round curves (the figure's series)", curves_md);
    rep.raw = Json::obj().set("cells", Json::Arr(raw));
    Ok(rep)
}
