//! Declarative sweep grids: one base [`ExperimentSpec`] × override axes,
//! executed by one generic runner.
//!
//! A [`SweepSpec`] is fully JSON-(de)serializable (`feds sweep --spec
//! file.json`); every paper table/figure driver in this crate is now a
//! sweep declaration plus a small report-shaping function over the
//! resulting [`SweepGrid`].  Axes use the same dotted override keys as
//! CLI flags ([`ExperimentSpec::apply`]), so `{"key": "algo", "values":
//! ["fedep", "feds"]}` and `--algo feds` are the same mechanism.
//!
//! Cells are materialized in row-major order (last axis fastest) and each
//! cell is an independent deterministic run, so grid results are
//! identical to driving the legacy per-table loops by hand.

use anyhow::{ensure, Result};

use crate::fed::RunOutcome;
use crate::metrics::observe::RunObserver;
use crate::spec::{ExperimentSpec, Session};
use crate::util::json::Json;

use super::report::{fmt4, MdTable, Report};

/// One sweep axis: a dotted override key and the values it takes.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepAxis {
    pub key: String,
    pub values: Vec<Json>,
}

/// A declarative experiment grid: base spec × axes.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepSpec {
    pub name: String,
    pub base: ExperimentSpec,
    pub axes: Vec<SweepAxis>,
}

impl SweepSpec {
    pub fn new(name: &str, base: ExperimentSpec) -> Self {
        Self { name: name.to_string(), base, axes: Vec::new() }
    }

    /// Append an axis (builder-style).
    pub fn axis(mut self, key: &str, values: Vec<Json>) -> Self {
        self.axes.push(SweepAxis { key: key.to_string(), values });
        self
    }

    /// Number of cells in the grid (1 when there are no axes).
    pub fn len(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize every cell: the applied overrides plus the resolved,
    /// validated spec, in row-major order (last axis fastest).
    pub fn cells(&self) -> Result<Vec<(Vec<(String, Json)>, ExperimentSpec)>> {
        let dims: Vec<usize> = self.axes.iter().map(|a| a.values.len()).collect();
        for (axis, &d) in self.axes.iter().zip(&dims) {
            ensure!(d > 0, "sweep axis '{}' has no values", axis.key);
        }
        let total: usize = dims.iter().product();
        let mut out = Vec::with_capacity(total);
        let mut idx = vec![0usize; self.axes.len()];
        for _ in 0..total {
            let mut spec = self.base.clone();
            let mut overrides = Vec::with_capacity(idx.len());
            for (i, axis) in self.axes.iter().enumerate() {
                let v = &axis.values[idx[i]];
                spec.apply(&axis.key, v)
                    .map_err(|e| anyhow::anyhow!("sweep axis '{}' value {v}: {e}", axis.key))?;
                overrides.push((axis.key.clone(), v.clone()));
            }
            spec.validate()?;
            out.push((overrides, spec));
            for i in (0..idx.len()).rev() {
                idx[i] += 1;
                if idx[i] < dims[i] {
                    break;
                }
                idx[i] = 0;
            }
        }
        Ok(out)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("base", self.base.to_json())
            .set(
                "axes",
                Json::Arr(
                    self.axes
                        .iter()
                        .map(|a| {
                            Json::obj()
                                .set("key", a.key.as_str())
                                .set("values", Json::Arr(a.values.clone()))
                        })
                        .collect(),
                ),
            )
    }

    pub fn from_json(v: &Json) -> Result<SweepSpec> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("sweep")
            .to_string();
        let base = ExperimentSpec::from_json(v.req("base")?)?;
        let mut axes = Vec::new();
        if let Some(list) = v.get("axes") {
            let list = list
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("axes must be an array"))?;
            for a in list {
                let key = a
                    .req("key")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("axis key must be a string"))?
                    .to_string();
                let values = a
                    .req("values")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("axis '{key}' values must be an array"))?
                    .to_vec();
                axes.push(SweepAxis { key, values });
            }
        }
        let sweep = SweepSpec { name, base, axes };
        // surface bad keys/values at load time, not mid-sweep
        sweep.cells()?;
        Ok(sweep)
    }

    pub fn parse(text: &str) -> Result<SweepSpec> {
        Self::from_json(&Json::parse(text)?)
    }

    pub fn load(path: &std::path::Path) -> Result<SweepSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading sweep spec {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow::anyhow!("sweep spec {}: {e}", path.display()))
    }
}

/// One executed grid cell.
pub struct SweepCell {
    /// the (key, value) overrides this cell applied to the base spec
    pub overrides: Vec<(String, Json)>,
    pub spec: ExperimentSpec,
    pub outcome: RunOutcome,
}

/// All executed cells of a sweep, in row-major axis order.
pub struct SweepGrid {
    pub name: String,
    pub axis_keys: Vec<String>,
    pub dims: Vec<usize>,
    pub cells: Vec<SweepCell>,
}

impl SweepGrid {
    /// The cell at one multi-dimensional axis index (row-major).
    pub fn at(&self, idx: &[usize]) -> &SweepCell {
        assert_eq!(idx.len(), self.dims.len(), "sweep index arity");
        let mut flat = 0usize;
        for (i, &x) in idx.iter().enumerate() {
            assert!(x < self.dims[i], "axis {i} index {x} out of range (dim {})", self.dims[i]);
            flat = flat * self.dims[i] + x;
        }
        &self.cells[flat]
    }

    /// First cell whose overrides contain every given (key, value) pair.
    pub fn find(&self, want: &[(&str, &Json)]) -> Option<&SweepCell> {
        self.cells.iter().find(|c| {
            want.iter().all(|(k, v)| {
                c.overrides.iter().any(|(ck, cv)| ck == k && cv == *v)
            })
        })
    }
}

/// Execute every cell of `sweep` through one [`Session`] (the PJRT
/// runtime, when used, loads once).  `extra` observers are shared across
/// all runs — a JSONL sink here yields one stream with `run_start` lines
/// delimiting the cells.
pub fn run_sweep(
    session: &mut Session,
    sweep: &SweepSpec,
    extra: &mut [&mut dyn RunObserver],
) -> Result<SweepGrid> {
    let cells_in = sweep.cells()?;
    let total = cells_in.len();
    let mut cells = Vec::with_capacity(total);
    for (i, (overrides, spec)) in cells_in.into_iter().enumerate() {
        crate::info!(
            "sweep {}: cell {}/{} [{}]",
            sweep.name,
            i + 1,
            total,
            describe(&overrides)
        );
        let mut run = session.build(&spec)?;
        let outcome = run.execute_with(extra)?;
        cells.push(SweepCell { overrides, spec, outcome });
    }
    Ok(SweepGrid {
        name: sweep.name.clone(),
        axis_keys: sweep.axes.iter().map(|a| a.key.clone()).collect(),
        dims: sweep.axes.iter().map(|a| a.values.len()).collect(),
        cells,
    })
}

/// Render a Json override value without string quotes.
pub fn fmt_value(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

fn describe(overrides: &[(String, Json)]) -> String {
    overrides
        .iter()
        .map(|(k, v)| format!("{k}={}", fmt_value(v)))
        .collect::<Vec<_>>()
        .join(", ")
}

/// The generic sweep report (`feds sweep --spec`): one row per cell with
/// the axis values and the headline metrics.
pub fn grid_report(grid: &SweepGrid) -> Report {
    let mut header: Vec<&str> = grid.axis_keys.iter().map(|s| s.as_str()).collect();
    header.extend(["MRR", "Hits@10", "R@CG", "params@CG", "bytes@CG"]);
    let mut t = MdTable::new(&header);
    let mut raw = Vec::new();
    for cell in &grid.cells {
        let h = &cell.outcome.history;
        let mut row: Vec<String> =
            cell.overrides.iter().map(|(_, v)| fmt_value(v)).collect();
        row.extend([
            fmt4(h.mrr_cg()),
            fmt4(h.hits10_cg()),
            h.rounds_cg().to_string(),
            h.params_cg().to_string(),
            h.converged().bytes_cum.to_string(),
        ]);
        t.row(row);
        let mut over = Json::obj();
        for (k, v) in &cell.overrides {
            over = over.set(k, v.clone());
        }
        raw.push(
            Json::obj()
                .set("overrides", over)
                .set("mrr", h.mrr_cg())
                .set("hits10", h.hits10_cg())
                .set("rounds_cg", h.rounds_cg())
                .set("params_cg", h.params_cg())
                .set("params_total", cell.outcome.acct.params())
                .set("bytes_total", cell.outcome.acct.bytes())
                .set("messages", cell.outcome.acct.messages()),
        );
    }
    let mut rep = Report::new(&grid.name, &format!("Sweep {} — {} cells", grid.name, grid.cells.len()));
    rep.table("Grid", t);
    rep.raw = Json::obj().set("cells", Json::Arr(raw));
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fed::ExecMode;
    use crate::kge::Method;
    use crate::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec};

    fn base() -> ExperimentSpec {
        ExperimentSpec {
            name: "t".into(),
            method: Method::TransE,
            algo: AlgoSpec::FedEP,
            data: DataSpec {
                entities: 192,
                relations: 12,
                triples: 2400,
                clusters: 4,
                clients: 3,
                seed: 7,
            },
            backend: BackendSpec::Native {
                dim: 16,
                learning_rate: 5e-3,
                batch: 64,
                negatives: 16,
                eval_batch: 32,
            },
            budget: BudgetSpec {
                max_rounds: 4,
                local_epochs: 1,
                eval_every: 2,
                patience: 3,
                eval_cap: 32,
            },
            seed: 7,
            exec: ExecMode::Sequential,
        }
    }

    #[test]
    fn cells_enumerate_row_major_last_axis_fastest() {
        let sweep = SweepSpec::new("s", base())
            .axis("data.clients", vec![Json::from(3usize), Json::from(4usize)])
            .axis("algo", vec![Json::from("fedep"), Json::from("feds")]);
        let cells = sweep.cells().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].1.data.clients, 3);
        assert_eq!(cells[0].1.algo, AlgoSpec::FedEP);
        assert_eq!(cells[1].1.data.clients, 3);
        assert_eq!(cells[1].1.algo, AlgoSpec::feds());
        assert_eq!(cells[2].1.data.clients, 4);
        assert_eq!(cells[2].1.algo, AlgoSpec::FedEP);
        assert_eq!(cells[3].1.data.clients, 4);
        assert_eq!(cells[3].1.algo, AlgoSpec::feds());
    }

    #[test]
    fn no_axes_yields_the_base_cell() {
        let sweep = SweepSpec::new("s", base());
        let cells = sweep.cells().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].1, base());
    }

    #[test]
    fn json_round_trip() {
        let sweep = SweepSpec::new("rt", base())
            .axis("method", vec![Json::from("transe"), Json::from("rotate")])
            .axis("algo.sparsity", vec![Json::Num(0.2), Json::Num(0.4)]);
        // algo.sparsity on a fedep base is invalid — swap the base algo
        let mut sweep = sweep;
        sweep.base.algo = AlgoSpec::feds();
        let rt = SweepSpec::parse(&sweep.to_json().to_string_pretty()).unwrap();
        assert_eq!(sweep, rt);
    }

    #[test]
    fn bad_axis_key_rejected_at_parse() {
        let sweep = SweepSpec::new("bad", base()).axis("nope", vec![Json::Num(1.0)]);
        let text = sweep.to_json().to_string();
        assert!(SweepSpec::parse(&text).is_err());
    }

    #[test]
    fn scoped_axis_on_wrong_family_rejected() {
        // base algo is fedep: a sparsity axis must fail loudly
        let sweep = SweepSpec::new("bad", base()).axis("algo.sparsity", vec![Json::Num(0.3)]);
        assert!(sweep.cells().is_err());
    }
}
