//! Declarative sweep grids: one base [`ExperimentSpec`] × override axes,
//! executed by one generic runner.
//!
//! A [`SweepSpec`] is fully JSON-(de)serializable (`feds sweep --spec
//! file.json`); every paper table/figure driver in this crate is now a
//! sweep declaration plus a small report-shaping function over the
//! resulting [`SweepGrid`].  Axes use the same dotted override keys as
//! CLI flags ([`ExperimentSpec::apply`]), so `{"key": "algo", "values":
//! ["fedep", "feds"]}` and `--algo feds` are the same mechanism.
//!
//! Cells are materialized in row-major order (last axis fastest) and each
//! cell is an independent deterministic run, so grid results are
//! identical to driving the legacy per-table loops by hand.
//!
//! Grids are **resumable**: because cells execute in deterministic order
//! and every completed run writes a `run_end` event to its JSONL stream,
//! [`completed_runs`] counts how many cells an interrupted sweep already
//! finished and [`run_sweep_from`] re-executes only the missing tail,
//! appending to the same stream (`feds sweep --resume`).

use std::path::Path;

use anyhow::{ensure, Result};

use crate::fed::RunOutcome;
use crate::metrics::observe::RunObserver;
use crate::spec::{ExperimentSpec, Session};
use crate::util::json::Json;

use super::report::{fmt4, MdTable, Report};

/// One sweep axis: a dotted override key and the values it takes.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepAxis {
    pub key: String,
    pub values: Vec<Json>,
}

/// A declarative experiment grid: base spec × axes.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepSpec {
    pub name: String,
    pub base: ExperimentSpec,
    pub axes: Vec<SweepAxis>,
}

impl SweepSpec {
    pub fn new(name: &str, base: ExperimentSpec) -> Self {
        Self { name: name.to_string(), base, axes: Vec::new() }
    }

    /// Append an axis (builder-style).
    pub fn axis(mut self, key: &str, values: Vec<Json>) -> Self {
        self.axes.push(SweepAxis { key: key.to_string(), values });
        self
    }

    /// Number of cells in the grid (1 when there are no axes).
    pub fn len(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize every cell: the applied overrides plus the resolved,
    /// validated spec, in row-major order (last axis fastest).
    pub fn cells(&self) -> Result<Vec<(Vec<(String, Json)>, ExperimentSpec)>> {
        let dims: Vec<usize> = self.axes.iter().map(|a| a.values.len()).collect();
        for (axis, &d) in self.axes.iter().zip(&dims) {
            ensure!(d > 0, "sweep axis '{}' has no values", axis.key);
        }
        let total: usize = dims.iter().product();
        let mut out = Vec::with_capacity(total);
        let mut idx = vec![0usize; self.axes.len()];
        for _ in 0..total {
            let mut spec = self.base.clone();
            let mut overrides = Vec::with_capacity(idx.len());
            for (i, axis) in self.axes.iter().enumerate() {
                let v = &axis.values[idx[i]];
                spec.apply(&axis.key, v)
                    .map_err(|e| anyhow::anyhow!("sweep axis '{}' value {v}: {e}", axis.key))?;
                overrides.push((axis.key.clone(), v.clone()));
            }
            spec.validate()?;
            out.push((overrides, spec));
            for i in (0..idx.len()).rev() {
                idx[i] += 1;
                if idx[i] < dims[i] {
                    break;
                }
                idx[i] = 0;
            }
        }
        Ok(out)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("base", self.base.to_json())
            .set(
                "axes",
                Json::Arr(
                    self.axes
                        .iter()
                        .map(|a| {
                            Json::obj()
                                .set("key", a.key.as_str())
                                .set("values", Json::Arr(a.values.clone()))
                        })
                        .collect(),
                ),
            )
    }

    pub fn from_json(v: &Json) -> Result<SweepSpec> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("sweep")
            .to_string();
        let base = ExperimentSpec::from_json(v.req("base")?)?;
        let mut axes = Vec::new();
        if let Some(list) = v.get("axes") {
            let list = list
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("axes must be an array"))?;
            for a in list {
                let key = a
                    .req("key")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("axis key must be a string"))?
                    .to_string();
                let values = a
                    .req("values")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("axis '{key}' values must be an array"))?
                    .to_vec();
                axes.push(SweepAxis { key, values });
            }
        }
        let sweep = SweepSpec { name, base, axes };
        // surface bad keys/values at load time, not mid-sweep
        sweep.cells()?;
        Ok(sweep)
    }

    pub fn parse(text: &str) -> Result<SweepSpec> {
        Self::from_json(&Json::parse(text)?)
    }

    pub fn load(path: &std::path::Path) -> Result<SweepSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading sweep spec {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow::anyhow!("sweep spec {}: {e}", path.display()))
    }
}

/// One executed grid cell.
pub struct SweepCell {
    /// the (key, value) overrides this cell applied to the base spec
    pub overrides: Vec<(String, Json)>,
    pub spec: ExperimentSpec,
    pub outcome: RunOutcome,
}

/// All executed cells of a sweep, in row-major axis order.  A resumed
/// sweep carries only the cells this invocation executed: `start` is the
/// flat index of the first one (0 for a full run).
pub struct SweepGrid {
    pub name: String,
    pub axis_keys: Vec<String>,
    pub dims: Vec<usize>,
    pub start: usize,
    pub cells: Vec<SweepCell>,
}

impl SweepGrid {
    /// The cell at one multi-dimensional axis index (row-major).  Panics
    /// for cells a resumed grid skipped.
    pub fn at(&self, idx: &[usize]) -> &SweepCell {
        assert_eq!(idx.len(), self.dims.len(), "sweep index arity");
        let mut flat = 0usize;
        for (i, &x) in idx.iter().enumerate() {
            assert!(x < self.dims[i], "axis {i} index {x} out of range (dim {})", self.dims[i]);
            flat = flat * self.dims[i] + x;
        }
        assert!(
            flat >= self.start,
            "cell {flat} was skipped by this resumed sweep (start {})",
            self.start
        );
        &self.cells[flat - self.start]
    }

    /// First cell whose overrides contain every given (key, value) pair.
    pub fn find(&self, want: &[(&str, &Json)]) -> Option<&SweepCell> {
        self.cells.iter().find(|c| {
            want.iter().all(|(k, v)| {
                c.overrides.iter().any(|(ck, cv)| ck == k && cv == *v)
            })
        })
    }
}

/// Execute every cell of `sweep` through one [`Session`] (the PJRT
/// runtime, when used, loads once).  `extra` observers are shared across
/// all runs — a JSONL sink here yields one stream with `run_start` lines
/// delimiting the cells.
pub fn run_sweep(
    session: &mut Session,
    sweep: &SweepSpec,
    extra: &mut [&mut dyn RunObserver],
) -> Result<SweepGrid> {
    run_sweep_from(session, sweep, 0, extra)
}

/// Execute the grid's cells from flat index `skip` onward — the resume
/// path: `skip` is [`completed_runs`] of the interrupted sweep's JSONL
/// stream, and `extra` should include a [`JsonlSink`] opened in append
/// mode so the completed cells' events survive.
///
/// [`JsonlSink`]: crate::metrics::observe::JsonlSink
pub fn run_sweep_from(
    session: &mut Session,
    sweep: &SweepSpec,
    skip: usize,
    extra: &mut [&mut dyn RunObserver],
) -> Result<SweepGrid> {
    let cells_in = sweep.cells()?;
    let total = cells_in.len();
    ensure!(
        skip <= total,
        "sweep {}: cannot skip {skip} of {total} cells — the JSONL stream records more \
         completed runs than the grid has (stale file for a different sweep?)",
        sweep.name
    );
    if skip > 0 {
        crate::info!("sweep {}: resuming — skipping {skip}/{total} completed cells", sweep.name);
    }
    let mut cells = Vec::with_capacity(total - skip);
    for (i, (overrides, spec)) in cells_in.into_iter().enumerate().skip(skip) {
        crate::info!(
            "sweep {}: cell {}/{} [{}]",
            sweep.name,
            i + 1,
            total,
            describe(&overrides)
        );
        let mut run = session.build(&spec)?;
        let outcome = run.execute_with(extra)?;
        cells.push(SweepCell { overrides, spec, outcome });
    }
    Ok(SweepGrid {
        name: sweep.name.clone(),
        axis_keys: sweep.axes.iter().map(|a| a.key.clone()).collect(),
        dims: sweep.axes.iter().map(|a| a.values.len()).collect(),
        start: skip,
        cells,
    })
}

/// How many runs a JSONL event stream records as completed — one
/// `run_end` line per finished cell.  A missing file is zero (nothing has
/// run); unparseable lines (e.g. a line truncated by a crash) are
/// skipped, so a cell only counts once its terminal event hit the disk
/// intact.
pub fn completed_runs(path: &Path) -> Result<usize> {
    if !path.exists() {
        return Ok(0);
    }
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading JSONL stream {}: {e}", path.display()))?;
    Ok(text
        .lines()
        .filter(|line| {
            Json::parse(line)
                .ok()
                .and_then(|j| j.get("event").and_then(Json::as_str).map(String::from))
                .is_some_and(|ev| ev == "run_end")
        })
        .count())
}

/// The validated resume point of `sweep` against an existing JSONL
/// stream: the number of completed cells to skip.  Besides counting
/// `run_end` events, every completed run's `run_start` label is checked
/// against the label the corresponding grid cell would produce — a
/// stream left over from a *different* sweep (stale file, edited spec)
/// fails loudly instead of silently skipping the wrong cells.
pub fn resume_point(sweep: &SweepSpec, path: &Path) -> Result<usize> {
    let done = completed_runs(path)?;
    if done == 0 {
        return Ok(0);
    }
    let cells = sweep.cells()?;
    ensure!(
        done <= cells.len(),
        "sweep {}: the JSONL stream {} records {done} completed runs but the grid has only \
         {} cells — it belongs to a different sweep; use a fresh --jsonl or drop --resume",
        sweep.name,
        path.display(),
        cells.len()
    );
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading JSONL stream {}: {e}", path.display()))?;
    let labels: Vec<String> = text
        .lines()
        .filter_map(|line| {
            let j = Json::parse(line).ok()?;
            if j.get("event").and_then(Json::as_str) != Some("run_start") {
                return None;
            }
            j.get("label").and_then(Json::as_str).map(String::from)
        })
        .collect();
    for (j, (_, spec)) in cells.iter().take(done).enumerate() {
        // the orchestrator's run label: "{algo}-{method}-{clients}c"
        let expected =
            format!("{}-{}-{}c", spec.algo.label(), spec.method.name(), spec.data.clients);
        if let Some(actual) = labels.get(j) {
            ensure!(
                *actual == expected,
                "sweep {}: completed run {} in {} is '{actual}' but this grid's cell there \
                 is '{expected}' — the stream belongs to a different sweep; use a fresh \
                 --jsonl or drop --resume",
                sweep.name,
                j + 1,
                path.display()
            );
        }
    }
    Ok(done)
}

/// Render a Json override value without string quotes.
pub fn fmt_value(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

fn describe(overrides: &[(String, Json)]) -> String {
    overrides
        .iter()
        .map(|(k, v)| format!("{k}={}", fmt_value(v)))
        .collect::<Vec<_>>()
        .join(", ")
}

/// The generic sweep report (`feds sweep --spec`): one row per cell with
/// the axis values and the headline metrics.
pub fn grid_report(grid: &SweepGrid) -> Report {
    let mut header: Vec<&str> = grid.axis_keys.iter().map(|s| s.as_str()).collect();
    header.extend(["MRR", "Hits@10", "R@CG", "params@CG", "bytes@CG"]);
    let mut t = MdTable::new(&header);
    let mut raw = Vec::new();
    for cell in &grid.cells {
        let h = &cell.outcome.history;
        let mut row: Vec<String> =
            cell.overrides.iter().map(|(_, v)| fmt_value(v)).collect();
        row.extend([
            fmt4(h.mrr_cg()),
            fmt4(h.hits10_cg()),
            h.rounds_cg().to_string(),
            h.params_cg().to_string(),
            h.converged().bytes_cum.to_string(),
        ]);
        t.row(row);
        let mut over = Json::obj();
        for (k, v) in &cell.overrides {
            over = over.set(k, v.clone());
        }
        raw.push(
            Json::obj()
                .set("overrides", over)
                .set("mrr", h.mrr_cg())
                .set("hits10", h.hits10_cg())
                .set("rounds_cg", h.rounds_cg())
                .set("params_cg", h.params_cg())
                .set("params_total", cell.outcome.acct.params())
                .set("bytes_total", cell.outcome.acct.bytes())
                .set("messages", cell.outcome.acct.messages()),
        );
    }
    let desc = if grid.start > 0 {
        // a resumed grid holds only this invocation's cells; the earlier
        // cells' events live in the original JSONL stream
        format!(
            "Sweep {} — resumed at cell {}: rows {}..{} of {} (earlier rows in the \
             sweep's JSONL stream)",
            grid.name,
            grid.start + 1,
            grid.start + 1,
            grid.start + grid.cells.len(),
            grid.start + grid.cells.len()
        )
    } else {
        format!("Sweep {} — {} cells", grid.name, grid.cells.len())
    };
    let mut rep = Report::new(&grid.name, &desc);
    rep.table("Grid", t);
    rep.raw = Json::obj().set("cells", Json::Arr(raw));
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fed::ExecMode;
    use crate::kge::Method;
    use crate::spec::{AlgoSpec, BackendSpec, BudgetSpec, DataSpec};

    fn base() -> ExperimentSpec {
        ExperimentSpec {
            name: "t".into(),
            method: Method::TransE,
            algo: AlgoSpec::FedEP,
            data: DataSpec {
                entities: 192,
                relations: 12,
                triples: 2400,
                clusters: 4,
                clients: 3,
                seed: 7,
            },
            backend: BackendSpec::Native {
                dim: 16,
                learning_rate: 5e-3,
                batch: 64,
                negatives: 16,
                eval_batch: 32,
            },
            budget: BudgetSpec {
                max_rounds: 4,
                local_epochs: 1,
                eval_every: 2,
                patience: 3,
                eval_cap: 32,
            },
            seed: 7,
            exec: ExecMode::Sequential,
            transport: Default::default(),
            shards: 0,
            participation: Default::default(),
            storage: Default::default(),
            compression: Default::default(),
        }
    }

    #[test]
    fn cells_enumerate_row_major_last_axis_fastest() {
        let sweep = SweepSpec::new("s", base())
            .axis("data.clients", vec![Json::from(3usize), Json::from(4usize)])
            .axis("algo", vec![Json::from("fedep"), Json::from("feds")]);
        let cells = sweep.cells().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].1.data.clients, 3);
        assert_eq!(cells[0].1.algo, AlgoSpec::FedEP);
        assert_eq!(cells[1].1.data.clients, 3);
        assert_eq!(cells[1].1.algo, AlgoSpec::feds());
        assert_eq!(cells[2].1.data.clients, 4);
        assert_eq!(cells[2].1.algo, AlgoSpec::FedEP);
        assert_eq!(cells[3].1.data.clients, 4);
        assert_eq!(cells[3].1.algo, AlgoSpec::feds());
    }

    #[test]
    fn no_axes_yields_the_base_cell() {
        let sweep = SweepSpec::new("s", base());
        let cells = sweep.cells().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].1, base());
    }

    #[test]
    fn json_round_trip() {
        let sweep = SweepSpec::new("rt", base())
            .axis("method", vec![Json::from("transe"), Json::from("rotate")])
            .axis("algo.sparsity", vec![Json::Num(0.2), Json::Num(0.4)]);
        // algo.sparsity on a fedep base is invalid — swap the base algo
        let mut sweep = sweep;
        sweep.base.algo = AlgoSpec::feds();
        let rt = SweepSpec::parse(&sweep.to_json().to_string_pretty()).unwrap();
        assert_eq!(sweep, rt);
    }

    #[test]
    fn bad_axis_key_rejected_at_parse() {
        let sweep = SweepSpec::new("bad", base()).axis("nope", vec![Json::Num(1.0)]);
        let text = sweep.to_json().to_string();
        assert!(SweepSpec::parse(&text).is_err());
    }

    #[test]
    fn scoped_axis_on_wrong_family_rejected() {
        // base algo is fedep: a sparsity axis must fail loudly
        let sweep = SweepSpec::new("bad", base()).axis("algo.sparsity", vec![Json::Num(0.3)]);
        assert!(sweep.cells().is_err());
    }

    #[test]
    fn completed_runs_counts_only_intact_run_end_lines() {
        let dir = std::env::temp_dir().join("feds_completed_runs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.jsonl");
        assert_eq!(completed_runs(&dir.join("missing.jsonl")).unwrap(), 0);
        std::fs::write(
            &path,
            concat!(
                "{\"event\": \"run_start\", \"label\": \"a\"}\n",
                "{\"event\": \"run_end\", \"params\": 1}\n",
                "{\"event\": \"evaluated\", \"round\": 2}\n",
                "{\"event\": \"run_end\", \"params\": 2}\n",
                "{\"event\": \"run_en", // truncated by a crash: not counted
            ),
        )
        .unwrap();
        assert_eq!(completed_runs(&path).unwrap(), 2);
    }

    /// Re-running a half-finished sweep executes only the missing cells:
    /// the first invocation covers a 2-cell prefix of a 4-cell grid, the
    /// resumed invocation skips those and completes the JSONL stream.
    #[test]
    fn resumed_sweep_executes_only_missing_cells() {
        use crate::metrics::observe::JsonlSink;

        let algos = vec![
            Json::from("single"),
            Json::from("fedep"),
            Json::from("fedepl"),
            Json::from("feds"),
        ];
        let sweep = SweepSpec::new("resume", base()).axis("algo", algos.clone());
        assert_eq!(sweep.len(), 4);

        let dir = std::env::temp_dir().join("feds_sweep_resume_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("resume.jsonl");
        let mut session = Session::new();

        // "interrupted" first attempt: only the first two cells ran
        let mut half = sweep.clone();
        half.axes[0].values.truncate(2);
        {
            let mut sink = JsonlSink::create(&path).unwrap();
            run_sweep(&mut session, &half, &mut [&mut sink]).unwrap();
        }
        assert_eq!(completed_runs(&path).unwrap(), 2);

        // resume the full grid: exactly the two missing cells execute.
        // (resume_point also validates the completed runs' labels against
        // the grid's cells — same algo axis prefix, so it passes here.)
        let skip = resume_point(&sweep, &path).unwrap();
        let grid = {
            let mut sink = JsonlSink::append(&path).unwrap();
            run_sweep_from(&mut session, &sweep, skip, &mut [&mut sink]).unwrap()
        };
        assert_eq!(grid.start, 2);
        assert_eq!(grid.cells.len(), 2, "only the missing cells run");
        assert_eq!(grid.cells[0].overrides, vec![("algo".to_string(), algos[2].clone())]);
        assert_eq!(grid.cells[1].overrides, vec![("algo".to_string(), algos[3].clone())]);
        assert_eq!(grid.at(&[3]).overrides[0].1, algos[3]);
        assert_eq!(
            completed_runs(&path).unwrap(),
            4,
            "the appended stream now records the whole grid"
        );

        // a fully-complete stream resumes to a no-op
        let done = resume_point(&sweep, &path).unwrap();
        assert_eq!(done, 4);
        let grid = run_sweep_from(&mut session, &sweep, done, &mut []).unwrap();
        assert!(grid.cells.is_empty());
        // more run_ends than cells is a stale/mismatched stream — an error
        assert!(run_sweep_from(&mut session, &sweep, 5, &mut []).is_err());
    }

    /// `--resume` must refuse a JSONL stream whose completed runs don't
    /// match the grid's cells (a stale file or an edited spec), instead
    /// of silently skipping the wrong cells.
    #[test]
    fn resume_rejects_a_stream_from_a_different_sweep() {
        let sweep = SweepSpec::new("mismatch", base())
            .axis("algo", vec![Json::from("fedep"), Json::from("feds")]);
        let dir = std::env::temp_dir().join("feds_resume_mismatch_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stale.jsonl");

        // a foreign run (different algo/method/clients) claims cell 1
        std::fs::write(
            &path,
            concat!(
                "{\"event\": \"run_start\", \"label\": \"FedS-rotate-9c\", ",
                "\"clients\": 9, \"width\": 4}\n",
                "{\"event\": \"run_end\", \"params\": 1, \"bytes\": 2, \"messages\": 3}\n",
            ),
        )
        .unwrap();
        assert!(resume_point(&sweep, &path).is_err());

        // the matching label passes: cell 1 of this grid is FedEP-transe-3c
        std::fs::write(
            &path,
            concat!(
                "{\"event\": \"run_start\", \"label\": \"FedEP-transe-3c\", ",
                "\"clients\": 3, \"width\": 32}\n",
                "{\"event\": \"run_end\", \"params\": 1, \"bytes\": 2, \"messages\": 3}\n",
            ),
        )
        .unwrap();
        assert_eq!(resume_point(&sweep, &path).unwrap(), 1);

        // more completed runs than grid cells: a different sweep entirely
        let mut many = String::new();
        for _ in 0..3 {
            many.push_str("{\"event\": \"run_start\", \"label\": \"FedEP-transe-3c\"}\n");
            many.push_str("{\"event\": \"run_end\", \"params\": 1}\n");
        }
        std::fs::write(&path, many).unwrap();
        assert!(resume_point(&sweep, &path).is_err());
    }
}
