//! Analytic link model: turn transmitted bytes into wall-clock estimates
//! for bandwidth-constrained edge links (the deployment scenario motivating
//! the paper's §I).  Round time = max over clients of per-client link time,
//! since uploads happen in parallel across clients.

#[derive(Clone, Copy, Debug)]
pub struct BandwidthModel {
    /// link rate in bytes/second (per client)
    pub bytes_per_sec: f64,
    /// per-message latency in seconds
    pub latency_s: f64,
}

impl BandwidthModel {
    /// 10 Mbit/s, 20 ms RTT — a constrained edge uplink.
    pub fn edge() -> Self {
        Self { bytes_per_sec: 10e6 / 8.0, latency_s: 0.02 }
    }

    /// 1 Gbit/s, 1 ms — datacenter baseline.
    pub fn datacenter() -> Self {
        Self { bytes_per_sec: 1e9 / 8.0, latency_s: 0.001 }
    }

    pub fn time_for(&self, bytes: u64, messages: u64) -> f64 {
        bytes as f64 / self.bytes_per_sec + messages as f64 * self.latency_s
    }

    /// Time for one round where each client moves `per_client_bytes[i]`
    /// in `msgs` messages, links operating in parallel.
    pub fn round_time(&self, per_client_bytes: &[u64], msgs_per_client: u64) -> f64 {
        per_client_bytes
            .iter()
            .map(|&b| self.time_for(b, msgs_per_client))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_scales_linearly() {
        let m = BandwidthModel { bytes_per_sec: 1000.0, latency_s: 0.5 };
        assert!((m.time_for(2000, 2) - (2.0 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn round_time_is_max() {
        let m = BandwidthModel { bytes_per_sec: 1000.0, latency_s: 0.0 };
        assert!((m.round_time(&[1000, 5000, 2000], 1) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn presets_sane() {
        assert!(BandwidthModel::edge().bytes_per_sec < BandwidthModel::datacenter().bytes_per_sec);
    }
}
