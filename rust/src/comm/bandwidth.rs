//! Link timing: the analytic [`BandwidthModel`] turns transmitted bytes
//! into wall-clock estimates for bandwidth-constrained edge links (the
//! deployment scenario motivating the paper's §I); [`Throttle`] enforces
//! the same model on a live stream so a loopback cluster run *measures*
//! that wall-clock instead of predicting it; [`RoundTimes`] accumulates
//! the per-round measurements the cluster server reports into
//! `BENCH_cluster.json`.  Round time = max over clients of per-client
//! link time, since uploads happen in parallel across clients.

use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BandwidthModel {
    /// link rate in bytes/second (per client)
    pub bytes_per_sec: f64,
    /// per-message latency in seconds
    pub latency_s: f64,
}

impl BandwidthModel {
    /// 10 Mbit/s, 20 ms RTT — a constrained edge uplink.
    pub fn edge() -> Self {
        Self { bytes_per_sec: 10e6 / 8.0, latency_s: 0.02 }
    }

    /// 1 Gbit/s, 1 ms — datacenter baseline.
    pub fn datacenter() -> Self {
        Self { bytes_per_sec: 1e9 / 8.0, latency_s: 0.001 }
    }

    pub fn time_for(&self, bytes: u64, messages: u64) -> f64 {
        bytes as f64 / self.bytes_per_sec + messages as f64 * self.latency_s
    }

    /// Time for one round where each client moves `per_client_bytes[i]`
    /// in `msgs` messages, links operating in parallel.
    pub fn round_time(&self, per_client_bytes: &[u64], msgs_per_client: u64) -> f64 {
        per_client_bytes
            .iter()
            .map(|&b| self.time_for(b, msgs_per_client))
            .fold(0.0, f64::max)
    }
}

/// Enforce a [`BandwidthModel`] on a live link: the transport's writer
/// calls [`Throttle::pace`] before each frame, sleeping for the model's
/// transmission time, so the modeled latency becomes measured latency.
#[derive(Clone, Copy, Debug)]
pub struct Throttle {
    model: BandwidthModel,
}

impl Throttle {
    pub fn new(model: BandwidthModel) -> Self {
        Self { model }
    }

    /// Block for as long as `model` says a `bytes`-byte message occupies
    /// the link (serialization delay + per-message latency).
    pub fn pace(&self, bytes: usize) {
        let s = self.model.time_for(bytes as u64, 1);
        if s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(s));
        }
    }
}

/// Measured wall-clock per round.  The cluster server brackets each
/// round — local training through the last download — with
/// [`RoundTimes::start`]/[`RoundTimes::stop`]; totals feed
/// `BENCH_cluster.json`, where FedS vs dense shows up as latency rather
/// than bytes.
#[derive(Default)]
pub struct RoundTimes {
    open: Option<Instant>,
    /// seconds per completed round, in round order
    pub secs: Vec<f64>,
}

impl RoundTimes {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        self.open = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t) = self.open.take() {
            self.secs.push(t.elapsed().as_secs_f64());
        }
    }

    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.secs.is_empty() {
            0.0
        } else {
            self.total() / self.secs.len() as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.secs.iter().fold(0.0, |a, &b| f64::max(a, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_scales_linearly() {
        let m = BandwidthModel { bytes_per_sec: 1000.0, latency_s: 0.5 };
        assert!((m.time_for(2000, 2) - (2.0 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn round_time_is_max() {
        let m = BandwidthModel { bytes_per_sec: 1000.0, latency_s: 0.0 };
        assert!((m.round_time(&[1000, 5000, 2000], 1) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn presets_sane() {
        assert!(BandwidthModel::edge().bytes_per_sec < BandwidthModel::datacenter().bytes_per_sec);
    }

    #[test]
    fn throttle_sleeps_for_the_modeled_time() {
        // 1 MB/s + 10 ms latency: a 10 kB message should take ≥ 20 ms
        let t = Throttle::new(BandwidthModel { bytes_per_sec: 1e6, latency_s: 0.01 });
        let start = Instant::now();
        t.pace(10_000);
        assert!(start.elapsed() >= Duration::from_millis(19));
    }

    #[test]
    fn round_times_accumulate() {
        let mut rt = RoundTimes::new();
        assert_eq!(rt.mean(), 0.0);
        rt.start();
        std::thread::sleep(Duration::from_millis(5));
        rt.stop();
        rt.stop(); // unbalanced stop is a no-op
        assert_eq!(rt.secs.len(), 1);
        assert!(rt.total() > 0.0);
        assert!(rt.max() >= rt.mean());
    }
}
