//! Transmission accounting, in both the paper's unit (parameters) and
//! realistic bytes.
//!
//! Paper convention (§III-F, Eq. 5): every transmitted value — embedding
//! floats, sign-vector elements, priority-weight entries — counts as one
//! parameter ("both elements of sign vector and entity embedding use the
//! same data type (usually a 32-bit float) in the formula").  The byte
//! counters instead measure the actual wire encoding (bit-packed signs).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// client → server
    Upload,
    /// server → client
    Download,
}

#[derive(Debug, Default)]
pub struct Accounting {
    up_params: AtomicU64,
    down_params: AtomicU64,
    up_bytes: AtomicU64,
    down_bytes: AtomicU64,
    messages: AtomicU64,
}

impl Accounting {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn record(&self, dir: Direction, params: u64, bytes: u64) {
        match dir {
            Direction::Upload => {
                self.up_params.fetch_add(params, Ordering::Relaxed);
                self.up_bytes.fetch_add(bytes, Ordering::Relaxed);
            }
            Direction::Download => {
                self.down_params.fetch_add(params, Ordering::Relaxed);
                self.down_bytes.fetch_add(bytes, Ordering::Relaxed);
            }
        }
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    pub fn params(&self) -> u64 {
        self.up_params.load(Ordering::Relaxed) + self.down_params.load(Ordering::Relaxed)
    }

    pub fn params_dir(&self, dir: Direction) -> u64 {
        match dir {
            Direction::Upload => self.up_params.load(Ordering::Relaxed),
            Direction::Download => self.down_params.load(Ordering::Relaxed),
        }
    }

    pub fn bytes(&self) -> u64 {
        self.up_bytes.load(Ordering::Relaxed) + self.down_bytes.load(Ordering::Relaxed)
    }

    pub fn bytes_dir(&self, dir: Direction) -> u64 {
        match dir {
            Direction::Upload => self.up_bytes.load(Ordering::Relaxed),
            Direction::Download => self.down_bytes.load(Ordering::Relaxed),
        }
    }

    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    /// Checkpoint restore: seed every counter to an exact prior
    /// snapshot (unlike [`record`], no message increment happens).
    ///
    /// [`record`]: Accounting::record
    pub fn preload(
        &self,
        up_params: u64,
        down_params: u64,
        up_bytes: u64,
        down_bytes: u64,
        messages: u64,
    ) {
        self.up_params.store(up_params, Ordering::Relaxed);
        self.down_params.store(down_params, Ordering::Relaxed);
        self.up_bytes.store(up_bytes, Ordering::Relaxed);
        self.down_bytes.store(down_bytes, Ordering::Relaxed);
        self.messages.store(messages, Ordering::Relaxed);
    }

    pub fn reset(&self) {
        self.up_params.store(0, Ordering::Relaxed);
        self.down_params.store(0, Ordering::Relaxed);
        self.up_bytes.store(0, Ordering::Relaxed);
        self.down_bytes.store(0, Ordering::Relaxed);
        self.messages.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_by_direction() {
        let a = Accounting::new();
        a.record(Direction::Upload, 100, 400);
        a.record(Direction::Download, 50, 200);
        a.record(Direction::Upload, 10, 40);
        assert_eq!(a.params_dir(Direction::Upload), 110);
        assert_eq!(a.params_dir(Direction::Download), 50);
        assert_eq!(a.params(), 160);
        assert_eq!(a.bytes(), 640);
        assert_eq!(a.messages(), 3);
    }

    #[test]
    fn reset_clears() {
        let a = Accounting::new();
        a.record(Direction::Upload, 1, 1);
        a.reset();
        assert_eq!(a.params(), 0);
        assert_eq!(a.messages(), 0);
    }

    #[test]
    fn shared_across_threads() {
        let a = Accounting::new();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let a = a.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        a.record(Direction::Upload, 1, 4);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.params(), 400);
    }
}
