//! Binary wire codec: little-endian primitives, length-prefixed vectors,
//! and bit-packed 0/1 sign vectors.
//!
//! Every protocol message serializes through this codec, so the byte
//! accounting measures exactly what a real deployment would put on the
//! network (embeddings as raw f32, sign vectors as ceil(N/8) bytes).

#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u32s(&mut self, v: &[u32]) -> &mut Self {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self
    }

    pub fn f32s(&mut self, v: &[f32]) -> &mut Self {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self
    }

    /// Bit-packed 0/1 sign vector (the realistic encoding the paper notes
    /// "may utilize a 1-bit data type").
    pub fn bits(&mut self, v: &[bool]) -> &mut Self {
        self.u32(v.len() as u32);
        let mut byte = 0u8;
        for (i, &b) in v.iter().enumerate() {
            if b {
                byte |= 1 << (i % 8);
            }
            if i % 8 == 7 {
                self.buf.push(byte);
                byte = 0;
            }
        }
        if v.len() % 8 != 0 {
            self.buf.push(byte);
        }
        self
    }

    /// Opaque length-prefixed byte blob (e.g. a nested, already-encoded
    /// protocol frame carried inside a cluster envelope).
    pub fn blob(&mut self, v: &[u8]) -> &mut Self {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            anyhow::bail!(
                "wire underrun: need {n} bytes at {} of {}",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn u32s(&mut self) -> anyhow::Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn bits(&mut self) -> anyhow::Result<Vec<bool>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.div_ceil(8))?;
        Ok((0..n).map(|i| raw[i / 8] & (1 << (i % 8)) != 0).collect())
    }

    /// Opaque length-prefixed byte blob (mirror of [`WireWriter::blob`]).
    pub fn blob(&mut self) -> anyhow::Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

// --- stream framing ---------------------------------------------------------
//
// Message boundaries for byte-stream transports (`comm::transport::tcp`):
// each frame travels as a u32le length followed by the payload.  The
// 4-byte prefix is transport overhead, NOT part of the metered frame —
// accounting records the payload size only, so byte totals are identical
// across transports (see PERF.md "Transport overhead").

/// Write one length-prefixed frame to a byte stream.
pub fn write_frame<W: std::io::Write>(w: &mut W, frame: &[u8]) -> std::io::Result<()> {
    w.write_all(&(frame.len() as u32).to_le_bytes())?;
    w.write_all(frame)
}

/// Largest frame `read_frame` will accept.  Real frames top out at tens
/// of megabytes (a dense upload of every shared row); a prefix beyond
/// this bound means the stream desynchronized, and must surface as an
/// error instead of a multi-gigabyte allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// How reading a frame can fail.  A clean EOF at a frame boundary is NOT
/// an error (`read_frame` returns `Ok(None)`); these variants classify
/// everything else, so a dropout detector can tell a peer that hung up
/// gracefully from one that died mid-frame or desynchronized the stream.
#[derive(Debug)]
pub enum FrameError {
    /// EOF in the middle of a length prefix or payload: the peer vanished
    /// mid-frame (crash, kill, connection reset at an unlucky moment).
    Truncated {
        /// where in the frame the stream cut off
        context: &'static str,
    },
    /// A length prefix beyond [`MAX_FRAME_BYTES`]: the stream is no longer
    /// aligned on frame boundaries (protocol bug or corruption).
    Desync { claimed_len: u64 },
    /// Any other transport-level IO failure.
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated { context } => write!(f, "stream truncated {context}"),
            FrameError::Desync { claimed_len } => write!(
                f,
                "frame length {claimed_len} exceeds the {MAX_FRAME_BYTES}-byte cap (stream desync)"
            ),
            FrameError::Io(e) => write!(f, "frame read failed: {e}"),
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

/// Read one length-prefixed frame from a byte stream, tolerating
/// arbitrarily short `read()`s.  Returns `Ok(None)` on a clean EOF at a
/// frame boundary; EOF inside a frame is [`FrameError::Truncated`], a
/// length prefix beyond [`MAX_FRAME_BYTES`] is [`FrameError::Desync`].
pub fn read_frame<R: std::io::Read>(r: &mut R) -> Result<Option<Vec<u8>>, FrameError> {
    let mut len = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(FrameError::Truncated { context: "inside a frame length prefix" }),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME_BYTES {
        return Err(FrameError::Desync { claimed_len: n as u64 });
    }
    let mut buf = vec![0u8; n];
    if let Err(e) = r.read_exact(&mut buf) {
        return Err(if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FrameError::Truncated { context: "inside a frame payload" }
        } else {
            FrameError::Io(e)
        });
    }
    Ok(Some(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn primitives_roundtrip() {
        let mut w = WireWriter::new();
        w.u8(7).u16(300).u32(70_000).u64(1 << 40).f32(-2.5).f64(0.125);
        let buf = w.finish();
        let mut r = WireReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), -2.5);
        assert_eq!(r.f64().unwrap(), 0.125);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn vectors_roundtrip_property() {
        check("wire_vecs", 40, |rng| {
            let n = rng.usize_below(50);
            let us: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            let fs: Vec<f32> = (0..n).map(|_| rng.uniform(-10.0, 10.0)).collect();
            let bs: Vec<bool> = (0..rng.usize_below(70)).map(|_| rng.bool(0.5)).collect();
            let mut w = WireWriter::new();
            w.u32s(&us).f32s(&fs).bits(&bs);
            let buf = w.finish();
            let mut r = WireReader::new(&buf);
            assert_eq!(r.u32s().unwrap(), us);
            assert_eq!(r.f32s().unwrap(), fs);
            assert_eq!(r.bits().unwrap(), bs);
        });
    }

    #[test]
    fn bits_pack_tightly() {
        let v = vec![true; 16];
        let mut w = WireWriter::new();
        w.bits(&v);
        // 4-byte length + 2 payload bytes
        assert_eq!(w.len(), 6);
    }

    #[test]
    fn underrun_is_error() {
        let buf = [1u8, 2];
        let mut r = WireReader::new(&buf);
        assert!(r.u32().is_err());
    }

    #[test]
    fn f32s_byte_size_is_4n_plus_len() {
        let mut w = WireWriter::new();
        w.f32s(&[0.0; 100]);
        assert_eq!(w.len(), 4 + 400);
    }

    /// A `Read` that yields at most `cap` bytes per call — the shortest
    /// reads a stream socket could legally produce.
    pub(crate) struct ChunkedReader<'a> {
        buf: &'a [u8],
        pos: usize,
        cap: usize,
    }

    impl<'a> ChunkedReader<'a> {
        pub(crate) fn new(buf: &'a [u8], cap: usize) -> Self {
            Self { buf, pos: 0, cap: cap.max(1) }
        }
    }

    impl std::io::Read for ChunkedReader<'_> {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            let n = out.len().min(self.cap).min(self.buf.len() - self.pos);
            out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn frames_roundtrip_under_short_reads() {
        let frames: Vec<Vec<u8>> = vec![vec![], vec![7], (0..=255).collect()];
        let mut stream = Vec::new();
        for f in &frames {
            write_frame(&mut stream, f).unwrap();
        }
        for cap in [1usize, 2, 3, 7, 1024] {
            let mut r = ChunkedReader::new(&stream, cap);
            for f in &frames {
                assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some(&f[..]), "cap {cap}");
            }
            assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF at a boundary");
        }
    }

    #[test]
    fn frame_eof_inside_length_or_payload_is_truncation_not_clean_eof() {
        let mut stream = Vec::new();
        write_frame(&mut stream, &[1, 2, 3, 4, 5]).unwrap();
        // cut inside the length prefix and inside the payload
        for cut in [1usize, 3, 6] {
            let mut r = ChunkedReader::new(&stream[..cut], 2);
            let err = read_frame(&mut r).unwrap_err();
            assert!(
                matches!(err, FrameError::Truncated { .. }),
                "cut {cut} must classify as truncation, got {err:?}"
            );
        }
    }

    #[test]
    fn absurd_frame_length_is_a_desync_not_an_allocation() {
        // a desynced stream handing us a ~4 GiB length prefix
        let bogus = u32::MAX.to_le_bytes();
        let mut r = ChunkedReader::new(&bogus, 4);
        let err = read_frame(&mut r).unwrap_err();
        assert!(matches!(err, FrameError::Desync { .. }), "{err:?}");
    }
}
