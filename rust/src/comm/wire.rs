//! Binary wire codec: little-endian primitives, length-prefixed vectors,
//! and bit-packed 0/1 sign vectors.
//!
//! Every protocol message serializes through this codec, so the byte
//! accounting measures exactly what a real deployment would put on the
//! network (embeddings as raw f32, sign vectors as ceil(N/8) bytes).

#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u32s(&mut self, v: &[u32]) -> &mut Self {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self
    }

    pub fn f32s(&mut self, v: &[f32]) -> &mut Self {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self
    }

    /// Bit-packed 0/1 sign vector (the realistic encoding the paper notes
    /// "may utilize a 1-bit data type").
    pub fn bits(&mut self, v: &[bool]) -> &mut Self {
        self.u32(v.len() as u32);
        let mut byte = 0u8;
        for (i, &b) in v.iter().enumerate() {
            if b {
                byte |= 1 << (i % 8);
            }
            if i % 8 == 7 {
                self.buf.push(byte);
                byte = 0;
            }
        }
        if v.len() % 8 != 0 {
            self.buf.push(byte);
        }
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            anyhow::bail!(
                "wire underrun: need {n} bytes at {} of {}",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u32s(&mut self) -> anyhow::Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn bits(&mut self) -> anyhow::Result<Vec<bool>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.div_ceil(8))?;
        Ok((0..n).map(|i| raw[i / 8] & (1 << (i % 8)) != 0).collect())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn primitives_roundtrip() {
        let mut w = WireWriter::new();
        w.u8(7).u16(300).u32(70_000).u64(1 << 40).f32(-2.5);
        let buf = w.finish();
        let mut r = WireReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), -2.5);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn vectors_roundtrip_property() {
        check("wire_vecs", 40, |rng| {
            let n = rng.usize_below(50);
            let us: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            let fs: Vec<f32> = (0..n).map(|_| rng.uniform(-10.0, 10.0)).collect();
            let bs: Vec<bool> = (0..rng.usize_below(70)).map(|_| rng.bool(0.5)).collect();
            let mut w = WireWriter::new();
            w.u32s(&us).f32s(&fs).bits(&bs);
            let buf = w.finish();
            let mut r = WireReader::new(&buf);
            assert_eq!(r.u32s().unwrap(), us);
            assert_eq!(r.f32s().unwrap(), fs);
            assert_eq!(r.bits().unwrap(), bs);
        });
    }

    #[test]
    fn bits_pack_tightly() {
        let v = vec![true; 16];
        let mut w = WireWriter::new();
        w.bits(&v);
        // 4-byte length + 2 payload bytes
        assert_eq!(w.len(), 6);
    }

    #[test]
    fn underrun_is_error() {
        let buf = [1u8, 2];
        let mut r = WireReader::new(&buf);
        assert!(r.u32().is_err());
    }

    #[test]
    fn f32s_byte_size_is_4n_plus_len() {
        let mut w = WireWriter::new();
        w.f32s(&[0.0; 100]);
        assert_eq!(w.len(), 4 + 400);
    }
}
