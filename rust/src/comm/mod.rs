//! Simulated client↔server communication substrate.
//!
//! The paper's efficiency metrics count *transmitted parameters*; real
//! deployments care about bytes and wall-clock under constrained links.
//! This module provides all three views:
//!
//! * `wire` — a compact binary codec for the protocol messages (sign
//!   vectors as bitmaps, embeddings as raw f32le), giving exact byte sizes;
//! * `accounting` — per-client, per-direction parameter AND byte counters,
//!   with the paper's convention (every sign-vector element counts as one
//!   f32 parameter, Eq. 5) kept separate from the realistic byte count;
//! * `transport` — the metered [`transport::Endpoint`] trait with two
//!   implementations: in-process mpsc duplex links and length-prefixed
//!   TCP loopback sockets, selected per run by [`transport::TransportSpec`]
//!   with bit-identical accounting either way;
//! * `bandwidth` — an analytic link model to turn bytes into seconds,
//!   plus a [`bandwidth::Throttle`] that enforces the model on live
//!   sockets so cluster runs *measure* that wall-clock.

pub mod accounting;
pub mod bandwidth;
pub mod transport;
pub mod wire;

pub use accounting::{Accounting, Direction};
pub use bandwidth::{BandwidthModel, RoundTimes, Throttle};
pub use transport::{duplex, Disconnect, Endpoint, TransportSpec};
pub use wire::{WireReader, WireWriter};
