//! Length-prefixed TCP loopback transport.
//!
//! [`TcpTransport`] owns the server-side listener; [`connect_pair`]
//! establishes one real socket per client and returns the two
//! [`TcpEndpoint`] halves.  Frames travel as `comm::wire::write_frame`
//! length-prefixed payloads; metering records the **payload** bytes only
//! (the 4-byte prefix is transport overhead), so accounting is
//! bit-identical to the in-process [`super::mpsc`] links.
//!
//! Each endpoint runs two daemon threads:
//! * a **reader** that reassembles frames from the stream (tolerating
//!   arbitrarily short `read()`s) and queues them for `recv` — on EOF or
//!   a broken stream it closes the queue, preserving drain-then-error
//!   delivery of everything already received;
//! * a **writer** that drains an outbox onto the socket, so `send` never
//!   blocks on the peer.  Without it, single-threaded (sequential-mode)
//!   drivers could deadlock once a frame outgrew the kernel's socket
//!   buffers.  When the endpoint drops, the writer flushes the outbox
//!   and shuts down the write half, which is the peer's EOF.
//!
//! [`connect_pair`]: TcpTransport::connect_pair

use std::io::Write as _;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::super::accounting::{Accounting, Direction};
use super::super::bandwidth::Throttle;
use super::super::wire::{read_frame, write_frame, FrameError};
use super::{Disconnect, Endpoint, FrameQueue};

/// The server side's listener: one of these per run, one accepted
/// connection per client.
pub struct TcpTransport {
    listener: TcpListener,
    addr: SocketAddr,
}

impl TcpTransport {
    /// Bind an ephemeral loopback port.
    pub fn bind_loopback() -> Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", 0))?;
        let addr = listener.local_addr()?;
        Ok(Self { listener, addr })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Establish one client↔server connection and return
    /// `(client_end, server_end)` sharing `acct` — the TCP analogue of
    /// [`super::mpsc::duplex`].  Pairs must be established one at a time
    /// (concurrent connects would interleave in the accept queue).
    pub fn connect_pair(&self, acct: Arc<Accounting>) -> Result<(TcpEndpoint, TcpEndpoint)> {
        let client_sock = TcpStream::connect(self.addr)?;
        let (server_sock, _peer) = self.listener.accept()?;
        Ok((
            TcpEndpoint::from_stream(client_sock, acct.clone(), Direction::Upload, None)?,
            TcpEndpoint::from_stream(server_sock, acct, Direction::Download, None)?,
        ))
    }

    /// Accept the next incoming connection (blocking) — the cluster
    /// server's accept loop.
    pub fn accept(&self) -> Result<TcpStream> {
        let (sock, _peer) = self.listener.accept()?;
        Ok(sock)
    }
}

/// One side of a socket-backed connection.  Frames sent from the
/// `Direction::Upload` end are recorded as uploads, from the
/// `Direction::Download` end as downloads — exactly the mpsc contract.
pub struct TcpEndpoint {
    outbox: Sender<Vec<u8>>,
    queue: FrameQueue,
    acct: Arc<Accounting>,
    dir: Direction,
    /// set by the writer thread when the stream breaks mid-run
    broken: Arc<AtomicBool>,
    /// set by the reader thread when the peer's stream ends
    disconnect: Arc<Mutex<Option<Disconnect>>>,
}

impl TcpEndpoint {
    /// Wrap an established stream.  `throttle` (when `Some`) rate-limits
    /// the writer to the model's bandwidth and per-message latency, so a
    /// loopback run measures the wall-clock an edge link would show.
    pub fn from_stream(
        sock: TcpStream,
        acct: Arc<Accounting>,
        dir: Direction,
        throttle: Option<Throttle>,
    ) -> Result<Self> {
        sock.set_nodelay(true)?;
        let wsock = sock.try_clone()?;

        let (out_tx, out_rx) = channel::<Vec<u8>>();
        let broken = Arc::new(AtomicBool::new(false));
        let wbroken = broken.clone();
        std::thread::spawn(move || {
            let mut w = std::io::BufWriter::new(wsock);
            for frame in out_rx {
                if let Some(t) = &throttle {
                    // pace before the write: the frame "occupies the link"
                    // for its modeled transmission time
                    t.pace(frame.len() + 4);
                }
                if write_frame(&mut w, &frame).and_then(|()| w.flush()).is_err() {
                    wbroken.store(true, Ordering::Relaxed);
                    break;
                }
            }
            if let Ok(s) = w.into_inner() {
                let _ = s.shutdown(Shutdown::Write);
            }
        });

        let (in_tx, in_rx) = channel::<Vec<u8>>();
        let disconnect = Arc::new(Mutex::new(None));
        let rdisconnect = disconnect.clone();
        std::thread::spawn(move || {
            let mut r = std::io::BufReader::new(sock);
            let why = loop {
                match read_frame(&mut r) {
                    Ok(Some(frame)) => {
                        if in_tx.send(frame).is_err() {
                            return; // endpoint dropped, nobody will recv
                        }
                    }
                    // close the queue either way; frames already delivered
                    // drain before recv errors.  The *kind* of ending is
                    // recorded for dropout detection: a clean EOF at a
                    // frame boundary is a leave, anything else a crash.
                    Ok(None) => break Disconnect::Clean,
                    Err(FrameError::Truncated { .. })
                    | Err(FrameError::Desync { .. })
                    | Err(FrameError::Io(_)) => break Disconnect::Abrupt,
                }
            };
            *rdisconnect.lock().unwrap() = Some(why);
        });

        Ok(Self { outbox: out_tx, queue: FrameQueue::new(in_rx), acct, dir, broken, disconnect })
    }

    /// How the peer's stream ended, once it has (`None` while connected).
    pub fn disconnect_reason(&self) -> Option<Disconnect> {
        *self.disconnect.lock().unwrap()
    }
}

impl Endpoint for TcpEndpoint {
    fn send(&self, frame: Vec<u8>, params: u64) -> Result<()> {
        if self.broken.load(Ordering::Relaxed) {
            anyhow::bail!("peer disconnected");
        }
        self.acct.record(self.dir, params, frame.len() as u64);
        self.outbox
            .send(frame)
            .map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.queue.recv()
    }

    fn recv_timeout(&self, d: Duration) -> Result<Option<Vec<u8>>> {
        self.queue.recv_timeout(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (Arc<Accounting>, TcpEndpoint, TcpEndpoint) {
        let acct = Accounting::new();
        let t = TcpTransport::bind_loopback().unwrap();
        let (c, s) = t.connect_pair(acct.clone()).unwrap();
        (acct, c, s)
    }

    #[test]
    fn roundtrip_and_metering_matches_mpsc_contract() {
        let (acct, client, server) = pair();
        client.send(vec![1, 2, 3], 10).unwrap();
        assert_eq!(server.recv().unwrap(), vec![1, 2, 3]);
        server.send(vec![9; 8], 2).unwrap();
        assert_eq!(client.recv().unwrap(), vec![9; 8]);
        assert_eq!(acct.params_dir(Direction::Upload), 10);
        assert_eq!(acct.params_dir(Direction::Download), 2);
        // metered bytes are the frame payload, not payload + prefix
        assert_eq!(acct.bytes_dir(Direction::Upload), 3);
        assert_eq!(acct.bytes_dir(Direction::Download), 8);
        assert_eq!(acct.messages(), 2);
    }

    #[test]
    fn many_frames_keep_order_and_boundaries() {
        let (_acct, client, server) = pair();
        let frames: Vec<Vec<u8>> = (0..50u8).map(|i| vec![i; i as usize]).collect();
        for f in &frames {
            client.send(f.clone(), 1).unwrap();
        }
        for f in &frames {
            assert_eq!(&server.recv().unwrap(), f);
        }
    }

    #[test]
    fn timeout_returns_none() {
        let (_acct, client, _server) = pair();
        assert!(client.recv_timeout(Duration::from_millis(10)).unwrap().is_none());
    }

    /// Drain-then-error over a real socket: everything the peer sent
    /// before hanging up is delivered, then the disconnect surfaces.
    #[test]
    fn queued_frames_survive_peer_disconnect() {
        let (_acct, client, server) = pair();
        client.send(vec![1], 1).unwrap();
        client.send(vec![2, 2], 1).unwrap();
        drop(client); // writer flushes, then EOF
        let d = Duration::from_millis(500);
        assert_eq!(server.recv_timeout(d).unwrap(), Some(vec![1]));
        assert_eq!(server.recv_timeout(d).unwrap(), Some(vec![2, 2]));
        assert!(server.recv().is_err(), "after the drain the hangup surfaces");
    }

    fn wait_disconnect(ep: &TcpEndpoint) -> Disconnect {
        for _ in 0..200 {
            if let Some(d) = ep.disconnect_reason() {
                return d;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("peer disconnect never surfaced");
    }

    #[test]
    fn graceful_shutdown_classifies_as_clean_disconnect() {
        let (_acct, client, server) = pair();
        assert_eq!(server.disconnect_reason(), None, "connected peers report nothing");
        drop(client); // writer flushes, shuts down the write half: EOF at a boundary
        assert_eq!(wait_disconnect(&server), Disconnect::Clean);
    }

    #[test]
    fn mid_frame_death_classifies_as_abrupt_disconnect() {
        let acct = Accounting::new();
        let t = TcpTransport::bind_loopback().unwrap();
        let mut raw = TcpStream::connect(t.addr()).unwrap();
        let sock = t.accept().unwrap();
        let server =
            TcpEndpoint::from_stream(sock, acct, Direction::Download, None).unwrap();
        // a length prefix promising 10 bytes, then only 3 before vanishing
        raw.write_all(&10u32.to_le_bytes()).unwrap();
        raw.write_all(&[1, 2, 3]).unwrap();
        drop(raw);
        assert_eq!(wait_disconnect(&server), Disconnect::Abrupt);
    }

    /// A sequential (single-threaded) driver must be able to push a frame
    /// larger than any kernel socket buffer without deadlocking: the
    /// writer thread decouples `send` from the peer's reads.
    #[test]
    fn large_frame_send_does_not_block_the_caller() {
        let (_acct, client, server) = pair();
        let big = vec![0xABu8; 8 << 20]; // 8 MiB ≫ socket buffers
        client.send(big.clone(), 1).unwrap(); // must return immediately
        let got = server.recv().unwrap();
        assert_eq!(got.len(), big.len());
        assert_eq!(got, big);
    }
}
