//! Metered client↔server transport links.
//!
//! [`Endpoint`] is the seam between the orchestrator and the medium that
//! carries its frames.  Each endpoint pair models one client↔server
//! connection under a single **metering contract**: `send` records the
//! frame's real byte size (and the caller-supplied logical parameter
//! count) into the shared [`Accounting`] *before* the frame leaves, so
//! byte/parameter totals are bit-identical across implementations — the
//! frames are the unit of account, never the medium's own overhead.
//!
//! Two implementations:
//! * [`mpsc`] — in-process duplex links over `std::sync::mpsc` (the
//!   default; zero-copy hand-off of the frame buffer);
//! * [`tcp`] — length-prefixed loopback sockets (`comm::wire::write_frame`
//!   framing; a server listener plus one connection per client), proving
//!   the byte savings on a real stream transport.
//!
//! Receive semantics are **drain-then-error**: once a peer hangs up, any
//! frames it sent before disconnecting are still delivered in order;
//! only after the queue is empty do `recv`/`recv_timeout` report the
//! disconnect.

pub mod mpsc;
pub mod tcp;

pub use mpsc::{duplex, MpscEndpoint};
pub use tcp::{TcpEndpoint, TcpTransport};

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

use anyhow::Result;

/// One side of a metered client↔server connection.  `Send` so threaded
/// execution can move a client's endpoint onto its OS thread.
pub trait Endpoint: Send {
    /// Send a frame, recording `params` logical parameters and the
    /// frame's real byte size into the shared accounting.
    fn send(&self, frame: Vec<u8>, params: u64) -> Result<()>;

    /// Block for the next frame.  After a peer disconnect, queued frames
    /// drain first; only an empty queue reports the hangup.
    fn recv(&self) -> Result<Vec<u8>>;

    /// Wait up to `d` for a frame (`Ok(None)` on timeout), with the same
    /// drain-then-error disconnect semantics as [`Endpoint::recv`].
    fn recv_timeout(&self, d: Duration) -> Result<Option<Vec<u8>>>;
}

/// How a peer link stopped delivering frames.  Dropout detection keys on
/// this: a [`Disconnect::Clean`] is a deliberate leave (the peer shut its
/// write half at a frame boundary), while [`Disconnect::Abrupt`] means the
/// process died mid-frame or the stream desynchronized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Disconnect {
    /// EOF exactly at a frame boundary: a graceful shutdown.
    Clean,
    /// Truncation mid-frame, desync, or a transport IO failure.
    Abrupt,
}

/// Which transport carries a run's frames (the `"transport"` spec field /
/// `--transport` CLI flag).  Byte and parameter accounting are
/// bit-identical across variants for every exchange strategy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportSpec {
    /// In-process `std::sync::mpsc` duplex links (the default).
    #[default]
    Mpsc,
    /// Length-prefixed TCP loopback: one listener on the server side,
    /// one connection per client.
    Tcp,
}

impl TransportSpec {
    pub fn parse(s: &str) -> Result<TransportSpec> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "mpsc" | "inproc" => TransportSpec::Mpsc,
            "tcp" | "socket" => TransportSpec::Tcp,
            other => anyhow::bail!("unknown transport '{other}' (mpsc|tcp)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            TransportSpec::Mpsc => "mpsc",
            TransportSpec::Tcp => "tcp",
        }
    }
}

/// The receive half both endpoint implementations share: an ordered frame
/// queue with drain-then-error disconnect reporting.  Generic so the
/// cluster runtime can queue decoded control messages alongside the
/// default raw-frame payloads.
pub(crate) struct FrameQueue<T = Vec<u8>> {
    rx: Receiver<T>,
}

impl<T> FrameQueue<T> {
    pub(crate) fn new(rx: Receiver<T>) -> Self {
        Self { rx }
    }

    pub(crate) fn recv(&self) -> Result<T> {
        // std mpsc already drains buffered messages before reporting the
        // hangup on a blocking recv
        self.rx.recv().map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    pub(crate) fn recv_timeout(&self, d: Duration) -> Result<Option<T>> {
        match self.rx.recv_timeout(d) {
            Ok(f) => Ok(Some(f)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            // `recv_timeout` can report Disconnected while frames are
            // still queued (rust-lang/rust#39364); drain before
            // surfacing the hangup so no delivered frame is ever lost.
            Err(RecvTimeoutError::Disconnected) => match self.rx.try_recv() {
                Ok(f) => Ok(Some(f)),
                Err(_) => anyhow::bail!("peer disconnected"),
            },
        }
    }
}
