//! In-process duplex links over `std::sync::mpsc` — the default
//! [`Endpoint`] implementation.  The frame buffer is handed to the peer
//! without copying; metering happens at `send` exactly as on a real
//! transport, so the communication totals are what a distributed
//! deployment would transmit.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::super::accounting::{Accounting, Direction};
use super::{Endpoint, FrameQueue};

pub struct MpscEndpoint {
    tx: Sender<Vec<u8>>,
    queue: FrameQueue,
    acct: Arc<Accounting>,
    dir: Direction,
}

/// Build a connected (client_end, server_end) pair sharing `acct`.
/// Frames sent from the client end are recorded as uploads; frames sent
/// from the server end as downloads.
pub fn duplex(acct: Arc<Accounting>) -> (MpscEndpoint, MpscEndpoint) {
    let (tx_up, rx_up) = channel();
    let (tx_down, rx_down) = channel();
    let client = MpscEndpoint {
        tx: tx_up,
        queue: FrameQueue::new(rx_down),
        acct: acct.clone(),
        dir: Direction::Upload,
    };
    let server = MpscEndpoint {
        tx: tx_down,
        queue: FrameQueue::new(rx_up),
        acct,
        dir: Direction::Download,
    };
    (client, server)
}

impl Endpoint for MpscEndpoint {
    fn send(&self, frame: Vec<u8>, params: u64) -> Result<()> {
        self.acct.record(self.dir, params, frame.len() as u64);
        self.tx
            .send(frame)
            .map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.queue.recv()
    }

    fn recv_timeout(&self, d: Duration) -> Result<Option<Vec<u8>>> {
        self.queue.recv_timeout(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_metering() {
        let acct = Accounting::new();
        let (client, server) = duplex(acct.clone());
        client.send(vec![1, 2, 3], 10).unwrap();
        assert_eq!(server.recv().unwrap(), vec![1, 2, 3]);
        server.send(vec![9; 8], 2).unwrap();
        assert_eq!(client.recv().unwrap(), vec![9; 8]);
        assert_eq!(acct.params_dir(Direction::Upload), 10);
        assert_eq!(acct.params_dir(Direction::Download), 2);
        assert_eq!(acct.bytes_dir(Direction::Upload), 3);
        assert_eq!(acct.bytes_dir(Direction::Download), 8);
    }

    #[test]
    fn cross_thread() {
        let acct = Accounting::new();
        let (client, server) = duplex(acct.clone());
        let h = std::thread::spawn(move || {
            let f = server.recv().unwrap();
            server.send(f, 1).unwrap();
        });
        client.send(vec![42], 1).unwrap();
        assert_eq!(client.recv().unwrap(), vec![42]);
        h.join().unwrap();
    }

    #[test]
    fn timeout_returns_none() {
        let acct = Accounting::new();
        let (client, _server) = duplex(acct);
        let r = client.recv_timeout(Duration::from_millis(10)).unwrap();
        assert!(r.is_none());
    }

    #[test]
    fn disconnected_peer_errors() {
        let acct = Accounting::new();
        let (client, server) = duplex(acct);
        drop(server);
        assert!(client.send(vec![1], 1).is_err());
    }

    /// Regression (drain-then-error): frames queued before the peer hung
    /// up must all be delivered — by `recv` and by `recv_timeout` — and
    /// only an empty queue reports the disconnect.
    #[test]
    fn recv_timeout_drains_queued_frames_after_disconnect() {
        let acct = Accounting::new();
        let (client, server) = duplex(acct);
        client.send(vec![1], 1).unwrap();
        client.send(vec![2], 1).unwrap();
        client.send(vec![3], 1).unwrap();
        drop(client);
        let d = Duration::from_millis(10);
        assert_eq!(server.recv_timeout(d).unwrap(), Some(vec![1]));
        assert_eq!(server.recv().unwrap(), vec![2]);
        assert_eq!(server.recv_timeout(d).unwrap(), Some(vec![3]));
        assert!(server.recv_timeout(d).is_err(), "empty queue reports the hangup");
        assert!(server.recv().is_err());
    }
}
