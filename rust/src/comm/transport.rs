//! Metered in-process duplex links over `std::sync::mpsc`.
//!
//! Each `Endpoint` pair models one client↔server connection: sending a
//! frame records its byte size (and caller-supplied parameter count) into
//! the shared `Accounting`.  Both orchestrator execution modes
//! (`fed::ExecMode`) route every exchanged frame through these links —
//! they are the single metering path, so the communication totals are
//! what a distributed deployment would transmit.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use super::accounting::{Accounting, Direction};

pub struct Endpoint {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    acct: Arc<Accounting>,
    dir: Direction,
}

/// Build a connected (client_end, server_end) pair sharing `acct`.
/// Frames sent from the client end are recorded as uploads; frames sent
/// from the server end as downloads.
pub fn duplex(acct: Arc<Accounting>) -> (Endpoint, Endpoint) {
    let (tx_up, rx_up) = channel();
    let (tx_down, rx_down) = channel();
    let client = Endpoint {
        tx: tx_up,
        rx: rx_down,
        acct: acct.clone(),
        dir: Direction::Upload,
    };
    let server = Endpoint {
        tx: tx_down,
        rx: rx_up,
        acct,
        dir: Direction::Download,
    };
    (client, server)
}

impl Endpoint {
    /// Send a frame, recording `params` logical parameters and the frame's
    /// real byte size.
    pub fn send(&self, frame: Vec<u8>, params: u64) -> anyhow::Result<()> {
        self.acct.record(self.dir, params, frame.len() as u64);
        self.tx
            .send(frame)
            .map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    pub fn recv(&self) -> anyhow::Result<Vec<u8>> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    pub fn recv_timeout(&self, d: Duration) -> anyhow::Result<Option<Vec<u8>>> {
        match self.rx.recv_timeout(d) {
            Ok(f) => Ok(Some(f)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => anyhow::bail!("peer disconnected"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_metering() {
        let acct = Accounting::new();
        let (client, server) = duplex(acct.clone());
        client.send(vec![1, 2, 3], 10).unwrap();
        assert_eq!(server.recv().unwrap(), vec![1, 2, 3]);
        server.send(vec![9; 8], 2).unwrap();
        assert_eq!(client.recv().unwrap(), vec![9; 8]);
        assert_eq!(acct.params_dir(Direction::Upload), 10);
        assert_eq!(acct.params_dir(Direction::Download), 2);
        assert_eq!(acct.bytes_dir(Direction::Upload), 3);
        assert_eq!(acct.bytes_dir(Direction::Download), 8);
    }

    #[test]
    fn cross_thread() {
        let acct = Accounting::new();
        let (client, server) = duplex(acct.clone());
        let h = std::thread::spawn(move || {
            let f = server.recv().unwrap();
            server.send(f, 1).unwrap();
        });
        client.send(vec![42], 1).unwrap();
        assert_eq!(client.recv().unwrap(), vec![42]);
        h.join().unwrap();
    }

    #[test]
    fn timeout_returns_none() {
        let acct = Accounting::new();
        let (client, _server) = duplex(acct);
        let r = client.recv_timeout(Duration::from_millis(10)).unwrap();
        assert!(r.is_none());
    }

    #[test]
    fn disconnected_peer_errors() {
        let acct = Accounting::new();
        let (client, server) = duplex(acct);
        drop(server);
        assert!(client.send(vec![1], 1).is_err());
    }
}
