//! Fault injection for cluster drills: the small set of primitives the
//! robustness tests compose into crash/restore scenarios.
//!
//! Everything here either *causes* a fault at a deterministic point
//! (self-SIGKILL at a round boundary, a connection cut after round N, a
//! truncated checkpoint file) or *shapes* the link so faults get time to
//! land (added latency).  The invariant the test-suite drives with these:
//! every injected fault either recovers bit-identically (checkpoint
//! restore, reconnect backoff) or fails loudly with a typed error —
//! never a hang, never silently wrong numbers.

use std::io;
use std::path::Path;
use std::time::Duration;

use crate::comm::bandwidth::BandwidthModel;

use super::checkpoint::checkpoint_path;
use super::client::ClientOpts;
use super::server::ServeOpts;

/// SIGKILL the current process.  Unlike a panic or `process::exit`, no
/// destructor, socket shutdown, or flush runs — the peer observes an
/// abrupt mid-stream death, exactly what the crash-recovery drills need.
/// Used by [`ServeOpts::kill_after_checkpoint`] so the kill lands at an
/// exact round boundary instead of racing the round loop from outside.
pub fn sigkill_self() -> ! {
    let _ = std::process::Command::new("kill")
        .args(["-9", &std::process::id().to_string()])
        .status();
    // the signal is delivered asynchronously; never execute past here
    loop {
        std::thread::sleep(Duration::from_secs(1));
    }
}

/// Truncate the checkpoint in `dir` to its first `keep` bytes, returning
/// the original size.  Restore from the mangled file must fail with
/// `CheckpointError::Corrupt` — the checkpoint decoder's torn-write
/// drill.
pub fn truncate_checkpoint(dir: &Path, keep: u64) -> io::Result<u64> {
    let path = checkpoint_path(dir);
    let len = std::fs::metadata(&path)?.len();
    let f = std::fs::OpenOptions::new().write(true).open(&path)?;
    f.set_len(keep.min(len))?;
    Ok(len)
}

/// A link model that delays every frame by `latency` without limiting
/// throughput: pure added latency, for tests that need a window to
/// inject a fault while frames are in flight.
pub fn delay_frames(latency: Duration) -> BandwidthModel {
    BandwidthModel { bytes_per_sec: f64::INFINITY, latency_s: latency.as_secs_f64() }
}

/// Arrange for this client's connection to die abruptly (mid-frame)
/// right after it completes `round`.
pub fn cut_connection_after(opts: &mut ClientOpts, round: usize) {
    opts.fail_after = Some(round);
}

/// Arrange for the coordinator to halt with a typed
/// [`CoordinatorHalted`](super::CoordinatorHalted) error right after it
/// writes the round-`round` checkpoint (requires `checkpoint` to be set
/// and `round` to be a checkpoint round).
pub fn halt_coordinator_at(opts: &mut ServeOpts, round: u32) {
    opts.halt_after_checkpoint = Some(round);
}
