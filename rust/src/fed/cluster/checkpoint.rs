//! Coordinator checkpoints: atomic round-boundary snapshots of the
//! cluster server's cross-round state.
//!
//! A checkpoint is everything `drive_cluster` carries **between** rounds
//! — accounting totals, the early-stop tracker, evaluated records,
//! measured round times, the fleet's resync caches and carried uploads,
//! and the exchange strategy's stream state (sync-schedule position and
//! the FedS priority RNG).  Per-round server state (shard accumulators,
//! upload row stores) is deliberately absent: `Server::begin_round`
//! clears all of it, so a restored coordinator rebuilds it by simply
//! running the next round.
//!
//! Writes are atomic: the snapshot is encoded to `coordinator.ckpt.tmp`,
//! fsynced, then renamed over `coordinator.ckpt` — a crash mid-write
//! leaves the previous checkpoint intact, and a truncated or tampered
//! file fails loudly as [`CheckpointError::Corrupt`] at load (the decoder
//! is strict: every field bounds-checked, no trailing bytes).

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::comm::wire::{WireReader, WireWriter};
use crate::metrics::tracker::RoundRecord;
use crate::metrics::RankMetrics;

/// `"FEDSCKP1"` as a little-endian u64 — the first eight bytes of every
/// checkpoint file.
const MAGIC: u64 = u64::from_le_bytes(*b"FEDSCKP1");
/// Bump on any layout change; old files are refused, never misread.
const VERSION: u16 = 1;
/// The snapshot file inside a checkpoint directory.
const FILE: &str = "coordinator.ckpt";

/// Why a checkpoint could not be written or restored.
#[derive(Debug)]
pub enum CheckpointError {
    /// Filesystem failure (create, write, fsync, rename, read).
    Io(std::io::Error),
    /// The file exists but does not decode: bad magic, truncation,
    /// trailing bytes, or an out-of-range field.
    Corrupt(String),
    /// The file is a checkpoint of a different experiment spec.
    SpecMismatch { expected: u64, found: u64 },
    /// The file is a checkpoint layout this build does not speak.
    Version(u16),
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint io failure: {e}"),
            CheckpointError::Corrupt(why) => write!(f, "corrupt checkpoint: {why}"),
            CheckpointError::SpecMismatch { expected, found } => write!(
                f,
                "checkpoint belongs to a different spec (digest {found:#018x}, \
                 this server runs {expected:#018x})"
            ),
            CheckpointError::Version(v) => {
                write!(f, "checkpoint layout version {v} is not supported (this build: {VERSION})")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// One coordinator snapshot: the state of a run whose rounds
/// `1..=round` have fully completed (downloads sent and metered).
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// FNV-1a digest of the spec this run trains (refused on mismatch).
    pub spec_digest: u64,
    /// The last fully completed round; the restored loop resumes at
    /// `round + 1`.
    pub round: u32,
    /// Early-stop tracker position: `(best, best_index, declines, n_seen)`.
    pub early_stop: (f64, usize, usize, usize),
    /// Accounting totals at the boundary, by direction.
    pub up_params: u64,
    pub down_params: u64,
    pub up_bytes: u64,
    pub down_bytes: u64,
    pub messages: u64,
    /// Measured wall-clock of each completed round.
    pub secs: Vec<f64>,
    /// Every evaluated record so far (the restored run appends to these
    /// instead of re-evaluating completed rounds).
    pub records: Vec<RoundRecord>,
    /// Per client id: the last personalized download frame, replayed as
    /// the rejoin resync.
    pub last_download: Vec<Option<Vec<u8>>>,
    /// Uploads salvaged from clients cut during `round`, to fold into
    /// round `round + 1`: `(client id, encoded Upload frame)`.
    pub carried: Vec<(u16, Vec<u8>)>,
    /// The exchange strategy's cross-round state
    /// (`Exchange::save_state`), absent for `Single`.
    pub exchange: Option<Vec<u8>>,
}

fn write_metrics(w: &mut WireWriter, m: &RankMetrics) {
    w.u64(m.n as u64).f64(m.mrr).f64(m.hits1).f64(m.hits3).f64(m.hits10);
}

fn read_metrics(r: &mut WireReader) -> anyhow::Result<RankMetrics> {
    Ok(RankMetrics {
        n: r.u64()? as usize,
        mrr: r.f64()?,
        hits1: r.f64()?,
        hits3: r.f64()?,
        hits10: r.f64()?,
    })
}

impl Checkpoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        w.u64(MAGIC).u16(VERSION).u64(self.spec_digest).u32(self.round);
        let (best, best_index, declines, n_seen) = self.early_stop;
        w.f64(best).u64(best_index as u64).u64(declines as u64).u64(n_seen as u64);
        w.u64(self.up_params)
            .u64(self.down_params)
            .u64(self.up_bytes)
            .u64(self.down_bytes)
            .u64(self.messages);
        w.u32(self.secs.len() as u32);
        for s in &self.secs {
            w.f64(*s);
        }
        w.u32(self.records.len() as u32);
        for rec in &self.records {
            w.u64(rec.round as u64).u64(rec.params_cum).u64(rec.bytes_cum).f64(rec.mean_loss);
            write_metrics(&mut w, &rec.valid);
            write_metrics(&mut w, &rec.test);
        }
        w.u32(self.last_download.len() as u32);
        for d in &self.last_download {
            match d {
                Some(frame) => {
                    w.u8(1).blob(frame);
                }
                None => {
                    w.u8(0);
                }
            }
        }
        w.u32(self.carried.len() as u32);
        for (client, frame) in &self.carried {
            w.u16(*client).blob(frame);
        }
        match &self.exchange {
            Some(state) => {
                w.u8(1).blob(state);
            }
            None => {
                w.u8(0);
            }
        }
        w.finish()
    }

    /// Strict decode; `expected_digest` is this server's spec digest.
    pub fn decode(buf: &[u8], expected_digest: u64) -> Result<Checkpoint, CheckpointError> {
        Self::decode_inner(buf, expected_digest).map_err(|e| {
            // the digest/version arms carry their own typed error through
            match e.downcast::<CheckpointError>() {
                Ok(typed) => typed,
                Err(e) => CheckpointError::Corrupt(e.to_string()),
            }
        })
    }

    fn decode_inner(buf: &[u8], expected_digest: u64) -> anyhow::Result<Checkpoint> {
        let mut r = WireReader::new(buf);
        anyhow::ensure!(r.u64()? == MAGIC, "bad magic (not a coordinator checkpoint)");
        let version = r.u16()?;
        if version != VERSION {
            return Err(CheckpointError::Version(version).into());
        }
        let spec_digest = r.u64()?;
        if spec_digest != expected_digest {
            return Err(
                CheckpointError::SpecMismatch { expected: expected_digest, found: spec_digest }
                    .into(),
            );
        }
        let round = r.u32()?;
        let early_stop = (r.f64()?, r.u64()? as usize, r.u64()? as usize, r.u64()? as usize);
        let (up_params, down_params) = (r.u64()?, r.u64()?);
        let (up_bytes, down_bytes, messages) = (r.u64()?, r.u64()?, r.u64()?);
        let n_secs = r.u32()? as usize;
        let mut secs = Vec::with_capacity(n_secs.min(1 << 20));
        for _ in 0..n_secs {
            secs.push(r.f64()?);
        }
        let n_records = r.u32()? as usize;
        let mut records = Vec::with_capacity(n_records.min(1 << 20));
        for _ in 0..n_records {
            let (round, params_cum, bytes_cum) = (r.u64()? as usize, r.u64()?, r.u64()?);
            let mean_loss = r.f64()?;
            let valid = read_metrics(&mut r)?;
            let test = read_metrics(&mut r)?;
            records.push(RoundRecord { round, params_cum, bytes_cum, valid, test, mean_loss });
        }
        let n_clients = r.u32()? as usize;
        let mut last_download = Vec::with_capacity(n_clients.min(1 << 20));
        for _ in 0..n_clients {
            last_download.push(match r.u8()? {
                0 => None,
                1 => Some(r.blob()?),
                other => anyhow::bail!("bad download marker {other}"),
            });
        }
        let n_carried = r.u32()? as usize;
        let mut carried = Vec::with_capacity(n_carried.min(1 << 20));
        for _ in 0..n_carried {
            carried.push((r.u16()?, r.blob()?));
        }
        let exchange = match r.u8()? {
            0 => None,
            1 => Some(r.blob()?),
            other => anyhow::bail!("bad exchange marker {other}"),
        };
        anyhow::ensure!(r.remaining() == 0, "trailing bytes after checkpoint");
        Ok(Checkpoint {
            spec_digest,
            round,
            early_stop,
            up_params,
            down_params,
            up_bytes,
            down_bytes,
            messages,
            secs,
            records,
            last_download,
            carried,
            exchange,
        })
    }
}

/// The snapshot file's path inside `dir`.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join(FILE)
}

/// Atomically persist `ckpt` into `dir` (write temp → fsync → rename).
/// Returns the snapshot size in bytes.
pub fn save(dir: &Path, ckpt: &Checkpoint) -> Result<u64, CheckpointError> {
    fs::create_dir_all(dir)?;
    Ok(crate::util::fsio::atomic_write(&checkpoint_path(dir), &ckpt.encode())?)
}

/// Load and validate the snapshot in `dir` against this server's spec
/// digest.  A missing file is [`CheckpointError::Io`]; anything that does
/// not decode exactly is [`CheckpointError::Corrupt`].
pub fn load(dir: &Path, expected_digest: u64) -> Result<Checkpoint, CheckpointError> {
    let bytes = fs::read(checkpoint_path(dir))?;
    Checkpoint::decode(&bytes, expected_digest)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(mrr: f64) -> RankMetrics {
        RankMetrics { n: 9, mrr, hits1: 0.1, hits3: 0.3, hits10: 0.9 }
    }

    fn sample() -> Checkpoint {
        Checkpoint {
            spec_digest: 0xDEAD_BEEF,
            round: 6,
            early_stop: (0.42, 1, 2, 3),
            up_params: 100,
            down_params: 200,
            up_bytes: 400,
            down_bytes: 800,
            messages: 12,
            secs: vec![0.5, 0.25],
            records: vec![RoundRecord {
                round: 4,
                params_cum: 77,
                bytes_cum: 308,
                valid: metrics(0.42),
                test: metrics(0.40),
                mean_loss: 1.5,
            }],
            last_download: vec![Some(vec![1, 2, 3]), None, Some(vec![])],
            carried: vec![(2, vec![9, 9])],
            exchange: Some(vec![4, 5, 6]),
        }
    }

    #[test]
    fn round_trips_exactly() {
        let ckpt = sample();
        let decoded = Checkpoint::decode(&ckpt.encode(), ckpt.spec_digest).unwrap();
        assert_eq!(ckpt, decoded);
    }

    #[test]
    fn save_load_round_trips_on_disk() {
        let dir = std::env::temp_dir().join(format!("feds-ckpt-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let ckpt = sample();
        let bytes = save(&dir, &ckpt).unwrap();
        assert!(bytes > 0);
        assert_eq!(load(&dir, ckpt.spec_digest).unwrap(), ckpt);
        assert!(!checkpoint_path(&dir).with_extension("ckpt.tmp").exists(), "temp file renamed");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn spec_mismatch_is_typed() {
        let ckpt = sample();
        match Checkpoint::decode(&ckpt.encode(), ckpt.spec_digest ^ 1) {
            Err(CheckpointError::SpecMismatch { .. }) => {}
            other => panic!("expected SpecMismatch, got {other:?}"),
        }
    }

    #[test]
    fn truncation_anywhere_is_corrupt_never_a_panic() {
        let ckpt = sample();
        let buf = ckpt.encode();
        for cut in 0..buf.len() {
            match Checkpoint::decode(&buf[..cut], ckpt.spec_digest) {
                Err(CheckpointError::Corrupt(_)) => {}
                other => panic!("cut at {cut}/{}: expected Corrupt, got {other:?}", buf.len()),
            }
        }
        // trailing garbage is a desync, not data
        let mut long = buf.clone();
        long.push(0);
        assert!(matches!(
            Checkpoint::decode(&long, ckpt.spec_digest),
            Err(CheckpointError::Corrupt(_))
        ));
    }

    #[test]
    fn unknown_version_is_typed() {
        let ckpt = sample();
        let mut buf = ckpt.encode();
        buf[8] = 99; // the version u16 follows the 8-byte magic
        match Checkpoint::decode(&buf, ckpt.spec_digest) {
            Err(CheckpointError::Version(99)) => {}
            other => panic!("expected Version, got {other:?}"),
        }
    }
}
