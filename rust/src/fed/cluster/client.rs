//! One federated client as an OS process: connect, register, and run the
//! round loop against a [`ClusterServer`](super::ClusterServer).
//!
//! The client is a thin shell around the ordinary
//! [`ClientRunner`](crate::fed::orchestrator::client::ClientRunner): it
//! performs the versioned handshake, then plugs the runner into the
//! connection's data plane and mirrors the in-process threaded loop —
//! train → report → (verdict on eval rounds) → upload → download — so a
//! failure-free cluster run computes exactly what the in-process driver
//! computes.  Rejoin support: a `join_round > 1` registration is held by
//! the server until that round, and the welcome's resync frame (the
//! server's cached last personalized download for this id) restores the
//! shared rows missed while away; the stateful sync schedule is
//! fast-forwarded through the missed rounds.
//!
//! Failure injection for tests and drills: `leave_after` closes the
//! socket cleanly after a round's exchange; `fail_after` dies mid-frame
//! instead, which the server classifies as an abrupt crash.

use std::net::TcpStream;
use std::sync::Arc;

use anyhow::Result;

use crate::comm::accounting::Accounting;
use crate::comm::bandwidth::{BandwidthModel, Throttle};
use crate::comm::transport::Endpoint;
use crate::fed::orchestrator::client::ClientRunner;
use crate::fed::orchestrator::RoundParams;
use crate::spec::ExperimentSpec;

use super::conn::Conn;
use super::native_backend;
use super::proto::{spec_digest, ClusterMsg, PROTO_VERSION};

/// How this client process joins and (optionally) leaves the federation.
#[derive(Clone, Debug)]
pub struct ClientOpts {
    /// Server address, `HOST:PORT`.
    pub connect: String,
    /// This client's id within the spec's fleet.
    pub id: u16,
    /// Defer participation until this round (0 or 1 = immediately).  The
    /// server holds the registration and welcomes it when the round
    /// starts — the rejoin path of a dropout drill.
    pub join_round: u32,
    /// Rate-limit this client's uplink to the model.
    pub bandwidth: Option<BandwidthModel>,
    /// Failure injection: leave cleanly after completing this round.
    pub leave_after: Option<usize>,
    /// Failure injection: die mid-frame after completing this round (the
    /// server sees an abrupt crash, exactly like a SIGKILL mid-write).
    pub fail_after: Option<usize>,
}

impl ClientOpts {
    pub fn new(connect: impl Into<String>, id: u16) -> Self {
        Self {
            connect: connect.into(),
            id,
            join_round: 0,
            bandwidth: None,
            leave_after: None,
            fail_after: None,
        }
    }
}

/// Connect, register, and run this client's rounds to completion.
/// Returns once the run converges, `max_rounds` completes, an injected
/// failure triggers, or the server cuts the connection (deadline missed,
/// duplicate id, shutdown) — the last case is an error.
pub fn run_client(spec: &ExperimentSpec, opts: &ClientOpts) -> Result<()> {
    let backend = native_backend(spec)?;
    let data = spec.data.build();
    anyhow::ensure!(
        (opts.id as usize) < data.clients.len(),
        "client id {} out of range (the spec has {} clients)",
        opts.id,
        data.clients.len()
    );
    let params = RoundParams::from_spec(spec, &backend);
    let (batch_size, negatives) = backend.batch_shape();

    let sock = TcpStream::connect(&opts.connect)?;
    let mut conn = Conn::new(sock, opts.bandwidth.map(Throttle::new))?;
    conn.send(&ClusterMsg::Hello {
        version: PROTO_VERSION,
        client: opts.id,
        spec_digest: spec_digest(spec),
        join_round: opts.join_round,
    })?;
    let (start_round, resync) = match conn.recv()? {
        ClusterMsg::Welcome { round, resync } => (round.max(1) as usize, resync),
        ClusterMsg::Reject { reason } => anyhow::bail!("server refused the handshake: {reason}"),
        other => anyhow::bail!("unexpected handshake reply: {other:?}"),
    };

    // This process's own view of the metered traffic; the server's
    // accounting is the authoritative one for the run.
    let acct: Arc<Accounting> = Accounting::new();
    let trainer = backend.make_trainer(&params, data.num_entities, data.num_relations)?;
    let link = Box::new(conn.data_endpoint(acct)) as Box<dyn Endpoint>;
    let mut runner =
        ClientRunner::build(&data, opts.id, &params, trainer, link, batch_size, negatives)?;
    if start_round > 1 {
        runner.fast_forward(start_round as u32 - 1);
    }
    if let Some(frame) = resync {
        runner.apply_resync(&frame)?;
    }

    for round in start_round..=params.max_rounds {
        let eval_round = round % params.eval_every == 0;
        let report = runner.local_round(round, eval_round)?;
        conn.send(&ClusterMsg::Report {
            round: round as u32,
            loss: report.loss,
            batches: report.batches as u64,
            eval: report.eval,
        })?;
        if eval_round {
            match conn.recv().map_err(|_| anyhow::anyhow!("server hung up before the verdict"))? {
                ClusterMsg::Verdict { stop } => {
                    if stop {
                        break;
                    }
                }
                other => anyhow::bail!("expected a verdict, got {other:?}"),
            }
        }
        runner.send_upload(round as u32)?;
        runner.recv_download()?;
        if opts.fail_after == Some(round) {
            drop(runner); // release the endpoint's outbox clone
            conn.fail_abruptly();
            return Ok(());
        }
        if opts.leave_after == Some(round) {
            break;
        }
    }
    // flush the final frames before the process exits: the runner holds a
    // clone of the outbox, so it must go first
    drop(runner);
    conn.finish();
    Ok(())
}
