//! One federated client as an OS process: connect, register, and run the
//! round loop against a [`ClusterServer`](super::ClusterServer).
//!
//! The client is a thin shell around the ordinary
//! [`ClientRunner`](crate::fed::orchestrator::client::ClientRunner): it
//! performs the versioned handshake, then plugs the runner into the
//! connection's data plane and mirrors the in-process threaded loop —
//! train → report → (verdict on eval rounds) → upload → download — so a
//! failure-free cluster run computes exactly what the in-process driver
//! computes.  Rejoin support: a `join_round > 1` registration is held by
//! the server until that round, and the welcome's resync frame (the
//! server's cached last personalized download for this id) restores the
//! shared rows missed while away; the stateful sync schedule is
//! fast-forwarded through the missed rounds.
//!
//! **Reconnect**: when the coordinator vanishes mid-run (crash, restart,
//! network cut) the client does not die — it re-dials with capped
//! exponential backoff ([`ReconnectPolicy`], deterministic jitter) and
//! re-registers at its current round.  Local training is never repeated:
//! each round's report and encoded upload frame are built once and
//! cached, so a redone round resends the exact same bytes (`make_upload`
//! mutates the FedS history table and is not idempotent).  The welcome
//! round then says where the coordinator stands — behind us is a loud
//! error (a restore lost rounds), at us redoes the round's protocol
//! phases, ahead of us fast-forwards the schedule through the missed
//! rounds and folds the resync replay.
//!
//! **Sampled participation**: when the spec's participation policy is
//! not `Full`, every round opens with a [`ClusterMsg::RoundCall`]; a
//! non-sampled client skips the round's report/upload/download but still
//! advances its exchange schedule so sparse/dense parity holds.
//!
//! Failure injection for tests and drills: `leave_after` closes the
//! socket cleanly after a round's exchange; `fail_after` dies mid-frame
//! instead, which the server classifies as an abrupt crash.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::comm::accounting::Accounting;
use crate::comm::bandwidth::{BandwidthModel, Throttle};
use crate::comm::transport::Endpoint;
use crate::fed::orchestrator::client::ClientRunner;
use crate::fed::orchestrator::RoundParams;
use crate::fed::protocol::Download;
use crate::metrics::RankMetrics;
use crate::spec::{ExperimentSpec, ParticipationSpec};
use crate::util::rng::Rng;

use super::conn::Conn;
use super::native_backend;
use super::proto::{spec_digest, ClusterMsg, PROTO_VERSION};

/// Keys the backoff jitter stream; mixed with the seed, client id, and
/// attempt number so every delay is reproducible yet clients never
/// thundering-herd the restarted coordinator in lockstep.
const BACKOFF_SALT: u64 = 0x0BAC_C0FF;

/// Capped exponential backoff for re-dialing a lost coordinator.
#[derive(Clone, Copy, Debug)]
pub struct ReconnectPolicy {
    /// Re-dial at most this many times per disconnect before giving up
    /// with an error (0 = fail on the first lost connection).
    pub attempts: u32,
    /// Delay before the first retry; doubles each attempt.
    pub base: Duration,
    /// Ceiling on the per-attempt delay.
    pub cap: Duration,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        Self { attempts: 8, base: Duration::from_millis(50), cap: Duration::from_secs(2) }
    }
}

/// How this client process joins and (optionally) leaves the federation.
#[derive(Clone, Debug)]
pub struct ClientOpts {
    /// Server address, `HOST:PORT`.
    pub connect: String,
    /// This client's id within the spec's fleet.
    pub id: u16,
    /// Defer participation until this round (0 or 1 = immediately).  The
    /// server holds the registration and welcomes it when the round
    /// starts — the rejoin path of a dropout drill.
    pub join_round: u32,
    /// Rate-limit this client's uplink to the model.
    pub bandwidth: Option<BandwidthModel>,
    /// How hard to try re-dialing a vanished coordinator.
    pub reconnect: ReconnectPolicy,
    /// Failure injection: leave cleanly after completing this round.
    pub leave_after: Option<usize>,
    /// Failure injection: die mid-frame after completing this round (the
    /// server sees an abrupt crash, exactly like a SIGKILL mid-write).
    pub fail_after: Option<usize>,
}

impl ClientOpts {
    pub fn new(connect: impl Into<String>, id: u16) -> Self {
        Self {
            connect: connect.into(),
            id,
            join_round: 0,
            bandwidth: None,
            reconnect: ReconnectPolicy::default(),
            leave_after: None,
            fail_after: None,
        }
    }
}

/// One round's locally computed work, cached so a reconnect redoes the
/// protocol phases with the exact bytes of the first attempt instead of
/// re-training (the upload builder is not idempotent).
struct RoundWork {
    round: usize,
    loss: f32,
    batches: u64,
    eval: Option<(RankMetrics, RankMetrics)>,
    /// `None` when this client exchanges nothing (no shared entities or
    /// a no-exchange algorithm).
    upload: Option<(Vec<u8>, u64)>,
}

/// How a round's protocol phases ended.
enum Outcome {
    /// Everything delivered; move to the next round.
    Continue,
    /// The server's verdict said stop: the run is over.
    Stop,
    /// The connection died mid-phase; reconnect and redo the round.
    Lost,
}

/// The round a download frame belongs to.
fn frame_round(frame: &[u8]) -> Result<usize> {
    Ok(match Download::decode(frame)? {
        Download::Full { round, .. }
        | Download::Sparse { round, .. }
        | Download::Packed { round, .. } => round as usize,
    })
}

/// One dial + handshake.  `Err` is a transient transport failure (retry);
/// an explicit server rejection is terminal and surfaces as `Rejected`.
enum Dial {
    Admitted(Conn, usize, Option<Vec<u8>>),
    Rejected(String),
}

fn dial(spec: &ExperimentSpec, opts: &ClientOpts, join_round: u32) -> Result<Dial> {
    let sock = TcpStream::connect(&opts.connect)?;
    let conn = Conn::new(sock, opts.bandwidth.map(Throttle::new))?;
    conn.send(&ClusterMsg::Hello {
        version: PROTO_VERSION,
        client: opts.id,
        spec_digest: spec_digest(spec),
        join_round,
    })?;
    match conn.recv()? {
        ClusterMsg::Welcome { round, resync } => {
            Ok(Dial::Admitted(conn, round.max(1) as usize, resync))
        }
        ClusterMsg::Reject { reason } => Ok(Dial::Rejected(reason)),
        other => anyhow::bail!("unexpected handshake reply: {other:?}"),
    }
}

/// Dial until admitted, backing off exponentially (with deterministic
/// jitter) between attempts.  A [`ClusterMsg::Reject`] is terminal: the
/// server answered and said no, so retrying cannot help.
fn connect_with_backoff(
    spec: &ExperimentSpec,
    opts: &ClientOpts,
    params: &RoundParams,
    join_round: u32,
) -> Result<(Conn, usize, Option<Vec<u8>>)> {
    let policy = opts.reconnect;
    let mut last_err = None;
    for attempt in 0..=policy.attempts {
        if attempt > 0 {
            let exp = policy.base.as_secs_f64() * (1u64 << (attempt - 1).min(20)) as f64;
            let capped = exp.min(policy.cap.as_secs_f64());
            let salt = params.seed ^ BACKOFF_SALT ^ ((opts.id as u64) << 32) ^ attempt as u64;
            let mut rng = Rng::new(salt);
            std::thread::sleep(Duration::from_secs_f64(capped * (0.5 + rng.f64())));
        }
        match dial(spec, opts, join_round) {
            Ok(Dial::Admitted(conn, round, resync)) => return Ok((conn, round, resync)),
            Ok(Dial::Rejected(reason)) => {
                anyhow::bail!("server refused the handshake: {reason}")
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.expect("at least one dial attempt ran").context(format!(
        "coordinator unreachable after {} reconnect attempts",
        policy.attempts + 1
    )))
}

/// Re-dial after a lost coordinator, re-seat the runner on the fresh
/// link, and reconcile positions.  Returns the round to resume at
/// (`>= round`; a coordinator behind us is a loud error).
#[allow(clippy::too_many_arguments)]
fn reconnect(
    spec: &ExperimentSpec,
    opts: &ClientOpts,
    params: &RoundParams,
    acct: &Arc<Accounting>,
    runner: &mut ClientRunner<'_>,
    conn: &mut Conn,
    round: usize,
    done_round: &mut usize,
) -> Result<usize> {
    let (mut fresh, w, resync) = connect_with_backoff(spec, opts, params, round as u32)?;
    anyhow::ensure!(
        w >= round,
        "the coordinator was restored behind this client (welcome round {w}, local round \
         {round}); restart the client to rejoin from scratch"
    );
    runner.set_link(Box::new(fresh.data_endpoint(acct.clone())) as Box<dyn Endpoint>);
    *conn = fresh;
    // rounds the coordinator completed without us: advance the stateful
    // exchange schedule (idempotent for the round already begun locally)
    for missed in round..w {
        runner.skip_round(missed as u32);
    }
    // the resync replay is the server's last download for this id; it may
    // predate our position (we already applied it) — fold it only if new
    if let Some(frame) = resync {
        if frame_round(&frame)? > *done_round {
            runner.apply_resync(&frame)?;
        }
    }
    if w > round {
        *done_round = w - 1;
    }
    Ok(w)
}

/// The round's protocol phases over an established connection: report →
/// (verdict on eval rounds) → upload → download.  Transport failures are
/// an [`Outcome::Lost`] (the caller reconnects and redoes the round);
/// protocol violations are hard errors.
fn run_round(
    conn: &Conn,
    runner: &mut ClientRunner<'_>,
    work: &RoundWork,
    eval_round: bool,
    done_round: &mut usize,
) -> Result<Outcome> {
    let round = work.round;
    let report = ClusterMsg::Report {
        round: round as u32,
        loss: work.loss,
        batches: work.batches,
        eval: work.eval,
    };
    if conn.send(&report).is_err() {
        return Ok(Outcome::Lost);
    }
    if eval_round {
        match conn.recv() {
            Ok(ClusterMsg::Verdict { stop }) => {
                if stop {
                    return Ok(Outcome::Stop);
                }
            }
            Ok(other) => anyhow::bail!("expected a verdict, got {other:?}"),
            Err(_) => return Ok(Outcome::Lost),
        }
    }
    if let Some((frame, params)) = &work.upload {
        if runner.send_frame(frame.clone(), *params).is_err() {
            return Ok(Outcome::Lost);
        }
        let reply = match runner.recv_frame() {
            Ok(f) => f,
            Err(_) => return Ok(Outcome::Lost),
        };
        runner.apply_download_frame(&reply)?;
        *done_round = round;
    }
    Ok(Outcome::Continue)
}

/// Connect, register, and run this client's rounds to completion.
/// Returns once the run converges, `max_rounds` completes, an injected
/// failure triggers, or the coordinator stays unreachable through a full
/// backoff cycle — the last case is an error.
pub fn run_client(spec: &ExperimentSpec, opts: &ClientOpts) -> Result<()> {
    let backend = native_backend(spec)?;
    let data = spec.data.build();
    anyhow::ensure!(
        (opts.id as usize) < data.clients.len(),
        "client id {} out of range (the spec has {} clients)",
        opts.id,
        data.clients.len()
    );
    let params = RoundParams::from_spec(spec, &backend);
    let (batch_size, negatives) = backend.batch_shape();
    let sampled_mode = params.participation != ParticipationSpec::Full;

    // This process's own view of the metered traffic; the server's
    // accounting is the authoritative one for the run.
    let acct: Arc<Accounting> = Accounting::new();
    let (mut conn, start_round, resync) =
        connect_with_backoff(spec, opts, &params, opts.join_round)?;

    let trainer = backend.make_trainer(&params, data.num_entities, data.num_relations)?;
    let link = Box::new(conn.data_endpoint(acct.clone())) as Box<dyn Endpoint>;
    let mut runner =
        ClientRunner::build(&data, opts.id, &params, trainer, link, batch_size, negatives)?;
    if start_round > 1 {
        runner.fast_forward(start_round as u32 - 1);
    }
    if let Some(frame) = resync {
        runner.apply_resync(&frame)?;
    }
    // the last round whose download (or resync) this process folded;
    // everything before `start_round` is covered by the fast-forward
    let mut done_round: usize = start_round.saturating_sub(1);

    let mut cache: Option<RoundWork> = None;
    let mut round = start_round;
    'rounds: while round <= params.max_rounds {
        let eval_round = round % params.eval_every == 0;

        // --- round call: sampled-participation gate ---------------------
        let mut participate = true;
        if sampled_mode {
            match conn.recv() {
                Ok(ClusterMsg::RoundCall { round: rr, participate: p }) => {
                    anyhow::ensure!(
                        rr as usize == round,
                        "round call for round {rr} arrived while in round {round}"
                    );
                    participate = p;
                }
                // the run converged in a round we sat out
                Ok(ClusterMsg::Verdict { stop: true }) => break 'rounds,
                Ok(other) => anyhow::bail!("expected a round call, got {other:?}"),
                Err(_) => {
                    let w = reconnect(
                        spec,
                        opts,
                        &params,
                        &acct,
                        &mut runner,
                        &mut conn,
                        round,
                        &mut done_round,
                    )?;
                    if w > round {
                        cache = None;
                        round = w;
                    }
                    continue 'rounds;
                }
            }
        }

        if participate {
            // --- local work, computed exactly once per round ------------
            if cache.as_ref().map(|w| w.round) != Some(round) {
                let report = runner.local_round(round, eval_round)?;
                let upload = runner.upload_frame(round as u32)?;
                cache = Some(RoundWork {
                    round,
                    loss: report.loss,
                    batches: report.batches as u64,
                    eval: report.eval,
                    upload,
                });
            }
            let work = cache.as_ref().expect("round work cached above");

            // --- protocol phases, redone verbatim after a reconnect -----
            match run_round(&conn, &mut runner, work, eval_round, &mut done_round)? {
                Outcome::Continue => {}
                Outcome::Stop => break 'rounds,
                Outcome::Lost => {
                    let w = reconnect(
                        spec,
                        opts,
                        &params,
                        &acct,
                        &mut runner,
                        &mut conn,
                        round,
                        &mut done_round,
                    )?;
                    if w > round {
                        cache = None;
                        round = w;
                    }
                    continue 'rounds;
                }
            }
        } else {
            // sat out: keep the exchange schedule's parity advancing
            runner.skip_round(round as u32);
        }

        if opts.fail_after == Some(round) {
            drop(runner); // release the endpoint's outbox clone
            conn.fail_abruptly();
            return Ok(());
        }
        if opts.leave_after == Some(round) {
            break 'rounds;
        }
        round += 1;
    }
    // flush the final frames before the process exits: the runner holds a
    // clone of the outbox, so it must go first
    drop(runner);
    conn.finish();
    Ok(())
}
