//! Multi-process cluster runtime: the federated engine deployed across
//! OS processes with real failure semantics.
//!
//! The in-process drivers (`fed::orchestrator`) run every client inside
//! one process.  This module runs the **same engine** over a routable
//! TCP server (`feds serve --bind HOST:PORT`) and independent client
//! processes (`feds client --connect HOST:PORT --spec file.json`):
//!
//! * [`proto`] — the versioned control-plane envelope ([`ClusterMsg`]):
//!   hello/welcome/reject handshake, per-round reports and verdicts, and
//!   nested data-plane frames carrying the exact `fed::protocol` bytes.
//!   A registration is validated against the protocol version and an
//!   FNV-1a digest of the experiment spec ([`spec_digest`]), so two
//!   processes can never silently train different experiments.
//! * [`ClusterServer`] — the coordinator: accepts registrations, drives
//!   the round loop with a per-round **deadline** (stragglers are cut
//!   and the round aggregates partially, their completed uploads carried
//!   into the next round), detects dropouts through the transport's
//!   clean/abrupt disconnect classification, and welcomes rejoining ids
//!   back with a **resync** replay of their last personalized download.
//! * [`run_client`] — one client process: handshake, then the ordinary
//!   `ClientRunner` round loop over the connection's data plane.  When
//!   the coordinator vanishes it re-dials with capped exponential
//!   backoff ([`ReconnectPolicy`]) and redoes the interrupted round from
//!   cached frames — never re-training.  Optional failure injection
//!   (`leave_after` / `fail_after`) for drills and tests.
//! * [`checkpoint`] — atomic round-boundary snapshots of the
//!   coordinator's cross-round state (`--checkpoint DIR`); `--restore
//!   DIR` resumes at the snapshot's round + 1, bit-identical to a run
//!   that never stopped, and refuses mismatched or tampered snapshots
//!   loudly at bind.
//! * [`chaos`] — fault-injection primitives (self-SIGKILL at a round
//!   boundary, typed coordinator halts, checkpoint truncation, frame
//!   delays) composed by the crash/restore drills in `tests/cluster.rs`
//!   and `tests/cluster_process.rs`.
//!
//! Guarantee: with no failures injected, a cluster run over N processes
//! is bit-identical — accounting, round records, convergence — to the
//! same spec driven in-process (`session_equivalence` has the in-process
//! bar, `tests/cluster.rs` the cross-process one).  Under failures the
//! run still terminates: every round ends by deadline, partial rounds
//! aggregate whoever reported, and `RunEvent::{ClientJoined,
//! ClientDropped, PartialRound}` record the membership history.  A
//! non-`Full` participation policy samples a seeded per-round cohort
//! ([`ClusterMsg::RoundCall`], `RunEvent::ClientSampled`); sitting a
//! round out is not a dropout.
//!
//! Wall-clock: [`ClusterOutcome::times`] measures real seconds per round
//! (training + transfer), the dynamic counterpart of the static
//! `comm::bandwidth` byte model — on a throttled link the two are
//! directly comparable (see `benches/cluster_wallclock.rs`).

pub mod chaos;
pub mod checkpoint;
mod client;
mod conn;
pub mod proto;
mod server;

pub use checkpoint::{Checkpoint, CheckpointError};
pub use client::{run_client, ClientOpts, ReconnectPolicy};
pub use proto::{spec_digest, ClusterMsg, PROTO_VERSION};
pub use server::{ClusterOutcome, ClusterServer, CoordinatorHalted, ServeOpts};

use anyhow::Result;

use crate::fed::Backend;
use crate::kge::Hyper;
use crate::spec::{BackendSpec, ExperimentSpec};

/// Resolve a spec's backend for cluster use.  Native only: cluster
/// processes build their trainers from the spec alone, and the XLA
/// runtime's AOT artifacts are not part of the handshake.
pub(crate) fn native_backend(spec: &ExperimentSpec) -> Result<Backend> {
    spec.validate()?;
    let BackendSpec::Native { dim, learning_rate, batch, negatives, eval_batch } = &spec.backend
    else {
        anyhow::bail!("the cluster runtime is native-backend only (spec backend must be native)");
    };
    Ok(Backend::Native {
        hyper: Hyper { dim: *dim, learning_rate: *learning_rate, ..Default::default() },
        batch: *batch,
        negatives: *negatives,
        eval_batch: *eval_batch,
    })
}
