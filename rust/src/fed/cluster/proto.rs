//! The cluster control-plane protocol: versioned envelopes carrying the
//! handshake, per-round reports/verdicts, and the (already-encoded)
//! `fed::protocol` data frames.
//!
//! Every message on a cluster socket is one [`ClusterMsg`] envelope,
//! length-prefix framed by `comm::wire`.  The data-plane payloads
//! ([`ClusterMsg::Upload`] / [`ClusterMsg::Download`]) nest the exact
//! bytes the in-process transports would carry, so metering the inner
//! blob keeps byte accounting bit-identical to a single-process run;
//! the envelope itself is control-plane overhead and is never metered.

use anyhow::Result;

use crate::comm::wire::{WireReader, WireWriter};
use crate::metrics::RankMetrics;
use crate::spec::ExperimentSpec;

/// Version of this control-plane protocol.  A [`ClusterMsg::Hello`] with
/// any other version is rejected before the client enters the federation.
/// v2 added [`ClusterMsg::RoundCall`] (sampled participation).  v3 added
/// the packed compression frames (`--compress` stage stacks) to the data
/// plane; a run with an empty pipeline emits exactly the v2 frame bytes.
pub const PROTO_VERSION: u16 = 3;

/// FNV-1a digest of the spec's canonical JSON form.  Server and clients
/// each hash their own copy; a mismatch at handshake time means the two
/// processes would train different experiments, so the join is refused.
pub fn spec_digest(spec: &ExperimentSpec) -> u64 {
    let text = spec.to_json().to_string();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One control-plane envelope.  Tags are part of the wire format; new
/// message kinds must append, never renumber.
#[derive(Clone, Debug, PartialEq)]
pub enum ClusterMsg {
    /// Client → server, first frame on the socket: register `client`
    /// against the server's experiment, deferred until `join_round`
    /// (0 or 1 = immediately).
    Hello {
        version: u16,
        client: u16,
        spec_digest: u64,
        join_round: u32,
    },
    /// Server → client, admission: start working at `round`; `resync`
    /// replays the server's last personalized download frame when this
    /// id rejoins after a dropout.
    Welcome {
        round: u32,
        resync: Option<Vec<u8>>,
    },
    /// Server → client: the handshake (or a duplicate registration) was
    /// refused; the socket closes after this frame.
    Reject { reason: String },
    /// Client → server, once per round: the local-training result
    /// (mirrors `orchestrator::client::Report`).
    Report {
        round: u32,
        loss: f32,
        batches: u64,
        eval: Option<(RankMetrics, RankMetrics)>,
    },
    /// Server → client after an evaluation round: continue or stop.
    Verdict { stop: bool },
    /// Client → server data plane: an encoded `fed::protocol::Upload`.
    Upload(Vec<u8>),
    /// Server → client data plane: an encoded `fed::protocol::Download`.
    Download(Vec<u8>),
    /// Server → client at a round start, only when the spec's
    /// participation policy is not `Full`: whether this client is sampled
    /// into `round`.  Non-sampled clients skip the round's report,
    /// upload, and download but keep their exchange schedule advancing.
    RoundCall { round: u32, participate: bool },
}

const TAG_HELLO: u8 = 0;
const TAG_WELCOME: u8 = 1;
const TAG_REJECT: u8 = 2;
const TAG_REPORT: u8 = 3;
const TAG_VERDICT: u8 = 4;
const TAG_UPLOAD: u8 = 5;
const TAG_DOWNLOAD: u8 = 6;
const TAG_ROUND_CALL: u8 = 7;

fn write_metrics(w: &mut WireWriter, m: &RankMetrics) {
    w.u64(m.n as u64).f64(m.mrr).f64(m.hits1).f64(m.hits3).f64(m.hits10);
}

fn read_metrics(r: &mut WireReader) -> Result<RankMetrics> {
    Ok(RankMetrics {
        n: r.u64()? as usize,
        mrr: r.f64()?,
        hits1: r.f64()?,
        hits3: r.f64()?,
        hits10: r.f64()?,
    })
}

impl ClusterMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        match self {
            ClusterMsg::Hello { version, client, spec_digest, join_round } => {
                w.u8(TAG_HELLO).u16(*version).u16(*client).u64(*spec_digest).u32(*join_round);
            }
            ClusterMsg::Welcome { round, resync } => {
                w.u8(TAG_WELCOME).u32(*round);
                match resync {
                    Some(frame) => {
                        w.u8(1).blob(frame);
                    }
                    None => {
                        w.u8(0);
                    }
                }
            }
            ClusterMsg::Reject { reason } => {
                w.u8(TAG_REJECT).blob(reason.as_bytes());
            }
            ClusterMsg::Report { round, loss, batches, eval } => {
                w.u8(TAG_REPORT).u32(*round).f32(*loss).u64(*batches);
                match eval {
                    Some((valid, test)) => {
                        w.u8(1);
                        write_metrics(&mut w, valid);
                        write_metrics(&mut w, test);
                    }
                    None => {
                        w.u8(0);
                    }
                }
            }
            ClusterMsg::Verdict { stop } => {
                w.u8(TAG_VERDICT).u8(*stop as u8);
            }
            ClusterMsg::Upload(frame) => {
                w.u8(TAG_UPLOAD).blob(frame);
            }
            ClusterMsg::Download(frame) => {
                w.u8(TAG_DOWNLOAD).blob(frame);
            }
            ClusterMsg::RoundCall { round, participate } => {
                w.u8(TAG_ROUND_CALL).u32(*round).u8(*participate as u8);
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<ClusterMsg> {
        let mut r = WireReader::new(buf);
        let msg = match r.u8()? {
            TAG_HELLO => ClusterMsg::Hello {
                version: r.u16()?,
                client: r.u16()?,
                spec_digest: r.u64()?,
                join_round: r.u32()?,
            },
            TAG_WELCOME => {
                let round = r.u32()?;
                let resync = match r.u8()? {
                    0 => None,
                    1 => Some(r.blob()?),
                    other => anyhow::bail!("bad resync marker {other}"),
                };
                ClusterMsg::Welcome { round, resync }
            }
            TAG_REJECT => ClusterMsg::Reject {
                reason: String::from_utf8(r.blob()?)
                    .map_err(|_| anyhow::anyhow!("reject reason is not UTF-8"))?,
            },
            TAG_REPORT => {
                let round = r.u32()?;
                let loss = r.f32()?;
                let batches = r.u64()?;
                let eval = match r.u8()? {
                    0 => None,
                    1 => Some((read_metrics(&mut r)?, read_metrics(&mut r)?)),
                    other => anyhow::bail!("bad eval marker {other}"),
                };
                ClusterMsg::Report { round, loss, batches, eval }
            }
            TAG_VERDICT => ClusterMsg::Verdict { stop: r.u8()? != 0 },
            TAG_UPLOAD => ClusterMsg::Upload(r.blob()?),
            TAG_DOWNLOAD => ClusterMsg::Download(r.blob()?),
            TAG_ROUND_CALL => {
                let round = r.u32()?;
                let participate = match r.u8()? {
                    0 => false,
                    1 => true,
                    other => anyhow::bail!("bad participate marker {other}"),
                };
                ClusterMsg::RoundCall { round, participate }
            }
            other => anyhow::bail!("unknown cluster message tag {other}"),
        };
        anyhow::ensure!(r.remaining() == 0, "trailing bytes after cluster message");
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn arb_metrics(rng: &mut Rng) -> RankMetrics {
        RankMetrics {
            n: rng.below(1000) as usize,
            mrr: rng.f64(),
            hits1: rng.f64(),
            hits3: rng.f64(),
            hits10: rng.f64(),
        }
    }

    fn arb_msg(rng: &mut Rng) -> ClusterMsg {
        match rng.below(8) {
            0 => ClusterMsg::Hello {
                version: rng.below(1 << 16) as u16,
                client: rng.below(64) as u16,
                spec_digest: rng.next_u64(),
                join_round: rng.below(100) as u32,
            },
            1 => ClusterMsg::Welcome {
                round: rng.below(100) as u32,
                resync: (rng.below(2) == 1)
                    .then(|| (0..rng.below(40)).map(|_| rng.below(256) as u8).collect()),
            },
            2 => ClusterMsg::Reject { reason: format!("reason {}", rng.below(1000)) },
            3 => ClusterMsg::Report {
                round: rng.below(100) as u32,
                loss: rng.f64() as f32,
                batches: rng.below(10_000),
                eval: (rng.below(2) == 1).then(|| (arb_metrics(rng), arb_metrics(rng))),
            },
            4 => ClusterMsg::Verdict { stop: rng.below(2) == 1 },
            5 => ClusterMsg::Upload((0..rng.below(64)).map(|_| rng.below(256) as u8).collect()),
            6 => ClusterMsg::Download((0..rng.below(64)).map(|_| rng.below(256) as u8).collect()),
            _ => ClusterMsg::RoundCall {
                round: rng.below(100) as u32,
                participate: rng.below(2) == 1,
            },
        }
    }

    #[test]
    fn envelope_roundtrip() {
        check("cluster envelope roundtrip", 300, |rng| {
            let msg = arb_msg(rng);
            let decoded = ClusterMsg::decode(&msg.encode()).expect("decode");
            assert_eq!(msg, decoded);
        });
    }

    #[test]
    fn malformed_envelopes_are_rejected() {
        assert!(ClusterMsg::decode(&[]).is_err(), "empty buffer");
        assert!(ClusterMsg::decode(&[200]).is_err(), "unknown tag");
        // a valid message truncated anywhere must fail, never panic
        check("truncated envelope rejected", 200, |rng| {
            let buf = arb_msg(rng).encode();
            let cut = rng.below(buf.len() as u64) as usize;
            assert!(ClusterMsg::decode(&buf[..cut]).is_err(), "cut at {cut}/{}", buf.len());
        });
        // trailing garbage after a complete message is a desync, not data
        let mut buf = ClusterMsg::Verdict { stop: true }.encode();
        buf.push(0);
        assert!(ClusterMsg::decode(&buf).is_err(), "trailing bytes");
    }

    #[test]
    fn round_call_decodes_strictly() {
        // the participate flag is a strict 0/1 marker, not a truthy byte
        let mut buf = ClusterMsg::RoundCall { round: 9, participate: true }.encode();
        *buf.last_mut().unwrap() = 2;
        assert!(ClusterMsg::decode(&buf).is_err(), "participate marker 2");
        let mut trailing = ClusterMsg::RoundCall { round: 9, participate: false }.encode();
        trailing.push(0);
        assert!(ClusterMsg::decode(&trailing).is_err(), "trailing bytes after round call");
    }
}
