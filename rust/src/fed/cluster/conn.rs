//! One cluster socket: a demultiplexing connection wrapper.
//!
//! Both sides of a cluster link speak [`ClusterMsg`] envelopes over one
//! TCP stream.  A [`Conn`] owns the socket's reader/writer threads and
//! splits incoming traffic onto two queues:
//!
//! * **control** — decoded envelopes (handshake, reports, verdicts, and
//!   on the server side the nested upload frames), consumed by whoever
//!   drives the connection;
//! * **data** — the payloads of [`ClusterMsg::Download`] envelopes, raw.
//!   Only the client side receives downloads, and it consumes them
//!   through a [`ClusterEndpoint`] — the `comm::transport::Endpoint`
//!   the ordinary `ClientRunner` plugs into, none the wiser that its
//!   frames ride inside cluster envelopes.
//!
//! Metering: the envelope is control-plane overhead and is never
//! recorded.  The client end meters its upload payloads in
//! [`ClusterEndpoint::send`]; the server meters upload payloads on
//! receipt and download payloads before sending, so both sides account
//! exactly the bytes the in-process transports would.
//!
//! Disconnect classification mirrors [`TcpEndpoint`]
//! (`comm::transport::tcp`): a clean EOF at a frame boundary is a
//! deliberate leave, truncation/desync/IO failure is a crash.  For the
//! crash-injection tests and CLI, [`Conn::fail_abruptly`] writes a
//! deliberately truncated frame (a length prefix promising more bytes
//! than follow) and drops the socket, which the peer classifies as
//! [`Disconnect::Abrupt`].
//!
//! [`TcpEndpoint`]: crate::comm::transport::TcpEndpoint

use std::io::Write as _;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::comm::accounting::{Accounting, Direction};
use crate::comm::bandwidth::Throttle;
use crate::comm::transport::{Disconnect, Endpoint, FrameQueue};
use crate::comm::wire::{read_frame, write_frame, FrameError};

use super::proto::ClusterMsg;

/// What the writer thread should put on the stream next.
enum WriteCmd {
    /// An encoded envelope, length-prefix framed.
    Frame(Vec<u8>),
    /// Crash injection: a length prefix claiming `promised` bytes, then
    /// only `partial`, then die — the peer sees a mid-frame truncation.
    PartialThenDie { promised: u32, partial: Vec<u8> },
}

/// One side of a cluster socket.  See the module docs for the routing
/// and metering contract.
pub(crate) struct Conn {
    out: Option<Sender<WriteCmd>>,
    ctrl: FrameQueue<ClusterMsg>,
    data: Option<FrameQueue<Vec<u8>>>,
    broken: Arc<AtomicBool>,
    disconnect: Arc<Mutex<Option<Disconnect>>>,
    writer: Option<JoinHandle<()>>,
}

impl Conn {
    /// Wrap an established stream.  `throttle` (when `Some`) paces the
    /// writer to the bandwidth model, so loopback rounds measure the
    /// wall-clock a rate-limited link would show.
    pub(crate) fn new(sock: TcpStream, throttle: Option<Throttle>) -> Result<Self> {
        sock.set_nodelay(true)?;
        sock.set_read_timeout(None)?;
        let wsock = sock.try_clone()?;

        let (out_tx, out_rx) = channel::<WriteCmd>();
        let broken = Arc::new(AtomicBool::new(false));
        let wbroken = broken.clone();
        let writer = std::thread::spawn(move || {
            let mut w = std::io::BufWriter::new(wsock);
            for cmd in out_rx {
                match cmd {
                    WriteCmd::Frame(frame) => {
                        if let Some(t) = &throttle {
                            t.pace(frame.len() + 4);
                        }
                        if write_frame(&mut w, &frame).and_then(|()| w.flush()).is_err() {
                            wbroken.store(true, Ordering::Relaxed);
                            break;
                        }
                    }
                    WriteCmd::PartialThenDie { promised, partial } => {
                        let _ = w
                            .write_all(&promised.to_le_bytes())
                            .and_then(|()| w.write_all(&partial))
                            .and_then(|()| w.flush());
                        wbroken.store(true, Ordering::Relaxed);
                        if let Ok(s) = w.into_inner() {
                            let _ = s.shutdown(Shutdown::Both);
                        }
                        return;
                    }
                }
            }
            if let Ok(s) = w.into_inner() {
                let _ = s.shutdown(Shutdown::Write);
            }
        });

        let (ctrl_tx, ctrl_rx) = channel::<ClusterMsg>();
        let (data_tx, data_rx) = channel::<Vec<u8>>();
        let disconnect = Arc::new(Mutex::new(None));
        let rdisconnect = disconnect.clone();
        std::thread::spawn(move || {
            let mut r = std::io::BufReader::new(sock);
            let why = loop {
                match read_frame(&mut r) {
                    Ok(Some(frame)) => match ClusterMsg::decode(&frame) {
                        // data plane raw, everything else decoded: the
                        // ClientRunner's endpoint reads downloads without
                        // re-encoding, the driver reads typed control
                        Ok(ClusterMsg::Download(payload)) => {
                            if data_tx.send(payload).is_err() {
                                return; // data consumer gone, link winding down
                            }
                        }
                        Ok(msg) => {
                            if ctrl_tx.send(msg).is_err() {
                                return;
                            }
                        }
                        // an undecodable envelope means the stream is no
                        // longer trustworthy — same as a desync
                        Err(_) => break Disconnect::Abrupt,
                    },
                    Ok(None) => break Disconnect::Clean,
                    Err(FrameError::Truncated { .. })
                    | Err(FrameError::Desync { .. })
                    | Err(FrameError::Io(_)) => break Disconnect::Abrupt,
                }
            };
            *rdisconnect.lock().unwrap() = Some(why);
        });

        Ok(Self {
            out: Some(out_tx),
            ctrl: FrameQueue::new(ctrl_rx),
            data: Some(FrameQueue::new(data_rx)),
            broken,
            disconnect,
            writer: Some(writer),
        })
    }

    pub(crate) fn send(&self, msg: &ClusterMsg) -> Result<()> {
        if self.broken.load(Ordering::Relaxed) {
            anyhow::bail!("peer disconnected");
        }
        self.out
            .as_ref()
            .expect("connection already finished")
            .send(WriteCmd::Frame(msg.encode()))
            .map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    /// Block for the next control message.
    pub(crate) fn recv(&self) -> Result<ClusterMsg> {
        self.ctrl.recv()
    }

    /// Wait up to `d` for a control message (`Ok(None)` on timeout, error
    /// once the peer hung up and the queue is drained).
    pub(crate) fn recv_timeout(&self, d: Duration) -> Result<Option<ClusterMsg>> {
        self.ctrl.recv_timeout(d)
    }

    /// How the peer's stream ended, once it has (`None` while connected).
    pub(crate) fn disconnect_reason(&self) -> Option<Disconnect> {
        *self.disconnect.lock().unwrap()
    }

    /// Split off the data-plane half as a `comm::transport::Endpoint` for
    /// a `ClientRunner`.  Client side only; callable once.
    pub(crate) fn data_endpoint(&mut self, acct: Arc<Accounting>) -> ClusterEndpoint {
        ClusterEndpoint {
            out: self.out.as_ref().expect("connection already finished").clone(),
            data: self.data.take().expect("data endpoint already taken"),
            acct,
            broken: self.broken.clone(),
        }
    }

    /// Crash injection: put a truncated frame on the stream and kill the
    /// connection, so the peer observes [`Disconnect::Abrupt`] — exactly
    /// what a process dying mid-write looks like.
    pub(crate) fn fail_abruptly(mut self) {
        if let Some(out) = self.out.take() {
            let _ = out.send(WriteCmd::PartialThenDie {
                promised: 10,
                partial: vec![0xDE, 0xAD, 0xBE],
            });
        }
        if let Some(w) = self.writer.take() {
            let _ = w.join();
        }
    }

    /// Graceful close: flush every queued frame, shut down the write half
    /// (the peer's clean EOF), and only then return.  Joining the writer
    /// matters in short-lived client processes, where exiting `main`
    /// would otherwise race the final frames onto a dying socket.
    ///
    /// Any [`ClusterEndpoint`] split off this connection must be dropped
    /// first — it holds a clone of the outbox sender, and the writer only
    /// exits once every sender is gone.
    pub(crate) fn finish(mut self) {
        self.out.take();
        if let Some(w) = self.writer.take() {
            let _ = w.join();
        }
    }
}

/// The client-side data plane of a [`Conn`], as the metered
/// [`Endpoint`] seam `ClientRunner` expects: `send` wraps the frame in a
/// [`ClusterMsg::Upload`] envelope (metering the inner payload, exactly
/// the in-process contract), `recv` yields unwrapped download payloads.
pub(crate) struct ClusterEndpoint {
    out: Sender<WriteCmd>,
    data: FrameQueue<Vec<u8>>,
    acct: Arc<Accounting>,
    broken: Arc<AtomicBool>,
}

impl Endpoint for ClusterEndpoint {
    fn send(&self, frame: Vec<u8>, params: u64) -> Result<()> {
        if self.broken.load(Ordering::Relaxed) {
            anyhow::bail!("peer disconnected");
        }
        self.acct.record(Direction::Upload, params, frame.len() as u64);
        self.out
            .send(WriteCmd::Frame(ClusterMsg::Upload(frame).encode()))
            .map_err(|_| anyhow::anyhow!("peer disconnected"))
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.data.recv()
    }

    fn recv_timeout(&self, d: Duration) -> Result<Option<Vec<u8>>> {
        self.data.recv_timeout(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pair() -> (Conn, Conn) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (Conn::new(client, None).unwrap(), Conn::new(server, None).unwrap())
    }

    #[test]
    fn control_and_data_planes_demultiplex() {
        let (mut client, server) = pair();
        let acct = Accounting::new();
        let ep = client.data_endpoint(acct.clone());

        // server → client: a verdict (control) then a download (data)
        server.send(&ClusterMsg::Verdict { stop: false }).unwrap();
        server.send(&ClusterMsg::Download(vec![7, 8, 9])).unwrap();
        assert_eq!(client.recv().unwrap(), ClusterMsg::Verdict { stop: false });
        assert_eq!(ep.recv().unwrap(), vec![7, 8, 9]);

        // client → server: endpoint sends arrive as Upload envelopes,
        // metered as upload payload bytes only
        ep.send(vec![1, 2, 3, 4], 11).unwrap();
        assert_eq!(server.recv().unwrap(), ClusterMsg::Upload(vec![1, 2, 3, 4]));
        assert_eq!(acct.params_dir(Direction::Upload), 11);
        assert_eq!(acct.bytes_dir(Direction::Upload), 4);
        assert_eq!(acct.messages(), 1);
    }

    fn wait_disconnect(conn: &Conn) -> Disconnect {
        for _ in 0..200 {
            if let Some(d) = conn.disconnect_reason() {
                return d;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("peer disconnect never surfaced");
    }

    #[test]
    fn finish_flushes_then_reads_as_clean_leave() {
        let (client, server) = pair();
        client.send(&ClusterMsg::Verdict { stop: true }).unwrap();
        client.finish();
        assert_eq!(server.recv().unwrap(), ClusterMsg::Verdict { stop: true });
        assert_eq!(wait_disconnect(&server), Disconnect::Clean);
        assert!(server.recv().is_err(), "drained queue surfaces the hangup");
    }

    #[test]
    fn fail_abruptly_reads_as_crash() {
        let (client, server) = pair();
        client.fail_abruptly();
        assert_eq!(wait_disconnect(&server), Disconnect::Abrupt);
    }
}
