//! The cluster coordinator: a routable TCP server driving the federated
//! round loop over independent client processes.
//!
//! [`ClusterServer::bind`] starts an acceptor that performs the
//! versioned handshake (protocol version, spec digest, client-id range)
//! and hands validated connections to the round loop;
//! [`ClusterServer::run`] then drives exactly the sequence of the
//! in-process driver (`orchestrator::drive`) — train → report →
//! eval/verdict → exchange — with the failure semantics a real
//! deployment needs:
//!
//! * **Round deadline / partial aggregation** — a round waits at most
//!   [`ServeOpts::deadline`] for reports.  Stragglers are cut (their
//!   connection closes; they observe "server hung up" and may rejoin)
//!   and the round proceeds over the clients that reported, emitting
//!   [`RunEvent::PartialRound`].  An upload a cut client had already
//!   completed is **carried**: metered on salvage and folded into the
//!   next round's aggregation, so no finished work is discarded.
//! * **Dropout detection** — the transport classifies how a peer's
//!   stream ended ([`Disconnect::Clean`] leave vs [`Disconnect::Abrupt`]
//!   mid-frame crash); either way the member is removed and
//!   [`RunEvent::ClientDropped`] records which it was.
//! * **Rejoin with resync** — a client id that re-registers after a
//!   dropout is welcomed back at the current round with the server's
//!   cached last personalized download replayed inside the
//!   [`ClusterMsg::Welcome`], restoring the shared rows it missed.
//!
//! With no failures injected, a cluster run is **bit-identical** to the
//! same spec driven in-process: uploads fold and downloads build in
//! client-id order, metering points match the in-process driver's, and
//! every scalar crosses the wire in exact little-endian bits.

use std::fmt;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::comm::accounting::{Accounting, Direction};
use crate::comm::bandwidth::{BandwidthModel, RoundTimes, Throttle};
use crate::comm::transport::Disconnect;
use crate::comm::wire::{read_frame, write_frame, WireReader, WireWriter};
use crate::data::partition::FedDataset;
use crate::fed::orchestrator::client::{initial_table, Report};
use crate::fed::orchestrator::{
    native_trainer, server_side, Algo, Backend, RoundParams, RunOutcome, ServerSide,
};
use crate::fed::protocol::Upload;
use crate::fed::server::Server;
use crate::fed::{comm_ratio, fedepl_dim};
use crate::kge::Table;
use crate::metrics::observe::{emit, HistoryObserver, RunEvent, RunObserver};
use crate::metrics::tracker::RoundRecord;
use crate::metrics::{EarlyStop, RankMetrics};
use crate::spec::{ExperimentSpec, ParticipationSpec};
use crate::util::rng::Rng;

use super::checkpoint::{self, Checkpoint};
use super::conn::Conn;
use super::native_backend;
use super::proto::{spec_digest, ClusterMsg, PROTO_VERSION};

/// Keys the per-round participation sampling stream: the draw for round
/// `r` comes from `Rng::new(seed ^ SAMPLE_SALT ^ r)`, so a restored
/// coordinator reproduces every sample without checkpointing RNG state.
const SAMPLE_SALT: u64 = 0x5A39_17;

/// How the coordinator handles its fleet.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// How long a round waits for reports before cutting stragglers and
    /// aggregating partially.
    pub deadline: Duration,
    /// Rate-limit every server→client link to this model, so measured
    /// wall-clock per round reflects the link instead of loopback.
    pub bandwidth: Option<BandwidthModel>,
    /// How many clients must register before round 1 starts
    /// (0 = every client in the spec).
    pub expect: usize,
    /// Write a round-boundary checkpoint into this directory (atomic
    /// write-temp + rename) every [`checkpoint_every`] rounds.
    ///
    /// [`checkpoint_every`]: ServeOpts::checkpoint_every
    pub checkpoint: Option<PathBuf>,
    /// Rounds between snapshots (≥ 1; read only when `checkpoint` is
    /// set).  Snapshots land after rounds `every, 2·every, …`.
    pub checkpoint_every: u32,
    /// Resume from the snapshot in this directory instead of round 1.
    /// The snapshot must belong to the same spec (digest-checked) and
    /// the run continues at its round + 1, bit-identical to a run that
    /// never stopped.
    pub restore: Option<PathBuf>,
    /// Fault injection: return [`CoordinatorHalted`] immediately after
    /// writing this round's checkpoint — the in-test stand-in for a
    /// coordinator crash at an exact round boundary.
    pub halt_after_checkpoint: Option<u32>,
    /// Fault injection: SIGKILL this whole process immediately after
    /// writing this round's checkpoint (the multi-process crash drill;
    /// see [`super::chaos::sigkill_self`]).
    pub kill_after_checkpoint: Option<u32>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        Self {
            deadline: Duration::from_secs(30),
            bandwidth: None,
            expect: 0,
            checkpoint: None,
            checkpoint_every: 1,
            restore: None,
            halt_after_checkpoint: None,
            kill_after_checkpoint: None,
        }
    }
}

/// The typed error a fault-injected coordinator halt surfaces: the
/// round-`round` checkpoint was written and then the round loop stopped
/// cold, exactly as a crash at the boundary would.  Restore from the
/// checkpoint directory to continue the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoordinatorHalted {
    /// The round whose checkpoint landed immediately before the halt.
    pub round: usize,
}

impl fmt::Display for CoordinatorHalted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "coordinator halted by fault injection after the round-{} checkpoint", self.round)
    }
}

impl std::error::Error for CoordinatorHalted {}

/// A cluster run's result: the engine outcome plus measured wall-clock
/// per round — the dynamic counterpart of the static
/// [`BandwidthModel::round_time`] estimate.
pub struct ClusterOutcome {
    pub run: RunOutcome,
    pub times: RoundTimes,
}

/// A validated registration waiting for its join round.
struct Join {
    client: u16,
    join_round: u32,
    conn: Conn,
}

/// The coordinator: bound listener + handshake acceptor + round driver.
pub struct ClusterServer {
    spec: ExperimentSpec,
    opts: ServeOpts,
    data: FedDataset,
    backend: Backend,
    params: RoundParams,
    addr: SocketAddr,
    pending: Receiver<Join>,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    digest: u64,
    /// The validated snapshot to resume from (loaded at bind, so a
    /// corrupt or mismatched checkpoint fails before any client joins).
    restore: Option<Checkpoint>,
}

impl ClusterServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting registrations.  The round loop does not start until
    /// [`ClusterServer::run`].
    pub fn bind(addr: &str, spec: &ExperimentSpec, opts: ServeOpts) -> Result<Self> {
        let backend = native_backend(spec)?;
        let data = spec.data.build();
        let params = RoundParams::from_spec(spec, &backend);
        anyhow::ensure!(
            params.algo != Algo::FedKd,
            "FedE-KD requires the XLA backend and cannot run on a cluster"
        );
        let n = data.clients.len();
        let digest = spec_digest(spec);
        let throttle = opts.bandwidth.map(Throttle::new);
        let restore = match &opts.restore {
            Some(dir) => Some(checkpoint::load(dir, digest)?),
            None => None,
        };

        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let astop = stop.clone();
        let (pending_tx, pending_rx) = channel::<Join>();
        let acceptor = std::thread::spawn(move || loop {
            let Ok((sock, _peer)) = listener.accept() else { return };
            if astop.load(Ordering::Relaxed) {
                return;
            }
            // handshake inline: registrations are rare and tiny, and the
            // 10 s hello timeout bounds how long a silent peer can stall
            // the acceptor
            match handshake(sock, digest, n, throttle) {
                Ok(join) => {
                    if pending_tx.send(join).is_err() {
                        return; // server dropped
                    }
                }
                Err(_) => continue, // rejected or vanished; socket dropped
            }
        });

        Ok(Self {
            spec: spec.clone(),
            opts,
            data,
            backend,
            params,
            addr: local,
            pending: pending_rx,
            stop,
            acceptor: Some(acceptor),
            digest,
            restore,
        })
    }

    /// The bound address (useful with an ephemeral `--bind` port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The spec this server registers clients against.
    pub fn spec(&self) -> &ExperimentSpec {
        &self.spec
    }

    /// Drive the run to completion and return the outcome plus measured
    /// per-round wall-clock.  Blocks until [`ServeOpts::expect`] clients
    /// have registered, then loops rounds until convergence or
    /// `max_rounds`; errors only if the whole fleet is gone and nobody
    /// rejoins within one deadline, or on an internal engine failure.
    pub fn run(mut self, extra: &mut [&mut dyn RunObserver]) -> Result<ClusterOutcome> {
        let acct = Accounting::new();
        let mut hist = HistoryObserver::new();
        let mut times = RoundTimes::new();
        let width_res = {
            let mut observers: Vec<&mut dyn RunObserver> = Vec::with_capacity(1 + extra.len());
            observers.push(&mut hist);
            for o in extra.iter_mut() {
                observers.push(&mut **o);
            }
            drive_cluster(
                &self.data,
                &self.params,
                &self.backend,
                &self.opts,
                &self.pending,
                &acct,
                &mut times,
                &mut observers,
                self.digest,
                self.restore.as_ref(),
            )
        };
        // stop the acceptor whatever happened: raise the flag, then
        // self-connect to unblock its `accept`
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let width = width_res?;
        let eq5 = matches!(self.params.algo, Algo::FedS { .. })
            .then(|| comm_ratio(self.params.sparsity, self.params.sync_interval, width));
        let mut history = hist.take();
        if let Some(ckpt) = &self.restore {
            // restored records are not re-emitted as events; the final
            // history is checkpointed rounds followed by resumed ones
            let mut records = ckpt.records.clone();
            records.append(&mut history.records);
            history.records = records;
        }
        Ok(ClusterOutcome {
            run: RunOutcome { history, acct, eq5_ratio: eq5 },
            times,
        })
    }
}

/// Validate one incoming socket's hello.  Refusals send a
/// [`ClusterMsg::Reject`] with the reason before the socket drops.
fn handshake(sock: TcpStream, digest: u64, n: usize, throttle: Option<Throttle>) -> Result<Join> {
    sock.set_read_timeout(Some(Duration::from_secs(10)))?;
    let frame = match read_frame(&mut (&sock)) {
        Ok(Some(f)) => f,
        Ok(None) => anyhow::bail!("peer closed before the hello"),
        Err(e) => anyhow::bail!("hello never arrived: {e}"),
    };
    let hello = ClusterMsg::decode(&frame)?;
    let ClusterMsg::Hello { version, client, spec_digest, join_round } = hello else {
        reject(&sock, "the first frame must be a hello");
        anyhow::bail!("first frame was not a hello");
    };
    if version != PROTO_VERSION {
        let why = format!("unsupported protocol version {version}, server speaks {PROTO_VERSION}");
        reject(&sock, &why);
        anyhow::bail!("protocol version mismatch");
    }
    if spec_digest != digest {
        reject(&sock, "experiment spec mismatch: this server is running a different spec");
        anyhow::bail!("spec digest mismatch");
    }
    if client as usize >= n {
        let why = format!("client id {client} out of range (the spec has {n} clients)");
        reject(&sock, &why);
        anyhow::bail!("client id out of range");
    }
    let conn = Conn::new(sock, throttle)?;
    Ok(Join { client, join_round, conn })
}

fn reject(sock: &TcpStream, reason: &str) {
    let frame = ClusterMsg::Reject { reason: reason.to_string() }.encode();
    let _ = write_frame(&mut (&*sock), &frame);
}

/// Fleet membership state: live connections, dropout history, the cached
/// last personalized download per id (the rejoin resync), and uploads
/// carried over from cut stragglers.
struct Fleet {
    members: Vec<Option<Conn>>,
    dropped_before: Vec<bool>,
    last_download: Vec<Option<Vec<u8>>>,
    carried: Vec<(u16, Upload)>,
}

impl Fleet {
    fn new(n: usize) -> Self {
        Self {
            members: (0..n).map(|_| None).collect(),
            dropped_before: vec![false; n],
            last_download: vec![None; n],
            carried: Vec::new(),
        }
    }

    fn live(&self) -> usize {
        self.members.iter().filter(|m| m.is_some()).count()
    }

    fn conn(&self, id: usize) -> Option<&Conn> {
        self.members[id].as_ref()
    }

    /// Welcome a registration (or refuse a duplicate id).  On a rejoin
    /// the server replays its cached last personalized download so the
    /// client recovers the shared rows it missed while away.
    fn admit(&mut self, join: Join, round: usize, observers: &mut [&mut dyn RunObserver]) {
        let id = join.client as usize;
        if self.members[id].is_some() {
            let _ = join.conn.send(&ClusterMsg::Reject {
                reason: format!("client {id} is already registered"),
            });
            join.conn.finish();
            return;
        }
        let rejoin = self.dropped_before[id];
        let resync = if rejoin { self.last_download[id].clone() } else { None };
        let welcome = ClusterMsg::Welcome { round: round as u32, resync };
        if join.conn.send(&welcome).is_ok() {
            self.members[id] = Some(join.conn);
            emit(observers, &RunEvent::ClientJoined { round, client: id, rejoin });
            if rejoin {
                emit(observers, &RunEvent::ClientReconnected { round, client: id });
            }
        }
    }

    /// Remove a member whose link ended (or blew the deadline).  Anything
    /// it had already delivered is salvaged: a completed upload is
    /// metered and **carried** into the next round's aggregation.
    fn cut(
        &mut self,
        id: usize,
        round: usize,
        acct: &Accounting,
        obs: &mut [&mut dyn RunObserver],
    ) {
        let Some(conn) = self.members[id].take() else { return };
        while let Ok(Some(msg)) = conn.recv_timeout(Duration::ZERO) {
            if let ClusterMsg::Upload(frame) = msg {
                if let Ok(up) = Upload::decode(&frame) {
                    acct.record(Direction::Upload, up.params(), frame.len() as u64);
                    self.carried.push((id as u16, up));
                }
            }
        }
        let clean = matches!(conn.disconnect_reason(), Some(Disconnect::Clean));
        self.dropped_before[id] = true;
        emit(obs, &RunEvent::ClientDropped { round, client: id, clean });
        conn.finish();
    }
}

/// What to do with a registration arriving while the coordinator is at
/// `round`.  A future `join_round` from a *fresh* id is the documented
/// deferred-join feature and is held; the same claim from an id that
/// already dropped means the peer is ahead of this coordinator — only
/// possible when a restore lost rounds relative to the fleet — and is
/// refused with a reason the client surfaces verbatim.
enum Intake {
    Due(Join),
    Hold(Join),
}

fn intake(fleet: &Fleet, j: Join, round: usize) -> Option<Intake> {
    if (j.join_round as usize) <= round {
        return Some(Intake::Due(j));
    }
    if fleet.dropped_before[j.client as usize] {
        let reason = format!(
            "join round {} is ahead of the coordinator (round {round}): the coordinator \
             was restored from a checkpoint older than this client's position",
            j.join_round
        );
        let _ = j.conn.send(&ClusterMsg::Reject { reason });
        j.conn.finish();
        return None;
    }
    Some(Intake::Hold(j))
}

/// The ids participating in `round`: everyone live under `Full`,
/// otherwise a seeded draw keyed only by `(seed, round)` — see
/// [`SAMPLE_SALT`] — of [`ParticipationSpec::sample_size`] ids, in
/// ascending order.
fn sample_round(params: &RoundParams, live: &[usize], round: usize) -> Vec<usize> {
    if params.participation == ParticipationSpec::Full {
        return live.to_vec();
    }
    let k = params.participation.sample_size(live.len());
    let mut pool = live.to_vec();
    let mut rng = Rng::new(params.seed ^ SAMPLE_SALT ^ round as u64);
    for i in 0..k {
        let j = i + rng.usize_below(pool.len() - i);
        pool.swap(i, j);
    }
    pool.truncate(k);
    pool.sort_unstable();
    pool
}

/// Fold a carried upload outside the exchange's round-parity guards: the
/// rows merge into the current round's aggregation exactly as if the
/// (now gone) client had sent them this round.
fn fold_carried(server: &mut Server, client: u16, up: &Upload) {
    match up {
        Upload::Full { emb, .. } => server.receive_all_shared(client, emb),
        Upload::Sparse { sign, emb, .. } => {
            let ids: Vec<u32> = {
                let shared = &server.shared[client as usize];
                sign.iter()
                    .enumerate()
                    .filter(|(_, &s)| s)
                    .map(|(i, _)| shared[i])
                    .collect()
            };
            server.receive(client, &ids, emb);
        }
        // packed uploads must decode against the exchange's per-client
        // reference mirror — routed through `Exchange::server_receive`
        // at the call site, never here
        Upload::Packed { .. } => {
            unreachable!("packed carried uploads fold through the exchange")
        }
    }
}

/// The cluster round loop.  Mirrors `orchestrator::drive` exactly on the
/// happy path (same event sequence, same metering points, same
/// id-ordered aggregation) and layers membership/deadline semantics on
/// top.  With `restore` set, the loop resumes at the snapshot's round + 1
/// with every cross-round structure seeded from the snapshot, so the
/// continuation is bit-identical to a run that never stopped.
#[allow(clippy::too_many_arguments)]
fn drive_cluster(
    data: &FedDataset,
    params: &RoundParams,
    backend: &Backend,
    opts: &ServeOpts,
    pending: &Receiver<Join>,
    acct: &Arc<Accounting>,
    times: &mut RoundTimes,
    observers: &mut [&mut dyn RunObserver],
    digest: u64,
    restore: Option<&Checkpoint>,
) -> Result<usize> {
    const POLL: Duration = Duration::from_millis(20);
    let Backend::Native { hyper, eval_batch, .. } = backend else {
        anyhow::bail!("the cluster runtime is native-backend only");
    };
    let dim = if params.algo == Algo::FedEPL {
        fedepl_dim(hyper.dim, params.sparsity, params.sync_interval)
    } else {
        hyper.dim
    };
    let width = params.method.entity_width(dim);
    let refs: Vec<Table> = if params.wants_refs() {
        // same probe-trainer trick as the threaded driver: every client
        // seeds from `params.seed`, so one throwaway trainer yields the
        // agreed initial reference state (SVD or pipeline transport)
        let mut probe_rng = Rng::new(params.seed);
        let mut probe = native_trainer(
            hyper,
            *eval_batch,
            params,
            data.num_entities,
            data.num_relations,
            &mut probe_rng,
        )?;
        debug_assert_eq!(probe.entity_width(), width);
        data.clients
            .iter()
            .map(|c| {
                let shared = data.shared_entities_of(c.id);
                initial_table(&mut probe, &shared, data.num_entities, width)
            })
            .collect::<Result<_>>()?
    } else {
        Vec::new()
    };
    let mut side: ServerSide = server_side(data, params, width, refs)?;
    let n = data.clients.len();
    emit(observers, &RunEvent::RunStart { label: side.label.clone(), clients: n, width });

    let mut fleet = Fleet::new(n);
    let mut held: Vec<Join> = Vec::new();
    let expect = if opts.expect == 0 { n } else { opts.expect.min(n) };

    // --- restore: seed every cross-round structure from the snapshot ----
    let mut es = EarlyStop::new(params.patience);
    let mut records: Vec<RoundRecord> = Vec::new();
    let start_round = match restore {
        Some(ckpt) => {
            anyhow::ensure!(
                ckpt.last_download.len() == n,
                "checkpoint is for {} clients, the spec has {n}",
                ckpt.last_download.len()
            );
            debug_assert_eq!(ckpt.spec_digest, digest);
            acct.preload(
                ckpt.up_params,
                ckpt.down_params,
                ckpt.up_bytes,
                ckpt.down_bytes,
                ckpt.messages,
            );
            times.secs = ckpt.secs.clone();
            es = EarlyStop::from_state(params.patience, ckpt.early_stop);
            records = ckpt.records.clone();
            fleet.last_download = ckpt.last_download.clone();
            // everyone in the old fleet is gone; whoever re-registers is
            // a rejoin and gets the resync replay
            fleet.dropped_before = vec![true; n];
            for (client, frame) in &ckpt.carried {
                let up = Upload::decode(frame)
                    .map_err(|e| anyhow::anyhow!("corrupt carried upload in checkpoint: {e}"))?;
                fleet.carried.push((*client, up));
            }
            match (&ckpt.exchange, side.exchange.as_mut()) {
                (Some(state), Some(ex)) => {
                    let mut r = WireReader::new(state);
                    ex.load_state(&mut r)?;
                    anyhow::ensure!(
                        r.remaining() == 0,
                        "trailing bytes after checkpoint exchange state"
                    );
                }
                (None, None) => {}
                _ => anyhow::bail!("checkpoint exchange state does not match this algorithm"),
            }
            ckpt.round as usize
        }
        None => 0,
    };

    // --- initial fleet barrier: wait for `expect` due registrations ----
    while fleet.live() < expect {
        match pending.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => match intake(&fleet, j, start_round + 1) {
                Some(Intake::Due(j)) => fleet.admit(j, start_round + 1, observers),
                Some(Intake::Hold(j)) => held.push(j),
                None => {}
            },
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => anyhow::bail!("accept loop terminated"),
        }
    }

    let mut converged_emitted = false;
    'rounds: for round in (start_round + 1)..=params.max_rounds {
        // --- 0. membership: admit pending registrations due this round --
        // new arrivals are vetted once at intake; entries already held
        // for a future join round are never re-vetted
        while let Ok(j) = pending.try_recv() {
            if let Some(Intake::Due(j) | Intake::Hold(j)) = intake(&fleet, j, round) {
                held.push(j);
            }
        }
        let (due, later): (Vec<Join>, Vec<Join>) =
            held.drain(..).partition(|j| (j.join_round as usize) <= round);
        held = later;
        for j in due {
            fleet.admit(j, round, observers);
        }
        while fleet.live() == 0 {
            // the whole fleet is gone: hold the round open for one
            // deadline in case a dropout rejoins, then give up
            match pending.recv_timeout(opts.deadline) {
                Ok(j) => match intake(&fleet, j, round) {
                    Some(Intake::Due(j)) => fleet.admit(j, round, observers),
                    Some(Intake::Hold(j)) => held.push(j),
                    None => {}
                },
                Err(_) => anyhow::bail!(
                    "every client disconnected and none rejoined within {:?} (round {round})",
                    opts.deadline
                ),
            }
        }

        times.start();
        emit(observers, &RunEvent::RoundStart { round });
        let eval_round = round % params.eval_every == 0;

        // --- 0b. participation: sample the round's cohort ---------------
        // under `Full` no RoundCall is sent and the wire traffic is
        // byte-identical to protocol v1 runs
        let live_ids: Vec<usize> = (0..n).filter(|&id| fleet.conn(id).is_some()).collect();
        let sampled = sample_round(params, &live_ids, round);
        if params.participation != ParticipationSpec::Full {
            for &id in &live_ids {
                let call = ClusterMsg::RoundCall {
                    round: round as u32,
                    participate: sampled.binary_search(&id).is_ok(),
                };
                let lost = match fleet.conn(id) {
                    Some(conn) => conn.send(&call).is_err(),
                    None => false,
                };
                if lost {
                    fleet.cut(id, round, acct, observers);
                }
            }
            for &id in &sampled {
                emit(observers, &RunEvent::ClientSampled { round, client: id });
            }
        }

        // --- 1. collect reports, bounded by the round deadline ----------
        let expected = sampled.len();
        let mut reports: Vec<Option<Report>> = (0..n).map(|_| None).collect();
        let deadline_at = Instant::now() + opts.deadline;
        loop {
            let mut waiting = 0usize;
            for &id in &sampled {
                if reports[id].is_some() {
                    continue;
                }
                let polled = match fleet.conn(id) {
                    Some(conn) => conn.recv_timeout(POLL),
                    None => continue,
                };
                match polled {
                    Ok(Some(ClusterMsg::Report { round: rr, loss, batches, eval }))
                        if rr as usize == round =>
                    {
                        reports[id] = Some(Report { loss, batches: batches as usize, eval });
                    }
                    // an out-of-schedule frame means the peer slipped
                    // rounds: cut it rather than aggregate inconsistently
                    Ok(Some(_)) => fleet.cut(id, round, acct, observers),
                    Ok(None) => waiting += 1,
                    Err(_) => fleet.cut(id, round, acct, observers),
                }
            }
            if waiting == 0 {
                break;
            }
            if Instant::now() >= deadline_at {
                // deadline: cut every sampled straggler, aggregate
                // partially (non-sampled members are left untouched)
                for &id in &sampled {
                    if reports[id].is_none() && fleet.conn(id).is_some() {
                        fleet.cut(id, round, acct, observers);
                    }
                }
                break;
            }
        }
        let reported: Vec<usize> = (0..n).filter(|&id| reports[id].is_some()).collect();
        if reported.len() < expected {
            let ev = RunEvent::PartialRound { round, reported: reported.len(), expected };
            emit(observers, &ev);
        }

        // --- 2. evaluation + early stopping over the reporters ----------
        if eval_round && !reported.is_empty() {
            let mut loss_sum = 0.0f64;
            let mut loss_n = 0usize;
            let mut valid_pc = Vec::new();
            let mut test_pc = Vec::new();
            let mut weights = Vec::new();
            for &id in &reported {
                let rep = reports[id].as_ref().unwrap();
                loss_sum += rep.loss as f64 * rep.batches as f64;
                loss_n += rep.batches;
                if let Some((v, t)) = rep.eval {
                    valid_pc.push(v);
                    test_pc.push(t);
                    weights.push(side.weights[id]);
                }
            }
            let valid = RankMetrics::weighted(&valid_pc, &weights);
            let test = RankMetrics::weighted(&test_pc, &weights);
            let mean_loss = if loss_n > 0 { loss_sum / loss_n as f64 } else { 0.0 };
            let record = RoundRecord {
                round,
                params_cum: acct.params(),
                bytes_cum: acct.bytes(),
                valid,
                test,
                mean_loss,
            };
            records.push(record.clone());
            emit(observers, &RunEvent::Evaluated { record });
            let stop = es.update(valid.mrr);
            for &id in &reported {
                let lost = match fleet.conn(id) {
                    Some(conn) => conn.send(&ClusterMsg::Verdict { stop }).is_err(),
                    None => false,
                };
                if lost {
                    fleet.cut(id, round, acct, observers);
                }
            }
            if stop {
                // non-sampled members are parked waiting for the next
                // round call; tell them the run is over (no-op under
                // `Full`, where every live member reported)
                for id in 0..n {
                    if reports[id].is_none() {
                        if let Some(conn) = fleet.conn(id) {
                            let _ = conn.send(&ClusterMsg::Verdict { stop: true });
                        }
                    }
                }
                emit(observers, &RunEvent::Converged { record_index: es.best_index() });
                converged_emitted = true;
                times.stop();
                break 'rounds;
            }
        }

        // --- 3. communication over the surviving reporters --------------
        if let Some(ex) = side.exchange.as_mut() {
            ex.begin_round(round as u32);
            side.server.begin_round();
            // carried uploads first, in id order, so bit-stable results
            // never depend on when a dropout was detected
            fleet.carried.sort_by_key(|(c, _)| *c);
            for (c, up) in std::mem::take(&mut fleet.carried) {
                if matches!(up, Upload::Packed { .. }) {
                    ex.server_receive(&mut side.server, c, up)?;
                } else {
                    fold_carried(&mut side.server, c, &up);
                }
            }
            for &id in &reported {
                if side.server.shared[id].is_empty() || fleet.conn(id).is_none() {
                    continue;
                }
                let got = fleet.conn(id).unwrap().recv_timeout(opts.deadline);
                match got {
                    Ok(Some(ClusterMsg::Upload(frame))) => match Upload::decode(&frame) {
                        Ok(up) => {
                            acct.record(Direction::Upload, up.params(), frame.len() as u64);
                            ex.server_receive(&mut side.server, id as u16, up)?;
                        }
                        Err(_) => fleet.cut(id, round, acct, observers),
                    },
                    _ => fleet.cut(id, round, acct, observers),
                }
            }
            let up_params = acct.params_dir(Direction::Upload);
            let up_bytes = acct.bytes_dir(Direction::Upload);
            emit(
                observers,
                &RunEvent::UploadAccounted {
                    round,
                    params_cum: acct.params(),
                    bytes_cum: acct.bytes(),
                    messages: acct.messages(),
                },
            );
            for &id in &reported {
                if side.server.shared[id].is_empty() || fleet.conn(id).is_none() {
                    continue;
                }
                let msg = ex.server_download(round as u32, &mut side.server, id as u16)?;
                let frame = msg.encode();
                acct.record(Direction::Download, msg.params(), frame.len() as u64);
                fleet.last_download[id] = Some(frame.clone());
                let lost = fleet.conn(id).unwrap().send(&ClusterMsg::Download(frame)).is_err();
                if lost {
                    fleet.cut(id, round, acct, observers);
                }
            }
            emit(
                observers,
                &RunEvent::Synced {
                    round,
                    params_cum: up_params + acct.params_dir(Direction::Download),
                    bytes_cum: up_bytes + acct.bytes_dir(Direction::Download),
                },
            );
        }
        times.stop();

        // --- 4. round-boundary checkpoint + fault injection -------------
        if let Some(dir) = &opts.checkpoint {
            if round % opts.checkpoint_every.max(1) as usize == 0 {
                let ckpt = Checkpoint {
                    spec_digest: digest,
                    round: round as u32,
                    early_stop: es.state(),
                    up_params: acct.params_dir(Direction::Upload),
                    down_params: acct.params_dir(Direction::Download),
                    up_bytes: acct.bytes_dir(Direction::Upload),
                    down_bytes: acct.bytes_dir(Direction::Download),
                    messages: acct.messages(),
                    secs: times.secs.clone(),
                    records: records.clone(),
                    last_download: fleet.last_download.clone(),
                    carried: fleet.carried.iter().map(|(c, up)| (*c, up.encode())).collect(),
                    exchange: side.exchange.as_ref().map(|ex| {
                        let mut w = WireWriter::new();
                        ex.save_state(&mut w);
                        w.finish()
                    }),
                };
                let bytes = checkpoint::save(dir, &ckpt)?;
                emit(observers, &RunEvent::CheckpointWritten { round, bytes });
                if opts.halt_after_checkpoint == Some(round as u32) {
                    return Err(CoordinatorHalted { round }.into());
                }
                if opts.kill_after_checkpoint == Some(round as u32) {
                    super::chaos::sigkill_self();
                }
            }
        }
    }

    if !converged_emitted && !records.is_empty() {
        let idx = es.best_index().min(records.len() - 1);
        emit(observers, &RunEvent::Converged { record_index: idx });
    }
    emit(
        observers,
        &RunEvent::RunEnd {
            params: acct.params(),
            bytes: acct.bytes(),
            messages: acct.messages(),
        },
    );

    // graceful teardown: flush every member's outbox (final downloads /
    // verdicts) before the sockets close, and refuse whoever never got in
    for m in fleet.members.iter_mut() {
        if let Some(conn) = m.take() {
            conn.finish();
        }
    }
    for j in held {
        let reason = "the run ended before your join round".to_string();
        let _ = j.conn.send(&ClusterMsg::Reject { reason });
        j.conn.finish();
    }
    Ok(width)
}
