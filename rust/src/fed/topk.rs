//! Entity-Wise Top-K selection — the paper's core mechanism.
//!
//! Upstream (§III-C): clients rank their shared entities by embedding
//! change (Eq. 1, `1 − cos(E^t, E^h)`) and upload the K with the greatest
//! change, `K = N_c × p` (Eq. 2).
//!
//! Downstream (§III-D): the server ranks each client's aggregated entities
//! by **priority weight** (the number of other clients that uploaded the
//! entity this round) and sends the Top-K, breaking equal-priority ties
//! randomly.  Entities nobody uploaded are not available; if fewer than K
//! are available, all available are sent.

use crate::util::rng::Rng;

/// Eq. 2: K = N_c × p (floor, at least 1 when N_c > 0 and p > 0).
pub fn top_k_count(n_shared: usize, sparsity: f64) -> usize {
    if n_shared == 0 || sparsity <= 0.0 {
        return 0;
    }
    ((n_shared as f64 * sparsity) as usize).max(1).min(n_shared)
}

/// Upstream selection: indices (into the shared list) of the K largest
/// change scores, descending.  Deterministic: ties broken by lower index.
pub fn select_by_change(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    // partial selection: full sort is fine at N_c ≤ tens of thousands, but
    // select_nth keeps the hot path O(n)
    if k < idx.len() {
        idx.select_nth_unstable_by(k, |&a, &b| {
            scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(k);
    }
    idx.sort_unstable_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

/// Downstream selection: indices of available entities (priority > 0),
/// ranked by priority descending, equal-priority ties shuffled randomly
/// (§III-D "a random strategy is employed").  Returns at most `k`.
///
/// O(n + k log k): a random permutation makes the threshold tie-break
/// uniform, `select_nth_unstable` partitions the top-k without sorting
/// the tail (mirroring `select_by_change`), and only the k winners are
/// sorted for the caller.
pub fn select_by_priority(priorities: &[u32], k: usize, rng: &mut Rng) -> Vec<usize> {
    let mut avail: Vec<usize> = (0..priorities.len()).filter(|&i| priorities[i] > 0).collect();
    if avail.len() > k {
        // shuffle first so the partial selection's equal-priority
        // tie-break at the threshold is random
        rng.shuffle(&mut avail);
        avail.select_nth_unstable_by(k, |&a, &b| priorities[b].cmp(&priorities[a]));
        avail.truncate(k);
    }
    avail.sort_by(|&a, &b| priorities[b].cmp(&priorities[a]));
    avail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn k_formula() {
        assert_eq!(top_k_count(100, 0.4), 40);
        assert_eq!(top_k_count(99, 0.4), 39);
        assert_eq!(top_k_count(3, 0.1), 1); // at least one
        assert_eq!(top_k_count(0, 0.4), 0);
        assert_eq!(top_k_count(10, 0.0), 0);
        assert_eq!(top_k_count(10, 1.0), 10);
    }

    #[test]
    fn change_selection_picks_largest() {
        let scores = [0.1, 0.9, 0.3, 0.7, 0.0];
        assert_eq!(select_by_change(&scores, 2), vec![1, 3]);
        assert_eq!(select_by_change(&scores, 5), vec![1, 3, 2, 0, 4]);
        assert_eq!(select_by_change(&scores, 9), vec![1, 3, 2, 0, 4]);
    }

    #[test]
    fn change_selection_property() {
        check("topk_change", 30, |rng| {
            let n = 1 + rng.usize_below(200);
            let k = rng.usize_below(n + 4);
            let scores: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 2.0)).collect();
            let sel = select_by_change(&scores, k);
            assert_eq!(sel.len(), k.min(n));
            // every selected ≥ every unselected
            let min_sel = sel.iter().map(|&i| scores[i]).fold(f32::INFINITY, f32::min);
            for i in 0..n {
                if !sel.contains(&i) {
                    assert!(scores[i] <= min_sel + 1e-6);
                }
            }
            // no duplicates
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), sel.len());
        });
    }

    #[test]
    fn priority_selection_excludes_unavailable() {
        let mut rng = Rng::new(1);
        let prio = [0u32, 3, 0, 1, 2];
        let sel = select_by_priority(&prio, 10, &mut rng);
        let mut s = sel.clone();
        s.sort_unstable();
        assert_eq!(s, vec![1, 3, 4]); // all available, fewer than k
    }

    #[test]
    fn priority_selection_ranks_by_count() {
        let mut rng = Rng::new(2);
        let prio = [1u32, 5, 2, 4, 3];
        let sel = select_by_priority(&prio, 2, &mut rng);
        assert_eq!(sel, vec![1, 3]);
    }

    #[test]
    fn priority_ties_are_random_but_valid() {
        let prio = vec![2u32; 10];
        let mut seen = std::collections::HashSet::new();
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let sel = select_by_priority(&prio, 3, &mut rng);
            assert_eq!(sel.len(), 3);
            seen.insert(sel);
        }
        // across seeds the random tie-break must produce variety
        assert!(seen.len() > 3, "tie-break not random: {} variants", seen.len());
    }

    /// The partial selection must pick exactly the priorities a full
    /// descending sort would (the tie-break may pick different *indices*,
    /// but the selected priority multiset is determined).
    #[test]
    fn priority_partial_selection_matches_full_sort_multiset() {
        check("topk_priority_partial", 30, |rng| {
            let n = 1 + rng.usize_below(300);
            let k = rng.usize_below(n + 3);
            let prio: Vec<u32> = (0..n).map(|_| rng.u32_below(6)).collect();
            let sel = select_by_priority(&prio, k, rng);
            let mut want: Vec<u32> = prio.iter().copied().filter(|&p| p > 0).collect();
            want.sort_unstable_by(|a, b| b.cmp(a));
            want.truncate(k);
            let got: Vec<u32> = sel.iter().map(|&i| prio[i]).collect();
            assert_eq!(got, want, "selected priorities must match a full sort");
        });
    }

    #[test]
    fn priority_property() {
        check("topk_priority", 30, |rng| {
            let n = 1 + rng.usize_below(100);
            let k = rng.usize_below(n + 3);
            let prio: Vec<u32> = (0..n).map(|_| rng.u32_below(4)).collect();
            let sel = select_by_priority(&prio, k, rng);
            assert!(sel.len() <= k);
            assert!(sel.iter().all(|&i| prio[i] > 0));
            let avail = prio.iter().filter(|&&p| p > 0).count();
            assert_eq!(sel.len(), k.min(avail));
            if !sel.is_empty() {
                let min_sel = sel.iter().map(|&i| prio[i]).min().unwrap();
                for i in 0..n {
                    if prio[i] > 0 && !sel.contains(&i) {
                        assert!(prio[i] <= min_sel);
                    }
                }
            }
        });
    }
}
