//! Intermittent Synchronization Mechanism (§III-E).
//!
//! Every `interval` rounds since the last synchronization, clients and the
//! server exchange ALL parameters (a dense FedE-style round), re-aligning
//! the embeddings of shared entities across clients and bounding the drift
//! that personalized sparse updates accumulate.

#[derive(Clone, Debug)]
pub struct SyncSchedule {
    /// `None` disables synchronization entirely (the FedS/syn ablation).
    pub interval: Option<usize>,
    last_sync: usize,
}

impl SyncSchedule {
    pub fn new(interval: Option<usize>) -> Self {
        assert!(interval != Some(0), "sync interval must be >= 1");
        Self { interval, last_sync: 0 }
    }

    /// Should round `round` (1-based) be a full synchronization round?
    /// "clients and server check if the difference between the current
    /// round and the last synchronization round matches a predefined
    /// interval" (§III-E).
    pub fn is_sync(&self, round: usize) -> bool {
        match self.interval {
            None => false,
            Some(s) => round - self.last_sync >= s + 1,
        }
    }

    /// Record that a synchronization happened at `round`.
    pub fn mark(&mut self, round: usize) {
        self.last_sync = round;
    }

    /// The round of the most recent synchronization (0 before the first).
    pub fn last_sync(&self) -> usize {
        self.last_sync
    }

    /// Rebuild a schedule at an exact position saved via [`last_sync`].
    ///
    /// [`last_sync`]: SyncSchedule::last_sync
    pub fn restore(interval: Option<usize>, last_sync: usize) -> Self {
        let mut s = Self::new(interval);
        s.last_sync = last_sync;
        s
    }

    /// Convenience: check-and-mark in one step.
    pub fn step(&mut self, round: usize) -> bool {
        if self.is_sync(round) {
            self.mark(round);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_s_means_s_sparse_rounds_between_syncs() {
        // s = 4 → "there are s communication rounds between two consecutive
        // synchronization operations (exclusive)" (§III-F)
        let mut sched = SyncSchedule::new(Some(4));
        let flags: Vec<bool> = (1..=11).map(|r| sched.step(r)).collect();
        assert_eq!(
            flags,
            vec![false, false, false, false, true, false, false, false, false, true, false]
        );
    }

    #[test]
    fn cycle_length_matches_eq5() {
        // a cycle = s sparse rounds + 1 sync round = s + 1 rounds (Eq. 5's
        // denominator)
        let mut sched = SyncSchedule::new(Some(3));
        let mut syncs = 0;
        for r in 1..=40 {
            if sched.step(r) {
                syncs += 1;
            }
        }
        assert_eq!(syncs, 10); // 40 / (3 + 1)
    }

    #[test]
    fn none_never_syncs() {
        let mut sched = SyncSchedule::new(None);
        assert!((1..=100).all(|r| !sched.step(r)));
    }

    #[test]
    fn interval_one_alternates() {
        let mut sched = SyncSchedule::new(Some(1));
        let flags: Vec<bool> = (1..=6).map(|r| sched.step(r)).collect();
        assert_eq!(flags, vec![false, true, false, true, false, true]);
    }
}
