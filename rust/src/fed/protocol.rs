//! Wire protocol between clients and the server.
//!
//! Three message kinds per direction (dense/full, sparsified, and
//! stage-tagged packed), encoded via the byte-exact `comm::wire` codec.
//! Every message also reports its **paper-parameter count** (§III-F
//! convention: each embedding float, each sign-vector element and each
//! priority entry counts as one parameter), which is what Tables I/III/IV
//! meter; the byte size of the encoded frame is metered separately by the
//! transport/accounting layer.  `Packed` frames carry a
//! [`compression::PackedBlock`] — the output of a `--compress` pipeline —
//! whose byte size reflects the *actual packed payload* (quantized codes,
//! factor floats, bit-packed selection), so transport metering prices the
//! compression stack for free.
//!
//! [`compression::PackedBlock`]: crate::fed::compression::PackedBlock

use anyhow::Result;

use crate::comm::wire::{WireReader, WireWriter};
use crate::fed::compression::PackedBlock;

/// client → server
#[derive(Clone, Debug, PartialEq)]
pub enum Upload {
    /// All shared-entity embeddings (dense FedE round or FedS sync round).
    Full { round: u32, client: u16, emb: Vec<f32> },
    /// Entity-wise Top-K: sign bits over the client's shared list (in
    /// sorted shared-id order) + the selected rows.
    Sparse {
        round: u32,
        client: u16,
        sign: Vec<bool>,
        emb: Vec<f32>,
    },
    /// Compression-pipeline output: a self-describing stage-tagged block
    /// (selection bitmap + byte-packed rows).
    Packed { round: u32, client: u16, block: PackedBlock },
}

/// server → client
#[derive(Clone, Debug, PartialEq)]
pub enum Download {
    /// Aggregated embeddings for every shared entity of the client.
    Full { round: u32, emb: Vec<f32> },
    /// Personalized Top-K: sign bits + aggregated rows + priority weights
    /// (|C_{c,e}^t| per selected entity, same order as the rows).
    Sparse {
        round: u32,
        sign: Vec<bool>,
        emb: Vec<f32>,
        prio: Vec<u32>,
    },
    /// Compression-pipeline output for the downstream direction.
    Packed { round: u32, block: PackedBlock },
}

const TAG_FULL: u8 = 0;
const TAG_SPARSE: u8 = 1;
const TAG_PACKED: u8 = 2;

impl Upload {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        match self {
            Upload::Full { round, client, emb } => {
                w.u8(TAG_FULL).u32(*round).u16(*client).f32s(emb);
            }
            Upload::Sparse { round, client, sign, emb } => {
                w.u8(TAG_SPARSE).u32(*round).u16(*client).bits(sign).f32s(emb);
            }
            Upload::Packed { round, client, block } => {
                w.u8(TAG_PACKED).u32(*round).u16(*client);
                block.write(&mut w);
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Upload> {
        let mut r = WireReader::new(buf);
        let tag = r.u8()?;
        let round = r.u32()?;
        let client = r.u16()?;
        Ok(match tag {
            TAG_FULL => Upload::Full { round, client, emb: r.f32s()? },
            TAG_SPARSE => {
                let sign = r.bits()?;
                let emb = r.f32s()?;
                Upload::Sparse { round, client, sign, emb }
            }
            TAG_PACKED => Upload::Packed { round, client, block: PackedBlock::read(&mut r)? },
            t => anyhow::bail!("bad upload tag {t}"),
        })
    }

    /// Paper-parameter count (§III-F).
    pub fn params(&self) -> u64 {
        match self {
            Upload::Full { emb, .. } => emb.len() as u64,
            Upload::Sparse { sign, emb, .. } => sign.len() as u64 + emb.len() as u64,
            Upload::Packed { block, .. } => block.params(),
        }
    }
}

impl Download {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        match self {
            Download::Full { round, emb } => {
                w.u8(TAG_FULL).u32(*round).f32s(emb);
            }
            Download::Sparse { round, sign, emb, prio } => {
                w.u8(TAG_SPARSE).u32(*round).bits(sign).f32s(emb).u32s(prio);
            }
            Download::Packed { round, block } => {
                w.u8(TAG_PACKED).u32(*round);
                block.write(&mut w);
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Download> {
        let mut r = WireReader::new(buf);
        let tag = r.u8()?;
        let round = r.u32()?;
        Ok(match tag {
            TAG_FULL => Download::Full { round, emb: r.f32s()? },
            TAG_SPARSE => {
                let sign = r.bits()?;
                let emb = r.f32s()?;
                let prio = r.u32s()?;
                Download::Sparse { round, sign, emb, prio }
            }
            TAG_PACKED => Download::Packed { round, block: PackedBlock::read(&mut r)? },
            t => anyhow::bail!("bad download tag {t}"),
        })
    }

    pub fn params(&self) -> u64 {
        match self {
            Download::Full { emb, .. } => emb.len() as u64,
            Download::Sparse { sign, emb, prio, .. } => {
                sign.len() as u64 + emb.len() as u64 + prio.len() as u64
            }
            Download::Packed { block, .. } => block.params(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upload_roundtrip() {
        let msgs = [
            Upload::Full { round: 3, client: 1, emb: vec![1.0, -2.0, 0.5] },
            Upload::Sparse {
                round: 9,
                client: 4,
                sign: vec![true, false, true, true, false],
                emb: vec![0.25; 8],
            },
        ];
        for m in msgs {
            assert_eq!(Upload::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn download_roundtrip() {
        let msgs = [
            Download::Full { round: 1, emb: vec![9.0; 4] },
            Download::Sparse {
                round: 2,
                sign: vec![false, true],
                emb: vec![1.0, 2.0],
                prio: vec![3],
            },
        ];
        for m in msgs {
            assert_eq!(Download::decode(&m.encode()).unwrap(), m);
        }
    }

    #[test]
    fn paper_param_counts() {
        // sparse upload: K·W emb + N_c sign
        let up = Upload::Sparse {
            round: 0,
            client: 0,
            sign: vec![true; 100],
            emb: vec![0.0; 40 * 8],
        };
        assert_eq!(up.params(), 100 + 320);
        // sparse download adds K priorities
        let down = Download::Sparse {
            round: 0,
            sign: vec![true; 100],
            emb: vec![0.0; 40 * 8],
            prio: vec![1; 40],
        };
        assert_eq!(down.params(), 100 + 320 + 40);
        // dense counts only embeddings
        assert_eq!(Upload::Full { round: 0, client: 0, emb: vec![0.0; 64] }.params(), 64);
    }

    #[test]
    fn sparse_bytes_smaller_than_params_suggest() {
        // sign bits are bit-packed on the wire (paper counts them as f32)
        let up = Upload::Sparse {
            round: 0,
            client: 0,
            sign: vec![false; 800],
            emb: vec![],
        };
        let bytes = up.encode().len();
        assert!(bytes < 800 / 8 + 32, "bytes {bytes}");
    }

    #[test]
    fn bad_tag_errors() {
        assert!(Upload::decode(&[7, 0, 0, 0, 0, 0, 0]).is_err());
    }

    #[test]
    fn packed_roundtrip_and_params() {
        use crate::fed::compression::{PackedBlock, StageSpec};
        let block = PackedBlock {
            stages: vec![StageSpec::TopK { ratio: 0.5, ef: true }, StageSpec::Int8 { ef: false }],
            n_in: 4,
            sel: vec![true, false, true, false],
            width: 8,
            body: vec![0u8; 2 * (4 + 8)],
        };
        let up = Upload::Packed { round: 5, client: 2, block: block.clone() };
        assert_eq!(Upload::decode(&up.encode()).unwrap(), up);
        // 4 sel bits + 2 rows × (8 codes + 1 scale)
        assert_eq!(up.params(), 4 + 2 * 9);
        let down = Download::Packed { round: 5, block };
        assert_eq!(Download::decode(&down.encode()).unwrap(), down);
        assert_eq!(down.params(), 4 + 2 * 9);
    }

    #[test]
    fn legacy_tags_encode_unchanged() {
        // adding TAG_PACKED must not perturb the v2 byte layout of the
        // existing frames — spot-check the exact prefix bytes
        let up = Upload::Full { round: 1, client: 2, emb: vec![1.0] };
        let buf = up.encode();
        assert_eq!(&buf[..7], &[0, 1, 0, 0, 0, 2, 0], "tag, round LE, client LE");
        let down = Download::Sparse { round: 3, sign: vec![true], emb: vec![], prio: vec![] };
        assert_eq!(down.encode()[0], 1);
    }

    #[test]
    fn truncated_packed_is_error_not_panic() {
        use crate::fed::compression::{PackedBlock, StageSpec};
        let block = PackedBlock {
            stages: vec![StageSpec::Fp16 { ef: false }],
            n_in: 2,
            sel: vec![true, true],
            width: 4,
            body: vec![0u8; 16],
        };
        let buf = Upload::Packed { round: 0, client: 0, block }.encode();
        for cut in 0..buf.len() {
            assert!(Upload::decode(&buf[..cut]).is_err(), "cut {cut} must error");
        }
    }
}
