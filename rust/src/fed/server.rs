//! Server-side state: per-round upload accumulation, FedE-style dense
//! aggregation, and FedS's personalized aggregation (Eq. 3) + priority
//! computation (§III-D).
//!
//! Eq. 3: `A_{c,e}^t = Σ_{i ∈ C_{c,e}^t} E_{i,e}^t` where `C_{c,e}^t` is
//! the set of clients **other than c** that uploaded entity e this round;
//! the priority weight `P_{c,e}^t = |C_{c,e}^t|`.

use std::collections::HashMap;

use crate::util::rng::Rng;

use super::topk::select_by_priority;

pub struct Server {
    pub num_entities: usize,
    pub width: usize,
    /// registered shared-entity lists (sorted global ids), per client
    pub shared: Vec<Vec<u32>>,
    /// Σ of all uploads this round, per entity (E × W).  Invariant:
    /// entities not in `dirty` have an all-zero sum row and a zero count,
    /// so per-round reset work scales with what was uploaded, not E.
    sum: Vec<f32>,
    /// number of uploaders this round, per entity
    count: Vec<u32>,
    /// entities with ≥1 upload this round, in first-upload order
    dirty: Vec<u32>,
    /// this round's per-client uploads: id → row offset in `rows[c]`
    /// (maps and row buffers are cleared, never reallocated, per round)
    uploaded: Vec<HashMap<u32, usize>>,
    rows: Vec<Vec<f32>>,
}

impl Server {
    pub fn new(num_entities: usize, width: usize, shared: Vec<Vec<u32>>) -> Self {
        let n_clients = shared.len();
        Self {
            num_entities,
            width,
            shared,
            sum: vec![0.0; num_entities * width],
            count: vec![0; num_entities],
            dirty: Vec::new(),
            uploaded: vec![HashMap::new(); n_clients],
            rows: vec![Vec::new(); n_clients],
        }
    }

    pub fn n_clients(&self) -> usize {
        self.shared.len()
    }

    /// Entities uploaded at least once this round.
    pub fn dirty_len(&self) -> usize {
        self.dirty.len()
    }

    /// Clear per-round accumulation state.  O(dirty·width + uploads) —
    /// only the rows the previous round actually touched are re-zeroed.
    pub fn begin_round(&mut self) {
        let w = self.width;
        for &id in &self.dirty {
            let e = id as usize;
            self.sum[e * w..(e + 1) * w].fill(0.0);
            self.count[e] = 0;
        }
        self.dirty.clear();
        for m in &mut self.uploaded {
            m.clear();
        }
        for r in &mut self.rows {
            r.clear();
        }
    }

    /// Accept a client's upload: `ids` (global) with concatenated `rows`.
    /// Accumulation is slice-wise per row; first touch of an entity this
    /// round registers it in the dirty list.
    pub fn receive(&mut self, client: u16, ids: &[u32], rows: &[f32]) {
        let w = self.width;
        assert_eq!(rows.len(), ids.len() * w, "upload size mismatch");
        let c = client as usize;
        for (k, &id) in ids.iter().enumerate() {
            let e = id as usize;
            let row = &rows[k * w..(k + 1) * w];
            if self.count[e] == 0 {
                self.dirty.push(id);
            }
            self.count[e] += 1;
            let dst = &mut self.sum[e * w..(e + 1) * w];
            for (d, &v) in dst.iter_mut().zip(row) {
                *d += v;
            }
            self.uploaded[c].insert(id, self.rows[c].len());
            self.rows[c].extend_from_slice(row);
        }
    }

    /// Accept a dense upload covering every registered shared entity of
    /// `client`, in shared-list order (dense and sync rounds, and the SVD
    /// transport's reconstructed states).
    pub fn receive_all_shared(&mut self, client: u16, rows: &[f32]) {
        let ids = std::mem::take(&mut self.shared[client as usize]);
        self.receive(client, &ids, rows);
        self.shared[client as usize] = ids;
    }

    /// Dense FedE aggregation for client `c`: the average over ALL
    /// uploaders of each of c's shared entities (c included).  Entities
    /// nobody uploaded keep... that cannot happen on dense rounds (every
    /// owner uploads); they fall back to zero-count guard anyway.
    pub fn fede_download(&self, c: u16) -> Vec<f32> {
        let w = self.width;
        let ids = &self.shared[c as usize];
        let mut out = vec![0.0f32; ids.len() * w];
        for (k, &id) in ids.iter().enumerate() {
            let e = id as usize;
            let n = self.count[e].max(1) as f32;
            for j in 0..w {
                out[k * w + j] = self.sum[e * w + j] / n;
            }
        }
        out
    }

    /// FedS personalized aggregation + Top-K for client `c` (§III-D).
    ///
    /// Returns `(sign, rows, prio)`: `sign[i]` marks the i-th entity of
    /// c's shared list as selected; `rows` holds the aggregated SUMS
    /// (Eq. 3, own contribution excluded) of the selected entities in
    /// shared-list order; `prio[i]` the matching |C_{c,e}|.
    pub fn feds_download(
        &self,
        c: u16,
        k: usize,
        rng: &mut Rng,
    ) -> (Vec<bool>, Vec<f32>, Vec<u32>) {
        let w = self.width;
        let ci = c as usize;
        let ids = &self.shared[ci];

        // personalized priorities: exclude c's own upload
        let prios: Vec<u32> = ids
            .iter()
            .map(|&id| {
                let own = u32::from(self.uploaded[ci].contains_key(&id));
                self.count[id as usize] - own
            })
            .collect();

        let sel = select_by_priority(&prios, k, rng);
        let mut selected = vec![false; ids.len()];
        for &i in &sel {
            selected[i] = true;
        }

        let mut rows = Vec::with_capacity(sel.len() * w);
        let mut prio_out = Vec::with_capacity(sel.len());
        for (i, &id) in ids.iter().enumerate() {
            if !selected[i] {
                continue;
            }
            let e = id as usize;
            let mut row: Vec<f32> = self.sum[e * w..(e + 1) * w].to_vec();
            if let Some(&off) = self.uploaded[ci].get(&id) {
                let own = &self.rows[ci][off..off + w];
                for j in 0..w {
                    row[j] -= own[j];
                }
            }
            rows.extend_from_slice(&row);
            prio_out.push(prios[i]);
        }
        (selected, rows, prio_out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server2() -> Server {
        // 2 clients, entities {0,1,2} shared by both; width 2
        Server::new(4, 2, vec![vec![0, 1, 2], vec![0, 1, 2]])
    }

    #[test]
    fn dense_aggregation_averages() {
        let mut s = server2();
        s.begin_round();
        s.receive(0, &[0, 1, 2], &[1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        s.receive(1, &[0, 1, 2], &[3.0, 3.0, 4.0, 4.0, 5.0, 5.0]);
        let d = s.fede_download(0);
        assert_eq!(d, vec![2.0, 2.0, 3.0, 3.0, 4.0, 4.0]);
    }

    #[test]
    fn personalized_agg_excludes_own_contribution() {
        let mut s = server2();
        s.begin_round();
        s.receive(0, &[0], &[10.0, 10.0]);
        s.receive(1, &[0, 1], &[1.0, 2.0, 3.0, 4.0]);
        let mut rng = Rng::new(1);
        let (sign, rows, prio) = s.feds_download(0, 3, &mut rng);
        // entity 0: uploaded by both → A for client 0 excludes its own 10s
        // entity 1: uploaded by client 1 only
        // entity 2: nobody → unavailable
        assert_eq!(sign, vec![true, true, false]);
        assert_eq!(rows, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(prio, vec![1, 1]);
    }

    #[test]
    fn priority_counts_other_uploaders() {
        let mut s = Server::new(4, 1, vec![vec![0], vec![0], vec![0]]);
        s.begin_round();
        s.receive(0, &[0], &[1.0]);
        s.receive(1, &[0], &[2.0]);
        s.receive(2, &[0], &[4.0]);
        let mut rng = Rng::new(1);
        let (sign, rows, prio) = s.feds_download(0, 1, &mut rng);
        assert_eq!(sign, vec![true]);
        assert_eq!(rows, vec![6.0]); // 2 + 4, own 1 excluded
        assert_eq!(prio, vec![2]);
    }

    #[test]
    fn fewer_available_than_k_sends_all() {
        let mut s = server2();
        s.begin_round();
        s.receive(1, &[2], &[7.0, 8.0]);
        let mut rng = Rng::new(1);
        let (sign, rows, prio) = s.feds_download(0, 3, &mut rng);
        assert_eq!(sign, vec![false, false, true]);
        assert_eq!(rows, vec![7.0, 8.0]);
        assert_eq!(prio, vec![1]);
    }

    #[test]
    fn receive_all_shared_covers_the_registered_list() {
        let mut s = server2();
        s.begin_round();
        s.receive_all_shared(0, &[1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        s.receive_all_shared(1, &[3.0, 3.0, 4.0, 4.0, 5.0, 5.0]);
        assert_eq!(s.shared[0], vec![0, 1, 2], "shared list must survive");
        assert_eq!(s.fede_download(0), vec![2.0, 2.0, 3.0, 3.0, 4.0, 4.0]);
    }

    #[test]
    fn begin_round_resets() {
        let mut s = server2();
        s.begin_round();
        s.receive(0, &[0], &[1.0, 1.0]);
        s.begin_round();
        let mut rng = Rng::new(1);
        let (sign, rows, _) = s.feds_download(1, 3, &mut rng);
        assert!(sign.iter().all(|&b| !b));
        assert!(rows.is_empty());
    }

    #[test]
    fn dirty_tracking_resets_only_touched_rows() {
        let mut s = server2();
        s.begin_round();
        s.receive(0, &[0, 2], &[1.0, 1.0, 3.0, 3.0]);
        assert_eq!(s.dirty_len(), 2);
        s.begin_round();
        assert_eq!(s.dirty_len(), 0);
        // a fresh round over different entities sees clean accumulators
        s.receive(1, &[1], &[5.0, 6.0]);
        assert_eq!(s.dirty_len(), 1);
        assert_eq!(s.fede_download(0), vec![0.0, 0.0, 5.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    fn duplicate_entity_across_clients_is_dirty_once() {
        let mut s = server2();
        s.begin_round();
        s.receive(0, &[1], &[1.0, 2.0]);
        s.receive(1, &[1], &[3.0, 4.0]);
        assert_eq!(s.dirty_len(), 1);
        assert_eq!(s.fede_download(0)[2..4], [2.0, 3.0]);
    }

    #[test]
    fn k_limits_selection_by_priority() {
        let mut s = Server::new(4, 1, vec![vec![0, 1, 2, 3], vec![0, 1, 2, 3], vec![0, 1]]);
        s.begin_round();
        s.receive(1, &[0, 1, 2, 3], &[1.0, 1.0, 1.0, 1.0]);
        s.receive(2, &[0, 1], &[2.0, 2.0]);
        let mut rng = Rng::new(3);
        let (sign, _, prio) = s.feds_download(0, 2, &mut rng);
        // entities 0,1 have priority 2; entities 2,3 priority 1 → top-2 = {0,1}
        assert_eq!(sign, vec![true, true, false, false]);
        assert_eq!(prio, vec![2, 2]);
    }
}
