//! Server-side state: per-round upload accumulation, FedE-style dense
//! aggregation, and FedS's personalized aggregation (Eq. 3) + priority
//! computation (§III-D) — sharded by entity range so heavy rounds
//! parallelize across OS threads.
//!
//! Eq. 3: `A_{c,e}^t = Σ_{i ∈ C_{c,e}^t} E_{i,e}^t` where `C_{c,e}^t` is
//! the set of clients **other than c** that uploaded entity e this round;
//! the priority weight `P_{c,e}^t = |C_{c,e}^t|`.
//!
//! ## Sharding
//!
//! Round state (`sum`/`count`/`dirty`/per-client upload index) is split
//! into N independent contiguous entity-range shards.  Every entity
//! belongs to exactly one shard, upload ids arrive ascending, and
//! download rows leave in shared-list (ascending-id) order, so each
//! operation decomposes into per-shard work on disjoint state and
//! disjoint output slices — no locks, and results are **bit-identical
//! for any shard count** (per-entity accumulation order is the client
//! call order regardless of sharding; Top-K selection stays global and
//! single-threaded to preserve the deterministic RNG tie-break stream).
//! Small rounds stay on the calling thread: threads are only spawned
//! when a call writes at least [`PAR_MIN_WORK`] output elements.

use std::collections::HashMap;

use crate::store::{StorageSpec, StoreTable};
use crate::util::rng::Rng;

use super::topk::select_by_priority;

/// Below this many output elements written per call, per-shard work runs
/// inline on the calling thread — thread spawn would cost more than it
/// buys.  (Row gathers count floats, priority fills count counters.)
pub const PAR_MIN_WORK: usize = 1 << 16;

/// Split `buf` into consecutive segments of `(cuts[s+1] - cuts[s]) * unit`
/// elements, one per shard — the disjoint output slices the per-shard
/// fills write into.
fn split_segments<'a, T>(
    mut rest: &'a mut [T],
    cuts: &[usize],
    unit: usize,
) -> Vec<&'a mut [T]> {
    let mut segs = Vec::with_capacity(cuts.len().saturating_sub(1));
    for s in 0..cuts.len().saturating_sub(1) {
        let (seg, tail) =
            std::mem::take(&mut rest).split_at_mut((cuts[s + 1] - cuts[s]) * unit);
        segs.push(seg);
        rest = tail;
    }
    segs
}

/// One contiguous entity range `[lo, hi)` of round state.
struct Shard {
    lo: usize,
    hi: usize,
    /// Σ of all uploads this round for entities in range ((hi-lo) × W),
    /// on the run's storage backend ([`StoreTable`] — under mmap the
    /// zero-initialized accumulator is a sparse file, so only uploaded
    /// rows ever become resident).  Invariant: entities not in `dirty`
    /// have an all-zero sum row and a zero count, so per-round reset work
    /// scales with what was uploaded.
    sum: StoreTable,
    /// number of uploaders this round, per in-range entity
    count: Vec<u32>,
    /// in-range entities (global ids) with ≥1 upload this round, in
    /// first-upload order
    dirty: Vec<u32>,
    /// this round's per-client uploads: id → row offset in `rows[c]`
    /// (maps and row buffers are cleared, never reallocated, per round)
    uploaded: Vec<HashMap<u32, usize>>,
    rows: Vec<Vec<f32>>,
}

impl Shard {
    fn begin_round(&mut self, _w: usize) {
        for &id in &self.dirty {
            let e = id as usize - self.lo;
            self.sum.row_mut(e).fill(0.0);
            self.count[e] = 0;
        }
        self.dirty.clear();
        for m in &mut self.uploaded {
            m.clear();
        }
        for r in &mut self.rows {
            r.clear();
        }
    }

    /// Fold `client`'s in-range upload slice into this shard's state.
    fn receive(&mut self, client: usize, ids: &[u32], rows: &[f32], w: usize) {
        for (k, &id) in ids.iter().enumerate() {
            let e = id as usize - self.lo;
            let row = &rows[k * w..(k + 1) * w];
            if self.count[e] == 0 {
                self.dirty.push(id);
            }
            self.count[e] += 1;
            let dst = self.sum.row_mut(e);
            for (d, &v) in dst.iter_mut().zip(row) {
                *d += v;
            }
            self.uploaded[client].insert(id, self.rows[client].len());
            self.rows[client].extend_from_slice(row);
        }
    }

    /// FedE means for the in-range slice of a client's shared list.
    fn fill_mean(&self, ids: &[u32], out: &mut [f32], w: usize) {
        for (k, &id) in ids.iter().enumerate() {
            let e = id as usize - self.lo;
            let n = self.count[e].max(1) as f32;
            let src = self.sum.row(e);
            for (o, &s) in out[k * w..(k + 1) * w].iter_mut().zip(src) {
                *o = s / n;
            }
        }
    }

    /// §III-D priorities (own upload excluded) for the in-range slice.
    fn fill_prios(&self, client: usize, ids: &[u32], out: &mut [u32]) {
        for (k, &id) in ids.iter().enumerate() {
            let own = u32::from(self.uploaded[client].contains_key(&id));
            out[k] = self.count[id as usize - self.lo] - own;
        }
    }

    /// Gather the Eq. 3 aggregates (own contribution excluded) for the
    /// selected in-range entities, in shared-list order.
    #[allow(clippy::too_many_arguments)]
    fn fill_selected(
        &self,
        client: usize,
        ids: &[u32],
        selected: &[bool],
        prios: &[u32],
        rows_out: &mut [f32],
        prio_out: &mut [u32],
        w: usize,
    ) {
        let mut j = 0usize;
        for (i, &id) in ids.iter().enumerate() {
            if !selected[i] {
                continue;
            }
            let e = id as usize - self.lo;
            let out = &mut rows_out[j * w..(j + 1) * w];
            out.copy_from_slice(self.sum.row(e));
            if let Some(&off) = self.uploaded[client].get(&id) {
                let own = &self.rows[client][off..off + w];
                for (o, &v) in out.iter_mut().zip(own) {
                    *o -= v;
                }
            }
            prio_out[j] = prios[i];
            j += 1;
        }
        debug_assert_eq!(j * w, rows_out.len());
    }
}

pub struct Server {
    pub num_entities: usize,
    pub width: usize,
    /// registered shared-entity lists (sorted global ids), per client
    pub shared: Vec<Vec<u32>>,
    shards: Vec<Shard>,
    /// parallelism gate, in output elements per call (see [`PAR_MIN_WORK`])
    par_min_work: usize,
}

impl Server {
    pub fn new(num_entities: usize, width: usize, shared: Vec<Vec<u32>>) -> Self {
        Self::with_shards(num_entities, width, shared, 1)
    }

    /// Build with `n_shards` entity-range shards (clamped to ≥ 1 and to
    /// the entity count).  Results are bit-identical for any value; only
    /// the available parallelism changes.
    pub fn with_shards(
        num_entities: usize,
        width: usize,
        shared: Vec<Vec<u32>>,
        n_shards: usize,
    ) -> Self {
        Self::with_store(num_entities, width, shared, n_shards, &StorageSpec::Ram)
            .expect("in-RAM storage is infallible")
    }

    /// [`Server::with_shards`] with the per-shard accumulators on the
    /// selected storage backend.  The shard decomposition doubles as the
    /// store decomposition: one store per shard, mutated only by its own
    /// scoped thread, so the concurrency story is unchanged.  Results
    /// are bit-identical across backends.
    pub fn with_store(
        num_entities: usize,
        width: usize,
        shared: Vec<Vec<u32>>,
        n_shards: usize,
        storage: &StorageSpec,
    ) -> anyhow::Result<Self> {
        let n = n_shards.clamp(1, num_entities.max(1));
        let n_clients = shared.len();
        let shards = (0..n)
            .map(|s| {
                let lo = s * num_entities / n;
                let hi = (s + 1) * num_entities / n;
                Ok(Shard {
                    lo,
                    hi,
                    sum: StoreTable::zeros_in(storage, hi - lo, width)?,
                    count: vec![0; hi - lo],
                    dirty: Vec::new(),
                    uploaded: vec![HashMap::new(); n_clients],
                    rows: vec![Vec::new(); n_clients],
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self { num_entities, width, shared, shards, par_min_work: PAR_MIN_WORK })
    }

    pub fn n_clients(&self) -> usize {
        self.shared.len()
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Override the inline-vs-threads work threshold (output elements per
    /// call).  `0` forces the threaded path — tests and benches use this
    /// to exercise or isolate the parallel code on small inputs.
    pub fn set_parallel_threshold(&mut self, elements: usize) {
        self.par_min_work = elements;
    }

    /// Entities uploaded at least once this round.
    pub fn dirty_len(&self) -> usize {
        self.shards.iter().map(|s| s.dirty.len()).sum()
    }

    /// The per-shard contiguous subranges of an ascending id list:
    /// `cuts[s]..cuts[s+1]` indexes the ids owned by shard `s`.
    fn cuts(&self, ids: &[u32]) -> Vec<usize> {
        debug_assert!(ids.windows(2).all(|p| p[0] < p[1]), "id lists must ascend");
        let mut cuts = Vec::with_capacity(self.shards.len() + 1);
        cuts.push(0);
        for shard in &self.shards {
            cuts.push(ids.partition_point(|&id| (id as usize) < shard.hi));
        }
        cuts
    }

    /// Run `run(s, shard, payload)` for every shard, handing shard `s`
    /// the s-th payload (typically its disjoint output segment from
    /// [`split_segments`]).  Threads are spawned only when the call
    /// writes at least `work` ≥ the parallel threshold; the inline path
    /// is identical in every other respect.
    fn run_sharded<P: Send>(
        &self,
        work: usize,
        payloads: Vec<P>,
        run: impl Fn(usize, &Shard, P) + Sync,
    ) {
        debug_assert_eq!(payloads.len(), self.shards.len());
        if self.shards.len() > 1 && work >= self.par_min_work {
            std::thread::scope(|scope| {
                for ((s, shard), payload) in self.shards.iter().enumerate().zip(payloads) {
                    let run = &run;
                    scope.spawn(move || run(s, shard, payload));
                }
            });
        } else {
            for ((s, shard), payload) in self.shards.iter().enumerate().zip(payloads) {
                run(s, shard, payload);
            }
        }
    }

    /// [`Server::run_sharded`] for mutating operations (`receive`): same
    /// gate, same inline fallback, `&mut Shard` access.
    fn run_sharded_mut<P: Send>(
        &mut self,
        work: usize,
        payloads: Vec<P>,
        run: impl Fn(&mut Shard, P) + Sync,
    ) {
        debug_assert_eq!(payloads.len(), self.shards.len());
        if self.shards.len() > 1 && work >= self.par_min_work {
            std::thread::scope(|scope| {
                for (shard, payload) in self.shards.iter_mut().zip(payloads) {
                    let run = &run;
                    scope.spawn(move || run(shard, payload));
                }
            });
        } else {
            for (shard, payload) in self.shards.iter_mut().zip(payloads) {
                run(shard, payload);
            }
        }
    }

    /// Clear per-round accumulation state.  O(dirty·width + uploads) —
    /// only the rows the previous round actually touched are re-zeroed.
    pub fn begin_round(&mut self) {
        let w = self.width;
        for shard in &mut self.shards {
            shard.begin_round(w);
        }
    }

    /// Accept a client's upload: ascending `ids` (global) with
    /// concatenated `rows`.  Accumulation is slice-wise per row; first
    /// touch of an entity this round registers it in its shard's dirty
    /// list.  Shards fold their id subranges in parallel on large
    /// uploads — bit-identical to the inline path, since every entity's
    /// accumulation order is the per-client call order either way.
    pub fn receive(&mut self, client: u16, ids: &[u32], rows: &[f32]) {
        let w = self.width;
        assert_eq!(rows.len(), ids.len() * w, "upload size mismatch");
        let c = client as usize;
        let cuts = self.cuts(ids);
        let payloads: Vec<(&[u32], &[f32])> = (0..cuts.len() - 1)
            .map(|s| (&ids[cuts[s]..cuts[s + 1]], &rows[cuts[s] * w..cuts[s + 1] * w]))
            .collect();
        self.run_sharded_mut(ids.len() * w, payloads, |shard, (ids, rows)| {
            shard.receive(c, ids, rows, w);
        });
    }

    /// Accept a dense upload covering every registered shared entity of
    /// `client`, in shared-list order (dense and sync rounds, and the SVD
    /// transport's reconstructed states).
    pub fn receive_all_shared(&mut self, client: u16, rows: &[f32]) {
        let ids = std::mem::take(&mut self.shared[client as usize]);
        self.receive(client, &ids, rows);
        self.shared[client as usize] = ids;
    }

    /// Which of client `c`'s shared entities received at least one upload
    /// this round (shared-list order).  `fede_download` returns 0.0 rows
    /// for the others — downstream compression pipelines use this mask so
    /// those rows are never mistaken for real aggregated state.
    pub fn uploaded_mask(&self, c: u16) -> Vec<bool> {
        let ids = &self.shared[c as usize];
        let cuts = self.cuts(ids);
        let mut out = vec![false; ids.len()];
        for (s, shard) in self.shards.iter().enumerate() {
            for i in cuts[s]..cuts[s + 1] {
                out[i] = shard.count[ids[i] as usize - shard.lo] > 0;
            }
        }
        out
    }

    /// Dense FedE aggregation for client `c`: the average over ALL
    /// uploaders of each of c's shared entities (c included), computed
    /// per shard into disjoint output slices.
    pub fn fede_download(&self, c: u16) -> Vec<f32> {
        let w = self.width;
        let ids = &self.shared[c as usize];
        let mut out = vec![0.0f32; ids.len() * w];
        let cuts = self.cuts(ids);
        let segs = split_segments(&mut out, &cuts, w);
        self.run_sharded(ids.len() * w, segs, |s, shard, seg| {
            shard.fill_mean(&ids[cuts[s]..cuts[s + 1]], seg, w);
        });
        out
    }

    /// FedS personalized aggregation + Top-K for client `c` (§III-D).
    ///
    /// Returns `(sign, rows, prio)`: `sign[i]` marks the i-th entity of
    /// c's shared list as selected; `rows` holds the aggregated SUMS
    /// (Eq. 3, own contribution excluded) of the selected entities in
    /// shared-list order; `prio[i]` the matching |C_{c,e}|.  Priority
    /// computation and the row gather run per shard; the Top-K selection
    /// itself stays global so the RNG tie-break stream is unchanged.
    pub fn feds_download(
        &self,
        c: u16,
        k: usize,
        rng: &mut Rng,
    ) -> (Vec<bool>, Vec<f32>, Vec<u32>) {
        let w = self.width;
        let ci = c as usize;
        let ids = &self.shared[ci];
        let cuts = self.cuts(ids);

        // personalized priorities: exclude c's own upload.  The work
        // measure is the counters written (NOT scaled by width — the
        // rows aren't touched here), so small fills stay inline.
        let mut prios = vec![0u32; ids.len()];
        {
            let segs = split_segments(&mut prios, &cuts, 1);
            self.run_sharded(ids.len(), segs, |s, shard, seg| {
                shard.fill_prios(ci, &ids[cuts[s]..cuts[s + 1]], seg);
            });
        }

        let sel = select_by_priority(&prios, k, rng);
        let mut selected = vec![false; ids.len()];
        for &i in &sel {
            selected[i] = true;
        }

        // shared-list order groups selected rows contiguously by shard
        let mut sel_cuts = Vec::with_capacity(cuts.len());
        sel_cuts.push(0usize);
        for s in 0..self.shards.len() {
            let n = selected[cuts[s]..cuts[s + 1]].iter().filter(|&&x| x).count();
            sel_cuts.push(sel_cuts[s] + n);
        }
        let n_sel = *sel_cuts.last().unwrap();
        let mut rows = vec![0.0f32; n_sel * w];
        let mut prio_out = vec![0u32; n_sel];
        {
            let rsegs = split_segments(&mut rows, &sel_cuts, w);
            let psegs = split_segments(&mut prio_out, &sel_cuts, 1);
            let segs: Vec<(&mut [f32], &mut [u32])> = rsegs.into_iter().zip(psegs).collect();
            self.run_sharded(n_sel * w, segs, |s, shard, (rseg, pseg)| {
                let (a, b) = (cuts[s], cuts[s + 1]);
                shard.fill_selected(ci, &ids[a..b], &selected[a..b], &prios[a..b], rseg, pseg, w);
            });
        }
        (selected, rows, prio_out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn server2() -> Server {
        // 2 clients, entities {0,1,2} shared by both; width 2
        Server::new(4, 2, vec![vec![0, 1, 2], vec![0, 1, 2]])
    }

    #[test]
    fn dense_aggregation_averages() {
        let mut s = server2();
        s.begin_round();
        s.receive(0, &[0, 1, 2], &[1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        s.receive(1, &[0, 1, 2], &[3.0, 3.0, 4.0, 4.0, 5.0, 5.0]);
        let d = s.fede_download(0);
        assert_eq!(d, vec![2.0, 2.0, 3.0, 3.0, 4.0, 4.0]);
    }

    #[test]
    fn personalized_agg_excludes_own_contribution() {
        let mut s = server2();
        s.begin_round();
        s.receive(0, &[0], &[10.0, 10.0]);
        s.receive(1, &[0, 1], &[1.0, 2.0, 3.0, 4.0]);
        let mut rng = Rng::new(1);
        let (sign, rows, prio) = s.feds_download(0, 3, &mut rng);
        // entity 0: uploaded by both → A for client 0 excludes its own 10s
        // entity 1: uploaded by client 1 only
        // entity 2: nobody → unavailable
        assert_eq!(sign, vec![true, true, false]);
        assert_eq!(rows, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(prio, vec![1, 1]);
    }

    #[test]
    fn priority_counts_other_uploaders() {
        let mut s = Server::new(4, 1, vec![vec![0], vec![0], vec![0]]);
        s.begin_round();
        s.receive(0, &[0], &[1.0]);
        s.receive(1, &[0], &[2.0]);
        s.receive(2, &[0], &[4.0]);
        let mut rng = Rng::new(1);
        let (sign, rows, prio) = s.feds_download(0, 1, &mut rng);
        assert_eq!(sign, vec![true]);
        assert_eq!(rows, vec![6.0]); // 2 + 4, own 1 excluded
        assert_eq!(prio, vec![2]);
    }

    #[test]
    fn fewer_available_than_k_sends_all() {
        let mut s = server2();
        s.begin_round();
        s.receive(1, &[2], &[7.0, 8.0]);
        let mut rng = Rng::new(1);
        let (sign, rows, prio) = s.feds_download(0, 3, &mut rng);
        assert_eq!(sign, vec![false, false, true]);
        assert_eq!(rows, vec![7.0, 8.0]);
        assert_eq!(prio, vec![1]);
    }

    #[test]
    fn uploaded_mask_tracks_per_round_uploads() {
        let mut s = server2();
        s.begin_round();
        s.receive(1, &[0, 2], &[1.0, 1.0, 2.0, 2.0]);
        assert_eq!(s.uploaded_mask(0), vec![true, false, true]);
        s.receive(0, &[1], &[3.0, 3.0]);
        assert_eq!(s.uploaded_mask(0), vec![true, true, true]);
        s.begin_round();
        assert_eq!(s.uploaded_mask(0), vec![false, false, false], "mask resets each round");
    }

    #[test]
    fn receive_all_shared_covers_the_registered_list() {
        let mut s = server2();
        s.begin_round();
        s.receive_all_shared(0, &[1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        s.receive_all_shared(1, &[3.0, 3.0, 4.0, 4.0, 5.0, 5.0]);
        assert_eq!(s.shared[0], vec![0, 1, 2], "shared list must survive");
        assert_eq!(s.fede_download(0), vec![2.0, 2.0, 3.0, 3.0, 4.0, 4.0]);
    }

    #[test]
    fn begin_round_resets() {
        let mut s = server2();
        s.begin_round();
        s.receive(0, &[0], &[1.0, 1.0]);
        s.begin_round();
        let mut rng = Rng::new(1);
        let (sign, rows, _) = s.feds_download(1, 3, &mut rng);
        assert!(sign.iter().all(|&b| !b));
        assert!(rows.is_empty());
    }

    #[test]
    fn dirty_tracking_resets_only_touched_rows() {
        let mut s = server2();
        s.begin_round();
        s.receive(0, &[0, 2], &[1.0, 1.0, 3.0, 3.0]);
        assert_eq!(s.dirty_len(), 2);
        s.begin_round();
        assert_eq!(s.dirty_len(), 0);
        // a fresh round over different entities sees clean accumulators
        s.receive(1, &[1], &[5.0, 6.0]);
        assert_eq!(s.dirty_len(), 1);
        assert_eq!(s.fede_download(0), vec![0.0, 0.0, 5.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    fn duplicate_entity_across_clients_is_dirty_once() {
        let mut s = server2();
        s.begin_round();
        s.receive(0, &[1], &[1.0, 2.0]);
        s.receive(1, &[1], &[3.0, 4.0]);
        assert_eq!(s.dirty_len(), 1);
        assert_eq!(s.fede_download(0)[2..4], [2.0, 3.0]);
    }

    #[test]
    fn k_limits_selection_by_priority() {
        let mut s = Server::new(4, 1, vec![vec![0, 1, 2, 3], vec![0, 1, 2, 3], vec![0, 1]]);
        s.begin_round();
        s.receive(1, &[0, 1, 2, 3], &[1.0, 1.0, 1.0, 1.0]);
        s.receive(2, &[0, 1], &[2.0, 2.0]);
        let mut rng = Rng::new(3);
        let (sign, _, prio) = s.feds_download(0, 2, &mut rng);
        // entities 0,1 have priority 2; entities 2,3 priority 1 → top-2 = {0,1}
        assert_eq!(sign, vec![true, true, false, false]);
        assert_eq!(prio, vec![2, 2]);
    }

    #[test]
    fn shard_ranges_cover_all_entities_exactly_once() {
        for (e, n) in [(10usize, 3usize), (7, 7), (5, 9), (100, 8), (1, 4)] {
            let s = Server::with_shards(e, 1, vec![vec![]], n);
            assert!(s.num_shards() >= 1 && s.num_shards() <= e.max(1));
            let mut covered = 0usize;
            let mut prev_hi = 0usize;
            for shard in &s.shards {
                assert_eq!(shard.lo, prev_hi, "ranges must be contiguous");
                covered += shard.hi - shard.lo;
                prev_hi = shard.hi;
            }
            assert_eq!(prev_hi, e);
            assert_eq!(covered, e);
        }
    }

    /// The shard accumulator must behave bit-identically whether it lives
    /// in RAM or in an mmap-backed store (ISSUE 9 acceptance).
    #[test]
    fn mmap_accumulator_matches_ram_bit_exactly() {
        let dir = std::env::temp_dir().join(format!("feds-server-store-{}", std::process::id()));
        let mmap = StorageSpec::Mmap { dir: Some(dir.to_string_lossy().into_owned()) };
        let shared = vec![vec![0u32, 1, 2], vec![0, 1, 2]];
        let run = |storage: &StorageSpec| {
            let mut s = Server::with_store(4, 2, shared.clone(), 3, storage).unwrap();
            s.begin_round();
            s.receive(0, &[0, 2], &[1.5, -1.0, 3.0, 0.25]);
            s.receive(1, &[0, 1], &[2.5, 2.0, -0.5, 4.0]);
            let mut rng = Rng::new(7);
            (s.fede_download(0), s.feds_download(0, 2, &mut rng), s.dirty_len())
        };
        let ram = run(&StorageSpec::Ram);
        let via_mmap = run(&mmap);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&ram.0), bits(&via_mmap.0));
        assert_eq!(ram.1 .0, via_mmap.1 .0);
        assert_eq!(bits(&ram.1 .1), bits(&via_mmap.1 .1));
        assert_eq!(ram.1 .2, via_mmap.1 .2);
        assert_eq!(ram.2, via_mmap.2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Property: for random upload patterns, every shard count — inline
    /// or forced-threaded — yields bit-identical dense means, sparse
    /// downloads, priorities and dirty counts to the single-shard server.
    #[test]
    fn sharded_servers_match_single_shard_bit_exactly() {
        check("server_shard_equivalence", 25, |rng| {
            let e = 16 + rng.usize_below(120);
            let w = 1 + rng.usize_below(6);
            let n_clients = 2 + rng.usize_below(4);
            // ascending shared lists, one per client
            let shared: Vec<Vec<u32>> = (0..n_clients)
                .map(|_| {
                    (0..e as u32).filter(|_| rng.bool(0.5)).collect::<Vec<u32>>()
                })
                .collect();
            // one round of uploads: a random ascending subset per client
            let uploads: Vec<(Vec<u32>, Vec<f32>)> = shared
                .iter()
                .map(|ids| {
                    let up: Vec<u32> = ids.iter().copied().filter(|_| rng.bool(0.6)).collect();
                    let rows: Vec<f32> =
                        (0..up.len() * w).map(|_| rng.uniform(-3.0, 3.0)).collect();
                    (up, rows)
                })
                .collect();
            let k = 1 + rng.usize_below(e);
            let seed = rng.next_u64();

            let run = |n_shards: usize, force_threads: bool| {
                let mut s = Server::with_shards(e, w, shared.clone(), n_shards);
                if force_threads {
                    s.set_parallel_threshold(0);
                }
                s.begin_round();
                for (c, (ids, rows)) in uploads.iter().enumerate() {
                    s.receive(c as u16, ids, rows);
                }
                let mut drng = Rng::new(seed);
                let mut out = Vec::new();
                for c in 0..n_clients as u16 {
                    out.push((s.fede_download(c), s.feds_download(c, k, &mut drng)));
                }
                (s.dirty_len(), out)
            };

            let baseline = run(1, false);
            for n_shards in [2usize, 3, 8, 64] {
                for force in [false, true] {
                    let got = run(n_shards, force);
                    assert_eq!(
                        baseline.0, got.0,
                        "dirty_len diverged at {n_shards} shards (threads: {force})"
                    );
                    for (c, (base, shard)) in baseline.1.iter().zip(&got.1).enumerate() {
                        assert_eq!(base.0, shard.0, "fede_download c{c} @ {n_shards} shards");
                        assert_eq!(base.1 .0, shard.1 .0, "sign c{c} @ {n_shards} shards");
                        assert_eq!(
                            base.1 .1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                            shard.1 .1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                            "rows c{c} @ {n_shards} shards"
                        );
                        assert_eq!(base.1 .2, shard.1 .2, "prio c{c} @ {n_shards} shards");
                    }
                }
            }
        });
    }
}
