//! The federated round loop: local training → evaluation/early-stop →
//! communication, for every algorithm in the paper's evaluation.
//!
//! Algorithms (§IV-B, Appendix VI):
//! * `Single`  — local training only, no communication.
//! * `FedEP`   — dense FedE with personalized evaluation (the baseline all
//!               efficiency metrics are scaled against).
//! * `FedEPL`  — FedEP at the reduced dimension of Appendix VI-C.
//! * `FedS`    — Entity-Wise Top-K sparsification both ways + Intermittent
//!               Synchronization; `sync: false` is the FedS/syn ablation.
//! * `FedKd`   — dual-dimension co-distillation transport (Table I).
//! * `FedSvd`  — SVD-compressed update transport; `constrained` adds the
//!               SVD+ low-rank training constraint (Table I).
//!
//! Execution is sequential over clients within a round (the PJRT client is
//! not Send; all clients share one compiled artifact cache), but every
//! exchanged message round-trips through the byte-exact wire codec and the
//! parameter/byte accounting, so the communication metrics are identical
//! to a distributed deployment's.

use std::rc::Rc;

use anyhow::Result;

use crate::comm::accounting::{Accounting, Direction};
use crate::data::dataset::{BatchIter, EvalSet, FilterIndex};
use crate::data::partition::FedDataset;
use crate::kge::{Hyper, Method, Table};
use crate::metrics::tracker::{RoundRecord, RunHistory};
use crate::metrics::{EarlyStop, RankMetrics};
use crate::runtime::Runtime;
use crate::trainer::{evaluate, KdXlaTrainer, LocalTrainer, NativeTrainer, XlaTrainer};
use crate::util::rng::Rng;

use super::compression::SvdCodec;
use super::protocol::{Download, Upload};
use super::server::Server;
use super::sync::SyncSchedule;
use super::topk::{select_by_change, top_k_count};
use super::{comm_ratio, fedepl_dim};

/// Which algorithm drives the communication phase.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Algo {
    Single,
    FedEP,
    FedEPL,
    FedS { sync: bool },
    FedKd,
    FedSvd { constrained: bool },
}

impl Algo {
    pub fn label(&self) -> &'static str {
        match self {
            Algo::Single => "Single",
            Algo::FedEP => "FedEP",
            Algo::FedEPL => "FedEPL",
            Algo::FedS { sync: true } => "FedS",
            Algo::FedS { sync: false } => "FedS/syn",
            Algo::FedKd => "FedE-KD",
            Algo::FedSvd { constrained: false } => "FedE-SVD",
            Algo::FedSvd { constrained: true } => "FedE-SVD+",
        }
    }

    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "single" => Algo::Single,
            "fedep" | "fede" => Algo::FedEP,
            "fedepl" => Algo::FedEPL,
            "feds" => Algo::FedS { sync: true },
            "feds-nosync" | "feds/syn" => Algo::FedS { sync: false },
            "fedkd" | "fede-kd" => Algo::FedKd,
            "fedsvd" | "fede-svd" => Algo::FedSvd { constrained: false },
            "fedsvd+" | "fede-svd+" => Algo::FedSvd { constrained: true },
            other => anyhow::bail!(
                "unknown algorithm '{other}' \
                 (single|fedep|fedepl|feds|feds-nosync|fedkd|fedsvd|fedsvd+)"
            ),
        })
    }
}

/// Where local training executes.
#[derive(Clone)]
pub enum Backend {
    /// AOT artifacts via PJRT — the production path.
    Xla(Rc<Runtime>),
    /// Pure-Rust oracle — artifact-free tests and the SVD+ native path.
    Native {
        hyper: Hyper,
        batch: usize,
        negatives: usize,
        eval_batch: usize,
    },
}

impl Backend {
    fn batch_shape(&self) -> (usize, usize) {
        match self {
            Backend::Xla(rt) => (rt.manifest.batch, rt.manifest.negatives),
            Backend::Native { batch, negatives, .. } => (*batch, *negatives),
        }
    }

    fn sparsity_defaults(&self) -> (f64, usize) {
        match self {
            Backend::Xla(rt) => (rt.manifest.sparsity, rt.manifest.sync_interval),
            Backend::Native { .. } => (0.4, 4),
        }
    }

    fn make_trainer(
        &self,
        algo: Algo,
        method: Method,
        num_entities: usize,
        num_relations: usize,
        seed: u64,
    ) -> Result<Box<dyn LocalTrainer>> {
        let mut rng = Rng::new(seed);
        match self {
            Backend::Xla(rt) => match algo {
                Algo::FedKd => Ok(Box::new(KdXlaTrainer::new(rt.clone(), method, &mut rng)?)),
                Algo::FedEPL => {
                    let dim = rt.manifest.fedepl_dim;
                    Ok(Box::new(XlaTrainer::new(rt.clone(), method, dim, &mut rng)?))
                }
                _ => Ok(Box::new(XlaTrainer::new(
                    rt.clone(),
                    method,
                    rt.manifest.hyper.dim,
                    &mut rng,
                )?)),
            },
            Backend::Native { hyper, eval_batch, .. } => {
                anyhow::ensure!(
                    algo != Algo::FedKd,
                    "FedE-KD requires the XLA backend (co-distillation artifact)"
                );
                let hyper = if algo == Algo::FedEPL {
                    let (p, s) = self.sparsity_defaults();
                    Hyper { dim: fedepl_dim(hyper.dim, p, s), ..hyper.clone() }
                } else {
                    hyper.clone()
                };
                Ok(Box::new(NativeTrainer::new(
                    method,
                    hyper,
                    num_entities,
                    num_relations,
                    *eval_batch,
                    &mut rng,
                )))
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct FedRunConfig {
    pub algo: Algo,
    pub method: Method,
    /// hard cap on communication rounds
    pub max_rounds: usize,
    /// local epochs per round (paper default 3)
    pub local_epochs: usize,
    /// evaluate every N rounds (paper: every 5)
    pub eval_every: usize,
    /// early-stop patience in evaluations (paper: 3)
    pub patience: usize,
    /// FedS sparsity ratio p (paper: 0.4, 0.7 for one config)
    pub sparsity: f64,
    /// FedS synchronization interval s (paper: 4)
    pub sync_interval: usize,
    /// cap on eval queries per client per split (0 = all)
    pub eval_cap: usize,
    pub seed: u64,
    /// columns of the SVD reshape (paper: 8)
    pub svd_cols: usize,
}

impl Default for FedRunConfig {
    fn default() -> Self {
        Self {
            algo: Algo::FedS { sync: true },
            method: Method::TransE,
            max_rounds: 200,
            local_epochs: 3,
            eval_every: 5,
            patience: 3,
            sparsity: 0.4,
            sync_interval: 4,
            eval_cap: 0,
            seed: 0xFED5,
            svd_cols: 8,
        }
    }
}

struct ClientCtx {
    id: u16,
    trainer: Box<dyn LocalTrainer>,
    /// shared entities (sorted global ids) — the communicated set N_c
    shared: Vec<u32>,
    /// FedS history table E^h (full-size; only shared rows meaningful)
    hist: Option<Table>,
    /// SVD variants: the client/server-agreed reference state
    svd_ref: Option<Table>,
    filters: FilterIndex,
    valid_set: EvalSet,
    test_set: EvalSet,
    rng: Rng,
}

/// Outcome of a federated run: history plus final accounting.
pub struct RunOutcome {
    pub history: RunHistory,
    pub acct: std::sync::Arc<Accounting>,
    /// analytic Eq. 5 ratio for this configuration (FedS only)
    pub eq5_ratio: Option<f64>,
}

/// Run one federated training experiment.
pub fn run_federated(
    data: &FedDataset,
    cfg: &FedRunConfig,
    backend: &Backend,
) -> Result<RunOutcome> {
    let acct = Accounting::new();
    let (batch_size, negatives) = backend.batch_shape();
    let n_clients = data.clients.len();

    // --- build clients (identical entity init: same trainer seed) ----------
    let mut clients: Vec<ClientCtx> = Vec::with_capacity(n_clients);
    for c in &data.clients {
        let trainer = backend.make_trainer(
            cfg.algo,
            cfg.method,
            data.num_entities,
            data.num_relations,
            cfg.seed,
        )?;
        let mut rng = Rng::new(cfg.seed ^ (0xC11E57 + c.id as u64));
        let filters = c.filter_index();
        let mut valid_set = EvalSet::new(&c.valid, data.num_entities);
        let mut test_set = EvalSet::new(&c.test, data.num_entities);
        valid_set.subsample(cfg.eval_cap, &mut rng);
        test_set.subsample(cfg.eval_cap, &mut rng);
        clients.push(ClientCtx {
            id: c.id,
            trainer,
            shared: data.shared_entities_of(c.id),
            hist: None,
            svd_ref: None,
            filters,
            valid_set,
            test_set,
            rng,
        });
    }

    let width = clients[0].trainer.entity_width();
    let is_feds = matches!(cfg.algo, Algo::FedS { .. });
    let is_svd = matches!(cfg.algo, Algo::FedSvd { .. });

    // FedS history tables / SVD reference tables start at the initial state
    for ctx in clients.iter_mut() {
        if is_feds || is_svd {
            let mut t = Table::zeros(data.num_entities, width);
            let rows = ctx.trainer.get_entity_rows(&ctx.shared)?;
            for (k, &id) in ctx.shared.iter().enumerate() {
                t.set_row(id as usize, &rows[k * width..(k + 1) * width]);
            }
            if is_feds {
                ctx.hist = Some(t);
            } else {
                ctx.svd_ref = Some(t);
            }
        }
    }

    let mut server = Server::new(
        data.num_entities,
        width,
        clients.iter().map(|c| c.shared.clone()).collect(),
    );
    let mut server_rng = Rng::new(cfg.seed ^ 0x5E4E4);
    let mut sync = SyncSchedule::new(match cfg.algo {
        Algo::FedS { sync: true } => Some(cfg.sync_interval),
        _ => None,
    });
    // codec only meaningful (and width-compatible) for the SVD baselines
    let codec = if is_svd || cfg.algo == (Algo::FedSvd { constrained: true }) {
        SvdCodec::for_width(width, cfg.svd_cols.min(width))
    } else {
        SvdCodec::new(1, 1)
    };
    let weights = data.test_weights();
    let mut es = EarlyStop::new(cfg.patience);
    let mut history = RunHistory::new(&format!(
        "{}-{}-{}c",
        cfg.algo.label(),
        cfg.method.name(),
        n_clients
    ));

    crate::info!(
        "run {}: {} clients, {} shared entities, width {}, p={}, s={}",
        history.label,
        n_clients,
        data.shared.len(),
        width,
        cfg.sparsity,
        cfg.sync_interval
    );

    for round in 1..=cfg.max_rounds {
        // --- 1. local training ---------------------------------------------
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        for (ci, ctx) in clients.iter_mut().enumerate() {
            let train = &data.clients[ci].train;
            let local_ents = &data.clients[ci].entities;
            // all epochs' batches gathered so the XLA trainers can fuse the
            // whole phase into scan-stepped executions
            let mut batches = Vec::new();
            for _ in 0..cfg.local_epochs {
                let mut brng = ctx.rng.fork(round as u64);
                batches
                    .extend(BatchIter::new(train, local_ents, batch_size, negatives, &mut brng));
            }
            let n = batches.len();
            loss_sum += ctx.trainer.train_batches(&batches)? as f64 * n as f64;
            loss_n += n;
        }

        // SVD+ low-rank constraint: project this round's local update
        if cfg.algo == (Algo::FedSvd { constrained: true }) {
            for ctx in clients.iter_mut() {
                let refs = ctx.svd_ref.as_ref().unwrap();
                let cur = ctx.trainer.get_entity_rows(&ctx.shared)?;
                let mut projected = Vec::with_capacity(cur.len());
                for (k, &id) in ctx.shared.iter().enumerate() {
                    let row = &cur[k * width..(k + 1) * width];
                    let upd = crate::linalg::sub(row, refs.row(id as usize));
                    let proj = codec.project_row(&upd);
                    let mut out = refs.row(id as usize).to_vec();
                    crate::linalg::axpy(1.0, &proj, &mut out);
                    projected.extend_from_slice(&out);
                }
                ctx.trainer.set_entity_rows(&ctx.shared, &projected)?;
            }
        }

        // --- 2. evaluation + early stopping --------------------------------
        if round % cfg.eval_every == 0 {
            let (valid, test) = eval_all(&mut clients, &weights)?;
            let mean_loss = if loss_n > 0 { loss_sum / loss_n as f64 } else { 0.0 };
            history.push(RoundRecord {
                round,
                params_cum: acct.params(),
                bytes_cum: acct.bytes(),
                valid,
                test,
                mean_loss,
            });
            crate::info!(
                "{} round {round}: loss {mean_loss:.4} valid MRR {:.4} test MRR {:.4} params {:.2}M",
                history.label,
                valid.mrr,
                test.mrr,
                acct.params() as f64 / 1e6
            );
            if es.update(valid.mrr) {
                history.mark_converged(es.best_index());
                break;
            }
        }

        // --- 3. communication -----------------------------------------------
        match cfg.algo {
            Algo::Single => {}
            Algo::FedEP | Algo::FedEPL | Algo::FedKd => {
                dense_round(round as u32, &mut clients, &mut server, &acct, width)?;
            }
            Algo::FedSvd { .. } => {
                svd_round(round as u32, &mut clients, &mut server, &acct, width, &codec)?;
            }
            Algo::FedS { .. } => {
                if sync.step(round) {
                    feds_sync_round(round as u32, &mut clients, &mut server, &acct, width)?;
                } else {
                    feds_sparse_round(
                        round as u32,
                        &mut clients,
                        &mut server,
                        &acct,
                        width,
                        cfg.sparsity,
                        &mut server_rng,
                    )?;
                }
            }
        }
    }

    if history.converged_idx.is_none() && !history.records.is_empty() {
        history.mark_converged(es.best_index().min(history.records.len() - 1));
    }

    let eq5 = is_feds.then(|| comm_ratio(cfg.sparsity, cfg.sync_interval, width));
    Ok(RunOutcome { history, acct, eq5_ratio: eq5 })
}

fn eval_all(
    clients: &mut [ClientCtx],
    weights: &[f64],
) -> Result<(RankMetrics, RankMetrics)> {
    let mut valid = Vec::with_capacity(clients.len());
    let mut test = Vec::with_capacity(clients.len());
    for ctx in clients.iter_mut() {
        valid.push(evaluate(ctx.trainer.as_mut(), &ctx.valid_set, &ctx.filters)?);
        test.push(evaluate(ctx.trainer.as_mut(), &ctx.test_set, &ctx.filters)?);
    }
    Ok((
        RankMetrics::weighted(&valid, weights),
        RankMetrics::weighted(&test, weights),
    ))
}

/// Dense FedE-style exchange (FedEP, FedEPL, FedE-KD).
fn dense_round(
    round: u32,
    clients: &mut [ClientCtx],
    server: &mut Server,
    acct: &Accounting,
    width: usize,
) -> Result<()> {
    server.begin_round();
    for ctx in clients.iter_mut() {
        if ctx.shared.is_empty() {
            continue;
        }
        let rows = ctx.trainer.get_entity_rows(&ctx.shared)?;
        let msg = Upload::Full { round, client: ctx.id, emb: rows };
        let frame = msg.encode();
        acct.record(Direction::Upload, msg.params(), frame.len() as u64);
        let Upload::Full { emb, client, .. } = Upload::decode(&frame)? else {
            unreachable!()
        };
        server.receive(client, &ctx.shared, &emb);
    }
    for ctx in clients.iter_mut() {
        if ctx.shared.is_empty() {
            continue;
        }
        let rows = server.fede_download(ctx.id);
        let msg = Download::Full { round, emb: rows };
        let frame = msg.encode();
        acct.record(Direction::Download, msg.params(), frame.len() as u64);
        let Download::Full { emb, .. } = Download::decode(&frame)? else {
            unreachable!()
        };
        debug_assert_eq!(emb.len(), ctx.shared.len() * width);
        ctx.trainer.set_entity_rows(&ctx.shared, &emb)?;
    }
    Ok(())
}

/// FedS full synchronization round (§III-E): dense exchange + history reset.
fn feds_sync_round(
    round: u32,
    clients: &mut [ClientCtx],
    server: &mut Server,
    acct: &Accounting,
    width: usize,
) -> Result<()> {
    server.begin_round();
    for ctx in clients.iter_mut() {
        if ctx.shared.is_empty() {
            continue;
        }
        let rows = ctx.trainer.get_entity_rows(&ctx.shared)?;
        // E^h := what was sent (all entities on sync rounds)
        let hist = ctx.hist.as_mut().unwrap();
        for (k, &id) in ctx.shared.iter().enumerate() {
            hist.set_row(id as usize, &rows[k * width..(k + 1) * width]);
        }
        let msg = Upload::Full { round, client: ctx.id, emb: rows };
        let frame = msg.encode();
        acct.record(Direction::Upload, msg.params(), frame.len() as u64);
        let Upload::Full { emb, client, .. } = Upload::decode(&frame)? else {
            unreachable!()
        };
        server.receive(client, &ctx.shared, &emb);
    }
    for ctx in clients.iter_mut() {
        if ctx.shared.is_empty() {
            continue;
        }
        let rows = server.fede_download(ctx.id);
        let msg = Download::Full { round, emb: rows };
        let frame = msg.encode();
        acct.record(Direction::Download, msg.params(), frame.len() as u64);
        let Download::Full { emb, .. } = Download::decode(&frame)? else {
            unreachable!()
        };
        ctx.trainer.set_entity_rows(&ctx.shared, &emb)?;
    }
    Ok(())
}

/// FedS sparsified round: upstream Top-K by change (§III-C), downstream
/// personalized aggregation + priority Top-K (§III-D), Eq. 4 merge.
fn feds_sparse_round(
    round: u32,
    clients: &mut [ClientCtx],
    server: &mut Server,
    acct: &Accounting,
    width: usize,
    sparsity: f64,
    server_rng: &mut Rng,
) -> Result<()> {
    server.begin_round();

    // upstream
    for ctx in clients.iter_mut() {
        if ctx.shared.is_empty() {
            continue;
        }
        let hist = ctx.hist.as_ref().unwrap();
        let scores = ctx.trainer.change_scores(&ctx.shared, hist)?;
        let k = top_k_count(ctx.shared.len(), sparsity);
        let sel = select_by_change(&scores, k);
        let ids: Vec<u32> = sel.iter().map(|&i| ctx.shared[i]).collect();
        let rows = ctx.trainer.get_entity_rows(&ids)?;

        let hist = ctx.hist.as_mut().unwrap();
        for (k2, &id) in ids.iter().enumerate() {
            hist.set_row(id as usize, &rows[k2 * width..(k2 + 1) * width]);
        }

        let mut sign = vec![false; ctx.shared.len()];
        for &i in &sel {
            sign[i] = true;
        }
        let msg = Upload::Sparse { round, client: ctx.id, sign, emb: rows };
        let frame = msg.encode();
        acct.record(Direction::Upload, msg.params(), frame.len() as u64);
        let Upload::Sparse { sign, emb, client, .. } = Upload::decode(&frame)? else {
            unreachable!()
        };
        let ids: Vec<u32> = sign
            .iter()
            .enumerate()
            .filter(|(_, &s)| s)
            .map(|(i, _)| ctx.shared[i])
            .collect();
        server.receive(client, &ids, &emb);
    }

    // downstream
    for ctx in clients.iter_mut() {
        if ctx.shared.is_empty() {
            continue;
        }
        let k = top_k_count(ctx.shared.len(), sparsity);
        let (sign, rows, prio) = server.feds_download(ctx.id, k, server_rng);
        let msg = Download::Sparse { round, sign, emb: rows, prio };
        let frame = msg.encode();
        acct.record(Direction::Download, msg.params(), frame.len() as u64);
        let Download::Sparse { sign, emb, prio, .. } = Download::decode(&frame)? else {
            unreachable!()
        };

        let ids: Vec<u32> = sign
            .iter()
            .enumerate()
            .filter(|(_, &s)| s)
            .map(|(i, _)| ctx.shared[i])
            .collect();
        if ids.is_empty() {
            continue;
        }
        // Eq. 4: E^{t+1} = (A + E^t) / (1 + P)
        let own = ctx.trainer.get_entity_rows(&ids)?;
        let mut merged = vec![0.0f32; ids.len() * width];
        for (j, _) in ids.iter().enumerate() {
            let p = prio[j] as f32;
            for w in 0..width {
                merged[j * width + w] =
                    (emb[j * width + w] + own[j * width + w]) / (1.0 + p);
            }
        }
        ctx.trainer.set_entity_rows(&ids, &merged)?;
    }
    Ok(())
}

/// FedE-SVD / FedE-SVD+ exchange: rank-k factorized updates both ways
/// against the client/server-agreed reference state.
fn svd_round(
    round: u32,
    clients: &mut [ClientCtx],
    server: &mut Server,
    acct: &Accounting,
    width: usize,
    codec: &SvdCodec,
) -> Result<()> {
    server.begin_round();
    for ctx in clients.iter_mut() {
        if ctx.shared.is_empty() {
            continue;
        }
        let refs = ctx.svd_ref.as_ref().unwrap();
        let cur = ctx.trainer.get_entity_rows(&ctx.shared)?;
        let mut updates = Vec::with_capacity(cur.len());
        for (k, &id) in ctx.shared.iter().enumerate() {
            updates.extend_from_slice(&crate::linalg::sub(
                &cur[k * width..(k + 1) * width],
                refs.row(id as usize),
            ));
        }
        let packed = codec.encode_rows(&updates, width);
        let msg = Upload::Full { round, client: ctx.id, emb: packed };
        let frame = msg.encode();
        acct.record(Direction::Upload, msg.params(), frame.len() as u64);
        let Upload::Full { emb: packed, client, .. } = Upload::decode(&frame)? else {
            unreachable!()
        };
        // server reconstructs the client's (approximate) state
        let approx_updates = codec.decode_rows(&packed, width, ctx.shared.len());
        let mut state = Vec::with_capacity(approx_updates.len());
        for (k, &id) in ctx.shared.iter().enumerate() {
            let mut row = refs.row(id as usize).to_vec();
            crate::linalg::axpy(1.0, &approx_updates[k * width..(k + 1) * width], &mut row);
            state.extend_from_slice(&row);
        }
        server.receive(client, &ctx.shared, &state);
    }
    for ctx in clients.iter_mut() {
        if ctx.shared.is_empty() {
            continue;
        }
        let agg = server.fede_download(ctx.id);
        let refs = ctx.svd_ref.as_mut().unwrap();
        let mut deltas = Vec::with_capacity(agg.len());
        for (k, &id) in ctx.shared.iter().enumerate() {
            deltas.extend_from_slice(&crate::linalg::sub(
                &agg[k * width..(k + 1) * width],
                refs.row(id as usize),
            ));
        }
        let packed = codec.encode_rows(&deltas, width);
        let msg = Download::Full { round, emb: packed };
        let frame = msg.encode();
        acct.record(Direction::Download, msg.params(), frame.len() as u64);
        let Download::Full { emb: packed, .. } = Download::decode(&frame)? else {
            unreachable!()
        };
        let approx = codec.decode_rows(&packed, width, ctx.shared.len());
        let mut new_rows = Vec::with_capacity(approx.len());
        for (k, &id) in ctx.shared.iter().enumerate() {
            let mut row = refs.row(id as usize).to_vec();
            crate::linalg::axpy(1.0, &approx[k * width..(k + 1) * width], &mut row);
            refs.set_row(id as usize, &row);
            new_rows.extend_from_slice(&row);
        }
        ctx.trainer.set_entity_rows(&ctx.shared, &new_rows)?;
    }
    Ok(())
}
