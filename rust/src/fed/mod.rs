//! The paper's system contribution: FedS — bidirectional Entity-Wise Top-K
//! sparsification for federated knowledge-graph embedding — plus every
//! baseline its evaluation compares against.
//!
//! Module map:
//! * `topk`         — Eq. 1/2 upstream selection, §III-D priority selection
//! * `sync`         — Intermittent Synchronization Mechanism (§III-E)
//! * `server`       — personalized aggregation (Eq. 3) + dense aggregation
//! * `protocol`     — wire messages with paper-parameter accounting (§III-F)
//! * `compression`  — the stage algebra behind `--compress`: composable
//!                    `CompressionStage`s (entity-wise Top-K, int8/fp16
//!                    row quantizers, rank-k SVD — Appendix VI-B) stacked
//!                    by a `PipelineSpec` with optional per-stage error
//!                    feedback, packed into self-describing `PackedBlock`
//!                    wire payloads
//! * `orchestrator` — the message-driven round loop for FedS, FedEP,
//!                    FedEPL, Single, FedE-KD, FedE-SVD, FedE-SVD+:
//!   * `orchestrator::exchange` — per-algorithm `Exchange` strategies
//!     (`DenseExchange`, `FedSExchange`, `SvdExchange`, and the
//!     `PipelineExchange` that carries any non-empty `--compress` stack
//!     as reference-mirrored deltas), each with a client half and a
//!     server half
//!   * `orchestrator::client`   — `ClientRunner`s that own their local
//!     state and exchange only framed `Upload`/`Download` messages over
//!     metered `comm::transport` links (in-process mpsc or TCP loopback,
//!     selected per run with bit-identical accounting)
//!   * `orchestrator::params`   — `RoundParams`, the resolved-parameter
//!     struct derived once per run; the only configuration the
//!     orchestrator internals consume
//!   * sequential and per-client-thread execution drivers (`ExecMode`),
//!     byte- and bit-identical to each other
//!   * the round loop reports through typed `RunEvent`s to registered
//!     `RunObserver`s (`crate::metrics::observe`); history, console
//!     progress and JSONL metric streams are observers, not hard-wired
//!
//! Entry points: describe runs with [`crate::spec::ExperimentSpec`] and
//! execute them through [`crate::spec::Session`], which derives the
//! resolved [`RoundParams`] and drives the engine ([`run_params`]).
//! Every O(entities × width) table the loop owns — client models, Adam
//! moments, FedS history, the server accumulator — is hosted on a
//! [`crate::store::EmbedStore`] backend chosen by `RoundParams::storage`
//! (in-RAM or mmap-backed files, bit-identical results).  The
//! `cluster` module deploys the same engine across OS processes: a
//! routable TCP server plus independent client processes, with round
//! deadlines, partial aggregation and rejoin-with-resync semantics.

pub mod cluster;
pub mod compression;
pub mod orchestrator;
pub mod protocol;
pub mod server;
pub mod sync;
pub mod topk;

pub use orchestrator::{run_params, Algo, Backend, ExecMode, RoundParams, RunOutcome};
pub use server::Server;
pub use sync::SyncSchedule;

/// Eq. 5: the worst-case ratio of parameters transmitted by FedS per cycle
/// vs. a dense method, with sparsity `p`, sync interval `s`, dimension `d`.
pub fn comm_ratio(p: f64, s: usize, d: usize) -> f64 {
    let s = s as f64;
    let d = d as f64;
    (p * s + 1.0 + (2.0 + p) * s / (2.0 * d)) / (s + 1.0)
}

/// Appendix VI-C: FedEPL's reduced dimension — `ceil(D × R_c^p)` so a dense
/// run transmits the same volume per cycle as FedS.
pub fn fedepl_dim(dim: usize, p: f64, s: usize) -> usize {
    let r = comm_ratio(p, s, dim);
    (dim as f64 * r).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq5_matches_paper_appendix() {
        // p=0.7, s=4, D=256 → R ≈ 0.7642 → dim 196
        assert!((comm_ratio(0.7, 4, 256) - 0.7642).abs() < 1e-3);
        assert_eq!(fedepl_dim(256, 0.7, 4), 196);
        // p=0.4 → 135
        assert_eq!(fedepl_dim(256, 0.4, 4), 135);
    }

    #[test]
    fn eq5_decreases_with_sparsity() {
        assert!(comm_ratio(0.2, 4, 64) < comm_ratio(0.8, 4, 64));
    }

    #[test]
    fn eq5_approaches_p_for_large_s_and_d() {
        let r = comm_ratio(0.4, 1000, 100_000);
        assert!((r - 0.4).abs() < 0.01, "{r}");
    }
}
