//! Transport compression: the SVD codec (Table I, paper Appendix VI-B)
//! and the composable stage pipeline behind `--compress`.
//!
//! `SvdCodec` implements the FedE-SVD transport: each entity's embedding
//! *update* row (width W) is reshaped to an (m, n) matrix (m = W/n ≥ n),
//! decomposed with the one-sided Jacobi SVD, truncated to rank k, and
//! transmitted as packed `U[:, :k] ‖ s[:k] ‖ Vt[:k, :]` — exactly the
//! paper's parameter accounting (m·k + k + k·n per entity).
//!
//! FedE-SVD+ additionally constrains local training toward low-rank
//! updates; we approximate the constraint by hard-projecting the local
//! update to rank k at the end of local training (the information loss the
//! paper attributes to the constraint), documented in DESIGN.md §5.
//!
//! ## The compression algebra
//!
//! [`PipelineSpec`] stacks [`CompressionStage`]s — entity-wise Top-K row
//! selection, int8/fp16 row quantization, rank-k SVD — over the *delta*
//! stream of a dense exchange (see `orchestrator::exchange::
//! PipelineExchange`).  A bound [`Pipeline`] encodes a block of update
//! rows into a self-describing [`PackedBlock`] (stage tags + selection
//! bitmap + byte-packed rows) and decodes it back; every stage may carry
//! an error-feedback residual table ([`Pipeline::make_residuals`], hosted
//! on `store::EmbedStore`) that re-injects this round's compression error
//! into the next round's input, FSPPD_EF-style.
//!
//! Stage semantics are split so arbitrary orders compose:
//! * mid-pipeline, a stage acts in the **value domain** ([`forward`]:
//!   quantizers emit their lossy reconstruction, SVD emits packed
//!   factors) with [`backward`] undoing any shape change on decode;
//! * the **last** stage instead byte-packs its input rows
//!   ([`pack_row`]/[`unpack_row`]: int8 = per-row f32 scale + codes,
//!   fp16 = 2 bytes/value, SVD/Top-K = raw f32), with the invariant
//!   `unpack_row(pack_row(v)) == backward(forward(v))` bit-exactly, so
//!   sender-side mirrors and residuals agree with what receivers decode.
//!
//! [`forward`]: CompressionStage::forward
//! [`backward`]: CompressionStage::backward
//! [`pack_row`]: CompressionStage::pack_row
//! [`unpack_row`]: CompressionStage::unpack_row

use anyhow::{bail, ensure, Result};

use crate::comm::wire::{WireReader, WireWriter};
use crate::fed::topk::{select_by_change, top_k_count};
use crate::linalg::svd::{svd, Svd};
use crate::store::{StorageSpec, StoreTable};

#[derive(Clone, Copy, Debug)]
pub struct SvdCodec {
    /// columns of the reshaped update matrix (paper: 8)
    pub n_cols: usize,
    /// retained singular values (paper: 5 of 8 at D=256; scaled configs
    /// pick k so the codec actually compresses, see `for_width`)
    pub rank: usize,
}

impl SvdCodec {
    pub fn new(n_cols: usize, rank: usize) -> Self {
        assert!(rank <= n_cols);
        Self { n_cols, rank }
    }

    /// Pick a rank that yields real compression at this row width:
    /// the largest k with (m·k + k + k·n) < W.  `n_cols` shrinks to the
    /// largest **divisor** of `width` that is ≤ the requested value and
    /// keeps the reshaped matrix tall (m ≥ n), as the Jacobi SVD
    /// requires.  Any width ≥ 1 is accepted — non-divisible widths
    /// (d = 100, 200, …) fall back to their nearest divisor instead of
    /// aborting.
    pub fn for_width(width: usize, n_cols: usize) -> Self {
        assert!(width >= 1, "zero-width rows cannot be factorized");
        let n_cols = (1..=n_cols.max(1))
            .rev()
            .find(|&n| width % n == 0 && width / n >= n)
            .unwrap_or(1);
        let m = width / n_cols;
        let mut rank = 1;
        for k in 1..=n_cols.min(m) {
            if Svd::transmitted_params(m, n_cols, k) < width {
                rank = k;
            }
        }
        Self { n_cols, rank }
    }

    pub fn rows(&self, width: usize) -> usize {
        width / self.n_cols
    }

    /// Transmitted floats per entity row.
    pub fn params_per_row(&self, width: usize) -> usize {
        Svd::transmitted_params(self.rows(width), self.n_cols, self.rank)
    }

    /// Compression ratio per the paper's definition: (W − transmitted)/W.
    pub fn compression_ratio(&self, width: usize) -> f64 {
        1.0 - self.params_per_row(width) as f64 / width as f64
    }

    /// Encode one update row into packed factors.
    pub fn encode_row(&self, update: &[f32]) -> Vec<f32> {
        let n = self.n_cols;
        let m = update.len() / n;
        let k = self.rank;
        let f = svd(update, m, n);
        let mut out = Vec::with_capacity(m * k + k + k * n);
        for i in 0..m {
            for r in 0..k {
                out.push(f.u[i * n + r]);
            }
        }
        out.extend_from_slice(&f.s[..k]);
        for r in 0..k {
            out.extend_from_slice(&f.vt[r * n..(r + 1) * n]);
        }
        out
    }

    /// Decode packed factors back to an approximate update row.
    pub fn decode_row(&self, packed: &[f32], width: usize) -> Vec<f32> {
        let n = self.n_cols;
        let m = width / n;
        let k = self.rank;
        assert_eq!(packed.len(), m * k + k + k * n, "bad packed length");
        let (u, rest) = packed.split_at(m * k);
        let (s, vt) = rest.split_at(k);
        let mut out = vec![0.0f32; width];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for r in 0..k {
                    acc += u[i * k + r] * s[r] * vt[r * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    /// Encode many rows (concatenated) into one packed payload.
    pub fn encode_rows(&self, updates: &[f32], width: usize) -> Vec<f32> {
        updates
            .chunks_exact(width)
            .flat_map(|row| self.encode_row(row))
            .collect()
    }

    pub fn decode_rows(&self, packed: &[f32], width: usize, n_rows: usize) -> Vec<f32> {
        let per = self.params_per_row(width);
        assert_eq!(packed.len(), per * n_rows, "bad packed payload");
        let mut out = Vec::with_capacity(n_rows * width);
        for i in 0..n_rows {
            out.extend_from_slice(&self.decode_row(&packed[i * per..(i + 1) * per], width));
        }
        out
    }

    /// SVD+ constraint approximation: project an update row to rank k.
    pub fn project_row(&self, update: &[f32]) -> Vec<f32> {
        let n = self.n_cols;
        let m = update.len() / n;
        crate::linalg::svd::low_rank_project(update, m, n, self.rank)
    }
}

// ---------------------------------------------------------------------------
// Stage descriptions
// ---------------------------------------------------------------------------

/// Default kept fraction for a bare `topk` stage (the paper's p).
pub const DEFAULT_TOPK_RATIO: f64 = 0.4;
/// Default reshape columns for a bare `svd` stage (the paper's 8).
pub const DEFAULT_SVD_STAGE_COLS: usize = 8;

/// One parsed pipeline stage: `name[@param][:ef]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StageSpec {
    /// Entity-wise Top-K row selection by update magnitude: keep the
    /// `ratio` fraction of rows with the largest L2 norm.  With `ef`,
    /// dropped rows accumulate into a residual and compete again next
    /// round.
    TopK { ratio: f64, ef: bool },
    /// int8 row quantization with a per-row f32 scale (max-abs).
    Int8 { ef: bool },
    /// IEEE-754 half-precision rows (round-to-nearest-even).
    Fp16 { ef: bool },
    /// Rank-k SVD factorization of the reshaped update row.
    Svd { cols: usize, ef: bool },
}

const KIND_TOPK: u8 = 0;
const KIND_INT8: u8 = 1;
const KIND_FP16: u8 = 2;
const KIND_SVD: u8 = 3;

impl StageSpec {
    pub fn name(&self) -> &'static str {
        match self {
            StageSpec::TopK { .. } => "topk",
            StageSpec::Int8 { .. } => "int8",
            StageSpec::Fp16 { .. } => "fp16",
            StageSpec::Svd { .. } => "svd",
        }
    }

    pub fn ef(&self) -> bool {
        match *self {
            StageSpec::TopK { ef, .. }
            | StageSpec::Int8 { ef }
            | StageSpec::Fp16 { ef }
            | StageSpec::Svd { ef, .. } => ef,
        }
    }

    /// `name[@param][:ef]`, parseable by [`PipelineSpec::parse`].
    pub fn label(&self) -> String {
        let head = match self {
            StageSpec::TopK { ratio, .. } => format!("topk@{ratio}"),
            StageSpec::Int8 { .. } => "int8".to_string(),
            StageSpec::Fp16 { .. } => "fp16".to_string(),
            StageSpec::Svd { cols, .. } => format!("svd@{cols}"),
        };
        if self.ef() {
            format!("{head}:ef")
        } else {
            head
        }
    }

    fn parse(tok: &str) -> Result<StageSpec> {
        let (tok, ef) = match tok.strip_suffix(":ef") {
            Some(t) => (t, true),
            None => (tok, false),
        };
        let (name, param) = match tok.split_once('@') {
            Some((n, p)) => (n, Some(p)),
            None => (tok, None),
        };
        let numeric = |what: &str| -> Result<f64> {
            let p = param.unwrap_or_default();
            p.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("stage '{name}': bad {what} '{p}'"))
        };
        match name {
            "topk" => {
                let ratio = match param {
                    Some(_) => numeric("ratio")?,
                    None => DEFAULT_TOPK_RATIO,
                };
                ensure!(
                    ratio.is_finite() && ratio > 0.0 && ratio <= 1.0,
                    "stage 'topk': ratio must be in (0, 1], got {ratio}"
                );
                Ok(StageSpec::TopK { ratio, ef })
            }
            "int8" => {
                ensure!(param.is_none(), "stage 'int8' takes no parameter");
                Ok(StageSpec::Int8 { ef })
            }
            "fp16" => {
                ensure!(param.is_none(), "stage 'fp16' takes no parameter");
                Ok(StageSpec::Fp16 { ef })
            }
            "svd" => {
                let cols = match param {
                    Some(_) => {
                        let c = numeric("cols")?;
                        ensure!(
                            c.fract() == 0.0 && c >= 1.0 && c <= u16::MAX as f64,
                            "stage 'svd': cols must be a positive integer, got {c}"
                        );
                        c as usize
                    }
                    None => DEFAULT_SVD_STAGE_COLS,
                };
                Ok(StageSpec::Svd { cols, ef })
            }
            other => bail!(
                "unknown compression stage '{other}' (expected topk|int8|fp16|svd, \
                 each with an optional :ef suffix)"
            ),
        }
    }

    fn write(&self, w: &mut WireWriter) {
        let flags = u8::from(self.ef());
        match self {
            StageSpec::TopK { ratio, .. } => {
                w.u8(KIND_TOPK).u8(flags).f64(*ratio);
            }
            StageSpec::Int8 { .. } => {
                w.u8(KIND_INT8).u8(flags);
            }
            StageSpec::Fp16 { .. } => {
                w.u8(KIND_FP16).u8(flags);
            }
            StageSpec::Svd { cols, .. } => {
                w.u8(KIND_SVD).u8(flags).u16(*cols as u16);
            }
        }
    }

    fn read(r: &mut WireReader<'_>) -> Result<StageSpec> {
        let kind = r.u8()?;
        let flags = r.u8()?;
        ensure!(flags <= 1, "bad stage flags {flags} in packed payload");
        let ef = flags == 1;
        Ok(match kind {
            KIND_TOPK => {
                let ratio = r.f64()?;
                ensure!(
                    ratio.is_finite() && ratio > 0.0 && ratio <= 1.0,
                    "bad topk ratio {ratio} in packed payload"
                );
                StageSpec::TopK { ratio, ef }
            }
            KIND_INT8 => StageSpec::Int8 { ef },
            KIND_FP16 => StageSpec::Fp16 { ef },
            KIND_SVD => {
                let cols = r.u16()? as usize;
                ensure!(cols >= 1, "bad svd cols 0 in packed payload");
                StageSpec::Svd { cols, ef }
            }
            k => bail!("bad stage tag {k} in packed payload"),
        })
    }
}

/// An ordered stack of compression stages — the `--compress` value.
///
/// Grammar: comma-separated [`StageSpec`] tokens, e.g. `topk,int8:ef` or
/// `topk@0.25,svd@4`.  The empty string is the empty pipeline (no
/// compression — byte-identical to a plain dense exchange).  Validation:
/// at most one stage of each kind, and `topk` (a row *selector*, not a
/// value transform) must come first when present.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct PipelineSpec {
    pub stages: Vec<StageSpec>,
}

impl PipelineSpec {
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.is_empty() {
            return Ok(Self::default());
        }
        let stages = s
            .split(',')
            .map(|tok| StageSpec::parse(tok.trim()))
            .collect::<Result<Vec<_>>>()?;
        let spec = Self { stages };
        spec.validate()?;
        Ok(spec)
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Canonical text form; `parse(label())` round-trips.
    pub fn label(&self) -> String {
        self.stages.iter().map(StageSpec::label).collect::<Vec<_>>().join(",")
    }

    pub fn validate(&self) -> Result<()> {
        for (i, s) in self.stages.iter().enumerate() {
            if self.stages[..i].iter().any(|t| t.name() == s.name()) {
                bail!("duplicate compression stage '{}'", s.name());
            }
            if matches!(s, StageSpec::TopK { .. }) && i != 0 {
                bail!("stage 'topk' must come first: it selects which rows travel");
            }
        }
        Ok(())
    }

    /// Transmitted paper-parameters per selected row (§III-F convention:
    /// every float — including the int8 stage's per-row scale — counts as
    /// one parameter; selection bits are counted separately from the
    /// block's bitmap).
    pub fn wire_params_per_row(&self, width: usize) -> u64 {
        let mut len = width;
        let mut params = width as u64;
        for s in &self.stages {
            match s {
                StageSpec::TopK { .. } | StageSpec::Fp16 { .. } => params = len as u64,
                StageSpec::Int8 { .. } => params = len as u64 + 1,
                StageSpec::Svd { cols, .. } => {
                    let c = SvdCodec::for_width(len, (*cols).min(len));
                    len = c.params_per_row(len);
                    params = len as u64;
                }
            }
        }
        params
    }
}

// ---------------------------------------------------------------------------
// Stage behaviors
// ---------------------------------------------------------------------------

/// One value-stream transform in a compression stack.  See the module
/// docs for the mid-pipeline (`forward`/`backward`) vs terminal
/// (`pack_row`/`unpack_row`) split and the bit-exactness invariant that
/// ties them together.
pub trait CompressionStage {
    /// The parsed description this stage was built from.
    fn spec(&self) -> StageSpec;

    /// Values leaving per row, given `in_len` values entering.
    fn out_len(&self, in_len: usize) -> usize {
        in_len
    }

    /// Encode-side value map: what the next stage (or the wire model)
    /// sees.  Quantizers return their lossy reconstruction (same
    /// length); the SVD stage returns packed factors.
    fn forward(&self, vals: &[f32]) -> Vec<f32>;

    /// Decode-side inverse of `forward` back to `in_len` values:
    /// identity for quantizers (their loss happened on the encode side),
    /// factor expansion for SVD.
    fn backward(&self, out: &[f32], in_len: usize) -> Vec<f32>;

    /// Packed bytes per row when this stage terminates the pipeline.
    fn packed_row_bytes(&self, in_len: usize) -> usize;

    /// Terminal packing of one row of input-domain values.
    fn pack_row(&self, vals: &[f32], out: &mut Vec<u8>);

    /// Inverse of `pack_row`: the input-domain reconstruction.  Must be
    /// bit-identical to `backward(forward(vals), vals.len())`.
    fn unpack_row(&self, bytes: &[u8], in_len: usize) -> Result<Vec<f32>>;
}

fn pack_f32s(vals: &[f32], out: &mut Vec<u8>) {
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn unpack_f32s(bytes: &[u8], n: usize) -> Result<Vec<f32>> {
    ensure!(bytes.len() == n * 4, "raw row: want {} bytes, got {}", n * 4, bytes.len());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Entity-wise Top-K row selection.  As a *value* stage it is the
/// identity (selection is handled by [`Pipeline::encode`], which owns the
/// cross-row view); as a terminal it packs raw f32 rows.
pub struct TopKStage {
    pub ratio: f64,
    pub ef: bool,
}

impl TopKStage {
    /// Rows kept out of `n` candidates (Eq. 1's K, ≥ 1).
    pub fn k_of(&self, n: usize) -> usize {
        top_k_count(n, self.ratio)
    }
}

impl CompressionStage for TopKStage {
    fn spec(&self) -> StageSpec {
        StageSpec::TopK { ratio: self.ratio, ef: self.ef }
    }

    fn forward(&self, vals: &[f32]) -> Vec<f32> {
        vals.to_vec()
    }

    fn backward(&self, out: &[f32], _in_len: usize) -> Vec<f32> {
        out.to_vec()
    }

    fn packed_row_bytes(&self, in_len: usize) -> usize {
        in_len * 4
    }

    fn pack_row(&self, vals: &[f32], out: &mut Vec<u8>) {
        pack_f32s(vals, out);
    }

    fn unpack_row(&self, bytes: &[u8], in_len: usize) -> Result<Vec<f32>> {
        unpack_f32s(bytes, in_len)
    }
}

/// int8 row quantization: per-row max-abs scale (one f32) + one signed
/// byte per value.  Dequantization is `code · scale / 127`, so the row
/// error is bounded by `scale / 254` (half a quantization step).
pub struct Int8Stage {
    pub ef: bool,
}

/// Quantize one row: (scale, codes).  An all-zero row has scale 0.
pub fn int8_quantize(vals: &[f32]) -> (f32, Vec<i8>) {
    let scale = vals.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if scale == 0.0 {
        return (0.0, vec![0; vals.len()]);
    }
    let codes = vals
        .iter()
        .map(|&v| (v / scale * 127.0).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (scale, codes)
}

/// The receiver-side reconstruction (also the sender's `forward` model).
pub fn int8_dequantize(scale: f32, codes: &[i8]) -> Vec<f32> {
    codes.iter().map(|&c| c as f32 * scale / 127.0).collect()
}

impl CompressionStage for Int8Stage {
    fn spec(&self) -> StageSpec {
        StageSpec::Int8 { ef: self.ef }
    }

    fn forward(&self, vals: &[f32]) -> Vec<f32> {
        let (scale, codes) = int8_quantize(vals);
        int8_dequantize(scale, &codes)
    }

    fn backward(&self, out: &[f32], _in_len: usize) -> Vec<f32> {
        out.to_vec()
    }

    fn packed_row_bytes(&self, in_len: usize) -> usize {
        4 + in_len
    }

    fn pack_row(&self, vals: &[f32], out: &mut Vec<u8>) {
        let (scale, codes) = int8_quantize(vals);
        out.extend_from_slice(&scale.to_le_bytes());
        out.extend(codes.iter().map(|&c| c as u8));
    }

    fn unpack_row(&self, bytes: &[u8], in_len: usize) -> Result<Vec<f32>> {
        ensure!(
            bytes.len() == 4 + in_len,
            "int8 row: want {} bytes, got {}",
            4 + in_len,
            bytes.len()
        );
        let scale = f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
        ensure!(scale.is_finite() && scale >= 0.0, "int8 row: bad scale {scale}");
        let codes: Vec<i8> = bytes[4..].iter().map(|&b| b as i8).collect();
        Ok(int8_dequantize(scale, &codes))
    }
}

/// IEEE-754 binary16 rows: 2 bytes per value, round-to-nearest-even.
pub struct Fp16Stage {
    pub ef: bool,
}

/// f32 → binary16 bits with round-to-nearest-even (no `half` crate
/// offline; this is the standard bit manipulation).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf stays Inf; NaN collapses to a quiet NaN
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → Inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow → signed zero
        }
        // subnormal half: shift the full 24-bit significand into place
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let round_up = rem > halfway || (rem == halfway && half & 1 == 1);
        return sign | (half + u32::from(round_up)) as u16;
    }
    let half = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let round_up = rem > 0x1000 || (rem == 0x1000 && half & 1 == 1);
    // a mantissa carry rolls into the exponent (and into Inf) correctly
    sign | (half + u32::from(round_up)) as u16
}

/// binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = u32::from(h >> 15) << 31;
    let exp = u32::from((h >> 10) & 0x1f);
    let man = u32::from(h & 0x3ff);
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: value = m · 2^-24, renormalized for f32
            let p = 31 - m.leading_zeros(); // highest set bit, 0..=9
            let e = p + 103; // biased exponent of 2^(p-24)
            sign | (e << 23) | ((m << (23 - p)) & 0x007f_ffff)
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, m) => sign | 0x7f80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

impl CompressionStage for Fp16Stage {
    fn spec(&self) -> StageSpec {
        StageSpec::Fp16 { ef: self.ef }
    }

    fn forward(&self, vals: &[f32]) -> Vec<f32> {
        vals.iter().map(|&v| f16_bits_to_f32(f32_to_f16_bits(v))).collect()
    }

    fn backward(&self, out: &[f32], _in_len: usize) -> Vec<f32> {
        out.to_vec()
    }

    fn packed_row_bytes(&self, in_len: usize) -> usize {
        in_len * 2
    }

    fn pack_row(&self, vals: &[f32], out: &mut Vec<u8>) {
        for &v in vals {
            out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
        }
    }

    fn unpack_row(&self, bytes: &[u8], in_len: usize) -> Result<Vec<f32>> {
        ensure!(
            bytes.len() == in_len * 2,
            "fp16 row: want {} bytes, got {}",
            in_len * 2,
            bytes.len()
        );
        Ok(bytes
            .chunks_exact(2)
            .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect())
    }
}

/// Rank-k SVD over the reshaped row, via [`SvdCodec`].
pub struct SvdStage {
    pub codec: SvdCodec,
    pub ef: bool,
    /// the requested (pre-`for_width`) column count, kept for the tag
    pub cols: usize,
}

impl CompressionStage for SvdStage {
    fn spec(&self) -> StageSpec {
        StageSpec::Svd { cols: self.cols, ef: self.ef }
    }

    fn out_len(&self, in_len: usize) -> usize {
        self.codec.params_per_row(in_len)
    }

    fn forward(&self, vals: &[f32]) -> Vec<f32> {
        self.codec.encode_row(vals)
    }

    fn backward(&self, out: &[f32], in_len: usize) -> Vec<f32> {
        self.codec.decode_row(out, in_len)
    }

    fn packed_row_bytes(&self, in_len: usize) -> usize {
        self.codec.params_per_row(in_len) * 4
    }

    fn pack_row(&self, vals: &[f32], out: &mut Vec<u8>) {
        pack_f32s(&self.codec.encode_row(vals), out);
    }

    fn unpack_row(&self, bytes: &[u8], in_len: usize) -> Result<Vec<f32>> {
        let packed = unpack_f32s(bytes, self.codec.params_per_row(in_len))?;
        Ok(self.codec.decode_row(&packed, in_len))
    }
}

/// Instantiate the behavior for one stage at its input width.
pub fn build_stage(spec: StageSpec, in_len: usize) -> Box<dyn CompressionStage> {
    match spec {
        StageSpec::TopK { ratio, ef } => Box::new(TopKStage { ratio, ef }),
        StageSpec::Int8 { ef } => Box::new(Int8Stage { ef }),
        StageSpec::Fp16 { ef } => Box::new(Fp16Stage { ef }),
        StageSpec::Svd { cols, ef } => Box::new(SvdStage {
            codec: SvdCodec::for_width(in_len, cols.min(in_len)),
            ef,
            cols,
        }),
    }
}

// ---------------------------------------------------------------------------
// The bound pipeline
// ---------------------------------------------------------------------------

/// The stage-tagged wire form of one encoded block of update rows:
/// self-describing (the tags travel with the data), so a decoder can
/// both validate it against its own pipeline and account for it without
/// out-of-band state.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedBlock {
    pub stages: Vec<StageSpec>,
    /// rows entering selection (the shared-list length)
    pub n_in: u32,
    /// which input rows travel (always `n_in` long; all-true when
    /// nothing narrowed the block)
    pub sel: Vec<bool>,
    /// entity row width the decoder expands back to
    pub width: u32,
    /// selected rows in ascending input order, terminal-stage packed
    pub body: Vec<u8>,
}

/// Stage-count ceiling on the wire — there are only four stage kinds and
/// duplicates are invalid, so anything larger is garbage, rejected
/// before allocation.
const MAX_WIRE_STAGES: usize = 8;

impl PackedBlock {
    pub fn n_rows(&self) -> usize {
        self.sel.iter().filter(|&&s| s).count()
    }

    /// Paper-parameter count (§III-F): one per selection bit + the
    /// transmitted values of each selected row.
    pub fn params(&self) -> u64 {
        let per = PipelineSpec { stages: self.stages.clone() }
            .wire_params_per_row(self.width as usize);
        self.sel.len() as u64 + self.n_rows() as u64 * per
    }

    pub fn write(&self, w: &mut WireWriter) {
        w.u8(self.stages.len() as u8);
        for s in &self.stages {
            s.write(w);
        }
        w.u32(self.n_in).bits(&self.sel).u32(self.width).blob(&self.body);
    }

    pub fn read(r: &mut WireReader<'_>) -> Result<PackedBlock> {
        let n_stages = r.u8()? as usize;
        ensure!(n_stages <= MAX_WIRE_STAGES, "bad stage count {n_stages} in packed payload");
        let mut stages = Vec::with_capacity(n_stages);
        for _ in 0..n_stages {
            stages.push(StageSpec::read(r)?);
        }
        PipelineSpec { stages: stages.clone() }.validate()?;
        let n_in = r.u32()?;
        let sel = r.bits()?;
        ensure!(
            sel.len() == n_in as usize,
            "packed payload selection bitmap covers {} rows, expected {n_in}",
            sel.len()
        );
        let width = r.u32()?;
        let body = r.blob()?;
        Ok(PackedBlock { stages, n_in, sel, width, body })
    }
}

/// A [`PipelineSpec`] bound to a row width: stage behaviors plus the
/// per-stage input lengths, ready to encode/decode blocks.
pub struct Pipeline {
    spec: PipelineSpec,
    width: usize,
    stages: Vec<Box<dyn CompressionStage>>,
    /// input length of each stage (the residual-table width for EF)
    in_lens: Vec<usize>,
}

impl Pipeline {
    pub fn new(spec: &PipelineSpec, width: usize) -> Result<Self> {
        spec.validate()?;
        ensure!(width >= 1 || spec.is_empty(), "cannot compress zero-width rows");
        let mut stages = Vec::with_capacity(spec.stages.len());
        let mut in_lens = Vec::with_capacity(spec.stages.len());
        let mut len = width;
        for &s in &spec.stages {
            let stage = build_stage(s, len);
            in_lens.push(len);
            len = stage.out_len(len);
            stages.push(stage);
        }
        Ok(Self { spec: spec.clone(), width, stages, in_lens })
    }

    pub fn spec(&self) -> &PipelineSpec {
        &self.spec
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Residual-table width per stage (its input length).
    pub fn stage_in_lens(&self) -> &[usize] {
        &self.in_lens
    }

    /// One error-feedback residual table per `:ef` stage (`None` for
    /// stages without), on the run's storage backend: `num_entities`
    /// rows so residuals are addressed by global entity id, and
    /// zero-initialized — sparse under mmap, so only rows the pipeline
    /// actually touches become resident (the PR 9 residency story).
    pub fn make_residuals(
        &self,
        storage: &StorageSpec,
        num_entities: usize,
    ) -> Result<Vec<Option<StoreTable>>> {
        self.stages
            .iter()
            .zip(&self.in_lens)
            .map(|(s, &in_len)| {
                s.spec()
                    .ef()
                    .then(|| StoreTable::zeros_in(storage, num_entities, in_len))
                    .transpose()
            })
            .collect()
    }

    /// Index of the first value stage (1 when stage 0 is the Top-K
    /// selector, else 0).
    fn value_off(&self) -> usize {
        usize::from(matches!(self.spec.stages.first(), Some(StageSpec::TopK { .. })))
    }

    /// Packed bytes per selected row (fixed — every stage's terminal
    /// form is fixed-size).
    pub fn terminal_row_bytes(&self) -> usize {
        match self.stages.last() {
            None => self.width * 4,
            Some(s) => s.packed_row_bytes(*self.in_lens.last().unwrap()),
        }
    }

    /// Encode a block of update rows (`ids.len()` × `width`, global
    /// entity `ids` ascending).  `present` externally masks rows before
    /// the Top-K stage sees them (the server's "uploaded this round"
    /// mask); `res` are this encoder's residual tables from
    /// [`make_residuals`] — error feedback mutates them in place.
    pub fn encode(
        &self,
        ids: &[u32],
        deltas: &[f32],
        present: Option<&[bool]>,
        res: &mut [Option<StoreTable>],
    ) -> PackedBlock {
        let n_in = ids.len();
        let width = self.width;
        debug_assert_eq!(deltas.len(), n_in * width);
        debug_assert_eq!(res.len(), self.stages.len());
        let mut sel: Vec<bool> = match present {
            Some(p) => {
                debug_assert_eq!(p.len(), n_in);
                p.to_vec()
            }
            None => vec![true; n_in],
        };

        // candidate rows in ascending input order, residual-augmented
        // when the selector carries EF
        let mut cand: Vec<(usize, Vec<f32>)> = sel
            .iter()
            .enumerate()
            .filter(|&(_, &s)| s)
            .map(|(i, _)| (i, deltas[i * width..(i + 1) * width].to_vec()))
            .collect();

        // stage 0: entity-wise Top-K selection
        if let Some(StageSpec::TopK { ratio, ef }) = self.spec.stages.first().copied() {
            if ef {
                let table = res[0].as_ref().expect("topk:ef carries a residual table");
                for (i, v) in &mut cand {
                    crate::linalg::axpy(1.0, table.row(ids[*i] as usize), v);
                }
            }
            let scores: Vec<f32> =
                cand.iter().map(|(_, v)| v.iter().map(|x| x * x).sum()).collect();
            let k = top_k_count(cand.len(), ratio);
            let keep_ranked = select_by_change(&scores, k);
            let mut keep = vec![false; cand.len()];
            for &j in &keep_ranked {
                keep[j] = true;
            }
            let mut kept = Vec::with_capacity(k);
            for (j, (i, v)) in cand.into_iter().enumerate() {
                if keep[j] {
                    if ef {
                        let table = res[0].as_mut().unwrap();
                        table.row_mut(ids[i] as usize).fill(0.0);
                    }
                    kept.push((i, v));
                } else {
                    sel[i] = false;
                    if ef {
                        let table = res[0].as_mut().unwrap();
                        table.set_row(ids[i] as usize, &v);
                    }
                }
            }
            cand = kept;
        }

        // value stages: transforms, then the terminal byte packing
        let off = self.value_off();
        let value_stages = &self.stages[off..];
        let mut body = Vec::with_capacity(cand.len() * self.terminal_row_bytes());
        for (i, mut v) in cand {
            let id = ids[i] as usize;
            for (j, stage) in value_stages.iter().enumerate() {
                let ri = off + j;
                let terminal = j + 1 == value_stages.len();
                // the selector's EF was drained above; raw-pack as-is
                let ef_here = stage.spec().ef() && !matches!(stage.spec(), StageSpec::TopK { .. });
                if ef_here {
                    let table = res[ri].as_ref().unwrap();
                    crate::linalg::axpy(1.0, table.row(id), &mut v);
                }
                if terminal {
                    let at = body.len();
                    stage.pack_row(&v, &mut body);
                    if ef_here {
                        let rec = stage
                            .unpack_row(&body[at..], v.len())
                            .expect("a just-packed row must unpack");
                        let table = res[ri].as_mut().unwrap();
                        let slot = table.row_mut(id);
                        for ((s, &a), &b) in slot.iter_mut().zip(&v).zip(&rec) {
                            *s = a - b;
                        }
                    }
                } else {
                    let y = stage.forward(&v);
                    if ef_here {
                        let rec = stage.backward(&y, v.len());
                        let table = res[ri].as_mut().unwrap();
                        let slot = table.row_mut(id);
                        for ((s, &a), &b) in slot.iter_mut().zip(&v).zip(&rec) {
                            *s = a - b;
                        }
                    }
                    v = y;
                }
            }
            if value_stages.is_empty() {
                // pipeline is the bare selector: raw f32 rows
                pack_f32s(&v, &mut body);
            }
        }

        PackedBlock {
            stages: self.spec.stages.clone(),
            n_in: n_in as u32,
            sel,
            width: width as u32,
            body,
        }
    }

    /// Decode a block: selected input indices (ascending) plus their
    /// reconstructed `width`-wide update rows, concatenated.  Every
    /// structural mismatch is a typed error, never a panic.
    pub fn decode(&self, block: &PackedBlock) -> Result<(Vec<usize>, Vec<f32>)> {
        ensure!(
            block.stages == self.spec.stages,
            "packed payload stages [{}] do not match the run's pipeline [{}]",
            PipelineSpec { stages: block.stages.clone() }.label(),
            self.spec.label()
        );
        ensure!(
            block.width as usize == self.width,
            "packed payload width {} does not match the run's width {}",
            block.width,
            self.width
        );
        ensure!(
            block.sel.len() == block.n_in as usize,
            "packed payload selection bitmap covers {} rows, expected {}",
            block.sel.len(),
            block.n_in
        );
        let idx: Vec<usize> = block
            .sel
            .iter()
            .enumerate()
            .filter(|&(_, &s)| s)
            .map(|(i, _)| i)
            .collect();
        let per = self.terminal_row_bytes();
        ensure!(
            block.body.len() as u64 == idx.len() as u64 * per as u64,
            "packed payload body is {} bytes, expected {} rows x {} bytes",
            block.body.len(),
            idx.len(),
            per
        );
        let off = self.value_off();
        let value_stages = &self.stages[off..];
        let mut rows = Vec::with_capacity(idx.len() * self.width);
        for chunk in block.body.chunks_exact(per.max(1)) {
            let v = match value_stages.split_last() {
                None => unpack_f32s(chunk, self.width)?,
                Some((term, earlier)) => {
                    let term_in = *self.in_lens.last().unwrap();
                    let mut v = term.unpack_row(chunk, term_in)?;
                    for (j, stage) in earlier.iter().enumerate().rev() {
                        v = stage.backward(&v, self.in_lens[off + j]);
                    }
                    v
                }
            };
            ensure!(
                v.len() == self.width,
                "decoded row has {} values, expected {}",
                v.len(),
                self.width
            );
            rows.extend_from_slice(&v);
        }
        Ok((idx, rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn paper_scale_params() {
        // D=256 reshaped 32×8 rank 5 → 205 transmitted params
        let c = SvdCodec::new(8, 5);
        assert_eq!(c.params_per_row(256), 205);
        assert!((c.compression_ratio(256) - 0.1992).abs() < 1e-3);
    }

    #[test]
    fn for_width_compresses() {
        for width in [64usize, 128, 256] {
            let c = SvdCodec::for_width(width, 8);
            assert!(
                c.params_per_row(width) < width,
                "width {width}: {} params",
                c.params_per_row(width)
            );
        }
    }

    #[test]
    fn for_width_accepts_non_divisible_widths() {
        // the old code asserted width % n_cols == 0 and aborted on the
        // d ∈ {25, 100, 200} widths the kernel parity tests exercise
        for width in [25usize, 100, 200] {
            let c = SvdCodec::for_width(width, 8);
            assert_eq!(width % c.n_cols, 0, "width {width}: n_cols {} not a divisor", c.n_cols);
            assert!(width / c.n_cols >= c.n_cols, "width {width}: reshape not tall ({c:?})");
            let mut rng = Rng::new(width as u64);
            let row: Vec<f32> = (0..width).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let dec = c.decode_row(&c.encode_row(&row), width);
            assert_eq!(dec.len(), width);
            assert!(dec.iter().all(|v| v.is_finite()));
        }
        // and the divisor choice matches the old halving for existing widths
        for (width, want) in [(32usize, 5usize.min(8)), (64, 8), (128, 8), (256, 8)] {
            let c = SvdCodec::for_width(width, 8);
            let _ = want;
            assert!(width % c.n_cols == 0 && width / c.n_cols >= c.n_cols);
        }
        assert_eq!(SvdCodec::for_width(32, 8).n_cols, 4); // same as halving 8 → 4
    }

    #[test]
    fn roundtrip_is_low_rank_approximation() {
        let mut rng = Rng::new(3);
        let width = 64;
        let c = SvdCodec::for_width(width, 8);
        let row: Vec<f32> = (0..width).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let dec = c.decode_row(&c.encode_row(&row), width);
        // must equal the direct rank-k projection
        let proj = c.project_row(&row);
        for (a, b) in dec.iter().zip(&proj) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        // and be lossy but correlated
        let err = crate::linalg::frob_diff(&row, &dec);
        let nrm = crate::linalg::norm(&row);
        assert!(err > 0.0 && err < nrm, "err {err} nrm {nrm}");
    }

    #[test]
    fn exact_for_rank_deficient_updates() {
        // rank-1 update transmits exactly
        let width = 64;
        let c = SvdCodec::new(8, 1);
        let x: Vec<f32> = (0..8).map(|i| 0.5 * i as f32 - 2.0).collect();
        let y = [0.3f32, -0.2, 0.9, 1.1, 0.05, -0.7, 0.4, 0.25];
        let mut row = vec![0.0f32; width];
        for i in 0..8 {
            for j in 0..8 {
                row[i * 8 + j] = x[i] * y[j];
            }
        }
        let dec = c.decode_row(&c.encode_row(&row), width);
        assert!(crate::linalg::frob_diff(&row, &dec) < 1e-4);
    }

    #[test]
    fn for_width_handles_narrow_rows() {
        // width 32 with n_cols 8 would reshape 4×8 (m < n); for_width must
        // shrink n_cols until tall
        let c = SvdCodec::for_width(32, 8);
        assert!(32 / c.n_cols >= c.n_cols, "{c:?}");
        assert!(c.params_per_row(32) < 32);
    }

    #[test]
    fn multi_row_roundtrip() {
        let mut rng = Rng::new(5);
        let width = 32;
        let c = SvdCodec::for_width(width, 8);
        let rows: Vec<f32> = (0..3 * width).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let packed = c.encode_rows(&rows, width);
        assert_eq!(packed.len(), 3 * c.params_per_row(width));
        let dec = c.decode_rows(&packed, width, 3);
        assert_eq!(dec.len(), rows.len());
        // each decoded row equals its own projection
        for i in 0..3 {
            let p = c.project_row(&rows[i * width..(i + 1) * width]);
            for (a, b) in dec[i * width..(i + 1) * width].iter().zip(&p) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    // -- pipeline spec ------------------------------------------------------

    #[test]
    fn pipeline_parse_label_roundtrip() {
        for s in [
            "",
            "topk",
            "topk@0.25",
            "topk:ef",
            "int8",
            "fp16:ef",
            "svd@4",
            "topk,int8:ef",
            "topk@0.5:ef,svd@4,int8",
            "topk, int8 : ef".trim(), // outer whitespace tolerated per token
        ] {
            let p = PipelineSpec::parse(s).unwrap_or_else(|e| panic!("parse {s:?}: {e}"));
            let back = PipelineSpec::parse(&p.label()).unwrap();
            assert_eq!(p, back, "label {:?} must re-parse to the same spec", p.label());
        }
        assert!(PipelineSpec::parse("").unwrap().is_empty());
        assert!(PipelineSpec::parse("   ").unwrap().is_empty());
    }

    #[test]
    fn pipeline_parse_rejects_bad_stacks() {
        for s in [
            "gzip",              // unknown stage
            "topk@0",            // ratio out of range
            "topk@1.5",          // ratio out of range
            "topk@x",            // unparseable ratio
            "int8@4",            // int8 takes no parameter
            "svd@0",             // cols must be ≥ 1
            "svd@2.5",           // cols must be integral
            "int8,int8",         // duplicate kind
            "int8,topk",         // selector not first
            "svd@4,fp16,topk",   // selector not first
        ] {
            assert!(PipelineSpec::parse(s).is_err(), "{s:?} must be rejected");
        }
    }

    #[test]
    fn int8_row_error_bounded_by_half_step() {
        // |v − dequant(quant(v))| ≤ scale/254 (+ f32 rounding slack)
        let mut rng = Rng::new(17);
        for _ in 0..200 {
            let n = 1 + rng.usize_below(64);
            let amp = 10f32.powi(rng.usize_below(7) as i32 - 3);
            let vals: Vec<f32> = (0..n).map(|_| rng.uniform(-amp, amp)).collect();
            let (scale, codes) = int8_quantize(&vals);
            let back = int8_dequantize(scale, &codes);
            let bound = scale / 254.0 * (1.0 + 1e-5) + 1e-30;
            for (&v, &b) in vals.iter().zip(&back) {
                assert!((v - b).abs() <= bound, "v {v} back {b} scale {scale}");
            }
        }
        // all-zero rows quantize losslessly
        let (scale, codes) = int8_quantize(&[0.0; 8]);
        assert_eq!(scale, 0.0);
        assert!(int8_dequantize(scale, &codes).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn f16_conversion_is_exact_for_representable_values() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.25, -65504.0, 65504.0, 6.1035156e-5] {
            let rt = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(rt.to_bits(), v.to_bits(), "{v} -> {rt}");
        }
        // relative error ≤ 2^-11 for the normal range
        let mut rng = Rng::new(23);
        for _ in 0..500 {
            let v = rng.uniform(-100.0, 100.0);
            let rt = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!((v - rt).abs() <= v.abs() * (1.0 / 2048.0) + 1e-7, "{v} vs {rt}");
        }
        // specials
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY); // overflow
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-10)), 0.0); // underflow
    }

    fn rand_block(rng: &mut Rng, n: usize, w: usize) -> (Vec<u32>, Vec<f32>) {
        let ids: Vec<u32> = (0..n as u32).collect();
        let deltas: Vec<f32> = (0..n * w).map(|_| rng.uniform(-1.0, 1.0)).collect();
        (ids, deltas)
    }

    #[test]
    fn every_stack_encodes_and_decodes() {
        let mut rng = Rng::new(7);
        let stacks = [
            "topk@0.5",
            "int8",
            "fp16",
            "svd@4",
            "topk@0.5,int8",
            "topk@0.5,fp16",
            "topk@0.5,svd@4",
            "topk@0.5,svd@4,int8",
            "topk@0.5,int8:ef",
            "topk:ef,int8:ef",
            "topk@0.5:ef,svd@4:ef,fp16:ef",
        ];
        for s in stacks {
            let spec = PipelineSpec::parse(s).unwrap();
            let w = 32;
            let pipe = Pipeline::new(&spec, w).unwrap();
            let (ids, deltas) = rand_block(&mut rng, 10, w);
            let mut res = pipe.make_residuals(&StorageSpec::Ram, 10).unwrap();
            let block = pipe.encode(&ids, &deltas, None, &mut res);
            assert_eq!(block.n_in, 10);
            assert_eq!(block.body.len(), block.n_rows() * pipe.terminal_row_bytes(), "{s}");
            let (idx, rows) = pipe.decode(&block).unwrap();
            assert_eq!(idx.len(), block.n_rows(), "{s}");
            assert_eq!(rows.len(), idx.len() * w, "{s}");
            assert!(rows.iter().all(|v| v.is_finite()), "{s}");
            // decoded rows approximate the originals (loose: every stage
            // here keeps most of the energy at these widths)
            for (j, &i) in idx.iter().enumerate() {
                let orig = &deltas[i * w..(i + 1) * w];
                let dec = &rows[j * w..(j + 1) * w];
                let err = crate::linalg::frob_diff(orig, dec);
                let nrm = crate::linalg::norm(orig).max(1e-6);
                assert!(err / nrm < 1.0, "{s}: row {i} err {err} nrm {nrm}");
            }
        }
    }

    #[test]
    fn empty_pipeline_is_identity() {
        let pipe = Pipeline::new(&PipelineSpec::default(), 4).unwrap();
        let mut rng = Rng::new(9);
        let (ids, deltas) = rand_block(&mut rng, 5, 4);
        let mut res = pipe.make_residuals(&StorageSpec::Ram, 5).unwrap();
        let block = pipe.encode(&ids, &deltas, None, &mut res);
        let (idx, rows) = pipe.decode(&block).unwrap();
        assert_eq!(idx, vec![0, 1, 2, 3, 4]);
        assert_eq!(rows, deltas);
    }

    #[test]
    fn topk_selects_largest_rows_and_external_mask_narrows() {
        let spec = PipelineSpec::parse("topk@0.5").unwrap();
        let pipe = Pipeline::new(&spec, 2).unwrap();
        let ids = [10u32, 11, 12, 13];
        // norms: 5, 1, 4, 3
        let deltas = [5.0f32, 0.0, 1.0, 0.0, 0.0, 4.0, 3.0, 0.0];
        let mut res = pipe.make_residuals(&StorageSpec::Ram, 20).unwrap();
        let block = pipe.encode(&ids, &deltas, None, &mut res);
        assert_eq!(block.sel, vec![true, false, true, false]);
        let (idx, rows) = pipe.decode(&block).unwrap();
        assert_eq!(idx, vec![0, 2]);
        assert_eq!(rows, vec![5.0, 0.0, 0.0, 4.0]);
        // mask out row 0: top-1 of the remaining 3 candidates is row 2
        let present = [false, true, true, true];
        let block = pipe.encode(&ids, &deltas, Some(&present), &mut res);
        assert_eq!(block.sel, vec![false, false, true, false]);
    }

    #[test]
    fn error_feedback_resends_dropped_mass() {
        // round 1 drops a small row; with EF its residual accumulates and
        // wins selection once the competing row stops changing
        let spec = PipelineSpec::parse("topk@0.5:ef").unwrap();
        let pipe = Pipeline::new(&spec, 1).unwrap();
        let ids = [0u32, 1];
        let mut res = pipe.make_residuals(&StorageSpec::Ram, 2).unwrap();
        let block = pipe.encode(&ids, &[1.0, 0.6], None, &mut res);
        assert_eq!(block.sel, vec![true, false], "row 0 wins round 1");
        // round 2: row 0 went quiet; row 1's residual (0.6) + fresh 0.6
        let block = pipe.encode(&ids, &[0.1, 0.6], None, &mut res);
        assert_eq!(block.sel, vec![false, true], "row 1's accumulated mass wins");
        let (_, rows) = pipe.decode(&block).unwrap();
        assert!((rows[0] - 1.2).abs() < 1e-6, "residual + fresh = {}", rows[0]);
        // and the drained residual does not triple-send
        let block = pipe.encode(&ids, &[0.0, 0.6], None, &mut res);
        let (_, rows) = pipe.decode(&block).unwrap();
        assert!((rows[0] - 0.7).abs() < 1e-6, "0.6 fresh + 0.1 residual = {}", rows[0]);
    }

    #[test]
    fn quantizer_error_feedback_reduces_two_round_error() {
        // with EF, the sum of two rounds' decoded values converges to the
        // sum of the true deltas (the classic EF telescoping property)
        let spec_ef = PipelineSpec::parse("int8:ef").unwrap();
        let spec_no = PipelineSpec::parse("int8").unwrap();
        let w = 16;
        let mut rng = Rng::new(41);
        let (ids, d1) = rand_block(&mut rng, 4, w);
        let (_, d2) = rand_block(&mut rng, 4, w);
        let run = |spec: &PipelineSpec| {
            let pipe = Pipeline::new(spec, w).unwrap();
            let mut res = pipe.make_residuals(&StorageSpec::Ram, 4).unwrap();
            let (_, r1) = pipe.decode(&pipe.encode(&ids, &d1, None, &mut res)).unwrap();
            let (_, r2) = pipe.decode(&pipe.encode(&ids, &d2, None, &mut res)).unwrap();
            let got: Vec<f32> = r1.iter().zip(&r2).map(|(a, b)| a + b).collect();
            let want: Vec<f32> = d1.iter().zip(&d2).map(|(a, b)| a + b).collect();
            crate::linalg::frob_diff(&got, &want)
        };
        let with_ef = run(&spec_ef);
        let without = run(&spec_no);
        assert!(
            with_ef < without,
            "EF must shrink accumulated error: {with_ef} vs {without}"
        );
    }

    #[test]
    fn packed_block_wire_roundtrip_and_params() {
        let spec = PipelineSpec::parse("topk@0.5,int8").unwrap();
        let pipe = Pipeline::new(&spec, 8).unwrap();
        let mut rng = Rng::new(13);
        let (ids, deltas) = rand_block(&mut rng, 6, 8);
        let mut res = pipe.make_residuals(&StorageSpec::Ram, 6).unwrap();
        let block = pipe.encode(&ids, &deltas, None, &mut res);
        let mut w = WireWriter::new();
        block.write(&mut w);
        let buf = w.finish();
        let mut r = WireReader::new(&buf);
        let back = PackedBlock::read(&mut r).unwrap();
        assert_eq!(back, block);
        // params: 6 sel bits + 3 rows × (8 codes + 1 scale)
        assert_eq!(block.params(), 6 + 3 * 9);
    }

    #[test]
    fn truncated_or_corrupt_blocks_are_errors_not_panics() {
        let spec = PipelineSpec::parse("topk@0.5,int8").unwrap();
        let pipe = Pipeline::new(&spec, 8).unwrap();
        let mut rng = Rng::new(29);
        let (ids, deltas) = rand_block(&mut rng, 6, 8);
        let mut res = pipe.make_residuals(&StorageSpec::Ram, 6).unwrap();
        let block = pipe.encode(&ids, &deltas, None, &mut res);
        let mut w = WireWriter::new();
        block.write(&mut w);
        let buf = w.finish();
        for cut in 0..buf.len() {
            let mut r = WireReader::new(&buf[..cut]);
            let _ = PackedBlock::read(&mut r); // must not panic
        }
        // corrupt stage tag
        let mut bad = buf.clone();
        bad[1] = 200;
        assert!(PackedBlock::read(&mut WireReader::new(&bad)).is_err());
        // a structurally-valid block against the wrong pipeline
        let other = Pipeline::new(&PipelineSpec::parse("topk@0.5,fp16").unwrap(), 8).unwrap();
        assert!(other.decode(&block).is_err());
        // body length mismatch
        let mut short = block.clone();
        short.body.pop();
        assert!(pipe.decode(&short).is_err());
    }
}
