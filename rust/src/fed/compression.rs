//! Compression baselines for Table I (paper Appendix VI-B).
//!
//! `SvdCodec` implements the FedE-SVD transport: each entity's embedding
//! *update* row (width W) is reshaped to an (m, n) matrix (m = W/n ≥ n),
//! decomposed with the one-sided Jacobi SVD, truncated to rank k, and
//! transmitted as packed `U[:, :k] ‖ s[:k] ‖ Vt[:k, :]` — exactly the
//! paper's parameter accounting (m·k + k + k·n per entity).
//!
//! FedE-SVD+ additionally constrains local training toward low-rank
//! updates; we approximate the constraint by hard-projecting the local
//! update to rank k at the end of local training (the information loss the
//! paper attributes to the constraint), documented in DESIGN.md §5.

use crate::linalg::svd::{svd, Svd};

#[derive(Clone, Copy, Debug)]
pub struct SvdCodec {
    /// columns of the reshaped update matrix (paper: 8)
    pub n_cols: usize,
    /// retained singular values (paper: 5 of 8 at D=256; scaled configs
    /// pick k so the codec actually compresses, see `for_width`)
    pub rank: usize,
}

impl SvdCodec {
    pub fn new(n_cols: usize, rank: usize) -> Self {
        assert!(rank <= n_cols);
        Self { n_cols, rank }
    }

    /// Pick a rank that yields real compression at this row width:
    /// the largest k with (m·k + k + k·n) < W.  `n_cols` shrinks (by
    /// halving) until the reshaped matrix is tall (m ≥ n), as the Jacobi
    /// SVD requires.
    pub fn for_width(width: usize, mut n_cols: usize) -> Self {
        assert_eq!(width % n_cols, 0, "width {width} not divisible by {n_cols}");
        while n_cols > 1 && width / n_cols < n_cols {
            n_cols /= 2;
        }
        let m = width / n_cols;
        let mut rank = 1;
        for k in 1..=n_cols.min(m) {
            if Svd::transmitted_params(m, n_cols, k) < width {
                rank = k;
            }
        }
        Self { n_cols, rank }
    }

    pub fn rows(&self, width: usize) -> usize {
        width / self.n_cols
    }

    /// Transmitted floats per entity row.
    pub fn params_per_row(&self, width: usize) -> usize {
        Svd::transmitted_params(self.rows(width), self.n_cols, self.rank)
    }

    /// Compression ratio per the paper's definition: (W − transmitted)/W.
    pub fn compression_ratio(&self, width: usize) -> f64 {
        1.0 - self.params_per_row(width) as f64 / width as f64
    }

    /// Encode one update row into packed factors.
    pub fn encode_row(&self, update: &[f32]) -> Vec<f32> {
        let n = self.n_cols;
        let m = update.len() / n;
        let k = self.rank;
        let f = svd(update, m, n);
        let mut out = Vec::with_capacity(m * k + k + k * n);
        for i in 0..m {
            for r in 0..k {
                out.push(f.u[i * n + r]);
            }
        }
        out.extend_from_slice(&f.s[..k]);
        for r in 0..k {
            out.extend_from_slice(&f.vt[r * n..(r + 1) * n]);
        }
        out
    }

    /// Decode packed factors back to an approximate update row.
    pub fn decode_row(&self, packed: &[f32], width: usize) -> Vec<f32> {
        let n = self.n_cols;
        let m = width / n;
        let k = self.rank;
        assert_eq!(packed.len(), m * k + k + k * n, "bad packed length");
        let (u, rest) = packed.split_at(m * k);
        let (s, vt) = rest.split_at(k);
        let mut out = vec![0.0f32; width];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for r in 0..k {
                    acc += u[i * k + r] * s[r] * vt[r * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    /// Encode many rows (concatenated) into one packed payload.
    pub fn encode_rows(&self, updates: &[f32], width: usize) -> Vec<f32> {
        updates
            .chunks_exact(width)
            .flat_map(|row| self.encode_row(row))
            .collect()
    }

    pub fn decode_rows(&self, packed: &[f32], width: usize, n_rows: usize) -> Vec<f32> {
        let per = self.params_per_row(width);
        assert_eq!(packed.len(), per * n_rows, "bad packed payload");
        let mut out = Vec::with_capacity(n_rows * width);
        for i in 0..n_rows {
            out.extend_from_slice(&self.decode_row(&packed[i * per..(i + 1) * per], width));
        }
        out
    }

    /// SVD+ constraint approximation: project an update row to rank k.
    pub fn project_row(&self, update: &[f32]) -> Vec<f32> {
        let n = self.n_cols;
        let m = update.len() / n;
        crate::linalg::svd::low_rank_project(update, m, n, self.rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn paper_scale_params() {
        // D=256 reshaped 32×8 rank 5 → 205 transmitted params
        let c = SvdCodec::new(8, 5);
        assert_eq!(c.params_per_row(256), 205);
        assert!((c.compression_ratio(256) - 0.1992).abs() < 1e-3);
    }

    #[test]
    fn for_width_compresses() {
        for width in [64usize, 128, 256] {
            let c = SvdCodec::for_width(width, 8);
            assert!(
                c.params_per_row(width) < width,
                "width {width}: {} params",
                c.params_per_row(width)
            );
        }
    }

    #[test]
    fn roundtrip_is_low_rank_approximation() {
        let mut rng = Rng::new(3);
        let width = 64;
        let c = SvdCodec::for_width(width, 8);
        let row: Vec<f32> = (0..width).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let dec = c.decode_row(&c.encode_row(&row), width);
        // must equal the direct rank-k projection
        let proj = c.project_row(&row);
        for (a, b) in dec.iter().zip(&proj) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        // and be lossy but correlated
        let err = crate::linalg::frob_diff(&row, &dec);
        let nrm = crate::linalg::norm(&row);
        assert!(err > 0.0 && err < nrm, "err {err} nrm {nrm}");
    }

    #[test]
    fn exact_for_rank_deficient_updates() {
        // rank-1 update transmits exactly
        let width = 64;
        let c = SvdCodec::new(8, 1);
        let x: Vec<f32> = (0..8).map(|i| 0.5 * i as f32 - 2.0).collect();
        let y = [0.3f32, -0.2, 0.9, 1.1, 0.05, -0.7, 0.4, 0.25];
        let mut row = vec![0.0f32; width];
        for i in 0..8 {
            for j in 0..8 {
                row[i * 8 + j] = x[i] * y[j];
            }
        }
        let dec = c.decode_row(&c.encode_row(&row), width);
        assert!(crate::linalg::frob_diff(&row, &dec) < 1e-4);
    }

    #[test]
    fn for_width_handles_narrow_rows() {
        // width 32 with n_cols 8 would reshape 4×8 (m < n); for_width must
        // shrink n_cols until tall
        let c = SvdCodec::for_width(32, 8);
        assert!(32 / c.n_cols >= c.n_cols, "{c:?}");
        assert!(c.params_per_row(32) < 32);
    }

    #[test]
    fn multi_row_roundtrip() {
        let mut rng = Rng::new(5);
        let width = 32;
        let c = SvdCodec::for_width(width, 8);
        let rows: Vec<f32> = (0..3 * width).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let packed = c.encode_rows(&rows, width);
        assert_eq!(packed.len(), 3 * c.params_per_row(width));
        let dec = c.decode_rows(&packed, width, 3);
        assert_eq!(dec.len(), rows.len());
        // each decoded row equals its own projection
        for i in 0..3 {
            let p = c.project_row(&rows[i * width..(i + 1) * width]);
            for (a, b) in dec[i * width..(i + 1) * width].iter().zip(&p) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }
}
