//! The federated round loop: local training → evaluation/early-stop →
//! communication, for every algorithm in the paper's evaluation.
//!
//! Algorithms (§IV-B, Appendix VI):
//! * `Single`  — local training only, no communication.
//! * `FedEP`   — dense FedE with personalized evaluation (the baseline all
//!               efficiency metrics are scaled against).
//! * `FedEPL`  — FedEP at the reduced dimension of Appendix VI-C.
//! * `FedS`    — Entity-Wise Top-K sparsification both ways + Intermittent
//!               Synchronization; `sync: false` is the FedS/syn ablation.
//! * `FedKd`   — dual-dimension co-distillation transport (Table I).
//! * `FedSvd`  — SVD-compressed update transport; `constrained` adds the
//!               SVD+ low-rank training constraint (Table I).
//!
//! Architecture: the orchestrator is message-driven.  Each algorithm
//! family is an [`exchange::Exchange`] strategy with a client half and a
//! server half; each client is a [`client::ClientRunner`] that owns its
//! state and talks to the server **only** via framed `Upload`/`Download`
//! messages over a metered `comm::transport::Endpoint` pair — the single
//! path on which parameters and bytes are metered, identical to what a
//! distributed deployment would transmit.  The links are **pluggable**
//! ([`crate::comm::transport::TransportSpec`]): in-process mpsc duplexes
//! or real TCP loopback sockets, with bit-identical accounting either
//! way.  Two execution modes share the same server-side driver
//! ([`ExecMode`]): `Sequential` steps clients in order on the calling
//! thread (required for the non-`Send` PJRT-backed trainers), `Threaded`
//! runs each native-backend client's training and evaluation on its own
//! OS thread.  Both modes produce byte-identical accounting and
//! bit-identical metrics: uploads are folded and replies built in
//! client-id order regardless of thread arrival order.
//!
//! Internals consume [`RoundParams`] — the resolved-parameter struct
//! derived once per run from a [`crate::spec::ExperimentSpec`]
//! ([`RoundParams::from_spec`]); [`run_params`] is the engine entry
//! point every public surface (sessions, the CLI, the cluster runtime)
//! drives.

pub mod client;
pub mod exchange;
pub mod params;

use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::Result;

use crate::comm::accounting::{Accounting, Direction};
use crate::comm::transport::{duplex, Endpoint, TcpTransport, TransportSpec};
use crate::data::partition::FedDataset;
use crate::kge::{Hyper, Table};
use crate::metrics::observe::{emit, HistoryObserver, RunEvent, RunObserver};
use crate::metrics::tracker::{RoundRecord, RunHistory};
use crate::metrics::{EarlyStop, RankMetrics};
use crate::runtime::Runtime;
use crate::trainer::{KdXlaTrainer, LocalTrainer, NativeTrainer, XlaTrainer};
use crate::util::rng::Rng;

use super::protocol::Upload;
use super::server::Server;
use super::{comm_ratio, fedepl_dim};

use client::{initial_table, ClientRunner, Report};
pub use params::RoundParams;

/// Which algorithm drives the communication phase.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Algo {
    Single,
    FedEP,
    FedEPL,
    FedS { sync: bool },
    FedKd,
    FedSvd { constrained: bool },
}

impl Algo {
    pub fn label(&self) -> &'static str {
        match self {
            Algo::Single => "Single",
            Algo::FedEP => "FedEP",
            Algo::FedEPL => "FedEPL",
            Algo::FedS { sync: true } => "FedS",
            Algo::FedS { sync: false } => "FedS/syn",
            Algo::FedKd => "FedE-KD",
            Algo::FedSvd { constrained: false } => "FedE-SVD",
            Algo::FedSvd { constrained: true } => "FedE-SVD+",
        }
    }

    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "single" => Algo::Single,
            "fedep" | "fede" => Algo::FedEP,
            "fedepl" => Algo::FedEPL,
            "feds" => Algo::FedS { sync: true },
            "feds-nosync" | "feds/syn" => Algo::FedS { sync: false },
            "fedkd" | "fede-kd" => Algo::FedKd,
            "fedsvd" | "fede-svd" => Algo::FedSvd { constrained: false },
            "fedsvd+" | "fede-svd+" => Algo::FedSvd { constrained: true },
            other => anyhow::bail!(
                "unknown algorithm '{other}' \
                 (single|fedep|fedepl|feds|feds-nosync|fedkd|fedsvd|fedsvd+)"
            ),
        })
    }
}

/// Where local training executes.
#[derive(Clone)]
pub enum Backend {
    /// AOT artifacts via PJRT — the production path.
    Xla(Rc<Runtime>),
    /// Pure-Rust oracle — artifact-free tests and the SVD+ native path.
    Native {
        hyper: Hyper,
        batch: usize,
        negatives: usize,
        eval_batch: usize,
    },
}

impl Backend {
    pub(crate) fn batch_shape(&self) -> (usize, usize) {
        match self {
            Backend::Xla(rt) => (rt.manifest.batch, rt.manifest.negatives),
            Backend::Native { batch, negatives, .. } => (*batch, *negatives),
        }
    }

    pub(crate) fn make_trainer(
        &self,
        params: &RoundParams,
        num_entities: usize,
        num_relations: usize,
    ) -> Result<Box<dyn LocalTrainer>> {
        let mut rng = Rng::new(params.seed);
        match self {
            Backend::Xla(rt) => match params.algo {
                Algo::FedKd => {
                    Ok(Box::new(KdXlaTrainer::new(rt.clone(), params.method, &mut rng)?))
                }
                Algo::FedEPL => {
                    let dim = rt.manifest.fedepl_dim;
                    Ok(Box::new(XlaTrainer::new(rt.clone(), params.method, dim, &mut rng)?))
                }
                _ => Ok(Box::new(XlaTrainer::new(
                    rt.clone(),
                    params.method,
                    rt.manifest.hyper.dim,
                    &mut rng,
                )?)),
            },
            Backend::Native { hyper, eval_batch, .. } => Ok(Box::new(native_trainer(
                hyper,
                *eval_batch,
                params,
                num_entities,
                num_relations,
                &mut rng,
            )?)),
        }
    }
}

/// Build one client's pure-Rust trainer.  FedEPL's reduced dimension
/// (Appendix VI-C) is derived from the **configured** sparsity and sync
/// interval, so the FedEPL/FedS comparison stays volume-matched for any
/// parameterization, not just the paper defaults.
pub(crate) fn native_trainer(
    hyper: &Hyper,
    eval_batch: usize,
    params: &RoundParams,
    num_entities: usize,
    num_relations: usize,
    rng: &mut Rng,
) -> Result<NativeTrainer> {
    anyhow::ensure!(
        params.algo != Algo::FedKd,
        "FedE-KD requires the XLA backend (co-distillation artifact)"
    );
    let hyper = if params.algo == Algo::FedEPL {
        Hyper {
            dim: fedepl_dim(hyper.dim, params.sparsity, params.sync_interval),
            ..hyper.clone()
        }
    } else {
        hyper.clone()
    };
    NativeTrainer::with_store(
        params.method,
        hyper,
        num_entities,
        num_relations,
        eval_batch,
        &params.storage,
        rng,
    )
}

/// How client-side work executes within a round.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// All clients stepped in order on the calling thread (any backend).
    /// Native-backend evaluation still uses the full machine: each
    /// `eval_ranks` call chunks its candidate scan across cores
    /// (bit-identical to a single-threaded scan).
    #[default]
    Sequential,
    /// One OS thread per client for local training + evaluation (native
    /// backend only — the PJRT client is not `Send`).  Byte-identical
    /// accounting and bit-identical metrics to `Sequential`.
    Threaded,
}

impl ExecMode {
    pub fn parse(s: &str) -> Result<ExecMode> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "seq" | "sequential" => ExecMode::Sequential,
            "threaded" | "threads" | "thread" => ExecMode::Threaded,
            other => anyhow::bail!("unknown exec mode '{other}' (seq|threaded)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            ExecMode::Sequential => "seq",
            ExecMode::Threaded => "threaded",
        }
    }
}

/// Outcome of a federated run: history plus final accounting.
pub struct RunOutcome {
    pub history: RunHistory,
    pub acct: Arc<Accounting>,
    /// analytic Eq. 5 ratio for this configuration (FedS only)
    pub eq5_ratio: Option<f64>,
}

/// The engine entry point: run the round loop over the resolved
/// parameters, streaming [`RunEvent`]s to `extra` observers (plus the
/// internal [`HistoryObserver`] that assembles the outcome's history).
pub fn run_params(
    data: &FedDataset,
    params: &RoundParams,
    backend: &Backend,
    extra: &mut [&mut dyn RunObserver],
) -> Result<RunOutcome> {
    let acct = Accounting::new();
    let mut hist = HistoryObserver::new();
    let width;
    {
        let mut observers: Vec<&mut dyn RunObserver> = Vec::with_capacity(1 + extra.len());
        observers.push(&mut hist);
        for o in extra.iter_mut() {
            observers.push(&mut **o);
        }
        width = match params.exec {
            ExecMode::Sequential => run_sequential(data, params, backend, &acct, &mut observers)?,
            ExecMode::Threaded => run_threaded(data, params, backend, &acct, &mut observers)?,
        };
        emit(
            &mut observers,
            &RunEvent::RunEnd {
                params: acct.params(),
                bytes: acct.bytes(),
                messages: acct.messages(),
            },
        );
    }
    let eq5 = matches!(params.algo, Algo::FedS { .. })
        .then(|| comm_ratio(params.sparsity, params.sync_interval, width));
    Ok(RunOutcome { history: hist.take(), acct, eq5_ratio: eq5 })
}

/// The run's link factory: how each client↔server endpoint pair is
/// established for the selected transport.
enum LinkFactory {
    Mpsc,
    Tcp(TcpTransport),
}

impl LinkFactory {
    fn new(transport: TransportSpec) -> Result<Self> {
        Ok(match transport {
            TransportSpec::Mpsc => LinkFactory::Mpsc,
            TransportSpec::Tcp => LinkFactory::Tcp(TcpTransport::bind_loopback()?),
        })
    }

    /// One connected (client_end, server_end) pair metering into `acct`.
    fn pair(&self, acct: &Arc<Accounting>) -> Result<(Box<dyn Endpoint>, Box<dyn Endpoint>)> {
        Ok(match self {
            LinkFactory::Mpsc => {
                let (c, s) = duplex(acct.clone());
                (Box::new(c) as Box<dyn Endpoint>, Box::new(s) as Box<dyn Endpoint>)
            }
            LinkFactory::Tcp(t) => {
                let (c, s) = t.connect_pair(acct.clone())?;
                (Box::new(c) as Box<dyn Endpoint>, Box::new(s) as Box<dyn Endpoint>)
            }
        })
    }
}

/// The server side of a run: aggregation state, the strategy's server
/// half, eval weights, and the run label (history itself is assembled by
/// the observer pipeline).
pub(crate) struct ServerSide {
    pub(crate) server: Server,
    pub(crate) exchange: Option<Box<dyn exchange::Exchange>>,
    pub(crate) weights: Vec<f64>,
    pub(crate) label: String,
}

pub(crate) fn server_side(
    data: &FedDataset,
    params: &RoundParams,
    width: usize,
    refs: Vec<Table>,
) -> Result<ServerSide> {
    let shared: Vec<Vec<u32>> =
        data.clients.iter().map(|c| data.shared_entities_of(c.id)).collect();
    let server =
        Server::with_store(data.num_entities, width, shared, params.shards, &params.storage)?;
    let exchange = exchange::server_half(params, width, data.num_entities, refs)?;
    let label = format!(
        "{}-{}-{}c",
        params.algo.label(),
        params.method.name(),
        data.clients.len()
    );
    crate::info!(
        "run {}: {} clients, {} shared entities, width {}, p={}, s={}, exec {}, \
         transport {}, {} server shard(s)",
        label,
        data.clients.len(),
        data.shared.len(),
        width,
        params.sparsity,
        params.sync_interval,
        params.exec.label(),
        params.transport.label(),
        server.num_shards()
    );
    Ok(ServerSide { server, exchange, weights: data.test_weights(), label })
}

/// The driver's view of the client fleet.  The server-side round loop is
/// identical in both execution modes; only how client work is triggered
/// differs — stepped inline (sequential) or free-running threads that the
/// control plane paces (threaded).
trait ClientPool {
    /// One round of local work from every client, in client-id order.
    fn collect_reports(&mut self, round: usize, eval: bool) -> Result<Vec<Report>>;
    /// Deliver the continue/stop verdict after an evaluation.
    fn broadcast_verdict(&mut self, stop: bool) -> Result<()>;
    /// Client half of the upload phase (no-op when clients push on their
    /// own threads).
    fn send_uploads(&mut self, round: u32) -> Result<()>;
    /// Client half of the download phase.
    fn recv_downloads(&mut self) -> Result<()>;
}

/// Shared server-side round loop: pace the fleet, meter every frame over
/// the transport links, aggregate in client-id order for bit-stable
/// results.
///
/// The loop emits typed [`RunEvent`]s instead of assembling history or
/// printing inline; the [`HistoryObserver`] registered by [`run_params`]
/// reconstructs exactly the legacy history (bit-identical records, same
/// convergence index).
fn drive(
    pool: &mut dyn ClientPool,
    side: &mut ServerSide,
    links: &[Box<dyn Endpoint>],
    params: &RoundParams,
    acct: &Accounting,
    observers: &mut [&mut dyn RunObserver],
) -> Result<()> {
    let mut es = EarlyStop::new(params.patience);
    let mut n_records = 0usize;
    let mut converged_emitted = false;
    for round in 1..=params.max_rounds {
        emit(observers, &RunEvent::RoundStart { round });
        // --- 1. local training (+ eval) on every client --------------------
        let eval_round = round % params.eval_every == 0;
        let reports = pool.collect_reports(round, eval_round)?;
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        let mut valid_pc = Vec::new();
        let mut test_pc = Vec::new();
        for rep in &reports {
            loss_sum += rep.loss as f64 * rep.batches as f64;
            loss_n += rep.batches;
            if let Some((v, t)) = rep.eval {
                valid_pc.push(v);
                test_pc.push(t);
            }
        }

        // --- 2. evaluation + early stopping --------------------------------
        if eval_round {
            let valid = RankMetrics::weighted(&valid_pc, &side.weights);
            let test = RankMetrics::weighted(&test_pc, &side.weights);
            let mean_loss = if loss_n > 0 { loss_sum / loss_n as f64 } else { 0.0 };
            let record = RoundRecord {
                round,
                params_cum: acct.params(),
                bytes_cum: acct.bytes(),
                valid,
                test,
                mean_loss,
            };
            n_records += 1;
            emit(observers, &RunEvent::Evaluated { record });
            let stop = es.update(valid.mrr);
            pool.broadcast_verdict(stop)?;
            if stop {
                emit(observers, &RunEvent::Converged { record_index: es.best_index() });
                converged_emitted = true;
                break;
            }
        }

        // --- 3. communication ----------------------------------------------
        if let Some(ex) = side.exchange.as_mut() {
            ex.begin_round(round as u32);
            side.server.begin_round();
            pool.send_uploads(round as u32)?;
            for (c, link) in links.iter().enumerate() {
                if side.server.shared[c].is_empty() {
                    continue;
                }
                let msg = Upload::decode(&link.recv()?)?;
                ex.server_receive(&mut side.server, c as u16, msg)?;
            }
            // Snapshot the upload-side counters here, where they are
            // deterministic in both exec modes: every client has sent
            // exactly `round` uploads and none can start round+1 before
            // receiving this round's download.  (In threaded mode a fast
            // client may send its NEXT upload before the Synced emission
            // below — reading the shared totals there would race.)
            let up_params = acct.params_dir(Direction::Upload);
            let up_bytes = acct.bytes_dir(Direction::Upload);
            emit(
                observers,
                &RunEvent::UploadAccounted {
                    round,
                    params_cum: acct.params(),
                    bytes_cum: acct.bytes(),
                    messages: acct.messages(),
                },
            );
            for (c, link) in links.iter().enumerate() {
                if side.server.shared[c].is_empty() {
                    continue;
                }
                let msg = ex.server_download(round as u32, &mut side.server, c as u16)?;
                let params_count = msg.params();
                link.send(msg.encode(), params_count)?;
            }
            pool.recv_downloads()?;
            // Download counters are driver-written only, so combining
            // them with the pre-download upload snapshot makes Synced
            // deterministic and identical across exec modes/transports.
            emit(
                observers,
                &RunEvent::Synced {
                    round,
                    params_cum: up_params + acct.params_dir(Direction::Download),
                    bytes_cum: up_bytes + acct.bytes_dir(Direction::Download),
                },
            );
        }
    }

    if !converged_emitted && n_records > 0 {
        let idx = es.best_index().min(n_records - 1);
        emit(observers, &RunEvent::Converged { record_index: idx });
    }
    Ok(())
}

/// Sequential mode: runners stepped in order on this thread.  The frames
/// still round-trip through the transport links, so metering is exactly
/// the threaded path's.
struct SeqPool<'r, 'd> {
    runners: &'r mut [ClientRunner<'d>],
}

impl ClientPool for SeqPool<'_, '_> {
    fn collect_reports(&mut self, round: usize, eval: bool) -> Result<Vec<Report>> {
        self.runners.iter_mut().map(|r| r.local_round(round, eval)).collect()
    }

    fn broadcast_verdict(&mut self, _stop: bool) -> Result<()> {
        Ok(()) // inert runners stop when the driver stops stepping them
    }

    fn send_uploads(&mut self, round: u32) -> Result<()> {
        for r in self.runners.iter_mut() {
            r.send_upload(round)?;
        }
        Ok(())
    }

    fn recv_downloads(&mut self) -> Result<()> {
        for r in self.runners.iter_mut() {
            r.recv_download()?;
        }
        Ok(())
    }
}

/// Threaded mode: each client loops on its own OS thread; the pool only
/// relays control-plane traffic, in client-id order.
struct ThreadedPool {
    reports: Vec<Receiver<Report>>,
    verdicts: Vec<Sender<bool>>,
}

impl ClientPool for ThreadedPool {
    fn collect_reports(&mut self, _round: usize, _eval: bool) -> Result<Vec<Report>> {
        self.reports
            .iter()
            .enumerate()
            .map(|(c, rx)| {
                rx.recv().map_err(|_| anyhow::anyhow!("client {c} disconnected before reporting"))
            })
            .collect()
    }

    fn broadcast_verdict(&mut self, stop: bool) -> Result<()> {
        for (c, tx) in self.verdicts.iter().enumerate() {
            tx.send(stop)
                .map_err(|_| anyhow::anyhow!("client {c} disconnected before the verdict"))?;
        }
        Ok(())
    }

    fn send_uploads(&mut self, _round: u32) -> Result<()> {
        Ok(())
    }

    fn recv_downloads(&mut self) -> Result<()> {
        Ok(())
    }
}

fn run_sequential(
    data: &FedDataset,
    params: &RoundParams,
    backend: &Backend,
    acct: &Arc<Accounting>,
    observers: &mut [&mut dyn RunObserver],
) -> Result<usize> {
    let (batch_size, negatives) = backend.batch_shape();
    let factory = LinkFactory::new(params.transport)?;
    let mut runners = Vec::with_capacity(data.clients.len());
    let mut links = Vec::with_capacity(data.clients.len());
    for c in &data.clients {
        let (client_end, server_end) = factory.pair(acct)?;
        let trainer = backend.make_trainer(params, data.num_entities, data.num_relations)?;
        runners.push(ClientRunner::build(
            data, c.id, params, trainer, client_end, batch_size, negatives,
        )?);
        links.push(server_end);
    }
    let width = runners[0].width();
    let refs: Vec<Table> = if params.wants_refs() {
        runners
            .iter()
            .map(|r| {
                r.reference_table()
                    .expect("a reference-delta transport's runner carries a reference table")
            })
            .collect()
    } else {
        Vec::new()
    };
    let mut side = server_side(data, params, width, refs)?;
    emit(
        observers,
        &RunEvent::RunStart {
            label: side.label.clone(),
            clients: data.clients.len(),
            width,
        },
    );
    let mut pool = SeqPool { runners: &mut runners };
    drive(&mut pool, &mut side, &links, params, acct, observers)?;
    Ok(width)
}

fn run_threaded(
    data: &FedDataset,
    params: &RoundParams,
    backend: &Backend,
    acct: &Arc<Accounting>,
    observers: &mut [&mut dyn RunObserver],
) -> Result<usize> {
    let Backend::Native { hyper, batch, negatives, eval_batch } = backend else {
        anyhow::bail!("threaded execution is native-backend only");
    };
    let dim = if params.algo == Algo::FedEPL {
        fedepl_dim(hyper.dim, params.sparsity, params.sync_interval)
    } else {
        hyper.dim
    };
    let width = params.method.entity_width(dim);
    let refs: Vec<Table> = if params.wants_refs() {
        // Probe trainer: every client initializes from the same
        // `params.seed` stream, so one throwaway trainer yields the
        // agreed initial reference state (SVD or pipeline transport)
        // without touching any client's RNG.
        let mut probe_rng = Rng::new(params.seed);
        let mut probe = native_trainer(
            hyper,
            *eval_batch,
            params,
            data.num_entities,
            data.num_relations,
            &mut probe_rng,
        )?;
        debug_assert_eq!(probe.entity_width(), width);
        data.clients
            .iter()
            .map(|c| {
                let shared = data.shared_entities_of(c.id);
                initial_table(&mut probe, &shared, data.num_entities, width)
            })
            .collect::<Result<_>>()?
    } else {
        Vec::new()
    };
    let mut side = server_side(data, params, width, refs)?;
    emit(
        observers,
        &RunEvent::RunStart {
            label: side.label.clone(),
            clients: data.clients.len(),
            width,
        },
    );

    let factory = LinkFactory::new(params.transport)?;
    // establish every connection before any client thread starts: a
    // failed connect must surface as an error, not leave already-running
    // clients blocked on a server that will never drive them
    let mut pairs = Vec::with_capacity(data.clients.len());
    for _ in &data.clients {
        pairs.push(factory.pair(acct)?);
    }
    std::thread::scope(|s| -> Result<()> {
        let n = data.clients.len();
        let mut links = Vec::with_capacity(n);
        let mut reports = Vec::with_capacity(n);
        let mut verdicts = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (c, (client_end, server_end)) in data.clients.iter().zip(pairs) {
            let (rep_tx, rep_rx) = channel();
            let (ver_tx, ver_rx) = channel();
            let id = c.id;
            let params = params.clone();
            let hyper = hyper.clone();
            let (eval_batch, batch_size, negatives) = (*eval_batch, *batch, *negatives);
            handles.push(s.spawn(move || -> Result<()> {
                let mut rng = Rng::new(params.seed);
                let mut trainer = native_trainer(
                    &hyper,
                    eval_batch,
                    &params,
                    data.num_entities,
                    data.num_relations,
                    &mut rng,
                )?;
                // one OS thread per client already saturates the machine;
                // pin the per-trainer eval fan-out to avoid oversubscribing
                // (ranks are bit-identical for any thread count)
                trainer.set_eval_threads(1);
                let runner = ClientRunner::build(
                    data,
                    id,
                    &params,
                    Box::new(trainer),
                    client_end,
                    batch_size,
                    negatives,
                )?;
                runner.run(rep_tx, ver_rx)
            }));
            links.push(server_end);
            reports.push(rep_rx);
            verdicts.push(ver_tx);
        }
        let mut pool = ThreadedPool { reports, verdicts };
        let driven = drive(&mut pool, &mut side, &links, params, acct, observers);
        // Unblock any client still waiting on a verdict or a reply frame
        // before joining, so a server-side error can't deadlock the fleet.
        drop(pool);
        drop(links);
        let mut clients_res = Ok(());
        for (i, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if clients_res.is_ok() {
                        clients_res = Err(e.context(format!("client {i} failed")));
                    }
                }
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
        driven.and(clients_res)
    })?;
    Ok(width)
}
