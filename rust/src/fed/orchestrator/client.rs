//! Client-side execution: a `ClientRunner` owns its `ClientCtx` (trainer +
//! local tables + eval sets) and speaks to the server **only** through
//! framed `Upload`/`Download` messages on a metered
//! `comm::transport::Endpoint` — the single path on which every exchanged
//! parameter and byte is accounted, whichever transport backs it.  Round
//! results (loss, eval metrics) and the continue/stop verdict travel on a
//! separate unmetered control plane, mirroring a deployment's
//! control/data-plane split.

use std::sync::mpsc::{Receiver, Sender};

use anyhow::Result;

use crate::comm::transport::Endpoint;
use crate::data::dataset::{BatchIter, EvalSet, FilterIndex};
use crate::data::partition::FedDataset;
use crate::data::Triple;
use crate::fed::compression::SvdCodec;
use crate::fed::protocol::Download;
use crate::kge::Table;
use crate::metrics::RankMetrics;
use crate::store::{StorageSpec, StoreTable};
use crate::trainer::{evaluate, LocalTrainer};
use crate::util::rng::Rng;

use super::exchange::{self, Exchange};
use super::{Algo, RoundParams};

/// Per-client local state, owned by exactly one `ClientRunner`.
pub struct ClientCtx {
    pub id: u16,
    pub trainer: Box<dyn LocalTrainer>,
    /// shared entities (sorted global ids) — the communicated set N_c
    pub shared: Vec<u32>,
    /// FedS history table E^h (full-size; only shared rows meaningful).
    /// Storage-backed: on the mmap backend only touched pages of this
    /// O(entities × width) table become resident.
    pub hist: Option<StoreTable>,
    /// Reference-delta transports (the SVD variants and `--compress`
    /// pipelines): the client's copy of the agreed reference state
    pub ref_state: Option<Table>,
    pub filters: FilterIndex,
    pub valid_set: EvalSet,
    pub test_set: EvalSet,
    pub rng: Rng,
}

/// One round's client-side result, reported over the control plane.
pub struct Report {
    pub loss: f32,
    pub batches: usize,
    pub eval: Option<(RankMetrics, RankMetrics)>,
}

/// Snapshot `trainer`'s rows for `shared` into a full-size table (the
/// initial E^h / SVD reference state).
pub(crate) fn initial_table(
    trainer: &mut dyn LocalTrainer,
    shared: &[u32],
    num_entities: usize,
    width: usize,
) -> Result<Table> {
    let mut t = Table::zeros(num_entities, width);
    let rows = trainer.get_entity_rows(shared)?;
    for (k, &id) in shared.iter().enumerate() {
        t.set_row(id as usize, &rows[k * width..(k + 1) * width]);
    }
    Ok(t)
}

/// [`initial_table`] on a pluggable storage backend: the FedS history
/// table E^h lives wherever the run's `StorageSpec` says.  Only the
/// shared rows are ever written, so an mmap-backed table stays sparse
/// on disk and in RSS.
pub(crate) fn initial_store(
    trainer: &mut dyn LocalTrainer,
    shared: &[u32],
    num_entities: usize,
    width: usize,
    storage: &StorageSpec,
) -> Result<StoreTable> {
    let mut t = StoreTable::zeros_in(storage, num_entities, width)?;
    let rows = trainer.get_entity_rows(shared)?;
    for (k, &id) in shared.iter().enumerate() {
        t.set_row(id as usize, &rows[k * width..(k + 1) * width]);
    }
    Ok(t)
}

/// Drives one client: local training, evaluation, and the client half of
/// the exchange strategy.  Usable from the sequential driver (methods
/// called in order on one thread) or as a free-running loop on its own OS
/// thread (`run`), with identical numerics either way.
pub struct ClientRunner<'d> {
    ctx: ClientCtx,
    exchange: Option<Box<dyn Exchange>>,
    link: Box<dyn Endpoint>,
    params: RoundParams,
    train: &'d [Triple],
    local_ents: &'d [u32],
    batch_size: usize,
    negatives: usize,
    /// SVD+ only: the low-rank projection applied after local training
    svd_plus: Option<SvdCodec>,
}

impl<'d> ClientRunner<'d> {
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        data: &'d FedDataset,
        id: u16,
        params: &RoundParams,
        mut trainer: Box<dyn LocalTrainer>,
        link: Box<dyn Endpoint>,
        batch_size: usize,
        negatives: usize,
    ) -> Result<Self> {
        let c = &data.clients[id as usize];
        let shared = data.shared_entities_of(id);
        let mut rng = Rng::new(params.seed ^ (0xC11E57 + id as u64));
        let filters = c.filter_index();
        let mut valid_set = EvalSet::new(&c.valid, data.num_entities);
        let mut test_set = EvalSet::new(&c.test, data.num_entities);
        valid_set.subsample(params.eval_cap, &mut rng);
        test_set.subsample(params.eval_cap, &mut rng);

        let width = trainer.entity_width();
        let mut hist = None;
        let mut ref_state = None;
        if matches!(params.algo, Algo::FedS { .. }) {
            hist = Some(initial_store(
                trainer.as_mut(),
                &shared,
                data.num_entities,
                width,
                &params.storage,
            )?);
        } else if params.wants_refs() {
            ref_state = Some(initial_table(trainer.as_mut(), &shared, data.num_entities, width)?);
        }
        let exchange = exchange::client_half(params, width, data.num_entities)?;
        let svd_plus = (params.algo == (Algo::FedSvd { constrained: true }))
            .then(|| SvdCodec::for_width(width, params.svd_cols.min(width)));

        Ok(Self {
            ctx: ClientCtx {
                id,
                trainer,
                shared,
                hist,
                ref_state,
                filters,
                valid_set,
                test_set,
                rng,
            },
            exchange,
            link,
            params: params.clone(),
            train: &c.train,
            local_ents: &c.entities,
            batch_size,
            negatives,
            svd_plus,
        })
    }

    pub fn width(&self) -> usize {
        self.ctx.trainer.entity_width()
    }

    /// A copy of the reference state (the server seeds its per-client
    /// mirror from this in sequential mode — SVD and pipeline transports).
    pub fn reference_table(&self) -> Option<Table> {
        self.ctx.ref_state.clone()
    }

    /// Cluster reconnect: swap in a freshly connected metered link.  All
    /// local state (trainer, history, schedule position) is untouched —
    /// only the transport underneath changes.
    pub fn set_link(&mut self, link: Box<dyn Endpoint>) {
        self.link = link;
    }

    /// One round of local work: `local_epochs` of training (plus the SVD+
    /// low-rank projection) and, on eval rounds, both eval splits.
    pub fn local_round(&mut self, round: usize, eval: bool) -> Result<Report> {
        // all epochs' batches gathered so the XLA trainers can fuse the
        // whole phase into scan-stepped executions
        let per_epoch = self.train.len().div_ceil(self.batch_size.max(1));
        let mut batches = Vec::with_capacity(self.params.local_epochs * per_epoch);
        for _ in 0..self.params.local_epochs {
            let mut brng = self.ctx.rng.fork(round as u64);
            batches.extend(BatchIter::new(
                self.train,
                self.local_ents,
                self.batch_size,
                self.negatives,
                &mut brng,
            ));
        }
        let n = batches.len();
        let loss = self.ctx.trainer.train_batches(&batches)?;

        // SVD+ low-rank constraint: project this round's local update
        if let Some(codec) = &self.svd_plus {
            let width = self.ctx.trainer.entity_width();
            let refs = self.ctx.ref_state.as_ref().unwrap();
            let cur = self.ctx.trainer.get_entity_rows(&self.ctx.shared)?;
            let mut projected = Vec::with_capacity(cur.len());
            for (k, &id) in self.ctx.shared.iter().enumerate() {
                let row = &cur[k * width..(k + 1) * width];
                let upd = crate::linalg::sub(row, refs.row(id as usize));
                let proj = codec.project_row(&upd);
                let mut out = refs.row(id as usize).to_vec();
                crate::linalg::axpy(1.0, &proj, &mut out);
                projected.extend_from_slice(&out);
            }
            self.ctx.trainer.set_entity_rows(&self.ctx.shared, &projected)?;
        }

        let eval_metrics = if eval { Some(self.eval_both()?) } else { None };
        Ok(Report { loss, batches: n, eval: eval_metrics })
    }

    fn eval_both(&mut self) -> Result<(RankMetrics, RankMetrics)> {
        let valid = evaluate(self.ctx.trainer.as_mut(), &self.ctx.valid_set, &self.ctx.filters)?;
        let test = evaluate(self.ctx.trainer.as_mut(), &self.ctx.test_set, &self.ctx.filters)?;
        Ok((valid, test))
    }

    /// Build (but do not send) this round's upload: advance the exchange
    /// to `round` and return the encoded frame plus its parameter count,
    /// or `None` when this client exchanges nothing.  `make_upload`
    /// mutates the FedS history table, so the frame is built **once** per
    /// round; a reconnecting cluster client resends this exact cached
    /// frame rather than rebuilding it.
    pub fn upload_frame(&mut self, round: u32) -> Result<Option<(Vec<u8>, u64)>> {
        let Some(ex) = self.exchange.as_mut() else { return Ok(None) };
        ex.begin_round(round);
        if self.ctx.shared.is_empty() {
            return Ok(None);
        }
        let msg = ex.make_upload(round, &mut self.ctx)?;
        let params = msg.params();
        Ok(Some((msg.encode(), params)))
    }

    /// Put an already-built upload frame on the metered link.
    pub fn send_frame(&mut self, frame: Vec<u8>, params: u64) -> Result<()> {
        self.link.send(frame, params)
    }

    /// Block for the server's reply frame on the metered link.
    pub fn recv_frame(&mut self) -> Result<Vec<u8>> {
        self.link.recv()
    }

    /// Fold a download frame into local state through the exchange.
    pub fn apply_download_frame(&mut self, frame: &[u8]) -> Result<()> {
        let Some(ex) = self.exchange.as_mut() else { return Ok(()) };
        ex.apply_download(&mut self.ctx, Download::decode(frame)?)
    }

    /// Advance the exchange schedule through a round this client sits out
    /// (not sampled into the cluster round's cohort).  Idempotent for a
    /// round already begun, so redoing a round after a reconnect is safe.
    pub fn skip_round(&mut self, round: u32) {
        if let Some(ex) = self.exchange.as_mut() {
            ex.begin_round(round);
        }
    }

    /// Client half of the upload phase: frame this round's upload and put
    /// it on the metered link.
    pub fn send_upload(&mut self, round: u32) -> Result<()> {
        match self.upload_frame(round)? {
            Some((frame, params)) => self.send_frame(frame, params),
            None => Ok(()),
        }
    }

    /// Client half of the download phase: block for the server's reply
    /// frame and fold it into local state.
    pub fn recv_download(&mut self) -> Result<()> {
        if self.exchange.is_none() || self.ctx.shared.is_empty() {
            return Ok(());
        }
        let frame = self.link.recv()?;
        self.apply_download_frame(&frame)
    }

    /// Cluster rejoin: advance the client half of the exchange through
    /// rounds this process never ran.  The FedS sync schedule is stateful
    /// (`last_sync`), so a client joining at round `r` must replay
    /// `begin_round` for every earlier round or its sparse/dense parity
    /// diverges from the server's persistent half.
    pub fn fast_forward(&mut self, last_completed_round: u32) {
        if let Some(ex) = self.exchange.as_mut() {
            for r in 1..=last_completed_round {
                ex.begin_round(r);
            }
        }
    }

    /// Cluster rejoin resync: fold a replayed download frame (the server's
    /// last personalized reply to this client id) into local state,
    /// bypassing the exchange's round-parity guards.  A full frame
    /// overwrites the shared rows outright; a sparse frame applies the
    /// Eq. 4 priority-weighted merge against this trainer's current rows.
    pub fn apply_resync(&mut self, frame: &[u8]) -> Result<()> {
        let width = self.ctx.trainer.entity_width();
        match Download::decode(frame)? {
            Download::Full { emb, .. } => {
                anyhow::ensure!(
                    emb.len() == self.ctx.shared.len() * width,
                    "resync frame disagrees with this client's shared-row count"
                );
                self.ctx.trainer.set_entity_rows(&self.ctx.shared, &emb)
            }
            Download::Sparse { sign, emb, prio, .. } => {
                anyhow::ensure!(
                    sign.len() == self.ctx.shared.len(),
                    "resync sign vector disagrees with this client's shared-row count"
                );
                let ids: Vec<u32> = sign
                    .iter()
                    .enumerate()
                    .filter(|(_, &s)| s)
                    .map(|(i, _)| self.ctx.shared[i])
                    .collect();
                anyhow::ensure!(prio.len() == ids.len(), "resync priority vector length mismatch");
                if ids.is_empty() {
                    return Ok(());
                }
                let own = self.ctx.trainer.get_entity_rows(&ids)?;
                let mut merged = vec![0.0f32; ids.len() * width];
                for (j, out) in merged.chunks_exact_mut(width).enumerate() {
                    let denom = 1.0 + prio[j] as f32;
                    let agg = &emb[j * width..(j + 1) * width];
                    let mine = &own[j * width..(j + 1) * width];
                    for ((o, &a), &m) in out.iter_mut().zip(agg).zip(mine) {
                        *o = (a + m) / denom;
                    }
                }
                self.ctx.trainer.set_entity_rows(&ids, &merged)
            }
            Download::Packed { .. } => anyhow::bail!(
                "resync of a packed (compressed-pipeline) download is not supported: \
                 replaying it would advance the reference mirror a second time — \
                 rejoin instead restarts the client from a checkpoint"
            ),
        }
    }

    /// Threaded-mode loop: train → report → (await verdict on eval
    /// rounds) → exchange, every round, mirroring the server driver's
    /// schedule exactly.
    pub fn run(mut self, reports: Sender<Report>, verdicts: Receiver<bool>) -> Result<()> {
        for round in 1..=self.params.max_rounds {
            let eval_round = round % self.params.eval_every == 0;
            let report = self.local_round(round, eval_round)?;
            reports
                .send(report)
                .map_err(|_| anyhow::anyhow!("server hung up mid-round"))?;
            if eval_round {
                let stop = verdicts
                    .recv()
                    .map_err(|_| anyhow::anyhow!("server hung up before the verdict"))?;
                if stop {
                    break;
                }
            }
            self.send_upload(round as u32)?;
            self.recv_download()?;
        }
        Ok(())
    }
}
