//! Per-algorithm `Exchange` strategies.
//!
//! Each strategy encapsulates both halves of one communication pattern:
//! how a client turns local state into an `Upload` and folds a `Download`
//! back in, and how the server folds decoded uploads into its round
//! aggregate and builds each client's personalized reply.  Client and
//! server instantiate *separate* copies (exactly as two processes would);
//! state both sides must agree on — the FedS synchronization schedule, the
//! SVD codec and reference tables — is advanced deterministically on each
//! side from the transmitted frames alone, never shared through memory.

use anyhow::Result;

use crate::comm::wire::{WireReader, WireWriter};
use crate::fed::compression::{Pipeline, SvdCodec};
use crate::fed::protocol::{Download, Upload};
use crate::fed::server::Server;
use crate::fed::sync::SyncSchedule;
use crate::fed::topk::{select_by_change, top_k_count};
use crate::kge::Table;
use crate::store::{StorageSpec, StoreTable};
use crate::util::rng::Rng;

use super::client::ClientCtx;
use super::{Algo, RoundParams};

/// One algorithm family's communication pattern.  The orchestrator drives
/// the client methods on the client side of an `Endpoint` and the server
/// methods on the other; every embedding that crosses between them does so
/// as an encoded frame on the metered link.
pub trait Exchange {
    /// Called once per communication round on each side, before any
    /// message work: advances per-round shared state (e.g. the FedS
    /// synchronization schedule).
    fn begin_round(&mut self, _round: u32) {}

    /// Client: build this round's upload from local state.
    fn make_upload(&mut self, round: u32, ctx: &mut ClientCtx) -> Result<Upload>;

    /// Client: integrate the server's decoded reply into local state.
    fn apply_download(&mut self, ctx: &mut ClientCtx, msg: Download) -> Result<()>;

    /// Server: fold one client's decoded upload into the round aggregate.
    fn server_receive(&mut self, server: &mut Server, client: u16, msg: Upload) -> Result<()>;

    /// Server: build the personalized reply for `client`.
    fn server_download(&mut self, round: u32, server: &mut Server, client: u16)
        -> Result<Download>;

    /// Serialize this half's cross-round state (schedule position, RNG
    /// stream, reference mirrors) into a coordinator checkpoint.  The
    /// default covers stateless strategies.
    fn save_state(&self, _w: &mut WireWriter) {}

    /// Restore state written by [`save_state`] — the strategy must have
    /// been freshly built from the same `RoundParams`.
    ///
    /// [`save_state`]: Exchange::save_state
    fn load_state(&mut self, _r: &mut WireReader<'_>) -> Result<()> {
        Ok(())
    }
}

/// The client-side strategy instance for `params` (`None`: no
/// communication).  `num_entities` sizes error-feedback residual tables
/// when a `--compress` pipeline is active.
pub fn client_half(
    params: &RoundParams,
    width: usize,
    num_entities: usize,
) -> Result<Option<Box<dyn Exchange>>> {
    build_half(params, width, num_entities, None)
}

/// The server-side strategy instance.  `refs` carries the per-client
/// initial reference tables the SVD and pipeline transports need (empty
/// for all other algorithms).
pub fn server_half(
    params: &RoundParams,
    width: usize,
    num_entities: usize,
    refs: Vec<Table>,
) -> Result<Option<Box<dyn Exchange>>> {
    build_half(params, width, num_entities, Some(refs))
}

fn build_half(
    params: &RoundParams,
    width: usize,
    num_entities: usize,
    server_refs: Option<Vec<Table>>,
) -> Result<Option<Box<dyn Exchange>>> {
    Ok(match params.algo {
        Algo::Single => None,
        Algo::FedEP | Algo::FedEPL | Algo::FedKd => {
            if params.compression.is_empty() {
                Some(Box::new(DenseExchange))
            } else {
                // the dense family is the pipeline's substrate: the
                // stack compresses its delta stream
                Some(Box::new(PipelineExchange::build(
                    params,
                    width,
                    num_entities,
                    server_refs,
                )?))
            }
        }
        Algo::FedS { sync } => {
            let schedule = SyncSchedule::new(sync.then_some(params.sync_interval));
            let rng = server_refs.is_some().then(|| Rng::new(params.seed ^ 0x5E4E4));
            Some(Box::new(FedSExchange {
                sparsity: params.sparsity,
                schedule,
                sync_now: false,
                last_round: None,
                rng,
            }))
        }
        Algo::FedSvd { .. } => Some(Box::new(SvdExchange {
            codec: SvdCodec::for_width(width, params.svd_cols.min(width)),
            width,
            refs: server_refs.unwrap_or_default(),
        })),
    })
}

/// Dense FedE-style exchange (FedEP, FedEPL, FedE-KD): every shared-entity
/// row upstream, the FedE average back down.
pub struct DenseExchange;

impl Exchange for DenseExchange {
    fn make_upload(&mut self, round: u32, ctx: &mut ClientCtx) -> Result<Upload> {
        let emb = ctx.trainer.get_entity_rows(&ctx.shared)?;
        Ok(Upload::Full { round, client: ctx.id, emb })
    }

    fn apply_download(&mut self, ctx: &mut ClientCtx, msg: Download) -> Result<()> {
        let Download::Full { emb, .. } = msg else {
            anyhow::bail!("dense exchange expects a full download");
        };
        debug_assert_eq!(emb.len(), ctx.shared.len() * ctx.trainer.entity_width());
        ctx.trainer.set_entity_rows(&ctx.shared, &emb)
    }

    fn server_receive(&mut self, server: &mut Server, client: u16, msg: Upload) -> Result<()> {
        let Upload::Full { emb, .. } = msg else {
            anyhow::bail!("dense exchange expects a full upload");
        };
        server.receive_all_shared(client, &emb);
        Ok(())
    }

    fn server_download(
        &mut self,
        round: u32,
        server: &mut Server,
        client: u16,
    ) -> Result<Download> {
        Ok(Download::Full { round, emb: server.fede_download(client) })
    }
}

/// FedS (§III): Entity-Wise Top-K sparsification both ways with the
/// Intermittent Synchronization Mechanism.  Sync rounds are dense
/// exchanges that reset the client's history table E^h; sparse rounds
/// send Top-K-by-change upstream (Eq. 1/2) and personalized-aggregation
/// priority Top-K downstream (Eq. 3, merged by Eq. 4).
pub struct FedSExchange {
    sparsity: f64,
    schedule: SyncSchedule,
    sync_now: bool,
    /// the round `begin_round` last advanced to, making it idempotent per
    /// round: a reconnecting client re-entering the same round must keep
    /// the schedule's verdict instead of stepping it a second time (which
    /// would flip a sync round back to sparse)
    last_round: Option<u32>,
    /// server side only: the §III-D priority tie-break stream
    rng: Option<Rng>,
}

impl Exchange for FedSExchange {
    fn begin_round(&mut self, round: u32) {
        if self.last_round == Some(round) {
            return;
        }
        self.last_round = Some(round);
        self.sync_now = self.schedule.step(round as usize);
    }

    fn make_upload(&mut self, round: u32, ctx: &mut ClientCtx) -> Result<Upload> {
        let width = ctx.trainer.entity_width();
        if self.sync_now {
            let rows = ctx.trainer.get_entity_rows(&ctx.shared)?;
            // E^h := what was sent (all shared entities on sync rounds)
            let hist = ctx.hist.as_mut().unwrap();
            for (k, &id) in ctx.shared.iter().enumerate() {
                hist.set_row(id as usize, &rows[k * width..(k + 1) * width]);
            }
            return Ok(Upload::Full { round, client: ctx.id, emb: rows });
        }
        let hist = ctx.hist.as_ref().unwrap();
        let scores = ctx.trainer.change_scores(&ctx.shared, hist)?;
        let k = top_k_count(ctx.shared.len(), self.sparsity);
        let sel = select_by_change(&scores, k);
        let mut sign = vec![false; ctx.shared.len()];
        for &i in &sel {
            sign[i] = true;
        }
        // rows must travel in ascending shared-index order — exactly the
        // order `server_receive` reconstructs from the sign vector.  (The
        // score-ranked `sel` order previously leaked into the frame here,
        // misaligning rows with entities whenever a higher change score
        // sat at a higher shared index.)
        let ids: Vec<u32> = sign
            .iter()
            .enumerate()
            .filter(|(_, &s)| s)
            .map(|(i, _)| ctx.shared[i])
            .collect();
        let rows = ctx.trainer.get_entity_rows(&ids)?;
        let hist = ctx.hist.as_mut().unwrap();
        for (k2, &id) in ids.iter().enumerate() {
            hist.set_row(id as usize, &rows[k2 * width..(k2 + 1) * width]);
        }
        Ok(Upload::Sparse { round, client: ctx.id, sign, emb: rows })
    }

    fn apply_download(&mut self, ctx: &mut ClientCtx, msg: Download) -> Result<()> {
        let width = ctx.trainer.entity_width();
        match msg {
            Download::Full { emb, .. } => {
                anyhow::ensure!(self.sync_now, "dense download outside a sync round");
                ctx.trainer.set_entity_rows(&ctx.shared, &emb)
            }
            Download::Sparse { sign, emb, prio, .. } => {
                anyhow::ensure!(!self.sync_now, "sparse download on a sync round");
                let ids: Vec<u32> = sign
                    .iter()
                    .enumerate()
                    .filter(|(_, &s)| s)
                    .map(|(i, _)| ctx.shared[i])
                    .collect();
                if ids.is_empty() {
                    return Ok(());
                }
                // Eq. 4: E^{t+1} = (A + E^t) / (1 + P), merged row-slice-wise
                let own = ctx.trainer.get_entity_rows(&ids)?;
                let mut merged = vec![0.0f32; ids.len() * width];
                for (j, out) in merged.chunks_exact_mut(width).enumerate() {
                    let denom = 1.0 + prio[j] as f32;
                    let agg = &emb[j * width..(j + 1) * width];
                    let mine = &own[j * width..(j + 1) * width];
                    for ((o, &a), &m) in out.iter_mut().zip(agg).zip(mine) {
                        *o = (a + m) / denom;
                    }
                }
                ctx.trainer.set_entity_rows(&ids, &merged)
            }
            Download::Packed { .. } => {
                anyhow::bail!("FedS exchange cannot apply a packed download")
            }
        }
    }

    fn server_receive(&mut self, server: &mut Server, client: u16, msg: Upload) -> Result<()> {
        match msg {
            Upload::Full { emb, .. } => {
                anyhow::ensure!(self.sync_now, "dense upload outside a sync round");
                server.receive_all_shared(client, &emb);
            }
            Upload::Sparse { sign, emb, .. } => {
                anyhow::ensure!(!self.sync_now, "sparse upload on a sync round");
                let ids: Vec<u32> = {
                    let shared = &server.shared[client as usize];
                    sign.iter()
                        .enumerate()
                        .filter(|(_, &s)| s)
                        .map(|(i, _)| shared[i])
                        .collect()
                };
                server.receive(client, &ids, &emb);
            }
            Upload::Packed { .. } => {
                anyhow::bail!("FedS exchange cannot fold a packed upload")
            }
        }
        Ok(())
    }

    fn server_download(
        &mut self,
        round: u32,
        server: &mut Server,
        client: u16,
    ) -> Result<Download> {
        if self.sync_now {
            return Ok(Download::Full { round, emb: server.fede_download(client) });
        }
        let k = top_k_count(server.shared[client as usize].len(), self.sparsity);
        let rng = self.rng.as_mut().expect("server-side FedS exchange carries the priority rng");
        let (sign, emb, prio) = server.feds_download(client, k, rng);
        Ok(Download::Sparse { round, sign, emb, prio })
    }

    fn save_state(&self, w: &mut WireWriter) {
        w.u64(self.schedule.last_sync() as u64);
        w.u8(self.sync_now as u8);
        match self.last_round {
            Some(r) => w.u8(1).u32(r),
            None => w.u8(0),
        };
        match &self.rng {
            Some(rng) => {
                w.u8(1);
                for s in rng.state() {
                    w.u64(s);
                }
            }
            None => {
                w.u8(0);
            }
        }
    }

    fn load_state(&mut self, r: &mut WireReader<'_>) -> Result<()> {
        let last_sync = r.u64()? as usize;
        self.schedule = SyncSchedule::restore(self.schedule.interval, last_sync);
        self.sync_now = r.u8()? != 0;
        self.last_round = match r.u8()? {
            0 => None,
            1 => Some(r.u32()?),
            m => anyhow::bail!("bad option marker {m} in FedS exchange state"),
        };
        match r.u8()? {
            0 => self.rng = None,
            1 => {
                let mut s = [0u64; 4];
                for x in &mut s {
                    *x = r.u64()?;
                }
                self.rng = Some(Rng::from_state(s));
            }
            m => anyhow::bail!("bad rng marker {m} in FedS exchange state"),
        }
        Ok(())
    }
}

/// FedE-SVD / FedE-SVD+ (Appendix VI-B): rank-k factorized *updates*
/// against a client/server-agreed reference state, in both directions.
/// Each side owns its copy of the reference tables and advances it from
/// the transmitted (lossy) factors alone, so the copies stay bit-identical
/// without any extra synchronization traffic.
pub struct SvdExchange {
    codec: SvdCodec,
    width: usize,
    /// server side: per-client reference mirrors (client side: empty —
    /// the client's reference lives in `ClientCtx::ref_state`)
    refs: Vec<Table>,
}

impl Exchange for SvdExchange {
    fn make_upload(&mut self, round: u32, ctx: &mut ClientCtx) -> Result<Upload> {
        let width = self.width;
        let refs = ctx.ref_state.as_ref().unwrap();
        let cur = ctx.trainer.get_entity_rows(&ctx.shared)?;
        let mut updates = Vec::with_capacity(cur.len());
        for (k, &id) in ctx.shared.iter().enumerate() {
            updates.extend_from_slice(&crate::linalg::sub(
                &cur[k * width..(k + 1) * width],
                refs.row(id as usize),
            ));
        }
        let packed = self.codec.encode_rows(&updates, width);
        Ok(Upload::Full { round, client: ctx.id, emb: packed })
    }

    fn apply_download(&mut self, ctx: &mut ClientCtx, msg: Download) -> Result<()> {
        let Download::Full { emb: packed, .. } = msg else {
            anyhow::bail!("SVD exchange expects a full (packed) download");
        };
        let width = self.width;
        let approx = self.codec.decode_rows(&packed, width, ctx.shared.len());
        let refs = ctx.ref_state.as_mut().unwrap();
        let mut new_rows = Vec::with_capacity(approx.len());
        for (k, &id) in ctx.shared.iter().enumerate() {
            let mut row = refs.row(id as usize).to_vec();
            crate::linalg::axpy(1.0, &approx[k * width..(k + 1) * width], &mut row);
            refs.set_row(id as usize, &row);
            new_rows.extend_from_slice(&row);
        }
        ctx.trainer.set_entity_rows(&ctx.shared, &new_rows)
    }

    fn server_receive(&mut self, server: &mut Server, client: u16, msg: Upload) -> Result<()> {
        let Upload::Full { emb: packed, .. } = msg else {
            anyhow::bail!("SVD exchange expects a full (packed) upload");
        };
        let width = self.width;
        let refs = &self.refs[client as usize];
        let shared_len = server.shared[client as usize].len();
        // reconstruct the client's (approximate) state against the mirror
        let approx = self.codec.decode_rows(&packed, width, shared_len);
        let mut state = Vec::with_capacity(approx.len());
        for (k, &id) in server.shared[client as usize].iter().enumerate() {
            let mut row = refs.row(id as usize).to_vec();
            crate::linalg::axpy(1.0, &approx[k * width..(k + 1) * width], &mut row);
            state.extend_from_slice(&row);
        }
        server.receive_all_shared(client, &state);
        Ok(())
    }

    fn server_download(
        &mut self,
        round: u32,
        server: &mut Server,
        client: u16,
    ) -> Result<Download> {
        let width = self.width;
        let agg = server.fede_download(client);
        let refs = &mut self.refs[client as usize];
        let shared = &server.shared[client as usize];
        let mut deltas = Vec::with_capacity(agg.len());
        for (k, &id) in shared.iter().enumerate() {
            deltas.extend_from_slice(&crate::linalg::sub(
                &agg[k * width..(k + 1) * width],
                refs.row(id as usize),
            ));
        }
        let packed = self.codec.encode_rows(&deltas, width);
        // advance the mirror by the same lossy update the client will
        // decode, keeping both reference copies bit-identical
        let approx = self.codec.decode_rows(&packed, width, shared.len());
        for (k, &id) in shared.iter().enumerate() {
            let mut row = refs.row(id as usize).to_vec();
            crate::linalg::axpy(1.0, &approx[k * width..(k + 1) * width], &mut row);
            refs.set_row(id as usize, &row);
        }
        Ok(Download::Full { round, emb: packed })
    }

    fn save_state(&self, w: &mut WireWriter) {
        w.u32(self.refs.len() as u32);
        for t in &self.refs {
            w.u32(t.rows as u32).u32(t.width as u32).f32s(&t.data);
        }
    }

    fn load_state(&mut self, r: &mut WireReader<'_>) -> Result<()> {
        let n = r.u32()? as usize;
        let mut refs = Vec::with_capacity(n);
        for _ in 0..n {
            let rows = r.u32()? as usize;
            let width = r.u32()? as usize;
            let data = r.f32s()?;
            anyhow::ensure!(
                data.len() == rows * width,
                "SVD reference table shape mismatch in checkpoint"
            );
            refs.push(Table { rows, width, data });
        }
        self.refs = refs;
        Ok(())
    }
}

/// A `--compress` stage stack over the dense family's exchange
/// (FedEP/FedEPL/FedE-KD): both directions transmit *deltas against
/// reference mirrors* — the generalization of [`SvdExchange`]'s
/// reference scheme to arbitrary [`Pipeline`] stacks.  The client's
/// reference lives in `ClientCtx::ref_state` and advances only on
/// decoded downloads; the server keeps one mirror per client, advanced
/// by lossy-decoding its own encoded downloads, so both copies stay
/// bit-identical without extra traffic.  Upload receives reconstruct
/// client state as `ref + decoded delta` *without* advancing the mirror.
/// Error-feedback residuals (stage `:ef`) live on `store::EmbedStore`
/// tables and ride through `save_state`, keeping checkpoint/restore
/// bit-identical.
pub struct PipelineExchange {
    pipeline: Pipeline,
    width: usize,
    storage: StorageSpec,
    /// server side: per-client reference mirrors (client side: empty —
    /// the client's reference lives in `ClientCtx::ref_state`)
    refs: Vec<Table>,
    /// this half's *encoder* residuals: one set on the client (upstream),
    /// one set per client on the server (downstream personalized
    /// encoders); each set has one optional table per pipeline stage
    res: Vec<Vec<Option<StoreTable>>>,
}

impl PipelineExchange {
    fn build(
        params: &RoundParams,
        width: usize,
        num_entities: usize,
        server_refs: Option<Vec<Table>>,
    ) -> Result<Self> {
        let pipeline = Pipeline::new(&params.compression, width)?;
        let storage = params.storage.clone();
        let (refs, res) = match server_refs {
            Some(refs) => {
                let res = (0..refs.len())
                    .map(|_| pipeline.make_residuals(&storage, num_entities))
                    .collect::<Result<Vec<_>>>()?;
                (refs, res)
            }
            None => {
                let res = vec![pipeline.make_residuals(&storage, num_entities)?];
                (Vec::new(), res)
            }
        };
        Ok(Self { pipeline, width, storage, refs, res })
    }
}

impl Exchange for PipelineExchange {
    fn make_upload(&mut self, round: u32, ctx: &mut ClientCtx) -> Result<Upload> {
        let width = self.width;
        let refs = ctx.ref_state.as_ref().unwrap();
        let cur = ctx.trainer.get_entity_rows(&ctx.shared)?;
        let mut deltas = Vec::with_capacity(cur.len());
        for (k, &id) in ctx.shared.iter().enumerate() {
            deltas.extend_from_slice(&crate::linalg::sub(
                &cur[k * width..(k + 1) * width],
                refs.row(id as usize),
            ));
        }
        let block = self.pipeline.encode(&ctx.shared, &deltas, None, &mut self.res[0]);
        Ok(Upload::Packed { round, client: ctx.id, block })
    }

    fn apply_download(&mut self, ctx: &mut ClientCtx, msg: Download) -> Result<()> {
        let Download::Packed { block, .. } = msg else {
            anyhow::bail!("pipeline exchange expects a packed download");
        };
        anyhow::ensure!(
            block.n_in as usize == ctx.shared.len(),
            "packed download covers {} rows, client has {} shared entities",
            block.n_in,
            ctx.shared.len()
        );
        let width = self.width;
        let (idx, rows) = self.pipeline.decode(&block)?;
        let refs = ctx.ref_state.as_mut().unwrap();
        let ids: Vec<u32> = idx.iter().map(|&i| ctx.shared[i]).collect();
        let mut new_rows = Vec::with_capacity(ids.len() * width);
        for (j, &id) in ids.iter().enumerate() {
            let mut row = refs.row(id as usize).to_vec();
            crate::linalg::axpy(1.0, &rows[j * width..(j + 1) * width], &mut row);
            refs.set_row(id as usize, &row);
            new_rows.extend_from_slice(&row);
        }
        if ids.is_empty() {
            return Ok(());
        }
        ctx.trainer.set_entity_rows(&ids, &new_rows)
    }

    fn server_receive(&mut self, server: &mut Server, client: u16, msg: Upload) -> Result<()> {
        let Upload::Packed { block, .. } = msg else {
            anyhow::bail!("pipeline exchange expects a packed upload");
        };
        let shared_len = server.shared[client as usize].len();
        anyhow::ensure!(
            block.n_in as usize == shared_len,
            "packed upload covers {} rows, client {client} shares {shared_len} entities",
            block.n_in
        );
        let width = self.width;
        let (idx, rows) = self.pipeline.decode(&block)?;
        // reconstruct the client's (approximate) state for the rows that
        // traveled — against the mirror, which does NOT advance here
        let refs = &self.refs[client as usize];
        let ids: Vec<u32> = {
            let shared = &server.shared[client as usize];
            idx.iter().map(|&i| shared[i]).collect()
        };
        let mut state = Vec::with_capacity(ids.len() * width);
        for (j, &id) in ids.iter().enumerate() {
            let mut row = refs.row(id as usize).to_vec();
            crate::linalg::axpy(1.0, &rows[j * width..(j + 1) * width], &mut row);
            state.extend_from_slice(&row);
        }
        server.receive(client, &ids, &state);
        Ok(())
    }

    fn server_download(
        &mut self,
        round: u32,
        server: &mut Server,
        client: u16,
    ) -> Result<Download> {
        let width = self.width;
        let agg = server.fede_download(client);
        // rows nobody uploaded this round aggregate to 0.0, not to a real
        // state — mask them out before the Top-K stage ever sees them
        let present = server.uploaded_mask(client);
        let shared = &server.shared[client as usize];
        let refs = &mut self.refs[client as usize];
        let mut deltas = Vec::with_capacity(agg.len());
        for (k, &id) in shared.iter().enumerate() {
            deltas.extend_from_slice(&crate::linalg::sub(
                &agg[k * width..(k + 1) * width],
                refs.row(id as usize),
            ));
        }
        let block =
            self.pipeline.encode(shared, &deltas, Some(&present), &mut self.res[client as usize]);
        // advance the mirror by the same lossy update the client will
        // decode, keeping both reference copies bit-identical
        let (idx, rows) = self.pipeline.decode(&block)?;
        for (j, &i) in idx.iter().enumerate() {
            let id = shared[i] as usize;
            let mut row = refs.row(id).to_vec();
            crate::linalg::axpy(1.0, &rows[j * width..(j + 1) * width], &mut row);
            refs.set_row(id, &row);
        }
        Ok(Download::Packed { round, block })
    }

    fn save_state(&self, w: &mut WireWriter) {
        w.u32(self.refs.len() as u32);
        for t in &self.refs {
            w.u32(t.rows as u32).u32(t.width as u32).f32s(&t.data);
        }
        w.u32(self.res.len() as u32);
        for set in &self.res {
            w.u32(set.len() as u32);
            for entry in set {
                match entry {
                    Some(t) => {
                        w.u8(1).u32(t.rows as u32).u32(t.width as u32).f32s(t.as_slice());
                    }
                    None => {
                        w.u8(0);
                    }
                }
            }
        }
    }

    fn load_state(&mut self, r: &mut WireReader<'_>) -> Result<()> {
        let n = r.u32()? as usize;
        let mut refs = Vec::with_capacity(n);
        for _ in 0..n {
            let rows = r.u32()? as usize;
            let width = r.u32()? as usize;
            let data = r.f32s()?;
            anyhow::ensure!(
                data.len() == rows * width,
                "pipeline reference table shape mismatch in checkpoint"
            );
            refs.push(Table { rows, width, data });
        }
        self.refs = refs;
        let n_sets = r.u32()? as usize;
        let mut res = Vec::with_capacity(n_sets);
        for _ in 0..n_sets {
            let n_entries = r.u32()? as usize;
            let mut set = Vec::with_capacity(n_entries);
            for _ in 0..n_entries {
                match r.u8()? {
                    0 => set.push(None),
                    1 => {
                        let rows = r.u32()? as usize;
                        let width = r.u32()? as usize;
                        let data = r.f32s()?;
                        anyhow::ensure!(
                            data.len() == rows * width,
                            "pipeline residual table shape mismatch in checkpoint"
                        );
                        let mut t = StoreTable::zeros_in(&self.storage, rows, width)?;
                        t.as_mut_slice().copy_from_slice(&data);
                        set.push(Some(t));
                    }
                    m => anyhow::bail!("bad residual marker {m} in pipeline exchange state"),
                }
            }
            res.push(set);
        }
        self.res = res;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Triple;
    use crate::data::dataset::{EvalSet, FilterIndex};
    use crate::kge::Hyper;
    use crate::store::StoreTable;
    use crate::trainer::{LocalTrainer, NativeTrainer};

    fn empty_ctx_parts(e: usize) -> (FilterIndex, EvalSet, EvalSet) {
        let none: Vec<Triple> = Vec::new();
        (FilterIndex::build(none.iter()), EvalSet::new(&none, e), EvalSet::new(&none, e))
    }

    /// Regression: FedS sparse-upload rows must travel in ascending
    /// shared-index order — the order `server_receive` reconstructs from
    /// the sign vector — not in change-score rank order.  Change scores
    /// are planted strictly increasing over the shared list, so a
    /// rank-ordered frame would arrive exactly reversed.
    #[test]
    fn sparse_upload_rows_align_with_server_reconstruction() {
        let e = 6usize;
        let mut rng = Rng::new(3);
        let hyper = Hyper { dim: 2, ..Default::default() }; // TransE → width 2
        let mut trainer = NativeTrainer::new(crate::kge::Method::TransE, hyper, e, 2, 4, &mut rng);
        let shared: Vec<u32> = vec![1, 3, 5];
        let width = trainer.entity_width();
        trainer.set_entity_rows(&shared, &[1.0, 0.0, 0.0, 2.0, 3.0, 3.0]).unwrap();
        // history: cos(cur, hist) = 1, 0.707, 0 → change scores 0 < 0.3 < 1
        let mut hist = StoreTable::zeros(e, width);
        hist.set_row(1, &[1.0, 0.0]);
        hist.set_row(3, &[2.0, 2.0]);
        hist.set_row(5, &[-3.0, 3.0]);

        let schedule = SyncSchedule::new(None);
        let mut ex =
            FedSExchange { sparsity: 0.7, schedule, sync_now: false, last_round: None, rng: None };
        ex.begin_round(2);
        let (filters, valid_set, test_set) = empty_ctx_parts(e);
        let mut ctx = ClientCtx {
            id: 0,
            trainer: Box::new(trainer),
            shared: shared.clone(),
            hist: Some(hist),
            ref_state: None,
            filters,
            valid_set,
            test_set,
            rng: Rng::new(9),
        };
        let up = ex.make_upload(2, &mut ctx).unwrap();
        let Upload::Sparse { sign, emb, .. } = up.clone() else {
            panic!("expected a sparse upload");
        };
        // K = ⌊3·0.7⌋ = 2 → the two largest changes: entities 3 and 5
        assert_eq!(sign, vec![false, true, true]);
        let r3 = ctx.trainer.get_entity_rows(&[3]).unwrap();
        let r5 = ctx.trainer.get_entity_rows(&[5]).unwrap();
        assert_eq!(&emb[..width], &r3[..], "first row must be entity 3");
        assert_eq!(&emb[width..], &r5[..], "second row must be entity 5");

        // fold through a server strategy: rows land on the right entities
        let mut server = Server::new(e, width, vec![shared.clone()]);
        let mut sx = FedSExchange {
            sparsity: 0.7,
            schedule: SyncSchedule::new(None),
            sync_now: false,
            last_round: None,
            rng: Some(Rng::new(1)),
        };
        sx.begin_round(2);
        server.begin_round();
        sx.server_receive(&mut server, 0, up).unwrap();
        let down = server.fede_download(0);
        assert_eq!(&down[..width], &[0.0, 0.0], "entity 1 was not uploaded");
        assert_eq!(&down[width..2 * width], &r3[..]);
        assert_eq!(&down[2 * width..], &r5[..]);
    }

    /// One full compressed round: the client's reference table and the
    /// server's per-client mirror must end the round bit-identical, with
    /// the trainer's shared rows equal to the (lossily) agreed state.
    #[test]
    fn pipeline_exchange_keeps_reference_mirrors_aligned() {
        use crate::fed::compression::PipelineSpec;

        let e = 6usize;
        let mut rng = Rng::new(4);
        let hyper = Hyper { dim: 2, ..Default::default() };
        let mut trainer = NativeTrainer::new(crate::kge::Method::TransE, hyper, e, 2, 4, &mut rng);
        let shared: Vec<u32> = vec![1, 3, 5];
        let width = trainer.entity_width();
        trainer.set_entity_rows(&shared, &[1.0, 0.0, 0.0, 2.0, 3.0, 3.0]).unwrap();

        let spec = PipelineSpec::parse("topk@0.7,int8:ef").unwrap();
        let storage = StorageSpec::Ram;
        let mk = || Pipeline::new(&spec, width).unwrap();
        let zeros = || Table { rows: e, width, data: vec![0.0; e * width] };
        let mut cx = PipelineExchange {
            pipeline: mk(),
            width,
            storage: storage.clone(),
            refs: Vec::new(),
            res: vec![mk().make_residuals(&storage, e).unwrap()],
        };
        let mut sx = PipelineExchange {
            pipeline: mk(),
            width,
            storage: storage.clone(),
            refs: vec![zeros()],
            res: vec![mk().make_residuals(&storage, e).unwrap()],
        };

        let (filters, valid_set, test_set) = empty_ctx_parts(e);
        let mut ctx = ClientCtx {
            id: 0,
            trainer: Box::new(trainer),
            shared: shared.clone(),
            hist: None,
            ref_state: Some(zeros()),
            filters,
            valid_set,
            test_set,
            rng: Rng::new(9),
        };
        let mut server = Server::new(e, width, vec![shared.clone()]);

        for round in 0..3u32 {
            let up = cx.make_upload(round, &mut ctx).unwrap();
            if let Upload::Packed { block, .. } = &up {
                // K = ⌊3·0.7⌋ = 2 rows travel, int8-packed
                assert_eq!(block.n_rows(), 2);
                assert_eq!(block.body.len(), 2 * (4 + width));
            } else {
                panic!("expected a packed upload");
            }
            server.begin_round();
            sx.server_receive(&mut server, 0, up).unwrap();
            let down = sx.server_download(round, &mut server, 0).unwrap();
            cx.apply_download(&mut ctx, down).unwrap();
            let cref = ctx.ref_state.as_ref().unwrap();
            assert_eq!(cref.data, sx.refs[0].data, "round {round}: mirrors diverged");
        }

        // checkpoint round-trip: refs + residuals survive bit-exactly
        let mut w = WireWriter::new();
        sx.save_state(&mut w);
        let buf = w.finish();
        let mut fresh = PipelineExchange {
            pipeline: mk(),
            width,
            storage: storage.clone(),
            refs: vec![zeros()],
            res: vec![mk().make_residuals(&storage, e).unwrap()],
        };
        fresh.load_state(&mut WireReader::new(&buf)).unwrap();
        assert_eq!(fresh.refs[0].data, sx.refs[0].data);
        let (a, b) = (&fresh.res[0], &sx.res[0]);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            match (x, y) {
                (None, None) => {}
                (Some(t), Some(u)) => assert_eq!(t.as_slice(), u.as_slice()),
                _ => panic!("residual presence diverged after restore"),
            }
        }
    }
}
