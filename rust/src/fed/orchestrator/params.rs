//! The orchestrator's resolved per-run parameters.
//!
//! [`RoundParams`] is derived **once** per run from a
//! [`crate::spec::ExperimentSpec`] by [`RoundParams::from_spec`] (what
//! [`crate::spec::Session::build`] calls) and is the only configuration
//! type the orchestrator internals (`client`, `exchange`, the drivers)
//! consume.  Resolution happens at derivation, not at use sites: the
//! execution mode is already downgraded when the backend cannot thread,
//! the transport and server shard count are concrete values, and every
//! knob is the one the run will actually honor.

use crate::comm::transport::TransportSpec;
use crate::fed::compression::PipelineSpec;
use crate::kge::Method;
use crate::spec::{AlgoSpec, ExperimentSpec, ParticipationSpec};
use crate::store::StorageSpec;

use super::{Algo, Backend, ExecMode};

/// Knobs a run carries whether or not the selected algorithm reads them
/// (FedEPL's volume-matched dimension derives from the paper-default
/// sparsity and sync interval; the SVD column count only matters to the
/// SVD transport).
const DEFAULT_SPARSITY: f64 = 0.4;
const DEFAULT_SYNC_INTERVAL: usize = 4;
const DEFAULT_SVD_COLS: usize = 8;

/// Resolved knobs of one federated run (see module docs).
#[derive(Clone, Debug)]
pub struct RoundParams {
    pub algo: Algo,
    pub method: Method,
    /// hard cap on communication rounds
    pub max_rounds: usize,
    /// local epochs per round (paper default 3)
    pub local_epochs: usize,
    /// evaluate every N rounds (paper: every 5)
    pub eval_every: usize,
    /// early-stop patience in evaluations (paper: 3)
    pub patience: usize,
    /// FedS sparsity ratio p (paper: 0.4, 0.7 for one config)
    pub sparsity: f64,
    /// FedS synchronization interval s (paper: 4)
    pub sync_interval: usize,
    /// cap on eval queries per client per split (0 = all)
    pub eval_cap: usize,
    pub seed: u64,
    /// columns of the SVD reshape (paper: 8)
    pub svd_cols: usize,
    /// client execution mode, already resolved against the backend
    /// (threaded + PJRT downgrades to sequential at derivation)
    pub exec: ExecMode,
    /// which transport carries the frames (accounting is bit-identical
    /// across variants)
    pub transport: TransportSpec,
    /// server aggregation shard count (≥ 1; results are bit-identical
    /// for any value)
    pub shards: usize,
    /// per-round client sampling policy — enforced by the cluster
    /// coordinator only; the in-process engine always runs every client
    pub participation: ParticipationSpec,
    /// backend for every O(entities × width) table (server shard
    /// accumulators, entity embeddings, Adam moments, FedS history) —
    /// results are bit-identical across backends
    pub storage: StorageSpec,
    /// `--compress` stage stack over the dense family's delta stream
    /// (empty: plain dense frames, byte-identical to runs without the
    /// knob)
    pub compression: PipelineSpec,
}

impl RoundParams {
    /// The one derivation point: resolve a spec against `backend`.
    ///
    /// Scoped algorithm knobs land in their flat slots; knobs a variant
    /// does not own take the paper defaults (so e.g. FedEPL's
    /// volume-matched dimension derives from p=0.4, s=4 for any spec).
    /// `shards == 0` resolves to [`auto_shards`]; threaded execution on
    /// the XLA backend downgrades to sequential here, with a warning.
    pub fn from_spec(spec: &ExperimentSpec, backend: &Backend) -> Self {
        let (sparsity, sync_interval, svd_cols) = match &spec.algo {
            AlgoSpec::FedS { sparsity, sync_interval, .. } => {
                (*sparsity, *sync_interval, DEFAULT_SVD_COLS)
            }
            AlgoSpec::Svd { cols, .. } => (DEFAULT_SPARSITY, DEFAULT_SYNC_INTERVAL, *cols),
            _ => (DEFAULT_SPARSITY, DEFAULT_SYNC_INTERVAL, DEFAULT_SVD_COLS),
        };
        let exec = match (spec.exec, backend) {
            (ExecMode::Threaded, Backend::Xla(_)) => {
                crate::warn_!(
                    "threaded execution needs Send trainers and the PJRT client is not Send; \
                     falling back to sequential"
                );
                ExecMode::Sequential
            }
            (e, _) => e,
        };
        Self {
            algo: spec.algo.algo(),
            method: spec.method,
            max_rounds: spec.budget.max_rounds,
            local_epochs: spec.budget.local_epochs,
            eval_every: spec.budget.eval_every,
            patience: spec.budget.patience,
            sparsity,
            sync_interval,
            eval_cap: spec.budget.eval_cap,
            seed: spec.seed,
            svd_cols,
            exec,
            transport: spec.transport,
            shards: if spec.shards > 0 { spec.shards } else { auto_shards() },
            participation: spec.participation,
            storage: spec.storage.clone(),
            compression: spec.compression.clone(),
        }
    }

    /// Whether clients (and the sequential/threaded drivers) must build
    /// initial reference tables: the SVD transport always transmits
    /// deltas against references, and the dense family does too once a
    /// `--compress` pipeline is active.
    pub fn wants_refs(&self) -> bool {
        match self.algo {
            Algo::FedSvd { .. } => true,
            Algo::FedEP | Algo::FedEPL | Algo::FedKd => !self.compression.is_empty(),
            _ => false,
        }
    }
}

/// The default server shard count: one per core, capped — aggregation is
/// memory-bound well before it scales past a handful of threads.
pub fn auto_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::{BackendSpec, BudgetSpec, DataSpec};

    fn spec() -> ExperimentSpec {
        ExperimentSpec {
            name: String::new(),
            method: Method::TransE,
            algo: AlgoSpec::FedS { sparsity: 0.7, sync_interval: 2, sync: false },
            data: DataSpec {
                entities: 192,
                relations: 12,
                triples: 2400,
                clusters: 4,
                clients: 3,
                seed: 7,
            },
            backend: BackendSpec::native_default(),
            budget: BudgetSpec { max_rounds: 9, ..Default::default() },
            seed: 7,
            exec: ExecMode::Threaded,
            transport: TransportSpec::Mpsc,
            shards: 0,
            participation: Default::default(),
            storage: Default::default(),
            compression: Default::default(),
        }
    }

    #[test]
    fn from_spec_copies_every_knob() {
        let spec = spec();
        let backend = crate::exp::native_backend();
        let p = RoundParams::from_spec(&spec, &backend);
        assert_eq!(p.algo, Algo::FedS { sync: false });
        assert_eq!(p.sparsity, 0.7);
        assert_eq!(p.sync_interval, 2);
        assert_eq!(p.max_rounds, 9);
        assert_eq!(p.svd_cols, DEFAULT_SVD_COLS, "unowned knobs take the paper defaults");
        assert_eq!(p.exec, ExecMode::Threaded, "native backend keeps threaded exec");
        assert_eq!(p.transport, TransportSpec::Mpsc);
        assert!(p.shards >= 1, "shards 0 resolves to auto");
    }

    #[test]
    fn from_spec_scopes_svd_and_defaults() {
        let mut spec = spec();
        spec.algo = AlgoSpec::Svd { cols: 4, plus: true };
        spec.shards = 3;
        spec.transport = TransportSpec::Tcp;
        let backend = crate::exp::native_backend();
        let p = RoundParams::from_spec(&spec, &backend);
        assert_eq!(p.algo, Algo::FedSvd { constrained: true });
        assert_eq!(p.svd_cols, 4);
        assert_eq!(p.sparsity, DEFAULT_SPARSITY);
        assert_eq!(p.sync_interval, DEFAULT_SYNC_INTERVAL);
        assert_eq!(p.shards, 3, "explicit shard counts pass through");
        assert_eq!(p.transport, TransportSpec::Tcp);
    }

    #[test]
    fn wants_refs_scopes_to_svd_and_compressed_dense() {
        let backend = crate::exp::native_backend();
        let mut s = spec();
        let p = RoundParams::from_spec(&s, &backend);
        assert!(!p.wants_refs(), "FedS never carries reference tables");
        s.algo = AlgoSpec::Svd { cols: 8, plus: false };
        assert!(RoundParams::from_spec(&s, &backend).wants_refs());
        s.algo = AlgoSpec::FedEP;
        assert!(!RoundParams::from_spec(&s, &backend).wants_refs());
        s.compression = PipelineSpec::parse("topk,int8:ef").unwrap();
        let p = RoundParams::from_spec(&s, &backend);
        assert!(p.wants_refs(), "a compressed dense run transmits deltas vs refs");
        assert_eq!(p.compression.label(), "topk@0.4,int8:ef");
    }
}
