//! The orchestrator's resolved per-run parameters.
//!
//! [`RoundParams`] is derived **once** per run — from a
//! [`crate::spec::ExperimentSpec`] by [`crate::spec::Session::build`], or
//! from the deprecated flat [`FedRunConfig`] by [`RoundParams::resolve`]
//! — and is the only configuration type the orchestrator internals
//! (`client`, `exchange`, the drivers) consume.  Resolution happens at
//! derivation, not at use sites: the execution mode is already downgraded
//! when the backend cannot thread, the transport and server shard count
//! are concrete values, and every knob is the one the run will actually
//! honor.  `FedRunConfig` itself survives only as the public shim.

use crate::comm::transport::TransportSpec;
use crate::kge::Method;

use super::{Algo, Backend, ExecMode, FedRunConfig};

/// Resolved knobs of one federated run (see module docs).
#[derive(Clone, Debug)]
pub struct RoundParams {
    pub algo: Algo,
    pub method: Method,
    /// hard cap on communication rounds
    pub max_rounds: usize,
    /// local epochs per round (paper default 3)
    pub local_epochs: usize,
    /// evaluate every N rounds (paper: every 5)
    pub eval_every: usize,
    /// early-stop patience in evaluations (paper: 3)
    pub patience: usize,
    /// FedS sparsity ratio p (paper: 0.4, 0.7 for one config)
    pub sparsity: f64,
    /// FedS synchronization interval s (paper: 4)
    pub sync_interval: usize,
    /// cap on eval queries per client per split (0 = all)
    pub eval_cap: usize,
    pub seed: u64,
    /// columns of the SVD reshape (paper: 8)
    pub svd_cols: usize,
    /// client execution mode, already resolved against the backend
    /// (threaded + PJRT downgrades to sequential at derivation)
    pub exec: ExecMode,
    /// which transport carries the frames (accounting is bit-identical
    /// across variants)
    pub transport: TransportSpec,
    /// server aggregation shard count (≥ 1; results are bit-identical
    /// for any value)
    pub shards: usize,
}

impl RoundParams {
    /// Resolve the deprecated flat config against `backend`.  The legacy
    /// path always ran in-process links, so the transport stays mpsc;
    /// the server shard count defaults to the machine's parallelism
    /// (bit-identical to one shard, see `fed::server`).
    pub fn resolve(cfg: &FedRunConfig, backend: &Backend) -> Self {
        let exec = match (cfg.exec, backend) {
            (ExecMode::Threaded, Backend::Xla(_)) => {
                crate::warn_!(
                    "threaded execution needs Send trainers and the PJRT client is not Send; \
                     falling back to sequential"
                );
                ExecMode::Sequential
            }
            (e, _) => e,
        };
        Self {
            algo: cfg.algo,
            method: cfg.method,
            max_rounds: cfg.max_rounds,
            local_epochs: cfg.local_epochs,
            eval_every: cfg.eval_every,
            patience: cfg.patience,
            sparsity: cfg.sparsity,
            sync_interval: cfg.sync_interval,
            eval_cap: cfg.eval_cap,
            seed: cfg.seed,
            svd_cols: cfg.svd_cols,
            exec,
            transport: TransportSpec::Mpsc,
            shards: auto_shards(),
        }
    }
}

/// The default server shard count: one per core, capped — aggregation is
/// memory-bound well before it scales past a handful of threads.
pub fn auto_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_copies_every_knob() {
        let cfg = FedRunConfig {
            algo: Algo::FedS { sync: false },
            sparsity: 0.7,
            sync_interval: 2,
            max_rounds: 9,
            exec: ExecMode::Threaded,
            ..Default::default()
        };
        let backend = crate::exp::native_backend();
        let p = RoundParams::resolve(&cfg, &backend);
        assert_eq!(p.algo, cfg.algo);
        assert_eq!(p.sparsity, cfg.sparsity);
        assert_eq!(p.sync_interval, cfg.sync_interval);
        assert_eq!(p.max_rounds, cfg.max_rounds);
        assert_eq!(p.exec, ExecMode::Threaded, "native backend keeps threaded exec");
        assert_eq!(p.transport, TransportSpec::Mpsc, "legacy path is in-process");
        assert!(p.shards >= 1);
    }
}
