//! Pure-Rust reference implementation of the KGE local-training step and
//! evaluation scoring — the oracle mirror of `python/compile/model.py`.
//!
//! Identical math to the lowered artifact: query composition per method,
//! self-adversarial negative-sampling loss, dense Adam.  An integration
//! test (`rust/tests/xla_parity.rs`) checks native-vs-artifact agreement
//! step-for-step at 1e-3 tolerance.

use crate::data::dataset::{Batch, EvalBatch};
use crate::util::rng::Rng;

use super::{Adam, Hyper, Method, Table};

const MOD_EPS: f32 = 1e-12;

/// Full native model state for one client (entity + relation tables + Adam).
#[derive(Clone, Debug)]
pub struct NativeModel {
    pub method: Method,
    pub hyper: Hyper,
    pub ent: Table,
    pub rel: Table,
    pub ent_adam: Adam,
    pub rel_adam: Adam,
    pub step: u64,
    // scratch gradient buffers (dense, reused across steps)
    g_ent: Vec<f32>,
    g_rel: Vec<f32>,
}

impl NativeModel {
    pub fn new(
        method: Method,
        hyper: Hyper,
        num_entities: usize,
        num_relations: usize,
        rng: &mut Rng,
    ) -> Self {
        let we = method.entity_width(hyper.dim);
        let wr = method.relation_width(hyper.dim);
        let range = hyper.embedding_range();
        let ent = Table::init_uniform(num_entities, we, range, rng);
        let rel = Table::init_uniform(num_relations, wr, range, rng);
        let ent_adam = Adam::new(ent.data.len());
        let rel_adam = Adam::new(rel.data.len());
        let g_ent = vec![0.0; ent.data.len()];
        let g_rel = vec![0.0; rel.data.len()];
        Self { method, hyper, ent, rel, ent_adam, rel_adam, step: 0, g_ent, g_rel }
    }

    /// One training step on a padded batch; returns the loss.
    pub fn train_batch(&mut self, batch: &Batch) -> f32 {
        self.g_ent.iter_mut().for_each(|g| *g = 0.0);
        self.g_rel.iter_mut().for_each(|g| *g = 0.0);
        let loss = self.accumulate_grads(batch);
        self.step += 1;
        self.ent_adam
            .update(&mut self.ent.data, &self.g_ent, self.step, &self.hyper);
        self.rel_adam
            .update(&mut self.rel.data, &self.g_rel, self.step, &self.hyper);
        loss
    }

    /// Loss + gradient accumulation into the dense scratch buffers.
    fn accumulate_grads(&mut self, batch: &Batch) -> f32 {
        let b = batch.batch_size;
        let n = batch.negatives;
        let we = self.ent.width;
        let h = self.hyper.clone();
        let denom: f32 = batch.mask.iter().sum::<f32>().max(1.0);
        let mut total = 0.0f32;

        let mut q = vec![0.0f32; we];
        let mut dq = vec![0.0f32; we];
        let mut logits = vec![0.0f32; n];
        let mut dlogits = vec![0.0f32; n];

        for i in 0..b {
            let (hid, rid, tid) = (
                batch.pos[i * 3] as usize,
                batch.pos[i * 3 + 1] as usize,
                batch.pos[i * 3 + 2] as usize,
            );
            let corrupt_head = batch.neg_is_head[i] > 0.5;
            let weight = batch.mask[i] / denom;

            // ComplEx regularizer includes padded rows (matches the artifact,
            // which regularises every gathered row unmasked).
            if self.method == Method::ComplEx {
                total += self.complex_reg_and_grads(i, batch);
            }
            if weight == 0.0 {
                continue;
            }

            let src_id = if corrupt_head { tid } else { hid };
            let true_id = if corrupt_head { hid } else { tid };

            // forward: query
            compose(
                self.method,
                self.ent.row(src_id),
                self.rel.row(rid),
                corrupt_head,
                &h,
                &mut q,
            );

            // forward: logits
            let pos_logit = self.logit(&q, self.ent.row(true_id));
            for j in 0..n {
                let cid = batch.neg[i * n + j] as usize;
                logits[j] = self.logit(&q, self.ent.row(cid));
            }

            // self-adversarial weights (detached)
            let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for j in 0..n {
                dlogits[j] = ((logits[j] - mx) * h.adv_temperature).exp();
                z += dlogits[j];
            }
            for p in dlogits.iter_mut() {
                *p /= z; // now holds softmax probs
            }

            // loss
            let l_pos = softplus(-pos_logit);
            let mut l_neg = 0.0f32;
            for j in 0..n {
                l_neg += dlogits[j] * softplus(logits[j]);
            }
            total += 0.5 * (l_pos + l_neg) * weight;

            // backward through logits:
            //   dL/dpos = -0.5 σ(-pos) w ; dL/dneg_j = 0.5 p_j σ(neg_j) w
            let dpos = -0.5 * sigmoid(-pos_logit) * weight;
            for j in 0..n {
                dlogits[j] = 0.5 * dlogits[j] * sigmoid(logits[j]) * weight;
            }

            // backward through scores into q and candidate rows
            dq.iter_mut().for_each(|x| *x = 0.0);
            self.backward_candidate(&q, true_id, dpos, &mut dq);
            for j in 0..n {
                let cid = batch.neg[i * n + j] as usize;
                self.backward_candidate(&q, cid, dlogits[j], &mut dq);
            }

            // backward through compose into src entity + relation rows
            self.backward_compose(src_id, rid, corrupt_head, &q, &dq);
        }
        total
    }

    /// logit(q, cand) = γ − dist (TransE/RotatE) or dot (ComplEx)
    fn logit(&self, q: &[f32], cand: &[f32]) -> f32 {
        match self.method {
            Method::TransE => {
                let mut d = 0.0;
                for k in 0..q.len() {
                    d += (q[k] - cand[k]).abs();
                }
                self.hyper.gamma - d
            }
            Method::RotatE => {
                let dh = q.len() / 2;
                let mut d = 0.0;
                for k in 0..dh {
                    let dre = q[k] - cand[k];
                    let dim = q[dh + k] - cand[dh + k];
                    d += (dre * dre + dim * dim + MOD_EPS).sqrt();
                }
                self.hyper.gamma - d
            }
            Method::ComplEx => crate::linalg::dot(q, cand),
        }
    }

    /// d logit/d q and d logit/d cand, scaled by `g`, accumulated into `dq`
    /// and the candidate's dense gradient row.
    fn backward_candidate(&mut self, q: &[f32], cand_id: usize, g: f32, dq: &mut [f32]) {
        let we = self.ent.width;
        let cand = &self.ent.data[cand_id * we..(cand_id + 1) * we];
        let gc = &mut self.g_ent[cand_id * we..(cand_id + 1) * we];
        match self.method {
            Method::TransE => {
                // logit = γ − Σ|q−c| → dlogit/dq = −sign(q−c)
                for k in 0..we {
                    let s = (q[k] - cand[k]).signum();
                    dq[k] += -g * s;
                    gc[k] += g * s;
                }
            }
            Method::RotatE => {
                let dh = we / 2;
                for k in 0..dh {
                    let dre = q[k] - cand[k];
                    let dim = q[dh + k] - cand[dh + k];
                    let m = (dre * dre + dim * dim + MOD_EPS).sqrt();
                    let (ure, uim) = (dre / m, dim / m);
                    dq[k] += -g * ure;
                    dq[dh + k] += -g * uim;
                    gc[k] += g * ure;
                    gc[dh + k] += g * uim;
                }
            }
            Method::ComplEx => {
                for k in 0..we {
                    dq[k] += g * cand[k];
                    gc[k] += g * q[k];
                }
            }
        }
    }

    /// Backprop the query gradient into the source-entity and relation rows.
    fn backward_compose(
        &mut self,
        src_id: usize,
        rel_id: usize,
        corrupt_head: bool,
        q: &[f32],
        dq: &[f32],
    ) {
        let we = self.ent.width;
        let wr = self.rel.width;
        let src = self.ent.data[src_id * we..(src_id + 1) * we].to_vec();
        let rel = self.rel.data[rel_id * wr..(rel_id + 1) * wr].to_vec();
        let emb_range = self.hyper.embedding_range();
        let gsrc = &mut self.g_ent[src_id * we..(src_id + 1) * we];
        let grel = &mut self.g_rel[rel_id * wr..(rel_id + 1) * wr];
        match self.method {
            Method::TransE => {
                // q = src ± r
                let sign = if corrupt_head { -1.0 } else { 1.0 };
                for k in 0..we {
                    gsrc[k] += dq[k];
                    grel[k] += sign * dq[k];
                }
            }
            Method::RotatE => {
                let dh = we / 2;
                let scale = std::f32::consts::PI / emb_range;
                let sign = if corrupt_head { -1.0 } else { 1.0 };
                for k in 0..dh {
                    let theta = rel[k] * scale * sign;
                    let (c, s) = (theta.cos(), theta.sin());
                    // q_re = sre·c − sim·s ; q_im = sre·s + sim·c
                    gsrc[k] += dq[k] * c + dq[dh + k] * s;
                    gsrc[dh + k] += -dq[k] * s + dq[dh + k] * c;
                    // dq/dθ' = (−q_im, q_re); θ' = sign·θ; θ = raw·π/range
                    let dtheta = -dq[k] * q[dh + k] + dq[dh + k] * q[k];
                    grel[k] += dtheta * sign * scale;
                }
            }
            Method::ComplEx => {
                let dh = we / 2;
                let (sre, sim) = src.split_at(dh);
                let (rre, rim) = rel.split_at(dh);
                if !corrupt_head {
                    // tail query: q = s∘r
                    for k in 0..dh {
                        gsrc[k] += dq[k] * rre[k] + dq[dh + k] * rim[k];
                        gsrc[dh + k] += -dq[k] * rim[k] + dq[dh + k] * rre[k];
                        grel[k] += dq[k] * sre[k] + dq[dh + k] * sim[k];
                        grel[dh + k] += -dq[k] * sim[k] + dq[dh + k] * sre[k];
                    }
                } else {
                    // head query: q_re = rre·sre + rim·sim ; q_im = rre·sim − rim·sre
                    for k in 0..dh {
                        gsrc[k] += dq[k] * rre[k] - dq[dh + k] * rim[k];
                        gsrc[dh + k] += dq[k] * rim[k] + dq[dh + k] * rre[k];
                        grel[k] += dq[k] * sre[k] + dq[dh + k] * sim[k];
                        grel[dh + k] += dq[k] * sim[k] - dq[dh + k] * sre[k];
                    }
                }
            }
        }
    }

    /// ComplEx L2 regularizer for row i of the batch (matches the artifact:
    /// mean over each gathered tensor, applied every row incl. padding).
    fn complex_reg_and_grads(&mut self, i: usize, batch: &Batch) -> f32 {
        let we = self.ent.width;
        let wr = self.rel.width;
        let b = batch.batch_size;
        let n = batch.negatives;
        let lam = self.hyper.complex_reg;
        let mut reg = 0.0f32;
        // h, t: mean over (B, We); r over (B, Wr); cand over (B, N, We)
        let ids = [
            (batch.pos[i * 3] as usize, b * we, true),
            (batch.pos[i * 3 + 2] as usize, b * we, true),
        ];
        for (id, numel, is_ent) in ids {
            let row = if is_ent { self.ent.row(id) } else { self.rel.row(id) };
            let ss: f32 = row.iter().map(|x| x * x).sum();
            reg += lam * ss / numel as f32;
            let coef = 2.0 * lam / numel as f32;
            let g = &mut self.g_ent[id * we..(id + 1) * we];
            for k in 0..we {
                g[k] += coef * self.ent.data[id * we + k];
            }
        }
        let rid = batch.pos[i * 3 + 1] as usize;
        let ss: f32 = self.rel.row(rid).iter().map(|x| x * x).sum();
        reg += lam * ss / (b * wr) as f32;
        let coef = 2.0 * lam / (b * wr) as f32;
        for k in 0..wr {
            self.g_rel[rid * wr + k] += coef * self.rel.data[rid * wr + k];
        }
        for j in 0..n {
            let cid = batch.neg[i * n + j] as usize;
            let ss: f32 = self.ent.row(cid).iter().map(|x| x * x).sum();
            reg += lam * ss / (b * n * we) as f32;
            let coef = 2.0 * lam / (b * n * we) as f32;
            for k in 0..we {
                self.g_ent[cid * we + k] += coef * self.ent.data[cid * we + k];
            }
        }
        reg
    }

    /// Filtered ranks for an eval batch (mirror of the eval artifact).
    pub fn eval_ranks(&self, eb: &EvalBatch) -> Vec<f32> {
        let e = self.ent.rows;
        let we = self.ent.width;
        let h = &self.hyper;
        let mut q = vec![0.0f32; we];
        let mut ranks = Vec::with_capacity(eb.len);
        for i in 0..eb.len {
            let src = eb.src[i] as usize;
            let rid = eb.rel[i] as usize;
            let truth = eb.truth[i] as usize;
            let ph = eb.pred_head[i] > 0.5;
            compose(self.method, self.ent.row(src), self.rel.row(rid), ph, h, &mut q);
            let true_good = self.logit(&q, self.ent.row(truth));
            let filt = &eb.filter[i * e..(i + 1) * e];
            let mut greater = 0u32;
            let mut equal = 0u32;
            for c in 0..e {
                if c == truth || filt[c] > 0.5 {
                    continue;
                }
                let g = self.logit(&q, self.ent.row(c));
                if g > true_good {
                    greater += 1;
                } else if g == true_good {
                    equal += 1;
                }
            }
            ranks.push(1.0 + greater as f32 + 0.5 * equal as f32);
        }
        ranks
    }
}

/// Query composition — mirror of `model.compose` in python.
pub fn compose(
    method: Method,
    src: &[f32],
    rel: &[f32],
    predict_head: bool,
    h: &Hyper,
    out: &mut [f32],
) {
    match method {
        Method::TransE => {
            let s = if predict_head { -1.0 } else { 1.0 };
            for k in 0..src.len() {
                out[k] = src[k] + s * rel[k];
            }
        }
        Method::RotatE => {
            let dh = src.len() / 2;
            let scale = std::f32::consts::PI / h.embedding_range();
            let sign = if predict_head { -1.0 } else { 1.0 };
            for k in 0..dh {
                let theta = rel[k] * scale * sign;
                let (c, s) = (theta.cos(), theta.sin());
                out[k] = src[k] * c - src[dh + k] * s;
                out[dh + k] = src[k] * s + src[dh + k] * c;
            }
        }
        Method::ComplEx => {
            let dh = src.len() / 2;
            let (sre, sim) = src.split_at(dh);
            let (rre, rim) = rel.split_at(dh);
            if !predict_head {
                for k in 0..dh {
                    out[k] = sre[k] * rre[k] - sim[k] * rim[k];
                    out[dh + k] = sre[k] * rim[k] + sim[k] * rre[k];
                }
            } else {
                for k in 0..dh {
                    out[k] = rre[k] * sre[k] + rim[k] * sim[k];
                    out[dh + k] = rre[k] * sim[k] - rim[k] * sre[k];
                }
            }
        }
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
fn softplus(x: f32) -> f32 {
    // stable: log(1 + e^x)
    if x > 20.0 {
        x
    } else if x < -20.0 {
        x.exp()
    } else {
        (1.0 + x.exp()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Triple;
    use crate::data::dataset::BatchIter;
    use crate::util::prop::check;

    fn toy_batch(b: usize, n: usize, e: usize, r: usize, rng: &mut Rng) -> Batch {
        let triples: Vec<Triple> = (0..b)
            .map(|_| {
                Triple::new(
                    rng.u32_below(e as u32),
                    rng.u32_below(r as u32),
                    rng.u32_below(e as u32),
                )
            })
            .collect();
        let ents: Vec<u32> = (0..e as u32).collect();
        BatchIter::new(&triples, &ents, b, n, rng).next().unwrap()
    }

    fn model(method: Method, rng: &mut Rng) -> NativeModel {
        let hyper = Hyper { dim: 6, ..Default::default() };
        NativeModel::new(method, hyper, 32, 4, rng)
    }

    #[test]
    fn loss_decreases_all_methods() {
        for method in Method::ALL {
            let mut rng = Rng::new(42);
            let mut m = model(method, &mut rng);
            let batch = toy_batch(16, 8, 32, 4, &mut rng);
            let first = m.train_batch(&batch);
            let mut last = first;
            for _ in 0..60 {
                last = m.train_batch(&batch);
            }
            assert!(last < first, "{method:?}: {first} → {last}");
            assert!(last.is_finite());
        }
    }

    #[test]
    fn masked_batch_is_noop_for_distance_methods() {
        for method in [Method::TransE, Method::RotatE] {
            let mut rng = Rng::new(3);
            let mut m = model(method, &mut rng);
            let mut batch = toy_batch(8, 4, 32, 4, &mut rng);
            batch.mask.iter_mut().for_each(|x| *x = 0.0);
            let before = m.ent.data.clone();
            m.train_batch(&batch);
            assert_eq!(m.ent.data, before, "{method:?}");
        }
    }

    /// Finite-difference gradient check on the full loss, all methods.
    #[test]
    fn gradients_match_finite_difference() {
        for method in Method::ALL {
            check(&format!("fd_grad_{}", method.name()), 3, |rng| {
                // adv_temperature = 0 → uniform negative weights, so the
                // (detached) softmax does not perturb the finite difference.
                let hyper = Hyper {
                    dim: 4,
                    complex_reg: 1e-3,
                    adv_temperature: 0.0,
                    ..Default::default()
                };
                let mut m = NativeModel::new(method, hyper, 12, 3, rng);
                let batch = toy_batch(4, 3, 12, 3, rng);

                // analytic grads
                m.g_ent.iter_mut().for_each(|g| *g = 0.0);
                m.g_rel.iter_mut().for_each(|g| *g = 0.0);
                let _ = m.accumulate_grads(&batch);
                let ga = m.g_ent.clone();
                let gr = m.g_rel.clone();

                let loss_at = |m: &mut NativeModel| {
                    m.g_ent.iter_mut().for_each(|g| *g = 0.0);
                    m.g_rel.iter_mut().for_each(|g| *g = 0.0);
                    m.accumulate_grads(&batch)
                };

                let eps = 1e-3f32;
                // probe a handful of random coordinates in each table
                for _ in 0..6 {
                    let i = rng.usize_below(m.ent.data.len());
                    let orig = m.ent.data[i];
                    m.ent.data[i] = orig + eps;
                    let lp = loss_at(&mut m);
                    m.ent.data[i] = orig - eps;
                    let lm = loss_at(&mut m);
                    m.ent.data[i] = orig;
                    let fd = (lp - lm) / (2.0 * eps);
                    assert!(
                        (fd - ga[i]).abs() < 2e-2 * (1.0 + fd.abs()),
                        "{method:?} ent[{i}]: fd {fd} vs {}",
                        ga[i]
                    );
                }
                for _ in 0..6 {
                    let i = rng.usize_below(m.rel.data.len());
                    let orig = m.rel.data[i];
                    m.rel.data[i] = orig + eps;
                    let lp = loss_at(&mut m);
                    m.rel.data[i] = orig - eps;
                    let lm = loss_at(&mut m);
                    m.rel.data[i] = orig;
                    let fd = (lp - lm) / (2.0 * eps);
                    assert!(
                        (fd - gr[i]).abs() < 2e-2 * (1.0 + fd.abs()),
                        "{method:?} rel[{i}]: fd {fd} vs {}",
                        gr[i]
                    );
                }
            });
        }
    }

    #[test]
    fn compose_head_tail_score_symmetry() {
        // score(h,r,t) via tail query vs via head query must agree
        for method in Method::ALL {
            let mut rng = Rng::new(9);
            let m = model(method, &mut rng);
            let we = m.ent.width;
            let mut qt = vec![0.0; we];
            let mut qh = vec![0.0; we];
            for _ in 0..20 {
                let h = rng.usize_below(32);
                let r = rng.usize_below(4);
                let t = rng.usize_below(32);
                compose(method, m.ent.row(h), m.rel.row(r), false, &m.hyper, &mut qt);
                compose(method, m.ent.row(t), m.rel.row(r), true, &m.hyper, &mut qh);
                let st = m.logit(&qt, m.ent.row(t));
                let sh = m.logit(&qh, m.ent.row(h));
                assert!((st - sh).abs() < 1e-3, "{method:?} {st} vs {sh}");
            }
        }
    }

    #[test]
    fn eval_rank_perfect_answer_is_one() {
        for method in Method::ALL {
            let mut rng = Rng::new(5);
            let mut m = model(method, &mut rng);
            // plant: entity 0's embedding = query composition of (src=1, r=0)
            let we = m.ent.width;
            let mut q = vec![0.0; we];
            compose(method, m.ent.row(1), m.rel.row(0), false, &m.hyper, &mut q);
            if method == Method::ComplEx {
                crate::linalg::scale(&mut q, 100.0);
            }
            m.ent.set_row(0, &q);
            let eb = EvalBatch {
                src: vec![1],
                rel: vec![0],
                truth: vec![0],
                pred_head: vec![0.0],
                filter: vec![0.0; 32],
                len: 1,
                eval_batch: 1,
            };
            let ranks = m.eval_ranks(&eb);
            assert!(ranks[0] <= 1.5, "{method:?}: rank {}", ranks[0]);
        }
    }

    #[test]
    fn eval_filter_forces_rank_one() {
        let mut rng = Rng::new(6);
        let m = model(Method::TransE, &mut rng);
        let mut filter = vec![1.0f32; 32];
        filter[7] = 0.0;
        let eb = EvalBatch {
            src: vec![3],
            rel: vec![1],
            truth: vec![7],
            pred_head: vec![1.0],
            filter,
            len: 1,
            eval_batch: 1,
        };
        assert_eq!(m.eval_ranks(&eb), vec![1.0]);
    }

    #[test]
    fn training_improves_planted_structure() {
        // tiny closed-world: relation 0 maps i → i+8; training should push
        // the true tail's rank toward the top.
        let mut rng = Rng::new(11);
        let hyper = Hyper { dim: 8, learning_rate: 3e-3, ..Default::default() };
        let mut m = NativeModel::new(Method::TransE, hyper, 16, 1, &mut rng);
        let triples: Vec<Triple> = (0..8).map(|i| Triple::new(i, 0, i + 8)).collect();
        let ents: Vec<u32> = (0..16).collect();
        let before = mean_rank(&m, &triples);
        for _ in 0..150 {
            let mut r2 = rng.fork(1);
            for batch in BatchIter::new(&triples, &ents, 8, 8, &mut r2) {
                m.train_batch(&batch);
            }
        }
        let after = mean_rank(&m, &triples);
        assert!(after < before, "mean rank {before} → {after}");
        assert!(after < 3.0, "after {after}");
    }

    fn mean_rank(m: &NativeModel, triples: &[Triple]) -> f32 {
        let e = m.ent.rows;
        let eb = EvalBatch {
            src: triples.iter().map(|t| t.h as i32).collect(),
            rel: triples.iter().map(|t| t.r as i32).collect(),
            truth: triples.iter().map(|t| t.t as i32).collect(),
            pred_head: vec![0.0; triples.len()],
            filter: vec![0.0; triples.len() * e],
            len: triples.len(),
            eval_batch: triples.len(),
        };
        let ranks = m.eval_ranks(&eb);
        ranks.iter().sum::<f32>() / ranks.len() as f32
    }
}
