//! Pure-Rust reference implementation of the KGE local-training step and
//! evaluation scoring — the oracle mirror of `python/compile/model.py`.
//!
//! Same forward/backward math as the lowered artifact: query composition
//! per method, self-adversarial negative-sampling loss.  The optimizer is
//! lazy row-wise Adam, which matches the artifact's dense Adam exactly on
//! every *touched* row but does not apply the dense zero-grad drift to
//! rows a batch never gathers (see [`LazyAdam`](super::LazyAdam)); a
//! sparse-aware XLA optimizer artifact is ROADMAP follow-on work.  The
//! integration test (`rust/tests/xla_parity.rs`) checks native-vs-artifact
//! agreement over a short, near-full-coverage run at 1e-3 tolerance.
//!
//! The training hot path is **sparse** and **lane-parallel**: gradients
//! accumulate into [`SparseGrad`] (an index map over the ≤
//! `3·batch + batch·negatives` rows a batch actually gathers), the
//! optimizer is the lazy row-wise [`LazyAdam`](super::LazyAdam), and the
//! per-pair `logit` / candidate-backward / compose-backward math runs
//! through the width-dispatched kernels of [`super::kernels`]
//! ([`KernelSet::select`]ed once at construction).  The dispatched pass
//! also **dedups repeated negative ids per positive** — duplicate
//! candidates share one logit, one softmax weight (scaled by
//! multiplicity), and one coalesced gradient accumulation, so
//! [`SparseGrad`]/[`LazyAdam`] registration never pays per-duplicate
//! `row_mut` churn — and reuses model-owned scratch buffers, so a step
//! performs no heap allocation at all.
//!
//! Two reference engines are retained for parity: the element-at-a-time
//! loops survive behind [`KernelSet::scalar`] (per-occurrence negatives,
//! no dedup — the kernel oracle), and the pre-sparse full-table engine
//! survives as [`DenseOracle`] (the optimizer oracle and `train_hot_path`
//! bench baseline).  `eval_ranks` chunks its O(rows) candidate scan across
//! OS threads with bit-identical results for any thread count.

use crate::data::dataset::{Batch, EvalBatch};
use crate::store::{StorageSpec, StoreTable};
use crate::util::rng::Rng;

use super::kernels::{self, KernelSet, MOD_EPS};
use super::{Adam, Hyper, LazyAdam, Method, Table};

/// Below this many candidate·query scores, `eval_ranks` stays on the
/// calling thread (thread spawn would dominate the scan).
const PAR_EVAL_MIN_WORK: usize = 1 << 18;

const UNTOUCHED: u32 = u32::MAX;

/// Touched-row gradient accumulator for one table: a per-batch index map
/// from row id to a slot in a compact `touched × width` buffer.  Clearing
/// costs O(touched), not O(rows); slot storage is reused across steps.
#[derive(Clone, Debug)]
pub struct SparseGrad {
    width: usize,
    /// row id → slot index, `UNTOUCHED` when the row has no gradient
    slot: Vec<u32>,
    /// touched row ids, in first-touch order
    touched: Vec<u32>,
    /// compact gradient rows, `touched.len() × width`
    data: Vec<f32>,
}

impl SparseGrad {
    pub fn new(rows: usize, width: usize) -> Self {
        Self { width, slot: vec![UNTOUCHED; rows], touched: Vec::new(), data: Vec::new() }
    }

    /// Number of rows with a gradient this step.
    pub fn len(&self) -> usize {
        self.touched.len()
    }

    pub fn is_empty(&self) -> bool {
        self.touched.is_empty()
    }

    /// Reset for the next step in O(touched).
    pub fn clear(&mut self) {
        for &r in &self.touched {
            self.slot[r as usize] = UNTOUCHED;
        }
        self.touched.clear();
        self.data.clear();
    }

    /// The (zero-initialized on first touch) gradient row for `row`.
    pub fn row_mut(&mut self, row: usize) -> &mut [f32] {
        let w = self.width;
        let mut s = self.slot[row];
        if s == UNTOUCHED {
            s = self.touched.len() as u32;
            self.slot[row] = s;
            self.touched.push(row as u32);
            self.data.resize(self.data.len() + w, 0.0);
        }
        let off = s as usize * w;
        &mut self.data[off..off + w]
    }

    /// Touched rows in first-touch order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &[f32])> + '_ {
        let w = self.width;
        self.touched.iter().enumerate().map(move |(s, &r)| (r, &self.data[s * w..(s + 1) * w]))
    }

    /// Scatter into a dense `rows × width` buffer (oracle/test path; the
    /// buffer must already be zeroed).
    pub fn scatter_into(&self, dense: &mut [f32]) {
        let w = self.width;
        for (r, row) in self.iter() {
            let off = r as usize * w;
            dense[off..off + w].copy_from_slice(row);
        }
    }

    /// Dense copy of the accumulated gradients (test convenience).
    pub fn to_dense(&self, rows: usize) -> Vec<f32> {
        let mut d = vec![0.0; rows * self.width];
        self.scatter_into(&mut d);
        d
    }
}

/// Model-owned step scratch: every buffer the gradient pass needs, reused
/// across steps so the hot loop never allocates.  `neg_slot` is the
/// per-positive negative-id dedup map (entity id → slot in `uniq_ids`,
/// [`UNTOUCHED`] when absent — same idiom as [`SparseGrad`]); `cos`/`sin`
/// cache RotatE's per-positive rotation so its compose backward needs no
/// trigonometry.
#[derive(Clone, Debug, Default)]
struct StepScratch {
    q: Vec<f32>,
    dq: Vec<f32>,
    logits: Vec<f32>,
    probs: Vec<f32>,
    cos: Vec<f32>,
    sin: Vec<f32>,
    uniq_ids: Vec<u32>,
    uniq_cnt: Vec<f32>,
    neg_slot: Vec<u32>,
}

/// Full native model state for one client (entity + relation tables +
/// lazy row-wise Adam).
#[derive(Clone, Debug)]
pub struct NativeModel {
    pub method: Method,
    pub hyper: Hyper,
    /// Entity table on the run's storage backend ([`StoreTable`]): the
    /// O(E·width) state that moves to mmap for million-entity runs.  The
    /// relation table stays a plain [`Table`] — R is small.
    pub ent: StoreTable,
    pub rel: Table,
    pub ent_adam: LazyAdam,
    pub rel_adam: LazyAdam,
    pub step: u64,
    /// OS-thread cap for `eval_ranks` candidate chunking (0 = auto from
    /// `available_parallelism`).  Results are bit-identical for any value.
    pub eval_threads: usize,
    /// Inner-loop dispatch, selected once at construction from the entity
    /// row width ([`KernelSet::select`]).  Set to [`KernelSet::scalar`]
    /// to run the retained element-at-a-time reference loops (the kernel
    /// parity oracle); switching is safe at any step boundary — dispatch
    /// is stateless.
    pub kernels: KernelSet,
    // touched-row gradient accumulators (reused across steps)
    g_ent: SparseGrad,
    g_rel: SparseGrad,
    // step-loop scratch (reused across steps; no per-positive allocation)
    scratch: StepScratch,
}

impl NativeModel {
    pub fn new(
        method: Method,
        hyper: Hyper,
        num_entities: usize,
        num_relations: usize,
        rng: &mut Rng,
    ) -> Self {
        Self::with_store(method, hyper, num_entities, num_relations, &StorageSpec::Ram, rng)
            .expect("in-RAM storage is infallible")
    }

    /// Like [`NativeModel::new`] with the entity-scaled state (entity
    /// table + its Adam moments) on the selected storage backend.  The
    /// RNG draw order is backend-independent, so results are
    /// bit-identical across backends.
    pub fn with_store(
        method: Method,
        hyper: Hyper,
        num_entities: usize,
        num_relations: usize,
        storage: &StorageSpec,
        rng: &mut Rng,
    ) -> anyhow::Result<Self> {
        let we = method.entity_width(hyper.dim);
        let wr = method.relation_width(hyper.dim);
        let range = hyper.embedding_range();
        let ent = StoreTable::init_uniform_in(storage, num_entities, we, range, rng)?;
        let rel = Table::init_uniform(num_relations, wr, range, rng);
        let ent_adam = LazyAdam::new_in(storage, num_entities, we)?;
        let rel_adam = LazyAdam::new(num_relations, wr);
        let g_ent = SparseGrad::new(num_entities, we);
        let g_rel = SparseGrad::new(num_relations, wr);
        let kernels = KernelSet::select(we);
        let scratch = StepScratch { neg_slot: vec![UNTOUCHED; num_entities], ..Default::default() };
        Ok(Self {
            method,
            hyper,
            ent,
            rel,
            ent_adam,
            rel_adam,
            step: 0,
            eval_threads: 0,
            kernels,
            g_ent,
            g_rel,
            scratch,
        })
    }

    /// One training step on a padded batch; returns the loss.  Work is
    /// O(touched·width): only rows gathered by the batch are visited, by
    /// the gradient pass and by the optimizer alike.
    pub fn train_batch(&mut self, batch: &Batch) -> f32 {
        let loss = self.forward_backward(batch);
        self.step += 1;
        for (r, g) in self.g_ent.iter() {
            let r = r as usize;
            let p = self.ent.row_mut(r);
            self.ent_adam.update_row(p, g, r, self.step, &self.hyper);
        }
        let wr = self.rel.width;
        for (r, g) in self.g_rel.iter() {
            let r = r as usize;
            let p = &mut self.rel.data[r * wr..(r + 1) * wr];
            self.rel_adam.update_row(p, g, r, self.step, &self.hyper);
        }
        loss
    }

    /// Forward + gradient accumulation only (no optimizer step): clears
    /// the touched-row accumulators and returns the batch loss.  This is
    /// the kernel-bench / parity-test entry point; [`Self::train_batch`]
    /// is this plus the [`LazyAdam`] update.
    pub fn forward_backward(&mut self, batch: &Batch) -> f32 {
        self.g_ent.clear();
        self.g_rel.clear();
        self.accumulate_grads(batch)
    }

    /// Dense copies of the currently accumulated (entity, relation)
    /// gradients — parity-test convenience.
    pub fn grads_dense(&self) -> (Vec<f32>, Vec<f32>) {
        (self.g_ent.to_dense(self.ent.rows), self.g_rel.to_dense(self.rel.rows))
    }

    /// Loss + gradient accumulation, routed through the selected kernels:
    /// the width-dispatched deduping pass by default, the retained
    /// element-at-a-time reference when `kernels` is scalar.
    fn accumulate_grads(&mut self, batch: &Batch) -> f32 {
        if self.kernels.is_scalar() {
            self.accumulate_scalar(batch)
        } else {
            self.accumulate_fast(batch)
        }
    }

    /// The width-dispatched gradient pass: per positive, negative ids are
    /// coalesced first (one logit, one softmax weight scaled by
    /// multiplicity, one gradient accumulation per **unique** candidate),
    /// then the lane kernels run over model-owned scratch.  RotatE's
    /// rotation is computed once in the forward compose and cached for
    /// the backward.
    fn accumulate_fast(&mut self, batch: &Batch) -> f32 {
        let b = batch.batch_size;
        let n = batch.negatives;
        let we = self.ent.width;
        let dh = we / 2;
        let ks = self.kernels;
        let h = self.hyper.clone();
        let denom: f32 = batch.mask.iter().sum::<f32>().max(1.0);
        let mut total = 0.0f32;

        let mut sc = std::mem::take(&mut self.scratch);
        sc.q.resize(we, 0.0);
        sc.dq.resize(we, 0.0);
        sc.logits.resize(n, 0.0);
        sc.probs.resize(n, 0.0);
        if self.method == Method::RotatE {
            sc.cos.resize(dh, 0.0);
            sc.sin.resize(dh, 0.0);
        }

        for i in 0..b {
            let (hid, rid, tid) = (
                batch.pos[i * 3] as usize,
                batch.pos[i * 3 + 1] as usize,
                batch.pos[i * 3 + 2] as usize,
            );
            let corrupt_head = batch.neg_is_head[i] > 0.5;
            let weight = batch.mask[i] / denom;
            let sign = if corrupt_head { -1.0f32 } else { 1.0f32 };

            // coalesce this positive's negative ids (first-occurrence order)
            sc.uniq_ids.clear();
            sc.uniq_cnt.clear();
            for j in 0..n {
                let cid = batch.neg[i * n + j] as usize;
                let s = sc.neg_slot[cid];
                if s == UNTOUCHED {
                    sc.neg_slot[cid] = sc.uniq_ids.len() as u32;
                    sc.uniq_ids.push(cid as u32);
                    sc.uniq_cnt.push(1.0);
                } else {
                    sc.uniq_cnt[s as usize] += 1.0;
                }
            }
            let nu = sc.uniq_ids.len();

            if self.method == Method::ComplEx {
                total += self.complex_reg_fast(i, batch, &sc.uniq_ids, &sc.uniq_cnt, ks);
            }
            if weight == 0.0 {
                for &id in &sc.uniq_ids {
                    sc.neg_slot[id as usize] = UNTOUCHED;
                }
                continue;
            }

            let src_id = if corrupt_head { tid } else { hid };
            let true_id = if corrupt_head { hid } else { tid };

            // forward: query (RotatE caches cos/sin for the backward)
            {
                let src = self.ent.row(src_id);
                let rel = self.rel.row(rid);
                match self.method {
                    Method::TransE => kernels::transe_compose_k(ks.full, src, rel, sign, &mut sc.q),
                    Method::RotatE => {
                        let scale = std::f32::consts::PI / h.embedding_range();
                        kernels::rotate_compose_cached(
                            src, rel, scale, sign, &mut sc.cos, &mut sc.sin, &mut sc.q,
                        );
                    }
                    Method::ComplEx => {
                        kernels::complex_compose_k(ks.half, src, rel, corrupt_head, &mut sc.q)
                    }
                }
            }

            // forward: one logit per unique candidate
            let pos_logit = ks.logit(self.method, h.gamma, &sc.q, self.ent.row(true_id));
            for u in 0..nu {
                let cid = sc.uniq_ids[u] as usize;
                sc.logits[u] = ks.logit(self.method, h.gamma, &sc.q, self.ent.row(cid));
            }

            // self-adversarial weights over the multiset (duplicates share
            // one bitwise-identical probability, counted by multiplicity)
            let mut mx = f32::NEG_INFINITY;
            for &l in &sc.logits[..nu] {
                mx = mx.max(l);
            }
            let mut z = 0.0f32;
            for u in 0..nu {
                let e = ((sc.logits[u] - mx) * h.adv_temperature).exp();
                sc.probs[u] = e;
                z += sc.uniq_cnt[u] * e;
            }

            // loss
            let l_pos = softplus(-pos_logit);
            let mut l_neg = 0.0f32;
            for u in 0..nu {
                sc.probs[u] /= z;
                l_neg += sc.uniq_cnt[u] * sc.probs[u] * softplus(sc.logits[u]);
            }
            total += 0.5 * (l_pos + l_neg) * weight;

            // backward through logits, coalesced per unique candidate
            let dpos = -0.5 * sigmoid(-pos_logit) * weight;
            sc.dq.iter_mut().for_each(|x| *x = 0.0);
            {
                let cand = self.ent.row(true_id);
                let gc = self.g_ent.row_mut(true_id);
                ks.bwd_candidate(self.method, &sc.q, cand, dpos, &mut sc.dq, gc);
            }
            for u in 0..nu {
                let cid = sc.uniq_ids[u] as usize;
                let g = 0.5 * sc.uniq_cnt[u] * sc.probs[u] * sigmoid(sc.logits[u]) * weight;
                let cand = self.ent.row(cid);
                let gc = self.g_ent.row_mut(cid);
                ks.bwd_candidate(self.method, &sc.q, cand, g, &mut sc.dq, gc);
            }

            // backward through compose into src entity + relation rows
            match self.method {
                Method::TransE => {
                    let gsrc = self.g_ent.row_mut(src_id);
                    let grel = self.g_rel.row_mut(rid);
                    kernels::transe_bwd_compose_k(ks.full, &sc.dq, sign, gsrc, grel);
                }
                Method::RotatE => {
                    let scale = std::f32::consts::PI / h.embedding_range();
                    let gsrc = self.g_ent.row_mut(src_id);
                    let grel = self.g_rel.row_mut(rid);
                    kernels::rotate_bwd_compose_k(
                        ks.half, &sc.q, &sc.dq, &sc.cos, &sc.sin, sign, scale, gsrc, grel,
                    );
                }
                Method::ComplEx => {
                    let src = self.ent.row(src_id);
                    let rel = self.rel.row(rid);
                    let gsrc = self.g_ent.row_mut(src_id);
                    let grel = self.g_rel.row_mut(rid);
                    kernels::complex_bwd_compose_k(
                        ks.half, src, rel, corrupt_head, &sc.dq, gsrc, grel,
                    );
                }
            }

            // release the dedup slots in O(unique)
            for &id in &sc.uniq_ids {
                sc.neg_slot[id as usize] = UNTOUCHED;
            }
        }
        self.scratch = sc;
        total
    }

    /// ComplEx L2 regularizer for row i, candidate terms coalesced over
    /// the positive's unique negative ids (duplicates contribute
    /// `count ×` one term — same math, one `row_mut` registration).
    fn complex_reg_fast(
        &mut self,
        i: usize,
        batch: &Batch,
        uniq_ids: &[u32],
        uniq_cnt: &[f32],
        ks: KernelSet,
    ) -> f32 {
        let we = self.ent.width;
        let wr = self.rel.width;
        let b = batch.batch_size;
        let n = batch.negatives;
        let lam = self.hyper.complex_reg;
        let mut reg = 0.0f32;
        for id in [batch.pos[i * 3] as usize, batch.pos[i * 3 + 2] as usize] {
            let numel = (b * we) as f32;
            let ss = kernels::sumsq_k(ks.full, self.ent.row(id));
            reg += lam * ss / numel;
            let coef = 2.0 * lam / numel;
            let row = self.ent.row(id);
            let g = self.g_ent.row_mut(id);
            kernels::axpy_k(ks.full, coef, row, g);
        }
        let rid = batch.pos[i * 3 + 1] as usize;
        let numel = (b * wr) as f32;
        let ss = kernels::sumsq_k(ks.full, self.rel.row(rid));
        reg += lam * ss / numel;
        let coef = 2.0 * lam / numel;
        let row = &self.rel.data[rid * wr..(rid + 1) * wr];
        let gr = self.g_rel.row_mut(rid);
        kernels::axpy_k(ks.full, coef, row, gr);
        let numel = (b * n * we) as f32;
        for (u, &id) in uniq_ids.iter().enumerate() {
            let cid = id as usize;
            let cnt = uniq_cnt[u];
            let ss = kernels::sumsq_k(ks.full, self.ent.row(cid));
            reg += cnt * (lam * ss / numel);
            let coef = cnt * (2.0 * lam / numel);
            let row = self.ent.row(cid);
            let gc = self.g_ent.row_mut(cid);
            kernels::axpy_k(ks.full, coef, row, gc);
        }
        reg
    }

    /// The retained element-at-a-time reference pass (the kernel parity
    /// oracle): per-occurrence negatives, no dedup, scalar inner loops.
    fn accumulate_scalar(&mut self, batch: &Batch) -> f32 {
        let b = batch.batch_size;
        let n = batch.negatives;
        let we = self.ent.width;
        let h = self.hyper.clone();
        let denom: f32 = batch.mask.iter().sum::<f32>().max(1.0);
        let mut total = 0.0f32;

        let mut q = vec![0.0f32; we];
        let mut dq = vec![0.0f32; we];
        let mut logits = vec![0.0f32; n];
        let mut dlogits = vec![0.0f32; n];

        for i in 0..b {
            let (hid, rid, tid) = (
                batch.pos[i * 3] as usize,
                batch.pos[i * 3 + 1] as usize,
                batch.pos[i * 3 + 2] as usize,
            );
            let corrupt_head = batch.neg_is_head[i] > 0.5;
            let weight = batch.mask[i] / denom;

            // ComplEx regularizer includes padded rows (matches the artifact,
            // which regularises every gathered row unmasked).
            if self.method == Method::ComplEx {
                total += self.complex_reg_and_grads(i, batch);
            }
            if weight == 0.0 {
                continue;
            }

            let src_id = if corrupt_head { tid } else { hid };
            let true_id = if corrupt_head { hid } else { tid };

            // forward: query
            compose(
                self.method,
                self.ent.row(src_id),
                self.rel.row(rid),
                corrupt_head,
                &h,
                &mut q,
            );

            // forward: logits
            let pos_logit = self.logit(&q, self.ent.row(true_id));
            for j in 0..n {
                let cid = batch.neg[i * n + j] as usize;
                logits[j] = self.logit(&q, self.ent.row(cid));
            }

            // self-adversarial weights (detached)
            let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for j in 0..n {
                dlogits[j] = ((logits[j] - mx) * h.adv_temperature).exp();
                z += dlogits[j];
            }
            for p in dlogits.iter_mut() {
                *p /= z; // now holds softmax probs
            }

            // loss
            let l_pos = softplus(-pos_logit);
            let mut l_neg = 0.0f32;
            for j in 0..n {
                l_neg += dlogits[j] * softplus(logits[j]);
            }
            total += 0.5 * (l_pos + l_neg) * weight;

            // backward through logits:
            //   dL/dpos = -0.5 σ(-pos) w ; dL/dneg_j = 0.5 p_j σ(neg_j) w
            let dpos = -0.5 * sigmoid(-pos_logit) * weight;
            for j in 0..n {
                dlogits[j] = 0.5 * dlogits[j] * sigmoid(logits[j]) * weight;
            }

            // backward through scores into q and candidate rows
            dq.iter_mut().for_each(|x| *x = 0.0);
            self.backward_candidate(&q, true_id, dpos, &mut dq);
            for j in 0..n {
                let cid = batch.neg[i * n + j] as usize;
                self.backward_candidate(&q, cid, dlogits[j], &mut dq);
            }

            // backward through compose into src entity + relation rows
            self.backward_compose(src_id, rid, corrupt_head, &q, &dq);
        }
        total
    }

    /// logit(q, cand) = γ − dist (TransE/RotatE) or dot (ComplEx).
    /// Routed through the width-dispatched kernels unless this model is
    /// the scalar reference, which keeps the element-at-a-time loops.
    fn logit(&self, q: &[f32], cand: &[f32]) -> f32 {
        if !self.kernels.is_scalar() {
            return self.kernels.logit(self.method, self.hyper.gamma, q, cand);
        }
        match self.method {
            Method::TransE => {
                let mut d = 0.0;
                for k in 0..q.len() {
                    d += (q[k] - cand[k]).abs();
                }
                self.hyper.gamma - d
            }
            Method::RotatE => {
                let dh = q.len() / 2;
                let mut d = 0.0;
                for k in 0..dh {
                    let dre = q[k] - cand[k];
                    let dim = q[dh + k] - cand[dh + k];
                    d += (dre * dre + dim * dim + MOD_EPS).sqrt();
                }
                self.hyper.gamma - d
            }
            Method::ComplEx => crate::linalg::dot(q, cand),
        }
    }

    /// d logit/d q and d logit/d cand, scaled by `g`, accumulated into `dq`
    /// and the candidate's touched gradient row.
    fn backward_candidate(&mut self, q: &[f32], cand_id: usize, g: f32, dq: &mut [f32]) {
        let we = self.ent.width;
        let cand = self.ent.row(cand_id);
        let gc = self.g_ent.row_mut(cand_id);
        match self.method {
            Method::TransE => {
                // logit = γ − Σ|q−c| → dlogit/dq = −sign(q−c)
                for k in 0..we {
                    let s = (q[k] - cand[k]).signum();
                    dq[k] += -g * s;
                    gc[k] += g * s;
                }
            }
            Method::RotatE => {
                let dh = we / 2;
                for k in 0..dh {
                    let dre = q[k] - cand[k];
                    let dim = q[dh + k] - cand[dh + k];
                    let m = (dre * dre + dim * dim + MOD_EPS).sqrt();
                    let (ure, uim) = (dre / m, dim / m);
                    dq[k] += -g * ure;
                    dq[dh + k] += -g * uim;
                    gc[k] += g * ure;
                    gc[dh + k] += g * uim;
                }
            }
            Method::ComplEx => {
                for k in 0..we {
                    dq[k] += g * cand[k];
                    gc[k] += g * q[k];
                }
            }
        }
    }

    /// Backprop the query gradient into the source-entity and relation rows.
    fn backward_compose(
        &mut self,
        src_id: usize,
        rel_id: usize,
        corrupt_head: bool,
        q: &[f32],
        dq: &[f32],
    ) {
        let we = self.ent.width;
        let wr = self.rel.width;
        let emb_range = self.hyper.embedding_range();
        // src/rel (ent, rel) and the gradient rows (g_ent, g_rel) live in
        // disjoint fields, so no row copies are needed to satisfy the
        // borrow checker — the step loop stays allocation-free.
        let src = self.ent.row(src_id);
        let rel = &self.rel.data[rel_id * wr..(rel_id + 1) * wr];
        let gsrc = self.g_ent.row_mut(src_id);
        let grel = self.g_rel.row_mut(rel_id);
        match self.method {
            Method::TransE => {
                // q = src ± r
                let sign = if corrupt_head { -1.0 } else { 1.0 };
                for k in 0..we {
                    gsrc[k] += dq[k];
                    grel[k] += sign * dq[k];
                }
            }
            Method::RotatE => {
                let dh = we / 2;
                let scale = std::f32::consts::PI / emb_range;
                let sign = if corrupt_head { -1.0 } else { 1.0 };
                for k in 0..dh {
                    let theta = rel[k] * scale * sign;
                    let (c, s) = (theta.cos(), theta.sin());
                    // q_re = sre·c − sim·s ; q_im = sre·s + sim·c
                    gsrc[k] += dq[k] * c + dq[dh + k] * s;
                    gsrc[dh + k] += -dq[k] * s + dq[dh + k] * c;
                    // dq/dθ' = (−q_im, q_re); θ' = sign·θ; θ = raw·π/range
                    let dtheta = -dq[k] * q[dh + k] + dq[dh + k] * q[k];
                    grel[k] += dtheta * sign * scale;
                }
            }
            Method::ComplEx => {
                let dh = we / 2;
                let (sre, sim) = src.split_at(dh);
                let (rre, rim) = rel.split_at(dh);
                if !corrupt_head {
                    // tail query: q = s∘r
                    for k in 0..dh {
                        gsrc[k] += dq[k] * rre[k] + dq[dh + k] * rim[k];
                        gsrc[dh + k] += -dq[k] * rim[k] + dq[dh + k] * rre[k];
                        grel[k] += dq[k] * sre[k] + dq[dh + k] * sim[k];
                        grel[dh + k] += -dq[k] * sim[k] + dq[dh + k] * sre[k];
                    }
                } else {
                    // head query: q_re = rre·sre + rim·sim ; q_im = rre·sim − rim·sre
                    for k in 0..dh {
                        gsrc[k] += dq[k] * rre[k] - dq[dh + k] * rim[k];
                        gsrc[dh + k] += dq[k] * rim[k] + dq[dh + k] * rre[k];
                        grel[k] += dq[k] * sre[k] + dq[dh + k] * sim[k];
                        grel[dh + k] += dq[k] * sim[k] - dq[dh + k] * sre[k];
                    }
                }
            }
        }
    }

    /// ComplEx L2 regularizer for row i of the batch (matches the artifact:
    /// mean over each gathered tensor, applied every row incl. padding).
    fn complex_reg_and_grads(&mut self, i: usize, batch: &Batch) -> f32 {
        let we = self.ent.width;
        let wr = self.rel.width;
        let b = batch.batch_size;
        let n = batch.negatives;
        let lam = self.hyper.complex_reg;
        let mut reg = 0.0f32;
        // h, t: mean over (B, We); r over (B, Wr); cand over (B, N, We)
        let ids = [(batch.pos[i * 3] as usize, b * we), (batch.pos[i * 3 + 2] as usize, b * we)];
        for (id, numel) in ids {
            let row = self.ent.row(id);
            let ss: f32 = row.iter().map(|x| x * x).sum();
            reg += lam * ss / numel as f32;
            let coef = 2.0 * lam / numel as f32;
            let g = self.g_ent.row_mut(id);
            for k in 0..we {
                g[k] += coef * row[k];
            }
        }
        let rid = batch.pos[i * 3 + 1] as usize;
        let ss: f32 = self.rel.row(rid).iter().map(|x| x * x).sum();
        reg += lam * ss / (b * wr) as f32;
        let coef = 2.0 * lam / (b * wr) as f32;
        let gr = self.g_rel.row_mut(rid);
        for k in 0..wr {
            gr[k] += coef * self.rel.data[rid * wr + k];
        }
        for j in 0..n {
            let cid = batch.neg[i * n + j] as usize;
            let row = self.ent.row(cid);
            let ss: f32 = row.iter().map(|x| x * x).sum();
            reg += lam * ss / (b * n * we) as f32;
            let coef = 2.0 * lam / (b * n * we) as f32;
            let gc = self.g_ent.row_mut(cid);
            for k in 0..we {
                gc[k] += coef * row[k];
            }
        }
        reg
    }

    /// Filtered ranks for an eval batch (mirror of the eval artifact).
    ///
    /// The O(rows) candidate scan per query is chunked across OS threads
    /// when the batch is large enough to amortize the spawns.  Each chunk
    /// produces integer (greater, equal) counts, so the merged ranks are
    /// **bit-identical** for every thread count — safe to auto-tune even
    /// under the federated drivers' determinism guarantees.
    pub fn eval_ranks(&self, eb: &EvalBatch) -> Vec<f32> {
        let e = self.ent.rows;
        let threads = self.eval_thread_budget(eb.len, e);
        let counts = if threads <= 1 {
            self.eval_counts(eb, 0, e)
        } else {
            let chunk = e.div_ceil(threads);
            let mut merged = vec![(0u32, 0u32); eb.len];
            std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(threads);
                let mut lo = 0usize;
                while lo < e {
                    let hi = (lo + chunk).min(e);
                    handles.push(s.spawn(move || self.eval_counts(eb, lo, hi)));
                    lo = hi;
                }
                for h in handles {
                    let part = h.join().expect("eval worker panicked");
                    for (acc, p) in merged.iter_mut().zip(part) {
                        acc.0 += p.0;
                        acc.1 += p.1;
                    }
                }
            });
            merged
        };
        counts.iter().map(|&(greater, equal)| 1.0 + greater as f32 + 0.5 * equal as f32).collect()
    }

    /// Per-query (greater, equal) counts against candidates `lo..hi`.
    fn eval_counts(&self, eb: &EvalBatch, lo: usize, hi: usize) -> Vec<(u32, u32)> {
        let e = self.ent.rows;
        let we = self.ent.width;
        let h = &self.hyper;
        let mut q = vec![0.0f32; we];
        let mut out = Vec::with_capacity(eb.len);
        for i in 0..eb.len {
            let src = eb.src[i] as usize;
            let rid = eb.rel[i] as usize;
            let truth = eb.truth[i] as usize;
            let ph = eb.pred_head[i] > 0.5;
            compose(self.method, self.ent.row(src), self.rel.row(rid), ph, h, &mut q);
            let true_good = self.logit(&q, self.ent.row(truth));
            let filt = &eb.filter[i * e..(i + 1) * e];
            let mut greater = 0u32;
            let mut equal = 0u32;
            for c in lo..hi {
                if c == truth || filt[c] > 0.5 {
                    continue;
                }
                let g = self.logit(&q, self.ent.row(c));
                if g > true_good {
                    greater += 1;
                } else if g == true_good {
                    equal += 1;
                }
            }
            out.push((greater, equal));
        }
        out
    }

    /// How many OS threads `eval_ranks` should fan out across.
    fn eval_thread_budget(&self, queries: usize, candidates: usize) -> usize {
        if queries.saturating_mul(candidates) < PAR_EVAL_MIN_WORK {
            return 1;
        }
        let hw = match self.eval_threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        };
        hw.min(candidates).max(1)
    }
}

/// The pre-sparse reference engine: identical gradient math (it shares the
/// wrapped model's kernel dispatch), but gradients scattered to dense
/// scratch and applied by the retained full-table [`Adam::update`] —
/// O(rows·width) per step, zero-grad drift included.  Kept as the
/// optimizer parity oracle and the `train_hot_path` bench baseline; the
/// *kernel* parity oracle is a model with [`KernelSet::scalar`] dispatch.
pub struct DenseOracle {
    pub model: NativeModel,
    ent_adam: Adam,
    rel_adam: Adam,
    g_ent: Vec<f32>,
    g_rel: Vec<f32>,
    step: u64,
}

impl DenseOracle {
    /// Wrap a freshly-initialized model (its `LazyAdam`/step state is
    /// ignored; the oracle owns dense optimizer state instead — do not mix
    /// `model.train_batch` calls with oracle steps).
    pub fn new(model: NativeModel) -> Self {
        let g_ent = vec![0.0; model.ent.len()];
        let g_rel = vec![0.0; model.rel.data.len()];
        let ent_adam = Adam::new(model.ent.len());
        let rel_adam = Adam::new(model.rel.data.len());
        Self { model, ent_adam, rel_adam, g_ent, g_rel, step: 0 }
    }

    /// One dense training step: the historical O(rows·width) path —
    /// zero the full scratch buffers, accumulate, full-table Adam.
    pub fn train_batch(&mut self, batch: &Batch) -> f32 {
        let loss = self.model.forward_backward(batch);
        self.g_ent.iter_mut().for_each(|g| *g = 0.0);
        self.g_rel.iter_mut().for_each(|g| *g = 0.0);
        self.model.g_ent.scatter_into(&mut self.g_ent);
        self.model.g_rel.scatter_into(&mut self.g_rel);
        self.step += 1;
        self.ent_adam.update(
            self.model.ent.as_mut_slice(),
            &self.g_ent,
            self.step,
            &self.model.hyper,
        );
        self.rel_adam.update(&mut self.model.rel.data, &self.g_rel, self.step, &self.model.hyper);
        loss
    }

    pub fn eval_ranks(&self, eb: &EvalBatch) -> Vec<f32> {
        self.model.eval_ranks(eb)
    }
}

/// Query composition — mirror of `model.compose` in python.
pub fn compose(
    method: Method,
    src: &[f32],
    rel: &[f32],
    predict_head: bool,
    h: &Hyper,
    out: &mut [f32],
) {
    match method {
        Method::TransE => {
            let s = if predict_head { -1.0 } else { 1.0 };
            for k in 0..src.len() {
                out[k] = src[k] + s * rel[k];
            }
        }
        Method::RotatE => {
            let dh = src.len() / 2;
            let scale = std::f32::consts::PI / h.embedding_range();
            let sign = if predict_head { -1.0 } else { 1.0 };
            for k in 0..dh {
                let theta = rel[k] * scale * sign;
                let (c, s) = (theta.cos(), theta.sin());
                out[k] = src[k] * c - src[dh + k] * s;
                out[dh + k] = src[k] * s + src[dh + k] * c;
            }
        }
        Method::ComplEx => {
            let dh = src.len() / 2;
            let (sre, sim) = src.split_at(dh);
            let (rre, rim) = rel.split_at(dh);
            if !predict_head {
                for k in 0..dh {
                    out[k] = sre[k] * rre[k] - sim[k] * rim[k];
                    out[dh + k] = sre[k] * rim[k] + sim[k] * rre[k];
                }
            } else {
                for k in 0..dh {
                    out[k] = rre[k] * sre[k] + rim[k] * sim[k];
                    out[dh + k] = rre[k] * sim[k] - rim[k] * sre[k];
                }
            }
        }
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
fn softplus(x: f32) -> f32 {
    // stable: log(1 + e^x)
    if x > 20.0 {
        x
    } else if x < -20.0 {
        x.exp()
    } else {
        (1.0 + x.exp()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Triple;
    use crate::data::dataset::BatchIter;
    use crate::util::prop::check;

    fn toy_batch(b: usize, n: usize, e: usize, r: usize, rng: &mut Rng) -> Batch {
        let triples: Vec<Triple> = (0..b)
            .map(|_| {
                Triple::new(
                    rng.u32_below(e as u32),
                    rng.u32_below(r as u32),
                    rng.u32_below(e as u32),
                )
            })
            .collect();
        let ents: Vec<u32> = (0..e as u32).collect();
        BatchIter::new(&triples, &ents, b, n, rng).next().unwrap()
    }

    fn model(method: Method, rng: &mut Rng) -> NativeModel {
        let hyper = Hyper { dim: 6, ..Default::default() };
        NativeModel::new(method, hyper, 32, 4, rng)
    }

    #[test]
    fn loss_decreases_all_methods() {
        for method in Method::ALL {
            let mut rng = Rng::new(42);
            let mut m = model(method, &mut rng);
            let batch = toy_batch(16, 8, 32, 4, &mut rng);
            let first = m.train_batch(&batch);
            let mut last = first;
            for _ in 0..60 {
                last = m.train_batch(&batch);
            }
            assert!(last < first, "{method:?}: {first} → {last}");
            assert!(last.is_finite());
        }
    }

    #[test]
    fn masked_batch_is_noop_for_distance_methods() {
        for method in [Method::TransE, Method::RotatE] {
            let mut rng = Rng::new(3);
            let mut m = model(method, &mut rng);
            let mut batch = toy_batch(8, 4, 32, 4, &mut rng);
            batch.mask.iter_mut().for_each(|x| *x = 0.0);
            let before = m.ent.to_vec();
            m.train_batch(&batch);
            assert_eq!(m.ent, before, "{method:?}");
        }
    }

    /// Finite-difference gradient check on the full loss, all methods.
    #[test]
    fn gradients_match_finite_difference() {
        for method in Method::ALL {
            check(&format!("fd_grad_{}", method.name()), 3, |rng| {
                // adv_temperature = 0 → uniform negative weights, so the
                // (detached) softmax does not perturb the finite difference.
                let hyper = Hyper {
                    dim: 4,
                    complex_reg: 1e-3,
                    adv_temperature: 0.0,
                    ..Default::default()
                };
                let mut m = NativeModel::new(method, hyper, 12, 3, rng);
                let batch = toy_batch(4, 3, 12, 3, rng);

                // analytic grads (scattered dense for coordinate probing)
                m.g_ent.clear();
                m.g_rel.clear();
                let _ = m.accumulate_grads(&batch);
                let ga = m.g_ent.to_dense(m.ent.rows);
                let gr = m.g_rel.to_dense(m.rel.rows);

                let loss_at = |m: &mut NativeModel| {
                    m.g_ent.clear();
                    m.g_rel.clear();
                    m.accumulate_grads(&batch)
                };

                let eps = 1e-3f32;
                // probe a handful of random coordinates in each table
                for _ in 0..6 {
                    let i = rng.usize_below(m.ent.len());
                    let orig = m.ent[i];
                    m.ent.as_mut_slice()[i] = orig + eps;
                    let lp = loss_at(&mut m);
                    m.ent.as_mut_slice()[i] = orig - eps;
                    let lm = loss_at(&mut m);
                    m.ent.as_mut_slice()[i] = orig;
                    let fd = (lp - lm) / (2.0 * eps);
                    assert!(
                        (fd - ga[i]).abs() < 2e-2 * (1.0 + fd.abs()),
                        "{method:?} ent[{i}]: fd {fd} vs {}",
                        ga[i]
                    );
                }
                for _ in 0..6 {
                    let i = rng.usize_below(m.rel.data.len());
                    let orig = m.rel.data[i];
                    m.rel.data[i] = orig + eps;
                    let lp = loss_at(&mut m);
                    m.rel.data[i] = orig - eps;
                    let lm = loss_at(&mut m);
                    m.rel.data[i] = orig;
                    let fd = (lp - lm) / (2.0 * eps);
                    assert!(
                        (fd - gr[i]).abs() < 2e-2 * (1.0 + fd.abs()),
                        "{method:?} rel[{i}]: fd {fd} vs {}",
                        gr[i]
                    );
                }
            });
        }
    }

    #[test]
    fn compose_head_tail_score_symmetry() {
        // score(h,r,t) via tail query vs via head query must agree
        for method in Method::ALL {
            let mut rng = Rng::new(9);
            let m = model(method, &mut rng);
            let we = m.ent.width;
            let mut qt = vec![0.0; we];
            let mut qh = vec![0.0; we];
            for _ in 0..20 {
                let h = rng.usize_below(32);
                let r = rng.usize_below(4);
                let t = rng.usize_below(32);
                compose(method, m.ent.row(h), m.rel.row(r), false, &m.hyper, &mut qt);
                compose(method, m.ent.row(t), m.rel.row(r), true, &m.hyper, &mut qh);
                let st = m.logit(&qt, m.ent.row(t));
                let sh = m.logit(&qh, m.ent.row(h));
                assert!((st - sh).abs() < 1e-3, "{method:?} {st} vs {sh}");
            }
        }
    }

    #[test]
    fn eval_rank_perfect_answer_is_one() {
        for method in Method::ALL {
            let mut rng = Rng::new(5);
            let mut m = model(method, &mut rng);
            // plant: entity 0's embedding = query composition of (src=1, r=0)
            let we = m.ent.width;
            let mut q = vec![0.0; we];
            compose(method, m.ent.row(1), m.rel.row(0), false, &m.hyper, &mut q);
            if method == Method::ComplEx {
                crate::linalg::scale(&mut q, 100.0);
            }
            m.ent.set_row(0, &q);
            let eb = EvalBatch {
                src: vec![1],
                rel: vec![0],
                truth: vec![0],
                pred_head: vec![0.0],
                filter: vec![0.0; 32],
                len: 1,
                eval_batch: 1,
            };
            let ranks = m.eval_ranks(&eb);
            assert!(ranks[0] <= 1.5, "{method:?}: rank {}", ranks[0]);
        }
    }

    #[test]
    fn eval_filter_forces_rank_one() {
        let mut rng = Rng::new(6);
        let m = model(Method::TransE, &mut rng);
        let mut filter = vec![1.0f32; 32];
        filter[7] = 0.0;
        let eb = EvalBatch {
            src: vec![3],
            rel: vec![1],
            truth: vec![7],
            pred_head: vec![1.0],
            filter,
            len: 1,
            eval_batch: 1,
        };
        assert_eq!(m.eval_ranks(&eb), vec![1.0]);
    }

    #[test]
    fn training_improves_planted_structure() {
        // tiny closed-world: relation 0 maps i → i+8; training should push
        // the true tail's rank toward the top.
        let mut rng = Rng::new(11);
        let hyper = Hyper { dim: 8, learning_rate: 3e-3, ..Default::default() };
        let mut m = NativeModel::new(Method::TransE, hyper, 16, 1, &mut rng);
        let triples: Vec<Triple> = (0..8).map(|i| Triple::new(i, 0, i + 8)).collect();
        let ents: Vec<u32> = (0..16).collect();
        let before = mean_rank(&m, &triples);
        for _ in 0..150 {
            let mut r2 = rng.fork(1);
            for batch in BatchIter::new(&triples, &ents, 8, 8, &mut r2) {
                m.train_batch(&batch);
            }
        }
        let after = mean_rank(&m, &triples);
        assert!(after < before, "mean rank {before} → {after}");
        assert!(after < 3.0, "after {after}");
    }

    #[test]
    fn sparse_grad_indexes_and_clears() {
        let mut g = SparseGrad::new(10, 3);
        assert!(g.is_empty());
        g.row_mut(7)[0] += 1.0;
        g.row_mut(2)[2] += 2.0;
        g.row_mut(7)[1] += 3.0; // second touch reuses the slot
        assert_eq!(g.len(), 2);
        let dense = g.to_dense(10);
        assert_eq!(dense[7 * 3], 1.0);
        assert_eq!(dense[7 * 3 + 1], 3.0);
        assert_eq!(dense[2 * 3 + 2], 2.0);
        assert_eq!(dense.iter().filter(|&&x| x != 0.0).count(), 3);
        // first-touch iteration order
        let order: Vec<u32> = g.iter().map(|(r, _)| r).collect();
        assert_eq!(order, vec![7, 2]);
        g.clear();
        assert!(g.is_empty());
        assert!(g.row_mut(7).iter().all(|&x| x == 0.0), "slot must reset");
    }

    #[test]
    fn train_touches_at_most_gathered_rows() {
        let mut rng = Rng::new(21);
        let mut m = model(Method::TransE, &mut rng);
        let (b, n) = (8, 4);
        let batch = toy_batch(b, n, 32, 4, &mut rng);
        m.train_batch(&batch);
        assert!(m.g_ent.len() <= 3 * b + b * n, "{} ent rows", m.g_ent.len());
        assert!(m.g_rel.len() <= b, "{} rel rows", m.g_rel.len());
    }

    /// A batch whose gathers cover every entity and relation row each
    /// step, so the lazy engine's gap-free path and the dense oracle must
    /// stay in lockstep (no zero-grad drift exists to diverge them).
    fn full_coverage_batch(b: usize, n: usize, e: usize, r: usize, rng: &mut Rng) -> Batch {
        assert!(b * n >= e && b >= r);
        let mut batch = toy_batch(b, n, e, r, rng);
        for i in 0..b {
            batch.pos[i * 3 + 1] = (i % r) as i32;
            for j in 0..n {
                batch.neg[i * n + j] = ((i * n + j) % e) as i32;
            }
        }
        batch
    }

    /// Satellite: sparse-vs-dense parity over 200+ steps for every method.
    #[test]
    fn sparse_engine_matches_dense_oracle() {
        for method in Method::ALL {
            let mut rng = Rng::new(77);
            let hyper = Hyper { dim: 6, ..Default::default() };
            let mut sparse = NativeModel::new(method, hyper, 24, 4, &mut rng);
            let mut dense = DenseOracle::new(sparse.clone());
            let mut brng = rng.fork(9);
            for step in 0..220 {
                let batch = full_coverage_batch(8, 8, 24, 4, &mut brng);
                let ls = sparse.train_batch(&batch);
                let ld = dense.train_batch(&batch);
                assert!(
                    (ls - ld).abs() <= 1e-5 * (1.0 + ld.abs()),
                    "{method:?} step {step}: loss {ls} vs {ld}"
                );
            }
            for (i, (a, b)) in sparse.ent.iter().zip(dense.model.ent.iter()).enumerate() {
                assert!((a - b).abs() < 1e-4, "{method:?} ent[{i}]: {a} vs {b}");
            }
            for (i, (a, b)) in sparse.rel.data.iter().zip(&dense.model.rel.data).enumerate() {
                assert!((a - b).abs() < 1e-4, "{method:?} rel[{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn eval_ranks_parallel_matches_sequential_bitwise() {
        // large enough that queries·candidates crosses PAR_EVAL_MIN_WORK
        let e = 40_000;
        let mut rng = Rng::new(31);
        let hyper = Hyper { dim: 4, ..Default::default() };
        let mut m = NativeModel::new(Method::TransE, hyper, e, 3, &mut rng);
        let len = 8;
        let eb = EvalBatch {
            src: (0..len as i32).collect(),
            rel: (0..len as i32).map(|i| i % 3).collect(),
            truth: (0..len as i32).map(|i| i + 100).collect(),
            pred_head: (0..len).map(|i| (i % 2) as f32).collect(),
            filter: vec![0.0; len * e],
            len,
            eval_batch: len,
        };
        m.eval_threads = 1;
        let seq = m.eval_ranks(&eb);
        for threads in [2, 3, 7] {
            m.eval_threads = threads;
            assert_eq!(m.eval_ranks(&eb), seq, "threads={threads}");
        }
        m.eval_threads = 0; // auto
        assert_eq!(m.eval_ranks(&eb), seq);
    }

    /// Satellite: model construction picks the monomorphized kernels for
    /// the common widths and the generic lane path elsewhere.
    #[test]
    fn model_selects_expected_kernels() {
        use super::super::kernels::{Kernel, KernelSet};
        let mut rng = Rng::new(1);
        let m = |method, dim| {
            NativeModel::new(method, Hyper { dim, ..Default::default() }, 8, 2, &mut rng).kernels
        };
        assert_eq!(m(Method::TransE, 64), KernelSet { full: Kernel::Fixed64, half: Kernel::Lanes });
        assert_eq!(
            m(Method::RotatE, 64),
            KernelSet { full: Kernel::Fixed128, half: Kernel::Fixed64 }
        );
        assert_eq!(
            m(Method::ComplEx, 128),
            KernelSet { full: Kernel::Fixed256, half: Kernel::Fixed128 }
        );
        assert_eq!(m(Method::TransE, 100), KernelSet { full: Kernel::Lanes, half: Kernel::Lanes });
    }

    /// Tentpole parity: the width-dispatched dedup pass must match the
    /// retained scalar oracle at the existing 1e-4 tolerance — including
    /// widths not divisible by the lane count (d=100) and an odd RotatE
    /// half-width (d=25), plus the monomorphized fixed spans (d=64/128).
    #[test]
    fn dispatched_kernels_match_scalar_oracle() {
        for method in Method::ALL {
            for dim in [4usize, 25, 64, 100, 128] {
                let mut rng = Rng::new(dim as u64);
                let hyper = Hyper { dim, ..Default::default() };
                let mut fast = NativeModel::new(method, hyper, 32, 4, &mut rng);
                let mut scalar = fast.clone();
                scalar.kernels = KernelSet::scalar();
                assert!(!fast.kernels.is_scalar());

                let mut brng = rng.fork(3);
                for step in 0..5 {
                    let batch = toy_batch(8, 6, 32, 4, &mut brng);
                    let lf = fast.forward_backward(&batch);
                    let ls = scalar.forward_backward(&batch);
                    assert!(
                        (lf - ls).abs() <= 1e-5 * (1.0 + ls.abs()),
                        "{method:?} d={dim} step {step}: loss {lf} vs {ls}"
                    );
                    let (ge_f, gr_f) = fast.grads_dense();
                    let (ge_s, gr_s) = scalar.grads_dense();
                    for (i, (a, b)) in ge_f.iter().zip(&ge_s).enumerate() {
                        assert!(
                            (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                            "{method:?} d={dim} g_ent[{i}]: {a} vs {b}"
                        );
                    }
                    for (i, (a, b)) in gr_f.iter().zip(&gr_s).enumerate() {
                        assert!(
                            (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                            "{method:?} d={dim} g_rel[{i}]: {a} vs {b}"
                        );
                    }
                    // advance with the dispatched engine and re-sync the
                    // oracle, so every step compares gradients on evolved
                    // tables without compounding reassociation drift
                    // through Adam's normalization
                    fast.train_batch(&batch);
                    scalar = fast.clone();
                    scalar.kernels = KernelSet::scalar();
                }
            }
        }
    }

    /// Satellite: a duplicate-heavy negatives batch — the dedup pass must
    /// leave loss and gradients identical to the per-occurrence scalar
    /// reference (duplicates share bitwise-equal terms, so coalescing
    /// only re-associates sums).
    #[test]
    fn duplicate_heavy_negatives_dedup_is_exact() {
        for method in Method::ALL {
            let mut rng = Rng::new(13);
            let mut fast = model(method, &mut rng);
            let mut scalar = fast.clone();
            scalar.kernels = KernelSet::scalar();
            let (b, n) = (8usize, 16usize);
            let mut brng = rng.fork(7);
            let mut batch = toy_batch(b, n, 32, 4, &mut brng);
            // draw all negatives from 3 entities → ~5 duplicates per id
            for i in 0..b {
                for j in 0..n {
                    batch.neg[i * n + j] = ((i + j) % 3) as i32;
                }
            }
            let lf = fast.forward_backward(&batch);
            let ls = scalar.forward_backward(&batch);
            assert!(
                (lf - ls).abs() <= 1e-5 * (1.0 + ls.abs()),
                "{method:?}: loss {lf} vs {ls}"
            );
            let (ge_f, gr_f) = fast.grads_dense();
            let (ge_s, gr_s) = scalar.grads_dense();
            for (i, (a, b)) in ge_f.iter().zip(&ge_s).enumerate() {
                assert!(
                    (a - b).abs() < 1e-5 * (1.0 + b.abs()),
                    "{method:?} g_ent[{i}]: {a} vs {b}"
                );
            }
            for (i, (a, b)) in gr_f.iter().zip(&gr_s).enumerate() {
                assert!(
                    (a - b).abs() < 1e-5 * (1.0 + b.abs()),
                    "{method:?} g_rel[{i}]: {a} vs {b}"
                );
            }
            // each unique candidate registered once: ≤ 3·b + 3 entity rows
            assert!(fast.g_ent.len() <= 3 * b + 3, "{} rows", fast.g_ent.len());
        }
    }

    fn mean_rank(m: &NativeModel, triples: &[Triple]) -> f32 {
        let e = m.ent.rows;
        let eb = EvalBatch {
            src: triples.iter().map(|t| t.h as i32).collect(),
            rel: triples.iter().map(|t| t.r as i32).collect(),
            truth: triples.iter().map(|t| t.t as i32).collect(),
            pred_head: vec![0.0; triples.len()],
            filter: vec![0.0; triples.len() * e],
            len: triples.len(),
            eval_batch: triples.len(),
        };
        let ranks = m.eval_ranks(&eb);
        ranks.iter().sum::<f32>() / ranks.len() as f32
    }
}
