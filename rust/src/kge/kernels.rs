//! Width-dispatched, lane-friendly training kernels for the native engine.
//!
//! The per-step cost of `kge::native` is the batch·negatives gather math —
//! `logit`, the candidate backward, and the compose backward, executed
//! `B·(N+1)` times per step over `W`-float rows.  This module rebuilds
//! those inner loops as **fixed-lane accumulator** kernels that stable
//! Rust autovectorizes: every reduction accumulates into a `[f32; LANES]`
//! block (one partial sum per lane, horizontally combined once at the
//! end), every elementwise pass is written over pre-bounded slices so the
//! compiler can emit packed instructions without bounds checks.
//!
//! **Dispatch** happens once, at model/trainer construction
//! ([`KernelSet::select`]): the common spans (64/128/256 floats — d=64/128
//! entity rows and RotatE/ComplEx's re‖im halves) get monomorphized
//! copies with a compile-time width (`const W`), which lets LLVM fully
//! unroll and keep the whole row in vector registers; every other span
//! takes the `Lanes` path — the same lane-blocked loop with a runtime
//! bound plus a scalar remainder, so widths not divisible by [`LANES`]
//! (d=100, odd RotatE half-width, tiny test dims) are exact.
//!
//! The element-at-a-time loops these kernels replace are **retained** in
//! `kge::native` as the scalar reference oracle (`Kernel::Scalar`, same
//! pattern as `DenseOracle`): parity tests drive both engines over the
//! same batches and require agreement at the usual 1e-4 tolerance — the
//! only numeric difference is the reduction order of the lane partials.
//!
//! Lane layout: [`LANES`] = 8 f32 partials.  On baseline x86-64 that is
//! two SSE2 vectors per accumulator block; with wider ISAs the same code
//! compiles to a single AVX register.  The horizontal combine ([`hsum`])
//! is a fixed-shape pairwise tree so results do not depend on the ISA the
//! autovectorizer picked.

use super::Method;

/// f32 partial sums per accumulator block.
pub const LANES: usize = 8;

/// RotatE modulus epsilon (shared with the scalar reference loops).
pub const MOD_EPS: f32 = 1e-12;

/// One inner-loop implementation, selected per span at construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Element-at-a-time reference loops (the retained oracle; lives in
    /// `kge::native`, never dispatched through this module's fast paths).
    Scalar,
    /// Monomorphized 64-float span.
    Fixed64,
    /// Monomorphized 128-float span.
    Fixed128,
    /// Monomorphized 256-float span.
    Fixed256,
    /// Lane-blocked loop with runtime span + scalar remainder (any width).
    Lanes,
}

impl Kernel {
    /// Width dispatch for a `span`-float inner loop.
    pub fn select(span: usize) -> Kernel {
        match span {
            64 => Kernel::Fixed64,
            128 => Kernel::Fixed128,
            256 => Kernel::Fixed256,
            _ => Kernel::Lanes,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Fixed64 => "fixed64",
            Kernel::Fixed128 => "fixed128",
            Kernel::Fixed256 => "fixed256",
            Kernel::Lanes => "lanes",
        }
    }
}

/// The two spans one model needs, chosen once at construction: `full` for
/// whole-row loops (TransE L1, ComplEx dot/axpy), `half` for re‖im
/// half-row loops (RotatE modulus, ComplEx compose).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelSet {
    pub full: Kernel,
    pub half: Kernel,
}

impl KernelSet {
    /// Dispatch for an entity row of `entity_width` floats.
    pub fn select(entity_width: usize) -> Self {
        Self { full: Kernel::select(entity_width), half: Kernel::select(entity_width / 2) }
    }

    /// The retained element-at-a-time reference (parity oracle).
    pub fn scalar() -> Self {
        Self { full: Kernel::Scalar, half: Kernel::Scalar }
    }

    pub fn is_scalar(self) -> bool {
        self.full == Kernel::Scalar
    }

    /// `logit(q, cand)`: γ − dist (TransE/RotatE) or dot (ComplEx).
    #[inline]
    pub fn logit(self, method: Method, gamma: f32, q: &[f32], cand: &[f32]) -> f32 {
        match method {
            Method::TransE => gamma - l1_dist_k(self.full, q, cand),
            Method::RotatE => gamma - rot_dist_k(self.half, q, cand),
            Method::ComplEx => dot_k(self.full, q, cand),
        }
    }

    /// d logit/d q and d logit/d cand scaled by `g`, accumulated into `dq`
    /// and the candidate's gradient row `gc`.
    #[inline]
    pub fn bwd_candidate(
        self,
        method: Method,
        q: &[f32],
        cand: &[f32],
        g: f32,
        dq: &mut [f32],
        gc: &mut [f32],
    ) {
        match method {
            Method::TransE => transe_bwd_k(self.full, q, cand, g, dq, gc),
            Method::RotatE => rotate_bwd_k(self.half, q, cand, g, dq, gc),
            Method::ComplEx => complex_bwd_k(self.full, q, cand, g, dq, gc),
        }
    }
}

/// Dispatch a `const W`-generic kernel: monomorphized for the fixed spans,
/// `W = 0` (runtime span) otherwise.
macro_rules! widths {
    ($k:expr, $f:ident($($a:expr),* $(,)?)) => {
        match $k {
            Kernel::Fixed64 => $f::<64>($($a),*),
            Kernel::Fixed128 => $f::<128>($($a),*),
            Kernel::Fixed256 => $f::<256>($($a),*),
            Kernel::Scalar | Kernel::Lanes => $f::<0>($($a),*),
        }
    };
}

/// Fixed-shape pairwise combine of the lane partials, independent of the
/// vector ISA the autovectorizer picked.
#[inline(always)]
fn hsum(acc: &[f32; LANES]) -> f32 {
    let a = acc[0] + acc[4];
    let b = acc[1] + acc[5];
    let c = acc[2] + acc[6];
    let d = acc[3] + acc[7];
    (a + c) + (b + d)
}

// ---------------------------------------------------------------------------
// reductions (lane accumulators + horizontal combine)
// ---------------------------------------------------------------------------

#[inline(always)]
fn l1_dist<const W: usize>(q: &[f32], c: &[f32]) -> f32 {
    let n = if W != 0 { W } else { q.len() };
    let (q, c) = (&q[..n], &c[..n]);
    let mut acc = [0.0f32; LANES];
    let mut qc = q.chunks_exact(LANES);
    let mut cc = c.chunks_exact(LANES);
    for (qa, ca) in (&mut qc).zip(&mut cc) {
        for l in 0..LANES {
            acc[l] += (qa[l] - ca[l]).abs();
        }
    }
    let mut d = hsum(&acc);
    for (a, b) in qc.remainder().iter().zip(cc.remainder()) {
        d += (a - b).abs();
    }
    d
}

#[inline]
pub fn l1_dist_k(k: Kernel, q: &[f32], c: &[f32]) -> f32 {
    widths!(k, l1_dist(q, c))
}

#[inline(always)]
fn dot<const W: usize>(a: &[f32], b: &[f32]) -> f32 {
    let n = if W != 0 { W } else { a.len() };
    let (a, b) = (&a[..n], &b[..n]);
    let mut acc = [0.0f32; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (aa, ba) in (&mut ac).zip(&mut bc) {
        for l in 0..LANES {
            acc[l] += aa[l] * ba[l];
        }
    }
    let mut d = hsum(&acc);
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        d += x * y;
    }
    d
}

#[inline]
pub fn dot_k(k: Kernel, a: &[f32], b: &[f32]) -> f32 {
    widths!(k, dot(a, b))
}

/// Σ x², lane-blocked.
#[inline]
pub fn sumsq_k(k: Kernel, a: &[f32]) -> f32 {
    widths!(k, dot(a, a))
}

/// RotatE modulus distance over re‖im halves: Σ √(Δre² + Δim² + ε).
/// `DH` is the half span; `q`/`c` are full `2·dh` rows.
#[inline(always)]
fn rot_dist<const DH: usize>(q: &[f32], c: &[f32]) -> f32 {
    let dh = if DH != 0 { DH } else { q.len() / 2 };
    let (qre, qim) = q.split_at(dh);
    let (cre, cim) = c.split_at(dh);
    let (qre, qim) = (&qre[..dh], &qim[..dh]);
    let (cre, cim) = (&cre[..dh], &cim[..dh]);
    let mut acc = [0.0f32; LANES];
    let whole = dh - dh % LANES;
    let mut k = 0;
    while k < whole {
        for l in 0..LANES {
            let dre = qre[k + l] - cre[k + l];
            let dim = qim[k + l] - cim[k + l];
            acc[l] += (dre * dre + dim * dim + MOD_EPS).sqrt();
        }
        k += LANES;
    }
    let mut d = hsum(&acc);
    while k < dh {
        let dre = qre[k] - cre[k];
        let dim = qim[k] - cim[k];
        d += (dre * dre + dim * dim + MOD_EPS).sqrt();
        k += 1;
    }
    d
}

#[inline]
pub fn rot_dist_k(k: Kernel, q: &[f32], c: &[f32]) -> f32 {
    widths!(k, rot_dist(q, c))
}

// ---------------------------------------------------------------------------
// candidate backward (elementwise, packed)
// ---------------------------------------------------------------------------

#[inline(always)]
fn transe_bwd<const W: usize>(q: &[f32], c: &[f32], g: f32, dq: &mut [f32], gc: &mut [f32]) {
    let n = if W != 0 { W } else { q.len() };
    let (q, c) = (&q[..n], &c[..n]);
    let (dq, gc) = (&mut dq[..n], &mut gc[..n]);
    for k in 0..n {
        let s = (q[k] - c[k]).signum();
        dq[k] -= g * s;
        gc[k] += g * s;
    }
}

#[inline]
pub fn transe_bwd_k(k: Kernel, q: &[f32], c: &[f32], g: f32, dq: &mut [f32], gc: &mut [f32]) {
    widths!(k, transe_bwd(q, c, g, dq, gc))
}

#[inline(always)]
fn rotate_bwd<const DH: usize>(q: &[f32], c: &[f32], g: f32, dq: &mut [f32], gc: &mut [f32]) {
    let dh = if DH != 0 { DH } else { q.len() / 2 };
    let (qre, qim) = q.split_at(dh);
    let (cre, cim) = c.split_at(dh);
    let (dqre, dqim) = dq.split_at_mut(dh);
    let (gcre, gcim) = gc.split_at_mut(dh);
    let (qre, qim) = (&qre[..dh], &qim[..dh]);
    let (cre, cim) = (&cre[..dh], &cim[..dh]);
    let (dqre, dqim) = (&mut dqre[..dh], &mut dqim[..dh]);
    let (gcre, gcim) = (&mut gcre[..dh], &mut gcim[..dh]);
    for k in 0..dh {
        let dre = qre[k] - cre[k];
        let dim = qim[k] - cim[k];
        let m = (dre * dre + dim * dim + MOD_EPS).sqrt();
        let (ure, uim) = (dre / m, dim / m);
        dqre[k] -= g * ure;
        dqim[k] -= g * uim;
        gcre[k] += g * ure;
        gcim[k] += g * uim;
    }
}

#[inline]
pub fn rotate_bwd_k(k: Kernel, q: &[f32], c: &[f32], g: f32, dq: &mut [f32], gc: &mut [f32]) {
    widths!(k, rotate_bwd(q, c, g, dq, gc))
}

#[inline(always)]
fn complex_bwd<const W: usize>(q: &[f32], c: &[f32], g: f32, dq: &mut [f32], gc: &mut [f32]) {
    let n = if W != 0 { W } else { q.len() };
    let (q, c) = (&q[..n], &c[..n]);
    let (dq, gc) = (&mut dq[..n], &mut gc[..n]);
    for k in 0..n {
        dq[k] += g * c[k];
        gc[k] += g * q[k];
    }
}

#[inline]
pub fn complex_bwd_k(k: Kernel, q: &[f32], c: &[f32], g: f32, dq: &mut [f32], gc: &mut [f32]) {
    widths!(k, complex_bwd(q, c, g, dq, gc))
}

/// `y += a·x`, lane-blocked (ComplEx regularizer rows).
#[inline(always)]
fn axpy<const W: usize>(a: f32, x: &[f32], y: &mut [f32]) {
    let n = if W != 0 { W } else { x.len() };
    let (x, y) = (&x[..n], &mut y[..n]);
    for k in 0..n {
        y[k] += a * x[k];
    }
}

#[inline]
pub fn axpy_k(k: Kernel, a: f32, x: &[f32], y: &mut [f32]) {
    widths!(k, axpy(a, x, y))
}

// ---------------------------------------------------------------------------
// compose forward + backward
// ---------------------------------------------------------------------------

/// TransE: `out = src + sign·rel`.
#[inline(always)]
fn transe_compose<const W: usize>(src: &[f32], rel: &[f32], sign: f32, out: &mut [f32]) {
    let n = if W != 0 { W } else { src.len() };
    let (src, rel, out) = (&src[..n], &rel[..n], &mut out[..n]);
    for k in 0..n {
        out[k] = src[k] + sign * rel[k];
    }
}

#[inline]
pub fn transe_compose_k(k: Kernel, src: &[f32], rel: &[f32], sign: f32, out: &mut [f32]) {
    widths!(k, transe_compose(src, rel, sign, out))
}

/// TransE backward through compose: `gsrc += dq; grel += sign·dq`.
#[inline(always)]
fn transe_bwd_compose<const W: usize>(dq: &[f32], sign: f32, gsrc: &mut [f32], grel: &mut [f32]) {
    let n = if W != 0 { W } else { dq.len() };
    let (dq, gsrc, grel) = (&dq[..n], &mut gsrc[..n], &mut grel[..n]);
    for k in 0..n {
        gsrc[k] += dq[k];
        grel[k] += sign * dq[k];
    }
}

#[inline]
pub fn transe_bwd_compose_k(k: Kernel, dq: &[f32], sign: f32, gsrc: &mut [f32], grel: &mut [f32]) {
    widths!(k, transe_bwd_compose(dq, sign, gsrc, grel))
}

/// RotatE compose, **caching the per-element rotation** (cos θ, sin θ) so
/// the backward pass needs no trigonometry at all.  θ is trig-bound, not
/// width-bound, so this takes no dispatch tag.
#[inline]
pub fn rotate_compose_cached(
    src: &[f32],
    rel: &[f32],
    scale: f32,
    sign: f32,
    cos_c: &mut [f32],
    sin_c: &mut [f32],
    out: &mut [f32],
) {
    let dh = rel.len();
    let (sre, sim) = src.split_at(dh);
    let (ore, oim) = out.split_at_mut(dh);
    for k in 0..dh {
        let theta = rel[k] * scale * sign;
        let (c, s) = (theta.cos(), theta.sin());
        cos_c[k] = c;
        sin_c[k] = s;
        ore[k] = sre[k] * c - sim[k] * s;
        oim[k] = sre[k] * s + sim[k] * c;
    }
}

/// RotatE backward through compose off the cached rotation — pure packed
/// multiply/adds (the scalar reference recomputes cos/sin here).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn rotate_bwd_compose<const DH: usize>(
    q: &[f32],
    dq: &[f32],
    cos_c: &[f32],
    sin_c: &[f32],
    sign: f32,
    scale: f32,
    gsrc: &mut [f32],
    grel: &mut [f32],
) {
    let dh = if DH != 0 { DH } else { q.len() / 2 };
    let (qre, qim) = q.split_at(dh);
    let (dqre, dqim) = dq.split_at(dh);
    let (gsre, gsim) = gsrc.split_at_mut(dh);
    let (qre, qim) = (&qre[..dh], &qim[..dh]);
    let (dqre, dqim) = (&dqre[..dh], &dqim[..dh]);
    let (cos_c, sin_c) = (&cos_c[..dh], &sin_c[..dh]);
    let (gsre, gsim) = (&mut gsre[..dh], &mut gsim[..dh]);
    let grel = &mut grel[..dh];
    for k in 0..dh {
        let (c, s) = (cos_c[k], sin_c[k]);
        gsre[k] += dqre[k] * c + dqim[k] * s;
        gsim[k] += -dqre[k] * s + dqim[k] * c;
        let dtheta = -dqre[k] * qim[k] + dqim[k] * qre[k];
        grel[k] += dtheta * sign * scale;
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
pub fn rotate_bwd_compose_k(
    k: Kernel,
    q: &[f32],
    dq: &[f32],
    cos_c: &[f32],
    sin_c: &[f32],
    sign: f32,
    scale: f32,
    gsrc: &mut [f32],
    grel: &mut [f32],
) {
    widths!(k, rotate_bwd_compose(q, dq, cos_c, sin_c, sign, scale, gsrc, grel))
}

/// ComplEx compose over re‖im halves (Hadamard product, conjugated for
/// head queries).
#[inline(always)]
fn complex_compose<const DH: usize>(
    src: &[f32],
    rel: &[f32],
    predict_head: bool,
    out: &mut [f32],
) {
    let dh = if DH != 0 { DH } else { src.len() / 2 };
    let (sre, sim) = src.split_at(dh);
    let (rre, rim) = rel.split_at(dh);
    let (ore, oim) = out.split_at_mut(dh);
    let (sre, sim) = (&sre[..dh], &sim[..dh]);
    let (rre, rim) = (&rre[..dh], &rim[..dh]);
    let (ore, oim) = (&mut ore[..dh], &mut oim[..dh]);
    if !predict_head {
        for k in 0..dh {
            ore[k] = sre[k] * rre[k] - sim[k] * rim[k];
            oim[k] = sre[k] * rim[k] + sim[k] * rre[k];
        }
    } else {
        for k in 0..dh {
            ore[k] = rre[k] * sre[k] + rim[k] * sim[k];
            oim[k] = rre[k] * sim[k] - rim[k] * sre[k];
        }
    }
}

#[inline]
pub fn complex_compose_k(k: Kernel, src: &[f32], rel: &[f32], predict_head: bool, out: &mut [f32]) {
    widths!(k, complex_compose(src, rel, predict_head, out))
}

/// ComplEx backward through compose into the source and relation rows.
#[inline(always)]
fn complex_bwd_compose<const DH: usize>(
    src: &[f32],
    rel: &[f32],
    predict_head: bool,
    dq: &[f32],
    gsrc: &mut [f32],
    grel: &mut [f32],
) {
    let dh = if DH != 0 { DH } else { src.len() / 2 };
    let (sre, sim) = src.split_at(dh);
    let (rre, rim) = rel.split_at(dh);
    let (dqre, dqim) = dq.split_at(dh);
    let (gsre, gsim) = gsrc.split_at_mut(dh);
    let (grre, grim) = grel.split_at_mut(dh);
    let (sre, sim) = (&sre[..dh], &sim[..dh]);
    let (rre, rim) = (&rre[..dh], &rim[..dh]);
    let (dqre, dqim) = (&dqre[..dh], &dqim[..dh]);
    let (gsre, gsim) = (&mut gsre[..dh], &mut gsim[..dh]);
    let (grre, grim) = (&mut grre[..dh], &mut grim[..dh]);
    if !predict_head {
        for k in 0..dh {
            gsre[k] += dqre[k] * rre[k] + dqim[k] * rim[k];
            gsim[k] += -dqre[k] * rim[k] + dqim[k] * rre[k];
            grre[k] += dqre[k] * sre[k] + dqim[k] * sim[k];
            grim[k] += -dqre[k] * sim[k] + dqim[k] * sre[k];
        }
    } else {
        for k in 0..dh {
            gsre[k] += dqre[k] * rre[k] - dqim[k] * rim[k];
            gsim[k] += dqre[k] * rim[k] + dqim[k] * rre[k];
            grre[k] += dqre[k] * sre[k] + dqim[k] * sim[k];
            grim[k] += dqre[k] * sim[k] - dqim[k] * sre[k];
        }
    }
}

#[inline]
pub fn complex_bwd_compose_k(
    k: Kernel,
    src: &[f32],
    rel: &[f32],
    predict_head: bool,
    dq: &[f32],
    gsrc: &mut [f32],
    grel: &mut [f32],
) {
    widths!(k, complex_bwd_compose(src, rel, predict_head, dq, gsrc, grel))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        (a, b)
    }

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    #[test]
    fn selection_table() {
        assert_eq!(Kernel::select(64), Kernel::Fixed64);
        assert_eq!(Kernel::select(128), Kernel::Fixed128);
        assert_eq!(Kernel::select(256), Kernel::Fixed256);
        assert_eq!(Kernel::select(100), Kernel::Lanes);
        assert_eq!(Kernel::select(6), Kernel::Lanes);
        let ks = KernelSet::select(128);
        assert_eq!(ks, KernelSet { full: Kernel::Fixed128, half: Kernel::Fixed64 });
        assert!(KernelSet::scalar().is_scalar());
        assert!(!ks.is_scalar());
    }

    #[test]
    fn reductions_match_reference_at_every_span() {
        // fixed spans, lane-multiples, and remainder-carrying odd spans
        for n in [3usize, 8, 25, 50, 64, 100, 128, 200, 256] {
            let (a, b) = vecs(n, n as u64);
            let k = Kernel::select(n);
            let l1_ref: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
            assert!(close(l1_dist_k(k, &a, &b), l1_ref, 1e-5), "l1 n={n}");
            assert!(close(l1_dist_k(Kernel::Lanes, &a, &b), l1_ref, 1e-5));
            let dot_ref: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!(close(dot_k(k, &a, &b), dot_ref, 1e-5), "dot n={n}");
            assert!(close(sumsq_k(k, &a), a.iter().map(|x| x * x).sum(), 1e-5));
        }
        for dh in [3usize, 25, 64, 100, 128] {
            let (a, b) = vecs(2 * dh, dh as u64);
            let k = Kernel::select(dh);
            let mut d_ref = 0.0f32;
            for i in 0..dh {
                let dre = a[i] - b[i];
                let dim = a[dh + i] - b[dh + i];
                d_ref += (dre * dre + dim * dim + MOD_EPS).sqrt();
            }
            assert!(close(rot_dist_k(k, &a, &b), d_ref, 1e-5), "rot dh={dh}");
        }
    }

    #[test]
    fn elementwise_kernels_match_reference() {
        for n in [25usize, 64, 100] {
            let (q, c) = vecs(n, 7 + n as u64);
            let g = 0.37f32;
            let k = Kernel::select(n);

            let mut dq = vec![0.1f32; n];
            let mut gc = vec![0.2f32; n];
            transe_bwd_k(k, &q, &c, g, &mut dq, &mut gc);
            for i in 0..n {
                let s = (q[i] - c[i]).signum();
                assert!(close(dq[i], 0.1 - g * s, 1e-6));
                assert!(close(gc[i], 0.2 + g * s, 1e-6));
            }

            let mut y = vec![0.5f32; n];
            axpy_k(k, 2.0, &q, &mut y);
            for i in 0..n {
                assert!(close(y[i], 0.5 + 2.0 * q[i], 1e-6));
            }

            let mut out = vec![0.0f32; n];
            transe_compose_k(k, &q, &c, -1.0, &mut out);
            for i in 0..n {
                assert!(close(out[i], q[i] - c[i], 1e-6));
            }
        }
    }

    #[test]
    fn rotate_cached_compose_matches_uncached_math() {
        let dh = 25; // odd half-width → Lanes path downstream
        let (src, rel_full) = vecs(2 * dh, 11);
        let rel = &rel_full[..dh];
        let (scale, sign) = (0.17f32, -1.0f32);
        let mut cos_c = vec![0.0f32; dh];
        let mut sin_c = vec![0.0f32; dh];
        let mut out = vec![0.0f32; 2 * dh];
        rotate_compose_cached(&src, rel, scale, sign, &mut cos_c, &mut sin_c, &mut out);
        for k in 0..dh {
            let theta = rel[k] * scale * sign;
            assert_eq!(cos_c[k], theta.cos());
            assert_eq!(sin_c[k], theta.sin());
            let want_re = src[k] * theta.cos() - src[dh + k] * theta.sin();
            let want_im = src[k] * theta.sin() + src[dh + k] * theta.cos();
            assert!(close(out[k], want_re, 1e-6));
            assert!(close(out[dh + k], want_im, 1e-6));
        }
    }
}
