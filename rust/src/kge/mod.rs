//! Knowledge-graph embedding methods: shared definitions plus a pure-Rust
//! reference implementation (`native`).
//!
//! The production path executes the AOT-compiled JAX/Pallas artifacts via
//! `crate::runtime`; the native implementation exists to (a) cross-check the
//! artifact numerics step-for-step, (b) run artifact-free unit/property
//! tests of the federated protocols, and (c) host the SVD+ baseline's
//! low-rank-constrained local training (Appendix VI-B).

pub mod native;

use crate::util::rng::Rng;

/// The three KGE methods from the paper's experiments (§IV-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    TransE,
    RotatE,
    ComplEx,
}

impl Method {
    pub const ALL: [Method; 3] = [Method::TransE, Method::RotatE, Method::ComplEx];

    pub fn parse(s: &str) -> anyhow::Result<Method> {
        match s.to_ascii_lowercase().as_str() {
            "transe" => Ok(Method::TransE),
            "rotate" => Ok(Method::RotatE),
            "complex" => Ok(Method::ComplEx),
            other => anyhow::bail!("unknown KGE method '{other}' (transe|rotate|complex)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::TransE => "transe",
            Method::RotatE => "rotate",
            Method::ComplEx => "complex",
        }
    }

    /// Entity-table row width at base dimension `dim` (complex methods store
    /// re‖im concatenated).
    pub fn entity_width(&self, dim: usize) -> usize {
        match self {
            Method::TransE => dim,
            Method::RotatE | Method::ComplEx => 2 * dim,
        }
    }

    pub fn relation_width(&self, dim: usize) -> usize {
        match self {
            Method::TransE | Method::RotatE => dim,
            Method::ComplEx => 2 * dim,
        }
    }

    /// Distance methods rank lower-is-better; their logits are γ − dist.
    pub fn is_distance(&self) -> bool {
        matches!(self, Method::TransE | Method::RotatE)
    }
}

/// Hyper-parameters (mirror of `python/compile/config.py`; the runtime
/// asserts the manifest agrees with these at load time).
#[derive(Clone, Debug)]
pub struct Hyper {
    pub dim: usize,
    pub gamma: f32,
    pub epsilon: f32,
    pub adv_temperature: f32,
    pub learning_rate: f32,
    pub adam_beta1: f32,
    pub adam_beta2: f32,
    pub adam_eps: f32,
    pub complex_reg: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            dim: 64,
            gamma: 8.0,
            epsilon: 2.0,
            adv_temperature: 1.0,
            learning_rate: 1e-3,
            adam_beta1: 0.9,
            adam_beta2: 0.999,
            adam_eps: 1e-8,
            complex_reg: 1e-5,
        }
    }
}

impl Hyper {
    pub fn embedding_range(&self) -> f32 {
        (self.gamma + self.epsilon) / self.dim as f32
    }
}

/// A dense row-major embedding table.
#[derive(Clone, Debug, PartialEq)]
pub struct Table {
    pub rows: usize,
    pub width: usize,
    pub data: Vec<f32>,
}

impl Table {
    pub fn zeros(rows: usize, width: usize) -> Self {
        Self { rows, width, data: vec![0.0; rows * width] }
    }

    /// Uniform init in ±(γ+ε)/D, the RotatE-lineage convention used by FedE.
    pub fn init_uniform(rows: usize, width: usize, range: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * width).map(|_| rng.uniform(-range, range)).collect();
        Self { rows, width, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.width..(i + 1) * self.width]
    }

    pub fn set_row(&mut self, i: usize, v: &[f32]) {
        self.row_mut(i).copy_from_slice(v);
    }
}

/// Dense Adam state for one table (torch semantics, matching the artifact).
#[derive(Clone, Debug)]
pub struct Adam {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl Adam {
    pub fn new(len: usize) -> Self {
        Self { m: vec![0.0; len], v: vec![0.0; len] }
    }

    /// One dense update. `step` is 1-based.
    pub fn update(&mut self, p: &mut [f32], g: &[f32], step: u64, h: &Hyper) {
        let b1 = h.adam_beta1;
        let b2 = h.adam_beta2;
        let bc1 = 1.0 - b1.powi(step as i32);
        let bc2 = 1.0 - b2.powi(step as i32);
        for i in 0..p.len() {
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g[i];
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g[i] * g[i];
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            p[i] -= h.learning_rate * mh / (vh.sqrt() + h.adam_eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths() {
        assert_eq!(Method::TransE.entity_width(64), 64);
        assert_eq!(Method::RotatE.entity_width(64), 128);
        assert_eq!(Method::RotatE.relation_width(64), 64);
        assert_eq!(Method::ComplEx.relation_width(64), 128);
    }

    #[test]
    fn parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn table_init_in_range() {
        let mut rng = Rng::new(1);
        let t = Table::init_uniform(10, 8, 0.5, &mut rng);
        assert!(t.data.iter().all(|&x| (-0.5..0.5).contains(&x)));
        assert_eq!(t.row(3).len(), 8);
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        let h = Hyper::default();
        let mut a = Adam::new(4);
        let mut p = vec![0.0f32; 4];
        let g = vec![0.5f32, -0.5, 2.0, -2.0];
        a.update(&mut p, &g, 1, &h);
        for (x, gi) in p.iter().zip(&g) {
            let want = -h.learning_rate * gi.signum();
            assert!((x - want).abs() < 1e-4, "{x} vs {want}");
        }
    }

    #[test]
    fn adam_zero_grad_keeps_param_with_zero_moments() {
        let h = Hyper::default();
        let mut a = Adam::new(2);
        let mut p = vec![1.0f32, -1.0];
        a.update(&mut p, &[0.0, 0.0], 1, &h);
        assert_eq!(p, vec![1.0, -1.0]);
    }

    #[test]
    fn embedding_range_matches_python() {
        let h = Hyper::default();
        assert!((h.embedding_range() - 10.0 / 64.0).abs() < 1e-6);
    }
}
