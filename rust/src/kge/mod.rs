//! Knowledge-graph embedding methods: shared definitions plus a pure-Rust
//! reference implementation (`native`) and its width-dispatched inner-loop
//! kernels (`kernels`).
//!
//! The production path executes the AOT-compiled JAX/Pallas artifacts via
//! `crate::runtime`; the native implementation exists to (a) cross-check the
//! artifact numerics step-for-step, (b) run artifact-free unit/property
//! tests of the federated protocols, and (c) host the SVD+ baseline's
//! low-rank-constrained local training (Appendix VI-B).  `kernels` holds the
//! lane-friendly score/gradient primitives (monomorphized for common widths,
//! generic remainder-tolerant fallback) that `native` dispatches onto once at
//! model construction.

pub mod kernels;
pub mod native;

use crate::store::{StorageSpec, StoreTable};
use crate::util::rng::Rng;

/// The three KGE methods from the paper's experiments (§IV-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    TransE,
    RotatE,
    ComplEx,
}

impl Method {
    pub const ALL: [Method; 3] = [Method::TransE, Method::RotatE, Method::ComplEx];

    pub fn parse(s: &str) -> anyhow::Result<Method> {
        match s.to_ascii_lowercase().as_str() {
            "transe" => Ok(Method::TransE),
            "rotate" => Ok(Method::RotatE),
            "complex" => Ok(Method::ComplEx),
            other => anyhow::bail!("unknown KGE method '{other}' (transe|rotate|complex)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::TransE => "transe",
            Method::RotatE => "rotate",
            Method::ComplEx => "complex",
        }
    }

    /// Entity-table row width at base dimension `dim` (complex methods store
    /// re‖im concatenated).
    pub fn entity_width(&self, dim: usize) -> usize {
        match self {
            Method::TransE => dim,
            Method::RotatE | Method::ComplEx => 2 * dim,
        }
    }

    pub fn relation_width(&self, dim: usize) -> usize {
        match self {
            Method::TransE | Method::RotatE => dim,
            Method::ComplEx => 2 * dim,
        }
    }

    /// Distance methods rank lower-is-better; their logits are γ − dist.
    pub fn is_distance(&self) -> bool {
        matches!(self, Method::TransE | Method::RotatE)
    }
}

/// Hyper-parameters (mirror of `python/compile/config.py`; the runtime
/// asserts the manifest agrees with these at load time).
#[derive(Clone, Debug)]
pub struct Hyper {
    pub dim: usize,
    pub gamma: f32,
    pub epsilon: f32,
    pub adv_temperature: f32,
    pub learning_rate: f32,
    pub adam_beta1: f32,
    pub adam_beta2: f32,
    pub adam_eps: f32,
    pub complex_reg: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            dim: 64,
            gamma: 8.0,
            epsilon: 2.0,
            adv_temperature: 1.0,
            learning_rate: 1e-3,
            adam_beta1: 0.9,
            adam_beta2: 0.999,
            adam_eps: 1e-8,
            complex_reg: 1e-5,
        }
    }
}

impl Hyper {
    pub fn embedding_range(&self) -> f32 {
        (self.gamma + self.epsilon) / self.dim as f32
    }
}

/// A dense row-major embedding table.
#[derive(Clone, Debug, PartialEq)]
pub struct Table {
    pub rows: usize,
    pub width: usize,
    pub data: Vec<f32>,
}

impl Table {
    pub fn zeros(rows: usize, width: usize) -> Self {
        Self { rows, width, data: vec![0.0; rows * width] }
    }

    /// Uniform init in ±(γ+ε)/D, the RotatE-lineage convention used by FedE.
    pub fn init_uniform(rows: usize, width: usize, range: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * width).map(|_| rng.uniform(-range, range)).collect();
        Self { rows, width, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.width..(i + 1) * self.width]
    }

    pub fn set_row(&mut self, i: usize, v: &[f32]) {
        self.row_mut(i).copy_from_slice(v);
    }
}

/// Dense Adam state for one table (torch semantics, matching the artifact).
///
/// Retained as the **test oracle** for the sparse engine: `NativeModel`
/// trains with [`LazyAdam`], and `kge::native::DenseOracle` replays the
/// same gradients through this full-table update to cross-check them
/// (`sparse_engine_matches_dense_oracle`).
#[derive(Clone, Debug)]
pub struct Adam {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl Adam {
    pub fn new(len: usize) -> Self {
        Self { m: vec![0.0; len], v: vec![0.0; len] }
    }

    /// One dense update. `step` is 1-based.
    pub fn update(&mut self, p: &mut [f32], g: &[f32], step: u64, h: &Hyper) {
        let b1 = h.adam_beta1;
        let b2 = h.adam_beta2;
        let bc1 = 1.0 - b1.powi(step as i32);
        let bc2 = 1.0 - b2.powi(step as i32);
        for i in 0..p.len() {
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g[i];
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g[i] * g[i];
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            p[i] -= h.learning_rate * mh / (vh.sqrt() + h.adam_eps);
        }
    }
}

/// `b^n` for a u64 exponent (clamped; underflows to 0 for huge gaps, which
/// is the mathematically correct limit of the decay).
#[inline]
fn powu(b: f32, n: u64) -> f32 {
    b.powi(n.min(i32::MAX as u64) as i32)
}

/// Lazy **row-wise** Adam for one embedding table.
///
/// Per-row `last_step` timestamps let a step update only the rows whose
/// gradient is non-empty: when a row is next touched after `gap` skipped
/// steps, the β₁/β₂ moment decay those zero-gradient steps would have
/// applied is caught up in closed form (`m ·= β₁^gap`, `v ·= β₂^gap`)
/// instead of being walked step by step.  Untouched rows are never
/// visited, so a training step costs O(touched·width) rather than
/// O(rows·width).
///
/// Semantics are those of sparse Adam (torch's `SparseAdam` with moment
/// decay): a skipped step decays a row's moments but does **not** move its
/// parameters, whereas dense [`Adam`] also applies the residual
/// `-lr·m̂/(√v̂+ε)` drift on zero-gradient steps.  For rows touched on
/// every step the two are bit-identical (the gap-free path evaluates
/// exactly the dense update expression); the moment catch-up itself is
/// checked against repeated dense zero-grad updates in
/// `lazy_adam_catch_up_matches_dense_zero_grad_steps`.
#[derive(Clone, Debug)]
pub struct LazyAdam {
    /// First moments, one row per table row ([`StoreTable`] so huge-table
    /// runs keep moments on the same backend as the embeddings — sparse
    /// zeros under mmap mean a row's moments only become resident once it
    /// is touched).
    pub m: StoreTable,
    /// Second moments, same layout as `m`.
    pub v: StoreTable,
    /// 1-based step at which each row's moments were last advanced
    /// (0 = never touched).
    pub last_step: Vec<u64>,
    width: usize,
}

impl LazyAdam {
    pub fn new(rows: usize, width: usize) -> Self {
        Self::new_in(&StorageSpec::Ram, rows, width).expect("in-RAM storage is infallible")
    }

    /// Moment state on the selected storage backend.
    pub fn new_in(spec: &StorageSpec, rows: usize, width: usize) -> anyhow::Result<Self> {
        Ok(Self {
            m: StoreTable::zeros_in(spec, rows, width)?,
            v: StoreTable::zeros_in(spec, rows, width)?,
            last_step: vec![0; rows],
            width,
        })
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Closed-form geometric catch-up: advance `row`'s moments to `step`
    /// as if every step since `last_step[row]` had zero gradient.
    pub fn catch_up_row(&mut self, row: usize, step: u64, h: &Hyper) {
        let last = self.last_step[row];
        if step <= last {
            return;
        }
        let gap = step - last;
        let d1 = powu(h.adam_beta1, gap);
        let d2 = powu(h.adam_beta2, gap);
        for x in self.m.row_mut(row) {
            *x *= d1;
        }
        for x in self.v.row_mut(row) {
            *x *= d2;
        }
        self.last_step[row] = step;
    }

    /// One touched-row update at global `step` (1-based): catch up the
    /// skipped decay, then apply the standard Adam step to `p` with
    /// gradient `g`.  Bias corrections use the global step count, exactly
    /// like the dense oracle.
    pub fn update_row(&mut self, p: &mut [f32], g: &[f32], row: usize, step: u64, h: &Hyper) {
        debug_assert_eq!(p.len(), self.width);
        debug_assert_eq!(g.len(), self.width);
        self.catch_up_row(row, step - 1, h);
        let b1 = h.adam_beta1;
        let b2 = h.adam_beta2;
        let bc1 = 1.0 - b1.powi(step as i32);
        let bc2 = 1.0 - b2.powi(step as i32);
        let mr = self.m.row_mut(row);
        let vr = self.v.row_mut(row);
        for k in 0..g.len() {
            let m = b1 * mr[k] + (1.0 - b1) * g[k];
            let v = b2 * vr[k] + (1.0 - b2) * g[k] * g[k];
            mr[k] = m;
            vr[k] = v;
            let mh = m / bc1;
            let vh = v / bc2;
            p[k] -= h.learning_rate * mh / (vh.sqrt() + h.adam_eps);
        }
        self.last_step[row] = step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths() {
        assert_eq!(Method::TransE.entity_width(64), 64);
        assert_eq!(Method::RotatE.entity_width(64), 128);
        assert_eq!(Method::RotatE.relation_width(64), 64);
        assert_eq!(Method::ComplEx.relation_width(64), 128);
    }

    #[test]
    fn parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn table_init_in_range() {
        let mut rng = Rng::new(1);
        let t = Table::init_uniform(10, 8, 0.5, &mut rng);
        assert!(t.data.iter().all(|&x| (-0.5..0.5).contains(&x)));
        assert_eq!(t.row(3).len(), 8);
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        let h = Hyper::default();
        let mut a = Adam::new(4);
        let mut p = vec![0.0f32; 4];
        let g = vec![0.5f32, -0.5, 2.0, -2.0];
        a.update(&mut p, &g, 1, &h);
        for (x, gi) in p.iter().zip(&g) {
            let want = -h.learning_rate * gi.signum();
            assert!((x - want).abs() < 1e-4, "{x} vs {want}");
        }
    }

    #[test]
    fn adam_zero_grad_keeps_param_with_zero_moments() {
        let h = Hyper::default();
        let mut a = Adam::new(2);
        let mut p = vec![1.0f32, -1.0];
        a.update(&mut p, &[0.0, 0.0], 1, &h);
        assert_eq!(p, vec![1.0, -1.0]);
    }

    #[test]
    fn embedding_range_matches_python() {
        let h = Hyper::default();
        assert!((h.embedding_range() - 10.0 / 64.0).abs() < 1e-6);
    }

    #[test]
    fn lazy_adam_gap_free_path_matches_dense_bitwise() {
        // a row touched on every step must follow the dense oracle exactly
        let h = Hyper::default();
        let w = 4;
        let mut lazy = LazyAdam::new(1, w);
        let mut dense = Adam::new(w);
        let mut p_l = vec![0.3f32, -0.7, 1.5, 0.0];
        let mut p_d = p_l.clone();
        let mut rng = Rng::new(5);
        for step in 1..=50u64 {
            let g: Vec<f32> = (0..w).map(|_| rng.uniform(-1.0, 1.0)).collect();
            lazy.update_row(&mut p_l, &g, 0, step, &h);
            dense.update(&mut p_d, &g, step, &h);
        }
        assert_eq!(p_l, p_d);
        assert_eq!(lazy.m, dense.m);
        assert_eq!(lazy.v, dense.v);
    }

    /// A row untouched for T steps catches up its moment decay exactly
    /// like T dense zero-grad updates (the satellite regression test).
    #[test]
    fn lazy_adam_catch_up_matches_dense_zero_grad_steps() {
        let h = Hyper::default();
        let w = 6;
        let rows = 3;
        let mut lazy = LazyAdam::new(rows, w);
        let mut dense = Adam::new(rows * w);
        let mut p_l = vec![0.5f32; rows * w];
        let mut p_d = p_l.clone();
        // step 1: a real gradient through both engines
        let g: Vec<f32> = (0..rows * w).map(|i| 0.07 + 0.013 * i as f32).collect();
        for r in 0..rows {
            lazy.update_row(&mut p_l[r * w..(r + 1) * w], &g[r * w..(r + 1) * w], r, 1, &h);
        }
        dense.update(&mut p_d, &g, 1, &h);
        // steps 2..=1+T: dense sees T explicit zero-grad updates; the lazy
        // rows stay untouched and then catch up in one closed-form jump
        let t = 57u64;
        let zeros = vec![0.0f32; rows * w];
        for s in 2..=(1 + t) {
            dense.update(&mut p_d, &zeros, s, &h);
        }
        for r in 0..rows {
            lazy.catch_up_row(r, 1 + t, &h);
            assert_eq!(lazy.last_step[r], 1 + t);
        }
        for i in 0..rows * w {
            let rel = |a: f32, b: f32| (a - b).abs() / (1e-12 + b.abs().max(a.abs()));
            assert!(
                rel(lazy.m[i], dense.m[i]) < 1e-5,
                "m[{i}]: lazy {} vs dense {}",
                lazy.m[i],
                dense.m[i]
            );
            assert!(
                rel(lazy.v[i], dense.v[i]) < 1e-5,
                "v[{i}]: lazy {} vs dense {}",
                lazy.v[i],
                dense.v[i]
            );
        }
        // documented semantic difference: dense drifts parameters on
        // zero-grad steps (m ≠ 0), lazy leaves untouched rows in place
        assert_ne!(p_l, p_d);
    }

    #[test]
    fn lazy_adam_never_touched_row_is_inert() {
        let h = Hyper::default();
        let mut lazy = LazyAdam::new(2, 3);
        lazy.catch_up_row(1, 1000, &h);
        assert!(lazy.m.iter().all(|&x| x == 0.0));
        assert!(lazy.v.iter().all(|&x| x == 0.0));
        assert_eq!(lazy.last_step, vec![0, 1000]);
    }
}
