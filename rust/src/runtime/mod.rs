//! PJRT runtime: manifest loading, artifact compilation, typed execution.
//! See `/opt/xla-example/load_hlo` for the reference wiring this follows.

pub mod manifest;
pub mod pjrt;

pub use manifest::{ArtifactMeta, Manifest, Role};
pub use pjrt::{
    lit_f32, lit_i32, lit_scalar_f32, read_f32_into, scalar_f32, to_vec_f32, write_f32, Runtime,
};
