//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  Loaded from `artifacts/manifest.json`; every executable's
//! I/O signature is validated against it before compilation so shape drift
//! between the two layers fails fast with a useful error.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::kge::{Hyper, Method};
use crate::util::json::Json;

/// Roles an artifact can play (mirrors aot.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Train,
    TrainEpoch,
    Eval,
    Change,
    TrainKd,
    TrainKdEpoch,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "train" => Role::Train,
            "train_epoch" => Role::TrainEpoch,
            "eval" => Role::Eval,
            "change" => Role::Change,
            "train_kd" => Role::TrainKd,
            "train_kd_epoch" => Role::TrainKdEpoch,
            other => bail!("unknown artifact role '{other}'"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub role: Role,
    pub method: Method,
    pub dim: usize,
    pub entity_width: usize,
    pub relation_width: usize,
    pub num_entities: usize,
    pub num_relations: usize,
    pub batch: usize,
    pub negatives: usize,
    pub eval_batch: usize,
    pub n_outputs: usize,
    /// input signature: (shape, dtype)
    pub inputs: Vec<(Vec<usize>, String)>,
    /// KD artifacts: the low (transport) dimension
    pub kd_dim: Option<usize>,
    pub kd_entity_width: Option<usize>,
    pub kd_relation_width: Option<usize>,
    /// epoch artifacts: scan iterations fused per call
    pub scan_steps: Option<usize>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
    pub hyper: Hyper,
    pub num_entities: usize,
    pub num_relations: usize,
    pub batch: usize,
    pub negatives: usize,
    pub eval_batch: usize,
    pub sparsity: f64,
    pub sync_interval: usize,
    pub fedepl_dim: usize,
    pub kd_dim: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let cfg = j.req("config")?;
        let num = |k: &str| -> Result<f64> {
            cfg.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("config.{k} is not a number"))
        };
        let hyper = Hyper {
            dim: num("dim")? as usize,
            gamma: num("gamma")? as f32,
            epsilon: num("epsilon")? as f32,
            adv_temperature: num("adv_temperature")? as f32,
            learning_rate: num("learning_rate")? as f32,
            adam_beta1: num("adam_beta1")? as f32,
            adam_beta2: num("adam_beta2")? as f32,
            adam_eps: num("adam_eps")? as f32,
            complex_reg: num("complex_reg")? as f32,
        };

        let mut artifacts = Vec::new();
        for a in j.req("artifacts")?.as_arr().unwrap_or(&[]) {
            let s = |k: &str| -> Result<String> {
                Ok(a.req(k)?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("artifact.{k} not a string"))?
                    .to_string())
            };
            let n = |k: &str| -> Result<usize> {
                a.req(k)?
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("artifact.{k} not a number"))
            };
            let mut inputs = Vec::new();
            for spec in a.req("inputs")?.as_arr().unwrap_or(&[]) {
                let pair = spec.as_arr().context("input spec not a pair")?;
                let shape: Vec<usize> = pair[0]
                    .as_arr()
                    .context("input shape not an array")?
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect();
                let dtype = pair[1].as_str().unwrap_or("float32").to_string();
                inputs.push((shape, dtype));
            }
            artifacts.push(ArtifactMeta {
                name: s("name")?,
                file: s("file")?,
                role: Role::parse(&s("role")?)?,
                method: Method::parse(&s("method")?)?,
                dim: n("dim")?,
                entity_width: n("entity_width")?,
                relation_width: n("relation_width")?,
                num_entities: n("num_entities")?,
                num_relations: n("num_relations")?,
                batch: n("batch")?,
                negatives: n("negatives")?,
                eval_batch: n("eval_batch")?,
                n_outputs: n("n_outputs")?,
                inputs,
                kd_dim: a.get("kd_dim").and_then(|v| v.as_usize()),
                kd_entity_width: a.get("kd_entity_width").and_then(|v| v.as_usize()),
                kd_relation_width: a.get("kd_relation_width").and_then(|v| v.as_usize()),
                scan_steps: a.get("scan_steps").and_then(|v| v.as_usize()),
            });
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            hyper,
            num_entities: num("num_entities")? as usize,
            num_relations: num("num_relations")? as usize,
            batch: num("batch")? as usize,
            negatives: num("negatives")? as usize,
            eval_batch: num("eval_batch")? as usize,
            sparsity: num("sparsity")?,
            sync_interval: num("sync_interval")? as usize,
            fedepl_dim: j.req("fedepl_dim")?.as_usize().unwrap_or(0),
            kd_dim: j.req("kd_dim")?.as_usize().unwrap_or(0),
        })
    }

    /// Find the artifact for (role, method, dim).
    pub fn find(&self, role: Role, method: Method, dim: usize) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.role == role && a.method == method && a.dim == dim)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for role={role:?} method={} dim={dim}; \
                     rebuild with `make artifacts` (have: {})",
                    method.name(),
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Hyper-parameters at a non-base dimension (FedEPL / KD variants).
    pub fn hyper_at_dim(&self, dim: usize) -> Hyper {
        Hyper { dim, ..self.hyper.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 9);
        assert_eq!(m.hyper.dim, 64);
        // base-dim train/eval/change for all three methods
        for method in Method::ALL {
            for role in [Role::Train, Role::Eval, Role::Change] {
                let a = m.find(role, method, m.hyper.dim).unwrap();
                assert_eq!(a.num_entities, m.num_entities);
                assert!(dir.join(&a.file).exists(), "{} missing", a.file);
            }
            // fedepl variants for train/eval
            m.find(Role::Train, method, m.fedepl_dim).unwrap();
            m.find(Role::Eval, method, m.fedepl_dim).unwrap();
        }
        // KD for transe & rotate only
        assert!(m.find(Role::TrainKd, Method::TransE, m.hyper.dim).is_ok());
        assert!(m.find(Role::TrainKd, Method::ComplEx, m.hyper.dim).is_err());
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn train_signature_shape() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let a = m.find(Role::Train, Method::TransE, 64).unwrap();
        assert_eq!(a.inputs.len(), 11);
        assert_eq!(a.inputs[0].0, vec![m.num_entities, 64]);
        assert_eq!(a.inputs[7].0, vec![m.batch, 3]);
        assert_eq!(a.inputs[7].1, "int32");
        assert_eq!(a.n_outputs, 7);
    }
}
