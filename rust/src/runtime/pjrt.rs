//! PJRT runtime: load AOT artifacts (HLO text), compile once per process,
//! execute from the L3 hot path.
//!
//! Pattern adapted from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are cached by artifact name; outputs (a single tuple buffer,
//! PJRT does not untuple) are decomposed into per-output `Literal`s which
//! can be fed straight back as the next step's inputs — table state never
//! needs a host detour except where the federated protocol reads it.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::manifest::{ArtifactMeta, Manifest};

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn load(dir: &Path) -> Result<Rc<Runtime>> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::info!(
            "runtime: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Rc::new(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        }))
    }

    /// Default artifact directory: `$FEDS_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Rc<Runtime>> {
        let dir = std::env::var("FEDS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&dir))
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn executable(&self, meta: &ArtifactMeta) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(&meta.name) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(meta);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {}", meta.name))?,
        );
        crate::debug!("compiled {} in {:.2}s", meta.name, t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with `Literal` inputs; returns the decomposed
    /// output tuple (n_outputs literals).
    pub fn execute(&self, meta: &ArtifactMeta, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.execute_impl(meta, inputs)
    }

    /// Like `execute`, but borrowing the inputs (avoids moving state
    /// literals on the training hot path).
    pub fn execute_refs(
        &self,
        meta: &ArtifactMeta,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.execute_impl(meta, inputs)
    }

    fn execute_impl<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        meta: &ArtifactMeta,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == meta.inputs.len(),
            "artifact {} expects {} inputs, got {}",
            meta.name,
            meta.inputs.len(),
            inputs.len()
        );
        let exe = self.executable(meta)?;
        let out = exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", meta.name))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .context("fetching output tuple")?;
        let parts = tuple.to_tuple().context("decomposing output tuple")?;
        anyhow::ensure!(
            parts.len() == meta.n_outputs,
            "artifact {} produced {} outputs, manifest says {}",
            meta.name,
            parts.len(),
            meta.n_outputs
        );
        Ok(parts)
    }
}

// --- Literal helpers ---------------------------------------------------------

/// f32 literal with the given dims.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "lit_f32: {dims:?} vs len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// i32 literal with the given dims.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "lit_i32: {dims:?} vs len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read a literal's f32 payload.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read a scalar f32 literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Overwrite a literal's f32 payload in place (shape unchanged).
pub fn write_f32(lit: &mut xla::Literal, data: &[f32]) -> Result<()> {
    anyhow::ensure!(lit.element_count() == data.len(), "write_f32 size mismatch");
    lit.copy_raw_from(data)?;
    Ok(())
}

/// Read a literal's f32 payload into an existing buffer.
pub fn read_f32_into(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    anyhow::ensure!(lit.element_count() == out.len(), "read_f32 size mismatch");
    lit.copy_raw_to(out)?;
    Ok(())
}
