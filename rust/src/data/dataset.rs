//! Per-client dataset: splits, batching with negative sampling, and the
//! filtered-evaluation index.
//!
//! Batches are laid out exactly as the AOT train-step artifact expects
//! (`pos (B,3) i32`, `neg (B,NEG) i32`, `neg_is_head (B,) f32`,
//! `mask (B,) f32`, padding masked out), so the same structures drive both
//! the XLA trainer and the pure-Rust oracle.

use std::collections::{HashMap, HashSet};

use crate::util::rng::Rng;

use super::Triple;

/// One client's local KG.
#[derive(Clone, Debug)]
pub struct ClientData {
    pub id: u16,
    pub train: Vec<Triple>,
    pub valid: Vec<Triple>,
    pub test: Vec<Triple>,
    /// local entities (sorted, global ids)
    pub entities: Vec<u32>,
    /// local relations (sorted, global ids)
    pub relations: Vec<u32>,
}

impl ClientData {
    pub fn new(
        id: u16,
        train: Vec<Triple>,
        valid: Vec<Triple>,
        test: Vec<Triple>,
        _num_entities: usize,
    ) -> Self {
        let mut ents = HashSet::new();
        let mut rels = HashSet::new();
        for t in train.iter().chain(&valid).chain(&test) {
            ents.insert(t.h);
            ents.insert(t.t);
            rels.insert(t.r);
        }
        let mut entities: Vec<u32> = ents.into_iter().collect();
        entities.sort_unstable();
        let mut relations: Vec<u32> = rels.into_iter().collect();
        relations.sort_unstable();
        Self { id, train, valid, test, entities, relations }
    }

    /// Filter index over ALL local triples (train+valid+test) — the standard
    /// "filtered" evaluation setting.
    pub fn filter_index(&self) -> FilterIndex {
        FilterIndex::build(self.train.iter().chain(&self.valid).chain(&self.test))
    }
}

/// Known-positive lookup for filtered ranking: (known entity, relation) →
/// answers, per direction.
#[derive(Clone, Debug, Default)]
pub struct FilterIndex {
    /// (h, r) → tails
    tails: HashMap<(u32, u32), Vec<u32>>,
    /// (t, r) → heads
    heads: HashMap<(u32, u32), Vec<u32>>,
}

impl FilterIndex {
    pub fn build<'a>(triples: impl Iterator<Item = &'a Triple>) -> Self {
        let mut f = FilterIndex::default();
        for t in triples {
            f.tails.entry((t.h, t.r)).or_default().push(t.t);
            f.heads.entry((t.t, t.r)).or_default().push(t.h);
        }
        f
    }

    pub fn known_tails(&self, h: u32, r: u32) -> &[u32] {
        self.tails.get(&(h, r)).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn known_heads(&self, t: u32, r: u32) -> &[u32] {
        self.heads.get(&(t, r)).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

/// A padded training batch in artifact layout.
#[derive(Clone, Debug)]
pub struct Batch {
    pub pos: Vec<i32>,         // B*3 [h, r, t]
    pub neg: Vec<i32>,         // B*NEG entity ids
    pub neg_is_head: Vec<f32>, // B
    pub mask: Vec<f32>,        // B
    pub len: usize,            // real (unpadded) rows
    pub batch_size: usize,
    pub negatives: usize,
}

/// Shuffled epoch iterator producing padded batches with uniform negative
/// sampling from the client's local entity set (FedE convention) and
/// per-sample head/tail corruption.
pub struct BatchIter<'a> {
    triples: Vec<&'a Triple>,
    entities: &'a [u32],
    batch_size: usize,
    negatives: usize,
    pos_idx: usize,
    rng: &'a mut Rng,
}

impl<'a> BatchIter<'a> {
    pub fn new(
        triples: &'a [Triple],
        entities: &'a [u32],
        batch_size: usize,
        negatives: usize,
        rng: &'a mut Rng,
    ) -> Self {
        let mut refs: Vec<&Triple> = triples.iter().collect();
        rng.shuffle(&mut refs);
        Self { triples: refs, entities, batch_size, negatives, pos_idx: 0, rng }
    }

    pub fn batches_per_epoch(n_triples: usize, batch_size: usize) -> usize {
        n_triples.div_ceil(batch_size)
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.pos_idx >= self.triples.len() {
            return None;
        }
        let b = self.batch_size;
        let n = self.negatives;
        let take = (self.triples.len() - self.pos_idx).min(b);
        let mut pos = vec![0i32; b * 3];
        let mut neg = vec![0i32; b * n];
        let mut neg_is_head = vec![0f32; b];
        let mut mask = vec![0f32; b];
        for i in 0..take {
            let t = self.triples[self.pos_idx + i];
            pos[i * 3] = t.h as i32;
            pos[i * 3 + 1] = t.r as i32;
            pos[i * 3 + 2] = t.t as i32;
            neg_is_head[i] = if self.rng.bool(0.5) { 1.0 } else { 0.0 };
            mask[i] = 1.0;
            for j in 0..n {
                neg[i * n + j] =
                    self.entities[self.rng.usize_below(self.entities.len())] as i32;
            }
        }
        self.pos_idx += take;
        Some(Batch {
            pos,
            neg,
            neg_is_head,
            mask,
            len: take,
            batch_size: b,
            negatives: n,
        })
    }
}

/// A padded evaluation batch in artifact layout (one query per row).
#[derive(Clone, Debug)]
pub struct EvalBatch {
    pub src: Vec<i32>,       // EB known entity
    pub rel: Vec<i32>,       // EB
    pub truth: Vec<i32>,     // EB answer entity
    pub pred_head: Vec<f32>, // EB
    pub filter: Vec<f32>,    // EB*E — 1 marks known positives to exclude
    pub len: usize,
    pub eval_batch: usize,
}

/// All queries for a triple set: two per triple (tail- and head-prediction).
pub struct EvalSet {
    queries: Vec<(u32, u32, u32, bool)>, // (src, rel, truth, pred_head)
    pub num_entities: usize,
}

impl EvalSet {
    pub fn new(triples: &[Triple], num_entities: usize) -> Self {
        let mut queries = Vec::with_capacity(triples.len() * 2);
        for t in triples {
            queries.push((t.h, t.r, t.t, false)); // predict tail
            queries.push((t.t, t.r, t.h, true));  // predict head
        }
        Self { queries, num_entities }
    }

    pub fn len(&self) -> usize {
        self.queries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }

    /// Deterministically subsample to at most `max_queries` (evaluation cap
    /// for the scaled experiment harness; 0 = keep all).
    pub fn subsample(&mut self, max_queries: usize, rng: &mut crate::util::rng::Rng) {
        if max_queries == 0 || self.queries.len() <= max_queries {
            return;
        }
        rng.shuffle(&mut self.queries);
        self.queries.truncate(max_queries);
    }

    /// Produce padded eval batches; `filter` excludes every known positive
    /// except the true answer itself.
    pub fn batches(&self, eval_batch: usize, filters: &FilterIndex) -> Vec<EvalBatch> {
        let e = self.num_entities;
        let mut out = Vec::new();
        for chunk in self.queries.chunks(eval_batch) {
            let mut eb = EvalBatch {
                src: vec![0; eval_batch],
                rel: vec![0; eval_batch],
                truth: vec![0; eval_batch],
                pred_head: vec![0.0; eval_batch],
                filter: vec![0.0; eval_batch * e],
                len: chunk.len(),
                eval_batch,
            };
            for (i, &(src, rel, truth, ph)) in chunk.iter().enumerate() {
                eb.src[i] = src as i32;
                eb.rel[i] = rel as i32;
                eb.truth[i] = truth as i32;
                eb.pred_head[i] = if ph { 1.0 } else { 0.0 };
                let known: &[u32] = if ph {
                    filters.known_heads(src, rel)
                } else {
                    filters.known_tails(src, rel)
                };
                let row = &mut eb.filter[i * e..(i + 1) * e];
                for &k in known {
                    row[k as usize] = 1.0;
                }
                row[truth as usize] = 0.0; // never filter the answer itself
            }
            out.push(eb);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triples() -> Vec<Triple> {
        vec![
            Triple::new(0, 0, 1),
            Triple::new(0, 0, 2),
            Triple::new(2, 1, 3),
            Triple::new(3, 0, 0),
            Triple::new(1, 1, 4),
        ]
    }

    #[test]
    fn client_data_collects_vocab() {
        let c = ClientData::new(0, triples(), vec![], vec![], 16);
        assert_eq!(c.entities, vec![0, 1, 2, 3, 4]);
        assert_eq!(c.relations, vec![0, 1]);
    }

    #[test]
    fn filter_index_lookups() {
        let ts = triples();
        let f = FilterIndex::build(ts.iter());
        let mut tails = f.known_tails(0, 0).to_vec();
        tails.sort_unstable();
        assert_eq!(tails, vec![1, 2]);
        assert_eq!(f.known_heads(0, 0), &[3]);
        assert!(f.known_tails(9, 9).is_empty());
    }

    #[test]
    fn batches_cover_all_triples_once() {
        let ts: Vec<Triple> = (0..10).map(|i| Triple::new(i, 0, i + 1)).collect();
        let ents: Vec<u32> = (0..12).collect();
        let mut rng = Rng::new(1);
        let batches: Vec<Batch> = BatchIter::new(&ts, &ents, 4, 2, &mut rng).collect();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches.iter().map(|b| b.len).sum::<usize>(), 10);
        // every real row's positive must be one of the source triples
        let set: HashSet<(i32, i32, i32)> =
            ts.iter().map(|t| (t.h as i32, t.r as i32, t.t as i32)).collect();
        let mut count = 0;
        for b in &batches {
            for i in 0..b.len {
                let key = (b.pos[i * 3], b.pos[i * 3 + 1], b.pos[i * 3 + 2]);
                assert!(set.contains(&key));
                count += 1;
            }
            // padding is masked
            for i in b.len..b.batch_size {
                assert_eq!(b.mask[i], 0.0);
            }
        }
        assert_eq!(count, 10);
    }

    #[test]
    fn negatives_from_local_entities() {
        let ts: Vec<Triple> = (0..6).map(|i| Triple::new(i, 0, i + 1)).collect();
        let ents: Vec<u32> = vec![100, 101, 102];
        let mut rng = Rng::new(2);
        for b in BatchIter::new(&ts, &ents, 4, 8, &mut rng) {
            for i in 0..b.len {
                for j in 0..b.negatives {
                    let id = b.neg[i * b.negatives + j] as u32;
                    assert!(ents.contains(&id));
                }
            }
        }
    }

    #[test]
    fn eval_set_two_queries_per_triple() {
        let ts = triples();
        let es = EvalSet::new(&ts, 16);
        assert_eq!(es.len(), 10);
    }

    #[test]
    fn eval_filter_excludes_known_but_not_answer() {
        let ts = triples();
        let f = FilterIndex::build(ts.iter());
        let es = EvalSet::new(&ts, 16);
        let batches = es.batches(4, &f);
        // first query: (0, 0, predict tail, answer 1); known tails {1, 2}
        let b = &batches[0];
        assert_eq!(b.src[0], 0);
        assert_eq!(b.truth[0], 1);
        assert_eq!(b.pred_head[0], 0.0);
        let row = &b.filter[0..16];
        assert_eq!(row[1], 0.0, "answer must not be filtered");
        assert_eq!(row[2], 1.0, "other known positive must be filtered");
        assert_eq!(row[5], 0.0);
    }

    #[test]
    fn eval_batches_pad_correctly() {
        let ts = triples();
        let f = FilterIndex::build(ts.iter());
        let es = EvalSet::new(&ts, 16);
        let batches = es.batches(4, &f);
        assert_eq!(batches.len(), 3); // 10 queries / 4
        assert_eq!(batches[2].len, 2);
    }
}
