//! Synthetic FB15k-237-like knowledge-graph generator.
//!
//! The goal is not to imitate Freebase content but to reproduce the
//! *structural* properties that drive the paper's phenomena (DESIGN.md §5):
//!
//! 1. **Zipf-skewed entity usage** — a few hub entities participate in many
//!    triples; most appear rarely.  This is what makes entity-wise Top-K
//!    selection meaningful: hot entities change a lot each round, cold ones
//!    barely move.
//! 2. **Relation-typed structure** — each relation connects a source entity
//!    cluster to a destination cluster through a noisy affine index map, so
//!    embeddings can actually fit the data and federated sharing of entity
//!    embeddings genuinely helps (relations are disjoint across clients
//!    after partitioning, entities overlap).
//! 3. **Skewed relation frequencies** — like FB15k-237's long-tailed
//!    relation distribution.

use std::collections::HashSet;

use crate::util::rng::Rng;

use super::Triple;

#[derive(Clone, Debug)]
pub struct GeneratorConfig {
    pub num_entities: usize,
    pub num_relations: usize,
    pub num_triples: usize,
    /// Entities are grouped into this many clusters; each relation maps one
    /// cluster to another.
    pub num_clusters: usize,
    /// Zipf exponent for entity popularity within a cluster (0 = uniform).
    pub entity_skew: f64,
    /// Zipf exponent over relations.
    pub relation_skew: f64,
    /// Probability that a tail is drawn at random from the destination
    /// cluster instead of via the relation's index map.
    pub noise: f64,
    pub seed: u64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        Self {
            num_entities: 2048,
            num_relations: 24,
            num_triples: 30_000,
            num_clusters: 8,
            entity_skew: 0.8,
            relation_skew: 0.7,
            noise: 0.15,
            seed: 0xFED5,
        }
    }
}

/// A generated knowledge graph over global ids.
#[derive(Clone, Debug)]
pub struct Kg {
    pub num_entities: usize,
    pub num_relations: usize,
    pub triples: Vec<Triple>,
}

struct RelationSchema {
    src_cluster: usize,
    dst_cluster: usize,
    /// affine index map within the clusters: dst_idx = (a*src_idx + b) % len
    a: usize,
    b: usize,
}

/// Where the stream is in the three-phase generation algorithm.
enum Phase {
    /// the Zipf-skewed main draw (up to `num_triples` emissions)
    Main,
    /// relation-coverage pass: next relation id to examine
    RelCoverage(usize),
    /// entity-coverage pass: next entity id to examine
    EntityCoverage(u32),
    Done,
}

/// A lazily generated KG: yields exactly the triples (and order) of
/// [`generate`] without holding the full triple list.  At E=1M the
/// dominant transient cost drops to the dedup set and two coverage
/// bitmaps; the consumer decides what to materialize (the streaming
/// partitioner routes rows straight into per-client splits).
pub struct TripleStream {
    num_triples: usize,
    entity_skew: f64,
    relation_skew: f64,
    noise: f64,
    rng: Rng,
    clusters: Vec<Vec<u32>>,
    schemas: Vec<RelationSchema>,
    /// dedup set — every emitted triple, the one O(triples) structure
    seen: HashSet<Triple>,
    emitted: usize,
    attempts: usize,
    max_attempts: usize,
    /// relations covered by emitted triples (main phase only feeds this)
    rel_used: Vec<bool>,
    /// entities appearing in emitted triples, exactly as the batch
    /// algorithm's scan would see them at the entity-coverage pass
    used: Vec<bool>,
    phase: Phase,
}

/// Start streaming a KG.  Deterministic in `cfg.seed`: the stream
/// consumes the RNG in the same order as the batch algorithm, so
/// `stream(cfg).collect()` is triple-for-triple what [`generate`]
/// returns.
pub fn stream(cfg: &GeneratorConfig) -> TripleStream {
    assert!(cfg.num_clusters >= 2, "need at least 2 clusters");
    assert!(cfg.num_entities >= cfg.num_clusters * 4);
    let mut rng = Rng::new(cfg.seed);

    // Assign entities to clusters contiguously, then shuffle ids so cluster
    // membership is not correlated with id order.
    let mut ids: Vec<u32> = (0..cfg.num_entities as u32).collect();
    rng.shuffle(&mut ids);
    let per = cfg.num_entities / cfg.num_clusters;
    let clusters: Vec<Vec<u32>> = (0..cfg.num_clusters)
        .map(|c| {
            let lo = c * per;
            let hi = if c + 1 == cfg.num_clusters { cfg.num_entities } else { lo + per };
            ids[lo..hi].to_vec()
        })
        .collect();

    // Relation schemas: src→dst cluster + affine map (a odd → bijective mod
    // power-of-two sizes; harmless otherwise).
    let schemas: Vec<RelationSchema> = (0..cfg.num_relations)
        .map(|_| {
            let src_cluster = rng.usize_below(cfg.num_clusters);
            let mut dst_cluster = rng.usize_below(cfg.num_clusters);
            if dst_cluster == src_cluster {
                dst_cluster = (dst_cluster + 1) % cfg.num_clusters;
            }
            RelationSchema {
                src_cluster,
                dst_cluster,
                a: rng.usize_below(7) * 2 + 1,
                b: rng.usize_below(997),
            }
        })
        .collect();

    TripleStream {
        num_triples: cfg.num_triples,
        entity_skew: cfg.entity_skew,
        relation_skew: cfg.relation_skew,
        noise: cfg.noise,
        rng,
        clusters,
        schemas,
        seen: HashSet::with_capacity(cfg.num_triples * 2),
        emitted: 0,
        attempts: 0,
        max_attempts: cfg.num_triples * 30,
        rel_used: vec![false; cfg.num_relations],
        used: vec![false; cfg.num_entities],
        phase: Phase::Main,
    }
}

impl Iterator for TripleStream {
    type Item = Triple;

    fn next(&mut self) -> Option<Triple> {
        loop {
            match self.phase {
                Phase::Main => {
                    if self.emitted >= self.num_triples || self.attempts >= self.max_attempts {
                        self.phase = Phase::RelCoverage(0);
                        continue;
                    }
                    self.attempts += 1;
                    let nr = self.schemas.len();
                    let r = self.rng.zipf(nr, self.relation_skew) as u32;
                    let sch = &self.schemas[r as usize];
                    let src = &self.clusters[sch.src_cluster];
                    let dst = &self.clusters[sch.dst_cluster];
                    let hi = self.rng.zipf(src.len(), self.entity_skew);
                    let h = src[hi];
                    let t = if self.rng.bool(self.noise) {
                        dst[self.rng.zipf(dst.len(), self.entity_skew)]
                    } else {
                        dst[(sch.a * hi + sch.b) % dst.len()]
                    };
                    let tr = Triple::new(h, r, t);
                    if self.seen.insert(tr) {
                        self.emitted += 1;
                        self.rel_used[r as usize] = true;
                        self.used[h as usize] = true;
                        self.used[t as usize] = true;
                        return Some(tr);
                    }
                }
                // Guarantee coverage: every relation has at least one
                // triple (so the even relation partition is meaningful)...
                Phase::RelCoverage(mut r) => {
                    while r < self.schemas.len() && self.rel_used[r] {
                        r += 1;
                    }
                    if r >= self.schemas.len() {
                        self.phase = Phase::EntityCoverage(0);
                        continue;
                    }
                    self.phase = Phase::RelCoverage(r + 1);
                    let sch = &self.schemas[r];
                    let src = &self.clusters[sch.src_cluster];
                    let dst = &self.clusters[sch.dst_cluster];
                    let hi = self.rng.usize_below(src.len());
                    let tr = Triple::new(src[hi], r as u32, dst[(sch.a * hi + sch.b) % dst.len()]);
                    if self.seen.insert(tr) {
                        self.used[tr.h as usize] = true;
                        self.used[tr.t as usize] = true;
                        return Some(tr);
                    }
                }
                // ...and every entity appears in at least one triple (as in
                // FB15k-237 every entity occurs in the graph).
                Phase::EntityCoverage(mut e) => {
                    while (e as usize) < self.used.len() && self.used[e as usize] {
                        e += 1;
                    }
                    if e as usize >= self.used.len() {
                        self.phase = Phase::Done;
                        continue;
                    }
                    self.phase = Phase::EntityCoverage(e + 1);
                    // attach via a random relation whose src cluster we
                    // pretend contains e (structure noise, rare by
                    // construction); e is marked used whether or not the
                    // attachment deduplicates — exactly the batch pass
                    let r = self.rng.u32_below(self.schemas.len() as u32);
                    let dst = &self.clusters[self.schemas[r as usize].dst_cluster];
                    let t = dst[self.rng.usize_below(dst.len())];
                    self.used[e as usize] = true;
                    let tr = Triple::new(e, r, t);
                    if self.seen.insert(tr) {
                        return Some(tr);
                    }
                }
                Phase::Done => return None,
            }
        }
    }
}

/// Generate a KG.  Deterministic in `cfg.seed`.  A thin collect over
/// [`stream`]; callers that never need the full list (the streaming
/// partitioner, scale benchmarks) should consume the stream directly.
pub fn generate(cfg: &GeneratorConfig) -> Kg {
    Kg {
        num_entities: cfg.num_entities,
        num_relations: cfg.num_relations,
        triples: stream(cfg).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> GeneratorConfig {
        GeneratorConfig {
            num_entities: 256,
            num_relations: 8,
            num_triples: 2000,
            num_clusters: 4,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn generates_requested_size() {
        let kg = generate(&tiny());
        assert!(kg.triples.len() >= 2000);
        assert_eq!(kg.num_entities, 256);
    }

    /// The pre-streaming batch implementation, kept verbatim as a
    /// reference: the state machine must replicate its RNG consumption
    /// and emission order exactly, phase by phase.
    fn batch_reference(cfg: &GeneratorConfig) -> Vec<Triple> {
        let mut rng = Rng::new(cfg.seed);
        let mut ids: Vec<u32> = (0..cfg.num_entities as u32).collect();
        rng.shuffle(&mut ids);
        let per = cfg.num_entities / cfg.num_clusters;
        let clusters: Vec<Vec<u32>> = (0..cfg.num_clusters)
            .map(|c| {
                let lo = c * per;
                let hi = if c + 1 == cfg.num_clusters { cfg.num_entities } else { lo + per };
                ids[lo..hi].to_vec()
            })
            .collect();
        let schemas: Vec<RelationSchema> = (0..cfg.num_relations)
            .map(|_| {
                let src_cluster = rng.usize_below(cfg.num_clusters);
                let mut dst_cluster = rng.usize_below(cfg.num_clusters);
                if dst_cluster == src_cluster {
                    dst_cluster = (dst_cluster + 1) % cfg.num_clusters;
                }
                RelationSchema {
                    src_cluster,
                    dst_cluster,
                    a: rng.usize_below(7) * 2 + 1,
                    b: rng.usize_below(997),
                }
            })
            .collect();

        let mut seen: HashSet<Triple> = HashSet::new();
        let mut triples = Vec::new();
        let max_attempts = cfg.num_triples * 30;
        let mut attempts = 0;
        while triples.len() < cfg.num_triples && attempts < max_attempts {
            attempts += 1;
            let r = rng.zipf(cfg.num_relations, cfg.relation_skew) as u32;
            let sch = &schemas[r as usize];
            let src = &clusters[sch.src_cluster];
            let dst = &clusters[sch.dst_cluster];
            let hi = rng.zipf(src.len(), cfg.entity_skew);
            let h = src[hi];
            let t = if rng.bool(cfg.noise) {
                dst[rng.zipf(dst.len(), cfg.entity_skew)]
            } else {
                dst[(sch.a * hi + sch.b) % dst.len()]
            };
            let tr = Triple::new(h, r, t);
            if seen.insert(tr) {
                triples.push(tr);
            }
        }
        let mut rel_used = vec![false; cfg.num_relations];
        for t in &triples {
            rel_used[t.r as usize] = true;
        }
        for r in 0..cfg.num_relations {
            if !rel_used[r] {
                let sch = &schemas[r];
                let src = &clusters[sch.src_cluster];
                let dst = &clusters[sch.dst_cluster];
                let hi = rng.usize_below(src.len());
                let tr = Triple::new(src[hi], r as u32, dst[(sch.a * hi + sch.b) % dst.len()]);
                if seen.insert(tr) {
                    triples.push(tr);
                }
            }
        }
        let mut used = vec![false; cfg.num_entities];
        for t in &triples {
            used[t.h as usize] = true;
            used[t.t as usize] = true;
        }
        for e in 0..cfg.num_entities as u32 {
            if !used[e as usize] {
                let r = rng.u32_below(cfg.num_relations as u32);
                let dst = &clusters[schemas[r as usize].dst_cluster];
                let t = dst[rng.usize_below(dst.len())];
                let tr = Triple::new(e, r, t);
                if seen.insert(tr) {
                    triples.push(tr);
                }
                used[e as usize] = true;
            }
        }
        triples
    }

    #[test]
    fn stream_matches_batch_reference_triple_for_triple() {
        for seed in [7u64, 8, 99] {
            let cfg = GeneratorConfig { seed, ..tiny() };
            let streamed: Vec<Triple> = stream(&cfg).collect();
            assert_eq!(streamed, batch_reference(&cfg), "seed {seed}");
        }
        // a sparse config that exercises both coverage phases: few main
        // draws over many entities/relations leave plenty uncovered
        let cfg = GeneratorConfig {
            num_entities: 512,
            num_relations: 24,
            num_triples: 40,
            num_clusters: 8,
            seed: 3,
            ..Default::default()
        };
        let streamed: Vec<Triple> = stream(&cfg).collect();
        let reference = batch_reference(&cfg);
        assert_eq!(streamed, reference, "coverage phases must replay identically");
        assert!(reference.len() > 40 + 360, "config must actually hit both coverage phases");
    }

    #[test]
    fn deterministic() {
        let a = generate(&tiny());
        let b = generate(&tiny());
        assert_eq!(a.triples, b.triples);
    }

    #[test]
    fn different_seed_differs() {
        let mut cfg = tiny();
        cfg.seed = 8;
        assert_ne!(generate(&tiny()).triples, generate(&cfg).triples);
    }

    #[test]
    fn ids_in_range_and_no_duplicates() {
        let kg = generate(&tiny());
        let mut seen = HashSet::new();
        for t in &kg.triples {
            assert!((t.h as usize) < kg.num_entities);
            assert!((t.t as usize) < kg.num_entities);
            assert!((t.r as usize) < kg.num_relations);
            assert!(seen.insert(*t), "duplicate {t:?}");
        }
    }

    #[test]
    fn every_entity_appears() {
        let kg = generate(&tiny());
        let mut used = vec![false; kg.num_entities];
        for t in &kg.triples {
            used[t.h as usize] = true;
            used[t.t as usize] = true;
        }
        assert!(used.iter().all(|&u| u));
    }

    #[test]
    fn entity_usage_is_skewed() {
        let kg = generate(&GeneratorConfig { entity_skew: 1.0, ..tiny() });
        let mut deg = vec![0usize; kg.num_entities];
        for t in &kg.triples {
            deg[t.h as usize] += 1;
            deg[t.t as usize] += 1;
        }
        deg.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = deg.iter().sum();
        let top10: usize = deg[..kg.num_entities / 10].iter().sum();
        // top 10% of entities should carry well over 10% of the degree mass
        assert!(
            top10 as f64 > 0.3 * total as f64,
            "top10 {top10} / total {total}"
        );
    }

    #[test]
    fn relations_have_learnable_structure() {
        // For a low-noise generator, a relation's tails should concentrate:
        // given h and r, the modal tail should dominate.
        let cfg = GeneratorConfig { noise: 0.0, ..tiny() };
        let kg = generate(&cfg);
        use std::collections::HashMap;
        let mut tails: HashMap<(u32, u32), HashSet<u32>> = HashMap::new();
        for t in &kg.triples {
            tails.entry((t.h, t.r)).or_default().insert(t.t);
        }
        // with zero noise the map is a function: one tail per (h, r)
        // (modulo the coverage triples, which are rare)
        let single = tails.values().filter(|s| s.len() == 1).count();
        assert!(
            single as f64 > 0.9 * tails.len() as f64,
            "{single}/{}",
            tails.len()
        );
    }
}
