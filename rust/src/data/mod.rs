//! Federated knowledge-graph data substrate.
//!
//! FB15k-237 is not available offline, so `generator` produces a synthetic
//! FB15k-237-like KG with the structural properties FedS exploits (Zipf
//! entity usage, relation-typed structure), and `partition` applies the same
//! relation-partitioning pipeline the paper used to build
//! FB15k-237-R10/R5/R3 (DESIGN.md §5).
//!
//! Both ends stream: `generator::stream` yields triples one at a time
//! (bit-identical to collecting `generate`), and `partition_stream`
//! routes them straight into per-client splits — a million-entity KG is
//! partitioned without ever holding the full triple list in one buffer.

pub mod dataset;
pub mod generator;
pub mod partition;

pub use dataset::{Batch, BatchIter, ClientData, EvalBatch, EvalSet, FilterIndex};
pub use generator::{generate, stream, GeneratorConfig, Kg};
pub use partition::{partition, partition_stream, FedDataset};

/// A (head, relation, tail) triple over global ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Triple {
    pub h: u32,
    pub r: u32,
    pub t: u32,
}

impl Triple {
    pub fn new(h: u32, r: u32, t: u32) -> Self {
        Self { h, r, t }
    }
}
