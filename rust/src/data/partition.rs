//! Relation-based federated partitioning — the FB15k-237-R{10,5,3} pipeline.
//!
//! The paper's datasets are "created by partitioning relations evenly and
//! then distributing corresponding triples into ten, five, and three
//! clients" with a 0.8/0.1/0.1 train/valid/test split per client (§IV-A).
//! Relations end up disjoint across clients; entities overlap — that
//! overlap is exactly the set FedS communicates.

use crate::util::rng::Rng;

use super::dataset::ClientData;
use super::generator::Kg;
use super::Triple;

/// A federated dataset: per-client splits plus the sharing structure.
#[derive(Clone, Debug)]
pub struct FedDataset {
    pub num_entities: usize,
    pub num_relations: usize,
    pub clients: Vec<ClientData>,
    /// entity → sorted list of client ids that own it
    pub owners: Vec<Vec<u16>>,
    /// entities owned by ≥ 2 clients (the communicated set), sorted
    pub shared: Vec<u32>,
}

/// Partition a KG into `num_clients` clients by relation (even split),
/// then split each client 0.8/0.1/0.1.
pub fn partition(kg: &Kg, num_clients: usize, seed: u64) -> FedDataset {
    partition_stream(
        kg.num_entities,
        kg.num_relations,
        kg.triples.iter().copied(),
        num_clients,
        seed,
    )
}

/// [`partition`] over a triple stream: rows are routed into per-client
/// splits as they arrive, so the full KG is never materialized in one
/// list.  Identical RNG schedule (and therefore bit-identical output)
/// to partitioning a collected [`Kg`] — the relation split draws before
/// any triple is consumed, the per-client shuffles after all are.
pub fn partition_stream(
    num_entities: usize,
    num_relations: usize,
    triples: impl IntoIterator<Item = Triple>,
    num_clients: usize,
    seed: u64,
) -> FedDataset {
    assert!(num_clients >= 2);
    assert!(
        num_relations >= num_clients,
        "need at least one relation per client"
    );
    let mut rng = Rng::new(seed ^ 0x9A27_1EED);

    // Even relation split (shuffled round-robin, like the paper's datasets).
    let mut rels: Vec<u32> = (0..num_relations as u32).collect();
    rng.shuffle(&mut rels);
    let mut rel_owner = vec![0u16; num_relations];
    for (i, r) in rels.iter().enumerate() {
        rel_owner[*r as usize] = (i % num_clients) as u16;
    }

    let mut per_client: Vec<Vec<Triple>> = vec![Vec::new(); num_clients];
    for t in triples {
        per_client[rel_owner[t.r as usize] as usize].push(t);
    }

    let mut clients = Vec::with_capacity(num_clients);
    for (id, mut triples) in per_client.into_iter().enumerate() {
        rng.shuffle(&mut triples);
        let n = triples.len();
        let n_test = n / 10;
        let n_valid = n / 10;
        let n_train = n - n_test - n_valid;
        // split off back-to-front so each piece drops to its final
        // capacity instead of cloning out of one long-lived buffer
        let test = triples.split_off(n_train + n_valid);
        let valid = triples.split_off(n_train);
        let train = triples;
        clients.push(ClientData::new(id as u16, train, valid, test, num_entities));
    }

    let mut owners: Vec<Vec<u16>> = vec![Vec::new(); num_entities];
    for c in &clients {
        for &e in &c.entities {
            owners[e as usize].push(c.id);
        }
    }
    let shared: Vec<u32> = (0..num_entities as u32)
        .filter(|&e| owners[e as usize].len() >= 2)
        .collect();

    FedDataset {
        num_entities,
        num_relations,
        clients,
        owners,
        shared,
    }
}

impl FedDataset {
    /// Entities of client `c` shared with at least one other client — the
    /// paper's N_c (§III-B: exclusive entities are never communicated).
    pub fn shared_entities_of(&self, client: u16) -> Vec<u32> {
        self.clients[client as usize]
            .entities
            .iter()
            .copied()
            .filter(|&e| self.owners[e as usize].len() >= 2)
            .collect()
    }

    pub fn total_triples(&self) -> usize {
        self.clients.iter().map(|c| c.train.len() + c.valid.len() + c.test.len()).sum()
    }

    /// Test-triple counts, used as weights for the paper's weighted-average
    /// metrics ("weights being the proportions of the triple size").
    pub fn test_weights(&self) -> Vec<f64> {
        let total: usize = self.clients.iter().map(|c| c.test.len()).sum();
        self.clients
            .iter()
            .map(|c| c.test.len() as f64 / total.max(1) as f64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate, GeneratorConfig};

    fn kg() -> Kg {
        generate(&GeneratorConfig {
            num_entities: 256,
            num_relations: 12,
            num_triples: 3000,
            num_clusters: 4,
            seed: 3,
            ..Default::default()
        })
    }

    #[test]
    fn relations_disjoint_across_clients() {
        let fd = partition(&kg(), 3, 1);
        let mut seen = std::collections::HashSet::new();
        for c in &fd.clients {
            for &r in &c.relations {
                assert!(seen.insert(r), "relation {r} on two clients");
            }
        }
    }

    #[test]
    fn relation_split_is_even() {
        let fd = partition(&kg(), 3, 1);
        let counts: Vec<usize> = fd.clients.iter().map(|c| c.relations.len()).collect();
        assert_eq!(counts.iter().sum::<usize>(), 12);
        assert!(counts.iter().all(|&c| c == 4), "{counts:?}");
    }

    #[test]
    fn split_ratios_hold() {
        let fd = partition(&kg(), 3, 1);
        for c in &fd.clients {
            let n = c.train.len() + c.valid.len() + c.test.len();
            assert!(c.train.len() as f64 >= 0.78 * n as f64);
            assert!(c.valid.len() as f64 <= 0.11 * n as f64);
            assert!(c.test.len() as f64 <= 0.11 * n as f64);
        }
    }

    #[test]
    fn no_triple_lost() {
        let k = kg();
        let fd = partition(&k, 5, 1);
        assert_eq!(fd.total_triples(), k.triples.len());
    }

    #[test]
    fn entities_overlap_across_clients() {
        let fd = partition(&kg(), 3, 1);
        assert!(
            !fd.shared.is_empty(),
            "partitioned KG must have shared entities"
        );
        // shared entities have ≥ 2 owners
        for &e in &fd.shared {
            assert!(fd.owners[e as usize].len() >= 2);
        }
    }

    #[test]
    fn shared_entities_of_client_subset_of_local() {
        let fd = partition(&kg(), 3, 1);
        for c in &fd.clients {
            let sh = fd.shared_entities_of(c.id);
            let local: std::collections::HashSet<u32> = c.entities.iter().copied().collect();
            assert!(sh.iter().all(|e| local.contains(e)));
        }
    }

    #[test]
    fn more_clients_more_sharing_ratio() {
        // with more clients each entity tends to be spread wider — the R10
        // vs R3 effect that amplifies FedS savings in the paper
        let k = kg();
        let f3 = partition(&k, 3, 1);
        let f6 = partition(&k, 6, 1);
        let avg_owners = |f: &FedDataset| {
            let total: usize = f.owners.iter().map(|o| o.len()).sum();
            total as f64 / f.num_entities as f64
        };
        assert!(avg_owners(&f6) >= avg_owners(&f3));
    }

    #[test]
    fn streamed_partition_matches_materialized() {
        let cfg = GeneratorConfig {
            num_entities: 256,
            num_relations: 12,
            num_triples: 3000,
            num_clusters: 4,
            seed: 3,
            ..Default::default()
        };
        let batch = partition(&generate(&cfg), 3, 9);
        let s = crate::data::generator::stream(&cfg);
        let streamed = partition_stream(cfg.num_entities, cfg.num_relations, s, 3, 9);
        assert_eq!(streamed.num_entities, batch.num_entities);
        assert_eq!(streamed.shared, batch.shared);
        assert_eq!(streamed.owners, batch.owners);
        for (s, b) in streamed.clients.iter().zip(&batch.clients) {
            assert_eq!(s.train, b.train);
            assert_eq!(s.valid, b.valid);
            assert_eq!(s.test, b.test);
            assert_eq!(s.entities, b.entities);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let k = kg();
        let a = partition(&k, 3, 9);
        let b = partition(&k, 3, 9);
        for (x, y) in a.clients.iter().zip(&b.clients) {
            assert_eq!(x.train, y.train);
        }
    }

    #[test]
    fn weights_sum_to_one() {
        let fd = partition(&kg(), 4, 2);
        let s: f64 = fd.test_weights().iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}
