//! Run history + the paper's communication-efficiency metrics
//! (P@CG, P@99, P@98, R@CG), computed exactly as defined in §IV-B.

use super::RankMetrics;

/// One evaluated communication round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// cumulative transmitted parameters (both directions, all clients)
    pub params_cum: u64,
    /// cumulative transmitted bytes on the simulated wire
    pub bytes_cum: u64,
    pub valid: RankMetrics,
    pub test: RankMetrics,
    pub mean_loss: f64,
}

/// Full history of one federated run.
#[derive(Clone, Debug, Default)]
pub struct RunHistory {
    pub records: Vec<RoundRecord>,
    /// index into `records` of the convergence point (best valid MRR)
    pub converged_idx: Option<usize>,
    pub label: String,
}

impl RunHistory {
    pub fn new(label: &str) -> Self {
        Self { label: label.to_string(), ..Default::default() }
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.records.push(rec);
    }

    pub fn mark_converged(&mut self, idx: usize) {
        self.converged_idx = Some(idx);
    }

    pub fn converged(&self) -> &RoundRecord {
        let idx = self.converged_idx.unwrap_or(self.records.len().saturating_sub(1));
        &self.records[idx]
    }

    /// MRR at convergence (test set) — the table's "MRR".
    pub fn mrr_cg(&self) -> f64 {
        self.converged().test.mrr
    }

    pub fn hits10_cg(&self) -> f64 {
        self.converged().test.hits10
    }

    /// R@CG: communication rounds at convergence.
    pub fn rounds_cg(&self) -> usize {
        self.converged().round
    }

    /// P@CG: total transmitted parameters at convergence.
    pub fn params_cg(&self) -> u64 {
        self.converged().params_cum
    }

    /// Cumulative transmitted parameters when first reaching `target` test
    /// MRR (None if never reached) — the building block of P@99/P@98.
    pub fn params_at_mrr(&self, target: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| r.test.mrr >= target)
            .map(|r| r.params_cum)
    }

    pub fn rounds_at_mrr(&self, target: f64) -> Option<usize> {
        self.records
            .iter()
            .find(|r| r.test.mrr >= target)
            .map(|r| r.round)
    }
}

/// The paper's scaled metrics of a model run against a baseline run
/// (baseline = FedEP in Tables I/III): every value is `model / baseline`.
#[derive(Clone, Debug)]
pub struct EfficiencyReport {
    pub p_cg: f64,
    pub p99: Option<f64>,
    pub p98: Option<f64>,
    pub r_cg: usize,
    pub mrr: f64,
    pub hits10: f64,
}

pub fn efficiency(model: &RunHistory, baseline: &RunHistory) -> EfficiencyReport {
    let base_mrr = baseline.mrr_cg();
    let base_p_cg = baseline.params_cg().max(1) as f64;
    let p99 = match (
        model.params_at_mrr(0.99 * base_mrr),
        baseline.params_at_mrr(0.99 * base_mrr),
    ) {
        (Some(m), Some(b)) => Some(m as f64 / b.max(1) as f64),
        _ => None,
    };
    let p98 = match (
        model.params_at_mrr(0.98 * base_mrr),
        baseline.params_at_mrr(0.98 * base_mrr),
    ) {
        (Some(m), Some(b)) => Some(m as f64 / b.max(1) as f64),
        _ => None,
    };
    EfficiencyReport {
        p_cg: model.params_cg() as f64 / base_p_cg,
        p99,
        p98,
        r_cg: model.rounds_cg(),
        mrr: model.mrr_cg(),
        hits10: model.hits10_cg(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, params: u64, mrr: f64) -> RoundRecord {
        let m = RankMetrics { n: 1, mrr, hits1: 0.0, hits3: 0.0, hits10: mrr + 0.2 };
        RoundRecord {
            round,
            params_cum: params,
            bytes_cum: params * 4,
            valid: m,
            test: m,
            mean_loss: 0.0,
        }
    }

    fn history(label: &str, recs: Vec<RoundRecord>, cg: usize) -> RunHistory {
        let mut h = RunHistory::new(label);
        for r in recs {
            h.push(r);
        }
        h.mark_converged(cg);
        h
    }

    #[test]
    fn params_at_mrr_finds_first_crossing() {
        let h = history(
            "m",
            vec![rec(5, 100, 0.1), rec(10, 200, 0.3), rec(15, 300, 0.35)],
            2,
        );
        assert_eq!(h.params_at_mrr(0.25), Some(200));
        assert_eq!(h.params_at_mrr(0.5), None);
        assert_eq!(h.rounds_at_mrr(0.25), Some(10));
    }

    #[test]
    fn converged_metrics() {
        let h = history("m", vec![rec(5, 100, 0.1), rec(10, 200, 0.4), rec(15, 300, 0.38)], 1);
        assert_eq!(h.mrr_cg(), 0.4);
        assert_eq!(h.rounds_cg(), 10);
        assert_eq!(h.params_cg(), 200);
    }

    #[test]
    fn efficiency_ratios() {
        let base = history(
            "fedep",
            vec![rec(5, 1000, 0.2), rec(10, 2000, 0.39), rec(15, 3000, 0.4)],
            2,
        );
        let model = history(
            "feds",
            vec![rec(5, 400, 0.2), rec(10, 800, 0.396), rec(15, 1200, 0.41)],
            2,
        );
        let e = efficiency(&model, &base);
        assert!((e.p_cg - 1200.0 / 3000.0).abs() < 1e-9);
        // 99% of 0.4 = 0.396: model at 800, base at 3000
        assert!((e.p99.unwrap() - 800.0 / 3000.0).abs() < 1e-9);
        // 98% of 0.4 = 0.392: model at 800, base at 2000 (0.39 < 0.392 → round 15? no: 0.39 < 0.392, so base first reaches at 0.4 → 3000)
        assert!((e.p98.unwrap() - 800.0 / 3000.0).abs() < 1e-9);
        assert_eq!(e.r_cg, 15);
    }

    #[test]
    fn default_converged_is_last() {
        let mut h = RunHistory::new("x");
        h.push(rec(1, 10, 0.5));
        h.push(rec(2, 20, 0.6));
        assert_eq!(h.rounds_cg(), 2);
    }
}
