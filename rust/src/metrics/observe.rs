//! The run observer pipeline: the orchestrator's round loop emits typed
//! [`RunEvent`]s; registered [`RunObserver`]s turn them into whatever a
//! consumer needs — the in-memory [`HistoryObserver`] assembles the
//! [`RunHistory`] every outcome carries, [`ConsoleObserver`] prints the
//! per-evaluation progress line, and [`JsonlSink`] streams one JSON object
//! per event so downstream tooling consumes metrics without scraping
//! stdout.
//!
//! Events are emitted on the coordinator thread in a deterministic order
//! (identical for sequential and threaded execution), so observers need no
//! synchronization and see bit-identical payloads across exec modes.

use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

use super::tracker::{RoundRecord, RunHistory};
use super::RankMetrics;

/// One typed event from the federated round loop.
///
/// Cumulative counters (`params_cum`, `bytes_cum`, `messages`) are
/// snapshots of the run's communication accounting at the emission point;
/// they are deterministic in both execution modes because uploads are
/// received and downloads sent in client-id order with the control plane
/// pacing every client.
#[derive(Clone, Debug)]
pub enum RunEvent {
    /// Emitted once before the first round.
    RunStart {
        label: String,
        clients: usize,
        /// entity-embedding row width of this run
        width: usize,
    },
    /// A communication round is beginning (1-based).
    RoundStart { round: usize },
    /// All of this round's uploads have been received and metered.
    UploadAccounted {
        round: usize,
        params_cum: u64,
        bytes_cum: u64,
        messages: u64,
    },
    /// The round's communication phase completed: downloads metered and
    /// (in sequential mode) folded into every client.
    Synced {
        round: usize,
        params_cum: u64,
        bytes_cum: u64,
    },
    /// An evaluation round produced a full metric record.
    Evaluated { record: RoundRecord },
    /// A cluster client completed the handshake and entered the
    /// federation (`rejoin` when it re-registered after a dropout and
    /// had the cached personalized download replayed).
    ClientJoined {
        round: usize,
        client: usize,
        rejoin: bool,
    },
    /// A cluster client's connection ended (`clean` distinguishes a
    /// graceful leave from a mid-frame crash — see
    /// [`crate::comm::Disconnect`]).
    ClientDropped {
        round: usize,
        client: usize,
        clean: bool,
    },
    /// The round deadline expired before every live client reported; the
    /// server aggregated partially over the `reported` of `expected`.
    PartialRound {
        round: usize,
        reported: usize,
        expected: usize,
    },
    /// The coordinator sampled this client into the round (emitted only
    /// when the spec's participation policy is not `Full`).
    ClientSampled { round: usize, client: usize },
    /// The coordinator wrote a round-boundary checkpoint (`bytes` is the
    /// snapshot file size after the atomic rename).
    CheckpointWritten { round: usize, bytes: u64 },
    /// A previously admitted client re-registered on a fresh socket after
    /// losing its connection (reconnect backoff path, not a new join).
    ClientReconnected { round: usize, client: usize },
    /// The convergence point is known (index into the evaluated records —
    /// the best validation MRR so far, exactly the legacy early-stop rule).
    Converged { record_index: usize },
    /// Emitted once after the loop with final accounting totals.
    RunEnd {
        params: u64,
        bytes: u64,
        messages: u64,
    },
}

impl RunEvent {
    /// One flat JSON object per event (the JSONL wire format).
    pub fn to_json(&self) -> Json {
        match self {
            RunEvent::RunStart { label, clients, width } => Json::obj()
                .set("event", "run_start")
                .set("label", label.as_str())
                .set("clients", *clients)
                .set("width", *width),
            RunEvent::RoundStart { round } => {
                Json::obj().set("event", "round_start").set("round", *round)
            }
            RunEvent::UploadAccounted { round, params_cum, bytes_cum, messages } => Json::obj()
                .set("event", "upload_accounted")
                .set("round", *round)
                .set("params_cum", *params_cum)
                .set("bytes_cum", *bytes_cum)
                .set("messages", *messages),
            RunEvent::Synced { round, params_cum, bytes_cum } => Json::obj()
                .set("event", "synced")
                .set("round", *round)
                .set("params_cum", *params_cum)
                .set("bytes_cum", *bytes_cum),
            RunEvent::Evaluated { record } => {
                let rank = |m: &RankMetrics| {
                    Json::obj()
                        .set("n", m.n)
                        .set("mrr", m.mrr)
                        .set("hits1", m.hits1)
                        .set("hits3", m.hits3)
                        .set("hits10", m.hits10)
                };
                Json::obj()
                    .set("event", "evaluated")
                    .set("round", record.round)
                    .set("mean_loss", record.mean_loss)
                    .set("params_cum", record.params_cum)
                    .set("bytes_cum", record.bytes_cum)
                    .set("valid", rank(&record.valid))
                    .set("test", rank(&record.test))
            }
            RunEvent::ClientJoined { round, client, rejoin } => Json::obj()
                .set("event", "client_joined")
                .set("round", *round)
                .set("client", *client)
                .set("rejoin", *rejoin),
            RunEvent::ClientDropped { round, client, clean } => Json::obj()
                .set("event", "client_dropped")
                .set("round", *round)
                .set("client", *client)
                .set("clean", *clean),
            RunEvent::PartialRound { round, reported, expected } => Json::obj()
                .set("event", "partial_round")
                .set("round", *round)
                .set("reported", *reported)
                .set("expected", *expected),
            RunEvent::ClientSampled { round, client } => Json::obj()
                .set("event", "client_sampled")
                .set("round", *round)
                .set("client", *client),
            RunEvent::CheckpointWritten { round, bytes } => Json::obj()
                .set("event", "checkpoint_written")
                .set("round", *round)
                .set("bytes", *bytes),
            RunEvent::ClientReconnected { round, client } => Json::obj()
                .set("event", "client_reconnected")
                .set("round", *round)
                .set("client", *client),
            RunEvent::Converged { record_index } => Json::obj()
                .set("event", "converged")
                .set("record_index", *record_index),
            RunEvent::RunEnd { params, bytes, messages } => Json::obj()
                .set("event", "run_end")
                .set("params", *params)
                .set("bytes", *bytes)
                .set("messages", *messages),
        }
    }
}

/// A consumer of run events.  Observers run on the coordinator thread;
/// `on_event` must not block on the clients.
pub trait RunObserver {
    fn on_event(&mut self, ev: &RunEvent);
}

/// Deliver `ev` to every observer, in registration order.
pub fn emit(observers: &mut [&mut dyn RunObserver], ev: &RunEvent) {
    for o in observers.iter_mut() {
        o.on_event(ev);
    }
}

/// Assembles the [`RunHistory`] a [`crate::fed::RunOutcome`] carries:
/// `Evaluated` pushes a record, `Converged` marks the convergence index.
/// The engine registers one of these on every run, so the outcome is
/// observer-assembled rather than hard-wired into the round loop.
#[derive(Default)]
pub struct HistoryObserver {
    history: RunHistory,
}

impl HistoryObserver {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take the assembled history out (leaves an empty one behind).
    pub fn take(&mut self) -> RunHistory {
        std::mem::take(&mut self.history)
    }

    pub fn history(&self) -> &RunHistory {
        &self.history
    }
}

impl RunObserver for HistoryObserver {
    fn on_event(&mut self, ev: &RunEvent) {
        match ev {
            RunEvent::RunStart { label, .. } => self.history = RunHistory::new(label),
            RunEvent::Evaluated { record } => self.history.push(record.clone()),
            RunEvent::Converged { record_index } => self.history.mark_converged(*record_index),
            _ => {}
        }
    }
}

/// Console progress: the per-evaluation `info!` line the round loop used
/// to print inline, now just another observer.
#[derive(Default)]
pub struct ConsoleObserver {
    label: String,
}

impl ConsoleObserver {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RunObserver for ConsoleObserver {
    fn on_event(&mut self, ev: &RunEvent) {
        match ev {
            RunEvent::RunStart { label, .. } => self.label = label.clone(),
            RunEvent::Evaluated { record } => {
                crate::info!(
                    "{} round {}: loss {:.4} valid MRR {:.4} test MRR {:.4} \
                     params {:.2}M",
                    self.label,
                    record.round,
                    record.mean_loss,
                    record.valid.mrr,
                    record.test.mrr,
                    record.params_cum as f64 / 1e6
                );
            }
            RunEvent::ClientJoined { round, client, rejoin } => {
                let how = if *rejoin { "rejoined (resynced)" } else { "joined" };
                crate::info!("{} round {}: client {} {}", self.label, round, client, how);
            }
            RunEvent::ClientDropped { round, client, clean } => {
                let how = if *clean { "left" } else { "dropped" };
                crate::info!("{} round {}: client {} {}", self.label, round, client, how);
            }
            RunEvent::PartialRound { round, reported, expected } => {
                crate::info!(
                    "{} round {}: partial aggregation over {}/{} clients",
                    self.label,
                    round,
                    reported,
                    expected
                );
            }
            RunEvent::CheckpointWritten { round, bytes } => {
                crate::info!(
                    "{} round {}: checkpoint written ({} bytes)",
                    self.label,
                    round,
                    bytes
                );
            }
            RunEvent::ClientReconnected { round, client } => {
                crate::info!("{} round {}: client {} reconnected", self.label, round, client);
            }
            _ => {}
        }
    }
}

/// Streams every event as one JSON line.  Multiple runs may share a sink
/// (a sweep appends each run's stream; `run_start` lines delimit them).
/// IO errors are logged once and further writes dropped — metrics
/// streaming must never abort training.
pub struct JsonlSink<W: Write> {
    w: W,
    failed: bool,
}

impl JsonlSink<std::io::BufWriter<std::fs::File>> {
    /// Create (truncate) `path` and stream events into it.
    pub fn create(path: &Path) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let f = std::fs::File::create(path)?;
        Ok(Self::new(std::io::BufWriter::new(f)))
    }

    /// Append to an existing stream (creating it when absent) — the
    /// resumable-sweep mode, where completed runs' events must survive.
    /// If the file ends mid-line (a crashed run), a newline is inserted
    /// first so the partial line cannot corrupt the next event.
    pub fn append(path: &Path) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let needs_newline = {
            use std::io::{Read as _, Seek as _, SeekFrom};
            std::fs::File::open(path)
                .ok()
                .and_then(|mut f| {
                    if f.seek(SeekFrom::End(0)).ok()? == 0 {
                        return Some(false);
                    }
                    f.seek(SeekFrom::End(-1)).ok()?;
                    let mut last = [0u8; 1];
                    f.read_exact(&mut last).ok()?;
                    Some(last[0] != b'\n')
                })
                .unwrap_or(false)
        };
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        if needs_newline {
            f.write_all(b"\n")?;
        }
        Ok(Self::new(std::io::BufWriter::new(f)))
    }
}

impl<W: Write> JsonlSink<W> {
    pub fn new(w: W) -> Self {
        Self { w, failed: false }
    }

    fn write_line(&mut self, line: String) {
        if self.failed {
            return;
        }
        if let Err(e) = self.w.write_all(line.as_bytes()).and_then(|()| self.w.write_all(b"\n")) {
            crate::warn_!("jsonl sink write failed ({e}); disabling metric stream");
            self.failed = true;
        }
    }
}

impl<W: Write> RunObserver for JsonlSink<W> {
    fn on_event(&mut self, ev: &RunEvent) {
        self.write_line(ev.to_json().to_string());
        // checkpoint lines flush eagerly so an external watcher (the
        // crash drills) sees the boundary before any kill lands
        let boundary = matches!(ev, RunEvent::RunEnd { .. } | RunEvent::CheckpointWritten { .. });
        if boundary && !self.failed {
            if let Err(e) = self.w.flush() {
                crate::warn_!("jsonl sink flush failed ({e})");
                self.failed = true;
            }
        }
    }
}

impl<W: Write> Drop for JsonlSink<W> {
    fn drop(&mut self) {
        if !self.failed {
            let _ = self.w.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: usize, mrr: f64, params: u64) -> RoundRecord {
        let m = RankMetrics { n: 2, mrr, hits1: 0.0, hits3: 0.0, hits10: mrr };
        RoundRecord {
            round,
            params_cum: params,
            bytes_cum: params * 4,
            valid: m,
            test: m,
            mean_loss: 0.5,
        }
    }

    #[test]
    fn history_observer_assembles_runs() {
        let mut h = HistoryObserver::new();
        h.on_event(&RunEvent::RunStart { label: "t".into(), clients: 3, width: 8 });
        h.on_event(&RunEvent::RoundStart { round: 1 });
        h.on_event(&RunEvent::Evaluated { record: record(2, 0.3, 100) });
        h.on_event(&RunEvent::Evaluated { record: record(4, 0.4, 200) });
        h.on_event(&RunEvent::Converged { record_index: 1 });
        let hist = h.take();
        assert_eq!(hist.label, "t");
        assert_eq!(hist.records.len(), 2);
        assert_eq!(hist.converged_idx, Some(1));
        assert_eq!(hist.rounds_cg(), 4);
        assert_eq!(hist.params_cg(), 200);
    }

    #[test]
    fn jsonl_sink_emits_parseable_lines() {
        let mut buf = Vec::new();
        {
            let mut sink = JsonlSink::new(&mut buf);
            sink.on_event(&RunEvent::RunStart { label: "x".into(), clients: 2, width: 4 });
            sink.on_event(&RunEvent::Evaluated { record: record(5, 0.25, 64) });
            sink.on_event(&RunEvent::RunEnd { params: 64, bytes: 256, messages: 4 });
        }
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("event").unwrap().as_str(), Some("run_start"));
        let eval = Json::parse(lines[1]).unwrap();
        assert_eq!(eval.get("round").unwrap().as_usize(), Some(5));
        assert_eq!(
            eval.get("valid").unwrap().get("mrr").unwrap().as_f64(),
            Some(0.25)
        );
        let end = Json::parse(lines[2]).unwrap();
        assert_eq!(end.get("messages").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn every_event_serializes_with_a_tag() {
        let evs = [
            RunEvent::RunStart { label: "l".into(), clients: 1, width: 2 },
            RunEvent::RoundStart { round: 1 },
            RunEvent::UploadAccounted { round: 1, params_cum: 2, bytes_cum: 3, messages: 4 },
            RunEvent::Synced { round: 1, params_cum: 5, bytes_cum: 6 },
            RunEvent::Evaluated { record: record(1, 0.1, 7) },
            RunEvent::ClientJoined { round: 3, client: 1, rejoin: true },
            RunEvent::ClientDropped { round: 2, client: 0, clean: false },
            RunEvent::PartialRound { round: 2, reported: 2, expected: 3 },
            RunEvent::ClientSampled { round: 4, client: 2 },
            RunEvent::CheckpointWritten { round: 4, bytes: 4096 },
            RunEvent::ClientReconnected { round: 5, client: 1 },
            RunEvent::Converged { record_index: 0 },
            RunEvent::RunEnd { params: 8, bytes: 9, messages: 10 },
        ];
        for ev in &evs {
            let j = ev.to_json();
            assert!(j.get("event").and_then(Json::as_str).is_some(), "{ev:?}");
            // the wire form round-trips through the parser
            assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        }
    }
}
