//! Link-prediction metrics and the paper's communication-efficiency metrics.
//!
//! §IV-B of the paper defines: MRR and Hits@10 at convergence (weighted
//! across clients by test-triple share), **P@CG** (total transmitted
//! parameters at convergence), **P@99 / P@98** (transmitted parameters when
//! first reaching 99%/98% of the *baseline's* converged MRR, as a ratio to
//! the baseline), and **R@CG** (communication rounds at convergence).

pub mod early_stop;
pub mod observe;
pub mod tracker;

pub use early_stop::EarlyStop;
pub use observe::{ConsoleObserver, HistoryObserver, JsonlSink, RunEvent, RunObserver};
pub use tracker::{RoundRecord, RunHistory};

/// Ranking metrics accumulated from filtered ranks.
#[derive(Clone, Copy, Debug, Default)]
pub struct RankMetrics {
    pub n: usize,
    pub mrr: f64,
    pub hits1: f64,
    pub hits3: f64,
    pub hits10: f64,
}

impl RankMetrics {
    pub fn from_ranks(ranks: &[f32]) -> Self {
        let mut m = RankMetrics { n: ranks.len(), ..Default::default() };
        if ranks.is_empty() {
            return m;
        }
        for &r in ranks {
            let r = r as f64;
            m.mrr += 1.0 / r;
            if r <= 1.0 {
                m.hits1 += 1.0;
            }
            if r <= 3.0 {
                m.hits3 += 1.0;
            }
            if r <= 10.0 {
                m.hits10 += 1.0;
            }
        }
        let n = ranks.len() as f64;
        m.mrr /= n;
        m.hits1 /= n;
        m.hits3 /= n;
        m.hits10 /= n;
        m
    }

    pub fn merge(metrics: &[RankMetrics]) -> Self {
        let total: usize = metrics.iter().map(|m| m.n).sum();
        if total == 0 {
            return RankMetrics::default();
        }
        let mut out = RankMetrics { n: total, ..Default::default() };
        for m in metrics {
            let w = m.n as f64 / total as f64;
            out.mrr += w * m.mrr;
            out.hits1 += w * m.hits1;
            out.hits3 += w * m.hits3;
            out.hits10 += w * m.hits10;
        }
        out
    }

    /// Paper's aggregation: weighted average over clients with weights
    /// proportional to triple counts.
    pub fn weighted(per_client: &[RankMetrics], weights: &[f64]) -> Self {
        assert_eq!(per_client.len(), weights.len());
        let mut out = RankMetrics::default();
        for (m, &w) in per_client.iter().zip(weights) {
            out.n += m.n;
            out.mrr += w * m.mrr;
            out.hits1 += w * m.hits1;
            out.hits3 += w * m.hits3;
            out.hits10 += w * m.hits10;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_ranks_basics() {
        let m = RankMetrics::from_ranks(&[1.0, 2.0, 10.0, 100.0]);
        assert!((m.mrr - (1.0 + 0.5 + 0.1 + 0.01) / 4.0).abs() < 1e-9);
        assert!((m.hits1 - 0.25).abs() < 1e-9);
        assert!((m.hits10 - 0.75).abs() < 1e-9);
        assert_eq!(m.n, 4);
    }

    #[test]
    fn empty_ranks() {
        let m = RankMetrics::from_ranks(&[]);
        assert_eq!(m.n, 0);
        assert_eq!(m.mrr, 0.0);
    }

    #[test]
    fn merge_weighted_by_counts() {
        let a = RankMetrics::from_ranks(&[1.0, 1.0]); // mrr 1.0, n 2
        let b = RankMetrics::from_ranks(&[2.0]);      // mrr 0.5, n 1
        let m = RankMetrics::merge(&[a, b]);
        assert!((m.mrr - (2.0 * 1.0 + 1.0 * 0.5) / 3.0).abs() < 1e-9);
        assert_eq!(m.n, 3);
    }

    #[test]
    fn weighted_uses_given_weights() {
        let a = RankMetrics::from_ranks(&[1.0]);
        let b = RankMetrics::from_ranks(&[4.0]);
        let m = RankMetrics::weighted(&[a, b], &[0.75, 0.25]);
        assert!((m.mrr - (0.75 * 1.0 + 0.25 * 0.25)).abs() < 1e-9);
    }

    #[test]
    fn perfect_ranks() {
        let m = RankMetrics::from_ranks(&[1.0; 10]);
        assert_eq!(m.mrr, 1.0);
        assert_eq!(m.hits1, 1.0);
        assert_eq!(m.hits10, 1.0);
    }
}
