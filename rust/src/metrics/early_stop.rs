//! Early stopping on validation MRR, as in the paper (§IV-B): "training
//! ceases after three consecutive declines in MRR of the validation set".
//! The convergence point (CG) is the round with the best validation MRR.

#[derive(Clone, Debug)]
pub struct EarlyStop {
    pub patience: usize,
    best: f64,
    best_index: usize,
    declines: usize,
    n_seen: usize,
}

impl EarlyStop {
    pub fn new(patience: usize) -> Self {
        Self { patience, best: f64::NEG_INFINITY, best_index: 0, declines: 0, n_seen: 0 }
    }

    /// Record a new validation score; returns `true` if training should stop.
    pub fn update(&mut self, score: f64) -> bool {
        if score > self.best {
            self.best = score;
            self.best_index = self.n_seen;
            self.declines = 0;
        } else {
            self.declines += 1;
        }
        self.n_seen += 1;
        self.declines >= self.patience
    }

    pub fn best(&self) -> f64 {
        self.best
    }

    /// Index (in update order) of the best score — the convergence point.
    pub fn best_index(&self) -> usize {
        self.best_index
    }

    pub fn evaluations(&self) -> usize {
        self.n_seen
    }

    /// Snapshot `(best, best_index, declines, n_seen)` for checkpointing.
    pub fn state(&self) -> (f64, usize, usize, usize) {
        (self.best, self.best_index, self.declines, self.n_seen)
    }

    /// Rebuild a tracker at an exact position saved by [`state`].
    ///
    /// [`state`]: EarlyStop::state
    pub fn from_state(patience: usize, state: (f64, usize, usize, usize)) -> Self {
        let (best, best_index, declines, n_seen) = state;
        Self { patience, best, best_index, declines, n_seen }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stops_after_patience_declines() {
        let mut es = EarlyStop::new(3);
        assert!(!es.update(0.5));
        assert!(!es.update(0.4));
        assert!(!es.update(0.45));
        assert!(es.update(0.3));
        assert_eq!(es.best(), 0.5);
        assert_eq!(es.best_index(), 0);
    }

    #[test]
    fn improvement_resets_counter() {
        let mut es = EarlyStop::new(2);
        assert!(!es.update(0.1));
        assert!(!es.update(0.05)); // decline 1
        assert!(!es.update(0.2));  // improvement resets
        assert!(!es.update(0.15)); // decline 1
        assert!(es.update(0.1));   // decline 2 → stop
        assert_eq!(es.best_index(), 2);
    }

    #[test]
    fn equal_score_counts_as_decline() {
        let mut es = EarlyStop::new(2);
        es.update(0.3);
        assert!(!es.update(0.3));
        assert!(es.update(0.3));
    }
}
