//! Sessions turn [`ExperimentSpec`]s into executable [`Run`] handles.
//!
//! A [`Session`] owns the expensive shared resources (today: the PJRT
//! runtime, loaded once and reused across builds — a sweep builds many
//! runs from one session).  A [`Run`] owns everything one experiment
//! needs — the generated dataset, the resolved backend and the registered
//! [`RunObserver`]s — and executes the engine through
//! [`run_params`], the only entry point.

use std::rc::Rc;

use anyhow::Result;

use crate::data::partition::FedDataset;
use crate::fed::orchestrator::run_params;
use crate::fed::{Backend, RoundParams, RunOutcome};
use crate::kge::Hyper;
use crate::metrics::observe::{ConsoleObserver, RunObserver};
use crate::runtime::Runtime;

use super::{BackendSpec, ExperimentSpec};

/// Builds runs from specs, caching the PJRT runtime across builds.
#[derive(Default)]
pub struct Session {
    xla: Option<Rc<Runtime>>,
}

impl Session {
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed the session with an already-loaded runtime (e.g. the
    /// experiment harness's).
    pub fn with_runtime(rt: Rc<Runtime>) -> Self {
        Self { xla: Some(rt) }
    }

    /// Validate `spec`, resolve its backend, generate its dataset and
    /// return the run handle.  Building is deterministic: the same spec
    /// always yields the same dataset and initial state.
    pub fn build(&mut self, spec: &ExperimentSpec) -> Result<Run> {
        spec.validate()?;
        let backend = match &spec.backend {
            BackendSpec::Xla => {
                let rt = match &self.xla {
                    Some(rt) => rt.clone(),
                    None => {
                        let rt = Runtime::load_default()?;
                        self.xla = Some(rt.clone());
                        rt
                    }
                };
                Backend::Xla(rt)
            }
            BackendSpec::Native { dim, learning_rate, batch, negatives, eval_batch } => {
                Backend::Native {
                    hyper: Hyper {
                        dim: *dim,
                        learning_rate: *learning_rate,
                        ..Default::default()
                    },
                    batch: *batch,
                    negatives: *negatives,
                    eval_batch: *eval_batch,
                }
            }
        };
        let data = spec.data.build();
        let params = RoundParams::from_spec(spec, &backend);
        Ok(Run {
            params,
            spec: spec.clone(),
            data,
            backend,
            observers: Vec::new(),
            console: true,
        })
    }
}

/// One executable experiment: dataset + backend + observers.
pub struct Run {
    spec: ExperimentSpec,
    params: RoundParams,
    data: FedDataset,
    backend: Backend,
    observers: Vec<Box<dyn RunObserver>>,
    console: bool,
}

impl Run {
    /// Register an observer; events arrive in registration order.
    pub fn observe(&mut self, o: Box<dyn RunObserver>) -> &mut Self {
        self.observers.push(o);
        self
    }

    /// Drop the default console-progress observer.
    pub fn quiet(&mut self) -> &mut Self {
        self.console = false;
        self
    }

    pub fn spec(&self) -> &ExperimentSpec {
        &self.spec
    }

    /// The generated federated dataset (inspect before executing).
    pub fn data(&self) -> &FedDataset {
        &self.data
    }

    /// The resolved parameters this run will execute.
    pub fn params(&self) -> &RoundParams {
        &self.params
    }

    /// Execute the round loop, streaming events to the registered
    /// observers, and return the observer-assembled outcome.
    pub fn execute(&mut self) -> Result<RunOutcome> {
        self.execute_with(&mut [])
    }

    /// Execute with additional borrowed observers (a sweep shares one
    /// JSONL sink across its runs this way).
    pub fn execute_with(&mut self, extra: &mut [&mut dyn RunObserver]) -> Result<RunOutcome> {
        let mut console = self.console.then(ConsoleObserver::new);
        let mut refs: Vec<&mut dyn RunObserver> =
            Vec::with_capacity(1 + self.observers.len() + extra.len());
        if let Some(c) = console.as_mut() {
            refs.push(c);
        }
        for o in self.observers.iter_mut() {
            refs.push(o.as_mut());
        }
        for o in extra.iter_mut() {
            refs.push(&mut **o);
        }
        run_params(&self.data, &self.params, &self.backend, &mut refs)
    }
}
