//! The declarative experiment API: a fully JSON-(de)serializable
//! description of a federated run.
//!
//! [`ExperimentSpec`] is the single public entry point for launching runs:
//! data ([`DataSpec`]), backend ([`BackendSpec`]), training budget
//! ([`BudgetSpec`]), and an algorithm-scoped [`AlgoSpec`] sum type where
//! each variant carries **only its own knobs** — `FedS { sparsity,
//! sync_interval, sync }`, `Svd { cols, plus }`, `Kd`, dense baselines
//! bare.  Specs validate on construction-from-JSON and before every build,
//! round-trip exactly through [`crate::util::json::Json`], and support
//! dotted-key overrides (`"algo.sparsity"`, `"data.clients"`,
//! `"budget.max_rounds"`, `"transport"`, `"shards"`) — the one mechanism
//! behind both CLI flag overrides and sweep axes (`crate::exp::sweep`).
//!
//! Specs are the only way to launch runs: [`Session::build`] derives the
//! orchestrator's resolved [`crate::fed::RoundParams`] directly from the
//! spec and the resolved backend.

pub mod session;

pub use session::{Run, Session};

use anyhow::{bail, ensure, Result};

use crate::data::generator::{stream, GeneratorConfig};
use crate::data::partition::{partition_stream, FedDataset};
use crate::fed::compression::PipelineSpec;
use crate::fed::{Algo, ExecMode};
use crate::kge::Method;
use crate::store::StorageSpec;
use crate::util::json::Json;

pub use crate::comm::transport::TransportSpec;

/// Seeds ride in JSON numbers (f64), which are exact only up to 2^53;
/// larger seeds would silently corrupt on a round-trip, so validation
/// rejects them.
const MAX_JSON_SEED: u64 = 1 << 53;

/// Which algorithm runs, carrying only that algorithm's knobs.
#[derive(Clone, Debug, PartialEq)]
pub enum AlgoSpec {
    /// Local training only, no communication.
    Single,
    /// Dense FedE with personalized evaluation.
    FedEP,
    /// FedEP at the Appendix VI-C reduced dimension (volume-matched to
    /// FedS at the paper-default p=0.4, s=4).
    FedEPL,
    /// Entity-Wise Top-K sparsification + Intermittent Synchronization.
    FedS {
        /// sparsity ratio p ∈ (0, 1]
        sparsity: f64,
        /// synchronization interval s ≥ 1
        sync_interval: usize,
        /// `false` runs the FedS/syn ablation (no synchronization)
        sync: bool,
    },
    /// Dual-dimension co-distillation transport (XLA backend only).
    Kd,
    /// SVD-compressed update transport; `plus` adds the low-rank training
    /// constraint (FedE-SVD+).
    Svd {
        /// columns of the SVD reshape ≥ 1
        cols: usize,
        plus: bool,
    },
}

impl AlgoSpec {
    /// Paper-default knobs for each family.
    pub fn feds() -> Self {
        AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: true }
    }

    pub fn svd() -> Self {
        AlgoSpec::Svd { cols: 8, plus: false }
    }

    /// The CLI label set (same vocabulary as [`Algo::parse`]), yielding
    /// paper-default knobs for knobbed families.
    pub fn parse(s: &str) -> Result<AlgoSpec> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "single" => AlgoSpec::Single,
            "fedep" | "fede" => AlgoSpec::FedEP,
            "fedepl" => AlgoSpec::FedEPL,
            "feds" => AlgoSpec::feds(),
            "feds-nosync" | "feds/syn" => {
                AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: false }
            }
            "fedkd" | "fede-kd" | "kd" => AlgoSpec::Kd,
            "fedsvd" | "fede-svd" | "svd" => AlgoSpec::svd(),
            "fedsvd+" | "fede-svd+" | "svd+" => AlgoSpec::Svd { cols: 8, plus: true },
            other => bail!(
                "unknown algorithm '{other}' \
                 (single|fedep|fedepl|feds|feds-nosync|fedkd|fedsvd|fedsvd+)"
            ),
        })
    }

    /// The JSON `kind` tag.
    pub fn kind(&self) -> &'static str {
        match self {
            AlgoSpec::Single => "single",
            AlgoSpec::FedEP => "fedep",
            AlgoSpec::FedEPL => "fedepl",
            AlgoSpec::FedS { .. } => "feds",
            AlgoSpec::Kd => "kd",
            AlgoSpec::Svd { .. } => "svd",
        }
    }

    /// The resolved orchestrator algorithm.
    pub fn algo(&self) -> Algo {
        match self {
            AlgoSpec::Single => Algo::Single,
            AlgoSpec::FedEP => Algo::FedEP,
            AlgoSpec::FedEPL => Algo::FedEPL,
            AlgoSpec::FedS { sync, .. } => Algo::FedS { sync: *sync },
            AlgoSpec::Kd => Algo::FedKd,
            AlgoSpec::Svd { plus, .. } => Algo::FedSvd { constrained: *plus },
        }
    }

    pub fn label(&self) -> &'static str {
        self.algo().label()
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            AlgoSpec::FedS { sparsity, sync_interval, .. } => {
                ensure!(
                    sparsity.is_finite() && *sparsity > 0.0 && *sparsity <= 1.0,
                    "algo.sparsity must lie in (0, 1], got {sparsity}"
                );
                ensure!(*sync_interval >= 1, "algo.sync_interval must be ≥ 1, got 0");
            }
            AlgoSpec::Svd { cols, .. } => {
                ensure!(*cols >= 1, "algo.cols must be ≥ 1, got 0");
            }
            _ => {}
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let j = Json::obj().set("kind", self.kind());
        match self {
            AlgoSpec::FedS { sparsity, sync_interval, sync } => j
                .set("sparsity", *sparsity)
                .set("sync_interval", *sync_interval)
                .set("sync", *sync),
            AlgoSpec::Svd { cols, plus } => j.set("cols", *cols).set("plus", *plus),
            _ => j,
        }
    }

    /// Accepts either a bare label string (`"feds"`) or the tagged object
    /// form.  Knobs on the object form are scoped: a knob on a variant
    /// that does not own it is an error, not silently ignored.
    pub fn from_json(v: &Json) -> Result<AlgoSpec> {
        if let Some(label) = v.as_str() {
            return AlgoSpec::parse(label);
        }
        let entries = v
            .obj_entries()
            .ok_or_else(|| anyhow::anyhow!("algo must be a label string or an object"))?;
        let kind = v
            .req("kind")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("algo.kind must be a string"))?;
        let allowed: &[&str] = match kind {
            "feds" => &["kind", "sparsity", "sync_interval", "sync"],
            "svd" => &["kind", "cols", "plus"],
            "single" | "fedep" | "fedepl" | "kd" => &["kind"],
            other => bail!("unknown algo kind '{other}' (single|fedep|fedepl|feds|kd|svd)"),
        };
        for (k, _) in entries {
            ensure!(
                allowed.contains(&k.as_str()),
                "knob '{k}' does not belong to algo kind '{kind}' \
                 (each variant carries only its own knobs)"
            );
        }
        let spec = match kind {
            "single" => AlgoSpec::Single,
            "fedep" => AlgoSpec::FedEP,
            "fedepl" => AlgoSpec::FedEPL,
            "kd" => AlgoSpec::Kd,
            "feds" => {
                let AlgoSpec::FedS { sparsity, sync_interval, sync } = AlgoSpec::feds() else {
                    unreachable!()
                };
                AlgoSpec::FedS {
                    sparsity: opt_f64(v, "sparsity")?.unwrap_or(sparsity),
                    sync_interval: opt_count(v, "sync_interval")?.unwrap_or(sync_interval),
                    sync: opt_bool(v, "sync")?.unwrap_or(sync),
                }
            }
            "svd" => AlgoSpec::Svd {
                cols: opt_count(v, "cols")?.unwrap_or(8),
                plus: opt_bool(v, "plus")?.unwrap_or(false),
            },
            _ => unreachable!(),
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Per-round client participation policy: which live clients the cluster
/// coordinator samples into each communication round.  Stragglers become
/// a *policy* (the paper's unreliable-link regime) instead of only a
/// failure mode; non-sampled rounds reuse the `PartialRound`
/// aggregation/renormalization machinery.  The in-process engine always
/// runs `Full`; sampling is enforced by `feds serve`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum ParticipationSpec {
    /// every live client, every round (the default)
    #[default]
    Full,
    /// each round samples ⌈fraction × live⌉ clients, fraction ∈ (0, 1]
    Fraction(f64),
    /// each round samples min(k, live) clients, k ≥ 1
    KofN(usize),
}

impl ParticipationSpec {
    pub fn validate(&self) -> Result<()> {
        match self {
            ParticipationSpec::Full => {}
            ParticipationSpec::Fraction(f) => ensure!(
                f.is_finite() && *f > 0.0 && *f <= 1.0,
                "participation fraction must lie in (0, 1], got {f}"
            ),
            ParticipationSpec::KofN(k) => {
                ensure!(*k >= 1, "participation.k must be ≥ 1, got 0")
            }
        }
        Ok(())
    }

    /// How many of `live` clients a round samples (`live` when full).
    pub fn sample_size(&self, live: usize) -> usize {
        match self {
            ParticipationSpec::Full => live,
            ParticipationSpec::Fraction(f) => {
                let k = (*f * live as f64).ceil() as usize;
                k.clamp(usize::from(live > 0), live)
            }
            ParticipationSpec::KofN(k) => (*k).min(live),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ParticipationSpec::Full => Json::from("full"),
            ParticipationSpec::Fraction(f) => {
                Json::obj().set("kind", "fraction").set("fraction", *f)
            }
            ParticipationSpec::KofN(k) => Json::obj().set("kind", "k_of_n").set("k", *k),
        }
    }

    /// Accepts the bare label `"full"` or the tagged object form.
    pub fn from_json(v: &Json) -> Result<ParticipationSpec> {
        if let Some(label) = v.as_str() {
            ensure!(
                label == "full",
                "unknown participation label '{label}' (full, or an object with kind \
                 fraction|k_of_n)"
            );
            return Ok(ParticipationSpec::Full);
        }
        let kind = v
            .req("kind")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("participation.kind must be a string"))?;
        let spec = match kind {
            "full" => ParticipationSpec::Full,
            "fraction" => ParticipationSpec::Fraction(
                opt_f64(v, "fraction")?
                    .ok_or_else(|| anyhow::anyhow!("participation.fraction is required"))?,
            ),
            "k_of_n" => ParticipationSpec::KofN(
                opt_count(v, "k")?.ok_or_else(|| anyhow::anyhow!("participation.k is required"))?,
            ),
            other => bail!("unknown participation kind '{other}' (full|fraction|k_of_n)"),
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// The dataset of a run: synthetic-KG generation plus relation
/// partitioning, deterministic in `seed`.
#[derive(Clone, Debug, PartialEq)]
pub struct DataSpec {
    pub entities: usize,
    pub relations: usize,
    pub triples: usize,
    pub clusters: usize,
    /// number of clients of the relation partition
    pub clients: usize,
    /// generation + partition seed
    pub seed: u64,
}

impl DataSpec {
    pub fn generator(&self) -> GeneratorConfig {
        GeneratorConfig {
            num_entities: self.entities,
            num_relations: self.relations,
            num_triples: self.triples,
            num_clusters: self.clusters,
            seed: self.seed,
            ..Default::default()
        }
    }

    /// Generate and partition the federated dataset.  Streams triples
    /// straight from the generator into the per-client splits — the
    /// full triple list is never materialized in one place.
    pub fn build(&self) -> FedDataset {
        let cfg = self.generator();
        partition_stream(cfg.num_entities, cfg.num_relations, stream(&cfg), self.clients, self.seed)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.clients >= 2, "data.clients must be ≥ 2, got {}", self.clients);
        ensure!(self.clusters >= 2, "data.clusters must be ≥ 2, got {}", self.clusters);
        ensure!(
            self.relations >= self.clients,
            "data.relations ({}) must be ≥ data.clients ({}) for the relation partition",
            self.relations,
            self.clients
        );
        ensure!(
            self.entities >= self.clusters * 4,
            "data.entities ({}) must be ≥ 4 × data.clusters ({})",
            self.entities,
            self.clusters
        );
        ensure!(self.triples >= 1, "data.triples must be ≥ 1");
        ensure!(
            self.seed <= MAX_JSON_SEED,
            "data.seed must be ≤ 2^53 (JSON numbers cannot represent it exactly)"
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("entities", self.entities)
            .set("relations", self.relations)
            .set("triples", self.triples)
            .set("clusters", self.clusters)
            .set("clients", self.clients)
            .set("seed", self.seed)
    }

    pub fn from_json(v: &Json) -> Result<DataSpec> {
        Ok(DataSpec {
            entities: req_count(v, "entities")?,
            relations: req_count(v, "relations")?,
            triples: req_count(v, "triples")?,
            clusters: opt_count(v, "clusters")?.unwrap_or(8),
            clients: req_count(v, "clients")?,
            seed: req_count(v, "seed")? as u64,
        })
    }
}

/// Where local training executes.
#[derive(Clone, Debug, PartialEq)]
pub enum BackendSpec {
    /// AOT artifacts via PJRT ($FEDS_ARTIFACTS or ./artifacts).
    Xla,
    /// The pure-Rust engine (artifact-free).
    Native {
        dim: usize,
        learning_rate: f32,
        batch: usize,
        negatives: usize,
        eval_batch: usize,
    },
}

impl BackendSpec {
    /// The default native backend of fast sweeps and artifact-free tests
    /// (mirrors `exp::native_backend`).
    pub fn native_default() -> Self {
        BackendSpec::Native {
            dim: 32,
            learning_rate: 3e-3,
            batch: 128,
            negatives: 32,
            eval_batch: 64,
        }
    }

    /// Describe a resolved backend (non-default `Hyper` fields beyond
    /// `dim`/`learning_rate` are not representable and fall back to
    /// defaults on rebuild).
    pub fn of(backend: &crate::fed::Backend) -> Self {
        match backend {
            crate::fed::Backend::Xla(_) => BackendSpec::Xla,
            crate::fed::Backend::Native { hyper, batch, negatives, eval_batch } => {
                BackendSpec::Native {
                    dim: hyper.dim,
                    learning_rate: hyper.learning_rate,
                    batch: *batch,
                    negatives: *negatives,
                    eval_batch: *eval_batch,
                }
            }
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            BackendSpec::Xla => "xla",
            BackendSpec::Native { .. } => "native",
        }
    }

    pub fn validate(&self) -> Result<()> {
        if let BackendSpec::Native { dim, learning_rate, batch, negatives, eval_batch } = self {
            ensure!(*dim >= 1, "backend.dim must be ≥ 1");
            ensure!(
                learning_rate.is_finite() && *learning_rate > 0.0,
                "backend.learning_rate must be a positive number, got {learning_rate}"
            );
            ensure!(*batch >= 1, "backend.batch must be ≥ 1");
            ensure!(*negatives >= 1, "backend.negatives must be ≥ 1");
            ensure!(*eval_batch >= 1, "backend.eval_batch must be ≥ 1");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        match self {
            BackendSpec::Xla => Json::obj().set("kind", "xla"),
            BackendSpec::Native { dim, learning_rate, batch, negatives, eval_batch } => Json::obj()
                .set("kind", "native")
                .set("dim", *dim)
                .set("learning_rate", *learning_rate)
                .set("batch", *batch)
                .set("negatives", *negatives)
                .set("eval_batch", *eval_batch),
        }
    }

    /// Accepts `"xla"`, `"native"` (defaults), or the tagged object form.
    pub fn from_json(v: &Json) -> Result<BackendSpec> {
        let kind = match v {
            Json::Str(s) => s.as_str(),
            Json::Obj(_) => v
                .req("kind")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("backend.kind must be a string"))?,
            _ => bail!("backend must be a kind string or an object"),
        };
        match kind {
            "xla" => Ok(BackendSpec::Xla),
            "native" => {
                let BackendSpec::Native { dim, learning_rate, batch, negatives, eval_batch } =
                    BackendSpec::native_default()
                else {
                    unreachable!()
                };
                if v.as_str().is_some() {
                    return Ok(BackendSpec::native_default());
                }
                Ok(BackendSpec::Native {
                    dim: opt_count(v, "dim")?.unwrap_or(dim),
                    learning_rate: opt_f64(v, "learning_rate")?
                        .map(|x| x as f32)
                        .unwrap_or(learning_rate),
                    batch: opt_count(v, "batch")?.unwrap_or(batch),
                    negatives: opt_count(v, "negatives")?.unwrap_or(negatives),
                    eval_batch: opt_count(v, "eval_batch")?.unwrap_or(eval_batch),
                })
            }
            other => bail!("unknown backend '{other}' (xla|native)"),
        }
    }
}

/// The training budget of a run (paper §IV-B defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct BudgetSpec {
    /// hard cap on communication rounds
    pub max_rounds: usize,
    /// local epochs per round (paper: 3)
    pub local_epochs: usize,
    /// evaluate every N rounds (paper: 5)
    pub eval_every: usize,
    /// early-stop patience in evaluations (paper: 3)
    pub patience: usize,
    /// cap on eval queries per client per split (0 = all)
    pub eval_cap: usize,
}

impl Default for BudgetSpec {
    fn default() -> Self {
        Self { max_rounds: 200, local_epochs: 3, eval_every: 5, patience: 3, eval_cap: 0 }
    }
}

impl BudgetSpec {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.max_rounds >= 1, "budget.max_rounds must be ≥ 1");
        ensure!(self.local_epochs >= 1, "budget.local_epochs must be ≥ 1");
        ensure!(self.eval_every >= 1, "budget.eval_every must be ≥ 1");
        ensure!(self.patience >= 1, "budget.patience must be ≥ 1");
        ensure!(
            self.eval_every <= self.max_rounds,
            "budget.eval_every ({}) must be ≤ budget.max_rounds ({}) so the run is \
             evaluated at least once",
            self.eval_every,
            self.max_rounds
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("max_rounds", self.max_rounds)
            .set("local_epochs", self.local_epochs)
            .set("eval_every", self.eval_every)
            .set("patience", self.patience)
            .set("eval_cap", self.eval_cap)
    }

    pub fn from_json(v: &Json) -> Result<BudgetSpec> {
        let d = BudgetSpec::default();
        Ok(BudgetSpec {
            max_rounds: opt_count(v, "max_rounds")?.unwrap_or(d.max_rounds),
            local_epochs: opt_count(v, "local_epochs")?.unwrap_or(d.local_epochs),
            eval_every: opt_count(v, "eval_every")?.unwrap_or(d.eval_every),
            patience: opt_count(v, "patience")?.unwrap_or(d.patience),
            eval_cap: opt_count(v, "eval_cap")?.unwrap_or(d.eval_cap),
        })
    }
}

/// A fully serializable description of one federated run.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentSpec {
    /// free-form run name (reports, logs); may be empty
    pub name: String,
    pub method: Method,
    pub algo: AlgoSpec,
    pub data: DataSpec,
    pub backend: BackendSpec,
    pub budget: BudgetSpec,
    /// experiment seed (client RNG streams; independent of `data.seed`)
    pub seed: u64,
    pub exec: ExecMode,
    /// which transport carries the frames (mpsc or TCP loopback) —
    /// accounting and metrics are bit-identical across variants
    pub transport: TransportSpec,
    /// server aggregation shards (0 = auto: one per core, capped);
    /// results are bit-identical for any value
    pub shards: usize,
    /// per-round client sampling policy (cluster coordinator only)
    pub participation: ParticipationSpec,
    /// backend for every O(entities × width) table ("ram", "mmap", or
    /// "mmap:<dir>") — results are bit-identical across backends
    pub storage: StorageSpec,
    /// `--compress` stage stack (e.g. "topk,int8:ef") over the dense
    /// family's delta stream; empty = plain dense frames, byte-identical
    /// to runs without the knob
    pub compression: PipelineSpec,
}

impl ExperimentSpec {
    pub fn validate(&self) -> Result<()> {
        self.algo.validate()?;
        self.data.validate()?;
        self.backend.validate()?;
        self.budget.validate()?;
        self.participation.validate()?;
        if let ParticipationSpec::KofN(k) = self.participation {
            ensure!(
                k <= self.data.clients,
                "participation.k ({k}) must be ≤ data.clients ({})",
                self.data.clients
            );
        }
        if self.algo == AlgoSpec::Kd {
            ensure!(
                self.backend == BackendSpec::Xla,
                "algo 'kd' requires the xla backend (co-distillation artifact)"
            );
        }
        ensure!(
            self.seed <= MAX_JSON_SEED,
            "seed must be ≤ 2^53 (JSON numbers cannot represent it exactly)"
        );
        self.compression.validate()?;
        if !self.compression.is_empty() {
            match &self.algo {
                AlgoSpec::FedEP | AlgoSpec::FedEPL | AlgoSpec::Kd => {}
                AlgoSpec::Single => {
                    bail!("compression requires a communicating algorithm (fedep|fedepl|kd), not 'single'")
                }
                AlgoSpec::FedS { .. } => {
                    bail!("compression does not apply to feds (it carries its own Top-K transport)")
                }
                AlgoSpec::Svd { .. } => {
                    bail!("compression does not apply to svd (it carries its own low-rank transport)")
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        if !self.name.is_empty() {
            j = j.set("name", self.name.as_str());
        }
        j = j
            .set("method", self.method.name())
            .set("algo", self.algo.to_json())
            .set("data", self.data.to_json())
            .set("backend", self.backend.to_json())
            .set("budget", self.budget.to_json())
            .set("seed", self.seed)
            .set("exec", self.exec.label())
            .set("transport", self.transport.label())
            .set("shards", self.shards)
            .set("participation", self.participation.to_json())
            .set("storage", self.storage.label().as_str());
        if !self.compression.is_empty() {
            j = j.set("compression", self.compression.label().as_str());
        }
        j
    }

    pub fn from_json(v: &Json) -> Result<ExperimentSpec> {
        let spec = ExperimentSpec {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            method: Method::parse(
                v.req("method")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("method must be a string"))?,
            )?,
            algo: AlgoSpec::from_json(v.req("algo")?)?,
            data: DataSpec::from_json(v.req("data")?)?,
            backend: BackendSpec::from_json(v.req("backend")?)?,
            budget: match v.get("budget") {
                Some(b) => BudgetSpec::from_json(b)?,
                None => BudgetSpec::default(),
            },
            seed: req_count(v, "seed")? as u64,
            exec: match v.get("exec") {
                Some(e) => ExecMode::parse(
                    e.as_str().ok_or_else(|| anyhow::anyhow!("exec must be a string"))?,
                )?,
                None => ExecMode::Sequential,
            },
            transport: match v.get("transport") {
                Some(t) => TransportSpec::parse(
                    t.as_str().ok_or_else(|| anyhow::anyhow!("transport must be a string"))?,
                )?,
                None => TransportSpec::Mpsc,
            },
            shards: opt_count(v, "shards")?.unwrap_or(0),
            participation: match v.get("participation") {
                Some(p) => ParticipationSpec::from_json(p)?,
                None => ParticipationSpec::Full,
            },
            storage: match v.get("storage") {
                Some(s) => StorageSpec::parse(
                    s.as_str().ok_or_else(|| anyhow::anyhow!("storage must be a string"))?,
                )?,
                None => StorageSpec::Ram,
            },
            compression: match v.get("compression") {
                Some(c) => PipelineSpec::parse(
                    c.as_str().ok_or_else(|| anyhow::anyhow!("compression must be a string"))?,
                )?,
                None => PipelineSpec::default(),
            },
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn parse(text: &str) -> Result<ExperimentSpec> {
        Self::from_json(&Json::parse(text)?)
    }

    /// Read and parse a spec file.
    pub fn load(path: &std::path::Path) -> Result<ExperimentSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading spec {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow::anyhow!("spec {}: {e}", path.display()))
    }

    /// Apply one dotted-key override.  Algorithm knobs are scoped: setting
    /// `algo.sparsity` on a non-FedS spec is an error, as is a native
    /// backend knob on the XLA backend.  Does not re-validate — call
    /// [`ExperimentSpec::validate`] after the last override.
    pub fn apply(&mut self, key: &str, value: &Json) -> Result<()> {
        match key {
            "name" => {
                self.name = value
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("name must be a string"))?
                    .to_string();
            }
            "method" => {
                self.method = Method::parse(
                    value.as_str().ok_or_else(|| anyhow::anyhow!("method must be a string"))?,
                )?;
            }
            "exec" => {
                self.exec = ExecMode::parse(
                    value.as_str().ok_or_else(|| anyhow::anyhow!("exec must be a string"))?,
                )?;
            }
            "transport" => {
                self.transport = TransportSpec::parse(
                    value
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("transport must be a string"))?,
                )?;
            }
            "shards" => self.shards = count_of(value, key)?,
            "seed" => self.seed = count_of(value, key)? as u64,
            "storage" => {
                self.storage = StorageSpec::parse(
                    value.as_str().ok_or_else(|| anyhow::anyhow!("storage must be a string"))?,
                )?;
            }
            "compression" => {
                self.compression = PipelineSpec::parse(
                    value
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("compression must be a string"))?,
                )?;
            }
            "participation" => self.participation = ParticipationSpec::from_json(value)?,
            "participation.fraction" => {
                self.participation = ParticipationSpec::Fraction(f64_of(value, key)?);
            }
            "participation.k" => {
                self.participation = ParticipationSpec::KofN(count_of(value, key)?);
            }
            "algo" => self.algo = AlgoSpec::from_json(value)?,
            "algo.sparsity" => match &mut self.algo {
                AlgoSpec::FedS { sparsity, .. } => *sparsity = f64_of(value, key)?,
                other => bail!("algo.sparsity only applies to feds, not '{}'", other.kind()),
            },
            "algo.sync_interval" => match &mut self.algo {
                AlgoSpec::FedS { sync_interval, .. } => *sync_interval = count_of(value, key)?,
                other => bail!("algo.sync_interval only applies to feds, not '{}'", other.kind()),
            },
            "algo.sync" => match &mut self.algo {
                AlgoSpec::FedS { sync, .. } => *sync = bool_of(value, key)?,
                other => bail!("algo.sync only applies to feds, not '{}'", other.kind()),
            },
            "algo.cols" => match &mut self.algo {
                AlgoSpec::Svd { cols, .. } => *cols = count_of(value, key)?,
                other => bail!("algo.cols only applies to svd, not '{}'", other.kind()),
            },
            "algo.plus" => match &mut self.algo {
                AlgoSpec::Svd { plus, .. } => *plus = bool_of(value, key)?,
                other => bail!("algo.plus only applies to svd, not '{}'", other.kind()),
            },
            "data.entities" => self.data.entities = count_of(value, key)?,
            "data.relations" => self.data.relations = count_of(value, key)?,
            "data.triples" => self.data.triples = count_of(value, key)?,
            "data.clusters" => self.data.clusters = count_of(value, key)?,
            "data.clients" => self.data.clients = count_of(value, key)?,
            "data.seed" => self.data.seed = count_of(value, key)? as u64,
            "backend" => {
                let new = BackendSpec::from_json(value)?;
                // restating the current kind as a bare label ("--backend
                // native" on an already-native spec) keeps the spec's
                // knobs instead of resetting them to defaults
                if value.as_str().is_none() || new.kind() != self.backend.kind() {
                    self.backend = new;
                }
            }
            "backend.dim" | "backend.learning_rate" | "backend.batch" | "backend.negatives"
            | "backend.eval_batch" => match &mut self.backend {
                BackendSpec::Native { dim, learning_rate, batch, negatives, eval_batch } => {
                    match key {
                        "backend.dim" => *dim = count_of(value, key)?,
                        "backend.learning_rate" => *learning_rate = f64_of(value, key)? as f32,
                        "backend.batch" => *batch = count_of(value, key)?,
                        "backend.negatives" => *negatives = count_of(value, key)?,
                        _ => *eval_batch = count_of(value, key)?,
                    }
                }
                BackendSpec::Xla => {
                    bail!("{key} only applies to the native backend (this spec uses xla)")
                }
            },
            "budget.max_rounds" => self.budget.max_rounds = count_of(value, key)?,
            "budget.local_epochs" => self.budget.local_epochs = count_of(value, key)?,
            "budget.eval_every" => self.budget.eval_every = count_of(value, key)?,
            "budget.patience" => self.budget.patience = count_of(value, key)?,
            "budget.eval_cap" => self.budget.eval_cap = count_of(value, key)?,
            other => bail!(
                "unknown spec key '{other}' (see spec::ExperimentSpec::apply for the key set)"
            ),
        }
        Ok(())
    }

    /// Apply an override whose value arrived as CLI text: numbers and
    /// booleans are coerced, everything else stays a string.
    pub fn apply_str(&mut self, key: &str, raw: &str) -> Result<()> {
        let value = match raw {
            "true" => Json::Bool(true),
            "false" => Json::Bool(false),
            _ => match raw.parse::<f64>() {
                Ok(n) => Json::Num(n),
                Err(_) => Json::Str(raw.to_string()),
            },
        };
        self.apply(key, &value)
            .map_err(|e| anyhow::anyhow!("override --{}={raw}: {e}", key))
    }
}

// --- json field helpers ----------------------------------------------------

fn f64_of(v: &Json, key: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow::anyhow!("{key} must be a number"))
}

fn bool_of(v: &Json, key: &str) -> Result<bool> {
    v.as_bool().ok_or_else(|| anyhow::anyhow!("{key} must be true or false"))
}

/// A non-negative integer (rejects fractional and negative numbers).
fn count_of(v: &Json, key: &str) -> Result<usize> {
    let n = f64_of(v, key)?;
    ensure!(
        n.is_finite() && n >= 0.0 && n.fract() == 0.0,
        "{key} must be a non-negative integer, got {n}"
    );
    Ok(n as usize)
}

fn req_count(v: &Json, key: &str) -> Result<usize> {
    count_of(v.req(key)?, key)
}

fn opt_count(v: &Json, key: &str) -> Result<Option<usize>> {
    v.get(key).map(|x| count_of(x, key)).transpose()
}

fn opt_f64(v: &Json, key: &str) -> Result<Option<f64>> {
    v.get(key).map(|x| f64_of(x, key)).transpose()
}

fn opt_bool(v: &Json, key: &str) -> Result<Option<bool>> {
    v.get(key).map(|x| bool_of(x, key)).transpose()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ExperimentSpec {
        ExperimentSpec {
            name: "tiny".into(),
            method: Method::TransE,
            algo: AlgoSpec::feds(),
            data: DataSpec {
                entities: 192,
                relations: 12,
                triples: 2400,
                clusters: 4,
                clients: 3,
                seed: 7,
            },
            backend: BackendSpec::Native {
                dim: 16,
                learning_rate: 5e-3,
                batch: 64,
                negatives: 16,
                eval_batch: 32,
            },
            budget: BudgetSpec {
                max_rounds: 6,
                local_epochs: 1,
                eval_every: 2,
                patience: 3,
                eval_cap: 64,
            },
            seed: 7,
            exec: ExecMode::Sequential,
            transport: TransportSpec::Mpsc,
            shards: 0,
            participation: Default::default(),
            storage: Default::default(),
            compression: Default::default(),
        }
    }

    #[test]
    fn round_trips_through_json() {
        let spec = tiny_spec();
        let rt = ExperimentSpec::parse(&spec.to_json().to_string_pretty()).unwrap();
        assert_eq!(spec, rt);
        let rt2 = ExperimentSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(spec, rt2);
    }

    #[test]
    fn algo_labels_parse_to_default_knobs() {
        assert_eq!(AlgoSpec::parse("feds").unwrap(), AlgoSpec::feds());
        assert_eq!(
            AlgoSpec::parse("feds-nosync").unwrap(),
            AlgoSpec::FedS { sparsity: 0.4, sync_interval: 4, sync: false }
        );
        assert_eq!(AlgoSpec::parse("fedsvd+").unwrap(), AlgoSpec::Svd { cols: 8, plus: true });
        assert_eq!(AlgoSpec::parse("fedep").unwrap(), AlgoSpec::FedEP);
        assert!(AlgoSpec::parse("bogus").is_err());
    }

    #[test]
    fn scoped_knobs_reject_wrong_family() {
        let mut spec = tiny_spec();
        spec.algo = AlgoSpec::FedEP;
        assert!(spec.apply("algo.sparsity", &Json::Num(0.5)).is_err());
        assert!(spec.apply("algo.cols", &Json::Num(4.0)).is_err());
        spec.algo = AlgoSpec::feds();
        spec.apply("algo.sparsity", &Json::Num(0.5)).unwrap();
        assert_eq!(spec.algo, AlgoSpec::FedS { sparsity: 0.5, sync_interval: 4, sync: true });
    }

    #[test]
    fn unknown_algo_knob_rejected_in_json() {
        // sparsity is not a fedep knob: scoped configs make this a hard error
        let j = Json::parse(r#"{"kind": "fedep", "sparsity": 0.4}"#).unwrap();
        assert!(AlgoSpec::from_json(&j).is_err());
    }

    #[test]
    fn out_of_range_knobs_rejected() {
        for bad in [0.0, -0.2, 1.5, f64::NAN] {
            let a = AlgoSpec::FedS { sparsity: bad, sync_interval: 4, sync: true };
            assert!(a.validate().is_err(), "sparsity {bad} must be rejected");
        }
        let a = AlgoSpec::FedS { sparsity: 0.4, sync_interval: 0, sync: true };
        assert!(a.validate().is_err(), "sync_interval 0 must be rejected");
        let a = AlgoSpec::Svd { cols: 0, plus: false };
        assert!(a.validate().is_err(), "svd cols 0 must be rejected");
    }

    #[test]
    fn overrides_cover_every_section() {
        let mut spec = tiny_spec();
        spec.apply("method", &Json::from("rotate")).unwrap();
        spec.apply("data.clients", &Json::from(5usize)).unwrap();
        spec.apply("budget.max_rounds", &Json::from(9usize)).unwrap();
        spec.apply("backend.batch", &Json::from(32usize)).unwrap();
        spec.apply("algo", &Json::from("fedep")).unwrap();
        spec.apply("exec", &Json::from("threaded")).unwrap();
        assert_eq!(spec.method, Method::RotatE);
        assert_eq!(spec.data.clients, 5);
        assert_eq!(spec.budget.max_rounds, 9);
        assert_eq!(spec.algo, AlgoSpec::FedEP);
        assert_eq!(spec.exec, ExecMode::Threaded);
        assert!(spec.apply("nope.key", &Json::Null).is_err());
        // fractional counts are rejected, not truncated
        assert!(spec.apply("data.clients", &Json::Num(2.5)).is_err());
        // restating the current backend kind as a label keeps its knobs
        let before = spec.backend.clone();
        spec.apply("backend", &Json::from("native")).unwrap();
        assert_eq!(spec.backend, before, "--backend native must not reset native knobs");
        spec.apply("backend", &Json::from("xla")).unwrap();
        assert_eq!(spec.backend, BackendSpec::Xla, "kind changes still switch backends");
    }

    #[test]
    fn transport_and_shards_round_trip_and_override() {
        let mut spec = tiny_spec();
        spec.transport = TransportSpec::Tcp;
        spec.shards = 4;
        let rt = ExperimentSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(rt.transport, TransportSpec::Tcp);
        assert_eq!(rt.shards, 4);
        assert_eq!(spec, rt);

        let mut spec = tiny_spec();
        assert_eq!(spec.transport, TransportSpec::Mpsc, "mpsc is the default");
        spec.apply("transport", &Json::from("tcp")).unwrap();
        assert_eq!(spec.transport, TransportSpec::Tcp);
        spec.apply("shards", &Json::from(8usize)).unwrap();
        assert_eq!(spec.shards, 8);
        assert!(spec.apply("transport", &Json::from("carrier-pigeon")).is_err());
        assert!(spec.apply("shards", &Json::Num(2.5)).is_err(), "fractional shards rejected");

        // a spec file without the keys parses to the defaults
        let j = tiny_spec().to_json();
        let Json::Obj(entries) = j else { panic!() };
        let trimmed = Json::Obj(
            entries
                .into_iter()
                .filter(|(k, _)| k != "transport" && k != "shards")
                .collect(),
        );
        let rt = ExperimentSpec::from_json(&trimmed).unwrap();
        assert_eq!(rt.transport, TransportSpec::Mpsc);
        assert_eq!(rt.shards, 0);
    }

    #[test]
    fn storage_round_trips_and_overrides() {
        let mut spec = tiny_spec();
        assert_eq!(spec.storage, StorageSpec::Ram, "ram is the default");
        spec.storage = StorageSpec::Mmap { dir: Some("/tmp/feds".into()) };
        let rt = ExperimentSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(rt.storage, spec.storage);
        assert_eq!(spec, rt);

        let mut spec = tiny_spec();
        spec.apply("storage", &Json::from("mmap")).unwrap();
        assert_eq!(spec.storage, StorageSpec::Mmap { dir: None });
        assert!(spec.apply("storage", &Json::from("floppy")).is_err());

        // a spec file without the key parses to the in-RAM default
        let j = tiny_spec().to_json();
        let Json::Obj(entries) = j else { panic!() };
        let trimmed = Json::Obj(entries.into_iter().filter(|(k, _)| k != "storage").collect());
        assert_eq!(ExperimentSpec::from_json(&trimmed).unwrap().storage, StorageSpec::Ram);
    }

    #[test]
    fn compression_round_trips_and_overrides() {
        let mut spec = tiny_spec();
        assert!(spec.compression.is_empty(), "no compression is the default");
        spec.algo = AlgoSpec::FedEP;
        spec.compression = PipelineSpec::parse("topk@0.7,int8:ef").unwrap();
        let rt = ExperimentSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(rt.compression.label(), "topk@0.7,int8:ef");
        assert_eq!(spec, rt);

        let mut spec = tiny_spec();
        spec.algo = AlgoSpec::FedEP;
        spec.apply("compression", &Json::from("topk,fp16")).unwrap();
        assert_eq!(spec.compression.label(), "topk@0.4,fp16");
        assert!(spec.apply("compression", &Json::from("gzip")).is_err());
        spec.apply("compression", &Json::from("")).unwrap();
        assert!(spec.compression.is_empty(), "--compress \"\" clears the pipeline");

        // a spec file without the key parses to the empty pipeline
        let j = tiny_spec().to_json();
        let Json::Obj(entries) = j else { panic!() };
        let trimmed =
            Json::Obj(entries.into_iter().filter(|(k, _)| k != "compression").collect());
        assert!(ExperimentSpec::from_json(&trimmed).unwrap().compression.is_empty());
    }

    #[test]
    fn compression_scopes_to_the_dense_family() {
        let mut spec = tiny_spec();
        spec.compression = PipelineSpec::parse("topk,int8").unwrap();
        assert!(spec.validate().is_err(), "feds carries its own Top-K transport");
        spec.algo = AlgoSpec::Svd { cols: 8, plus: false };
        assert!(spec.validate().is_err(), "svd carries its own low-rank transport");
        spec.algo = AlgoSpec::Single;
        assert!(spec.validate().is_err(), "single has no communication to compress");
        spec.algo = AlgoSpec::FedEP;
        spec.validate().unwrap();
        spec.algo = AlgoSpec::FedEPL;
        spec.validate().unwrap();
    }

    #[test]
    fn kd_requires_xla() {
        let mut spec = tiny_spec();
        spec.algo = AlgoSpec::Kd;
        assert!(spec.validate().is_err());
        spec.backend = BackendSpec::Xla;
        spec.validate().unwrap();
    }
}
