//! `LocalTrainer` over the PJRT runtime — the production path.
//!
//! Model state (entity/relation tables + Adam moments) lives as XLA
//! `Literal`s that round-trip directly between executions; the decomposed
//! output tuple of step *t* becomes the input of step *t+1* with no host
//! copy.  A lazily synchronized host mirror of the entity table serves the
//! federated layer's row reads/writes (once per communication round).

use std::rc::Rc;

use anyhow::Result;

use crate::data::dataset::{Batch, EvalBatch};
use crate::kge::{Hyper, Method, Table};
use crate::runtime::{
    lit_f32, lit_i32, lit_scalar_f32, read_f32_into, scalar_f32, to_vec_f32, write_f32,
    ArtifactMeta, Role, Runtime,
};
use crate::store::StoreTable;
use crate::util::rng::Rng;

use super::LocalTrainer;

pub struct XlaTrainer {
    rt: Rc<Runtime>,
    method: Method,
    pub hyper: Hyper,
    train_meta: ArtifactMeta,
    epoch_meta: Option<ArtifactMeta>,
    eval_meta: ArtifactMeta,
    change_meta: Option<ArtifactMeta>,
    /// [ent, rel, ent_m, ent_v, rel_m, rel_v]
    state: Vec<xla::Literal>,
    step: u64,
    num_entities: usize,
    entity_width: usize,
    /// lazily synced host mirror of the entity table
    host_ent: Vec<f32>,
    host_valid: bool,
    host_dirty: bool,
}

impl XlaTrainer {
    /// Build a trainer at the given dimension (base dim for FedE/FedS,
    /// `manifest.fedepl_dim` for the FedEPL baseline).
    pub fn new(rt: Rc<Runtime>, method: Method, dim: usize, rng: &mut Rng) -> Result<Self> {
        let m = &rt.manifest;
        let train_meta = m.find(Role::Train, method, dim)?.clone();
        let epoch_meta = m.find(Role::TrainEpoch, method, dim).ok().cloned();
        let eval_meta = m.find(Role::Eval, method, dim)?.clone();
        let change_meta = m.find(Role::Change, method, dim).ok().cloned();
        let hyper = m.hyper_at_dim(dim);
        let (e, r) = (m.num_entities, m.num_relations);
        let we = train_meta.entity_width;
        let wr = train_meta.relation_width;
        let range = hyper.embedding_range();

        // same init path as NativeModel (Table::init_uniform with the same
        // rng stream) so a shared seed gives bit-identical starting tables
        let ent = Table::init_uniform(e, we, range, rng);
        let rel = Table::init_uniform(r, wr, range, rng);

        let state = vec![
            lit_f32(&ent.data, &[e as i64, we as i64])?,
            lit_f32(&rel.data, &[r as i64, wr as i64])?,
            lit_f32(&vec![0.0; e * we], &[e as i64, we as i64])?,
            lit_f32(&vec![0.0; e * we], &[e as i64, we as i64])?,
            lit_f32(&vec![0.0; r * wr], &[r as i64, wr as i64])?,
            lit_f32(&vec![0.0; r * wr], &[r as i64, wr as i64])?,
        ];
        Ok(Self {
            rt,
            method,
            hyper,
            train_meta,
            epoch_meta,
            eval_meta,
            change_meta,
            state,
            step: 0,
            num_entities: e,
            entity_width: we,
            host_ent: vec![0.0; e * we],
            host_valid: false,
            host_dirty: false,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.train_meta.batch
    }

    pub fn negatives(&self) -> usize {
        self.train_meta.negatives
    }

    /// Push pending host-side entity edits back into device state.
    fn flush_host(&mut self) -> Result<()> {
        if self.host_dirty {
            write_f32(&mut self.state[0], &self.host_ent)?;
            self.host_dirty = false;
        }
        Ok(())
    }

    /// Make the host mirror current.
    fn ensure_host(&mut self) -> Result<()> {
        if !self.host_valid {
            read_f32_into(&self.state[0], &mut self.host_ent)?;
            self.host_valid = true;
        }
        Ok(())
    }
}

impl LocalTrainer for XlaTrainer {
    fn method(&self) -> Method {
        self.method
    }

    fn entity_width(&self) -> usize {
        self.entity_width
    }

    fn num_entities(&self) -> usize {
        self.num_entities
    }

    fn eval_batch_size(&self) -> usize {
        self.eval_meta.eval_batch
    }

    fn train_batch(&mut self, batch: &Batch) -> Result<f32> {
        anyhow::ensure!(
            batch.batch_size == self.train_meta.batch
                && batch.negatives == self.train_meta.negatives,
            "batch shape ({}, {}) does not match artifact ({}, {})",
            batch.batch_size,
            batch.negatives,
            self.train_meta.batch,
            self.train_meta.negatives
        );
        self.flush_host()?;
        self.step += 1;
        let b = batch.batch_size as i64;
        let n = batch.negatives as i64;
        let inputs = [
            &self.state[0],
            &self.state[1],
            &self.state[2],
            &self.state[3],
            &self.state[4],
            &self.state[5],
            &lit_scalar_f32(self.step as f32),
            &lit_i32(&batch.pos, &[b, 3])?,
            &lit_i32(&batch.neg, &[b, n])?,
            &lit_f32(&batch.neg_is_head, &[b])?,
            &lit_f32(&batch.mask, &[b])?,
        ];
        let mut out = self.rt.execute_refs(&self.train_meta, &inputs)?;
        let loss = scalar_f32(&out[6])?;
        out.truncate(6);
        self.state = out;
        self.host_valid = false;
        Ok(loss)
    }

    /// Scan-fused local training: batches are stacked into (S, B, …) inputs
    /// and executed `ceil(n/S)` times, with fully-masked padding steps that
    /// the artifact skips exactly (tables + Adam step pass through).  State
    /// tables cross the PJRT boundary once per call instead of once per
    /// batch — the §Perf hot-path optimization.
    fn train_batches(&mut self, batches: &[Batch]) -> Result<f32> {
        let Some(meta) = self.epoch_meta.clone() else {
            // no epoch artifact at this dim — fall back to single steps
            let mut total = 0.0;
            for b in batches {
                total += self.train_batch(b)?;
            }
            return Ok(if batches.is_empty() { 0.0 } else { total / batches.len() as f32 });
        };
        if batches.is_empty() {
            return Ok(0.0);
        }
        let s = meta.scan_steps.unwrap_or(1);
        let b = meta.batch;
        let n = meta.negatives;
        self.flush_host()?;

        let mut loss_sum = 0.0f64;
        let mut loss_chunks = 0usize;
        for chunk in batches.chunks(s) {
            for batch in chunk {
                anyhow::ensure!(
                    batch.batch_size == b && batch.negatives == n,
                    "batch shape mismatch vs epoch artifact"
                );
            }
            let mut pos = vec![0i32; s * b * 3];
            let mut neg = vec![0i32; s * b * n];
            let mut nih = vec![0f32; s * b];
            let mut mask = vec![0f32; s * b];
            for (i, batch) in chunk.iter().enumerate() {
                pos[i * b * 3..(i + 1) * b * 3].copy_from_slice(&batch.pos);
                neg[i * b * n..(i + 1) * b * n].copy_from_slice(&batch.neg);
                nih[i * b..(i + 1) * b].copy_from_slice(&batch.neg_is_head);
                mask[i * b..(i + 1) * b].copy_from_slice(&batch.mask);
            }
            let (si, bi, ni) = (s as i64, b as i64, n as i64);
            let inputs = [
                &self.state[0],
                &self.state[1],
                &self.state[2],
                &self.state[3],
                &self.state[4],
                &self.state[5],
                &lit_scalar_f32(self.step as f32),
                &lit_i32(&pos, &[si, bi, 3])?,
                &lit_i32(&neg, &[si, bi, ni])?,
                &lit_f32(&nih, &[si, bi])?,
                &lit_f32(&mask, &[si, bi])?,
            ];
            let mut out = self.rt.execute_refs(&meta, &inputs)?;
            let steps_done = scalar_f32(&out[7])?;
            loss_sum += scalar_f32(&out[6])? as f64;
            loss_chunks += 1;
            out.truncate(6);
            self.state = out;
            self.step += steps_done as u64;
        }
        self.host_valid = false;
        Ok((loss_sum / loss_chunks as f64) as f32)
    }

    fn eval_ranks(&mut self, eb: &EvalBatch) -> Result<Vec<f32>> {
        anyhow::ensure!(
            eb.eval_batch == self.eval_meta.eval_batch,
            "eval batch {} does not match artifact {}",
            eb.eval_batch,
            self.eval_meta.eval_batch
        );
        self.flush_host()?;
        let q = eb.eval_batch as i64;
        let e = self.num_entities as i64;
        let inputs = [
            &self.state[0],
            &self.state[1],
            &lit_i32(&eb.src, &[q])?,
            &lit_i32(&eb.rel, &[q])?,
            &lit_i32(&eb.truth, &[q])?,
            &lit_f32(&eb.pred_head, &[q])?,
            &lit_f32(&eb.filter, &[q, e])?,
        ];
        let out = self.rt.execute_refs(&self.eval_meta, &inputs)?;
        to_vec_f32(&out[0])
    }

    fn get_entity_rows(&mut self, ids: &[u32]) -> Result<Vec<f32>> {
        self.ensure_host()?;
        let w = self.entity_width;
        let mut out = Vec::with_capacity(ids.len() * w);
        for &id in ids {
            let i = id as usize;
            out.extend_from_slice(&self.host_ent[i * w..(i + 1) * w]);
        }
        Ok(out)
    }

    fn set_entity_rows(&mut self, ids: &[u32], rows: &[f32]) -> Result<()> {
        let w = self.entity_width;
        anyhow::ensure!(rows.len() == ids.len() * w, "row data size mismatch");
        self.ensure_host()?;
        for (k, &id) in ids.iter().enumerate() {
            let i = id as usize;
            self.host_ent[i * w..(i + 1) * w].copy_from_slice(&rows[k * w..(k + 1) * w]);
        }
        self.host_dirty = true;
        Ok(())
    }

    fn change_scores(&mut self, ids: &[u32], hist: &StoreTable) -> Result<Vec<f32>> {
        let meta = self
            .change_meta
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no change artifact at dim {}", self.hyper.dim))?
            .clone();
        anyhow::ensure!(hist.width == self.entity_width, "hist width mismatch");
        self.flush_host()?;
        let e = self.num_entities as i64;
        let w = self.entity_width as i64;
        let hist_lit = lit_f32(hist.as_slice(), &[e, w])?;
        let inputs = [&self.state[0], &hist_lit];
        let out = self.rt.execute_refs(&meta, &inputs)?;
        let all = to_vec_f32(&out[0])?;
        Ok(ids.iter().map(|&id| all[id as usize]).collect())
    }
}
