//! Local-training abstraction: the federated layer drives a `LocalTrainer`
//! without knowing whether steps run on the PJRT runtime (production path,
//! `XlaTrainer`) or the pure-Rust oracle (`NativeTrainer`, used for
//! artifact-free tests and numerics cross-checks).
//!
//! Thread-safety: `NativeTrainer` is plain owned data and therefore
//! `Send`, which is what lets `fed::ExecMode::Threaded` run one client
//! per OS thread.  The XLA trainers hold an `Rc<Runtime>` (the PJRT
//! client is not `Send`), so XLA-backed runs stay sequential.

pub mod kd;
pub mod native;
pub mod xla;

use anyhow::Result;

use crate::data::dataset::{Batch, EvalBatch, EvalSet, FilterIndex};
use crate::kge::Method;
use crate::metrics::RankMetrics;
use crate::store::StoreTable;

pub use kd::KdXlaTrainer;
pub use native::NativeTrainer;
pub use xla::XlaTrainer;

pub trait LocalTrainer {
    fn method(&self) -> Method;
    fn entity_width(&self) -> usize;
    fn num_entities(&self) -> usize;
    /// Required eval-batch row count (XLA artifacts have a fixed shape).
    fn eval_batch_size(&self) -> usize;

    /// One SGD step on a padded batch; returns the loss.
    fn train_batch(&mut self, batch: &Batch) -> Result<f32>;

    /// A whole local-training phase.  Default: loop over `train_batch`.
    /// The XLA trainers override this with the scan-fused `train_epoch`
    /// artifact (one PJRT call per `scan_steps` batches — the §Perf
    /// optimization), with bit-identical semantics.
    fn train_batches(&mut self, batches: &[Batch]) -> Result<f32> {
        let mut total = 0.0;
        for b in batches {
            total += self.train_batch(b)?;
        }
        Ok(if batches.is_empty() { 0.0 } else { total / batches.len() as f32 })
    }

    /// Filtered ranks for a padded eval batch (only the first `eb.len`
    /// entries are meaningful).
    fn eval_ranks(&mut self, eb: &EvalBatch) -> Result<Vec<f32>>;

    /// Cap the OS threads `eval_ranks` may fan its candidate scan across
    /// (0 = auto).  Ranks are bit-identical for any value — this only
    /// tunes wall-clock, so drivers may set it freely (the threaded
    /// orchestrator pins it to 1 to avoid oversubscribing one thread per
    /// client × one per chunk).  Default: no-op for backends without a
    /// native candidate scan.
    fn set_eval_threads(&mut self, _threads: usize) {}

    /// Gather entity rows (concatenated) for the given global ids.
    fn get_entity_rows(&mut self, ids: &[u32]) -> Result<Vec<f32>>;

    /// Overwrite entity rows for the given global ids.
    fn set_entity_rows(&mut self, ids: &[u32], rows: &[f32]) -> Result<()>;

    /// Eq. 1 change scores (1 − cosine vs. the history table) for `ids`.
    /// The history rides a [`StoreTable`] so E-scaled clients can keep it
    /// on the run's storage backend.
    fn change_scores(&mut self, ids: &[u32], hist: &StoreTable) -> Result<Vec<f32>>;
}

/// Evaluate a trainer over a full query set; returns filtered-rank metrics.
pub fn evaluate(
    trainer: &mut dyn LocalTrainer,
    eval_set: &EvalSet,
    filters: &FilterIndex,
) -> Result<RankMetrics> {
    let mut all_ranks = Vec::with_capacity(eval_set.len());
    for eb in eval_set.batches(trainer.eval_batch_size(), filters) {
        let ranks = trainer.eval_ranks(&eb)?;
        all_ranks.extend_from_slice(&ranks[..eb.len.min(ranks.len())]);
    }
    Ok(RankMetrics::from_ranks(&all_ranks))
}

/// Train one epoch (all batches); returns the mean loss.
pub fn train_epoch(
    trainer: &mut dyn LocalTrainer,
    batches: impl Iterator<Item = Batch>,
) -> Result<f32> {
    let mut total = 0.0;
    let mut n = 0;
    for batch in batches {
        total += trainer.train_batch(&batch)?;
        n += 1;
    }
    Ok(if n == 0 { 0.0 } else { total / n as f32 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::BatchIter;
    use crate::data::Triple;
    use crate::kge::Hyper;
    use crate::util::rng::Rng;

    #[test]
    fn evaluate_and_train_epoch_with_native() {
        let mut rng = Rng::new(3);
        let hyper = Hyper { dim: 8, ..Default::default() };
        let mut t = NativeTrainer::new(Method::TransE, hyper, 64, 4, 16, &mut rng);
        let triples: Vec<Triple> = (0..32)
            .map(|i| Triple::new(i % 60, (i % 4) as u32, (i + 1) % 60))
            .collect();
        let ents: Vec<u32> = (0..64).collect();
        let mut r2 = rng.fork(1);
        let loss = train_epoch(
            &mut t,
            BatchIter::new(&triples, &ents, 8, 4, &mut r2),
        )
        .unwrap();
        assert!(loss.is_finite() && loss > 0.0);

        let filters = FilterIndex::build(triples.iter());
        let es = EvalSet::new(&triples, 64);
        let m = evaluate(&mut t, &es, &filters).unwrap();
        assert_eq!(m.n, 64); // 32 triples × 2 directions
        assert!(m.mrr > 0.0 && m.mrr <= 1.0);
    }
}
