//! `LocalTrainer` over the pure-Rust engine (`kge::native::NativeModel`).
//! Used for artifact-free protocol tests, numerics cross-checks, and the
//! SVD+ baseline's constrained local training.
//!
//! Constructing the trainer fixes the model's kernel dispatch for the whole
//! run: `NativeModel::new` selects width-specialized inner-loop kernels
//! (`kge::kernels::KernelSet`) from the method/dimension, so every
//! `train_batch` call goes through the monomorphized fast path without
//! per-step dispatch.

use anyhow::Result;

use crate::data::dataset::{Batch, EvalBatch};
use crate::kge::native::NativeModel;
use crate::kge::{Hyper, Method};
use crate::store::{StorageSpec, StoreTable};
use crate::util::rng::Rng;

use super::LocalTrainer;

pub struct NativeTrainer {
    pub model: NativeModel,
    eval_batch: usize,
}

impl NativeTrainer {
    pub fn new(
        method: Method,
        hyper: Hyper,
        num_entities: usize,
        num_relations: usize,
        eval_batch: usize,
        rng: &mut Rng,
    ) -> Self {
        Self {
            model: NativeModel::new(method, hyper, num_entities, num_relations, rng),
            eval_batch,
        }
    }

    /// Like [`NativeTrainer::new`] with entity-scaled model state on the
    /// selected storage backend (bit-identical across backends).
    pub fn with_store(
        method: Method,
        hyper: Hyper,
        num_entities: usize,
        num_relations: usize,
        eval_batch: usize,
        storage: &StorageSpec,
        rng: &mut Rng,
    ) -> Result<Self> {
        Ok(Self {
            model: NativeModel::with_store(
                method,
                hyper,
                num_entities,
                num_relations,
                storage,
                rng,
            )?,
            eval_batch,
        })
    }
}

impl LocalTrainer for NativeTrainer {
    fn method(&self) -> Method {
        self.model.method
    }

    fn entity_width(&self) -> usize {
        self.model.ent.width
    }

    fn num_entities(&self) -> usize {
        self.model.ent.rows
    }

    fn eval_batch_size(&self) -> usize {
        self.eval_batch
    }

    fn train_batch(&mut self, batch: &Batch) -> Result<f32> {
        Ok(self.model.train_batch(batch))
    }

    fn eval_ranks(&mut self, eb: &EvalBatch) -> Result<Vec<f32>> {
        Ok(self.model.eval_ranks(eb))
    }

    fn set_eval_threads(&mut self, threads: usize) {
        self.model.eval_threads = threads;
    }

    fn get_entity_rows(&mut self, ids: &[u32]) -> Result<Vec<f32>> {
        let w = self.model.ent.width;
        let mut out = Vec::with_capacity(ids.len() * w);
        for &id in ids {
            out.extend_from_slice(self.model.ent.row(id as usize));
        }
        Ok(out)
    }

    fn set_entity_rows(&mut self, ids: &[u32], rows: &[f32]) -> Result<()> {
        let w = self.model.ent.width;
        anyhow::ensure!(rows.len() == ids.len() * w, "row data size mismatch");
        for (i, &id) in ids.iter().enumerate() {
            self.model.ent.set_row(id as usize, &rows[i * w..(i + 1) * w]);
        }
        Ok(())
    }

    fn change_scores(&mut self, ids: &[u32], hist: &StoreTable) -> Result<Vec<f32>> {
        anyhow::ensure!(hist.width == self.model.ent.width, "hist width mismatch");
        Ok(ids
            .iter()
            .map(|&id| {
                crate::linalg::change_score(
                    self.model.ent.row(id as usize),
                    hist.row(id as usize),
                )
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trainer() -> NativeTrainer {
        let mut rng = Rng::new(1);
        NativeTrainer::new(
            Method::RotatE,
            Hyper { dim: 4, ..Default::default() },
            16,
            2,
            8,
            &mut rng,
        )
    }

    #[test]
    fn get_set_roundtrip() {
        let mut t = trainer();
        let ids = vec![3u32, 7, 11];
        let rows: Vec<f32> = (0..ids.len() * t.entity_width())
            .map(|i| i as f32)
            .collect();
        t.set_entity_rows(&ids, &rows).unwrap();
        assert_eq!(t.get_entity_rows(&ids).unwrap(), rows);
        // untouched row unchanged
        let other = t.get_entity_rows(&[0]).unwrap();
        assert_ne!(other[..4], rows[..4]);
    }

    #[test]
    fn change_scores_zero_for_identical() {
        let mut t = trainer();
        let hist = StoreTable::from_vec(16, t.entity_width(), t.model.ent.to_vec());
        let scores = t.change_scores(&[0, 5, 9], &hist).unwrap();
        for s in scores {
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn change_scores_positive_after_modification() {
        let mut t = trainer();
        let hist = StoreTable::from_vec(16, t.entity_width(), t.model.ent.to_vec());
        let w = t.entity_width();
        let newrow: Vec<f32> = (0..w).map(|i| (i as f32) - 3.0).collect();
        t.set_entity_rows(&[5], &newrow).unwrap();
        let scores = t.change_scores(&[0, 5], &hist).unwrap();
        assert!(scores[0].abs() < 1e-6);
        assert!(scores[1] > 1e-4);
    }

    #[test]
    fn size_mismatch_errors() {
        let mut t = trainer();
        assert!(t.set_entity_rows(&[1, 2], &[0.0; 3]).is_err());
    }

    #[test]
    fn construction_fixes_kernel_dispatch() {
        use crate::kge::kernels::Kernel;
        // RotatE at dim 64 → entity width 128: full span Fixed128, re‖im
        // half span Fixed64. Selected once here, never re-dispatched.
        let mut rng = Rng::new(2);
        let t = NativeTrainer::new(
            Method::RotatE,
            Hyper { dim: 64, ..Default::default() },
            16,
            2,
            8,
            &mut rng,
        );
        assert_eq!(t.model.kernels.full, Kernel::Fixed128);
        assert_eq!(t.model.kernels.half, Kernel::Fixed64);
        assert!(!t.model.kernels.is_scalar());
        // the odd dim-4 fixture falls back to the lane-generic path
        assert_eq!(trainer().model.kernels.full, Kernel::Lanes);
    }

    #[test]
    fn native_trainer_is_send() {
        // the threaded orchestrator moves one trainer per client onto an
        // OS thread; this must never regress
        fn assert_send<T: Send>() {}
        assert_send::<NativeTrainer>();
    }
}
