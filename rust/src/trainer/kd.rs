//! `LocalTrainer` for the FedE-KD baseline (paper Appendix VI-A): each
//! client co-trains a high-dimensional model (kept local, used for
//! evaluation) and a low-dimensional model (the transport representation)
//! with mutual distillation, via the `train_kd_*` artifact.
//!
//! The trait's entity-row accessors operate on the **low** table — that is
//! what FedE-KD uploads/downloads — so the dense federated loop works
//! unchanged and the parameter accounting automatically reflects the
//! reduced transport width.

use std::rc::Rc;

use anyhow::Result;

use crate::data::dataset::{Batch, EvalBatch};
use crate::kge::{Method, Table};
use crate::runtime::{
    lit_f32, lit_i32, lit_scalar_f32, read_f32_into, scalar_f32, to_vec_f32, write_f32,
    ArtifactMeta, Role, Runtime,
};
use crate::store::StoreTable;
use crate::util::rng::Rng;

use super::LocalTrainer;

pub struct KdXlaTrainer {
    rt: Rc<Runtime>,
    method: Method,
    train_meta: ArtifactMeta,
    epoch_meta: Option<ArtifactMeta>,
    eval_meta: ArtifactMeta,
    /// [ent_h, rel_h, ent_h_m, ent_h_v, rel_h_m, rel_h_v,
    ///  ent_l, rel_l, ent_l_m, ent_l_v, rel_l_m, rel_l_v]
    state: Vec<xla::Literal>,
    step: u64,
    num_entities: usize,
    lo_width: usize,
    host_lo: Vec<f32>,
    host_valid: bool,
    host_dirty: bool,
}

impl KdXlaTrainer {
    pub fn new(rt: Rc<Runtime>, method: Method, rng: &mut Rng) -> Result<Self> {
        let m = &rt.manifest;
        let train_meta = m.find(Role::TrainKd, method, m.hyper.dim)?.clone();
        let epoch_meta = m.find(Role::TrainKdEpoch, method, m.hyper.dim).ok().cloned();
        let eval_meta = m.find(Role::Eval, method, m.hyper.dim)?.clone();
        let kd_dim = train_meta
            .kd_dim
            .ok_or_else(|| anyhow::anyhow!("KD artifact missing kd_dim"))?;
        let we_h = train_meta.entity_width;
        let wr_h = train_meta.relation_width;
        let we_l = train_meta
            .kd_entity_width
            .unwrap_or_else(|| method.entity_width(kd_dim));
        let wr_l = train_meta
            .kd_relation_width
            .unwrap_or_else(|| method.relation_width(kd_dim));
        let (e, r) = (m.num_entities, m.num_relations);
        let hyper_h = m.hyper.clone();
        let hyper_l = m.hyper_at_dim(kd_dim);

        let ent_h = Table::init_uniform(e, we_h, hyper_h.embedding_range(), rng);
        let rel_h = Table::init_uniform(r, wr_h, hyper_h.embedding_range(), rng);
        let ent_l = Table::init_uniform(e, we_l, hyper_l.embedding_range(), rng);
        let rel_l = Table::init_uniform(r, wr_l, hyper_l.embedding_range(), rng);

        let z = |rows: usize, w: usize| lit_f32(&vec![0.0; rows * w], &[rows as i64, w as i64]);
        let state = vec![
            lit_f32(&ent_h.data, &[e as i64, we_h as i64])?,
            lit_f32(&rel_h.data, &[r as i64, wr_h as i64])?,
            z(e, we_h)?,
            z(e, we_h)?,
            z(r, wr_h)?,
            z(r, wr_h)?,
            lit_f32(&ent_l.data, &[e as i64, we_l as i64])?,
            lit_f32(&rel_l.data, &[r as i64, wr_l as i64])?,
            z(e, we_l)?,
            z(e, we_l)?,
            z(r, wr_l)?,
            z(r, wr_l)?,
        ];
        Ok(Self {
            rt,
            method,
            train_meta,
            epoch_meta,
            eval_meta,
            state,
            step: 0,
            num_entities: e,
            lo_width: we_l,
            host_lo: vec![0.0; e * we_l],
            host_valid: false,
            host_dirty: false,
        })
    }

    fn flush_host(&mut self) -> Result<()> {
        if self.host_dirty {
            write_f32(&mut self.state[6], &self.host_lo)?;
            self.host_dirty = false;
        }
        Ok(())
    }

    fn ensure_host(&mut self) -> Result<()> {
        if !self.host_valid {
            read_f32_into(&self.state[6], &mut self.host_lo)?;
            self.host_valid = true;
        }
        Ok(())
    }
}

impl LocalTrainer for KdXlaTrainer {
    fn method(&self) -> Method {
        self.method
    }

    /// Transport width: the low-dimensional table's row width.
    fn entity_width(&self) -> usize {
        self.lo_width
    }

    fn num_entities(&self) -> usize {
        self.num_entities
    }

    fn eval_batch_size(&self) -> usize {
        self.eval_meta.eval_batch
    }

    fn train_batch(&mut self, batch: &Batch) -> Result<f32> {
        self.flush_host()?;
        self.step += 1;
        let b = batch.batch_size as i64;
        let n = batch.negatives as i64;
        let step_lit = lit_scalar_f32(self.step as f32);
        let pos = lit_i32(&batch.pos, &[b, 3])?;
        let neg = lit_i32(&batch.neg, &[b, n])?;
        let nih = lit_f32(&batch.neg_is_head, &[b])?;
        let mask = lit_f32(&batch.mask, &[b])?;
        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.extend([&step_lit, &pos, &neg, &nih, &mask]);
        let mut out = self.rt.execute_refs(&self.train_meta, &inputs)?;
        let loss = scalar_f32(&out[12])?;
        out.truncate(12);
        self.state = out;
        self.host_valid = false;
        Ok(loss)
    }

    /// Scan-fused KD local training (see `XlaTrainer::train_batches`).
    fn train_batches(&mut self, batches: &[Batch]) -> Result<f32> {
        let Some(meta) = self.epoch_meta.clone() else {
            let mut total = 0.0;
            for b in batches {
                total += self.train_batch(b)?;
            }
            return Ok(if batches.is_empty() { 0.0 } else { total / batches.len() as f32 });
        };
        if batches.is_empty() {
            return Ok(0.0);
        }
        let s = meta.scan_steps.unwrap_or(1);
        let b = meta.batch;
        let n = meta.negatives;
        self.flush_host()?;
        let mut loss_sum = 0.0f64;
        let mut chunks = 0usize;
        for chunk in batches.chunks(s) {
            let mut pos = vec![0i32; s * b * 3];
            let mut neg = vec![0i32; s * b * n];
            let mut nih = vec![0f32; s * b];
            let mut mask = vec![0f32; s * b];
            for (i, batch) in chunk.iter().enumerate() {
                anyhow::ensure!(
                    batch.batch_size == b && batch.negatives == n,
                    "batch shape mismatch vs KD epoch artifact"
                );
                pos[i * b * 3..(i + 1) * b * 3].copy_from_slice(&batch.pos);
                neg[i * b * n..(i + 1) * b * n].copy_from_slice(&batch.neg);
                nih[i * b..(i + 1) * b].copy_from_slice(&batch.neg_is_head);
                mask[i * b..(i + 1) * b].copy_from_slice(&batch.mask);
            }
            let (si, bi, ni) = (s as i64, b as i64, n as i64);
            let step_lit = lit_scalar_f32(self.step as f32);
            let pos_l = lit_i32(&pos, &[si, bi, 3])?;
            let neg_l = lit_i32(&neg, &[si, bi, ni])?;
            let nih_l = lit_f32(&nih, &[si, bi])?;
            let mask_l = lit_f32(&mask, &[si, bi])?;
            let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
            inputs.extend([&step_lit, &pos_l, &neg_l, &nih_l, &mask_l]);
            let mut out = self.rt.execute_refs(&meta, &inputs)?;
            let steps_done = scalar_f32(&out[13])?;
            loss_sum += scalar_f32(&out[12])? as f64;
            chunks += 1;
            out.truncate(12);
            self.state = out;
            self.step += steps_done as u64;
        }
        self.host_valid = false;
        Ok((loss_sum / chunks as f64) as f32)
    }

    /// Evaluation uses the HIGH-dimensional model (the client's best local
    /// predictor), matching Appendix VI-A.
    fn eval_ranks(&mut self, eb: &EvalBatch) -> Result<Vec<f32>> {
        let q = eb.eval_batch as i64;
        let e = self.num_entities as i64;
        let inputs = [
            &self.state[0],
            &self.state[1],
            &lit_i32(&eb.src, &[q])?,
            &lit_i32(&eb.rel, &[q])?,
            &lit_i32(&eb.truth, &[q])?,
            &lit_f32(&eb.pred_head, &[q])?,
            &lit_f32(&eb.filter, &[q, e])?,
        ];
        let out = self.rt.execute_refs(&self.eval_meta, &inputs)?;
        to_vec_f32(&out[0])
    }

    fn get_entity_rows(&mut self, ids: &[u32]) -> Result<Vec<f32>> {
        self.ensure_host()?;
        let w = self.lo_width;
        let mut out = Vec::with_capacity(ids.len() * w);
        for &id in ids {
            let i = id as usize;
            out.extend_from_slice(&self.host_lo[i * w..(i + 1) * w]);
        }
        Ok(out)
    }

    fn set_entity_rows(&mut self, ids: &[u32], rows: &[f32]) -> Result<()> {
        let w = self.lo_width;
        anyhow::ensure!(rows.len() == ids.len() * w, "row data size mismatch");
        self.ensure_host()?;
        for (k, &id) in ids.iter().enumerate() {
            let i = id as usize;
            self.host_lo[i * w..(i + 1) * w].copy_from_slice(&rows[k * w..(k + 1) * w]);
        }
        self.host_dirty = true;
        Ok(())
    }

    fn change_scores(&mut self, _ids: &[u32], _hist: &StoreTable) -> Result<Vec<f32>> {
        anyhow::bail!("FedE-KD does not sparsify; change scores are undefined")
    }
}
